"""The Apriori serving stack: rulebook -> batch engine -> online gateway.

Public surface (DESIGN.md §8/§10): compile/load a :class:`Rulebook`, answer
pre-assembled batches with :func:`recommend`, or serve independent online
queries through a :class:`Gateway` (micro-batching, exact-basket cache,
live rulebook hot-swap, supervised dispatch worker — see
``distributed.supervisor``).
"""

from repro.serving.batcher import AdmissionRejected, MicroBatcher, Request, WorkerCrashed
from repro.serving.cache import BasketCache, basket_key
from repro.serving.gateway import Gateway, Response, pow2_bucket
from repro.serving.metrics import GatewayMetrics, LatencyHistogram
from repro.serving.recommend import (
    RecommendResult,
    make_match_step,
    pack_baskets,
    recommend,
    recommend_python,
)
from repro.serving.rulebook import Rulebook, compile_rulebook, place_rulebook

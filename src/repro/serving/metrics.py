"""Serving-side observability: latency histograms + gateway counters (§10).

Backed by the shared :mod:`repro.obs` substrate since §13: every counter
and the latency histogram live in one :class:`~repro.obs.MetricsRegistry`
whose re-entrant lock makes ``snapshot()`` **atomic across the whole metric
set** — a concurrent writer can never produce a torn snapshot where
``batch_rows_real`` comes from before a dispatch and ``batch_rows_padded``
from after it, and the derived ``batch_occupancy`` / ``cache_hit_rate`` are
computed from the same consistent cut.  The snapshot JSON shape is
unchanged; counters still read as plain attributes (``metrics.submitted``).

:class:`LatencyHistogram` is the registry histogram (log-bucketed,
conservative bucket-upper-edge quantiles — see ``obs/registry.py``), which
also gives it **merge**: the router aggregates replica latency histograms
by bucket-wise addition instead of re-measuring.
"""

from __future__ import annotations

import time
from typing import Optional

from repro.obs.registry import (
    FLOOR_S as _FLOOR_S,       # re-exported for back-compat
    GROWTH as _GROWTH,
    NUM_BUCKETS as _NUM_BUCKETS,
    Gauge,
    Histogram,
    MetricsRegistry,
)


class LatencyHistogram(Histogram):
    """Log-bucketed latency histogram with exact count/sum/min/max."""

    def __init__(self, name: str = "latency_seconds", labels=None, lock=None):
        super().__init__(name, labels, lock=lock)


class GenerationAgeGauge(Gauge):
    """Live rulebook-freshness gauge (ROADMAP): seconds since the serving
    generation was committed.  ``mark()`` stamps the commit instant; reads
    compute the age at read time, so every snapshot/exposition sees the
    CURRENT age without anyone having to poll-update a stored value — the
    freshness SLO's signal can never go stale itself."""

    def __init__(self, name: str = "generation_age_seconds", labels=None, lock=None):
        super().__init__(name, labels, lock=lock)
        self._commit_t = time.perf_counter()

    def mark(self) -> None:
        with self._lock:
            self._commit_t = time.perf_counter()

    @property
    def value(self) -> float:
        with self._lock:
            return time.perf_counter() - self._commit_t


class _RegistryMetrics:
    """Base for counter bundles: registry-backed counters readable as plain
    attributes, with one lock covering every metric for atomic snapshots."""

    _COUNTER_FIELDS: tuple = ()

    def __init__(self, registry: Optional[MetricsRegistry] = None, *, prefix: str):
        self.registry = registry if registry is not None else MetricsRegistry()
        self._lock = self.registry.lock
        self._counters = {f: self.registry.counter(f"{prefix}_{f}")
                          for f in self._COUNTER_FIELDS}
        self.latency = self.registry.register(
            LatencyHistogram(f"{prefix}_latency_seconds", lock=self.registry.lock))

    def __getattr__(self, name):
        counters = self.__dict__.get("_counters")
        if counters is not None and name in counters:
            return counters[name].value
        raise AttributeError(f"{type(self).__name__!s} has no attribute {name!r}")

    def _inc(self, field: str, n: int = 1) -> None:
        self._counters[field].inc(n)


class GatewayMetrics(_RegistryMetrics):
    """All gateway counters + the request-latency histogram, one lock."""

    _COUNTER_FIELDS = (
        "submitted",          # admitted into the queue (or served from cache)
        "rejected",           # refused at admission (queue full / closed)
        "completed",          # responses delivered (cache hits included)
        "failed",             # futures resolved with an exception
        "cache_hits",
        "cache_misses",
        "swaps",
        "deadline_expired",   # requests dropped past-deadline at dispatch
        "worker_restarts",    # dead dispatch workers re-armed (§11)
        "batches",            # dispatches through the match step
        "batch_rows_real",    # requests actually in dispatched batches
        "batch_rows_padded",  # rows of the padded jit buckets
    )

    def __init__(self, registry: Optional[MetricsRegistry] = None):
        super().__init__(registry, prefix="gateway")
        self.generation_age = self.registry.register(
            GenerationAgeGauge("gateway_generation_age_seconds",
                               lock=self.registry.lock))

    def mark_generation_commit(self) -> None:
        """Stamp the freshness clock — called when a generation commits
        (initial placement and every hot-swap commit)."""
        self.generation_age.mark()

    def record_admission(self, accepted: bool) -> None:
        self._inc("submitted" if accepted else "rejected")

    def record_cache(self, hit: bool) -> None:
        self._inc("cache_hits" if hit else "cache_misses")

    def record_batch(self, real_rows: int, padded_rows: int) -> None:
        with self._lock:
            self._inc("batches")
            self._inc("batch_rows_real", real_rows)
            self._inc("batch_rows_padded", padded_rows)

    def record_response(self, latency_s: float, failed: bool = False) -> None:
        if failed:
            self._inc("failed")
        else:
            self._inc("completed")
            self.latency.record(latency_s)

    def record_swap(self) -> None:
        with self._lock:
            self._inc("swaps")
            self.generation_age.mark()

    def record_deadline_expired(self) -> None:
        self._inc("deadline_expired")

    def record_worker_restart(self) -> None:
        self._inc("worker_restarts")

    @property
    def batch_occupancy(self) -> float:
        """Real rows / padded bucket rows over all dispatches (1.0 = full).
        Both counters are read in one lock hold — never torn mid-dispatch."""
        with self._lock:
            real = self._counters["batch_rows_real"].value
            padded = self._counters["batch_rows_padded"].value
        return real / padded if padded else 0.0

    @property
    def cache_hit_rate(self) -> float:
        with self._lock:
            hits = self._counters["cache_hits"].value
            misses = self._counters["cache_misses"].value
        total = hits + misses
        return hits / total if total else 0.0

    def snapshot(self) -> dict:
        # One lock hold covers counters, derived ratios AND the latency
        # histogram (they share the registry lock): a fully atomic cut.
        with self._lock:
            out = {f: self._counters[f].value for f in self._COUNTER_FIELDS}
            out["generation_age_s"] = self.generation_age.value
            out["batch_occupancy"] = (
                out["batch_rows_real"] / out["batch_rows_padded"]
                if out["batch_rows_padded"] else 0.0)
            total = out["cache_hits"] + out["cache_misses"]
            out["cache_hit_rate"] = out["cache_hits"] / total if total else 0.0
            out["latency"] = self.latency.snapshot()
        return out


class RouterMetrics(_RegistryMetrics):
    """Replica-router counters + the router-level latency histogram (§12).

    Router latency is submit → terminal outcome INCLUDING failover retries
    and backoff, so it is an end-to-end client view; a replica gateway's own
    histogram sees only the attempts that reached it."""

    _COUNTER_FIELDS = (
        "routed",             # requests accepted by the router
        "completed",          # outer futures resolved with a Response
        "failed",             # outer futures resolved with an exception
        "shed",               # refused: every candidate replica dead/saturated
        "failovers",          # re-submissions to another replica
        "attempt_timeouts",   # attempts abandoned as unresponsive
        "deadline_failed",    # outer futures failed with DeadlineExceeded
        "retries_exhausted",  # outer futures failed after the retry budget
        "resyncs",            # lagging replicas re-synced to the target gen
        "swap_prepare_failures",  # replicas that failed two-phase prepare
        "coordinated_swaps",      # successful two-phase hot-swaps
        "replica_deaths",         # replicas declared dead (restart storm)
        "brownout_sheds",         # requests shed by alert-driven brownout (§14)
        "alert_resyncs",          # re-syncs triggered by a generation-lag alert
    )

    def __init__(self, registry: Optional[MetricsRegistry] = None):
        super().__init__(registry, prefix="router")
        self._max_lag = self.registry.gauge("router_max_generation_lag")
        self._cur_lag = self.registry.gauge("router_current_generation_lag")
        # fraction of replicas currently HEALTHY — the replica-availability
        # SLO's signal; 1.0 until the health monitor first reports
        self._healthy_ratio = self.registry.gauge("router_healthy_replica_ratio")
        self._healthy_ratio.set(1.0)
        self.generation_age = self.registry.register(
            GenerationAgeGauge("router_generation_age_seconds",
                               lock=self.registry.lock))

    def mark_generation_commit(self) -> None:
        """Stamp the freshness clock at coordinated-swap commit time."""
        self.generation_age.mark()

    def record_routed(self) -> None:
        self._inc("routed")

    def record_completed(self, latency_s: float) -> None:
        self._inc("completed")
        self.latency.record(latency_s)

    def record_failed(self, *, deadline: bool = False, exhausted: bool = False) -> None:
        with self._lock:
            self._inc("failed")
            if deadline:
                self._inc("deadline_failed")
            if exhausted:
                self._inc("retries_exhausted")

    def record_shed(self) -> None:
        self._inc("shed")

    def record_failover(self) -> None:
        self._inc("failovers")

    def record_attempt_timeout(self) -> None:
        self._inc("attempt_timeouts")

    def record_resync(self) -> None:
        self._inc("resyncs")

    def record_swap_prepare_failure(self) -> None:
        self._inc("swap_prepare_failures")

    def record_coordinated_swap(self) -> None:
        self._inc("coordinated_swaps")

    def record_replica_death(self) -> None:
        self._inc("replica_deaths")

    def record_brownout_shed(self) -> None:
        with self._lock:
            self._inc("brownout_sheds")
            self._inc("shed")

    def record_alert_resync(self) -> None:
        self._inc("alert_resyncs")

    def set_healthy_ratio(self, ratio: float) -> None:
        self._healthy_ratio.set(ratio)

    @property
    def healthy_replica_ratio(self) -> float:
        return float(self._healthy_ratio.value)

    def observe_generation_lag(self, lag: int) -> None:
        with self._lock:
            self._cur_lag.set(lag)
            self._max_lag.max(lag)

    @property
    def max_generation_lag(self) -> int:
        return int(self._max_lag.value)

    @property
    def current_generation_lag(self) -> int:
        return int(self._cur_lag.value)

    def snapshot(self) -> dict:
        with self._lock:
            out = {f: self._counters[f].value for f in self._COUNTER_FIELDS}
            out["max_generation_lag"] = int(self._max_lag.value)
            out["current_generation_lag"] = int(self._cur_lag.value)
            out["healthy_replica_ratio"] = float(self._healthy_ratio.value)
            out["generation_age_s"] = self.generation_age.value
            out["latency"] = self.latency.snapshot()
        return out

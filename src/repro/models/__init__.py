from repro.models.config import ModelConfig, MoEConfig, MLAConfig, SSMConfig
from repro.models.transformer import init_model, forward, loss_fn, init_decode_cache, decode_step

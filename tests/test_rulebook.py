"""Serving subsystem: rulebook compile/save/load, batched recommend vs the
per-basket Python engine, the served-rule frequency property, and the mesh
(Map/Reduce) match step."""

import numpy as np
import pytest

from repro.core.apriori import AprioriConfig, mine
from repro.data.synthetic import QuestConfig, gen_transactions
from repro.serving import (
    Rulebook,
    compile_rulebook,
    pack_baskets,
    place_rulebook,
    recommend,
    recommend_python,
)
from repro.serving.recommend import rulebook_as_python


@pytest.fixture(scope="module")
def mined():
    db = gen_transactions(
        QuestConfig(num_transactions=400, num_items=40, avg_len=8, seed=3)
    )
    res = mine(db, AprioriConfig(min_support=0.04, max_k=4, count_impl="jnp"))
    return db, res


@pytest.fixture(scope="module")
def rulebook(mined):
    _, res = mined
    return compile_rulebook(res, min_confidence=0.4, num_items=40, pad_multiple=64)


# ------------------------------------------------------------- compile -------
def test_compile_layout_and_padding(rulebook):
    rb = rulebook
    assert rb.ante_packed.dtype == np.uint32 and rb.scores.dtype == np.float32
    assert rb.num_rows % 64 == 0 and rb.num_rules <= rb.num_rows
    pad = np.asarray(rb.ante_len) < 0
    assert not np.any(np.asarray(rb.ante_packed)[pad])          # zero words
    assert not np.any(np.asarray(rb.scores)[pad])               # zero scores
    real = np.asarray(rb.scores)[~pad]
    assert (np.diff(real) <= 1e-7).all()                        # sorted descending


def test_compile_max_rules_truncates_top_scores(mined):
    _, res = mined
    full = compile_rulebook(res, min_confidence=0.4, num_items=40, pad_multiple=1)
    trunc = compile_rulebook(
        res, min_confidence=0.4, num_items=40, max_rules=10, pad_multiple=1
    )
    assert trunc.num_rules == 10
    np.testing.assert_array_equal(trunc.scores[:10], full.scores[:10])


def test_compile_rejects_unknown_score(mined):
    _, res = mined
    with pytest.raises(ValueError):
        compile_rulebook(res, score="support")


def test_save_load_roundtrip(rulebook, tmp_path):
    path = str(tmp_path / "rb.npz")
    rulebook.save(path)
    rb2 = Rulebook.load(path)
    for field in ("ante_packed", "cons_packed", "ante_len", "scores"):
        np.testing.assert_array_equal(getattr(rulebook, field), getattr(rb2, field))
    assert (rb2.num_items, rb2.score_kind, rb2.min_confidence) == (40, "confidence", 0.4)


# ------------------------------------------------- served-rule property ------
def test_every_served_rule_union_is_frequent(mined, rulebook):
    """Property: every rule resident in the compiled rulebook came from a
    frequent itemset — antecedent ∪ consequent has support >= min_count."""
    _, res = mined
    rules = rulebook_as_python(rulebook)
    assert len(rules) == rulebook.num_rules > 0
    for ante, cons, _ in rules:
        union = tuple(sorted(ante | set(cons.tolist())))
        assert res.support(union) >= res.min_count


# ----------------------------------------------------------- recommend -------
def test_recommend_matches_python_engine(mined, rulebook):
    db, _ = mined
    baskets = db[:60]
    out_py = recommend_python(rulebook, baskets, top_k=5)
    for impl in ("jnp", "pallas_interpret"):
        out = recommend(rulebook, baskets, top_k=5, batch_size=32, impl=impl)
        np.testing.assert_allclose(out.scores, out_py.scores, rtol=1e-4, atol=1e-5)
        # identical item ranking wherever scores are distinct
        distinct = np.abs(np.diff(out_py.scores, axis=1)).min(axis=1) > 1e-5
        np.testing.assert_array_equal(out.items[distinct], out_py.items[distinct])


def test_recommend_excludes_basket_items(mined, rulebook):
    db, _ = mined
    out = recommend(rulebook, db[:40], top_k=5, batch_size=16, impl="jnp")
    for b in range(40):
        have = set(np.flatnonzero(db[b]).tolist())
        recs = set(out.items[b][np.isfinite(out.scores[b])].tolist())
        assert not (have & recs)


def test_recommend_accepts_lists_and_packed(mined, rulebook):
    db, _ = mined
    lists = [np.flatnonzero(row).tolist() for row in db[:20]]
    packed = pack_baskets(lists, rulebook.num_items)
    out_l = recommend(rulebook, lists, top_k=4, batch_size=8, impl="jnp")
    out_p = recommend(rulebook, packed, top_k=4, batch_size=8, impl="jnp")
    np.testing.assert_array_equal(out_l.items, out_p.items)
    np.testing.assert_array_equal(out_l.scores, out_p.scores)


def test_recommend_on_mesh_matches_single_device(mined, rulebook):
    """The Map/Reduce match step (rules psum'd over the model axis)."""
    from repro.launch.mesh import make_auto_mesh

    db, _ = mined
    mesh = make_auto_mesh((1, 1), ("data", "model"))
    placed = place_rulebook(rulebook, mesh, rule_axis="model")
    out_m = recommend(rulebook, db[:30], top_k=5, batch_size=16, impl="jnp", mesh=mesh)
    out_s = recommend(rulebook, db[:30], top_k=5, batch_size=16, impl="jnp")
    np.testing.assert_allclose(out_m.scores, out_s.scores, rtol=1e-5, atol=1e-6)
    assert placed.num_rules == rulebook.num_rules


_SERVE_2x3 = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=6"
import numpy as np
from repro.core.apriori import AprioriConfig, mine
from repro.data.synthetic import QuestConfig, gen_transactions
from repro.launch.mesh import make_auto_mesh
from repro.serving import compile_rulebook, place_rulebook, recommend, recommend_python

db = gen_transactions(QuestConfig(num_transactions=400, num_items=64, avg_len=8, seed=13))
res = mine(db, AprioriConfig(min_support=0.04, max_k=4, count_impl="jnp"))
rb = compile_rulebook(res, min_confidence=0.4, num_items=64, pad_multiple=64)

mesh = make_auto_mesh((2, 3), ("data", "model"))  # 3 rule shards: uneven-split trigger
placed = place_rulebook(rb, mesh, rule_axis="model")
assert placed.num_rows % 3 == 0 and placed.num_rules == rb.num_rules
out_m = recommend(placed, db[:90], top_k=5, batch_size=30, impl="jnp", mesh=mesh)
out_p = recommend_python(rb, db[:90], top_k=5)
np.testing.assert_allclose(out_m.scores, out_p.scores, rtol=1e-4, atol=1e-5)
distinct = np.abs(np.diff(out_p.scores, axis=1)).min(axis=1) > 1e-5
np.testing.assert_array_equal(out_m.items[distinct], out_p.items[distinct])
print("SERVE_2x3_OK", rb.num_rules)
"""


def test_recommend_on_real_2x3_mesh():
    """Runs in a subprocess with 6 host devices: the psum-over-rule-shards
    Map/Reduce branch (not the single-device shortcut) must reproduce the
    Python oracle, with the rulebook split unevenly over 3 model shards."""
    import subprocess
    import sys

    from conftest import REPO_ROOT, subprocess_env

    proc = subprocess.run(
        [sys.executable, "-c", _SERVE_2x3],
        capture_output=True,
        text=True,
        timeout=600,
        env=subprocess_env(),
        cwd=REPO_ROOT,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "SERVE_2x3_OK" in proc.stdout


def test_empty_rulebook_recommends_nothing(mined):
    db, res = mined
    rb = compile_rulebook(res, min_confidence=1.1, num_items=40, pad_multiple=32)
    assert rb.num_rules == 0
    out = recommend(rb, db[:8], top_k=3, batch_size=8, impl="jnp")
    assert np.all(out.scores <= 0)  # only -inf (basket) or 0 (no evidence)

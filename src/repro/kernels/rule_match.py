"""Pallas TPU kernel: batched basket->rule matching + per-item score fan-out.

The serving half of the pipeline (DESIGN.md §8).  A compiled rulebook
(``serving/rulebook.py``) is four device-resident columns in the packed
uint32 word layout of ``support_count_packed.py``:

    a_packed (R, W) uint32   antecedent bitsets
    c_packed (R, W) uint32   consequent bitsets
    lengths  (R,)   int32    antecedent popcounts (-1 = padding row)
    scores   (R,)   float32  rule weight (confidence / lift, 0 on padding)

For a batch of basket bitsets ``b_packed (B, W)`` the kernel computes, in one
fused pass per (basket-block, rule-block) tile:

    matched[b, r] = (∀w: b[b,w] & a[r,w] == a[r,w]) ∧ lengths[r] >= 0
    out[b, i]     = Σ_r matched[b, r] · scores[r] · cons_bit[r, i]

i.e. antecedent containment is the same VPU bitwise test as the packed
counting kernel, and the per-item aggregation is an MXU matmul of the masked
score matrix against the consequent bitsets unpacked in-register to a
(bk, 32·W) {0,1} operand — summed evidence per item, never a sparse scatter.
Top-k item selection happens outside the kernel (``kernels.ops.rule_match``
returns the dense (B, I) score matrix; ``serving/recommend.py`` applies
basket-exclusion masking + ``lax.top_k``).

Grid = (B/bn, R/bk); the word axis stays whole inside the body (serving
vocabularies keep W = ceil(I/32) small — 32 words at I = 1024) as a static
Python unroll, so no cross-tile accumulator state is needed: the output
block is revisited (accumulated) only across the rule grid dimension.

Padding semantics (DESIGN.md §3): padded baskets are zero rows — a real
antecedent has ≥ 1 set bit they lack, and their output rows are sliced off
by the wrapper anyway; padded rules are zero rows with ``len = -1`` *and*
``score = 0`` (masked twice over).  VMEM per step at (bn, bk, W) =
(256, 256, 32): two uint32 rule blocks 64 KB + basket block 32 KB + the
(bn, 32·W) f32 output and unpacked operand 1 MB each — comfortably under
budget.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(b_ref, a_ref, len_ref, c_ref, score_ref, out_ref, *, num_words):
    r = pl.program_id(1)

    b = b_ref[...]  # (bn, W) uint32
    a = a_ref[...]  # (bk, W) uint32

    # --- antecedent containment: count violated words (packed-kernel test) ---
    viol = jnp.zeros((b.shape[0], a.shape[0]), jnp.int32)
    for w in range(num_words):
        bw = b[:, w : w + 1]        # (bn, 1)
        aw = a[:, w : w + 1].T      # (1, bk)
        viol += ((bw & aw) != aw).astype(jnp.int32)
    matched = (viol == 0) & (len_ref[...] >= 0)            # (bn, bk)
    weights = matched.astype(jnp.float32) * score_ref[...]  # (bn, bk)

    # --- consequent fan-out: unpack bitsets in-register, one MXU matmul ---
    c = c_ref[...]  # (bk, W) uint32
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (1, 32), 1)
    cols = [
        ((c[:, w : w + 1] >> shifts) & jnp.uint32(1)).astype(jnp.float32)
        for w in range(num_words)
    ]
    cons_dense = jnp.concatenate(cols, axis=1)  # (bk, 32·W) — little-endian items
    contrib = jnp.dot(weights, cons_dense, preferred_element_type=jnp.float32)

    @pl.when(r == 0)
    def _init():
        out_ref[...] = contrib

    @pl.when(r > 0)
    def _accum():
        out_ref[...] += contrib


@functools.partial(
    jax.jit, static_argnames=("block_n", "block_k", "interpret")
)
def rule_match_pallas(
    b_packed: jax.Array,
    a_packed: jax.Array,
    lengths: jax.Array,
    c_packed: jax.Array,
    scores: jax.Array,
    *,
    block_n: int = 256,
    block_k: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """Per-item rule-evidence scores (B, 32·W) float32 for pre-padded
    operands: B % block_n == R % block_k == 0 (use ``kernels.ops.rule_match``
    for the padding/dispatch wrapper)."""
    n, w = b_packed.shape
    r, w2 = a_packed.shape
    assert w == w2 and c_packed.shape == (r, w)
    assert lengths.shape == (r,) and scores.shape == (r,)
    assert b_packed.dtype == jnp.uint32 and a_packed.dtype == jnp.uint32
    assert n % block_n == 0 and r % block_k == 0, (
        f"operands must be pre-padded: {(n, r)} vs blocks {(block_n, block_k)}"
    )

    len2d = lengths.astype(jnp.int32).reshape(1, r)
    score2d = scores.astype(jnp.float32).reshape(1, r)
    grid = (n // block_n, r // block_k)
    return pl.pallas_call(
        functools.partial(_kernel, num_words=w),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, w), lambda nn, rr: (nn, 0)),
            pl.BlockSpec((block_k, w), lambda nn, rr: (rr, 0)),
            pl.BlockSpec((1, block_k), lambda nn, rr: (0, rr)),
            pl.BlockSpec((block_k, w), lambda nn, rr: (rr, 0)),
            pl.BlockSpec((1, block_k), lambda nn, rr: (0, rr)),
        ],
        out_specs=pl.BlockSpec((block_n, 32 * w), lambda nn, rr: (nn, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 32 * w), jnp.float32),
        interpret=interpret,
    )(b_packed, a_packed, len2d, c_packed, score2d)

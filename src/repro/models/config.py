"""Unified model configuration for the assigned-architecture zoo."""

from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    padded_experts: Optional[int] = None   # pad E for mesh divisibility (granite 40 -> 48)
    router_jitter: float = 0.0

    @property
    def e_padded(self) -> int:
        return self.padded_experts or self.num_experts


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    qk_nope_dim: int = 64
    qk_rope_dim: int = 32
    v_head_dim: int = 64


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 64            # N
    head_dim: int = 64             # P
    expand: int = 2                # d_inner = expand * d_model
    n_groups: int = 1              # B/C groups (G)
    conv_width: int = 4
    chunk: int = 256               # SSD chunk length


@dataclasses.dataclass(frozen=True)
class RWKVConfig:
    head_dim: int = 64
    lora_dim: int = 32             # ddlerp / decay adapter rank
    d_ff: int = 7168
    chunk: int = 32                # chunked-WKV length (<=1 = per-step scan oracle)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int

    # block family: 'attn' (dense/MoE transformer), 'mamba2', 'rwkv6',
    # 'zamba_hybrid' (mamba2 backbone + ONE shared attn block every share_every)
    block_type: str = "attn"
    attn_type: str = "gqa"         # gqa | mla
    qkv_bias: bool = False
    share_every: int = 6           # zamba: shared block period

    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    rwkv: Optional[RWKVConfig] = None

    norm: str = "rmsnorm"          # rmsnorm | layernorm
    act: str = "swiglu"            # swiglu | gelu
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False

    # modality frontend: 'tokens' | 'frames' (audio: precomputed frame embeds)
    # | 'vlm' (precomputed patch embeds prepended to token embeds)
    frontend: str = "tokens"
    num_patches: int = 0           # vlm: patches per image

    compute_dtype: str = "bfloat16"
    param_dtype: str = "float32"
    remat: bool = True
    attn_block_k: int = 512        # chunked-attention kv block
    loss_chunk: int = 1024         # CE seq-chunking (0/indivisible = unchunked)
    moe_groups: int = 1            # MoE routing groups (= data shards on the mesh)

    @property
    def head_dim(self) -> int:
        return self.d_model // self.num_heads

    def reduced(self, **overrides) -> "ModelConfig":
        """A tiny same-family config for CPU smoke tests."""
        small = dict(
            num_layers=max(2, self.share_every if self.block_type == "zamba_hybrid" else 2),
            d_model=128,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 4) if self.num_kv_heads else 0,
            d_ff=256,
            vocab_size=251,
            compute_dtype="float32",
            remat=False,
            attn_block_k=64,
        )
        if self.block_type == "zamba_hybrid":
            small["num_layers"] = 4
            small["share_every"] = 2
        if self.moe is not None:
            # capacity_factor = E/top_k -> drop-free routing, so the reduced
            # config keeps exact prefill/decode equivalence (capacity drops
            # are non-causal by construction).
            small["moe"] = MoEConfig(
                num_experts=4, top_k=2, d_ff_expert=64, padded_experts=4, capacity_factor=2.0
            )
        if self.mla is not None:
            small["mla"] = MLAConfig(q_lora_rank=48, kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16)
        if self.ssm is not None:
            small["ssm"] = SSMConfig(state_dim=16, head_dim=16, chunk=8)
        if self.rwkv is not None:
            small["rwkv"] = RWKVConfig(head_dim=16, lora_dim=8, d_ff=192)
        if self.frontend == "vlm":
            small["num_patches"] = 16
        small.update(overrides)
        return dataclasses.replace(self, **small)

"""IBM Quest-style synthetic transaction generator (the T10I4D family used by
the Apriori literature, incl. the datasets the paper's testbed mimics).

Transactions are built from a pool of 'potentially frequent' patterns: each
transaction draws a few patterns (sizes ~ Poisson(pattern_len)), keeps each
pattern item with prob (1 - corruption), and tops up with zipf-weighted noise
items until ~Poisson(avg_len) items. Deterministic under seed.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class QuestConfig:
    num_transactions: int = 10_000
    num_items: int = 512
    avg_len: float = 10.0          # T in T10I4D
    num_patterns: int = 64
    avg_pattern_len: float = 4.0   # I in T10I4D
    corruption: float = 0.35
    patterns_per_txn: float = 1.5
    zipf_a: float = 1.3            # item popularity skew for noise items
    seed: int = 0


def gen_transactions_chunked(cfg: QuestConfig = QuestConfig(), chunk_rows: int = 8192):
    """Yield the rows of :func:`gen_transactions` as dense {0,1} int8 chunks
    of at most ``chunk_rows`` rows — the SAME rows, in the SAME order, under
    the SAME seed (``gen_transactions`` is literally the concatenation of
    this generator), so huge synthetic DBs can be ingested into an on-disk
    store (``data.store.ingest_quest``) without materializing the (n, i)
    matrix. Peak memory is O(chunk_rows · num_items) for the chunk buffer
    plus O(n) for the per-transaction Poisson draws, which must be drawn
    up-front in one call each to preserve the rng stream.
    """
    if chunk_rows < 1:
        raise ValueError("chunk_rows must be >= 1")
    rng = np.random.default_rng(cfg.seed)
    n, i = cfg.num_transactions, cfg.num_items

    # item popularity (zipf-ish, normalized)
    weights = 1.0 / np.power(np.arange(1, i + 1, dtype=np.float64), cfg.zipf_a)
    weights /= weights.sum()

    # pattern pool
    patterns = []
    for _ in range(cfg.num_patterns):
        size = max(2, rng.poisson(cfg.avg_pattern_len))
        size = min(size, i)
        patterns.append(rng.choice(i, size=size, replace=False, p=weights))

    n_pat = rng.poisson(cfg.patterns_per_txn, size=n)
    txn_len = np.maximum(1, rng.poisson(cfg.avg_len, size=n))
    pat_weights = 1.0 / np.arange(1, cfg.num_patterns + 1, dtype=np.float64)
    pat_weights /= pat_weights.sum()
    for start in range(0, n, chunk_rows):
        rows = min(chunk_rows, n - start)
        out = np.zeros((rows, i), dtype=np.int8)
        for r in range(rows):
            t = start + r
            for _ in range(n_pat[t]):
                pat = patterns[rng.choice(cfg.num_patterns, p=pat_weights)]
                keep = rng.random(pat.size) > cfg.corruption
                out[r, pat[keep]] = 1
            deficit = txn_len[t] - int(out[r].sum())
            if deficit > 0:
                noise = rng.choice(i, size=min(deficit, i), replace=False, p=weights)
                out[r, noise] = 1
        yield out


def gen_transactions(cfg: QuestConfig = QuestConfig()) -> np.ndarray:
    """Returns dense {0,1} int8 (num_transactions, num_items)."""
    chunks = list(gen_transactions_chunked(cfg, chunk_rows=max(1, cfg.num_transactions)))
    if not chunks:
        return np.zeros((0, cfg.num_items), dtype=np.int8)
    return chunks[0] if len(chunks) == 1 else np.concatenate(chunks)


def gen_transaction_lists(cfg: QuestConfig = QuestConfig()) -> list:
    dense = gen_transactions(cfg)
    return [np.flatnonzero(row).tolist() for row in dense]

"""DBRX-132B [hf:databricks/dbrx-base] — MoE 16 experts top-4, GQA kv=8."""

from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=10752,
    vocab_size=100352,
    moe=MoEConfig(num_experts=16, top_k=4, d_ff_expert=10752),
)

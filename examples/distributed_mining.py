"""Distributed mining on a simulated multi-node cluster (8 host devices),
reproducing the paper's single-node vs multi-node comparison (Fig 5) plus the
SON two-round variant.

python examples/distributed_mining.py          # re-execs with 8 fake devices
"""

import os
import sys

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.execv(sys.executable, [sys.executable] + sys.argv)

import time


from repro.core.apriori import AprioriConfig, mine
from repro.core.son import mine_son
from repro.data.synthetic import QuestConfig, gen_transactions


def main():
    db = gen_transactions(QuestConfig(num_transactions=20_000, num_items=512, avg_len=10, seed=7))
    print(f"DB: {db.shape} ({db.nbytes/1e6:.0f} MB dense)")

    # single node (the paper's 'standalone')
    cfg1 = AprioriConfig(min_support=0.02, max_k=5, count_impl="jnp")
    t0 = time.time(); r1 = mine(db, cfg1); t1 = time.time() - t0
    print(f"standalone: {t1:.2f}s, {r1.total_frequent} itemsets")

    # 4x2 'cluster' (4-way transaction sharding x 2-way candidate sharding)
    from repro.launch.mesh import make_auto_mesh

    mesh = make_auto_mesh((4, 2), ("data", "model"))
    cfg = AprioriConfig(min_support=0.02, max_k=5, count_impl="jnp",
                        data_axes=("data",), model_axis="model")
    t0 = time.time(); r2 = mine(db, cfg, mesh=mesh); t2 = time.time() - t0
    print(f"distributed (4x2): {t2:.2f}s, {r2.total_frequent} itemsets "
          f"(speedup {t1/t2:.2f}x)")
    assert r1.as_dict() == r2.as_dict(), "distribution must not change results"

    # SON: 2 distributed rounds instead of max_k
    t0 = time.time(); r3 = mine_son(db, cfg, mesh=mesh, num_partitions=8); t3 = time.time() - t0
    print(f"SON 2-phase: {t3:.2f}s, {r3.total_frequent} itemsets")
    assert r3.as_dict() == r1.as_dict()
    print("all modes agree — the paper's design claim, verified")


if __name__ == "__main__":
    main()

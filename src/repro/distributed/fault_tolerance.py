"""Fault tolerance: supervisor (checkpoint/restart + elastic re-mesh) and
straggler mitigation (over-partitioned work queue + speculative backups).

The paper's Fig-4 finding — heterogeneous clusters pay the slowest node's
price — is exactly the straggler problem; Hadoop answers with speculative
execution, and `run_with_backup_tasks` is the TPU-side equivalent: work is
over-partitioned `factor`x beyond the device count and unfinished shards are
re-issued to idle devices, bounding makespan by ~max(shard) instead of
~max(node) * load.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Sequence

import jax
import numpy as np

from repro.distributed.checkpoint import CheckpointManager, latest_step, load_checkpoint


class SimulatedFailure(Exception):
    """Raised by a failure injector to emulate a node loss."""

    def __init__(self, lost_nodes: int = 1):
        super().__init__(f"lost {lost_nodes} node(s)")
        self.lost_nodes = lost_nodes


@dataclasses.dataclass
class Supervisor:
    """Train-loop wrapper: periodic async checkpoints, restart-on-failure,
    elastic re-mesh through the checkpoint's elastic restore path.

    make_mesh_fn(num_nodes) -> mesh; rebuild_fn(mesh, restored_state) -> the
    jit'd step closure for that mesh (recompiled on re-mesh — elastic scale).
    """

    ckpt_dir: str
    make_mesh_fn: Callable
    rebuild_fn: Callable
    checkpoint_every: int = 10
    keep: int = 3

    def run(
        self,
        state,
        state_specs,
        batch_fn: Callable,
        num_steps: int,
        num_nodes: int,
        failure_injector: Callable | None = None,
        max_restarts: int = 3,
    ):
        """``batch_fn(step) -> batch`` must be a step-indexed DETERMINISTIC
        stream (data.pipeline seeds by step): on restore the data order
        rewinds with the model state, which is what makes restart bit-exact —
        a stateful iterator cannot be rewound and silently skips batches."""
        mgr = CheckpointManager(self.ckpt_dir, keep=self.keep)
        mesh = self.make_mesh_fn(num_nodes)
        step_fn = self.rebuild_fn(mesh, state)
        restarts = 0
        step = int(jax.device_get(state["opt"]["step"])) if "opt" in state else 0
        history = []
        while step < num_steps:
            try:
                if failure_injector:
                    failure_injector(step)
                batch = batch_fn(step)
                state, metrics = step_fn(state, batch)
                step += 1
                history.append({k: float(jax.device_get(v)) for k, v in metrics.items()})
                if step % self.checkpoint_every == 0:
                    mgr.save_async(state, step, specs=state_specs)
            except SimulatedFailure as fail:
                restarts += 1
                if restarts > max_restarts:
                    raise
                mgr.wait()
                num_nodes = max(1, num_nodes - fail.lost_nodes)  # elastic shrink
                mesh = self.make_mesh_fn(num_nodes)
                last = latest_step(self.ckpt_dir)
                if last is not None:
                    state, _ = load_checkpoint(
                        self.ckpt_dir, state, step=last, mesh=mesh, specs=state_specs
                    )
                    step = last
                step_fn = self.rebuild_fn(mesh, state)  # recompile for new mesh
        mgr.wait()
        return state, history, {"restarts": restarts, "final_nodes": num_nodes}


# ------------------------------------------------------- straggler layer ----
@dataclasses.dataclass
class WorkQueue:
    """Over-partitioned shard queue with speculative re-issue."""

    shards: Sequence
    factor: int = 4

    def __post_init__(self):
        self.pending = list(range(len(self.shards)))
        self.done: dict = {}


def run_with_backup_tasks(
    shards,
    worker_fn: Callable,
    node_speeds: Sequence[float],
    backup: bool = True,
):
    """Simulate the paper's FHDSC (heterogeneous) cluster executing a map
    phase. Shards are assigned round-robin (Hadoop block placement is
    speed-OBLIVIOUS — that is exactly why Fig 4's heterogeneous cluster
    lags). Each shard costs `size(shard)/speed` on its node.

    backup=True enables speculative re-execution: a node that drains its own
    queue steals the largest unstarted shard from the most-backlogged node
    (Hadoop's speculative task, TPU work-queue form — DESIGN.md §5).

    Returns (results, makespan_seconds_simulated).
    """
    n_nodes = len(node_speeds)
    costs = [float(np.asarray(s).size) for s in shards]
    queues = [[] for _ in range(n_nodes)]
    for i in range(len(shards)):
        queues[i % n_nodes].append(i)  # speed-oblivious placement

    times = [0.0] * n_nodes
    done = [False] * len(shards)
    while not all(done):
        node = min(range(n_nodes), key=lambda n: times[n])
        if queues[node]:
            i = queues[node].pop(0)
        elif backup:
            donor = max(range(n_nodes), key=lambda n: sum(costs[j] for j in queues[n]))
            if not queues[donor]:
                break
            # steal the donor's largest pending shard
            i = max(queues[donor], key=lambda j: costs[j])
            queues[donor].remove(i)
        else:
            times[node] = float("inf")  # idles forever; others drain their queues
            continue
        times[node] += costs[i] / node_speeds[node]
        done[i] = True
    makespan = max(t for t in times if t != float("inf"))

    results = [worker_fn(s) for s in shards]  # real compute (correctness path)
    return results, float(makespan)

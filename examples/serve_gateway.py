"""End-to-end online serving: store -> mine_streamed -> rulebook -> gateway.

  PYTHONPATH=src python examples/serve_gateway.py \
      [--transactions 4000] [--items 128] [--requests 1200] [--concurrency 12]

The full DESIGN.md §9 + §10 pipeline, step by step:

  1. ingest    — the synthetic Quest DB is written CHUNKED into an on-disk
                 ``TransactionStore`` (packed uint32 shards; the dense
                 matrix is never materialized);
  2. mine      — the streaming Map/Reduce driver (``mine_streamed``) folds
                 disk chunks through the count kernel, one host sync per
                 candidate pass;
  3. compile   — the mined itemsets become a device-resident rulebook;
  4. serve     — a ``Gateway`` answers independent single-basket queries:
                 concurrent arrivals coalesce into power-of-two jit
                 buckets, repeat baskets hit the exact-basket LRU cache,
                 and every response names the rulebook generation that
                 answered it;
  5. hot-swap  — while the client load is running, the store is re-mined
                 at a higher support and the fresh rulebook is swapped in
                 atomically: zero requests dropped, responses flip from
                 generation 0 to generation 1.

The same flow as a single command (plus a JSON summary for scripting):

  PYTHONPATH=src python -m repro.launch.serve --transactions 4000 \
      --items 128 --requests 2000 --concurrency 16 --hot-swap-mid-load
"""

import argparse
import tempfile
import threading
import time
from concurrent.futures import ThreadPoolExecutor

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--transactions", type=int, default=4_000)
    ap.add_argument("--items", type=int, default=128)
    ap.add_argument("--avg-len", type=float, default=10.0)
    ap.add_argument("--min-support", type=float, default=0.02)
    ap.add_argument("--max-k", type=int, default=4)
    ap.add_argument("--min-confidence", type=float, default=0.4)
    ap.add_argument("--top-k", type=int, default=5)
    ap.add_argument("--requests", type=int, default=1_200)
    ap.add_argument("--concurrency", type=int, default=12)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.core.apriori import AprioriConfig
    from repro.core.streaming import mine_streamed
    from repro.data.store import ingest_quest
    from repro.data.synthetic import QuestConfig
    from repro.serving import Gateway, compile_rulebook

    # ---- 1. ingest the synthetic DB into an on-disk store, chunked ----
    qcfg = QuestConfig(num_transactions=args.transactions, num_items=args.items,
                       avg_len=args.avg_len, seed=args.seed)
    tmp = tempfile.TemporaryDirectory(prefix="gateway_store_")
    store = ingest_quest(qcfg, tmp.name, shard_rows=2048, chunk_rows=2048)
    print(f"[gateway] store: n={store.num_transactions} items={store.num_items} "
          f"shards={store.num_partitions}")

    # ---- 2 + 3. mine_streamed -> compile a servable rulebook ----
    def mine_rulebook(min_support):
        res = mine_streamed(
            store,
            AprioriConfig(min_support=min_support, max_k=args.max_k,
                          representation="packed"),
            chunk_rows=2048,
        )
        rb = compile_rulebook(res, min_confidence=args.min_confidence,
                              num_items=store.num_items)
        print(f"[gateway] min_support={min_support}: {res.total_frequent} itemsets "
              f"-> {rb.num_rules} rules")
        return rb

    rb0 = mine_rulebook(args.min_support)

    # client baskets = the store's own transactions (pre-packed rows)
    chunk, real = next(store.iter_chunks(min(2048, store.num_transactions)))
    baskets = list(chunk[:real])

    # ---- 4. gateway + concurrent client load, hot-swap mid-stream ----
    responses, lock = [], threading.Lock()

    with Gateway(rb0, top_k=args.top_k, max_batch=64, max_wait_ms=1.0,
                 cache_capacity=2048) as gw:

        def client(indices):
            for i in indices:
                resp = gw.submit(baskets[i % len(baskets)]).result(timeout=120)
                with lock:
                    responses.append(resp)

        half = args.requests // 2
        t0 = time.perf_counter()
        with ThreadPoolExecutor(max_workers=args.concurrency) as pool:
            # first half of the load, answered by generation 0 ...
            for w in [pool.submit(client, range(o, half, args.concurrency))
                      for o in range(args.concurrency)]:
                w.result()
            # ---- 5. re-mine + hot-swap, then keep serving ----
            rb1 = mine_rulebook(2 * args.min_support)
            gen = gw.hot_swap(rb1)
            print(f"[gateway] hot-swapped to generation {gen}")
            for w in [pool.submit(client, range(half + o, args.requests, args.concurrency))
                      for o in range(args.concurrency)]:
                w.result()
        wall = time.perf_counter() - t0

        stats = gw.stats()

    gens = sorted({r.generation for r in responses})
    assert len(responses) == args.requests, "a request was dropped"
    assert gens == [0, 1], f"expected both generations to answer, saw {gens}"
    lat = np.array(sorted(r.latency_s for r in responses)) * 1e3
    print(f"[gateway] {len(responses)} responses in {wall:.2f}s "
          f"({len(responses) / wall:,.0f} qps) | generations={gens}")
    print(f"[gateway] latency p50={np.percentile(lat, 50):.2f}ms "
          f"p95={np.percentile(lat, 95):.2f}ms p99={np.percentile(lat, 99):.2f}ms")
    print(f"[gateway] batches={stats['batches']} occupancy={stats['batch_occupancy']:.2f} "
          f"cache_hit_rate={stats['cache_hit_rate']:.2f} swaps={stats['swaps']}")

    ex = responses[-1]
    print(f"[gateway] e.g. last response: items={ex.items.tolist()} "
          f"(generation {ex.generation}, cached={ex.cached}, "
          f"{ex.latency_s * 1e3:.2f}ms, bucket {ex.bucket})")
    tmp.cleanup()


if __name__ == "__main__":
    main()

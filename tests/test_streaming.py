"""Streaming Map/Reduce mining over the on-disk store (DESIGN.md §9):
chunked-count exactness properties, mine_streamed / mine_son_streamed
dict-equality with the in-memory drivers, and the mesh path."""

import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import streaming
from repro.core.apriori import AprioriConfig, mine, _count_level, make_count_step, place_db
from repro.core.son import mine_son
from repro.data import store as st
from repro.data.synthetic import QuestConfig, gen_transactions

from conftest import REPO_ROOT, random_problem, subprocess_env


def _store_from_dense(dense, path, shard_rows=64):
    return st.ingest_dense(dense, str(path), shard_rows=shard_rows)


# ------------------------------------------- chunked-count correctness -------
@pytest.mark.parametrize("rep", ["dense", "packed"])
@pytest.mark.parametrize("n,chunk_rows", [(100, 7), (96, 32), (130, 129), (60, 100), (50, 1)])
def test_streamed_counts_equal_whole_db(tmp_path, rep, n, chunk_rows):
    """Property: per-chunk device accumulation == whole-DB counts, exactly,
    for chunk sizes that divide n, don't divide n, exceed n, and degenerate
    to single rows — on both representations."""
    t, _, _ = random_problem(n, 45, 4, seed=n + chunk_rows)
    rng = np.random.default_rng(n)
    cands = np.sort(rng.choice(45, size=(23, 3), replace=True), axis=1).astype(np.int32)
    cfg = AprioriConfig(count_impl="jnp", representation=rep, candidate_pad=32)

    s = _store_from_dense(t, tmp_path / "db", shard_rows=40)
    got = streaming.count_supports_streamed(s, cands, cfg, chunk_rows=chunk_rows)

    count_step = make_count_step(None, cfg)
    want = _count_level(count_step, place_db(t, cfg, None), cands, 45, cfg, None)
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("rep", ["dense", "packed"])
def test_all_padding_chunk_is_inert(rep):
    """An all-padding (all-zero) chunk folded into the accumulator must not
    change any count — the invariant that lets the final chunk zero-pad to
    the jit bucket (DESIGN.md §3/§9)."""
    t, c, lengths = random_problem(40, 64, 9, seed=3)
    cfg = AprioriConfig(count_impl="jnp", representation=rep)
    step = streaming.make_accum_count_step(None, cfg)
    if rep == "packed":
        from repro.core.itemsets import pack_bits

        t_dev = jnp.asarray(pack_bits(t))
        c_dev = jnp.asarray(pack_bits(c))
    else:
        t_dev, c_dev = jnp.asarray(t), jnp.asarray(c)
    len_dev = jnp.asarray(lengths)
    acc = step(t_dev, c_dev, len_dev, jnp.zeros(9, jnp.int32))
    acc2 = step(jnp.zeros_like(t_dev), c_dev, len_dev, acc)
    np.testing.assert_array_equal(np.asarray(acc2), np.asarray(acc))


def test_multi_pass_candidate_split(tmp_path):
    """Streamed counting with max_candidates_per_pass smaller than K streams
    the DB once per pass and still matches."""
    t, _, _ = random_problem(70, 30, 4, seed=9)
    rng = np.random.default_rng(9)
    cands = np.sort(rng.choice(30, size=(40, 2), replace=True), axis=1).astype(np.int32)
    cfg = AprioriConfig(count_impl="jnp", candidate_pad=8, max_candidates_per_pass=16)
    s = _store_from_dense(t, tmp_path / "db", shard_rows=32)
    got = streaming.count_supports_streamed(s, cands, cfg, chunk_rows=33)
    count_step = make_count_step(None, cfg)
    want = _count_level(count_step, place_db(t, cfg, None), cands, 30, cfg, None)
    np.testing.assert_array_equal(got, want)


# --------------------------------------------------- end-to-end equality -----
@pytest.mark.parametrize("rep", ["dense", "packed"])
def test_mine_streamed_matches_mine(tmp_path, small_db, rep):
    """The acceptance criterion: mine_streamed dict-equal to mine, both
    representations, chunk size not dividing n (300)."""
    cfg = AprioriConfig(min_support=0.05, max_k=4, count_impl="jnp", representation=rep)
    want = mine(small_db, cfg)
    s = _store_from_dense(small_db, tmp_path / "db", shard_rows=90)
    got = streaming.mine_streamed(s, cfg, chunk_rows=77)
    assert got.as_dict() == want.as_dict()
    assert got.min_count == want.min_count
    assert got.num_transactions == want.num_transactions


@pytest.mark.parametrize("rep", ["dense", "packed"])
def test_mine_son_streamed_matches_in_memory(tmp_path, small_db, rep):
    cfg = AprioriConfig(min_support=0.05, max_k=4, count_impl="jnp", representation=rep)
    want = mine(small_db, cfg)
    want_son = mine_son(small_db, cfg, num_partitions=4)
    s = _store_from_dense(small_db, tmp_path / "db", shard_rows=80)
    got = streaming.mine_son_streamed(s, cfg, chunk_rows=64)
    assert got.as_dict() == want.as_dict() == want_son.as_dict()
    assert got.min_count == want.min_count


def test_son_streamed_phase2_single_disk_scan(tmp_path, small_db, monkeypatch):
    """Phase 2 must stream the store from disk exactly ONCE for the whole
    union (all levels' accumulators fold per chunk), not once per level —
    the SON two-round promise at the I/O layer."""
    cfg = AprioriConfig(min_support=0.05, max_k=4, count_impl="jnp")
    s = _store_from_dense(small_db, tmp_path / "db", shard_rows=100)
    calls = []
    orig = s.iter_chunks

    def counting_iter_chunks(*a, **kw):
        calls.append(1)
        return orig(*a, **kw)

    monkeypatch.setattr(s, "iter_chunks", counting_iter_chunks)
    got = streaming.mine_son_streamed(s, cfg, chunk_rows=64)
    assert sum(calls) == 1, "phase 2 re-scanned the store"
    assert got.as_dict() == mine(small_db, cfg).as_dict()


def test_streamed_worker_failure_raises(tmp_path, small_db, monkeypatch):
    """A shard read failure mid-stream must abort the mine, never return a
    silently undercounted result (the pipeline exception-propagation fix)."""
    cfg = AprioriConfig(min_support=0.05, max_k=3, count_impl="jnp")
    s = _store_from_dense(small_db, tmp_path / "db", shard_rows=100)
    orig = s.iter_chunks

    def flaky_iter_chunks(*a, **kw):
        yield next(iter(orig(*a, **kw)))
        raise OSError("shard read failed")

    monkeypatch.setattr(s, "iter_chunks", flaky_iter_chunks)
    with pytest.raises(OSError, match="shard read failed"):
        streaming.mine_streamed(s, cfg, chunk_rows=64)


def test_mine_streamed_checkpoint_resume(tmp_path, small_db):
    """resume_state flows through run_level_loop for the streamed driver too."""
    cfg = AprioriConfig(min_support=0.05, max_k=4, count_impl="jnp")
    s = _store_from_dense(small_db, tmp_path / "db")
    full = streaming.mine_streamed(s, cfg)
    seen = {}
    streaming.mine_streamed(
        s, cfg, checkpoint_cb=lambda k, levels: seen.update({k: dict(levels)})
    )
    assert set(seen) == set(full.levels)
    # resume from level 2: levels 1-2 taken from state, 3+ re-mined
    resume = {"levels": {k: v for k, v in full.levels.items() if k <= 2}, "next_k": 3}
    resumed = streaming.mine_streamed(s, cfg, resume_state=resume)
    assert resumed.as_dict() == full.as_dict()


def test_chunk_rows_validation(tmp_path, small_db):
    s = _store_from_dense(small_db, tmp_path / "db")
    with pytest.raises(ValueError):
        streaming.mine_streamed(s, AprioriConfig(count_impl="jnp"), chunk_rows=0)


# ----------------------------------------------------------------- mesh ------
_MESH_STREAM = textwrap.dedent(
    """
    import os, tempfile
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=6"
    import jax
    from repro.core.apriori import AprioriConfig, mine
    from repro.core.streaming import mine_son_streamed, mine_streamed
    from repro.data.store import ingest_quest
    from repro.data.synthetic import QuestConfig, gen_transactions

    qcfg = QuestConfig(num_transactions=400, num_items=64, avg_len=8, seed=13)
    single = mine(gen_transactions(qcfg),
                  AprioriConfig(min_support=0.05, max_k=4, count_impl="jnp"))
    mesh = jax.make_mesh((2, 3), ("data", "model"))
    with tempfile.TemporaryDirectory() as d:
        store = ingest_quest(qcfg, d, shard_rows=90)
        for rep in ("dense", "packed"):
            cfg = AprioriConfig(min_support=0.05, max_k=4, count_impl="jnp",
                                representation=rep, data_axes=("data",),
                                model_axis="model", candidate_pad=256)
            got = mine_streamed(store, cfg, mesh=mesh, chunk_rows=67)  # rounds to 68
            assert got.as_dict() == single.as_dict(), rep
            son = mine_son_streamed(store, cfg, mesh=mesh, chunk_rows=64)
            assert son.as_dict() == single.as_dict(), rep + " son"
    print("MESH_STREAM_OK", single.total_frequent)
    """
)


def test_mine_streamed_on_2x3_mesh():
    """Streamed mining on a (2, 3) data x model mesh (6 host devices) is
    dict-equal to the single-device in-memory mine, both representations,
    including a chunk size that does not divide the data-shard count."""
    proc = subprocess.run(
        [sys.executable, "-c", _MESH_STREAM],
        capture_output=True,
        text=True,
        timeout=600,
        env=subprocess_env(),
        cwd=REPO_ROOT,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "MESH_STREAM_OK" in proc.stdout

"""Three-term roofline from the compiled dry-run artifact.

Hardware constants (TPU v5e target):
  peak compute  197 TFLOP/s bf16 per chip
  HBM bandwidth 819 GB/s per chip
  ICI           ~50 GB/s per link

  compute term    = FLOPs_per_device            / peak_FLOPs
  memory term     = HBM_bytes_per_device        / HBM_bw
  collective term = collective_bytes_per_device / link_bw

FLOPs / HBM bytes / collective bytes come from launch.hlo_analysis (the
while-trip-count-corrected static walk of the compiled module — the raw
``cost_analysis()`` numbers are recorded alongside for reference; they count
scan bodies once and so underestimate by ~L×, see EXPERIMENTS.md §Dry-run).

MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE) tokens-based estimate; the
ratio MODEL_FLOPS / HLO_FLOPs measures how much compiled compute is 'useful'
(catches remat recompute and dispatch overhead).
"""

from __future__ import annotations

import dataclasses
import math

PEAK_FLOPS = 197e12       # bf16 / chip
HBM_BW = 819e9            # B/s / chip
ICI_BW = 50e9             # B/s / link


@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def compute_fraction(self) -> float:
        """How close the step is to pure-compute roofline: compute / bound."""
        return self.compute_s / max(self.bound_s, 1e-30)


def roofline_terms(flops_per_dev: float, hbm_bytes_per_dev: float, coll_bytes_per_dev: float) -> Roofline:
    return Roofline(
        compute_s=flops_per_dev / PEAK_FLOPS,
        memory_s=hbm_bytes_per_dev / HBM_BW,
        collective_s=coll_bytes_per_dev / ICI_BW,
    )


# ------------------------------------------------------------ model flops ----
def count_params(cfg) -> dict:
    """Analytic parameter counts (total and active) for MODEL_FLOPS."""
    d, L, V = cfg.d_model, cfg.num_layers, cfg.vocab_size
    h, kvh, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim

    def attn_params():
        if cfg.attn_type == "mla":
            m = cfg.mla
            qk = m.qk_nope_dim + m.qk_rope_dim
            return (d * m.q_lora_rank + m.q_lora_rank * h * qk
                    + d * (m.kv_lora_rank + m.qk_rope_dim)
                    + m.kv_lora_rank * h * (m.qk_nope_dim + m.v_head_dim)
                    + h * m.v_head_dim * d)
        return d * h * dh + 2 * d * kvh * dh + h * dh * d

    def ffn_params(total: bool):
        if cfg.moe:
            per = cfg.moe.d_ff_expert * d * (3 if cfg.act == "swiglu" else 2)
            # stored total includes padded (dead) experts; active = top_k real
            n_e = cfg.moe.e_padded if total else cfg.moe.top_k
            return n_e * per + d * cfg.moe.e_padded
        return cfg.d_ff * d * (3 if cfg.act == "swiglu" else 2)

    if cfg.block_type == "attn":
        per_layer_total = attn_params() + ffn_params(True)
        per_layer_active = attn_params() + ffn_params(False)
        body_total, body_active = L * per_layer_total, L * per_layer_active
    elif cfg.block_type == "mamba2":
        s = cfg.ssm
        d_inner = s.expand * d
        n_h = d_inner // s.head_dim
        per = d * (2 * d_inner + 2 * s.n_groups * s.state_dim + n_h) + d_inner * d
        body_total = body_active = L * per
    elif cfg.block_type == "rwkv6":
        r = cfg.rwkv
        per = 5 * d * d + 2 * d * r.lora_dim * 6 + d * r.d_ff * 2 + d * d
        body_total = body_active = L * per
    elif cfg.block_type == "zamba_hybrid":
        s = cfg.ssm
        d_inner = s.expand * d
        n_h = d_inner // s.head_dim
        per_m = d * (2 * d_inner + 2 * s.n_groups * s.state_dim + n_h) + d_inner * d
        shared = attn_params() + ffn_params(True)
        groups = L // cfg.share_every
        body_total = L * per_m + shared               # ONE shared copy stored
        body_active = L * per_m + groups * shared     # applied `groups` times
    else:
        raise ValueError(cfg.block_type)

    embed = V * d * (1 if cfg.tie_embeddings else 2)
    if cfg.frontend == "frames":
        embed = V * d  # head only (inputs are frame embeddings)
    return {
        "total": body_total + embed,
        "active": body_active + embed,
    }


def model_flops(cfg, shape: dict) -> float:
    """6·N_active·D for a train step; 2·N_active·D for prefill;
    2·N_active per token for decode (D = tokens processed)."""
    n_active = count_params(cfg)["active"]
    b, s = shape["global_batch"], shape["seq_len"]
    if shape["kind"] == "train":
        return 6.0 * n_active * b * s
    if shape["kind"] == "prefill":
        return 2.0 * n_active * b * s
    return 2.0 * n_active * b  # decode: one token per sequence

"""AdamW + warmup-cosine schedule, hand-rolled (no optax dependency).

fp32 master weights and moments; grads cast to fp32 before the update;
global-norm clipping; decoupled weight decay (skips norms/biases/1-D params).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def lr_schedule(step, cfg: AdamWConfig):
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(1.0, cfg.warmup_steps)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(1.0, cfg.decay_steps - cfg.warmup_steps), 0.0, 1.0
    )
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.peak_lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def adamw_init(params):
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {"m": zeros, "v": jax.tree.map(jnp.copy, zeros), "step": jnp.zeros((), jnp.int32)}


def global_norm(tree):
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree))
    )


def adamw_update(params, grads, opt_state, cfg: AdamWConfig):
    """Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    lr = lr_schedule(step, cfg)
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * clip, grads)

    m = jax.tree.map(lambda m_, g: cfg.b1 * m_ + (1 - cfg.b1) * g, opt_state["m"], grads)
    v = jax.tree.map(lambda v_, g: cfg.b2 * v_ + (1 - cfg.b2) * g * g, opt_state["v"], grads)
    bc1 = 1 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, m_, v_):
        u = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + cfg.eps)
        if p.ndim >= 2:  # decoupled decay on matrices only
            u = u + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

    new_params = jax.tree.map(upd, params, m, v)
    return new_params, {"m": m, "v": v, "step": step}, {"lr": lr, "grad_norm": gnorm}

"""Mine frequent token sets from an LM corpus — the paper's 'structured data
analysis' applied to the training pipeline (DESIGN.md §4 form 1).

PYTHONPATH=src python examples/mine_corpus.py
"""

import numpy as np

from repro.core.apriori import AprioriConfig, mine
from repro.data.corpus import transactions_from_tokens


def main():
    # synthetic 'corpus' with planted structure: a code-like trigram pattern
    rng = np.random.default_rng(0)
    tokens = rng.integers(0, 1000, size=200_000)
    tokens[::11] = 7     # 'def'
    tokens[1::11] = 13   # '('
    tokens[2::11] = 29   # ')'

    dense, vocab = transactions_from_tokens(tokens, window=64, num_items=256)
    print(f"{dense.shape[0]} windows x {dense.shape[1]} token-items")

    res = mine(dense, AprioriConfig(min_support=0.6, max_k=4))
    inv = {j: int(t) for j, t in enumerate(vocab)}
    print("frequent token sets (by original token id):")
    for k in sorted(res.levels):
        sets, sup = res.levels[k]
        for row, s in list(zip(sets, sup))[:8]:
            print(f"  k={k} tokens={[inv[int(i)] for i in row]} support={int(s)}")
    planted = {7, 13, 29}
    found = {
        frozenset(inv[int(i)] for i in row)
        for k in res.levels if k >= 3
        for row in res.levels[k][0]
    }
    assert any(planted <= f for f in found), "planted trigram set must be mined"
    print("planted {7,13,29} trigram recovered ✓")


if __name__ == "__main__":
    main()

from repro.training.optimizer import AdamWConfig, adamw_init, adamw_update, lr_schedule
from repro.training.train_loop import (
    build_grads_of,
    build_train_step,
    init_train_state,
    make_train_step,
    state_specs,
)

"""Continuous rulebook refresh: store append → delta mine → hot-swap.

The serving tier has had a freshness *gauge* since PR 9
(``generation_age_seconds`` + its SLO) but nothing that closed the loop —
rulebooks only changed when an operator re-mined. :class:`RefreshController`
is that loop (DESIGN.md §15):

    appended rows land in the store (``StoreWriter.open_for_append``)
        → the controller's watcher notices the row watermark advance
        → delta mine against the persisted count cache
          (``core.incremental.mine_delta``; full SON re-mine as fallback,
          PR-6 checkpoint snapshots so a crash mid-delta resumes)
        → ``compile_rulebook``
        → coordinated hot-swap on the target (Gateway or Router — both
          re-stamp ``generation_age_seconds`` at commit)

The controller is deliberately *level-triggered*: each cycle reads the
manifest row count and compares it to the watermark of the last swap, so a
missed poll, a crashed refresh, or many appends coalescing into one refresh
all converge to the same fixed point — serving generation covers store
contents. ``handle_alert`` accepts SLO engine events (signal ``freshness``)
and kicks an immediate cycle, turning a burning freshness budget into a
refresh instead of a page.
"""

from __future__ import annotations

import threading
import time

from repro.core import apriori as ap
from repro.core import incremental as inc
from repro.core import streaming as st
from repro.data.store import open_store
from repro.serving.metrics import _RegistryMetrics
from repro.serving.rulebook import compile_rulebook


class RefreshMetrics(_RegistryMetrics):
    """Registry-backed refresh counters + the ``refresh_latency_seconds``
    histogram (created by the base bundle), observable through the same
    snapshot/SLO machinery as the gateway/router bundles (§13)."""

    _COUNTER_FIELDS = (
        "triggered",          # refresh cycles started
        "delta",              # served by the incremental path
        "full",               # full re-mine (mode or fallback)
        "noop",               # no new rows since the cache generation
        "failures",
        "rows_folded",        # appended rows folded into the cache
        "novel_reverified",   # candidates re-counted over the base store
        "alert_kicks",        # cycles forced by a freshness SLO alert
    )

    def __init__(self, registry=None):
        super().__init__(registry, prefix="refresh")

    def record_cycle(self, mode: str, seconds: float, rows: int, novel: int) -> None:
        with self._lock:
            self._inc("triggered")
            self._inc(mode)      # "delta" | "full" | "noop"
            self._counters["rows_folded"].inc(rows)
            self._counters["novel_reverified"].inc(novel)
            self.latency.record(seconds)

    def record_failure(self) -> None:
        with self._lock:
            self._inc("triggered")
            self._inc("failures")


class RefreshController:
    """Background driver keeping a serving target's rulebook current with an
    append-only :class:`TransactionStore`.

    ``target`` is anything with ``hot_swap(rulebook) -> generation`` and a
    ``metrics.registry`` (Gateway or Router). ``mode="delta"`` goes through
    :func:`core.incremental.mine_delta` (which itself falls back to a full
    SON re-mine on a cold/invalid cache or an oversized delta);
    ``mode="full"`` always re-mines with the level-wise streamed driver.
    ``min_append_rows`` is the watermark hysteresis: a refresh fires once at
    least that many rows sit above the last swapped watermark.
    """

    def __init__(
        self,
        store_path: str,
        target,
        cfg: ap.AprioriConfig = ap.AprioriConfig(),
        *,
        mesh=None,
        chunk_rows: int = 8192,
        prefetch: int = 2,
        min_confidence: float = 0.5,
        score: str = "confidence",
        max_rules: int | None = None,
        mode: str = "delta",
        min_append_rows: int = 1,
        poll_interval_s: float = 0.25,
        max_delta_fraction: float = inc.DEFAULT_MAX_DELTA_FRACTION,
        max_drift_fraction: float = inc.DEFAULT_MAX_DRIFT_FRACTION,
        fault=None,
        checkpoint=True,
        registry=None,
        on_refresh=None,
    ):
        if mode not in ("delta", "full"):
            raise ValueError(f"mode must be delta|full, got {mode!r}")
        self.store_path = store_path
        self.target = target
        self.cfg = cfg
        self.mesh = mesh
        self.chunk_rows = chunk_rows
        self.prefetch = prefetch
        self.min_confidence = min_confidence
        self.score = score
        self.max_rules = max_rules
        self.mode = mode
        self.min_append_rows = max(1, int(min_append_rows))
        self.poll_interval_s = poll_interval_s
        self.max_delta_fraction = max_delta_fraction
        self.max_drift_fraction = max_drift_fraction
        self.fault = fault
        self.checkpoint = checkpoint
        self.on_refresh = on_refresh
        self.metrics = RefreshMetrics(
            registry if registry is not None
            else getattr(getattr(target, "metrics", None), "registry", None)
        )
        self.history: list[dict] = []
        self.last_error: BaseException | None = None
        # rows the SERVED rulebook covers; a refresh advances it. In delta
        # mode the count cache records exactly that (the initial rulebook
        # came out of build_count_cache), so rows appended BEFORE the
        # controller starts still count as pending; without a cache the
        # store's current size is the best available baseline.
        cache = inc.load_count_cache(open_store(store_path))
        self.watermark = (
            cache.n if (mode == "delta" and cache is not None)
            else open_store(store_path).num_transactions
        )
        self._lock = threading.Lock()        # serializes refresh cycles
        self._wake = threading.Event()
        self._stop = threading.Event()
        self._alert_kick = False
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------------ lifecycle --
    def start(self) -> "RefreshController":
        if self._thread is not None:
            raise RuntimeError("RefreshController already started")
        self._stop.clear()
        self._thread = threading.Thread(
            target=self._run, name="refresh-controller", daemon=True
        )
        self._thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        self._wake.set()
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def __enter__(self) -> "RefreshController":
        return self.start()

    def __exit__(self, exc_type, exc, tb) -> None:
        self.stop()

    # -------------------------------------------------------------- watcher --
    def _store_rows(self) -> int:
        try:
            return open_store(self.store_path).num_transactions
        except (FileNotFoundError, ValueError):
            return self.watermark   # store mid-rewrite: treat as unchanged

    def pending_rows(self) -> int:
        return max(0, self._store_rows() - self.watermark)

    def _run(self) -> None:
        while not self._stop.is_set():
            kicked, self._alert_kick = self._alert_kick, False
            threshold = 1 if kicked else self.min_append_rows
            if self.pending_rows() >= threshold:
                try:
                    self.refresh_now()
                except Exception:
                    pass   # recorded in metrics/last_error; keep watching
            self._wake.wait(self.poll_interval_s)
            self._wake.clear()

    def handle_alert(self, event) -> None:
        """SLO engine hook: a firing freshness alert forces a cycle even
        below the watermark hysteresis (the PR-9 loop, closed)."""
        signal = getattr(event, "signal", None) or (
            event.get("signal") if isinstance(event, dict) else None
        )
        severity = getattr(event, "severity", None) or (
            event.get("severity") if isinstance(event, dict) else None
        )
        if signal == "freshness" and severity not in (None, "ok"):
            self.metrics._inc("alert_kicks")
            self._alert_kick = True
            self._wake.set()

    # -------------------------------------------------------------- refresh --
    def refresh_now(self) -> int:
        """Run one synchronous refresh cycle; returns the new serving
        generation. Raises (and counts a failure) if mining/swap fail —
        the previous generation keeps serving either way."""
        with self._lock:
            t0 = time.perf_counter()
            try:
                store = open_store(self.store_path)
                if self.mode == "full":
                    res = st.mine_streamed(
                        store, self.cfg, self.mesh,
                        chunk_rows=self.chunk_rows, prefetch=self.prefetch,
                    )
                    report = inc.DeltaReport(
                        mode="full", reason="mode_full",
                        base_rows=0, delta_rows=store.num_transactions,
                        base_shards=0, delta_shards=store.num_partitions,
                    )
                else:
                    res, report = inc.mine_delta(
                        store, self.cfg, self.mesh,
                        chunk_rows=self.chunk_rows, prefetch=self.prefetch,
                        fault=self.fault, checkpoint=self.checkpoint,
                        resume=True,
                        max_delta_fraction=self.max_delta_fraction,
                        max_drift_fraction=self.max_drift_fraction,
                    )
                rulebook = compile_rulebook(
                    res,
                    min_confidence=self.min_confidence,
                    score=self.score,
                    max_rules=self.max_rules,
                    num_items=store.num_items,
                )
                generation = self.target.hot_swap(rulebook)
                self.watermark = store.num_transactions
            except BaseException as e:
                self.last_error = e
                self.metrics.record_failure()
                raise
            seconds = time.perf_counter() - t0
            self.metrics.record_cycle(
                report.mode, seconds,
                rows=report.delta_rows, novel=report.novel_candidates,
            )
            record = {
                "generation": generation,
                "mode": report.mode,
                "reason": report.reason,
                "seconds": seconds,
                "delta_rows": report.delta_rows,
                "novel_candidates": report.novel_candidates,
                "watermark": self.watermark,
                "rules": int(rulebook.num_rules),
            }
            self.history.append(record)
            if self.on_refresh is not None:
                self.on_refresh(record)
            return generation

    def stats(self) -> dict:
        return {
            "watermark": self.watermark,
            "pending_rows": self.pending_rows(),
            "cycles": len(self.history),
            "last": self.history[-1] if self.history else None,
        }

"""Qwen1.5-110B [hf:Qwen family] — dense GQA (kv=8) with QKV bias."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-110b",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=49152,
    vocab_size=152064,
    qkv_bias=True,
)

"""Train step factory: pjit full-step (GSPMD collectives) with optional
microbatch accumulation and optional int8-EF-compressed cross-pod reduction.

The compressed path reuses the paper's Map/Reduce skeleton for gradients
(DESIGN.md §4 form 2): shard_map manual over the 'pod' axis ONLY (data/model
stay GSPMD-auto inside), per-pod grads psum'd over ('data',) implicitly by
the inner auto partitioner, then the cross-pod (DCN) hop runs through
distributed.compression.compressed_psum — the expensive link carries int8.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.distributed import compression
from repro.distributed.sharding import ShardingRules, batch_pspec, param_pspecs
from repro.models.transformer import init_model, loss_fn
from repro.training.optimizer import AdamWConfig, adamw_init, adamw_update


def init_train_state(key, cfg, opt_cfg: AdamWConfig | None = None, compress: bool = False,
                     n_pods: int = 1):
    params = init_model(key, cfg)
    state = {"params": params, "opt": adamw_init(params)}
    if compress:
        # error-feedback residuals are PER-POD state: leading pod dim,
        # sharded P("pod", ...) through the manual shard_map.
        err = compression.int8_ef_state(params)
        state["ef_err"] = jax.tree.map(
            lambda e: jnp.zeros((n_pods,) + e.shape, e.dtype), err
        )
    return state


def state_specs(state, mesh, rules: ShardingRules = ShardingRules()):
    pspecs = param_pspecs(state["params"], mesh, rules)
    out = {
        "params": pspecs,
        "opt": {"m": pspecs, "v": pspecs, "step": P()},
    }
    if "ef_err" in state:
        out["ef_err"] = pspecs
    return out


def build_grads_of(cfg, microbatches: int = 1):
    """fn(params, batch) -> (loss, metrics, grads), with optional microbatch
    accumulation (scan over a leading micro dim)."""

    def grads_of(params, batch):
        if microbatches == 1:
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, cfg, batch
            )
            return loss, metrics, grads

        def micro(c, mb):
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, cfg, mb
            )
            acc_loss, acc_grads = c
            return (acc_loss + loss, jax.tree.map(jnp.add, acc_grads, grads)), metrics

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        mbs = jax.tree.map(lambda x: x.reshape(microbatches, -1, *x.shape[1:]), batch)
        (loss, grads), metrics = jax.lax.scan(micro, (jnp.float32(0), zeros), mbs)
        scale = 1.0 / microbatches
        return loss * scale, jax.tree.map(lambda m: m[-1], metrics), jax.tree.map(
            lambda g: g * scale, grads
        )

    return grads_of


def build_train_step(cfg, opt_cfg: AdamWConfig, microbatches: int = 1):
    """The raw (un-jitted) fn(state, batch) -> (state, metrics) — used by the
    trainer (jitted below) and by the dry-run (jitted with explicit shardings)."""

    grads_of = build_grads_of(cfg, microbatches)

    def plain_step(state, batch):
        loss, metrics, grads = grads_of(state["params"], batch)
        params, opt, opt_metrics = adamw_update(state["params"], grads, state["opt"], opt_cfg)
        new_state = dict(state, params=params, opt=opt)
        return new_state, {"loss": loss, **metrics, **opt_metrics}

    return plain_step


def make_train_step(
    cfg,
    opt_cfg: AdamWConfig,
    mesh=None,
    rules: ShardingRules = ShardingRules(),
    microbatches: int = 1,
    cross_pod_compress: bool = False,
    donate: bool = True,
):
    """Returns jit'd fn(state, batch) -> (state, metrics)."""
    plain_step = build_train_step(cfg, opt_cfg, microbatches)
    grads_of = build_grads_of(cfg, microbatches)

    if mesh is None:
        return jax.jit(plain_step, donate_argnums=(0,) if donate else ())

    if not cross_pod_compress:
        fn = plain_step
    else:
        if "pod" not in mesh.axis_names:
            raise ValueError("cross_pod_compress needs a 'pod' mesh axis")
        n_pods = mesh.shape["pod"]

        def fn(state, batch):
            # manual over 'pod' ONLY; 'data'/'model' stay GSPMD-auto inside
            # (in_specs describe just the manual axis; auto shardings are
            # inherited from the arrays).
            pod_spec = jax.tree.map(
                lambda x: P("pod", *([None] * (x.ndim - 1))), batch
            )
            ef_spec = jax.tree.map(
                lambda e: P("pod", *([None] * (e.ndim - 1))), state["ef_err"]
            )

            def body(params, opt, ef_err, batch):
                ef_err = jax.tree.map(lambda e: e[0], ef_err)  # drop pod dim
                loss, metrics, grads = grads_of(params, batch)
                grads, ef_err = compression.compressed_psum(grads, ef_err, ("pod",))
                grads = jax.tree.map(lambda g: g / n_pods, grads)
                params, opt, opt_metrics = adamw_update(params, grads, opt, opt_cfg)
                ef_err = jax.tree.map(lambda e: e[None], ef_err)
                out_metrics = jax.tree.map(
                    lambda v: jax.lax.pmean(v, ("pod",)),
                    {"loss": loss, **metrics},
                )
                return params, opt, ef_err, {**out_metrics, **opt_metrics}

            from repro.core.mapreduce import shard_map

            shard_fn = shard_map(
                body,
                mesh=mesh,
                in_specs=(P(), P(), ef_spec, pod_spec),
                out_specs=(P(), P(), ef_spec, P()),
                axis_names={"pod"},
            )
            params, opt, ef_err, metrics = shard_fn(
                state["params"], state["opt"], state["ef_err"], batch
            )
            return dict(state, params=params, opt=opt, ef_err=ef_err), metrics

    return jax.jit(fn, donate_argnums=(0,) if donate else ())

"""SLO engine (obs.slo): burn-rate math over synthetic cuts, the
ok -> warn -> page state machine (immediate upgrades, hysteresis on
downgrades, transition-only dedup), the JSONL alert stream, and the
canonical spec builders (DESIGN.md §14)."""

import json

import pytest

from repro.obs import (AlertEvent, BurnRule, SLOEvaluator, SLOSpec,
                       mining_slos, serving_slos)
from repro.obs.registry import MetricsRegistry


class FakeClock:
    def __init__(self, t: float = 0.0):
        self.t = t

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


PAGE_RULE = BurnRule("page", long_window_s=10.0, short_window_s=2.0,
                     burn_threshold=10.0)


def make_eval(spec, **kw):
    clock = FakeClock()
    ev = SLOEvaluator(MetricsRegistry(), [spec], clear_after_s=1.0,
                      now_fn=clock, **kw)
    return ev, clock


def ratio_spec(**overrides):
    kw = dict(name="avail", kind="error_ratio", signal="availability",
              bad=("bad",), good=("good",), target_ratio=0.99,
              rules=(PAGE_RULE,))
    kw.update(overrides)
    return SLOSpec(**kw)


# ------------------------------------------------------------- burn math --

def test_error_ratio_burn_fires_page_and_reports_burn_rate():
    ev, clock = make_eval(ratio_spec())
    ev.tick(cut={"bad": 0.0, "good": 0.0})
    clock.advance(1.0)
    assert ev.tick(cut={"bad": 0.0, "good": 100.0}) == []
    clock.advance(1.0)
    events = ev.tick(cut={"bad": 50.0, "good": 150.0})
    # windowed ratio 50/200 = 0.25; budget 0.01 -> burn 25x >= 10 on BOTH
    # windows (the burst is inside the 2s short window too)
    assert [e.severity for e in events] == ["page"]
    assert events[0].previous == "ok"
    assert events[0].burn_rate == pytest.approx(25.0)
    assert events[0].window_s == 10.0
    assert ev.states() == {"avail": "page"}


def test_no_data_and_clean_windows_stay_ok():
    ev, clock = make_eval(ratio_spec())
    assert ev.tick(cut={}) == []                 # no counters at all
    clock.advance(1.0)
    assert ev.tick(cut={"bad": 0.0, "good": 500.0}) == []
    assert ev.states() == {"avail": "ok"}


def test_short_window_gates_stale_burns():
    """An old burst still inside the long window but outside the short one
    must NOT fire: the multi-window AND is what makes recovery fast."""
    ev, clock = make_eval(ratio_spec())
    ev.tick(cut={"bad": 0.0, "good": 0.0})
    # jump 5s, arriving with a burst already in the books: the long window
    # (10s) spans it (burn 20x), but the short window (2s) only ever sees
    # the clean recent deltas
    clock.advance(5.0)
    ev.tick(cut={"bad": 100.0, "good": 400.0})
    clock.advance(0.5)
    ev.tick(cut={"bad": 100.0, "good": 500.0})
    clock.advance(0.5)
    assert ev.tick(cut={"bad": 100.0, "good": 600.0}) == []
    assert ev.alert_history() == []
    assert ev.states() == {"avail": "ok"}


def test_latency_kind_counts_over_threshold_buckets():
    spec = SLOSpec(name="lat", kind="latency", signal="latency",
                   metric="latency_seconds", threshold_s=0.05,
                   target_ratio=0.9, rules=(BurnRule("page", 10.0, 2.0, 5.0),))
    reg = MetricsRegistry()
    h = reg.histogram("latency_seconds")
    clock = FakeClock()
    ev = SLOEvaluator(reg, [spec], clear_after_s=1.0, now_fn=clock)
    ev.tick()                                    # baseline: empty histogram
    for _ in range(95):
        h.record(0.001)
    for _ in range(5):
        h.record(0.2)
    clock.advance(1.0)
    assert ev.tick() == []                       # 5% errs = burn 0.5 < 5
    for _ in range(100):
        h.record(0.2)                            # all over the objective now
    clock.advance(1.0)
    events = ev.tick()
    assert [e.severity for e in events] == ["page"]
    assert events[0].kind == "latency"
    assert events[0].objective == pytest.approx(0.05)


def test_gauge_bound_both_directions():
    above = SLOSpec(name="age", kind="gauge_bound", signal="freshness",
                    metric="g", bound=5.0, above_is_error=True,
                    target_ratio=0.9, rules=(BurnRule("page", 10.0, 2.0, 5.0),))
    ev, clock = make_eval(above)
    for v in (1.0, 1.0, 10.0, 10.0):             # half the samples violate
        ev.tick(cut={"g": v})
        clock.advance(0.5)
    assert ev.states() == {"age": "page"}        # 0.5 / 0.1 budget = 5x

    below = SLOSpec(name="healthy", kind="gauge_bound", signal="availability",
                    metric="g", bound=1.0, above_is_error=False,
                    target_ratio=0.9, rules=(BurnRule("page", 10.0, 2.0, 5.0),))
    ev2, clock2 = make_eval(below)
    for v in (1.0, 1.0, 0.5, 0.5):               # dips BELOW the floor err
        ev2.tick(cut={"g": v})
        clock2.advance(0.5)
    assert ev2.states() == {"healthy": "page"}


def test_throughput_floor():
    spec = SLOSpec(name="tput", kind="throughput", signal="throughput",
                   metric="rows", floor_per_s=100.0, target_ratio=0.99,
                   rules=(BurnRule("page", 2.0, 1.0, 10.0),))
    ev, clock = make_eval(spec)
    ev.tick(cut={"rows": 0.0})
    clock.advance(1.0)
    assert ev.tick(cut={"rows": 1000.0}) == []   # 1000 rows/s >= floor
    clock.advance(2.0)
    assert ev.tick(cut={"rows": 1001.0}) == []   # stall begins (short window
    clock.advance(0.5)                           # has no delta yet)
    events = ev.tick(cut={"rows": 1001.0})       # ~0 rows/s < floor: fire
    assert [e.severity for e in events] == ["page"]


# ---------------------------------------------------- state machine -------

def burn_cut(n):
    """A cut n steps into a sustained 50% error burn."""
    return {"bad": 50.0 * n, "good": 50.0 * n}


def test_sustained_burn_emits_exactly_one_event():
    ev, clock = make_eval(ratio_spec())
    ev.tick(cut=burn_cut(0))
    for n in range(1, 8):                        # burning for 7 straight ticks
        clock.advance(0.5)
        ev.tick(cut=burn_cut(n))
    history = ev.alert_history()
    assert [e.severity for e in history] == ["page"]     # dedup: once, not 7x


def test_downgrade_needs_hysteresis_and_calm_ticks_dont_flap():
    ev, clock = make_eval(ratio_spec())
    ev.tick(cut={"bad": 0.0, "good": 0.0})
    clock.advance(1.0)
    ev.tick(cut={"bad": 50.0, "good": 50.0})
    assert ev.states() == {"avail": "page"}

    # jump past both windows so every further delta is clean
    clock.advance(11.0)
    ev.tick(cut={"bad": 50.0, "good": 1000.0})   # calm verdict -> pending
    assert ev.states() == {"avail": "page"}      # hysteresis: not yet
    clock.advance(0.5)
    ev.tick(cut={"bad": 50.0, "good": 1100.0})   # 0.5s < clear_after 1.0s
    assert ev.states() == {"avail": "page"}
    clock.advance(0.6)
    events = ev.tick(cut={"bad": 50.0, "good": 1200.0})
    assert [e.severity for e in events] == ["ok"]
    assert events[0].previous == "page" and events[0].cleared
    # the full arc is exactly two transitions: fire once, clear once
    assert [e.severity for e in ev.alert_history()] == ["page", "ok"]


def test_refire_during_pending_resets_the_clear_timer():
    ev, clock = make_eval(ratio_spec())
    ev.tick(cut={"bad": 0.0, "good": 0.0})
    clock.advance(1.0)
    ev.tick(cut={"bad": 50.0, "good": 50.0})     # page
    clock.advance(11.0)
    ev.tick(cut={"bad": 50.0, "good": 1000.0})   # calm -> pending clear
    clock.advance(0.8)
    # a fresh burst while the clear is pending: both windows burn again
    ev.tick(cut={"bad": 550.0, "good": 1000.0})
    assert ev.states() == {"avail": "page"}      # still page, no flap
    clock.advance(11.0)
    ev.tick(cut={"bad": 550.0, "good": 9000.0})  # calm again, pending restarts
    clock.advance(0.8)
    ev.tick(cut={"bad": 550.0, "good": 9100.0})  # 0.8s < 1.0s: NOT cleared
    assert ev.states() == {"avail": "page"}
    clock.advance(0.3)
    ev.tick(cut={"bad": 550.0, "good": 9200.0})
    assert ev.states() == {"avail": "ok"}
    assert [e.severity for e in ev.alert_history()] == ["page", "ok"]


def test_warn_then_page_escalates_immediately():
    rules = (BurnRule("page", 10.0, 2.0, burn_threshold=20.0),
             BurnRule("warn", 10.0, 2.0, burn_threshold=5.0))
    ev, clock = make_eval(ratio_spec(rules=rules))
    ev.tick(cut={"bad": 0.0, "good": 0.0})
    clock.advance(1.0)
    ev.tick(cut={"bad": 10.0, "good": 90.0})     # 10% -> burn 10: warn only
    assert ev.states() == {"avail": "warn"}
    clock.advance(0.5)
    ev.tick(cut={"bad": 60.0, "good": 140.0})    # 30% -> burn 30: page NOW
    assert ev.states() == {"avail": "page"}      # upgrade skips hysteresis
    assert [e.severity for e in ev.alert_history()] == ["warn", "page"]


# ------------------------------------------------- fan-out + lifecycle ----

def test_jsonl_stream_and_subscriber_isolation(tmp_path):
    path = tmp_path / "alerts.jsonl"
    spec = ratio_spec()
    clock = FakeClock()
    got = []
    ev = SLOEvaluator(MetricsRegistry(), [spec], clear_after_s=1.0,
                      interval_s=999.0, jsonl_path=str(path), now_fn=clock)
    ev.subscribe(lambda e: got.append(e))
    bad_calls = []
    ev.subscribe(lambda e: (bad_calls.append(e), 1 / 0))   # raising subscriber
    with ev:
        clock.advance(1.0)
        ev.tick(cut={"bad": 0.0, "good": 0.0})
        clock.advance(1.0)
        ev.tick(cut={"bad": 50.0, "good": 50.0})
    assert [e.severity for e in got] == ["page"]
    assert len(bad_calls) == 1
    assert ev.subscriber_errors == 1             # counted, never fatal
    lines = [json.loads(line) for line in path.read_text().splitlines()]
    assert [rec["severity"] for rec in lines] == ["page"]
    assert set(lines[0]) == {
        "slo", "signal", "kind", "severity", "previous", "burn_rate",
        "window_s", "value", "objective", "t_wall", "message"}
    assert lines[0]["slo"] == "avail" and lines[0]["signal"] == "availability"


def test_status_exposes_burns_and_values():
    ev, clock = make_eval(ratio_spec())
    ev.tick(cut={"bad": 0.0, "good": 0.0})
    clock.advance(1.0)
    ev.tick(cut={"bad": 25.0, "good": 75.0})
    st = ev.status()["avail"]
    assert st["state"] == "page"
    assert st["value"] == pytest.approx(0.25)
    assert st["objective"] == pytest.approx(0.99)
    assert st["burns"]["10s"] == pytest.approx(25.0)


# -------------------------------------------------------------- builders --

def test_serving_slos_replicated_set():
    specs = serving_slos("router", p99_ms=25.0, replicated=True,
                         freshness_bound_s=30.0)
    by_name = {s.name: s for s in specs}
    assert set(by_name) == {"latency_p99", "availability",
                            "replica_availability", "replica_disruption",
                            "generation_lag", "freshness"}
    assert by_name["latency_p99"].threshold_s == pytest.approx(0.025)
    assert by_name["latency_p99"].metric == "router_latency_seconds"
    # every availability-signal spec keys the router's brownout reaction
    avail = [s for s in specs if s.signal == "availability"]
    assert len(avail) == 3
    assert "router_failovers" in by_name["replica_disruption"].bad
    assert by_name["replica_availability"].above_is_error is False
    assert by_name["freshness"].bound == pytest.approx(30.0)


def test_serving_slos_single_gateway_and_mining():
    specs = serving_slos("gateway")
    assert {s.name for s in specs} == {"latency_p99", "availability"}
    assert specs[1].bad == ("gateway_rejected", "gateway_failed")
    (tput,) = mining_slos(rows_per_s_floor=1e4)
    assert tput.kind == "throughput" and tput.floor_per_s == pytest.approx(1e4)


def test_spec_and_rule_validation():
    with pytest.raises(ValueError):
        BurnRule("fatal", 10.0, 2.0, 1.0)        # unknown severity
    with pytest.raises(ValueError):
        BurnRule("page", 2.0, 10.0, 1.0)         # short > long
    with pytest.raises(ValueError):
        SLOSpec(name="x", kind="vibes")
    with pytest.raises(ValueError):
        SLOSpec(name="x", kind="latency", target_ratio=1.0)  # empty budget
    with pytest.raises(ValueError):
        SLOSpec(name="x", kind="latency", rules=())
    with pytest.raises(ValueError):
        SLOEvaluator(MetricsRegistry(), [ratio_spec(), ratio_spec()])


def test_alert_event_round_trips_json():
    ev = AlertEvent(slo="s", signal="latency", kind="latency", severity="warn",
                    previous="ok", burn_rate=3.5, window_s=60.0, value=0.1,
                    objective=0.05, t_wall=123.0, message="m")
    assert AlertEvent(**ev.to_json()) == ev
    assert not ev.cleared

"""Serving loop: batched prefill + greedy decode over per-family caches.

The decode caches (GQA KV / MLA latent / SSD state / RWKV state) come from
models.transformer.init_decode_cache; distributed.sharding.cache_pspecs gives
their mesh layout (sequence-sharded KV -> GSPMD-partitioned softmax, the
flash-decoding dataflow)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models.transformer import decode_step, init_decode_cache, prefill_step


def make_prefill_step(cfg, cache_len: int, mesh=None, in_shardings=None, out_shardings=None):
    def fn(params, batch):
        return prefill_step(params, cfg, batch, cache_len)

    kw = {}
    if in_shardings is not None:
        kw = dict(in_shardings=in_shardings, out_shardings=out_shardings)
    return jax.jit(fn, **kw)


def make_decode_step(cfg, mesh=None, in_shardings=None, out_shardings=None, donate=True):
    def fn(params, cache, tokens, pos):
        return decode_step(params, cfg, cache, tokens, pos)

    kw = dict(donate_argnums=(1,) if donate else ())
    if in_shardings is not None:
        kw.update(in_shardings=in_shardings, out_shardings=out_shardings)
    return jax.jit(fn, **kw)


def generate(params, cfg, prompt_batch, max_new_tokens: int, cache_len: int | None = None):
    """Greedy generation for a batch of equal-length prompts. Returns
    (B, max_new_tokens) int32 tokens."""
    if cfg.frontend == "frames":
        b, s = prompt_batch["frames"].shape[:2]
        prompt_key = "frames"
    else:
        b, s = prompt_batch["tokens"].shape
        prompt_key = "tokens"
    cache_len = cache_len or (s + max_new_tokens)

    logits, cache = jax.jit(lambda p, bt: prefill_step(p, cfg, bt, cache_len))(
        params, prompt_batch
    )
    step = make_decode_step(cfg)

    out = []
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    vlm_off = cfg.num_patches if cfg.frontend == "vlm" else 0
    for i in range(max_new_tokens):
        out.append(tok)
        pos = jnp.full((b,), s + vlm_off + i, jnp.int32)
        if cfg.frontend == "frames":
            # audio stub decodes from the embedding of the sampled token id
            emb = jax.nn.one_hot(tok, cfg.vocab_size, dtype=jnp.float32)
            frame = emb @ jax.random.normal(jax.random.key(0), (cfg.vocab_size, cfg.d_model)) * 0.02
            logits, cache = step(params, cache, frame[:, None, :], pos)
        else:
            logits, cache = step(params, cache, tok[:, None], pos)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jnp.stack(out, axis=1)

"""Optimizer + train-step behaviour."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.training.optimizer import AdamWConfig, adamw_init, adamw_update, lr_schedule
from repro.training.train_loop import build_train_step, init_train_state


def test_lr_schedule_shape():
    cfg = AdamWConfig(peak_lr=1e-3, warmup_steps=10, decay_steps=100, min_lr_frac=0.1)
    lrs = [float(lr_schedule(jnp.asarray(s), cfg)) for s in [0, 5, 10, 55, 100, 1000]]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(5e-4)
    assert lrs[2] == pytest.approx(1e-3)
    assert lrs[3] < lrs[2]
    assert lrs[-1] == pytest.approx(1e-4)  # floor


def test_adamw_minimizes_quadratic():
    params = {"w": jnp.asarray([3.0, -2.0])}
    opt = adamw_init(params)
    cfg = AdamWConfig(peak_lr=0.1, warmup_steps=0, decay_steps=1000, weight_decay=0.0)
    for _ in range(300):
        g = {"w": 2 * params["w"]}
        params, opt, _ = adamw_update(params, g, opt, cfg)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_grad_clipping_applied():
    params = {"w": jnp.zeros(3)}
    opt = adamw_init(params)
    cfg = AdamWConfig(peak_lr=1.0, warmup_steps=0, grad_clip=1.0, weight_decay=0.0)
    _, _, metrics = adamw_update(params, {"w": jnp.asarray([100.0, 0, 0])}, opt, cfg)
    assert float(metrics["grad_norm"]) == pytest.approx(100.0)


def _tiny_batch(cfg, b=4, s=16, seed=0):
    rng = np.random.default_rng(seed)
    toks = rng.integers(0, cfg.vocab_size, (b, s + 1))
    return {
        "tokens": jnp.asarray(toks[:, :-1], jnp.int32),
        "labels": jnp.asarray(toks[:, 1:], jnp.int32),
    }


def test_train_step_reduces_loss():
    cfg = get_config("qwen1p5_4b").reduced()
    state = init_train_state(jax.random.key(0), cfg)
    step = jax.jit(build_train_step(cfg, AdamWConfig(peak_lr=3e-3, warmup_steps=5)))
    batch = _tiny_batch(cfg)  # overfit one batch
    losses = []
    for _ in range(25):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses[::6]
    assert int(state["opt"]["step"]) == 25


def test_microbatch_accumulation_matches_full_batch():
    cfg = get_config("qwen1p5_4b").reduced()
    state = init_train_state(jax.random.key(1), cfg)
    batch = _tiny_batch(cfg, b=8)
    opt = AdamWConfig(peak_lr=1e-3, warmup_steps=0)
    s1, m1 = jax.jit(build_train_step(cfg, opt, microbatches=1))(state, batch)
    s2, m2 = jax.jit(build_train_step(cfg, opt, microbatches=2))(state, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-5)
    l1, l2 = jax.tree.leaves(s1["params"]), jax.tree.leaves(s2["params"])
    for a, b in zip(l1, l2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-5)


def test_generate_greedy_runs():
    from repro.serving.serve_loop import generate

    cfg = get_config("deepseek_coder_33b").reduced()
    from repro.models.transformer import init_model

    params = init_model(jax.random.key(0), cfg)
    prompt = {"tokens": jnp.asarray(np.arange(12).reshape(2, 6) % cfg.vocab_size, jnp.int32)}
    toks = generate(params, cfg, prompt, max_new_tokens=4)
    assert toks.shape == (2, 4)
    assert ((0 <= np.asarray(toks)) & (np.asarray(toks) < cfg.vocab_size)).all()

"""Serving-side supervision: restart dead gateway dispatch workers.

The gateway's micro-batcher runs ONE dispatch worker thread; if that thread
dies (a bug outside the per-group exception fence, an injected fault), every
queued request would hang forever — the exact failure mode the paper's
JobTracker answers by re-arming a dead TaskTracker's work. The
:class:`WorkerSupervisor` polls the worker's liveness and, on death, calls
``MicroBatcher.restart_worker()``: the futures of the batch that was
IN FLIGHT inside the dead worker are failed explicitly (with the
:class:`~repro.serving.batcher.WorkerCrashed` cause — a client sees an
error, never a hang), the admission queue is left intact and a fresh worker
thread re-arms it, and the restart lands in
``serving/metrics.py::worker_restarts``.

**Restart-storm guard.** A worker that crashes on every dispatch (a
poisoned request, a broken kernel) must not be restarted forever — Hadoop
blacklists a TaskTracker after repeated task failures for the same reason.
Both supervisors cap restarts per sliding window (``max_restarts`` within
``restart_window_s``) with exponential backoff between consecutive
restarts; past the cap the worker is declared DEAD: its batcher is closed
so every pending future fails explicitly (``WorkerCrashed``) and new
submits are refused (``AdmissionRejected``) — degraded loudly, never a
restart loop or a hang. The verdict is surfaced in :meth:`stats`.

:class:`ReplicaSetSupervisor` generalizes the same loop to N gateway
replicas (the serving router's replica set, DESIGN.md §12): one poll
thread, a per-replica storm guard, and callbacks so the router can track
replica health transitions (restarted → re-sync, gave up → dead).

Scope: supervision restarts the DISPATCH LOOP, not the device state — the
rulebook generations are immutable host/device records owned by the
gateway, so a restarted worker serves the same generation bit-for-bit.
"""

from __future__ import annotations

import threading
import time
from collections import deque


class RestartGuard:
    """Sliding-window restart budget with exponential inter-restart backoff.

    ``allow(now)`` answers "may I restart right now?"; once the window holds
    ``max_restarts`` the guard gives up permanently (``gave_up``) — the
    supervisor's cue to declare the worker dead."""

    def __init__(self, max_restarts: int = 5, window_s: float = 10.0,
                 backoff_s: float = 0.05, backoff_multiplier: float = 2.0):
        if max_restarts < 1:
            raise ValueError("max_restarts must be >= 1")
        self.max_restarts = int(max_restarts)
        self.window_s = float(window_s)
        self.backoff_s = float(backoff_s)
        self.backoff_multiplier = float(backoff_multiplier)
        self._history: deque[float] = deque()
        self._next_allowed = 0.0
        self.gave_up = False

    def _prune(self, now: float) -> None:
        while self._history and self._history[0] < now - self.window_s:
            self._history.popleft()

    def allow(self, now: float) -> bool:
        if self.gave_up:
            return False
        self._prune(now)
        if len(self._history) >= self.max_restarts:
            self.gave_up = True          # restart storm: stop re-arming
            return False
        return now >= self._next_allowed

    def record(self, now: float) -> None:
        """Count one restart and push the next one out by the backoff."""
        self._history.append(now)
        self._next_allowed = now + self.backoff_s * (
            self.backoff_multiplier ** (len(self._history) - 1)
        )

    @property
    def window_restarts(self) -> int:
        return len(self._history)


def _give_up(batcher) -> None:
    """Declare a worker dead: close its batcher so every pending future
    fails explicitly (in-flight AND queued -> WorkerCrashed) and new
    submits are refused — a dead replica sheds load, it never hangs it."""
    batcher.close(timeout=1.0)


class WorkerSupervisor:
    """Poll a gateway's dispatch worker; restart it when it dies.

    Context-managed::

        with Gateway(rb) as gw, WorkerSupervisor(gw):
            ...

    ``restarts`` counts successful restarts (also mirrored into the
    gateway's metrics by ``restart_worker`` itself); ``dead`` is True once
    the restart-storm guard gave up and the worker was declared dead.
    """

    def __init__(self, gateway, poll_interval_s: float = 0.02, *,
                 max_restarts: int = 5, restart_window_s: float = 10.0,
                 restart_backoff_s: float = 0.05,
                 restart_backoff_multiplier: float = 2.0):
        self._batcher = gateway._batcher
        self._interval = float(poll_interval_s)
        self._guard = RestartGuard(max_restarts, restart_window_s,
                                   restart_backoff_s, restart_backoff_multiplier)
        self._stop = threading.Event()
        self.restarts = 0
        self.dead = False
        self._thread = threading.Thread(
            target=self._run, name="gateway-supervisor", daemon=True
        )
        self._thread.start()

    def _run(self) -> None:
        while not self._stop.wait(self._interval):
            if self._batcher.closed or self.dead:
                continue            # shutdown is not a crash
            if self._batcher.worker_alive:
                continue
            now = time.perf_counter()
            if self._guard.allow(now):
                if self._batcher.restart_worker():
                    self.restarts += 1
                    self._guard.record(now)
            elif self._guard.gave_up:
                self.dead = True
                _give_up(self._batcher)

    def stats(self) -> dict:
        return {
            "restarts": self.restarts,
            "dead": self.dead,
            "window_restarts": self._guard.window_restarts,
        }

    def close(self) -> None:
        self._stop.set()
        self._thread.join(timeout=5.0)

    def __enter__(self) -> "WorkerSupervisor":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()


class ReplicaSetSupervisor:
    """One supervision loop over N gateway replicas (DESIGN.md §12).

    The router's JobTracker: polls every replica's dispatch worker, re-arms
    dead ones through a per-replica :class:`RestartGuard`, and past the
    storm cap declares the REPLICA dead (batcher closed — pending futures
    fail explicitly, the router's failover re-routes them). ``on_restarted``
    / ``on_gave_up`` callbacks let the owner (the router) drive its health
    state machine and re-sync a revived replica's rulebook generation.
    """

    def __init__(self, gateways, poll_interval_s: float = 0.02, *,
                 max_restarts: int = 5, restart_window_s: float = 10.0,
                 restart_backoff_s: float = 0.05,
                 restart_backoff_multiplier: float = 2.0,
                 on_restarted=None, on_gave_up=None):
        self._batchers = [gw._batcher for gw in gateways]
        self._interval = float(poll_interval_s)
        self._guards = [
            RestartGuard(max_restarts, restart_window_s,
                         restart_backoff_s, restart_backoff_multiplier)
            for _ in self._batchers
        ]
        self._on_restarted = on_restarted
        self._on_gave_up = on_gave_up
        self._stop = threading.Event()
        self.restarts = [0] * len(self._batchers)
        self.dead = [False] * len(self._batchers)
        self._thread = threading.Thread(
            target=self._run, name="replica-set-supervisor", daemon=True
        )
        self._thread.start()

    def _run(self) -> None:
        while not self._stop.wait(self._interval):
            for i, b in enumerate(self._batchers):
                if b.closed or self.dead[i] or b.worker_alive:
                    continue
                now = time.perf_counter()
                guard = self._guards[i]
                if guard.allow(now):
                    if b.restart_worker():
                        self.restarts[i] += 1
                        guard.record(now)
                        if self._on_restarted is not None:
                            self._on_restarted(i)
                elif guard.gave_up:
                    self.dead[i] = True
                    _give_up(b)
                    if self._on_gave_up is not None:
                        self._on_gave_up(i)

    def stats(self) -> dict:
        return {
            "restarts": list(self.restarts),
            "dead": list(self.dead),
            "window_restarts": [g.window_restarts for g in self._guards],
        }

    def close(self) -> None:
        self._stop.set()
        self._thread.join(timeout=5.0)

    def __enter__(self) -> "ReplicaSetSupervisor":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()

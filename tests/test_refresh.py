"""RefreshController (DESIGN.md §15): the continuous append → delta mine →
hot-swap loop — watermark hysteresis, freshness-alert kick, refresh metrics,
failure isolation, and zero dropped requests across a live refresh."""

import os
import time

import numpy as np
import pytest

from repro.core import apriori as ap
from repro.core import incremental as inc
from repro.data import store as ds
from repro.data.synthetic import QuestConfig, gen_transactions
from repro.serving import Gateway, RefreshController, compile_rulebook

NUM_ITEMS = 48
CFG = ap.AprioriConfig(min_support=0.02, max_k=3)


def _rows(n, seed):
    return gen_transactions(
        QuestConfig(num_transactions=n, num_items=NUM_ITEMS, seed=seed)
    )


@pytest.fixture()
def served(tmp_path):
    """(store_path, gateway) with a built count cache behind generation 0."""
    p = str(tmp_path / "db")
    s = ds.ingest_dense(_rows(1500, seed=1), p, shard_rows=256)
    res, _ = inc.build_count_cache(s, CFG, chunk_rows=300)
    gw = Gateway(compile_rulebook(res, min_confidence=0.4, num_items=NUM_ITEMS))
    yield p, gw
    gw.close()


def _wait_for(pred, timeout=90.0):
    t0 = time.time()
    while not pred():
        assert time.time() - t0 < timeout, "timed out waiting"
        time.sleep(0.02)


def test_refresh_now_delta_swaps_and_advances_watermark(served):
    p, gw = served
    ctl = RefreshController(p, gw, CFG, chunk_rows=300, min_confidence=0.4)
    ds.append_chunks([_rows(120, seed=2)], p)
    assert ctl.pending_rows() == 120
    gen = ctl.refresh_now()
    assert gen == gw.generation == 1
    assert ctl.watermark == 1620 and ctl.pending_rows() == 0
    last = ctl.history[-1]
    assert last["mode"] == "delta" and last["delta_rows"] == 120
    assert ctl.metrics.delta == 1 and ctl.metrics.rows_folded == 120
    # the served rulebook equals one compiled from a full re-mine
    res, rep = inc.mine_delta(ds.open_store(p), CFG, chunk_rows=300)
    assert rep.mode == "noop"   # refresh_now already advanced the cache


def test_background_watermark_trigger_and_hysteresis(served):
    p, gw = served
    with RefreshController(
        p, gw, CFG, chunk_rows=300, min_confidence=0.4,
        min_append_rows=100, poll_interval_s=0.03,
    ) as ctl:
        ds.append_chunks([_rows(40, seed=3)], p)
        time.sleep(0.3)
        assert gw.generation == 0, "below hysteresis: no refresh"
        ds.append_chunks([_rows(80, seed=4)], p)   # 120 pending now
        _wait_for(lambda: gw.generation == 1)
        _wait_for(lambda: ctl.stats()["pending_rows"] == 0)
    assert ctl.metrics.triggered == 1
    assert ctl.history[-1]["delta_rows"] == 120


def test_freshness_alert_forces_refresh_below_hysteresis(served):
    p, gw = served
    with RefreshController(
        p, gw, CFG, chunk_rows=300, min_confidence=0.4,
        min_append_rows=10_000, poll_interval_s=0.03,
    ) as ctl:
        ds.append_chunks([_rows(30, seed=5)], p)
        ctl.handle_alert({"signal": "availability", "severity": "page"})
        ctl.handle_alert({"signal": "freshness", "severity": "ok"})
        time.sleep(0.2)
        assert gw.generation == 0, "only a firing freshness alert kicks"
        ctl.handle_alert({"signal": "freshness", "severity": "ticket"})
        _wait_for(lambda: gw.generation == 1)
    assert ctl.metrics.alert_kicks == 1


def test_refresh_restamps_generation_age(served):
    p, gw = served
    age = gw.metrics.generation_age
    time.sleep(0.3)
    before = age.value
    assert before >= 0.3
    ctl = RefreshController(p, gw, CFG, chunk_rows=300, min_confidence=0.4)
    ds.append_chunks([_rows(60, seed=6)], p)
    ctl.refresh_now()
    assert age.value < before, "the swap must re-stamp the freshness clock"


def test_refresh_failure_keeps_serving_and_counts(served, monkeypatch):
    p, gw = served
    ctl = RefreshController(p, gw, CFG, chunk_rows=300, min_confidence=0.4)
    ds.append_chunks([_rows(50, seed=7)], p)
    monkeypatch.setattr(
        inc, "mine_delta", lambda *a, **kw: (_ for _ in ()).throw(RuntimeError("boom"))
    )
    with pytest.raises(RuntimeError):
        ctl.refresh_now()
    monkeypatch.undo()
    assert gw.generation == 0, "old generation keeps serving"
    assert ctl.metrics.failures == 1 and isinstance(ctl.last_error, RuntimeError)
    assert ctl.pending_rows() == 50, "watermark not advanced by a failure"
    assert ctl.refresh_now() == 1    # and the next cycle succeeds


def test_full_mode_never_touches_the_cache(served):
    p, gw = served
    seq_before = ds.open_store(p).count_cache_meta["seq"]
    ctl = RefreshController(p, gw, CFG, chunk_rows=300, min_confidence=0.4, mode="full")
    ds.append_chunks([_rows(60, seed=8)], p)
    ctl.refresh_now()
    assert gw.generation == 1
    assert ctl.history[-1]["mode"] == "full"
    assert ctl.metrics.full == 1
    assert ds.open_store(p).count_cache_meta["seq"] == seq_before


def test_zero_dropped_requests_across_live_refresh(served):
    """Requests submitted while the delta mine + swap run all resolve, and
    every response names a generation that actually served (0 or 1)."""
    p, gw = served
    baskets = [np.flatnonzero(r).tolist() or [0] for r in _rows(64, seed=9)]
    ds.append_chunks([_rows(120, seed=10)], p)
    with RefreshController(
        p, gw, CFG, chunk_rows=300, min_confidence=0.4, poll_interval_s=0.02
    ):
        generations = set()
        deadline = time.time() + 90
        while gw.generation == 0 and time.time() < deadline:
            for b in baskets[:8]:
                generations.add(gw.submit(b, top_k=4).result().generation)
            time.sleep(0.02)   # paced client: leave the miner thread CPU
        assert gw.generation == 1
        for b in baskets:
            generations.add(gw.submit(b, top_k=4).result().generation)
    assert generations <= {0, 1} and 1 in generations
    m = gw.metrics
    assert m.completed == m.submitted - m.rejected
    assert m.rejected == 0


def test_refresh_metrics_share_target_registry(served):
    p, gw = served
    ctl = RefreshController(p, gw, CFG, chunk_rows=300, min_confidence=0.4)
    snap = gw.metrics.registry.snapshot()
    assert "refresh_triggered" in snap and "refresh_latency_seconds" in snap
    assert ctl.metrics.registry is gw.metrics.registry

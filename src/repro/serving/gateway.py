"""Online serving gateway: queue → bucketizer → match step → demux (§10).

The gateway turns the batch engine (`serving/recommend.py`) into an online
query service. Independent clients call :meth:`Gateway.submit` (or the
blocking :meth:`Gateway.query`) with ONE basket each; the micro-batcher
(`serving/batcher.py`) coalesces concurrent arrivals, the gateway pads each
coalesced group to a power-of-two jit bucket, runs the SAME cached match
step + top-k step the batch engine uses — so a gateway response is
bit-identical to a direct :func:`~repro.serving.recommend.recommend` call
against the answering rulebook — and demultiplexes per-request
:class:`Response` futures.

**Generations + hot-swap.** The servable rulebook is wrapped in an immutable
generation record ``(generation id, device-placed rulebook)`` behind a single
reference. :meth:`hot_swap` device-places and warm-compiles the incoming
rulebook FIRST (double-buffered: both generations resident), then replaces
the reference — one atomic store. Every dispatch grabs the reference exactly
once, so a batch is answered wholly by one generation and every
:class:`Response` carries the ``generation`` that answered it; in-flight and
queued requests are never dropped by a swap, they simply resolve against
whichever generation their dispatch grabbed. The old generation's device
arrays free when the last in-flight batch referencing them completes.

**Cache.** An exact-basket LRU (`serving/cache.py`) keyed on
``(packed words, top_k, generation)`` answers repeat baskets without
queueing; the generation in the key makes stale hits impossible after a
swap. All counters land in `serving/metrics.py`.
"""

from __future__ import annotations

import dataclasses
import math
import threading
import time
from concurrent.futures import Future

import numpy as np

from repro.core import itemsets as enc
from repro.serving.batcher import AdmissionRejected, MicroBatcher, Request
from repro.serving.cache import BasketCache, basket_key
from repro.serving.metrics import GatewayMetrics
from repro.serving.recommend import _cached_match_step, _topk_items, pack_baskets
from repro.serving.rulebook import Rulebook, place_rulebook


@dataclasses.dataclass
class Response:
    """One answered basket query."""

    items: np.ndarray      # (top_k,) int32 recommended item ids
    scores: np.ndarray     # (top_k,) float32 evidence (-inf = beyond scoreable)
    generation: int        # rulebook generation that answered
    cached: bool           # served from the exact-basket cache
    latency_s: float       # submit -> response
    bucket: int            # padded jit bucket of the answering dispatch: the
                           # response is bit-identical to recommend(...,
                           # batch_size=bucket) against this generation (§10)


class _Generation:
    """Immutable (id, device-placed rulebook) pair — the swap unit."""

    __slots__ = ("generation", "rulebook")

    def __init__(self, generation: int, rulebook: Rulebook):
        self.generation = generation
        self.rulebook = rulebook


def pow2_bucket(n: int, max_batch: int, multiple: int = 1) -> int:
    """Smallest power-of-two >= n (clamped to max_batch), rounded up to
    ``multiple`` (the data-shard count on a mesh) — the jit bucket ladder:
    O(log max_batch) compiled shapes regardless of arrival pattern."""
    if n < 1 or n > max_batch:
        raise ValueError(f"batch of {n} outside [1, {max_batch}]")
    b = 1 << (n - 1).bit_length()
    b = min(b, max_batch)
    b = max(b, n)                       # max_batch itself may not be a pow2
    return ((b + multiple - 1) // multiple) * multiple


class Gateway:
    """Micro-batched online query service over a hot-swappable rulebook."""

    def __init__(
        self,
        rulebook: Rulebook,
        *,
        mesh=None,
        impl: str = "auto",
        top_k: int = 10,
        exclude_basket: bool = True,
        max_batch: int = 64,
        max_wait_ms: float = 1.0,
        p99_target_ms: float | None = None,
        queue_depth: int = 1024,
        cache_capacity: int = 4096,
        data_axes: tuple = ("data",),
        rule_axis: str = "model",
        block_n: int = 256,
        block_k: int = 256,
        warmup: bool | str = True,
        tracer=None,
        trace_root: bool = True,
    ):
        """``warmup``: ``True`` compiles the bucket-ladder endpoints
        (1 and ``max_batch``) per generation before it serves; ``"ladder"``
        compiles every power-of-two bucket (no mid-load jit spikes at all);
        ``False`` compiles lazily on first use.

        ``p99_target_ms``: enables the p99-targeted adaptive straggler wait
        (§14): ``max_wait_ms`` becomes the wait CEILING (and starting point)
        and a bounded-AIMD controller shrinks the wait whenever the windowed
        latency p99 burns past the target — the adaptive gateway never waits
        longer than the fixed configuration, it only gets out of the way
        faster. ``None`` keeps the classic fixed wait.

        ``tracer``: optional :class:`repro.obs.Tracer`; sampled requests get
        cache-probe / queue-wait / batch-assembly / device-dispatch spans.
        ``trace_root=False`` (the router's replicas) makes the gateway only
        ever CONTINUE a trace handed in by its caller, never start one —
        sampling then happens once, at the router."""
        self.num_items = rulebook.num_items
        self.default_top_k = min(top_k, self.num_items)
        self.exclude_basket = exclude_basket
        self.max_batch = int(max_batch)
        self._words = enc.packed_words(self.num_items)
        self._mesh = mesh
        self._rule_axis = rule_axis
        self._warmup_enabled = warmup
        self._tracer = tracer
        self._trace_root = bool(trace_root)
        self._closed = False

        if mesh is None:
            self._row_multiple = 1
            self._basket_sharding = None
        else:
            from jax.sharding import NamedSharding
            from jax.sharding import PartitionSpec as P

            self._row_multiple = math.prod(mesh.shape[a] for a in data_axes)
            self._basket_sharding = NamedSharding(mesh, P(tuple(data_axes), None))
        # the SAME lru-cached step recommend() uses: gateway and batch engine
        # share one jit entry per (mesh, impl, axes, blocks)
        self._step = _cached_match_step(mesh, impl, tuple(data_axes), rule_axis, block_n, block_k)

        self.metrics = GatewayMetrics()
        self.cache = BasketCache(cache_capacity)
        self._swap_lock = threading.RLock()
        self._generation = self._place(0, rulebook)
        self.metrics.mark_generation_commit()   # freshness clock starts now
        if warmup:
            self._warm(self._generation)
        self.wait_controller = None
        if p99_target_ms is not None:
            from repro.serving.controller import AdaptiveMaxWait

            self.wait_controller = AdaptiveMaxWait(
                self.metrics.latency,
                objective_ms=float(p99_target_ms),
                initial_wait_ms=max_wait_ms,   # ceiling == the fixed config
                max_wait_ms=max_wait_ms,
            )
        self._batcher = MicroBatcher(
            self._dispatch,
            max_batch=max_batch,
            max_wait_ms=max_wait_ms,
            queue_depth=queue_depth,
            metrics=self.metrics,
            wait_controller=self.wait_controller,
        )

    # ---------------------------------------------------------- lifecycle --
    def close(self) -> None:
        """Stop admitting; every already-admitted request still resolves."""
        self._closed = True
        self._batcher.close()

    def __enter__(self) -> "Gateway":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()

    # ----------------------------------------------------------- requests --
    def submit(self, basket, top_k: int | None = None, deadline_ms: float | None = None,
               _span_parent=None):
        """Admit one basket query; returns a Future[:class:`Response`].

        ``basket``: item-id list/tuple/1-D int array, or a pre-packed (W,)
        uint32 bitset row. Raises :class:`AdmissionRejected` when the queue
        is full or the gateway is closed — overload is reported, not
        silently dropped. ``deadline_ms`` bounds the REQUEST, not just the
        caller's wait: a request still queued when its deadline passes is
        dropped at dispatch time with
        :class:`~repro.serving.batcher.DeadlineExceeded` instead of
        spending device time on abandoned work.

        ``_span_parent``: internal — a router attempt span this request
        should continue (the cross-layer trace-context propagation, §13).
        """
        if self._closed:
            self.metrics.record_admission(False)
            raise AdmissionRejected("gateway closed")
        k = min(self.default_top_k if top_k is None else int(top_k), self.num_items)
        packed = self._pack_one(basket)
        t0 = time.perf_counter()

        span = None
        if self._tracer is not None:
            if _span_parent is not None:
                span = self._tracer.child(_span_parent, "gateway.request", top_k=k)
            elif self._trace_root:
                span = self._tracer.root("gateway.request", top_k=k)
            if span is not None:
                span.t0 = t0   # backdate to submit entry so cache.probe
                               # and queue.wait nest inside this span

        gen = self._generation
        hit = self.cache.get(basket_key(packed, k, gen.generation), count=False)
        if span is not None:
            self._tracer.add_span(span, "cache.probe", t0, time.perf_counter(),
                                  hit=hit is not None)
        if hit is not None:
            items, scores, answered_by, bucket = hit
            latency = time.perf_counter() - t0
            self.cache.record(True)
            self.metrics.record_cache(True)
            self.metrics.record_admission(True)
            self.metrics.record_response(latency)
            fut = Future()
            fut.set_result(Response(items, scores, answered_by, True, latency, bucket))
            if span is not None:
                span.end(outcome="cache_hit", generation=answered_by)
            return fut

        deadline = None if deadline_ms is None else t0 + max(0.0, float(deadline_ms)) / 1e3
        req = Request(packed=packed, top_k=k, future=Future(), t_submit=t0,
                      deadline=deadline, span=span)
        try:
            self._batcher.submit(req)   # raises AdmissionRejected on overload
        except AdmissionRejected:
            if span is not None:
                span.end(outcome="rejected")
            raise
        # hit/miss is counted only for admitted requests, and on BOTH the
        # cache's and the gateway metrics' counters — the two published
        # hit-rates agree, and cache_hits + cache_misses == submitted
        self.cache.record(False)
        self.metrics.record_cache(False)
        return req.future

    def query(self, basket, top_k: int | None = None, timeout: float | None = 60.0,
              deadline_ms: float | None = None) -> Response:
        """Blocking convenience wrapper: ``submit(...).result(timeout)``."""
        return self.submit(basket, top_k, deadline_ms=deadline_ms).result(timeout)

    # ----------------------------------------------------------- hot-swap --
    def prepare_swap(self, rulebook: Rulebook, generation: int | None = None) -> "_Generation":
        """Phase 1 of the two-phase swap protocol (§12): device-place and
        (when ``warmup``) bucket-ladder-compile the incoming rulebook WITHOUT
        flipping the serving reference — both generations resident. Returns
        the prepared generation record for :meth:`commit_swap`. A failure
        here leaves serving untouched (the old generation keeps answering).

        ``generation`` pins the new generation id — the router uses this to
        keep ids aligned across replicas so a replica that missed a swap can
        re-sync straight to the coordinated target id.
        """
        if rulebook.num_items != self.num_items:
            raise ValueError(
                f"hot-swap rulebook has {rulebook.num_items} items, gateway "
                f"serves {self.num_items} — vocabulary must be stable across swaps"
            )
        gen_id = self._generation.generation + 1 if generation is None else int(generation)
        sp = None
        if self._tracer is not None and self._trace_root:
            sp = self._tracer.root("swap.prepare", force=True, generation=gen_id)
        try:
            gen = self._place(gen_id, rulebook)
            if self._warmup_enabled:
                self._warm(gen)          # double-buffer: compile before commit
        finally:
            if sp is not None:
                sp.end()
        return gen

    def commit_swap(self, prepared: "_Generation") -> int:
        """Phase 2: flip the serving reference to a prepared generation —
        one atomic store, same zero-drop/zero-mix contract as
        :meth:`hot_swap`."""
        sp = None
        if self._tracer is not None and self._trace_root:
            sp = self._tracer.root("swap.commit", force=True,
                                   generation=prepared.generation)
        with self._swap_lock:
            self._generation = prepared  # the atomic store
            self.metrics.record_swap()
            if sp is not None:
                sp.end()
            return prepared.generation

    def hot_swap(self, rulebook: Rulebook) -> int:
        """Atomically replace the serving rulebook; returns the new
        generation id. Prepare (place + warm, double-buffered) then commit —
        requests never stall on the incoming rulebook; requests already
        dispatched or queued resolve normally, and a response's
        ``generation`` says which rulebook answered.
        """
        with self._swap_lock:    # RLock: serializes concurrent hot_swaps so
            # two callers can never mint the same generation id
            return self.commit_swap(self.prepare_swap(rulebook))

    @property
    def generation(self) -> int:
        """Current serving generation id."""
        return self._generation.generation

    @property
    def queue_depth(self) -> int:
        """Requests currently queued in the batcher."""
        return self._batcher.depth

    @property
    def queue_capacity(self) -> int:
        """Admission-queue bound (brownout shedding's denominator, §14)."""
        return self._batcher.capacity

    def stats(self) -> dict:
        gen = self._generation
        out = self.metrics.snapshot()
        out["generation"] = gen.generation
        out["num_rules"] = gen.rulebook.num_rules
        out["queue_depth"] = self._batcher.depth
        out["max_wait_ms"] = self._batcher.current_max_wait_ms
        if self.wait_controller is not None:
            out["wait_controller"] = self.wait_controller.snapshot()
        out["cache"] = self.cache.snapshot()
        return out

    # ----------------------------------------------------------- internals --
    def _pack_one(self, basket) -> np.ndarray:
        """A 1-D uint32 array of exactly ``W`` words is the pre-packed form
        (how store rows arrive); every other sequence is an item-id list.
        The collision — uint32 *item ids* that happen to number exactly W —
        is unresolvable from the value alone, so submit id lists as plain
        Python ints / signed arrays, never uint32."""
        if (isinstance(basket, np.ndarray) and basket.ndim == 1
                and basket.dtype == np.uint32 and basket.shape[0] == self._words):
            return np.ascontiguousarray(basket)
        return pack_baskets([list(np.asarray(basket, dtype=np.int64))], self.num_items)[0]

    def _place(self, generation: int, rulebook: Rulebook) -> _Generation:
        import jax

        if not isinstance(rulebook.ante_packed, jax.Array):
            rulebook = place_rulebook(rulebook, self._mesh, self._rule_axis)
        return _Generation(generation, rulebook)

    def _warm(self, gen: _Generation) -> None:
        """Compile jit buckets for this generation's rule count (jit keys on
        the rulebook row count) off the serving path: the ladder endpoints,
        or with ``warmup="ladder"`` every power-of-two bucket."""
        if self._warmup_enabled == "ladder":
            ns = {1 << p for p in range(self.max_batch.bit_length())
                  if 1 << p <= self.max_batch} | {self.max_batch}
        else:
            ns = {1, self.max_batch}
        for n in sorted(ns):
            bucket = pow2_bucket(n, self.max_batch, self._row_multiple)
            self._match(np.zeros((bucket, self._words), np.uint32), gen, self.default_top_k)

    def _match(self, b: np.ndarray, gen: _Generation, top_k: int):
        """Pad-free core: run one padded bucket through match + top-k."""
        import jax
        import jax.numpy as jnp

        rb = gen.rulebook
        if self._basket_sharding is not None:
            b_dev = jax.device_put(b, self._basket_sharding)
        else:
            b_dev = jnp.asarray(b)
        item_scores = self._step(b_dev, rb.ante_packed, rb.ante_len, rb.cons_packed, rb.scores)
        idx, vals = _topk_items(
            item_scores, b_dev,
            top_k=top_k, exclude_basket=self.exclude_basket, num_items=self.num_items,
        )
        return np.asarray(idx), np.asarray(vals)

    def _dispatch(self, group: list) -> None:
        """Batcher callback: one coalesced same-top_k group -> responses.

        The generation reference is read ONCE per dispatch — the whole batch
        is answered by a single rulebook, so responses can never mix
        generations within a batch."""
        gen = self._generation
        k = group[0].top_k
        t_drain = time.perf_counter()
        bucket = pow2_bucket(len(group), self.max_batch, self._row_multiple)
        b = np.zeros((bucket, self._words), np.uint32)
        for i, r in enumerate(group):
            b[i] = r.packed
        t_asm = time.perf_counter()
        idx, vals = self._match(b, gen, k)
        t_dev = time.perf_counter()
        tr = self._tracer
        if tr is not None:
            for r in group:
                if r.span is not None:
                    tr.add_span(r.span, "queue.wait", r.t_submit, t_drain)
                    tr.add_span(r.span, "batch.assemble", t_drain, t_asm,
                                batch=len(group), bucket=bucket)
                    tr.add_span(r.span, "device.dispatch", t_asm, t_dev,
                                bucket=bucket)
        self.metrics.record_batch(len(group), bucket)
        now = time.perf_counter()
        for i, r in enumerate(group):
            items, scores = idx[i], vals[i]
            self.cache.put(
                basket_key(r.packed, k, gen.generation),
                (items, scores, gen.generation, bucket),
            )
            latency = now - r.t_submit
            self.metrics.record_response(latency)
            if r.span is not None:
                # the per-request "where did the time go" breakdown the p99
                # bench row reads straight off the root span (§13)
                r.span.end(outcome="ok", generation=gen.generation, bucket=bucket,
                           queue_ms=(t_drain - r.t_submit) * 1e3,
                           batch_ms=(t_asm - t_drain) * 1e3,
                           device_ms=(t_dev - t_asm) * 1e3)
            r.future.set_result(Response(items, scores, gen.generation, False, latency, bucket))

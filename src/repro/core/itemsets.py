"""Itemset / transaction encodings.

The canonical device format is a dense {0,1} int8 matrix over the item
vocabulary: transactions (N, I) and candidate itemsets (K, I).  Containment
``c ⊆ t`` then becomes ``<t, c> == |c|``, turning support counting into an
int8 matmul with an exact int32 accumulation — the MXU-native reshape of the
paper's per-transaction subset scan (DESIGN.md §2).

A packed uint32 bitset format (N, ceil(I/32)) is provided for host-side
storage and for the VPU popcount counting path.
"""

from __future__ import annotations

import numpy as np


def dense_from_lists(transactions, num_items: int) -> np.ndarray:
    """Lists of item ids -> dense {0,1} int8 matrix (N, num_items)."""
    out = np.zeros((len(transactions), num_items), dtype=np.int8)
    for row, items in enumerate(transactions):
        if len(items):
            idx = np.asarray(list(items), dtype=np.int64)
            if (idx < 0).any() or (idx >= num_items).any():
                raise ValueError(f"item id out of range in transaction {row}")
            out[row, idx] = 1
    return out


def itemsets_to_dense(itemsets: np.ndarray, num_items: int) -> np.ndarray:
    """(K, k) arrays of item ids -> dense {0,1} int8 matrix (K, num_items)."""
    itemsets = np.asarray(itemsets)
    if itemsets.ndim != 2:
        raise ValueError("itemsets must be (K, k)")
    k_count = itemsets.shape[0]
    out = np.zeros((k_count, num_items), dtype=np.int8)
    rows = np.repeat(np.arange(k_count), itemsets.shape[1])
    out[rows, itemsets.ravel()] = 1
    return out


def pack_bits(dense: np.ndarray) -> np.ndarray:
    """Dense {0,1} (N, I) -> packed uint32 (N, ceil(I/32)), little-endian bits."""
    dense = np.asarray(dense, dtype=np.uint8)
    n, i = dense.shape
    words = (i + 31) // 32
    padded = np.zeros((n, words * 32), dtype=np.uint8)
    padded[:, :i] = dense
    bits = padded.reshape(n, words, 32)
    shifts = np.arange(32, dtype=np.uint32)
    return (bits.astype(np.uint32) << shifts).sum(axis=2, dtype=np.uint32)


def unpack_bits(packed: np.ndarray, num_items: int) -> np.ndarray:
    """Inverse of :func:`pack_bits`."""
    packed = np.asarray(packed, dtype=np.uint32)
    n, words = packed.shape
    shifts = np.arange(32, dtype=np.uint32)
    bits = (packed[:, :, None] >> shifts) & np.uint32(1)
    return bits.reshape(n, words * 32)[:, :num_items].astype(np.int8)


def singleton_itemsets(num_items: int) -> np.ndarray:
    """All 1-itemsets, (num_items, 1)."""
    return np.arange(num_items, dtype=np.int32)[:, None]

"""Online serving driver: load store → mine → compile → serve loop (§10).

  # end to end on synthetic data (store ingested under a temp dir):
  PYTHONPATH=src python -m repro.launch.serve --transactions 4000 --items 128 \
      --requests 2000 --concurrency 16
  # persistent store (reused when the manifest exists; --ingest re-ingests):
  PYTHONPATH=src python -m repro.launch.serve --store /data/quest --ingest ...
  # exercise a live rulebook hot-swap halfway through the client load:
  PYTHONPATH=src python -m repro.launch.serve ... --hot-swap-mid-load \
      --swap-min-support 0.04
  # supervised dispatch worker + injected mid-load crash (DESIGN.md §11):
  PYTHONPATH=src python -m repro.launch.serve ... --supervise \
      --crash-worker-mid-load
  # replicated tier (DESIGN.md §12): N replicas behind the failure-aware
  # router, with an injected replica kill AND a coordinated hot-swap live:
  PYTHONPATH=src python -m repro.launch.serve ... --replicas 3 \
      --kill-replica-mid-load --hot-swap-mid-load --deadline-ms 5000
  # continuous refresh (DESIGN.md §15): the initial mine persists a count
  # cache; 5% new rows are APPENDED to the live store mid-load and the
  # RefreshController delta-mines + hot-swaps them in under traffic:
  PYTHONPATH=src python -m repro.launch.serve ... --refresh delta \
      --append-mid-load 0.05
  # machine-readable summary (the CI smoke gate reads this):
  PYTHONPATH=src python -m repro.launch.serve ... --json serve-smoke.json
  # SLOs + burn-rate alerting + closed-loop reactions (DESIGN.md §14); the
  # alert stream lands next to the metrics series and perfetto trace, and
  # `python -m repro.launch.status` renders both offline:
  PYTHONPATH=src python -m repro.launch.serve ... --replicas 2 \
      --kill-replica-mid-load --slo --slo-p99-ms 50 \
      --alerts-jsonl serve-alerts.jsonl --metrics-jsonl serve-series.jsonl

The full paper-to-production pipeline in one command: the synthetic DB is
ingested CHUNKED into an on-disk ``TransactionStore``, mined with the
streaming Map/Reduce driver (``mine_streamed``), compiled into a servable
rulebook, and served through the micro-batched online ``Gateway`` while a
closed-loop client population (``--concurrency`` threads, baskets drawn from
the store's own transactions) fires independent single-basket queries.
``--hot-swap-mid-load`` re-mines the SAME store at ``--swap-min-support``
while traffic is running and hot-swaps the fresh rulebook in: the summary
then shows both generations answering, with zero dropped requests.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--transactions", type=int, default=4_000)
    ap.add_argument("--items", type=int, default=128)
    ap.add_argument("--avg-len", type=float, default=10.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--store", default="", metavar="DIR",
                    help="on-disk transaction store (default: temp dir, ingested fresh)")
    ap.add_argument("--ingest", action="store_true",
                    help="force (re-)ingest of the synthetic DB into --store")
    ap.add_argument("--shard-rows", type=int, default=2048)
    ap.add_argument("--stream-chunk-rows", type=int, default=2048)
    ap.add_argument("--min-support", type=float, default=0.02)
    ap.add_argument("--max-k", type=int, default=4)
    ap.add_argument("--min-confidence", type=float, default=0.4)
    ap.add_argument("--rule-score", default="confidence", choices=["confidence", "lift"])
    ap.add_argument("--max-rules", type=int, default=None)
    ap.add_argument("--impl", default="auto",
                    choices=["auto", "jnp", "pallas", "pallas_interpret"])
    # gateway policy
    ap.add_argument("--top-k", type=int, default=10)
    ap.add_argument("--max-batch", type=int, default=64)
    ap.add_argument("--max-wait-ms", type=float, default=1.0)
    ap.add_argument("--queue-depth", type=int, default=1024)
    ap.add_argument("--cache", type=int, default=4096, help="basket cache capacity")
    # client load
    ap.add_argument("--requests", type=int, default=2_000)
    ap.add_argument("--concurrency", type=int, default=16)
    ap.add_argument("--hot-swap-mid-load", action="store_true",
                    help="re-mine the store and hot-swap the rulebook at half "
                         "load; goes through the incremental delta path when "
                         "the refresh mode resolves to delta (DESIGN.md §15)")
    ap.add_argument("--swap-min-support", type=float, default=None,
                    help="min-support of the full re-mine (default: 2x "
                         "--min-support; ignored on the delta path, which "
                         "keeps the serving config and folds in new rows)")
    ap.add_argument("--refresh", default="auto", choices=["auto", "delta", "full"],
                    help="rulebook refresh path: 'delta' mines appended rows "
                         "against the persisted count cache and drives the "
                         "swap through the RefreshController; 'full' keeps "
                         "the legacy whole-store re-mine; 'auto' picks delta "
                         "when the store already has a count cache (or "
                         "--append-mid-load asked for one)")
    ap.add_argument("--append-mid-load", type=float, default=0.0, metavar="FRAC",
                    help="append FRAC of the store's rows mid-load and wait "
                         "for the refresh controller to mine + hot-swap them "
                         "(the continuous-refresh smoke; implies a mid-load "
                         "swap)")
    ap.add_argument("--supervise", action="store_true",
                    help="run a WorkerSupervisor over the gateway's dispatch "
                         "worker (restarts it if it dies, DESIGN.md §11)")
    ap.add_argument("--crash-worker-mid-load", action="store_true",
                    help="fault injection: kill the dispatch worker once at "
                         "half load (requires --supervise to recover)")
    # replicated tier (DESIGN.md §12)
    ap.add_argument("--replicas", type=int, default=1,
                    help=">1 serves through the failure-aware Router over N "
                         "gateway replicas (consistent basket hashing, "
                         "failover, coordinated hot-swap)")
    ap.add_argument("--kill-replica-mid-load", action="store_true",
                    help="fault injection: kill one replica's dispatch worker "
                         "at half load (implies --replicas >= 2)")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-request deadline; expiry is a typed "
                         "DeadlineExceeded, counted in the summary")
    ap.add_argument("--json", default=None, metavar="OUT",
                    help="write the serving summary as JSON")
    # observability (DESIGN.md §13)
    ap.add_argument("--trace-out", default="", metavar="PATH",
                    help="write sampled request spans as Chrome trace-event "
                         "JSON (load in ui.perfetto.dev)")
    ap.add_argument("--trace-sample", type=float, default=0.01,
                    help="root-request sampling rate for --trace-out "
                         "(1.0 = every request; swaps are always traced)")
    ap.add_argument("--metrics-out", default="", metavar="PATH",
                    help="write the final unified metrics-registry snapshot "
                         "(gateway + per-replica + router) as JSON")
    ap.add_argument("--metrics-jsonl", default="", metavar="PATH",
                    help="append periodic registry snapshots as JSONL while "
                         "the load runs (obs.Sampler time series)")
    # active observability: SLOs + burn-rate alerting (DESIGN.md §14)
    ap.add_argument("--slo", action="store_true",
                    help="run the SLO evaluator over the serving registry "
                         "(latency/availability/replica-health/generation-lag "
                         "objectives, burn-rate alerts); with --replicas > 1 "
                         "the router subscribes to alerts (brownout shedding, "
                         "alert-triggered re-sync)")
    ap.add_argument("--slo-p99-ms", type=float, default=50.0,
                    help="latency SLO objective: p99 of request latency")
    ap.add_argument("--alerts-jsonl", default="", metavar="PATH",
                    help="append every alert state transition as JSONL "
                         "(implies --slo)")
    args = ap.parse_args()
    if args.alerts_jsonl and not args.slo:
        args.slo = True
    if args.crash_worker_mid_load and not args.supervise:
        print("[serve] --crash-worker-mid-load implies --supervise (else the load hangs)")
        args.supervise = True
    if args.kill_replica_mid_load and args.replicas < 2:
        print("[serve] --kill-replica-mid-load implies --replicas 2 "
              "(a lone killed replica has nowhere to fail over)")
        args.replicas = 2

    import numpy as np

    from repro.core import incremental as inc
    from repro.core.apriori import AprioriConfig
    from repro.core.streaming import mine_streamed
    from repro.data.store import append_chunks, ingest_quest, open_store
    from repro.data.synthetic import QuestConfig, gen_transactions_chunked
    from repro.distributed import FaultConfig
    from repro.serving import (
        AdmissionRejected,
        Gateway,
        RefreshController,
        Router,
        compile_rulebook,
    )

    # ---- 1. load (or ingest) the on-disk store ----
    qcfg = QuestConfig(num_transactions=args.transactions, num_items=args.items,
                       avg_len=args.avg_len, seed=args.seed)
    tmp = None
    store_dir = args.store
    if not store_dir:
        tmp = tempfile.TemporaryDirectory(prefix="serve_store_")
        store_dir = tmp.name
    if args.ingest or not os.path.exists(os.path.join(store_dir, "manifest.json")):
        print(f"[serve] ingesting {args.transactions} x {args.items} (chunked) "
              f"-> {store_dir} ...")
        store = ingest_quest(qcfg, store_dir, shard_rows=args.shard_rows,
                             chunk_rows=args.stream_chunk_rows)
    else:
        store = open_store(store_dir)
    print(f"[serve] store: n={store.num_transactions} items={store.num_items} "
          f"shards={store.num_partitions}")

    # ---- 2. mine (streamed) + 3. compile ----
    def mine_rulebook(min_support: float):
        cfg = AprioriConfig(min_support=min_support, max_k=args.max_k,
                            count_impl=args.impl, representation="packed")
        t0 = time.perf_counter()
        res = mine_streamed(store, cfg, chunk_rows=args.stream_chunk_rows)
        rb = compile_rulebook(res, min_confidence=args.min_confidence,
                              score=args.rule_score, max_rules=args.max_rules,
                              num_items=store.num_items)
        print(f"[serve] mined {res.total_frequent} itemsets -> {rb.num_rules} rules "
              f"(min_support={min_support}) in {time.perf_counter() - t0:.2f}s")
        return rb

    # refresh-path resolution (DESIGN.md §15): delta rides the persisted
    # count cache; auto picks it up when the store has one (a cache mined at
    # a different config is fine — mine_delta falls back + rebuilds it)
    refresh_mode = args.refresh
    if refresh_mode == "auto":
        refresh_mode = ("delta" if (store.count_cache_meta is not None
                                    or args.append_mid_load > 0) else "full")
    refresh_swap = (args.append_mid_load > 0
                    or (args.hot_swap_mid_load and refresh_mode == "delta"))
    legacy_swap = args.hot_swap_mid_load and not refresh_swap
    if refresh_swap and args.append_mid_load <= 0:
        args.append_mid_load = 0.05

    base_cfg = AprioriConfig(min_support=args.min_support, max_k=args.max_k,
                             count_impl=args.impl, representation="packed")
    if refresh_mode == "delta":
        # the universal entry: noop when the cache already covers the store,
        # delta when rows were appended, full build on a cold/invalid cache —
        # every path leaves a cache the mid-load refresh can fold into
        t0 = time.perf_counter()
        res0, rep0 = inc.mine_delta(store, base_cfg,
                                    chunk_rows=args.stream_chunk_rows)
        rb = compile_rulebook(res0, min_confidence=args.min_confidence,
                              score=args.rule_score, max_rules=args.max_rules,
                              num_items=store.num_items)
        print(f"[serve] initial mine via count cache: mode={rep0.mode} "
              f"({rep0.reason or 'up-to-date'}) {res0.total_frequent} itemsets "
              f"-> {rb.num_rules} rules in {time.perf_counter() - t0:.2f}s")
    else:
        rb = mine_rulebook(args.min_support)

    # baskets for the client load: the store's own transactions (packed rows)
    chunk, real = next(store.iter_chunks(min(4096, store.num_transactions)))
    baskets = list(chunk[:real])

    # ---- 4. serve loop under a closed-loop client population ----
    import threading
    from concurrent.futures import ThreadPoolExecutor

    from repro.distributed.supervisor import WorkerSupervisor
    from repro.serving.batcher import DeadlineExceeded, WorkerCrashed

    use_router = args.replicas > 1
    tracer = None
    if args.trace_out:
        from repro.obs import Tracer

        tracer = Tracer(sample_rate=args.trace_sample)
    gateway_kw = dict(impl=args.impl, top_k=args.top_k, max_batch=args.max_batch,
                      max_wait_ms=args.max_wait_ms, queue_depth=args.queue_depth,
                      cache_capacity=args.cache, warmup="ladder")
    if use_router:
        srv = Router(rb, args.replicas,
                     fault=FaultConfig(max_retries=3, backoff_s=0.01),
                     attempt_timeout_s=1.0, tracer=tracer, **gateway_kw)
        print(f"[serve] replicated tier: {args.replicas} replicas behind the "
              f"router (consistent basket hashing, supervised)")
    else:
        srv = Gateway(rb, tracer=tracer, **gateway_kw)

    supervisor = None
    sampler = None
    with srv as gw:
        if args.metrics_jsonl:
            from repro.obs import Sampler

            # the primary registry: router counters when replicated, else the
            # lone gateway's — one JSONL line per interval while load runs
            sampler = Sampler(gw.metrics.registry, args.metrics_jsonl,
                              interval_s=0.25)
            sampler.start()
        evaluator = None
        if args.slo:
            from repro.obs import BurnRule, SLOEvaluator, serving_slos

            # CLI-lifetime burn windows: the SRE-workbook 60s/300s ladder is
            # scaled down so a seconds-long smoke run can both FIRE and CLEAR
            rules = (BurnRule("page", long_window_s=2.0, short_window_s=0.5,
                              burn_threshold=10.0),
                     BurnRule("warn", long_window_s=6.0, short_window_s=1.5,
                              burn_threshold=3.0))
            specs = serving_slos("router" if use_router else "gateway",
                                 p99_ms=args.slo_p99_ms,
                                 replicated=use_router, rules=rules)
            evaluator = SLOEvaluator(gw.metrics.registry, specs,
                                     interval_s=0.05, clear_after_s=0.5,
                                     jsonl_path=args.alerts_jsonl or None)
            if use_router:
                # the closed loop (§14): availability alerts tighten
                # admission, generation-lag alerts trigger replica re-sync
                evaluator.subscribe(gw.handle_alert)
            evaluator.start()
            print(f"[slo] evaluating {len(specs)} SLOs "
                  f"({', '.join(s.name for s in specs)}) "
                  f"p99 objective {args.slo_p99_ms:g} ms")
        if args.supervise and not use_router:   # the router supervises itself
            supervisor = WorkerSupervisor(gw)
        # a minimal closed-loop client, intentionally independent of
        # benchmarks/load_gen.py: launch/ is importable as repro.launch.*
        # and must not depend on the repo-root `benchmarks` package
        rejected = {"n": 0}
        crashed = {"n": 0}
        expired = {"n": 0}
        latencies, generations = [], set()
        lock = threading.Lock()

        def client(indices):
            for i in indices:
                try:
                    resp = gw.submit(baskets[i % len(baskets)],
                                     deadline_ms=args.deadline_ms).result(timeout=120)
                except AdmissionRejected:
                    with lock:
                        rejected["n"] += 1
                    continue
                except WorkerCrashed:
                    # the request was in flight inside the dead worker: failed
                    # explicitly, safe to retry — matching is read-only
                    with lock:
                        crashed["n"] += 1
                    continue
                except DeadlineExceeded:
                    with lock:
                        expired["n"] += 1
                    continue
                with lock:
                    latencies.append(resp.latency_s)
                    generations.add(resp.generation)

        def fire(n_requests, offset, pool):
            shards = [range(offset + w, offset + n_requests, args.concurrency)
                      for w in range(args.concurrency)]
            for w in [pool.submit(client, s) for s in shards]:
                w.result()

        half = args.requests // 2
        print(f"[serve] firing {args.requests} requests from {args.concurrency} "
              f"closed-loop clients ...")
        if args.crash_worker_mid_load:
            # one-shot injected worker death: arms at half load below
            def _arm_crash():
                once = {"armed": True}

                def hook(batch):
                    if once["armed"]:
                        once["armed"] = False
                        gw._batcher._crash_hook = None
                        # SystemExit in a thread dies without a stderr traceback
                        raise SystemExit("injected dispatch-worker death")
                gw._batcher._crash_hook = hook
        mid_load = (args.crash_worker_mid_load or args.kill_replica_mid_load
                    or args.hot_swap_mid_load or refresh_swap)
        ctl = None
        refresh_summary = None
        t0 = time.perf_counter()
        with ThreadPoolExecutor(max_workers=args.concurrency) as pool:
            if mid_load:
                miner = None
                if legacy_swap:
                    # full path: re-mine WHILE the first half of the load is
                    # live, swap, then drive the rest on the new generation
                    swap_ms = (2 * args.min_support if args.swap_min_support is None
                               else args.swap_min_support)
                    rb2_box = {}
                    miner = threading.Thread(
                        target=lambda: rb2_box.update(rb=mine_rulebook(swap_ms)))
                    miner.start()
                elif refresh_swap:
                    ctl = RefreshController(
                        store_dir, gw, base_cfg,
                        chunk_rows=args.stream_chunk_rows,
                        min_confidence=args.min_confidence,
                        score=args.rule_score, max_rules=args.max_rules,
                        mode=refresh_mode, poll_interval_s=0.05,
                    ).start()
                fire(half, 0, pool)
                if args.crash_worker_mid_load:
                    _arm_crash()
                    print("[serve] armed a dispatch-worker crash; continuing load ...")
                if args.kill_replica_mid_load:
                    gw.fault_injection.kill_replica(0)
                    print("[serve] armed a replica-0 worker kill; continuing load ...")
                if miner is not None:
                    miner.join()
                    gen = gw.hot_swap(rb2_box["rb"])
                    kind = "coordinated two-phase" if use_router else "hot"
                    print(f"[serve] {kind}-swapped to generation {gen} with traffic live")
                if ctl is not None:
                    # append new rows into the LIVE store, then let the
                    # controller notice the watermark, delta-mine, and swap —
                    # the second half of the load runs on the new generation
                    age_gauge = getattr(gw.metrics, "generation_age", None)
                    age_before = age_gauge.value if age_gauge is not None else None
                    append_n = max(1, int(args.append_mid_load
                                          * store.num_transactions))
                    aq = QuestConfig(num_transactions=append_n,
                                     num_items=args.items,
                                     avg_len=args.avg_len, seed=args.seed + 1)
                    append_chunks(
                        gen_transactions_chunked(aq, args.stream_chunk_rows),
                        store_dir)
                    print(f"[serve] appended {append_n} rows mid-load; waiting "
                          f"for the {refresh_mode} refresh ...")
                    deadline = time.perf_counter() + 300.0
                    while not ctl.history and time.perf_counter() < deadline:
                        time.sleep(0.02)
                    if not ctl.history:
                        raise RuntimeError(
                            f"mid-load refresh did not complete: {ctl.last_error!r}")
                    age_after = age_gauge.value if age_gauge is not None else None
                    last = ctl.history[-1]
                    kind = "coordinated two-phase" if use_router else "hot"
                    print(f"[serve] refresh {kind}-swapped to generation "
                          f"{last['generation']} ({last['mode']}, "
                          f"{last['delta_rows']} rows, {last['seconds']:.2f}s) "
                          f"with traffic live")
                    refresh_summary = {
                        "mode": last["mode"],
                        "reason": last["reason"],
                        "latency_s": last["seconds"],
                        "delta_rows": last["delta_rows"],
                        "novel_candidates": last["novel_candidates"],
                        "appended_rows": append_n,
                        "generation": last["generation"],
                        "rules": last["rules"],
                        "age_before_s": age_before,
                        "age_after_s": age_after,
                    }
                fire(args.requests - half, half, pool)
            else:
                fire(args.requests, 0, pool)
        wall = time.perf_counter() - t0
        if ctl is not None:
            ctl.stop()

        if supervisor is not None:
            supervisor.close()
        if use_router:
            # let the health monitor finish reviving killed replicas so the
            # summary reports the RECOVERED replica set
            settle_until = time.perf_counter() + 5.0
            while time.perf_counter() < settle_until:
                states = [r["state"] for r in gw.stats()["replicas"]]
                if all(s == "healthy" for s in states):
                    break
                time.sleep(0.02)
        slo_status, alert_events = None, []
        if evaluator is not None:
            # alerts clear only once the bad samples age out of the long
            # burn window + hysteresis — give them time to resolve so the
            # summary (and the CI chaos gate) sees fire AND clear
            clear_until = time.perf_counter() + 10.0
            while time.perf_counter() < clear_until:
                if all(s == "ok" for s in evaluator.states().values()):
                    break
                time.sleep(0.05)
            evaluator.stop()
            slo_status = evaluator.status()
            alert_events = [e.to_json() for e in evaluator.alert_history()]
            fired = sum(1 for e in alert_events if e["severity"] != "ok")
            print(f"[slo] {len(alert_events)} alert transitions "
                  f"({fired} fired, {len(alert_events) - fired} cleared); "
                  f"final states: {evaluator.states()}")
        stats = gw.stats()
        if sampler is not None:
            sampler.stop()
            print(f"[obs] sampled {sampler.samples_written} registry snapshots "
                  f"-> {args.metrics_jsonl}", file=sys.stderr)
        if args.metrics_out:
            if use_router:
                registries = {
                    "router": gw.metrics.registry.snapshot(),
                    "replicas": [rep.gateway.metrics.registry.snapshot()
                                 for rep in gw.replicas],
                }
            else:
                registries = {"gateway": gw.metrics.registry.snapshot()}
            with open(args.metrics_out, "w") as f:
                json.dump(registries, f, indent=2)
            print(f"[obs] wrote metrics registry -> {args.metrics_out}",
                  file=sys.stderr)
        if tracer is not None:
            tracer.save_chrome(args.trace_out)
            print(f"[obs] wrote {len(tracer.spans())} spans "
                  f"({tracer.sampled_roots} sampled roots) -> {args.trace_out} "
                  "(load in ui.perfetto.dev)", file=sys.stderr)

    lat = np.asarray(sorted(latencies))
    pct = lambda q: float(np.percentile(lat, q)) * 1e3 if lat.size else 0.0
    # gated percentiles come from the REGISTRY histogram (conservative
    # bucket-upper-edge quantiles — the same numbers stats()/Prometheus/the
    # SLO evaluator see); the raw client-side np.percentile view is kept as
    # client_p*_ms so the two sources can be compared, never confused
    hist = stats["latency"]
    if use_router:
        # aggregate the per-replica gateway views into the single-gateway
        # summary shape (CI reads the same fields either way)
        gws = [r["gateway"] for r in stats["replicas"]]
        rows_real = sum(g["batch_rows_real"] for g in gws)
        rows_padded = sum(g["batch_rows_padded"] for g in gws)
        hits = sum(g["cache_hits"] for g in gws)
        misses = sum(g["cache_misses"] for g in gws)
        agg = {
            "batch_occupancy": rows_real / rows_padded if rows_padded else 0.0,
            "cache_hit_rate": hits / (hits + misses) if hits + misses else 0.0,
            "swaps": stats["coordinated_swaps"],
            "worker_restarts": sum(g["worker_restarts"] for g in gws),
        }
    else:
        agg = {k: stats[k] for k in
               ("batch_occupancy", "cache_hit_rate", "swaps", "worker_restarts")}
    summary = {
        "requests": args.requests,
        "responses": int(lat.size),
        "rejected": rejected["n"],
        "generations": sorted(int(g) for g in generations),
        "qps": lat.size / wall if wall > 0 else 0.0,
        "p50_ms": hist["p50_ms"], "p95_ms": hist["p95_ms"],
        "p99_ms": hist["p99_ms"],
        "client_p50_ms": pct(50), "client_p95_ms": pct(95),
        "client_p99_ms": pct(99),
        **agg,
        "crashed_requests": crashed["n"],
        "deadline_expired_requests": expired["n"],
        "wall_s": wall,
    }
    if use_router:
        terminal = lat.size + rejected["n"] + crashed["n"] + expired["n"]
        summary.update({
            "replicas": args.replicas,
            "replica_states": [r["state"] for r in stats["replicas"]],
            "replica_generations": [r["generation"] for r in stats["replicas"]],
            "failovers": stats["failovers"],
            "shed": stats["shed"],
            "resyncs": stats["resyncs"],
            "max_generation_lag": stats["max_generation_lag"],
            "kills_fired": srv.fault_injection.kills_fired,
            "availability": lat.size / terminal if terminal else 0.0,
            "brownout_level": stats["brownout_level"],
        })
    if refresh_summary is not None:
        summary["refresh"] = refresh_summary
    if slo_status is not None:
        summary["slo"] = slo_status
        summary["alerts"] = alert_events
        summary["alerts_fired"] = sum(
            1 for e in alert_events if e["severity"] != "ok")
        summary["alerts_cleared"] = sum(
            1 for e in alert_events if e["severity"] == "ok")
        from repro.launch.status import render_status

        print(render_status(
            metrics=None, slo_status=slo_status, alerts=alert_events,
            replicas=stats.get("replicas"), title="final SLO status"))
    print(f"[serve] {summary['responses']} responses (+{summary['rejected']} rejected, "
          f"{summary['crashed_requests']} crashed, "
          f"{summary['deadline_expired_requests']} expired) "
          f"in {wall:.2f}s = {summary['qps']:,.0f} qps | "
          f"p50={summary['p50_ms']:.2f}ms p95={summary['p95_ms']:.2f}ms "
          f"p99={summary['p99_ms']:.2f}ms | occupancy={summary['batch_occupancy']:.2f} "
          f"hit_rate={summary['cache_hit_rate']:.2f} | generations={summary['generations']} "
          f"worker_restarts={summary['worker_restarts']}")
    if use_router:
        print(f"[serve] router: states={summary['replica_states']} "
              f"gens={summary['replica_generations']} "
              f"failovers={summary['failovers']} shed={summary['shed']} "
              f"resyncs={summary['resyncs']} kills={summary['kills_fired']} "
              f"availability={summary['availability']:.4f}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(summary, f, indent=2)
        print(f"[serve] wrote {args.json}", file=sys.stderr)
    if tmp is not None:
        tmp.cleanup()


if __name__ == "__main__":
    main()

"""Generic Map/Combine/Reduce engine over ``jax.shard_map``.

The paper's Hadoop pipeline is:  map over HDFS partitions -> local combine ->
hash shuffle -> reduce per key.  On a TPU mesh the key space is dense (tensor
indices), so the shuffle+reduce degenerates to a single ``lax.psum`` (or
pmax/pmin) over the data axes — see DESIGN.md §2.  This module is the reusable
engine; ``core.apriori`` instantiates it for support counting, and
:func:`hierarchical_psum` models the paper's rack-local combiner tier.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

try:  # jax >= 0.5 exports shard_map at top level
    _new_shard_map = jax.shard_map
    _old_shard_map = None
except AttributeError:  # pragma: no cover - version-dependent
    from jax.experimental.shard_map import shard_map as _old_shard_map

    _new_shard_map = None


def shard_map(f, mesh, in_specs, out_specs, axis_names=None, check_vma=None):
    """Version-portable ``shard_map`` (the repo's single entry point).

    Accepts the jax >= 0.5 surface (``axis_names`` = manual axes,
    ``check_vma``) and translates to the jax 0.4 experimental API
    (``auto`` = complementary axis set, ``check_rep``) when needed.
    """
    if _new_shard_map is not None:
        kw = {}
        if axis_names is not None:
            kw["axis_names"] = set(axis_names)
        if check_vma is not None:
            kw["check_vma"] = check_vma
        return _new_shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)
    kw = {}
    if axis_names is not None:
        kw["auto"] = frozenset(mesh.axis_names) - set(axis_names)
    if check_vma is not None:
        kw["check_rep"] = check_vma
    return _old_shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)

_REDUCERS = {
    "sum": jax.lax.psum,
    "max": jax.lax.pmax,
    "min": jax.lax.pmin,
}


@dataclasses.dataclass(frozen=True)
class MapReduceJob:
    """A Hadoop-style job description.

    map_fn:      per-shard function ``(*shard_args) -> pytree`` — the map task
                 with its combiner already folded in (emit *partial sums*, not
                 per-record pairs; Hadoop combiners do the same on each node).
    reduce_axes: mesh axes over which partials are reduced (the shuffle).
    reduce_op:   'sum' | 'max' | 'min'.
    """

    map_fn: Callable[..., Any]
    reduce_axes: tuple[str, ...]
    reduce_op: str = "sum"


def mapreduce(
    job: MapReduceJob,
    mesh: jax.sharding.Mesh,
    *,
    in_specs: Sequence[P],
    out_specs: Any = P(),
    jit: bool = True,
) -> Callable[..., Any]:
    """Compile a MapReduceJob onto a mesh.

    Returns ``fn(*global_args) -> reduced pytree``. ``out_specs`` must mark the
    result replicated over ``reduce_axes`` (default: fully replicated); result
    may remain sharded over other axes (e.g. the candidate axis over 'model').
    """
    if job.reduce_op not in _REDUCERS:
        raise ValueError(f"unknown reduce_op {job.reduce_op!r}")
    reducer = _REDUCERS[job.reduce_op]
    axes = tuple(job.reduce_axes)

    def _mapper(*args):
        partial = job.map_fn(*args)
        return jax.tree.map(lambda x: reducer(x, axes), partial)

    fn = shard_map(_mapper, mesh=mesh, in_specs=tuple(in_specs), out_specs=out_specs)
    return jax.jit(fn) if jit else fn


def hierarchical_psum(
    x: Any,
    inner_axes: tuple[str, ...],
    outer_axes: tuple[str, ...] = (),
    outer_transform: tuple[Callable, Callable] | None = None,
) -> Any:
    """Two-level reduction: psum within ``inner_axes`` (fast ICI), then over
    ``outer_axes`` (slow DCN), optionally transforming the payload for the
    outer hop (e.g. quantizing partial counts before the cross-pod hop).

    Must be called inside a shard_map body.
    """
    y = jax.tree.map(lambda v: jax.lax.psum(v, inner_axes), x) if inner_axes else x
    if not outer_axes:
        return y
    if outer_transform is None:
        return jax.tree.map(lambda v: jax.lax.psum(v, outer_axes), y)
    encode, decode = outer_transform
    enc = encode(y)
    red = jax.tree.map(lambda v: jax.lax.psum(v, outer_axes), enc)
    return decode(red)


def shard_rows(mesh: jax.sharding.Mesh, axes: tuple[str, ...]) -> jax.sharding.NamedSharding:
    """Sharding for a row-partitioned 2-D dataset (the HDFS block layout)."""
    return jax.sharding.NamedSharding(mesh, P(axes, None))


def pad_rows_to_shards(arr: jnp.ndarray, num_shards: int):
    """Pad axis 0 to a multiple of num_shards with zero rows.

    Zero transaction rows are inert for support counting in both device
    representations: dense — every real candidate has |c| >= 1 and
    <0-row, c> == 0 != |c|; packed uint32 — a zero row misses every set
    candidate bit, so ``t & c == c`` fails (DESIGN.md §3). The row partition
    is payload-agnostic: P(data_axes, None) over int8 items or uint32 words
    alike. Returns (padded, original_n).
    """
    import numpy as np

    n = arr.shape[0]
    rem = (-n) % num_shards
    if rem == 0:
        return arr, n
    pad = np.zeros((rem,) + arr.shape[1:], dtype=arr.dtype)
    return np.concatenate([np.asarray(arr), pad], axis=0), n

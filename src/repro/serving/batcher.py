"""Micro-batching request scheduler for the serving gateway (DESIGN.md §10).

Concurrently arriving single-basket queries land in ONE bounded queue; a
single worker thread pops the oldest request, then coalesces everything that
is already queued — waiting at most ``max_wait_ms`` for stragglers — into a
batch of at most ``max_batch``, and hands it (grouped by ``top_k``, arrival
order preserved) to the gateway's dispatch function, which pads to the
power-of-two jit bucket and demultiplexes per-request futures.

``max_wait_ms = 0`` is the pure **greedy** policy: a lone request dispatches
immediately (no artificial latency floor), while a busy device back-builds
batches naturally because the queue fills during the previous dispatch —
the batching/throughput trade Singh et al. measure at the *job scheduling*
layer of MapReduce-Apriori, transplanted to the query side.

Backpressure is explicit: a full queue raises :class:`AdmissionRejected` at
``submit`` (counted in metrics) — overload degrades by refusing admission,
never by silently dropping an accepted request. A dispatch that throws
resolves every future in the group with that exception for the same reason.
A request carrying a ``deadline`` that passes while it sits in the queue is
dropped at dispatch time with :class:`DeadlineExceeded` (DESIGN.md §12) —
the device never works for a caller that has already given up.

Supervision (DESIGN.md §11): the worker publishes its liveness
(``worker_alive``) and the batch it is holding (``_inflight``), and
``restart_worker()`` re-arms a dead worker — the futures of the stranded
in-flight batch are failed with :class:`WorkerCrashed` (a client sees an
error, never a hang), every still-QUEUED request survives untouched for the
fresh worker to drain, and the restart is counted in
``metrics.worker_restarts``. ``distributed.supervisor.WorkerSupervisor``
drives this loop.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
import time
from concurrent.futures import Future

import numpy as np

_SENTINEL = object()


class AdmissionRejected(RuntimeError):
    """The gateway refused the request at admission (bounded-queue overload
    or shutdown). ``reason`` says which."""

    def __init__(self, reason: str):
        super().__init__(f"request rejected: {reason}")
        self.reason = reason


class WorkerCrashed(RuntimeError):
    """The dispatch worker died while this request was in flight; the
    request was NOT served (retrying it is safe — matching is read-only)."""


class DeadlineExceeded(RuntimeError):
    """The request's deadline passed before it was served. Expired requests
    are dropped at DISPATCH time — a request whose caller has given up never
    spends device time — and their futures fail with this, never hang."""


@dataclasses.dataclass
class Request:
    """One admitted basket query travelling through the batcher."""

    packed: np.ndarray        # (W,) uint32 basket bitset row
    top_k: int
    future: Future            # resolves to a gateway Response
    t_submit: float           # perf_counter at admission (latency accounting)
    deadline: float | None = None   # absolute perf_counter time; expired
                                    # requests are dropped at dispatch
    span: object | None = None      # sampled obs.trace.Span carrying trace
                                    # context through the batcher (§13);
                                    # None for the unsampled fast path


class MicroBatcher:
    """Bounded-queue scheduler: one worker thread, coalesced dispatches."""

    def __init__(
        self,
        dispatch_fn,
        *,
        max_batch: int = 64,
        max_wait_ms: float = 1.0,
        queue_depth: int = 1024,
        metrics=None,
        wait_controller=None,
    ):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        self._dispatch_fn = dispatch_fn
        self._max_batch = int(max_batch)
        self._max_wait_s = max(0.0, float(max_wait_ms)) / 1e3
        # optional serving.controller.AdaptiveMaxWait: consulted once per
        # batch for the straggler wait. Only dispatch TIMING changes — which
        # requests coalesce — so response bit-identity is untouched (§14)
        self._wait_controller = wait_controller
        self._metrics = metrics
        self._q: queue.Queue = queue.Queue(maxsize=int(queue_depth))
        self._closed = False
        # serializes (closed check + enqueue) against (close + sentinel):
        # an admitted request is always queued AHEAD of the sentinel, so the
        # worker is guaranteed to reach it — admitted ⇒ resolved
        self._admit_lock = threading.Lock()
        # _inflight has its OWN lock: the worker must never need the admit
        # lock (close() holds it across a blocking put while the worker drains)
        self._inflight_lock = threading.Lock()
        self._inflight: list = []
        self._crash_hook = None   # test/fault-injection seam, called in-worker
        self._worker = threading.Thread(target=self._run, name="gateway-batcher", daemon=True)
        self._worker.start()

    @property
    def depth(self) -> int:
        """Requests currently queued (admission-pressure signal)."""
        return self._q.qsize()

    @property
    def capacity(self) -> int:
        """Admission-queue bound — the denominator brownout shedding uses."""
        return self._q.maxsize

    @property
    def current_max_wait_ms(self) -> float:
        """The effective straggler wait: live controller value when adaptive,
        else the fixed configuration."""
        if self._wait_controller is not None:
            return self._wait_controller.current_wait_ms
        return self._max_wait_s * 1e3

    @property
    def closed(self) -> bool:
        return self._closed

    @property
    def worker_alive(self) -> bool:
        """Liveness of the dispatch worker — the supervisor's poll target."""
        return self._worker.is_alive()

    def submit(self, request: Request) -> None:
        """Admit one request or raise :class:`AdmissionRejected`."""
        with self._admit_lock:
            if self._closed:
                self._reject("gateway closed")
            try:
                self._q.put_nowait(request)
            except queue.Full:
                self._reject("admission queue full")
        if self._metrics is not None:
            self._metrics.record_admission(True)

    def _reject(self, reason: str):
        if self._metrics is not None:
            self._metrics.record_admission(False)
        raise AdmissionRejected(reason)

    def close(self, timeout: float | None = 30.0) -> None:
        """Stop admitting, flush every already-admitted request, join.

        The admit lock makes close/submit race-free: the sentinel is
        enqueued strictly after every admitted request, so the worker flushes
        all of them before exiting — no admitted future is ever left hanging.
        Closing with a DEAD (unsupervised) worker fails the stranded futures
        explicitly instead of waiting on a join that can never finish.
        """
        with self._admit_lock:
            if self._closed:
                return
            self._closed = True
            if not self._worker.is_alive():
                self._fail_stranded("gateway closed with a dead worker")
                return
            # blocking put is safe: the worker keeps draining ahead of it,
            # and submitters blocked on the lock will see _closed afterwards
            self._q.put(_SENTINEL)
        self._worker.join(timeout=timeout)

    # -------------------------------------------------------- supervision --
    def restart_worker(self) -> bool:
        """Re-arm a dead dispatch worker (the supervisor's repair action).

        The stranded in-flight batch's futures are failed with
        :class:`WorkerCrashed` — ONLY those; every still-queued request is
        untouched and drains through the fresh worker. Returns True when a
        restart happened (counted in ``metrics.worker_restarts``), False if
        the batcher is closed or the worker turned out to be alive.
        """
        with self._admit_lock:
            if self._closed or self._worker.is_alive():
                return False
            self._fail_stranded("dispatch worker crashed mid-batch")
            self._worker = threading.Thread(
                target=self._run, name="gateway-batcher", daemon=True
            )
            self._worker.start()
        if self._metrics is not None:
            self._metrics.record_worker_restart()
        return True

    def _fail_stranded(self, reason: str) -> None:
        with self._inflight_lock:
            stranded, self._inflight = self._inflight, []
        if self._closed:   # a closed batcher also strands whatever is queued
            while True:
                try:
                    item = self._q.get_nowait()
                except queue.Empty:
                    break
                if item is not _SENTINEL:
                    stranded.append(item)
        for r in stranded:
            if not r.future.done():
                # span first, future second: whoever awaits the future ends
                # the PARENT span on wake, so the child must close before
                if r.span is not None:
                    r.span.end(outcome="worker_crashed")
                r.future.set_exception(WorkerCrashed(reason))
                if self._metrics is not None:
                    self._metrics.record_response(0.0, failed=True)

    # ------------------------------------------------------------- worker --
    def _run(self) -> None:
        stop = False
        while not stop:
            item = self._q.get()
            if item is _SENTINEL:
                break
            batch = [item]
            wait_s = (self._wait_controller.current_wait_s()
                      if self._wait_controller is not None else self._max_wait_s)
            deadline = time.perf_counter() + wait_s
            while len(batch) < self._max_batch:
                remaining = deadline - time.perf_counter()
                try:
                    # past the deadline we still drain whatever is already
                    # queued (free batching), we just stop *waiting*
                    nxt = self._q.get_nowait() if remaining <= 0 else self._q.get(timeout=remaining)
                except queue.Empty:
                    break
                if nxt is _SENTINEL:
                    stop = True
                    break
                batch.append(nxt)
            self._dispatch_tracked(batch)
        # defensive flush: the admit lock orders every admitted request
        # ahead of the sentinel, so this drain should always be empty
        tail = []
        while True:
            try:
                item = self._q.get_nowait()
            except queue.Empty:
                break
            if item is not _SENTINEL:
                tail.append(item)
        for start in range(0, len(tail), self._max_batch):
            self._dispatch_tracked(tail[start : start + self._max_batch])

    def _drop_expired(self, batch: list) -> list:
        """Fail past-deadline requests with :class:`DeadlineExceeded` at
        dispatch time — the queue bounds a caller's WAIT via
        ``future.result(timeout)``, but only this bounds the REQUEST: an
        abandoned query must not spend device time."""
        now = time.perf_counter()
        live = []
        for r in batch:
            if r.deadline is not None and now >= r.deadline:
                if not r.future.done():
                    if r.span is not None:   # close before waking the waiter
                        r.span.end(outcome="deadline_expired")
                    r.future.set_exception(DeadlineExceeded(
                        f"deadline passed {(now - r.deadline) * 1e3:.1f} ms "
                        f"before dispatch (queued {(now - r.t_submit) * 1e3:.1f} ms)"
                    ))
                    if self._metrics is not None:
                        self._metrics.record_deadline_expired()
                        self._metrics.record_response(0.0, failed=True)
            else:
                live.append(r)
        return live

    def _dispatch_tracked(self, batch: list) -> None:
        """Dispatch with the batch registered as in-flight: if the worker
        dies anywhere in here, ``restart_worker`` knows exactly which
        futures were stranded. The crash hook is the fault-injection seam —
        it runs WITH the batch in flight, so an injected death exercises the
        real stranding path."""
        batch = self._drop_expired(batch)
        if not batch:
            return
        with self._inflight_lock:
            self._inflight = list(batch)
        # deliberately NOT try/finally: on a crash the batch must STAY
        # registered as in-flight so restart_worker can fail its futures
        if self._crash_hook is not None:
            self._crash_hook(batch)
        self._dispatch_batch(batch)
        with self._inflight_lock:
            self._inflight = []

    def _dispatch_batch(self, batch: list) -> None:
        """Group by top_k (jit-static in the top-k step) and dispatch; a
        throwing dispatch fails its group's futures, never drops them."""
        groups: dict[int, list] = {}
        for r in batch:
            groups.setdefault(r.top_k, []).append(r)
        for group in groups.values():
            try:
                self._dispatch_fn(group)
            except BaseException as e:  # noqa: BLE001 — must reach the futures
                for r in group:
                    if r.span is not None:   # close before waking the waiter
                        r.span.end(outcome="dispatch_error")
                    if not r.future.done():
                        r.future.set_exception(e)
                        if self._metrics is not None:
                            self._metrics.record_response(0.0, failed=True)

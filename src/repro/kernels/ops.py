"""Jit'd public wrappers around the Pallas kernels.

Handles shape padding to block multiples, impl dispatch ('auto' resolves to
the Pallas kernel on TPU and the jnp oracle on CPU — interpret-mode Pallas is
kept for tests, where it validates the kernel body semantics), and padding
semantics (padded transactions are zero rows; padded candidates get |c| = -1
so they can never match).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.kernels.support_count import support_count_pallas


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def resolve_impl(impl: str) -> str:
    if impl != "auto":
        return impl
    return "pallas" if jax.default_backend() == "tpu" else "jnp"


def support_count(
    t_dense,
    c_dense,
    lengths,
    *,
    impl: str = "auto",
    block_n: int = 256,
    block_k: int = 256,
    block_i: int = 512,
    operand_dtype: str = "bf16",
):
    """Support counts of K candidates over N transactions (exact int32).

    Accepts arbitrary (N, I, K); pads to kernel block multiples internally.
    impl: auto | jnp | pallas | pallas_interpret | packed
    """
    impl = resolve_impl(impl)
    n, i = t_dense.shape
    k = c_dense.shape[0]
    if impl == "jnp":
        return ref.support_count_ref(t_dense, c_dense, lengths)
    if impl == "jnp_blocked":
        from repro.kernels.blocked import support_count_blocked

        return support_count_blocked(t_dense, c_dense, lengths)
    if impl == "packed":
        raise ValueError("packed impl requires pre-packed uint32 operands; use ref.support_count_packed_ref")
    if impl not in ("pallas", "pallas_interpret"):
        raise ValueError(f"unknown impl {impl!r}")

    # Shrink blocks for small problems (keep the 128-lane minor alignment).
    block_n = min(block_n, _round_up(n, 8))
    block_k = min(block_k, _round_up(k, 128))
    block_i = min(block_i, _round_up(i, 128))
    np_, kp, ip = _round_up(n, block_n), _round_up(k, block_k), _round_up(i, block_i)
    t_p = jnp.pad(t_dense, ((0, np_ - n), (0, ip - i)))
    c_p = jnp.pad(c_dense, ((0, kp - k), (0, ip - i)))
    len_p = jnp.pad(lengths.astype(jnp.int32), (0, kp - k), constant_values=-1)
    counts = support_count_pallas(
        t_p,
        c_p,
        len_p,
        block_n=block_n,
        block_k=block_k,
        block_i=block_i,
        operand_dtype=operand_dtype,
        interpret=(impl == "pallas_interpret"),
    )
    return counts[:k]


def flash_attention(q, k, v, *, causal: bool = True, impl: str = "auto", block_q: int = 512, block_k: int = 512):
    """Dispatch for attention: Pallas flash kernel on TPU, chunked jnp otherwise."""
    impl = resolve_impl(impl)
    if impl == "jnp":
        from repro.models.attention import chunked_attention

        return chunked_attention(q, k, v, causal=causal)
    from repro.kernels.flash_attention import flash_attention_pallas

    return flash_attention_pallas(
        q, k, v, causal=causal, block_q=block_q, block_k=block_k, interpret=(impl == "pallas_interpret")
    )

"""LM-corpus adapter: token windows as transactions.

Ties the paper's mining stack to the LM training pipeline: frequent token-set
mining over a corpus ("structured data analysis" in the paper's framing —
co-occurring token sets are the corpus' association structure). Items are the
top-`num_items` most frequent token ids; each window of `window` tokens is one
transaction (the set of items present in it).
"""

from __future__ import annotations

import numpy as np


def transactions_from_tokens(tokens: np.ndarray, *, window: int = 64, num_items: int = 512):
    """tokens: 1-D int array -> (dense (N, num_items) int8, item_vocab (num_items,)).

    item_vocab[j] is the original token id of item j.
    """
    tokens = np.asarray(tokens).ravel()
    uniq, counts = np.unique(tokens, return_counts=True)
    order = np.argsort(-counts, kind="stable")
    vocab = uniq[order][:num_items]
    remap = {int(t): j for j, t in enumerate(vocab)}

    n_windows = len(tokens) // window
    dense = np.zeros((n_windows, num_items), dtype=np.int8)
    for w in range(n_windows):
        seg = tokens[w * window : (w + 1) * window]
        for t in seg:
            j = remap.get(int(t))
            if j is not None:
                dense[w, j] = 1
    return dense, vocab

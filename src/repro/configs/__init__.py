"""Architecture registry: one module per assigned architecture."""

from importlib import import_module

from repro.configs.shapes import SHAPES, shape_names_for, is_skipped

ARCH_IDS = [
    "zamba2_2p7b",
    "minicpm3_4b",
    "qwen1p5_110b",
    "deepseek_coder_33b",
    "qwen1p5_4b",
    "musicgen_medium",
    "dbrx_132b",
    "granite_moe_3b_a800m",
    "internvl2_2b",
    "rwkv6_1p6b",
    "apriori",          # the paper's own workload config
]


def get_config(arch_id: str):
    arch_id = arch_id.replace("-", "_").replace(".", "p")
    if arch_id not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    return import_module(f"repro.configs.{arch_id}").CONFIG

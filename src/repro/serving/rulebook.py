"""The compiled rulebook: device-servable association rules (DESIGN.md §8).

``compile_rulebook`` lowers a mined :class:`~repro.core.apriori.AprioriResult`
into four column arrays — the exact operand format of the rule-match kernel
(``kernels/rule_match.py``):

    ante_packed (R, W) uint32   antecedent bitsets (support_count_packed
    cons_packed (R, W) uint32   consequent bitsets    word layout, §4)
    ante_len    (R,)   int32    antecedent popcounts; -1 = padding row
    scores      (R,)   float32  serving weight (confidence | lift); 0 on padding

Rules are sorted by descending score with a deterministic bitset tie-break,
optionally truncated to ``max_rules``, and padded to ``pad_multiple`` rows
with the standard inert padding (zero words, ``len = -1``, score 0) so the
artifact device-places and shards evenly without re-padding at query time.

``save``/``load`` round-trip the artifact as a single ``.npz``;
``place_rulebook`` device-places the columns sharded over a mesh axis
(`place_db`-style: rules are the rulebook's row axis the way transactions
are the store's), which pairs with the psum-over-rule-shards match step in
``serving/recommend.py``.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import rules as rules_mod

SCORE_KINDS = ("confidence", "lift")


@dataclasses.dataclass
class Rulebook:
    ante_packed: np.ndarray   # (R, W) uint32
    cons_packed: np.ndarray   # (R, W) uint32
    ante_len: np.ndarray      # (R,)   int32, -1 = padding
    scores: np.ndarray        # (R,)   float32, 0 on padding
    num_items: int
    score_kind: str = "confidence"
    min_confidence: float = 0.0

    @property
    def num_rules(self) -> int:
        """Real (non-padding) rules."""
        return int((np.asarray(self.ante_len) >= 0).sum())

    @property
    def num_rows(self) -> int:
        """Padded row count actually resident on device."""
        return self.ante_packed.shape[0]

    def save(self, path: str) -> None:
        np.savez(
            path,
            ante_packed=np.asarray(self.ante_packed),
            cons_packed=np.asarray(self.cons_packed),
            ante_len=np.asarray(self.ante_len),
            scores=np.asarray(self.scores),
            num_items=np.int64(self.num_items),
            score_kind=np.bytes_(self.score_kind.encode()),
            min_confidence=np.float64(self.min_confidence),
        )

    @classmethod
    def load(cls, path: str) -> "Rulebook":
        with np.load(path) as z:
            return cls(
                ante_packed=z["ante_packed"],
                cons_packed=z["cons_packed"],
                ante_len=z["ante_len"],
                scores=z["scores"],
                num_items=int(z["num_items"]),
                score_kind=bytes(z["score_kind"]).decode(),
                min_confidence=float(z["min_confidence"]),
            )


def compile_rulebook(
    result,
    *,
    min_confidence: float = 0.5,
    score: str = "confidence",
    max_rules: int | None = None,
    num_items: int | None = None,
    pad_multiple: int = 256,
) -> Rulebook:
    """Vectorized extraction (``core.rules.extract_rule_arrays``) -> sorted,
    truncated, padded serving columns."""
    if score not in SCORE_KINDS:
        raise ValueError(f"score must be one of {SCORE_KINDS}, got {score!r}")
    arr = rules_mod.extract_rule_arrays(result, min_confidence, num_items)
    scores = np.asarray(arr.confidence if score == "confidence" else arr.lift, np.float32)

    # descending score, bitset tie-break (np.lexsort: last key is primary)
    keys = (
        [arr.cons_packed[:, w] for w in range(arr.cons_packed.shape[1] - 1, -1, -1)]
        + [arr.ante_packed[:, w] for w in range(arr.ante_packed.shape[1] - 1, -1, -1)]
        + [-scores.astype(np.float64)]
    )
    order = np.lexsort(keys)
    if max_rules is not None:
        order = order[:max_rules]

    r = order.size
    rp = max(pad_multiple, ((r + pad_multiple - 1) // pad_multiple) * pad_multiple)
    w = arr.ante_packed.shape[1]
    ante = np.zeros((rp, w), np.uint32)
    cons = np.zeros((rp, w), np.uint32)
    lens = np.full(rp, -1, np.int32)
    sc = np.zeros(rp, np.float32)
    ante[:r] = arr.ante_packed[order]
    cons[:r] = arr.cons_packed[order]
    lens[:r] = arr.ante_len[order]
    sc[:r] = scores[order]
    return Rulebook(ante, cons, lens, sc, arr.num_items, score, min_confidence)


def place_rulebook(rb: Rulebook, mesh, rule_axis: str = "model") -> Rulebook:
    """Device-place the rulebook columns sharded over ``rule_axis`` — the
    serving twin of ``core.apriori.place_db``.  Rows are padded (inertly) to
    the shard count first so ``P(rule_axis)`` always splits evenly.  With
    ``mesh is None`` the columns are simply committed to the default device.
    """
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P

    if mesh is None:
        return Rulebook(
            jnp.asarray(rb.ante_packed), jnp.asarray(rb.cons_packed),
            jnp.asarray(rb.ante_len), jnp.asarray(rb.scores),
            rb.num_items, rb.score_kind, rb.min_confidence,
        )
    shards = mesh.shape[rule_axis]
    pad = (-rb.num_rows) % shards
    ante = np.pad(np.asarray(rb.ante_packed), ((0, pad), (0, 0)))
    cons = np.pad(np.asarray(rb.cons_packed), ((0, pad), (0, 0)))
    lens = np.pad(np.asarray(rb.ante_len), (0, pad), constant_values=-1)
    sc = np.pad(np.asarray(rb.scores), (0, pad))
    row2d, row1d = NamedSharding(mesh, P(rule_axis, None)), NamedSharding(mesh, P(rule_axis))
    return Rulebook(
        jax.device_put(ante, row2d), jax.device_put(cons, row2d),
        jax.device_put(lens, row1d), jax.device_put(sc, row1d),
        rb.num_items, rb.score_kind, rb.min_confidence,
    )

"""Fault tolerance for the mining/serving stack (DESIGN.md §11).

Three layers, mirroring the paper's Hadoop reliance on task re-execution:

  * :mod:`repro.distributed.checkpoint` — resumable streamed mining:
    ``MiningCheckpoint`` persists the level loop's complete state (levels,
    pass cursor, count accumulator, chunk cursor) next to the store
    manifest; ``mine_streamed(resume=True)`` is dict-identical to an
    uninterrupted mine.
  * :mod:`repro.distributed.fault_tolerance` — retryable SON partitions:
    ``run_partitions`` executes phase-1 mappers through a bounded-retry,
    speculatively re-issuing work queue with explicit failure reporting.
  * :mod:`repro.distributed.supervisor` — supervised serving:
    ``WorkerSupervisor`` restarts a dead gateway dispatch worker (failing
    only the in-flight batch's futures) behind a restart-storm guard;
    ``ReplicaSetSupervisor`` runs the same loop over a router's N gateway
    replicas, declaring a storming replica dead.
"""

from repro.distributed.checkpoint import (
    CheckpointMismatch,
    MiningCheckpoint,
    MiningState,
    mining_fingerprint,
    store_fingerprint,
)
from repro.distributed.fault_tolerance import (
    FaultConfig,
    FaultReport,
    InjectedFailure,
    PartitionFailure,
    retry_delay,
    run_partitions,
)
from repro.distributed.supervisor import (
    ReplicaSetSupervisor,
    RestartGuard,
    WorkerSupervisor,
)

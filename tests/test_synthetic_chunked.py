"""gen_transactions_chunked must yield EXACTLY the rows of gen_transactions
under the same seed — the parity that makes chunked ingest of huge synthetic
DBs (data.store.ingest_quest) equivalent to the dense path."""

import numpy as np
import pytest

from repro.data.synthetic import QuestConfig, gen_transactions, gen_transactions_chunked


@pytest.mark.parametrize("chunk_rows", [1, 7, 64, 250, 1000])
def test_chunked_parity_with_dense(chunk_rows):
    cfg = QuestConfig(num_transactions=250, num_items=64, avg_len=8, seed=21)
    dense = gen_transactions(cfg)
    chunks = list(gen_transactions_chunked(cfg, chunk_rows))
    assert all(c.shape[0] <= chunk_rows for c in chunks)
    assert sum(c.shape[0] for c in chunks) == 250
    np.testing.assert_array_equal(np.concatenate(chunks), dense)


def test_chunked_parity_across_seeds_and_shapes():
    for seed, n, i in [(0, 100, 32), (5, 333, 100), (9, 64, 512)]:
        cfg = QuestConfig(num_transactions=n, num_items=i, seed=seed)
        np.testing.assert_array_equal(
            np.concatenate(list(gen_transactions_chunked(cfg, 37))),
            gen_transactions(cfg),
        )


def test_chunk_boundaries_do_not_leak_state():
    """Chunk size must not perturb the rng stream: two different chunkings
    agree with each other (not just with the monolithic path)."""
    cfg = QuestConfig(num_transactions=150, num_items=48, seed=4)
    a = np.concatenate(list(gen_transactions_chunked(cfg, 11)))
    b = np.concatenate(list(gen_transactions_chunked(cfg, 149)))
    np.testing.assert_array_equal(a, b)


def test_chunked_rejects_bad_chunk_rows():
    with pytest.raises(ValueError):
        list(gen_transactions_chunked(QuestConfig(num_transactions=10), 0))


def test_empty_db():
    cfg = QuestConfig(num_transactions=0, num_items=16)
    assert gen_transactions(cfg).shape == (0, 16)
    assert list(gen_transactions_chunked(cfg, 8)) == []

"""Three-term roofline from the compiled dry-run artifact.

Hardware constants (TPU v5e target):
  peak compute  197 TFLOP/s bf16 per chip
  HBM bandwidth 819 GB/s per chip
  ICI           ~50 GB/s per link

  compute term    = FLOPs_per_device            / peak_FLOPs
  memory term     = HBM_bytes_per_device        / HBM_bw
  collective term = collective_bytes_per_device / link_bw

FLOPs / HBM bytes / collective bytes come from launch.hlo_analysis (the
while-trip-count-corrected static walk of the compiled module — XLA's raw
``cost_analysis()`` counts a while body once and so underestimates any
scanned count step by ~trip-count×; see the hlo_analysis module docstring).

The miner's useful-FLOPs estimate (2·n·items·K/256 packed word ops) lives in
``launch.mine_dryrun`` and in ``launch.mine --metrics-out``'s static_cost
block (DESIGN.md §13); the ratio useful / HLO_FLOPs catches padding and
dispatch overhead.
"""

from __future__ import annotations

import dataclasses
import math

PEAK_FLOPS = 197e12       # bf16 / chip
HBM_BW = 819e9            # B/s / chip
ICI_BW = 50e9             # B/s / link


@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def compute_fraction(self) -> float:
        """How close the step is to pure-compute roofline: compute / bound."""
        return self.compute_s / max(self.bound_s, 1e-30)


def roofline_terms(flops_per_dev: float, hbm_bytes_per_dev: float, coll_bytes_per_dev: float) -> Roofline:
    return Roofline(
        compute_s=flops_per_dev / PEAK_FLOPS,
        memory_s=hbm_bytes_per_dev / HBM_BW,
        collective_s=coll_bytes_per_dev / ICI_BW,
    )

"""Mining checkpoints: resumable streamed mining as manifest + npz snapshots.

The paper's fault-tolerance story is Hadoop's: a map task that dies is
re-executed from its replicated input split, so a long mine over voluminous
data survives node loss without starting over. This module is that story for
the single-host streaming driver (DESIGN.md §11): ``mine_streamed``
periodically persists its COMPLETE driver state —

  * the frozen frequent-itemset dict (every completed level),
  * the level currently being counted and the candidate-pass cursor,
  * the device count accumulator of the in-progress pass (host snapshot),
  * the chunk cursor into the on-disk store,

— and a resumed mine is dict-identical to an uninterrupted one, because the
store's step-indexed chunk iteration is deterministic and support counting is
integer arithmetic (folding the remaining chunks into the saved accumulator
equals folding all chunks into zeros, bit for bit).

Layout (next to the store manifest by default, see
``TransactionStore.checkpoint_path``)::

    <dir>/ckpt_<SEQ>/{manifest.json, arrays.npz, COMMITTED}

The ``COMMITTED`` marker is written last, so a crash mid-write (including
``kill -9``) leaves an uncommitted directory that :meth:`load_latest`
ignores — restore is crash-consistent. Writes are double-buffered onto a
background thread (:meth:`save` snapshots host arrays synchronously, then
serializes off the driver's critical path); retention keeps the newest
``keep`` committed snapshots.

The manifest additionally records a **store fingerprint** (n, num_items,
shard layout) and the **mining fingerprint** (the result-affecting config
fields plus ``chunk_rows``): resuming against a different store, config or
chunking is an explicit :class:`CheckpointMismatch`, never a silent wrong
answer.
"""

from __future__ import annotations

import dataclasses
import json
import os
import shutil
import threading

import numpy as np

CKPT_VERSION = 1
CKPT_PREFIX = "ckpt_"
COMMITTED = "COMMITTED"

#: AprioriConfig fields that change the mined RESULT or the meaning of the
#: saved cursor state — these must match between the checkpointing mine and
#: the resuming mine. ``max_candidates_per_pass`` and ``candidate_pad`` are
#: cursor-affecting (pass boundaries / accumulator padding), not
#: result-affecting; representation/count_impl are deliberately absent:
#: counting is exact in both representations (DESIGN.md §3/§4).
_CONFIG_FIELDS = (
    "min_support",
    "max_k",
    "use_naive_paper_map",
    "max_candidates_per_pass",
    "candidate_pad",
)


class CheckpointMismatch(ValueError):
    """A checkpoint was written by a different (store, config, chunking)
    than the one trying to resume from it."""


@dataclasses.dataclass
class MiningState:
    """One resumable snapshot of the streamed level loop.

    ``levels`` holds every COMPLETED level (k -> (itemsets, supports)).
    ``next_k`` is the level being (or about to be) counted. A mid-level
    snapshot additionally carries the candidate-pass cursor: ``counts`` are
    the finalized supports of the level's already-finished passes,
    ``pass_start`` the candidate index of the in-progress pass, ``acc`` that
    pass's count accumulator, and ``chunks_done`` how many store chunks have
    been folded into it. ``mid_level`` is False at a clean level boundary
    (the cursor fields are then ignored).
    """

    levels: dict
    next_k: int
    mid_level: bool = False
    pass_start: int = 0
    chunks_done: int = 0
    counts: np.ndarray | None = None    # (k_total,) int64, finished passes
    acc: np.ndarray | None = None       # (kp,) int32, in-progress pass


def store_fingerprint(store, num_shards: int | None = None) -> dict:
    """Identity of the data a checkpoint is valid for.

    By default the fingerprint covers EVERY shard, so appending rows to the
    store invalidates a full-mine checkpoint (its counts covered fewer rows
    than the store now holds — resuming would be silently wrong). The
    incremental path (DESIGN.md §15) passes ``num_shards`` to fingerprint
    only the shard PREFIX its counts actually cover: the same grown store
    then validates against a pre-append fingerprint, because the delta miner
    counts the appended shards separately.
    """
    m = store.manifest
    rows = m.shard_rows if num_shards is None else m.shard_rows[:num_shards]
    return {"n": int(sum(rows)), "num_items": m.num_items, "words": m.words,
            "shard_rows": list(rows)}


def mining_fingerprint(cfg, chunk_rows: int) -> dict:
    """Identity of the mine a checkpoint's cursor state is valid for.
    ``chunk_rows`` is part of it: the chunk cursor counts chunks of exactly
    this size, so a different chunking would misplace the resume point."""
    out = {f: getattr(cfg, f) for f in _CONFIG_FIELDS}
    out["chunk_rows"] = int(chunk_rows)
    return out


class MiningCheckpoint:
    """Manifest+npz checkpoint writer/reader for the streamed mining driver."""

    def __init__(self, path: str, keep: int = 2):
        if keep < 1:
            raise ValueError("keep must be >= 1")
        self.path = path
        self.keep = keep
        self._thread: threading.Thread | None = None
        self._seq = self._max_seq(committed_only=False)

    # -------------------------------------------------------------- write --
    def save(self, state: MiningState, store_fp: dict, mine_fp: dict) -> int:
        """Queue one snapshot for writing; returns its sequence number.

        Host-side array snapshots are taken synchronously (the caller may
        mutate its buffers right after); serialization + fsync-order commit
        happen on a background thread, double-buffered — at most one write
        in flight, :meth:`save` joins the previous one first.
        """
        self.wait()
        self._seq += 1
        seq = self._seq
        arrays = {}
        for k, (sets, sup) in state.levels.items():
            arrays[f"sets_{k}"] = np.array(sets, dtype=np.int32, copy=True)
            arrays[f"sup_{k}"] = np.array(sup, dtype=np.int64, copy=True)
        if state.mid_level:
            arrays["counts"] = np.array(state.counts, dtype=np.int64, copy=True)
            arrays["acc"] = np.array(state.acc, dtype=np.int32, copy=True)
        manifest = {
            "version": CKPT_VERSION,
            "seq": seq,
            "next_k": int(state.next_k),
            "mid_level": bool(state.mid_level),
            "pass_start": int(state.pass_start),
            "chunks_done": int(state.chunks_done),
            "levels": sorted(int(k) for k in state.levels),
            "store": store_fp,
            "mining": mine_fp,
        }

        def work():
            self._write(seq, arrays, manifest)
            self._gc()

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()
        return seq

    def wait(self) -> None:
        """Join the in-flight background write, if any."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, seq: int, arrays: dict, manifest: dict) -> None:
        out_dir = os.path.join(self.path, f"{CKPT_PREFIX}{seq:08d}")
        os.makedirs(out_dir, exist_ok=True)
        np.savez(os.path.join(out_dir, "arrays.npz"), **arrays)
        with open(os.path.join(out_dir, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        # the commit point: everything above is invisible until this exists
        with open(os.path.join(out_dir, COMMITTED), "w") as f:
            f.write("ok")

    def _gc(self) -> None:
        seqs = sorted(self._committed_seqs())
        for s in seqs[: -self.keep]:
            shutil.rmtree(
                os.path.join(self.path, f"{CKPT_PREFIX}{s:08d}"), ignore_errors=True
            )

    # --------------------------------------------------------------- read --
    def _committed_seqs(self):
        if not os.path.isdir(self.path):
            return []
        out = []
        for d in os.listdir(self.path):
            if d.startswith(CKPT_PREFIX) and os.path.exists(
                os.path.join(self.path, d, COMMITTED)
            ):
                out.append(int(d[len(CKPT_PREFIX):]))
        return out

    def _max_seq(self, committed_only: bool = True) -> int:
        if not os.path.isdir(self.path):
            return 0
        seqs = [
            int(d[len(CKPT_PREFIX):])
            for d in os.listdir(self.path)
            if d.startswith(CKPT_PREFIX)
            and (not committed_only or os.path.exists(os.path.join(self.path, d, COMMITTED)))
        ]
        return max(seqs) if seqs else 0

    def latest_seq(self) -> int | None:
        seqs = self._committed_seqs()
        return max(seqs) if seqs else None

    def load_latest(self) -> tuple[MiningState, dict] | None:
        """Newest COMMITTED snapshot as ``(state, manifest)``, or None."""
        seq = self.latest_seq()
        if seq is None:
            return None
        in_dir = os.path.join(self.path, f"{CKPT_PREFIX}{seq:08d}")
        with open(os.path.join(in_dir, "manifest.json")) as f:
            manifest = json.load(f)
        if manifest["version"] != CKPT_VERSION:
            raise CheckpointMismatch(
                f"checkpoint version {manifest['version']} != supported {CKPT_VERSION}"
            )
        data = np.load(os.path.join(in_dir, "arrays.npz"))
        levels = {
            int(k): (data[f"sets_{k}"], data[f"sup_{k}"]) for k in manifest["levels"]
        }
        state = MiningState(
            levels=levels,
            next_k=int(manifest["next_k"]),
            mid_level=bool(manifest["mid_level"]),
            pass_start=int(manifest["pass_start"]),
            chunks_done=int(manifest["chunks_done"]),
            counts=data["counts"] if manifest["mid_level"] else None,
            acc=data["acc"] if manifest["mid_level"] else None,
        )
        return state, manifest

    def validate(self, manifest: dict, store_fp: dict, mine_fp: dict) -> None:
        """Refuse to resume across a store/config/chunking change."""
        if manifest["store"] != store_fp:
            raise CheckpointMismatch(
                f"checkpoint was written for store {manifest['store']}, "
                f"resuming against {store_fp}"
            )
        if manifest["mining"] != mine_fp:
            raise CheckpointMismatch(
                f"checkpoint was written with mining fingerprint "
                f"{manifest['mining']}, resuming with {mine_fp}"
            )

    def clear(self) -> None:
        """Drop every snapshot (a completed mine has no use for them)."""
        self.wait()
        if os.path.isdir(self.path):
            for d in os.listdir(self.path):
                if d.startswith(CKPT_PREFIX):
                    shutil.rmtree(os.path.join(self.path, d), ignore_errors=True)

"""Int8 error-feedback gradient compression for the slow (cross-pod / DCN)
all-reduce hop.

Scheme (1-bit-Adam family, here 8-bit): carry a residual per leaf; quantize
(g + residual) to int8 against a *shared* scale (pmax of local absmax so every
participant uses the same grid); psum the int8 payload in int32; dequantize;
keep the quantization error as the next step's residual. 4x wire reduction on
the DCN hop vs fp32 (2x vs bf16), unbiased in the error-feedback limit.

Used inside shard_map bodies (see training.train_loop hierarchical path and
core.mapreduce.hierarchical_psum).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def int8_ef_state(params):
    """Zero residuals, one per parameter leaf (fp32)."""
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _encode_leaf(g, err, axes):
    y = g.astype(jnp.float32) + err
    local_max = jnp.max(jnp.abs(y))
    scale = jax.lax.pmax(local_max, axes) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(y / scale), -127, 127)
    new_err = y - q * scale
    return q.astype(jnp.int32), scale, new_err


def compressed_psum(grads, err, axes):
    """psum(grads) over `axes` with int8-EF payload. Must run inside shard_map
    manual over `axes`. Returns (summed fp32 grads, new residuals)."""
    flat, treedef = jax.tree.flatten(grads)
    errs = treedef.flatten_up_to(err)
    out, new_errs = [], []
    for g, e in zip(flat, errs):
        q, scale, ne = _encode_leaf(g, e, axes)
        q_sum = jax.lax.psum(q, axes)
        out.append(q_sum.astype(jnp.float32) * scale)
        new_errs.append(ne)
    return treedef.unflatten(out), treedef.unflatten(new_errs)


def wire_bytes(grads, compressed: bool) -> int:
    """Bytes crossing the slow link per all-reduce (for the roofline log)."""
    per = 1 if compressed else 4
    return sum(int(g.size) * per for g in jax.tree.leaves(grads))

"""SLO walkthrough: burn-rate alerting + the closed loop, step by step.

  PYTHONPATH=src python examples/serve_slo.py \
      [--transactions 4000] [--items 128] [--requests 1200] [--replicas 3]

The DESIGN.md §14 observability loop on top of the §12 replicated tier:

  1. declare    — ``serving_slos()`` builds the serving SLO set (p99
                  latency, availability ratio, replica health, disruption
                  ratio, generation lag) as declarative specs; each spec
                  carries multi-window multi-burn-rate rules (fast-burn
                  pages, slow-burn warns, SRE-workbook style);
  2. evaluate   — an ``SLOEvaluator`` thread diffs ``MetricsRegistry``
                  snapshots over each rule's windows and runs every spec
                  through an ok -> warn -> page state machine with
                  hysteresis, emitting typed ``AlertEvent``s (deduplicated:
                  transitions only) to subscribers and a JSONL stream;
  3. close loop — the Router subscribes: an availability alert engages
                  brownout admission (shed when aggregate queues exceed
                  the alert level's budget), a generation-lag alert forces
                  an immediate replica re-sync.  Separately the Gateway's
                  ``p99_target_ms`` arms an AIMD controller that adapts the
                  micro-batcher's max-wait toward the latency objective —
                  batch timing changes, responses stay bit-identical;
  4. disrupt    — mid-load, fault injection kills a replica worker: the
                  failover burst burns the disruption budget, the page
                  fires, supervised restart + failover keep availability
                  at 100%, and the alert clears once the burn window
                  drains — watch the ok -> page -> ok arc in the stream;
  5. render     — ``render_status`` prints the final panel: per-SLO state,
                  burn rates, alert history, replica health.

The same flow as a single command (plus a JSON summary for scripting):

  PYTHONPATH=src python -m repro.launch.serve --replicas 3 --slo \
      --kill-replica-mid-load --alerts-jsonl alerts.jsonl --requests 2000
"""

import argparse
import tempfile
import threading
import time
from concurrent.futures import ThreadPoolExecutor


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--transactions", type=int, default=4_000)
    ap.add_argument("--items", type=int, default=128)
    ap.add_argument("--avg-len", type=float, default=10.0)
    ap.add_argument("--min-support", type=float, default=0.02)
    ap.add_argument("--max-k", type=int, default=4)
    ap.add_argument("--min-confidence", type=float, default=0.4)
    ap.add_argument("--top-k", type=int, default=5)
    ap.add_argument("--requests", type=int, default=1_200)
    ap.add_argument("--concurrency", type=int, default=12)
    ap.add_argument("--replicas", type=int, default=3)
    ap.add_argument("--p99-ms", type=float, default=50.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.core.apriori import AprioriConfig
    from repro.core.streaming import mine_streamed
    from repro.data.store import ingest_quest
    from repro.data.synthetic import QuestConfig
    from repro.distributed import FaultConfig
    from repro.obs import BurnRule, SLOEvaluator, serving_slos
    from repro.launch.status import render_status
    from repro.serving import Router, compile_rulebook

    # ---- 1. ingest + mine (identical to the replicated example) ----
    qcfg = QuestConfig(num_transactions=args.transactions, num_items=args.items,
                       avg_len=args.avg_len, seed=args.seed)
    tmp = tempfile.TemporaryDirectory(prefix="slo_store_")
    store = ingest_quest(qcfg, tmp.name, shard_rows=2048, chunk_rows=2048)
    res = mine_streamed(
        store,
        AprioriConfig(min_support=args.min_support, max_k=args.max_k,
                      representation="packed"),
        chunk_rows=2048,
    )
    rb = compile_rulebook(res, min_confidence=args.min_confidence,
                          num_items=store.num_items)
    print(f"[slo] {res.total_frequent} itemsets -> {rb.num_rules} rules")

    chunk, real = next(store.iter_chunks(min(2048, store.num_transactions)))
    baskets = list(chunk[:real])
    responses, lock = [], threading.Lock()

    with Router(rb, args.replicas, top_k=args.top_k, max_batch=64,
                max_wait_ms=1.0, cache_capacity=2048,
                fault=FaultConfig(max_retries=3, backoff_s=0.01),
                attempt_timeout_s=1.0) as router:
        # ---- 2. declare SLOs, start the evaluator, 3. close the loop ----
        # demo-scaled windows (seconds, not the production hours) so the
        # whole ok -> page -> ok arc fits in one short run
        rules = (BurnRule("page", long_window_s=2.0, short_window_s=0.5,
                          burn_threshold=10.0),
                 BurnRule("warn", long_window_s=6.0, short_window_s=1.5,
                          burn_threshold=3.0))
        specs = serving_slos("router", p99_ms=args.p99_ms, replicated=True,
                             rules=rules)
        evaluator = SLOEvaluator(router.metrics.registry, specs,
                                 interval_s=0.05, clear_after_s=0.5)
        evaluator.subscribe(router.handle_alert)          # the closed loop
        evaluator.subscribe(
            lambda ev: print(f"[alert] {ev.severity:>4} <- {ev.previous:<4} "
                             f"{ev.slo}: {ev.message}"))
        evaluator.start()
        print(f"[slo] evaluating {len(specs)} SLOs: "
              f"{', '.join(s.name for s in specs)}")

        def client(indices):
            for i in indices:
                resp = router.submit(baskets[i % len(baskets)]).result(timeout=120)
                with lock:
                    responses.append(resp)

        # ---- 4. load with a mid-load replica kill ----
        half = args.requests // 2
        t0 = time.perf_counter()
        with ThreadPoolExecutor(max_workers=args.concurrency) as pool:
            for w in [pool.submit(client, range(o, half, args.concurrency))
                      for o in range(args.concurrency)]:
                w.result()
            router.fault_injection.kill_replica(0)
            print("[slo] killed replica 0's dispatch worker mid-load")
            for w in [pool.submit(client, range(half + o, args.requests,
                                                args.concurrency))
                      for o in range(args.concurrency)]:
                w.result()
        wall = time.perf_counter() - t0

        # idle until the burn windows drain and every SLO returns to ok
        deadline = time.perf_counter() + 15.0
        while time.perf_counter() < deadline:
            if all(st["state"] == "ok" for st in evaluator.status().values()):
                break
            time.sleep(0.05)
        evaluator.stop()

        # ---- 5. the final panel ----
        stats = router.stats()
        print(render_status(
            slo_status=evaluator.status(),
            alerts=[ev.to_json() for ev in evaluator.alert_history()],
            replicas=stats["replicas"], title="final SLO status"))

    fired = [ev for ev in evaluator.alert_history() if not ev.cleared]
    cleared = [ev for ev in evaluator.alert_history() if ev.cleared]
    assert len(responses) == args.requests, "a request was dropped"
    assert any(ev.signal == "availability" for ev in fired), \
        "the replica kill should have fired an availability alert"
    assert any(ev.signal == "availability" for ev in cleared), \
        "the availability alert should have cleared after recovery"
    print(f"[slo] {len(responses)} responses in {wall:.2f}s "
          f"({len(responses) / wall:,.0f} qps) | "
          f"{len(fired)} alerts fired, {len(cleared)} cleared, "
          f"final states all ok | availability="
          f"{stats['completed'] / max(1, stats['completed'] + stats['failed']):.4f}")
    tmp.cleanup()


if __name__ == "__main__":
    main()

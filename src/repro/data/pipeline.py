"""Sharded host→device data pipeline.

Deterministic epoch shuffling (seed fold-in), global-batch sharding over the
mesh data axes, and a one-step prefetch thread (double buffering) so host
batch assembly overlaps device compute — the data-pipeline substrate for the
streaming miner (DESIGN.md §9).
"""

from __future__ import annotations

import queue
import threading
import time

import jax
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P


class ShardedBatchIterator:
    """Wraps a host batch generator; device_puts each pytree leaf with the
    given sharding and prefetches `prefetch` batches on a worker thread.

    ``close()`` actually terminates the worker: the worker's queue puts are
    timeout-loops that re-check the stop event (a plain blocking ``put``
    would deadlock forever on a full queue once the consumer stops taking),
    and ``close()`` drains the queue so a mid-put worker unblocks, then
    joins the thread. Iteration after ``close()`` raises StopIteration.
    Context-managed; exhausting the iterator also joins the worker.
    """

    def __init__(self, gen, mesh, spec_fn, prefetch: int = 2):
        self._gen = gen
        self._mesh = mesh
        self._spec_fn = spec_fn  # leaf_path-free: array -> PartitionSpec
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._err: BaseException | None = None
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _place(self, batch):
        if self._mesh is None:
            return jax.tree.map(jax.numpy.asarray, batch)
        return jax.tree.map(
            lambda x: jax.device_put(x, NamedSharding(self._mesh, self._spec_fn(x))), batch
        )

    def _put(self, item) -> bool:
        """Timeout-put loop: returns False (item dropped) once stopped."""
        while not self._stop.is_set():
            try:
                self._q.put(item, timeout=0.05)
                return True
            except queue.Full:
                continue
        return False

    def _worker(self):
        try:
            for batch in self._gen:
                if self._stop.is_set():
                    return
                if not self._put(self._place(batch)):
                    return
        except BaseException as e:  # surface generator/placement failures to
            self._err = e           # the consumer — NOT a clean end-of-stream
        finally:
            # end-of-stream sentinel: wait politely while the consumer is
            # live; only force room (dropping a stale batch) once stopped
            while True:
                try:
                    self._q.put(None, timeout=0.05)
                    break
                except queue.Full:
                    if self._stop.is_set():
                        try:
                            self._q.get_nowait()
                        except queue.Empty:
                            pass

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is None:
            self._thread.join()
            if self._err is not None:
                raise self._err
            raise StopIteration
        return item

    def close(self, timeout: float = 10.0):
        """Stop the worker, drain buffered batches, and join the thread."""
        self._stop.set()
        deadline = time.monotonic() + timeout
        while self._thread.is_alive() and time.monotonic() < deadline:
            try:   # unblock a worker waiting in its timeout-put
                self._q.get_nowait()
            except queue.Empty:
                pass
            self._thread.join(timeout=0.05)
        while True:   # drop stale buffered batches
            try:
                self._q.get_nowait()
            except queue.Empty:
                break
        try:   # guarantee subsequent __next__ sees end-of-stream
            self._q.put_nowait(None)
        except queue.Full:
            pass

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        self.close()


def batch_spec(data_axes=("data",)):
    """PartitionSpec factory: shard axis 0 (global batch) over the data axes."""

    def fn(x):
        return P(data_axes, *([None] * (np.ndim(x) - 1)))

    return fn

from repro.data.synthetic import gen_transactions, QuestConfig
from repro.data.corpus import transactions_from_tokens
from repro.data.pipeline import ShardedBatchIterator, synthetic_token_batches

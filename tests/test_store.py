"""On-disk partitioned transaction store: ingest roundtrips, manifest
schema/versioning, chunk iteration and padding invariants (DESIGN.md §9)."""

import json
import os

import numpy as np
import pytest

from repro.core.itemsets import pack_bits, packed_words
from repro.data import store as st
from repro.data.synthetic import QuestConfig, gen_transactions


def _rand_dense(n, i, seed=0, density=0.25):
    rng = np.random.default_rng(seed)
    return (rng.random((n, i)) < density).astype(np.int8)


# -------------------------------------------------------------- roundtrip ----
@pytest.mark.parametrize("n,i,shard_rows", [(100, 37, 30), (64, 32, 64), (257, 65, 100), (10, 7, 1000)])
def test_ingest_dense_roundtrip(tmp_path, n, i, shard_rows):
    dense = _rand_dense(n, i, seed=n)
    s = st.ingest_dense(dense, str(tmp_path / "db"), shard_rows=shard_rows)
    assert s.num_transactions == n and s.num_items == i
    assert sum(s.manifest.shard_rows) == n
    # fixed-row shards: all but the last are exactly shard_rows
    assert all(r == shard_rows for r in s.manifest.shard_rows[:-1])
    assert np.array_equal(s.read_dense(), dense)


def test_ingest_lists_matches_dense(tmp_path):
    dense = _rand_dense(50, 40, seed=2)
    lists = [np.flatnonzero(r).tolist() for r in dense]
    s1 = st.ingest_lists(lists, 40, str(tmp_path / "a"), shard_rows=16, chunk_rows=7)
    s2 = st.ingest_dense(dense, str(tmp_path / "b"), shard_rows=16)
    assert np.array_equal(s1.read_dense(), s2.read_dense())


def test_ingest_chunks_accepts_dense_and_packed(tmp_path):
    dense = _rand_dense(45, 33, seed=3)
    chunks_dense = [dense[:20], dense[20:]]
    chunks_packed = [pack_bits(dense[:10]), pack_bits(dense[10:])]
    s1 = st.ingest_chunks(chunks_dense, 33, str(tmp_path / "a"), shard_rows=16)
    s2 = st.ingest_chunks(chunks_packed, 33, str(tmp_path / "b"), shard_rows=16)
    assert np.array_equal(s1.read_dense(), dense)
    assert np.array_equal(s2.read_dense(), dense)


def test_ingest_quest_matches_gen_transactions(tmp_path):
    qcfg = QuestConfig(num_transactions=300, num_items=48, avg_len=7, seed=11)
    s = st.ingest_quest(qcfg, str(tmp_path / "q"), shard_rows=77, chunk_rows=41)
    assert np.array_equal(s.read_dense(), gen_transactions(qcfg))


# --------------------------------------------------------------- manifest ----
def test_manifest_schema_and_mmap(tmp_path):
    dense = _rand_dense(80, 70, seed=4)
    s = st.ingest_dense(dense, str(tmp_path / "db"), shard_rows=32)
    with open(os.path.join(s.path, st.MANIFEST_NAME)) as f:
        d = json.load(f)
    assert d["version"] == st.LAYOUT_VERSION
    assert d["layout"] == st.LAYOUT_NAME
    assert d["n"] == 80 and d["num_items"] == 70
    assert d["words"] == packed_words(70)
    assert d["shard_rows"] == [32, 32, 16]
    # shards open memory-mapped, packed layout
    part = s.partition_packed(0)
    assert isinstance(part, np.memmap) and part.dtype == np.uint32
    assert np.array_equal(s.partition_dense(2), dense[64:])


def test_open_store_rejects_version_mismatch(tmp_path):
    s = st.ingest_dense(_rand_dense(10, 8), str(tmp_path / "db"), shard_rows=8)
    mpath = os.path.join(s.path, st.MANIFEST_NAME)
    with open(mpath) as f:
        d = json.load(f)
    d["version"] = st.LAYOUT_VERSION + 1
    with open(mpath, "w") as f:
        json.dump(d, f)
    with pytest.raises(ValueError, match="layout version"):
        st.open_store(s.path)


def test_open_store_requires_manifest(tmp_path):
    with pytest.raises(FileNotFoundError):
        st.open_store(str(tmp_path / "nowhere"))


def test_reingest_invalidates_old_manifest_and_shards(tmp_path):
    path = str(tmp_path / "db")
    st.ingest_dense(_rand_dense(50, 8, seed=1), path, shard_rows=8)  # 7 shards
    s = st.ingest_dense(_rand_dense(12, 8, seed=2), path, shard_rows=8)
    assert s.num_transactions == 12
    assert np.array_equal(st.open_store(path).read_dense(), _rand_dense(12, 8, seed=2))
    # no orphan shard files from the larger first ingest
    shards_on_disk = sorted(f for f in os.listdir(path) if f.startswith("shard_"))
    assert shards_on_disk == [st.shard_filename(0), st.shard_filename(1)]


def test_writer_rejects_shape_mismatch(tmp_path):
    w = st.StoreWriter(str(tmp_path / "db"), num_items=16, shard_rows=8)
    with pytest.raises(ValueError):
        w.append_dense(np.zeros((4, 17), np.int8))
    with pytest.raises(ValueError):
        w.append_packed(np.zeros((4, 3), np.uint32))  # words(16) == 1


# ----------------------------------------------------------------- chunks ----
@pytest.mark.parametrize("chunk_rows", [1, 13, 30, 100, 1000])
def test_iter_chunks_covers_all_rows_across_shards(tmp_path, chunk_rows):
    dense = _rand_dense(100, 37, seed=5)
    s = st.ingest_dense(dense, str(tmp_path / "db"), shard_rows=30)
    got = []
    for chunk, valid in s.iter_chunks(chunk_rows, representation="dense"):
        assert valid == chunk.shape[0] <= chunk_rows
        got.append(chunk)
    assert np.array_equal(np.concatenate(got), dense)


def test_iter_chunks_packed_matches_pack_bits(tmp_path):
    dense = _rand_dense(64, 48, seed=6)
    s = st.ingest_dense(dense, str(tmp_path / "db"), shard_rows=25)
    got = np.concatenate([c for c, _ in s.iter_chunks(17, representation="packed")])
    assert np.array_equal(got, pack_bits(dense))


def test_iter_chunks_pad_fixed_shape(tmp_path):
    """pad=True: every chunk has exactly chunk_rows rows, tail zero-filled
    (inert rows, DESIGN.md §3) — the fixed jit shape the streamer relies on."""
    dense = _rand_dense(50, 32, seed=7)
    s = st.ingest_dense(dense, str(tmp_path / "db"), shard_rows=20)
    chunks = list(s.iter_chunks(16, representation="packed", pad=True))
    assert [c.shape[0] for c, _ in chunks] == [16, 16, 16, 16]
    assert [v for _, v in chunks] == [16, 16, 16, 2]
    last, valid = chunks[-1]
    assert np.array_equal(last[valid:], np.zeros((14, last.shape[1]), np.uint32))
    assert np.array_equal(
        np.concatenate([c[:v] for c, v in chunks]), pack_bits(dense)
    )


def test_iter_chunks_rejects_bad_args(tmp_path):
    s = st.ingest_dense(_rand_dense(10, 8), str(tmp_path / "db"))
    with pytest.raises(ValueError):
        list(s.iter_chunks(0))
    with pytest.raises(ValueError):
        list(s.iter_chunks(4, representation="sparse"))


# ------------------------------------------------------- chunk cursor seek ----
@pytest.mark.parametrize("chunk_rows,shard_rows", [(13, 30), (30, 30), (7, 100), (64, 25)])
def test_iter_chunks_start_chunk_equals_skipping(tmp_path, chunk_rows, shard_rows):
    """The resume cursor: iter_chunks(start_chunk=k) yields EXACTLY the
    chunks a full iteration yields from index k on — same shapes, same
    valid counts, same bytes — for chunk sizes that cross shard boundaries
    both ways. This is what makes a checkpointed chunk index replayable."""
    dense = _rand_dense(100, 37, seed=8)
    s = st.ingest_dense(dense, str(tmp_path / "db"), shard_rows=shard_rows)
    full = list(s.iter_chunks(chunk_rows, representation="packed", pad=True))
    for k in range(len(full) + 1):
        tail = list(s.iter_chunks(chunk_rows, representation="packed",
                                  pad=True, start_chunk=k))
        assert len(tail) == len(full) - k
        for (want, wv), (got, gv) in zip(full[k:], tail):
            assert wv == gv
            assert np.array_equal(want, got)


def test_iter_chunks_start_chunk_past_end_is_empty(tmp_path):
    s = st.ingest_dense(_rand_dense(20, 8), str(tmp_path / "db"), shard_rows=8)
    assert list(s.iter_chunks(8, start_chunk=100)) == []


# ----------------------------------------------------------- checkpoint dir ----
def test_manifest_checkpoint_dir_and_backward_compat(tmp_path):
    """New stores record a checkpoint_dir; manifests written BEFORE the
    fault-tolerance layer (no key) still open, defaulting it."""
    s = st.ingest_dense(_rand_dense(10, 8), str(tmp_path / "db"), shard_rows=8)
    assert s.checkpoint_path == os.path.join(s.path, st.DEFAULT_CHECKPOINT_DIR)
    mpath = os.path.join(s.path, st.MANIFEST_NAME)
    with open(mpath) as f:
        d = json.load(f)
    assert d["checkpoint_dir"] == st.DEFAULT_CHECKPOINT_DIR
    del d["checkpoint_dir"]                 # a pre-§11 manifest
    with open(mpath, "w") as f:
        json.dump(d, f)
    old = st.open_store(s.path)
    assert old.checkpoint_path == os.path.join(s.path, st.DEFAULT_CHECKPOINT_DIR)


# ------------------------------------------------------------- append mode ----
def test_open_for_append_roundtrip(tmp_path):
    """Append equals ingesting the concatenation: same logical rows, and the
    base shard files are never rewritten."""
    base = _rand_dense(100, 24, seed=1)
    extra = _rand_dense(37, 24, seed=2)
    p = str(tmp_path / "db")
    s0 = st.ingest_dense(base, p, shard_rows=32)
    base_shards = s0.num_partitions
    mtimes = {i: os.path.getmtime(s0.shard_path(i)) for i in range(base_shards)}
    w = st.StoreWriter.open_for_append(p)
    w.append_dense(extra)
    s1 = w.close()
    assert s1.num_transactions == 137
    assert np.array_equal(s1.read_dense(), np.concatenate([base, extra]))
    # appended rows start a NEW shard: the base prefix is untouched
    assert s1.manifest.shard_rows[:base_shards] == s0.manifest.shard_rows
    assert {i: os.path.getmtime(s1.shard_path(i)) for i in range(base_shards)} == mtimes
    # manifest generation bumped, atomically (no temp file left behind)
    assert s1.manifest.seq == s0.manifest.seq + 1
    assert not os.path.exists(os.path.join(p, st.MANIFEST_NAME + ".tmp"))


def test_append_chunks_matches_writer(tmp_path):
    base = _rand_dense(64, 16, seed=3)
    extra = _rand_dense(50, 16, seed=4)
    pa, pb = str(tmp_path / "a"), str(tmp_path / "b")
    st.ingest_dense(base, pa, shard_rows=16)
    sa = st.append_chunks([extra[:20], pack_bits(extra[20:])], pa)
    sb = st.ingest_dense(np.concatenate([base, extra]), pb, shard_rows=16)
    assert np.array_equal(sa.read_dense(), sb.read_dense())


def test_torn_append_leaves_old_manifest_readable(tmp_path):
    """Kill between shard write and manifest write: the old store must stay
    fully readable, and the next append open sweeps the orphan shards."""
    base = _rand_dense(80, 16, seed=5)
    p = str(tmp_path / "db")
    s0 = st.ingest_dense(base, p, shard_rows=32)
    w = st.StoreWriter.open_for_append(p)
    w.append_dense(_rand_dense(64, 16, seed=6))
    w._flush()                       # orphan shard files hit the disk...
    orphan = os.path.join(p, st.shard_filename(s0.num_partitions))
    assert os.path.exists(orphan)
    del w                            # ...but close() never ran: torn append
    old = st.open_store(p)
    assert old.manifest.seq == s0.manifest.seq
    assert old.num_transactions == 80
    assert np.array_equal(old.read_dense(), base)
    # recovery: a fresh append open removes the orphans and appends cleanly
    w2 = st.StoreWriter.open_for_append(p)
    assert not os.path.exists(orphan)
    extra = _rand_dense(10, 16, seed=7)
    w2.append_dense(extra)
    s2 = w2.close()
    assert np.array_equal(s2.read_dense(), np.concatenate([base, extra]))


def test_open_for_append_rejects_shape_mismatch_and_missing(tmp_path):
    with pytest.raises(FileNotFoundError):
        st.StoreWriter.open_for_append(str(tmp_path / "nope"))
    p = str(tmp_path / "db")
    st.ingest_dense(_rand_dense(10, 16, seed=8), p, shard_rows=8)
    w = st.StoreWriter.open_for_append(p)
    with pytest.raises(ValueError):
        w.append_dense(_rand_dense(4, 17, seed=9))   # wrong num_items


def test_append_preserves_count_cache_section(tmp_path):
    p = str(tmp_path / "db")
    s0 = st.ingest_dense(_rand_dense(40, 16, seed=10), p, shard_rows=16)
    meta = {"version": 1, "seq": 1, "file": "count_cache_00000001.npz",
            "min_support": 0.1, "max_k": 3, "n": 40,
            "store": {"shard_rows": list(s0.manifest.shard_rows)}, "levels": []}
    np.savez(os.path.join(p, meta["file"]))
    s0.set_count_cache(meta)
    assert st.open_store(p).count_cache_meta == meta
    s1 = st.append_chunks([_rand_dense(8, 16, seed=11)], p)
    assert s1.count_cache_meta == meta   # appends keep the section verbatim
    # clearing drops the section AND the sidecar
    s1.set_count_cache(None)
    assert st.open_store(p).count_cache_meta is None
    assert not os.path.exists(os.path.join(p, meta["file"]))


def test_iter_chunks_shard_range(tmp_path):
    dense = _rand_dense(100, 16, seed=12)
    p = str(tmp_path / "db")
    s = st.ingest_dense(dense, p, shard_rows=17)
    rows = s.manifest.shard_rows
    for s0, s1 in [(0, 2), (2, 5), (0, s.num_partitions), (3, 3)]:
        got = [c for c, v in s.iter_chunks(7, representation="dense", shards=(s0, s1))]
        lo = sum(rows[:s0]); hi = lo + sum(rows[s0:s1])
        want = dense[lo:hi]
        assert np.array_equal(np.concatenate(got) if got else np.zeros((0, 16)), want)
    with pytest.raises(ValueError):
        list(s.iter_chunks(7, shards=(3, 2)))
    with pytest.raises(ValueError):
        list(s.iter_chunks(7, shards=(0, s.num_partitions + 1)))

"""Shared layers: norms, RoPE, FFNs, embeddings. Functional style — every
module is ``init(key, ...) -> params pytree`` + ``apply(params, x, ...)``;
stacked layers carry a leading L dim and are driven by lax.scan."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def dense_init(key, shape, in_axis: int = 0):
    """Truncated-normal fan-in init (fp32 master params)."""
    fan_in = shape[in_axis]
    scale = fan_in ** -0.5
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * scale)


def vzero(*arrays):
    """Scalar 0.0 carrying the varying-manual-axes of `arrays`.

    Under partial-manual shard_map (cross-pod compressed training), scan
    carries seeded with plain constants are pod-INVARIANT while the scanned
    inputs are pod-VARYING — jax rejects the carry-type mismatch. Seeding
    with `const + vzero(inputs)` gives the carry the right vma; outside
    shard_map it folds to 0."""
    z = jnp.zeros((), jnp.float32)
    for a in arrays:
        z = z + (a * 0).sum().astype(jnp.float32)
    return z


# ---------------------------------------------------------------- norms ----
def rmsnorm_init(d: int):
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm_apply(p, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * p["scale"]
    return y.astype(x.dtype)


def layernorm_init(d: int):
    return {"scale": jnp.ones((d,), jnp.float32), "bias": jnp.zeros((d,), jnp.float32)}


def layernorm_apply(p, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mean = xf.mean(-1, keepdims=True)
    var = ((xf - mean) ** 2).mean(-1, keepdims=True)
    y = (xf - mean) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    return y.astype(x.dtype)


def norm_init(kind: str, d: int):
    return rmsnorm_init(d) if kind == "rmsnorm" else layernorm_init(d)


def norm_apply(kind: str, p, x):
    return rmsnorm_apply(p, x) if kind == "rmsnorm" else layernorm_apply(p, x)


# ----------------------------------------------------------------- rope ----
def apply_rope(x, positions, theta: float = 10_000.0):
    """Rotary embedding, split-half convention.

    x: (..., S, H, D) with D even; positions: broadcastable to (..., S).
    """
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freq  # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------------ ffn ----
def ffn_init(key, d: int, d_ff: int, act: str):
    k1, k2 = jax.random.split(key)
    if act == "swiglu":
        return {"wi": dense_init(k1, (d, 2 * d_ff)), "wo": dense_init(k2, (d_ff, d))}
    return {"wi": dense_init(k1, (d, d_ff)), "wo": dense_init(k2, (d_ff, d))}


def ffn_apply(p, x, act: str):
    from repro.models.shard_ctx import weight_use

    dt = x.dtype
    h = x @ weight_use(p["wi"].astype(dt))
    if act == "swiglu":
        gate, up = jnp.split(h, 2, axis=-1)
        h = jax.nn.silu(gate) * up
    else:
        h = jax.nn.gelu(h)
    return h @ weight_use(p["wo"].astype(dt), out_side=True)


# ------------------------------------------------------------ embedding ----
def embed_init(key, vocab: int, d: int):
    return {"table": jax.random.normal(key, (vocab, d), jnp.float32)}


def embed_apply(p, tokens, dtype):
    return p["table"].astype(dtype)[tokens]


def unembed_apply(p, x):
    """Logits in fp32 (softmax numerics)."""
    return (x @ p["table"].astype(x.dtype).T).astype(jnp.float32)

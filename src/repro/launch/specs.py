"""ShapeDtypeStruct stand-ins + shardings for every (arch × shape × mesh)
cell — the dry-run's input layer. Nothing here allocates device memory.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.configs.shapes import SHAPES
from repro.distributed.sharding import ShardingRules, cache_pspecs, param_pspecs
from repro.models.transformer import init_decode_cache, init_model
from repro.training.optimizer import adamw_init


def rules_for(mesh) -> ShardingRules:
    dp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    return ShardingRules(fsdp_axis="data", tensor_axis="model", dp_axes=dp)


def arch_for_mesh(cfg, mesh):
    """Bind mesh-dependent knobs (MoE routing groups = # data shards)."""
    if cfg.moe is not None:
        dp = math.prod(mesh.shape[a] for a in (("pod", "data") if "pod" in mesh.axis_names else ("data",)))
        cfg = dataclasses.replace(cfg, moe_groups=dp)
    return cfg


def _sds(tree):
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def params_sds(cfg, dtype=None):
    out = jax.eval_shape(lambda: init_model(jax.random.key(0), cfg))
    if dtype is not None:
        out = jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, dtype), out)
    return out


def train_state_sds(cfg):
    p = params_sds(cfg)
    opt = jax.eval_shape(lambda: adamw_init(jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), p)))
    return {"params": p, "opt": opt}


def batch_sds(cfg, shape_name: str):
    sh = SHAPES[shape_name]
    b, s = sh["global_batch"], sh["seq_len"]
    kind = sh["kind"]
    if kind == "decode":
        if cfg.frontend == "frames":
            tok = jax.ShapeDtypeStruct((b, 1, cfg.d_model), jnp.bfloat16)
        else:
            tok = jax.ShapeDtypeStruct((b, 1), jnp.int32)
        pos = jax.ShapeDtypeStruct((b,), jnp.int32)
        return {"tokens": tok, "pos": pos}
    # train / prefill: full-sequence inputs
    batch = {}
    if cfg.frontend == "tokens":
        batch["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    elif cfg.frontend == "frames":
        batch["frames"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.bfloat16)
    elif cfg.frontend == "vlm":
        s_text = s - cfg.num_patches
        batch["patches"] = jax.ShapeDtypeStruct((b, cfg.num_patches, cfg.d_model), jnp.bfloat16)
        batch["tokens"] = jax.ShapeDtypeStruct((b, s_text), jnp.int32)
    if kind == "train":
        s_lab = (s - cfg.num_patches) if cfg.frontend == "vlm" else s
        batch["labels"] = jax.ShapeDtypeStruct((b, s_lab), jnp.int32)
    return batch


def cache_sds(cfg, shape_name: str):
    sh = SHAPES[shape_name]
    return jax.eval_shape(lambda: init_decode_cache(cfg, sh["global_batch"], sh["seq_len"]))


def batch_shardings(batch, mesh, rules: ShardingRules):
    n_dp = math.prod(mesh.shape[a] for a in rules.dp_axes)

    def spec(x):
        if x.shape and x.shape[0] % n_dp == 0:
            return P(rules.dp_axes, *([None] * (len(x.shape) - 1)))
        return P(*([None] * len(x.shape)))

    return jax.tree.map(lambda x: NamedSharding(mesh, spec(x)), batch)


def named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda s: isinstance(s, P))


def cell_shardings(cfg, shape_name: str, mesh):
    """-> dict with sds + shardings for the cell's step function."""
    rules = rules_for(mesh)
    sh = SHAPES[shape_name]
    kind = sh["kind"]
    out = {"kind": kind, "rules": rules}

    if kind == "train":
        state = train_state_sds(cfg)
        pspecs = param_pspecs(state["params"], mesh, rules)
        state_spec = {"params": pspecs, "opt": {"m": pspecs, "v": pspecs, "step": P()}}
        batch = batch_sds(cfg, shape_name)
        out.update(
            state_sds=state,
            state_sh=named(mesh, state_spec),
            batch_sds=batch,
            batch_sh=batch_shardings(batch, mesh, rules),
        )
        return out

    p_sds = params_sds(cfg)  # serving keeps fp32 master layout (cast in compute)
    pspecs = param_pspecs(p_sds, mesh, rules)
    out.update(params_sds=p_sds, params_sh=named(mesh, pspecs))
    if kind == "prefill":
        batch = batch_sds(cfg, shape_name)
        out.update(batch_sds=batch, batch_sh=batch_shardings(batch, mesh, rules))
        cache = cache_sds(cfg, shape_name)
        out.update(
            cache_sds=cache,
            cache_sh=named(mesh, cache_pspecs(cache, mesh, rules, batch=sh["global_batch"])),
        )
    else:  # decode
        cache = cache_sds(cfg, shape_name)
        batch = batch_sds(cfg, shape_name)
        out.update(
            cache_sds=cache,
            cache_sh=named(mesh, cache_pspecs(cache, mesh, rules, batch=sh["global_batch"])),
            batch_sds=batch,
            batch_sh=batch_shardings(batch, mesh, rules),
        )
    return out

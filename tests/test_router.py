"""Replicated serving tier tests (DESIGN.md §12).

The routed-request contract under chaos: every admitted request reaches
EXACTLY ONE terminal outcome — a Response bit-identical to ``recommend()``
against the generation that answered it, or a typed ``DeadlineExceeded`` /
``AdmissionRejected`` / ``WorkerCrashed`` — with zero hung futures and zero
mixed-generation batches, while replicas are being killed, delayed, and
hot-swapped underneath.
"""

import threading
import time

import numpy as np
import pytest

from repro.distributed import FaultConfig
from repro.serving import (
    AdmissionRejected,
    DeadlineExceeded,
    Router,
    WorkerCrashed,
    compile_rulebook,
    recommend,
)
from repro.serving.router import DEAD, HEALTHY, SUSPECT, HashRing

# killing dispatch workers IS the subject under test
pytestmark = pytest.mark.filterwarnings(
    "ignore::pytest.PytestUnhandledThreadExceptionWarning")

NUM_ITEMS = 32


@pytest.fixture(scope="module")
def rulebooks(small_db):
    from repro.core.apriori import AprioriConfig, mine

    rb0 = compile_rulebook(
        mine(small_db, AprioriConfig(min_support=0.05, max_k=3, count_impl="jnp")),
        min_confidence=0.3, num_items=NUM_ITEMS,
    )
    rb1 = compile_rulebook(
        mine(small_db, AprioriConfig(min_support=0.12, max_k=3, count_impl="jnp")),
        min_confidence=0.5, num_items=NUM_ITEMS,
    )
    assert rb0.num_rules > rb1.num_rules > 0
    return rb0, rb1


def fresh_baskets(n, seed):
    rng = np.random.default_rng(seed)
    return [
        sorted(rng.choice(NUM_ITEMS, size=int(rng.integers(1, 7)),
                          replace=False).tolist())
        for _ in range(n)
    ]


def check_response(resp, rb, basket, top_k):
    """Bit-identity vs the direct batch engine at the answering bucket."""
    direct = recommend(rb, [basket], top_k=top_k, batch_size=resp.bucket)
    assert np.array_equal(resp.items, direct.items[0])
    assert np.array_equal(resp.scores, direct.scores[0])


def _wait_until(pred, timeout=10.0):
    deadline = time.perf_counter() + timeout
    while time.perf_counter() < deadline:
        if pred():
            return True
        time.sleep(0.005)
    return pred()


# ------------------------------------------------------------------ ring --
def test_ring_deterministic_and_balanced():
    a, b = HashRing(4, vnodes=64), HashRing(4, vnodes=64)
    counts = [0] * 4
    for i in range(2000):
        key = f"basket-{i}".encode()
        pref = a.preference(key)
        assert pref == b.preference(key)          # stable across instances
        assert sorted(pref) == [0, 1, 2, 3]       # every replica, owner first
        counts[pref[0]] += 1
    assert min(counts) >= 0.05 * 2000             # no starved replica


def test_ring_stability_under_replica_loss():
    """Consistent hashing: removing the last replica only moves the keys it
    owned — everyone else's baskets (and caches) stay put."""
    big, small = HashRing(4, vnodes=64), HashRing(3, vnodes=64)
    moved = kept = 0
    for i in range(2000):
        key = f"basket-{i}".encode()
        if big.owner(key) == 3:
            moved += 1
        else:
            assert small.owner(key) == big.owner(key)
            kept += 1
    assert moved > 0 and kept > 0


def test_ring_failover_order_is_a_rotation_start():
    ring = HashRing(5, vnodes=32)
    pref = ring.preference(b"some basket")
    assert pref[0] == ring.owner(b"some basket")
    assert len(set(pref)) == 5


# -------------------------------------------------------------- baseline --
def test_single_replica_parity(rulebooks):
    rb0, _ = rulebooks
    with Router(rb0, 1, warmup=False, max_wait_ms=0.0) as r:
        for basket in fresh_baskets(24, seed=0):
            resp = r.query(basket, timeout=30)
            assert resp.generation == 0
            check_response(resp, rb0, basket, r.default_top_k)
        s = r.stats()
        assert s["routed"] == 24
        assert s["completed"] >= 24 - s["failed"]


def test_sticky_routing_keeps_caches_effective(rulebooks):
    """A repeat basket lands on the same replica and hits its exact-basket
    LRU — the consistent-hashing cache argument."""
    rb0, _ = rulebooks
    with Router(rb0, 3, warmup=False, max_wait_ms=0.0) as r:
        basket = [1, 4, 9]
        first = r.query(basket, timeout=30)
        assert not first.cached
        second = r.query(basket, timeout=30)
        assert second.cached
        check_response(second, rb0, basket, r.default_top_k)
        # exactly one replica saw the basket: one cache holds one entry
        sizes = [rep.gateway.cache.snapshot()["size"] for rep in r._replicas]
        assert sorted(sizes) == [0, 0, 1]


# -------------------------------------------------------------- failover --
def test_failover_on_worker_kill(rulebooks):
    """Kill every replica's worker mid-batch: the supervisor revives them,
    failed attempts re-route, and EVERY request still resolves correctly."""
    rb0, _ = rulebooks
    with Router(rb0, 2, warmup=False, max_wait_ms=0.0, cache_capacity=0,
                attempt_timeout_s=0.5,
                fault=FaultConfig(max_retries=3, backoff_s=0.01)) as r:
        r.query(fresh_baskets(1, seed=9)[0], timeout=30)   # compile off-path
        r.fault_injection.kill_replica(0)
        r.fault_injection.kill_replica(1)
        baskets = fresh_baskets(40, seed=1)
        futs = [r.submit(b) for b in baskets]
        for b, f in zip(baskets, futs):
            check_response(f.result(timeout=30), rb0, b, r.default_top_k)
        assert r.fault_injection.kills_fired == 2
        assert sum(r.supervisor.stats()["restarts"]) >= 2
        assert r.metrics.failovers >= 1


def test_storming_replica_declared_dead_traffic_continues(rulebooks):
    """A replica whose worker crashes on EVERY dispatch exhausts its restart
    budget, is declared dead (typed rejects already failed its in-flight
    work), and the surviving replica keeps answering everything."""
    rb0, _ = rulebooks
    with Router(rb0, 2, warmup=False, max_wait_ms=0.0, cache_capacity=0,
                attempt_timeout_s=0.5, max_restarts=3, restart_window_s=30.0,
                fault=FaultConfig(max_retries=4, backoff_s=0.01)) as r:
        r.query(fresh_baskets(1, seed=9)[0], timeout=30)
        # always-crash hook on replica 0 (overrides the injection hook)
        r._replicas[0].gateway._batcher._crash_hook = (
            lambda batch: (_ for _ in ()).throw(SystemExit("poisoned"))
        )
        # sustained traffic: each wave re-feeds the poisoned worker until
        # the restart budget is exhausted and the replica is declared dead
        outcomes = []
        wave = 0
        give_up_at = time.perf_counter() + 30.0
        while r._replicas[0].state != DEAD and time.perf_counter() < give_up_at:
            for b in fresh_baskets(8, seed=200 + wave):
                try:
                    outcomes.append((b, r.submit(b)))
                except AdmissionRejected as e:
                    outcomes.append((b, e))
            wave += 1
            time.sleep(0.05)
        terminal = []
        for b, item in outcomes:
            if isinstance(item, Exception):
                terminal.append((b, item))
                continue
            try:
                terminal.append((b, item.result(timeout=30)))
            except (WorkerCrashed, AdmissionRejected, DeadlineExceeded) as e:
                terminal.append((b, e))
        assert r._replicas[0].state == DEAD
        assert r.metrics.replica_deaths == 1
        assert r.supervisor.stats()["dead"] == [True, False]
        ok = [(b, x) for b, x in terminal if not isinstance(x, Exception)]
        assert len(ok) > 0
        for b, resp in ok:
            check_response(resp, rb0, b, r.default_top_k)
        # the survivor still answers everything after the death
        for b in fresh_baskets(10, seed=3):
            check_response(r.query(b, timeout=30), rb0, b, r.default_top_k)
        assert r.stats()["replicas"][0]["state"] == "dead"


# -------------------------------------------------------------- deadlines --
def test_router_deadline_on_unresponsive_replicas(rulebooks):
    """Both replicas delayed past the deadline: the router's watchdog fails
    the outer future with DeadlineExceeded — a slow replica cannot hold a
    client past its deadline."""
    rb0, _ = rulebooks
    with Router(rb0, 2, warmup=False, max_wait_ms=0.0, cache_capacity=0,
                attempt_timeout_s=0.25,
                fault=FaultConfig(max_retries=2, backoff_s=0.01)) as r:
        r.query(fresh_baskets(1, seed=9)[0], timeout=30)
        r.fault_injection.delay_replica(0, 0.6)
        r.fault_injection.delay_replica(1, 0.6)
        t0 = time.perf_counter()
        with pytest.raises(DeadlineExceeded):
            r.query(fresh_baskets(1, seed=4)[0], deadline_ms=100, timeout=30)
        assert time.perf_counter() - t0 < 5.0
        assert r.metrics.deadline_failed == 1
        r.fault_injection.delay_replica(0, 0.0)
        r.fault_injection.delay_replica(1, 0.0)


def test_generous_deadline_served_normally(rulebooks):
    rb0, _ = rulebooks
    with Router(rb0, 2, warmup=False, max_wait_ms=0.0) as r:
        basket = fresh_baskets(1, seed=5)[0]
        resp = r.query(basket, deadline_ms=30_000, timeout=30)
        check_response(resp, rb0, basket, r.default_top_k)
        assert r.metrics.deadline_failed == 0


# ---------------------------------------------------------- load shedding --
def test_load_shedding_typed_reject_when_saturated(rulebooks):
    """Every candidate's admission queue full ⇒ a typed AdmissionRejected
    at submit, counted as shed — overload is loud, never a silent drop."""
    rb0, _ = rulebooks
    with Router(rb0, 1, warmup=False, max_wait_ms=0.0, cache_capacity=0,
                max_batch=1, queue_depth=2, supervise=False,
                fault=FaultConfig(max_retries=0, backoff_s=0.01)) as r:
        r.query(fresh_baskets(1, seed=9)[0], timeout=30)
        r.fault_injection.delay_replica(0, 0.4)
        baskets = fresh_baskets(32, seed=6)
        futs, shed = [], 0
        for b in baskets:
            try:
                futs.append(f := r.submit(b))
            except AdmissionRejected:
                shed += 1
        assert shed > 0
        assert r.metrics.shed == shed
        r.fault_injection.delay_replica(0, 0.0)
        for f in futs:       # admitted ⇒ resolved, even through the delay
            try:
                f.result(timeout=30)
            except (WorkerCrashed, DeadlineExceeded, AdmissionRejected):
                pass


def test_closed_router_rejects(rulebooks):
    rb0, _ = rulebooks
    r = Router(rb0, 1, warmup=False, supervise=False)
    r.close()
    with pytest.raises(AdmissionRejected):
        r.submit([1, 2, 3])


# ------------------------------------------------------ two-phase hot-swap --
def test_coordinated_swap_flips_every_replica(rulebooks):
    rb0, rb1 = rulebooks
    with Router(rb0, 3, warmup=False, max_wait_ms=0.0) as r:
        gen = r.hot_swap(rb1)
        assert gen == 1
        assert [rep.gateway.generation for rep in r._replicas] == [1, 1, 1]
        assert r.metrics.coordinated_swaps == 1
        assert r.metrics.swap_prepare_failures == 0
        for basket in fresh_baskets(12, seed=7):
            resp = r.query(basket, timeout=30)
            assert resp.generation == 1
            check_response(resp, rb1, basket, r.default_top_k)
        assert r.metrics.max_generation_lag == 0


def test_failed_prepare_stale_generation_then_resync(rulebooks):
    """Replica 1 fails phase-1 prepare: the swap still commits on replica 0,
    replica 1 keeps answering the STALE generation (lag tracked), and once
    the failure clears the monitor re-syncs it to the coordinated id."""
    rb0, rb1 = rulebooks
    with Router(rb0, 2, warmup=False, max_wait_ms=0.0,
                monitor_interval_s=0.01) as r:
        r.fault_injection.fail_swap_on(1)
        gen = r.hot_swap(rb1)
        assert gen == 1
        assert r._replicas[0].gateway.generation == 1
        assert r._replicas[1].gateway.generation == 0     # stale, still serving
        assert r.metrics.swap_prepare_failures == 1
        assert r._replicas[1].state == SUSPECT
        assert _wait_until(lambda: r.metrics.max_generation_lag >= 1)

        # the stale replica still answers ITS generation bit-correctly
        for basket in fresh_baskets(16, seed=8):
            resp = r.query(basket, timeout=30)
            assert resp.generation in (0, 1)
            check_response(resp, (rb0, rb1)[resp.generation], basket,
                           r.default_top_k)

        r.fault_injection.clear_swap_failures()
        assert _wait_until(lambda: r._replicas[1].gateway.generation == 1)
        assert r.metrics.resyncs >= 1
        assert _wait_until(lambda: r._replicas[1].state == HEALTHY)
        assert _wait_until(
            lambda: r.stats()["current_generation_lag"] == 0)
        resp = r.query(fresh_baskets(1, seed=9)[0], timeout=30)
        assert resp.generation == 1


def test_swap_with_no_preparable_replica_raises(rulebooks):
    rb0, rb1 = rulebooks
    with Router(rb0, 2, warmup=False, supervise=False) as r:
        r.fault_injection.fail_swap_on(0)
        r.fault_injection.fail_swap_on(1)
        with pytest.raises(RuntimeError):
            r.hot_swap(rb1)
        assert r.generation == 0          # nothing committed anywhere
        assert [rep.gateway.generation for rep in r._replicas] == [0, 0]


# ------------------------------------------------------------------ chaos --
def test_chaos_exactly_one_terminal_outcome_per_request(rulebooks):
    """Random replica kills + delays + concurrent coordinated hot-swaps +
    bursty submits from 4 client threads. Every request must reach exactly
    one terminal outcome: a bit-correct Response for the generation that
    answered it, or a typed DeadlineExceeded / AdmissionRejected /
    WorkerCrashed. Zero hung futures, zero mixed-generation answers, and
    routed == completed + failed at the end."""
    rb0, rb1 = rulebooks
    gens = {0: rb0}
    r = Router(
        rb0, 3, warmup=False, max_wait_ms=0.0, max_batch=16,
        cache_capacity=128, attempt_timeout_s=0.4,
        fault=FaultConfig(max_retries=3, backoff_s=0.01),
        max_restarts=50, restart_window_s=60.0, monitor_interval_s=0.01,
    )
    r.query([1, 2, 3], timeout=30)        # first compile off the clock

    outcomes: list = []
    out_lock = threading.Lock()
    stop = threading.Event()

    def submitter(seed, n):
        rng = np.random.default_rng(seed)
        for _ in range(n):
            basket = sorted(rng.choice(
                NUM_ITEMS, size=int(rng.integers(1, 7)), replace=False
            ).tolist())
            deadline_ms = (None if rng.random() < 0.7
                           else float(rng.integers(40, 400)))
            try:
                item = r.submit(basket, deadline_ms=deadline_ms)
            except AdmissionRejected as e:
                item = e
            with out_lock:
                outcomes.append((basket, item))
            if rng.random() < 0.25:
                time.sleep(0.002)          # bursts with occasional gaps

    threads = [threading.Thread(target=submitter, args=(100 + i, 60))
               for i in range(4)]
    for t in threads:
        t.start()

    chaos_rng = np.random.default_rng(0xC1A05)
    next_rb = [rb1]
    while any(t.is_alive() for t in threads):
        roll = chaos_rng.random()
        if roll < 0.40:
            r.fault_injection.kill_replica(int(chaos_rng.integers(0, 3)))
        elif roll < 0.55:
            rid = int(chaos_rng.integers(0, 3))
            r.fault_injection.delay_replica(rid, 0.08)
            time.sleep(0.02)
            r.fault_injection.delay_replica(rid, 0.0)
        elif roll < 0.75:
            try:
                new_gen = r.hot_swap(next_rb[0])
                gens[new_gen] = next_rb[0]
                next_rb[0] = rb0 if next_rb[0] is rb1 else rb1
            except RuntimeError:
                pass                       # no preparable replica right now
        time.sleep(0.02)
    for t in threads:
        t.join()
    stop.set()

    # ---- every request: exactly one typed terminal outcome, no hangs -----
    terminal = []
    for basket, item in outcomes:
        if isinstance(item, Exception):
            terminal.append((basket, item))
            continue
        try:
            terminal.append((basket, item.result(timeout=30)))   # no hangs
        except (DeadlineExceeded, AdmissionRejected, WorkerCrashed) as e:
            terminal.append((basket, e))
    assert len(terminal) == 240

    ok = [(b, x) for b, x in terminal if not isinstance(x, Exception)]
    failed = [(b, x) for b, x in terminal if isinstance(x, Exception)]
    # chaos must not take the service down: the vast majority still answers
    assert len(ok) >= 120
    for basket, resp in ok:
        # zero mixed generations: the response names ONE swapped-in
        # generation and is bit-identical to recommend() against it
        assert resp.generation in gens
        check_response(resp, gens[resp.generation], basket, r.default_top_k)

    m = r.metrics
    assert _wait_until(lambda: m.routed == m.completed + m.failed)
    assert m.completed == len(ok) + 1     # +1: the pre-chaos warm-up query
    s = r.stats()
    assert s["routed"] == s["completed"] + s["failed"]
    r.close()
    # after close everything is drained; nothing new is admitted
    with pytest.raises(AdmissionRejected):
        r.submit([1, 2])


# ----------------------------------------------- alert reactions (§14) ----
def _alert(signal, severity):
    from repro.obs import AlertEvent

    return AlertEvent(slo=f"{signal}_spec", signal=signal, kind="error_ratio",
                      severity=severity, previous="ok", burn_rate=20.0,
                      window_s=2.0, value=0.5, objective=0.999,
                      t_wall=0.0, message="test")


def test_brownout_sheds_early_and_lifts_on_clear(rulebooks):
    """An availability alert tightens admission: at level 2 (page) the
    router sheds once aggregate queue fill crosses 25% of capacity, with a
    typed reject naming the brownout; the clear lifts it."""
    rb0, _ = rulebooks
    with Router(rb0, 1, warmup=False, max_wait_ms=0.0, cache_capacity=0,
                max_batch=1, queue_depth=4, supervise=False,
                fault=FaultConfig(max_retries=0, backoff_s=0.01)) as r:
        r.query(fresh_baskets(1, seed=20)[0], timeout=30)   # warm the path
        assert r.brownout_level == 0
        r.handle_alert(_alert("availability", "page"))
        assert r.brownout_level == 2

        r.fault_injection.delay_replica(0, 0.3)
        futs, brownout_sheds = [], 0
        for b in fresh_baskets(16, seed=21):
            try:
                futs.append(r.submit(b))
            except AdmissionRejected as e:
                assert "brownout" in str(e)
                brownout_sheds += 1
        # 25% of a 4-deep queue = 1 slot: the burst must shed early, long
        # before the queue itself would have rejected anything
        assert brownout_sheds > 0
        assert r.metrics.brownout_sheds == brownout_sheds
        assert r.metrics.shed >= brownout_sheds     # counted as shed too
        r.fault_injection.delay_replica(0, 0.0)
        for f in futs:
            f.result(timeout=30)

        r.handle_alert(_alert("availability", "ok"))
        assert r.brownout_level == 0
        assert r.query(fresh_baskets(1, seed=22)[0], timeout=30) is not None
        assert r.stats()["brownout_level"] == 0


def test_brownout_warn_level_is_looser_than_page(rulebooks):
    rb0, _ = rulebooks
    with Router(rb0, 1, warmup=False, supervise=False) as r:
        r.handle_alert(_alert("availability", "warn"))
        assert r.brownout_level == 1
        r.handle_alert(_alert("availability", "page"))
        assert r.brownout_level == 2


def test_generation_lag_alert_forces_immediate_resync(rulebooks):
    """With the background monitor effectively disabled, a lag alert is the
    ONLY thing that can re-sync a stale replica — handle_alert must do it."""
    rb0, rb1 = rulebooks
    with Router(rb0, 2, warmup=False, max_wait_ms=0.0,
                monitor_interval_s=3600.0, supervise=False) as r:
        r.fault_injection.fail_swap_on(1)
        assert r.hot_swap(rb1) == 1
        assert r._replicas[1].gateway.generation == 0       # stale
        r.fault_injection.clear_swap_failures()

        r.handle_alert(_alert("generation_lag", "page"))
        assert r._replicas[1].gateway.generation == 1       # caught up NOW
        assert r.metrics.alert_resyncs == 1
        assert r.stats()["current_generation_lag"] == 0


def test_unknown_alert_signal_is_ignored(rulebooks):
    rb0, _ = rulebooks
    with Router(rb0, 1, warmup=False, supervise=False) as r:
        r.handle_alert(_alert("vibes", "page"))
        assert r.brownout_level == 0
        assert r.metrics.alert_resyncs == 0


def test_healthy_ratio_gauge_dips_through_kill_then_recovers(rulebooks):
    """The gauge the replica_availability SLO watches: a replica kill must
    hold the ratio below 1.0 for at least the suspect window (so a sampling
    evaluator can SEE it), then return to 1.0 after supervised recovery."""
    rb0, _ = rulebooks
    with Router(rb0, 2, warmup=False, max_wait_ms=0.0, cache_capacity=0,
                monitor_interval_s=0.01,
                fault=FaultConfig(max_retries=3, backoff_s=0.01)) as r:
        assert r.metrics.healthy_replica_ratio == 1.0
        r.fault_injection.kill_replica(0)
        for b in fresh_baskets(8, seed=23):     # trigger the armed kill
            r.query(b, timeout=30)
        assert _wait_until(lambda: r.metrics.healthy_replica_ratio < 1.0, 5.0)
        assert r.fault_injection.kills_fired == 1
        assert _wait_until(lambda: r.metrics.healthy_replica_ratio == 1.0, 10.0)
        assert _wait_until(lambda: all(rep.state == HEALTHY
                                       for rep in r._replicas), 5.0)
        # the gauge rides the registry too (the SLO evaluator's input)
        assert r.metrics.registry.raw_snapshot()[
            "router_healthy_replica_ratio"] == 1.0


def test_router_generation_age_resets_on_coordinated_swap(rulebooks):
    rb0, rb1 = rulebooks
    with Router(rb0, 2, warmup=False, max_wait_ms=0.0, supervise=False) as r:
        time.sleep(0.05)
        pre_swap = r.metrics.generation_age.value
        assert pre_swap >= 0.05
        r.hot_swap(rb1)
        assert r.metrics.generation_age.value < pre_swap
        assert r.metrics.registry.raw_snapshot()[
            "router_generation_age_seconds"] < pre_swap

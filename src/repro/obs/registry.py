"""Unified metrics registry: labeled counters/gauges/histograms (§13).

One :class:`MetricsRegistry` owns every metric a component exposes.  All
metrics created through a registry share the registry's re-entrant lock, so
``registry.snapshot()`` is **atomic across the whole registry**: no torn
reads where a counter from before an event is paired with a histogram from
after it.  Metrics constructed standalone get a private lock and the same
per-metric atomicity.

Three metric kinds:

- :class:`Counter` — monotonically increasing integer (``inc``).
- :class:`Gauge` — last-write-wins float (``set`` / ``inc``).
- :class:`Histogram` — log-bucketed (geometric ``GROWTH``-spaced edges from
  1 µs) with exact count/sum/min/max.  Recording is O(1); quantiles resolve
  to a bucket's upper edge — a conservative ≤ ``GROWTH``-factor
  overestimate, never an underestimate, the right bias for SLO gates.
  Histograms **merge**: ``Histogram.merged([h, ...])`` is bucket-wise
  addition, exactly equivalent to recording the union of the samples, which
  lets a router aggregate replica latency without re-measuring.

Exposition: ``snapshot()`` gives the JSON shape the serve CLI and CI gates
already read; ``to_prometheus()`` renders the standard text format.  A
:class:`Sampler` thread appends periodic snapshots to a JSONL file so a run
leaves a queryable time series behind.
"""

from __future__ import annotations

import json
import math
import threading
import time
from typing import Dict, Iterable, Optional, Tuple

FLOOR_S = 1e-6    # first histogram bucket edge: 1 us
GROWTH = 1.25
NUM_BUCKETS = 96  # 1us * 1.25**95 ~= 1.6e3 s: covers any sane request
_LOG_GROWTH = math.log(GROWTH)


def _label_key(labels: Dict[str, str]) -> Tuple[Tuple[str, str], ...]:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _escape_label_value(v: str) -> str:
    """Prometheus text-format label escaping: backslash, double-quote and
    newline must be escaped inside the quoted label value."""
    return v.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def _prom_name(name: str, labels: Dict[str, str]) -> str:
    if not labels:
        return name
    inner = ",".join(f'{k}="{_escape_label_value(v)}"'
                     for k, v in sorted(labels.items()))
    return f"{name}{{{inner}}}"


class Counter:
    """Monotonic integer counter."""

    kind = "counter"

    def __init__(self, name: str = "", labels: Optional[Dict[str, str]] = None,
                 lock: Optional[threading.RLock] = None):
        self.name = name
        self.labels = dict(labels or {})
        self._lock = lock if lock is not None else threading.RLock()
        self._value = 0

    def inc(self, n: int = 1) -> None:
        if n < 0:
            raise ValueError("counters are monotonic: inc(n) requires n >= 0")
        with self._lock:
            self._value += n

    @property
    def value(self) -> int:
        with self._lock:
            return self._value


class Gauge:
    """Last-write-wins float gauge."""

    kind = "gauge"

    def __init__(self, name: str = "", labels: Optional[Dict[str, str]] = None,
                 lock: Optional[threading.RLock] = None):
        self.name = name
        self.labels = dict(labels or {})
        self._lock = lock if lock is not None else threading.RLock()
        self._value = 0.0

    def set(self, v: float) -> None:
        with self._lock:
            self._value = float(v)

    def inc(self, n: float = 1.0) -> None:
        with self._lock:
            self._value += n

    def max(self, v: float) -> None:
        """Raise the gauge to ``v`` if larger (peak tracking)."""
        with self._lock:
            if v > self._value:
                self._value = float(v)

    @property
    def value(self) -> float:
        with self._lock:
            return self._value


class Histogram:
    """Log-bucketed histogram with exact count/sum/min/max and merge."""

    kind = "histogram"

    def __init__(self, name: str = "", labels: Optional[Dict[str, str]] = None,
                 lock: Optional[threading.RLock] = None):
        self.name = name
        self.labels = dict(labels or {})
        self._lock = lock if lock is not None else threading.RLock()
        self._counts = [0] * NUM_BUCKETS
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = 0.0

    @staticmethod
    def _bucket(seconds: float) -> int:
        if seconds <= FLOOR_S:
            return 0
        return min(NUM_BUCKETS - 1, 1 + int(math.log(seconds / FLOOR_S) / _LOG_GROWTH))

    @staticmethod
    def _edge(bucket: int) -> float:
        """Upper edge of ``bucket`` in seconds: bucket b holds samples in
        ``[FLOOR·GROWTH^(b-1), FLOOR·GROWTH^b)`` (bucket 0: everything ≤ FLOOR)."""
        return FLOOR_S * GROWTH**bucket

    def record(self, seconds: float) -> None:
        seconds = max(0.0, float(seconds))
        with self._lock:
            self._counts[self._bucket(seconds)] += 1
            self.count += 1
            self.sum += seconds
            self.min = min(self.min, seconds)
            self.max = max(self.max, seconds)

    def merge_from(self, other: "Histogram") -> None:
        """Fold ``other`` into self — equivalent to having recorded the union
        of both sample sets (bucket-wise addition + exact-stat folding)."""
        o_counts, o_count, o_sum, o_min, o_max = other._state()
        with self._lock:
            for b in range(NUM_BUCKETS):
                self._counts[b] += o_counts[b]
            self.count += o_count
            self.sum += o_sum
            self.min = min(self.min, o_min)
            self.max = max(self.max, o_max)

    @classmethod
    def merged(cls, hists: Iterable["Histogram"]) -> "Histogram":
        out = cls()
        for h in hists:
            out.merge_from(h)
        return out

    def _state(self) -> tuple:
        """Atomic copy of the mutable state (counts, count, sum, min, max)."""
        with self._lock:
            return list(self._counts), self.count, self.sum, self.min, self.max

    @staticmethod
    def _quantile_from(counts, count, max_v, q: float) -> float:
        if count == 0:
            return 0.0
        target = max(1, math.ceil(q * count))
        cum = 0
        for b, c in enumerate(counts):
            cum += c
            if cum >= target:
                return min(Histogram._edge(b), max_v)
        return max_v

    def quantile(self, q: float) -> float:
        """Latency (seconds) at quantile ``q`` in (0, 1]: the upper edge of
        the bucket holding the ceil(q·count)-th sample; 0.0 when empty."""
        counts, count, _, _, max_v = self._state()
        return self._quantile_from(counts, count, max_v, q)

    def snapshot(self) -> dict:
        """Atomic snapshot: one state copy under the lock, quantiles computed
        from that copy — a concurrent writer can never tear count vs sum."""
        counts, count, sum_s, min_s, max_s = self._state()
        qf = lambda q: self._quantile_from(counts, count, max_s, q)
        return {
            "count": count,
            "mean_ms": (sum_s / count * 1e3) if count else 0.0,
            "min_ms": (min_s * 1e3) if count else 0.0,
            "max_ms": max_s * 1e3,
            "p50_ms": qf(0.50) * 1e3,
            "p95_ms": qf(0.95) * 1e3,
            "p99_ms": qf(0.99) * 1e3,
        }


class MetricsRegistry:
    """Central metric registry with atomic cross-metric snapshots.

    ``counter/gauge/histogram`` get-or-create by (name, labels); every metric
    shares the registry lock, so ``snapshot()`` (taken under that lock) is a
    single consistent cut across all of them.
    """

    def __init__(self):
        self.lock = threading.RLock()
        self._metrics: Dict[Tuple[str, str, tuple], object] = {}

    def _get(self, cls, name: str, labels: Optional[Dict[str, str]]):
        labels = dict(labels or {})
        key = (cls.kind, name, _label_key(labels))
        with self.lock:
            m = self._metrics.get(key)
            if m is None:
                m = cls(name, labels, lock=self.lock)
                self._metrics[key] = m
            return m

    def register(self, metric):
        """Adopt a pre-built metric (e.g. a Histogram subclass).  The metric
        must have been constructed with ``lock=registry.lock`` to keep
        registry-wide snapshots atomic."""
        key = (metric.kind, metric.name, _label_key(metric.labels))
        with self.lock:
            self._metrics[key] = metric
        return metric

    def counter(self, name: str, labels: Optional[Dict[str, str]] = None) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, labels: Optional[Dict[str, str]] = None) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str, labels: Optional[Dict[str, str]] = None) -> Histogram:
        return self._get(Histogram, name, labels)

    def metrics(self) -> list:
        with self.lock:
            return list(self._metrics.values())

    def snapshot(self) -> dict:
        """One atomic cut across every registered metric (JSON-able)."""
        with self.lock:
            out: Dict[str, object] = {}
            for m in self._metrics.values():
                key = _prom_name(m.name, m.labels)
                if isinstance(m, Histogram):
                    out[key] = m.snapshot()
                elif isinstance(m, Counter):
                    out[key] = m.value
                else:
                    out[key] = m.value
            return out

    def raw_snapshot(self) -> dict:
        """One atomic cut at full resolution — the SLO evaluator's input.

        Counters and gauges map to plain floats; histograms map to
        ``{"kind": "histogram", "counts": [...], "count": n, "sum": s}``
        with the per-bucket counts intact, so a consumer can difference two
        cuts and compute windowed error fractions ("requests over the
        latency objective between t0 and t1") that ``snapshot()``'s
        pre-reduced quantiles cannot express."""
        with self.lock:
            out: Dict[str, object] = {}
            for m in self._metrics.values():
                key = _prom_name(m.name, m.labels)
                if isinstance(m, Histogram):
                    counts, count, sum_s, _, _ = m._state()
                    out[key] = {"kind": "histogram", "counts": counts,
                                "count": count, "sum": sum_s}
                else:
                    out[key] = float(m.value)
            return out

    def to_prometheus(self) -> str:
        """Standard Prometheus text exposition (one atomic cut)."""
        with self.lock:
            lines = []
            seen_types = set()
            for m in self._metrics.values():
                if m.name not in seen_types:
                    lines.append(f"# TYPE {m.name} {m.kind}")
                    seen_types.add(m.name)
                full = _prom_name(m.name, m.labels)
                if isinstance(m, Histogram):
                    counts, count, sum_s, _, _ = m._state()
                    cum = 0
                    for b, c in enumerate(counts):
                        cum += c
                        if c == 0:
                            continue
                        lab = dict(m.labels)
                        lab["le"] = f"{Histogram._edge(b):.9g}"
                        lines.append(f"{_prom_name(m.name + '_bucket', lab)} {cum}")
                    inf_lab = dict(m.labels)
                    inf_lab["le"] = "+Inf"
                    lines.append(f"{_prom_name(m.name + '_bucket', inf_lab)} {count}")
                    lines.append(f"{_prom_name(m.name + '_sum', m.labels)} {sum_s:.9g}")
                    lines.append(f"{_prom_name(m.name + '_count', m.labels)} {count}")
                else:
                    lines.append(f"{full} {m.value:.9g}" if isinstance(m, Gauge)
                                 else f"{full} {m.value}")
            return "\n".join(lines) + "\n"


class Sampler:
    """Background thread appending periodic registry snapshots to a JSONL
    file — each line ``{"t": epoch_seconds, "metrics": {...}}``.  ``stop()``
    always writes one final sample so short runs still leave a series."""

    def __init__(self, registry: MetricsRegistry, path: str, interval_s: float = 1.0):
        self.registry = registry
        self.path = path
        self.interval_s = float(interval_s)
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._fh = None
        self.samples_written = 0

    def _write_sample(self) -> None:
        line = json.dumps({"t": time.time(), "metrics": self.registry.snapshot()})
        self._fh.write(line + "\n")
        self._fh.flush()
        self.samples_written += 1

    def _run(self) -> None:
        while not self._stop.wait(self.interval_s):
            self._write_sample()

    def start(self) -> "Sampler":
        self._fh = open(self.path, "a")
        self._thread = threading.Thread(target=self._run, name="obs-sampler", daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
            self._thread = None
        if self._fh is not None:
            self._write_sample()
            self._fh.close()
            self._fh = None

    def __enter__(self) -> "Sampler":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

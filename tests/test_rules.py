"""Rule extraction: vectorized-vs-naive parity, hand-checked statistics,
missing-support (lift=NaN regression) handling, deterministic ordering, and
the frequency property of every extracted rule."""

import numpy as np
import pytest

from repro.core.apriori import AprioriConfig, AprioriResult, mine
from repro.core.itemsets import dense_from_lists
from repro.core.rules import (
    extract_rule_arrays,
    extract_rules,
    extract_rules_vectorized,
)
from repro.data.synthetic import QuestConfig, gen_transactions


def _rule_dict(rules):
    return {(r.antecedent, r.consequent): (r.support, r.confidence, r.lift) for r in rules}


# ------------------------------------------------------------- parity --------
@pytest.mark.parametrize("seed,min_conf", [(0, 0.0), (0, 0.5), (1, 0.6), (2, 0.0), (3, 0.45)])
def test_vectorized_matches_naive_random_corpora(seed, min_conf):
    db = gen_transactions(
        QuestConfig(num_transactions=250, num_items=28, avg_len=6, seed=seed)
    )
    res = mine(db, AprioriConfig(min_support=0.05, max_k=4, count_impl="jnp"))
    # bit-identical: same splits selected, same f64 statistics, same order
    assert extract_rules(res, min_conf) == extract_rules_vectorized(res, min_conf)


def test_vectorized_max_rules_prefix_matches_naive(small_db):
    res = mine(small_db, AprioriConfig(min_support=0.08, max_k=4, count_impl="jnp"))
    full = extract_rules_vectorized(res, 0.5)
    assert extract_rules_vectorized(res, 0.5, max_rules=7) == full[:7]
    assert extract_rules(res, 0.5, max_rules=7) == full[:7]


# ------------------------------------------------------ hand-checked ---------
def test_hand_checked_confidence_and_lift():
    """8 transactions over 3 items with hand-countable supports:
      s({0}) = 4, s({1}) = 6, s({2}) = 3, s({0,1}) = 3, s({1,2}) = 2."""
    baskets = [[0, 1], [0, 1], [0, 1], [0], [1, 2], [1, 2], [1], [2]]
    db = dense_from_lists(baskets, 3)
    res = mine(db, AprioriConfig(min_support=0.2, max_k=2, count_impl="jnp"))
    assert res.support((0, 1)) == 3 and res.support((1, 2)) == 2

    for extract in (extract_rules, extract_rules_vectorized):
        by_key = _rule_dict(extract(res, min_confidence=0.0))
        assert by_key[((0,), (1,))] == (3 / 8, 3 / 4, 1.0)   # exact in both paths
        assert by_key[((1,), (0,))] == pytest.approx((3 / 8, 1 / 2, 1.0), rel=1e-6)
        assert by_key[((2,), (1,))] == pytest.approx((2 / 8, 2 / 3, 8 / 9), rel=1e-6)
        assert by_key[((1,), (2,))] == pytest.approx((2 / 8, 1 / 3, 8 / 9), rel=1e-6)


# --------------------------------------- missing supports (NaN regression) ---
def _truncated_result():
    """A partial AprioriResult: {0,1} frequent but s({1}) absent (e.g. a
    filtered resume checkpoint) — lift of {0}->{1} is undefined."""
    levels = {
        1: (np.array([[0]], np.int32), np.array([7], np.int64)),
        2: (np.array([[0, 1]], np.int32), np.array([5], np.int64)),
    }
    return AprioriResult(levels=levels, num_transactions=10, min_count=2)


def test_missing_consequent_support_is_skipped_not_nan():
    res = _truncated_result()
    for extract in (extract_rules, extract_rules_vectorized):
        rules = extract(res, min_confidence=0.0)
        # {0}->{1}: consequent support missing; {1}->{0}: antecedent missing
        assert rules == []
        assert not any(np.isnan(r.lift) for r in rules)


def test_sort_is_deterministic_with_itemset_tiebreak():
    """Two rules with identical (confidence, support) order by itemset."""
    baskets = [[0, 1], [0, 1], [2, 3], [2, 3], [4]]
    db = dense_from_lists(baskets, 5)
    res = mine(db, AprioriConfig(min_support=0.2, max_k=2, count_impl="jnp"))
    for extract in (extract_rules, extract_rules_vectorized):
        rules = extract(res, min_confidence=0.0)
        keys = [(-r.confidence, -r.support, r.antecedent, r.consequent) for r in rules]
        assert keys == sorted(keys)
        pairs = [(r.antecedent, r.consequent) for r in rules]
        assert pairs.index(((0,), (1,))) < pairs.index(((2,), (3,)))


# ----------------------------------------------------- frequency property ----
def test_every_rule_union_is_frequent(small_db):
    """Property: A ∪ C of every extracted rule is itself a mined frequent
    itemset with support >= min_count (both extraction paths)."""
    res = mine(small_db, AprioriConfig(min_support=0.08, max_k=4, count_impl="jnp"))
    for extract in (extract_rules, extract_rules_vectorized):
        rules = extract(res, min_confidence=0.3)
        assert rules
        for r in rules:
            union = tuple(sorted(r.antecedent + r.consequent))
            assert res.support(union) >= res.min_count
            assert not set(r.antecedent) & set(r.consequent)


def test_rule_arrays_packed_layout(small_db):
    """RuleArrays bitsets round-trip through the packed word layout."""
    from repro.core.itemsets import unpack_bits

    res = mine(small_db, AprioriConfig(min_support=0.08, max_k=4, count_impl="jnp"))
    arr = extract_rule_arrays(res, 0.5)
    assert arr.ante_packed.dtype == np.uint32
    assert arr.ante_packed.shape == arr.cons_packed.shape
    assert arr.num_rules == arr.ante_len.shape[0]
    ante_dense = unpack_bits(arr.ante_packed, arr.num_items)
    np.testing.assert_array_equal(ante_dense.sum(1).astype(np.int32), arr.ante_len)
    # antecedent and consequent are disjoint bitsets
    assert not np.any(arr.ante_packed & arr.cons_packed)

"""Supervised serving (DESIGN.md §11): a dead gateway dispatch worker is
restarted, ONLY the in-flight batch's futures fail (with WorkerCrashed),
queued requests survive the restart, and the restart is counted."""

import time
from concurrent.futures import Future

import numpy as np
import pytest

from repro.serving import (
    GatewayMetrics,
    MicroBatcher,
    Request,
    WorkerCrashed,
)
from repro.distributed.supervisor import WorkerSupervisor

# killing the dispatch worker IS the subject under test
pytestmark = pytest.mark.filterwarnings(
    "ignore::pytest.PytestUnhandledThreadExceptionWarning")


def _req(top_k=5):
    return Request(packed=np.zeros(1, np.uint32), top_k=top_k, future=Future(),
                   t_submit=time.perf_counter())


def _wait_until(pred, timeout=10.0):
    deadline = time.perf_counter() + timeout
    while time.perf_counter() < deadline:
        if pred():
            return True
        time.sleep(0.005)
    return pred()


class _FakeGateway:
    """WorkerSupervisor only touches ``gateway._batcher``."""

    def __init__(self, batcher):
        self._batcher = batcher


def _echo_dispatch(group):
    for r in group:
        r.future.set_result(r.top_k)


# -------------------------------------------------------- restart_worker --
def test_restart_fails_only_inflight_futures():
    """Kill the worker while a batch is in flight: that batch's futures get
    WorkerCrashed, requests admitted AFTER the crash drain through the fresh
    worker, and the restart lands in the metric."""
    metrics = GatewayMetrics()
    batcher = MicroBatcher(_echo_dispatch, max_batch=4, max_wait_ms=0.0,
                           queue_depth=64, metrics=metrics)
    crash_once = {"armed": True}

    def hook(batch):
        if crash_once["armed"]:
            crash_once["armed"] = False
            batcher._crash_hook = None
            raise SystemExit("injected dispatch-worker death")

    batcher._crash_hook = hook
    doomed = _req(top_k=1)
    batcher.submit(doomed)
    assert _wait_until(lambda: not batcher.worker_alive)

    assert batcher.restart_worker() is True
    with pytest.raises(WorkerCrashed):
        doomed.future.result(timeout=10)
    assert batcher.worker_alive

    served = _req(top_k=7)                 # the fresh worker really dispatches
    batcher.submit(served)
    assert served.future.result(timeout=10) == 7
    batcher.close()
    assert metrics.worker_restarts == 1
    assert metrics.failed == 1             # exactly the in-flight request
    assert "worker_restarts" in metrics.snapshot()


def test_queued_requests_survive_restart():
    """Requests sitting in the admission queue at crash time are NOT failed:
    they are served by the restarted worker (admitted => resolved)."""
    gate = {"evt": None}

    def slow_dispatch(group):
        if gate["evt"] is not None:
            gate["evt"].wait(timeout=10)
        _echo_dispatch(group)

    import threading

    gate["evt"] = threading.Event()
    batcher = MicroBatcher(slow_dispatch, max_batch=1, max_wait_ms=0.0,
                           queue_depth=64, metrics=GatewayMetrics())
    armed = {"on": True}

    def hook(batch):
        if armed["on"]:
            armed["on"] = False
            raise SystemExit("boom")

    inflight = _req(top_k=1)
    queued = [_req(top_k=10 + i) for i in range(5)]
    batcher._crash_hook = hook
    batcher.submit(inflight)               # max_batch=1: alone in its batch
    for r in queued:
        batcher.submit(r)
    assert _wait_until(lambda: not batcher.worker_alive)
    gate["evt"].set()
    assert batcher.restart_worker() is True

    with pytest.raises(WorkerCrashed):
        inflight.future.result(timeout=10)
    for i, r in enumerate(queued):         # every queued request answered
        assert r.future.result(timeout=10) == 10 + i
    batcher.close()


def test_restart_noop_when_alive_or_closed():
    batcher = MicroBatcher(_echo_dispatch, max_batch=4, max_wait_ms=0.0)
    assert batcher.restart_worker() is False      # alive: nothing to do
    batcher.close()
    assert batcher.restart_worker() is False      # closed: shutdown != crash


def test_close_with_dead_worker_fails_stranded_not_hangs():
    """An UNsupervised batcher whose worker died must still close promptly,
    failing the stranded futures instead of joining a dead thread forever."""
    batcher = MicroBatcher(_echo_dispatch, max_batch=4, max_wait_ms=0.0,
                           metrics=GatewayMetrics())
    batcher._crash_hook = lambda batch: (_ for _ in ()).throw(SystemExit("boom"))
    doomed = _req()
    batcher.submit(doomed)
    assert _wait_until(lambda: not batcher.worker_alive)
    t0 = time.perf_counter()
    batcher.close()
    assert time.perf_counter() - t0 < 5.0
    with pytest.raises(WorkerCrashed):
        doomed.future.result(timeout=10)


# ----------------------------------------------------------- supervisor --
def test_supervisor_restarts_dead_worker():
    metrics = GatewayMetrics()
    batcher = MicroBatcher(_echo_dispatch, max_batch=4, max_wait_ms=0.0,
                           queue_depth=64, metrics=metrics)
    armed = {"on": True}

    def hook(batch):
        if armed["on"]:
            armed["on"] = False
            raise SystemExit("injected death")

    batcher._crash_hook = hook
    with WorkerSupervisor(_FakeGateway(batcher), poll_interval_s=0.005) as sup:
        doomed = _req(top_k=3)
        batcher.submit(doomed)
        with pytest.raises(WorkerCrashed):
            doomed.future.result(timeout=10)   # supervisor repaired the hang
        assert _wait_until(lambda: batcher.worker_alive)
        ok = _req(top_k=9)
        batcher.submit(ok)
        assert ok.future.result(timeout=10) == 9
        assert _wait_until(lambda: sup.restarts == 1)
    batcher.close()
    assert metrics.worker_restarts == 1


def test_supervisor_treats_shutdown_as_not_a_crash():
    """After close(), the worker thread exits — the supervisor must NOT
    count that as a death or try to restart it."""
    batcher = MicroBatcher(_echo_dispatch, max_batch=4, max_wait_ms=0.0,
                           metrics=GatewayMetrics())
    with WorkerSupervisor(_FakeGateway(batcher), poll_interval_s=0.005) as sup:
        r = _req()
        batcher.submit(r)
        assert r.future.result(timeout=10) == r.top_k
        batcher.close()
        time.sleep(0.05)                   # give the poll loop a few beats
        assert sup.restarts == 0
    assert not batcher.worker_alive


# -------------------------------------------------------- restart storm --
def test_restart_guard_window_backoff_and_give_up():
    from repro.distributed.supervisor import RestartGuard

    g = RestartGuard(max_restarts=3, window_s=10.0, backoff_s=0.5,
                     backoff_multiplier=2.0)
    assert g.allow(100.0)
    g.record(100.0)
    assert not g.allow(100.1)            # inside the 0.5 s backoff
    assert g.allow(100.6)
    g.record(100.6)
    assert not g.allow(101.0)            # backoff doubled to 1.0 s
    assert g.allow(101.7)
    g.record(101.7)
    assert not g.allow(105.0)            # window holds 3 == max: storm
    assert g.gave_up
    assert not g.allow(1000.0)           # permanent: no resurrection
    with pytest.raises(ValueError):
        RestartGuard(max_restarts=0)


def test_restart_guard_window_slides():
    from repro.distributed.supervisor import RestartGuard

    g = RestartGuard(max_restarts=2, window_s=1.0, backoff_s=0.0)
    g.record(100.0)
    g.record(100.1)
    assert g.allow(101.5)                # both restarts aged out: budget back
    assert not g.gave_up


def test_supervisor_declares_always_crashing_worker_dead():
    """A worker that crashes on EVERY dispatch must not be restarted
    forever: after max_restarts within the window the supervisor declares it
    dead, closes the batcher (pending futures fail explicitly, new submits
    are refused), and surfaces the verdict in stats()."""
    metrics = GatewayMetrics()
    batcher = MicroBatcher(_echo_dispatch, max_batch=1, max_wait_ms=0.0,
                           queue_depth=64, metrics=metrics)
    batcher._crash_hook = lambda batch: (_ for _ in ()).throw(
        SystemExit("poisoned: crashes on every dispatch"))
    requests = [_req(top_k=i) for i in range(8)]   # max_batch=1: each wave
    for r in requests:                             # re-feeds the fresh worker
        batcher.submit(r)

    with WorkerSupervisor(_FakeGateway(batcher), poll_interval_s=0.005,
                          max_restarts=3, restart_window_s=30.0,
                          restart_backoff_s=0.005) as sup:
        assert _wait_until(lambda: sup.dead, timeout=30.0)
        assert sup.restarts == 3                   # budget spent, then dead
        s = sup.stats()
        assert s["dead"] is True and s["restarts"] == 3
        # every pending future failed explicitly — no hangs, no silent drops
        for r in requests:
            with pytest.raises(WorkerCrashed):
                r.future.result(timeout=10)
        # the dead replica sheds load instead of hanging it
        from repro.serving import AdmissionRejected
        with pytest.raises(AdmissionRejected):
            batcher.submit(_req())
        assert batcher.closed


def test_replica_set_supervisor_restarts_and_gives_up_per_replica():
    """One poll loop over N batchers: the crashed-once replica is revived,
    the always-crashing one burns its budget and is declared dead — with the
    owner notified through the callbacks."""
    import threading

    good_metrics, bad_metrics = GatewayMetrics(), GatewayMetrics()
    good = MicroBatcher(_echo_dispatch, max_batch=1, max_wait_ms=0.0,
                        queue_depth=64, metrics=good_metrics)
    bad = MicroBatcher(_echo_dispatch, max_batch=1, max_wait_ms=0.0,
                       queue_depth=64, metrics=bad_metrics)
    once = {"armed": True}

    def crash_once(batch):
        if once["armed"]:
            once["armed"] = False
            raise SystemExit("transient")

    good._crash_hook = crash_once
    bad._crash_hook = lambda batch: (_ for _ in ()).throw(SystemExit("poisoned"))

    restarted, gave_up = [], []
    from repro.distributed import ReplicaSetSupervisor

    with ReplicaSetSupervisor(
        [_FakeGateway(good), _FakeGateway(bad)], poll_interval_s=0.005,
        max_restarts=2, restart_window_s=30.0, restart_backoff_s=0.005,
        on_restarted=restarted.append, on_gave_up=gave_up.append,
    ) as sup:
        doomed = _req(top_k=1)
        good.submit(doomed)                    # crashes once, then revived
        for i in range(6):
            bad.submit(_req(top_k=i))          # keeps crashing until dead
        with pytest.raises(WorkerCrashed):
            doomed.future.result(timeout=10)
        ok = _req(top_k=9)
        _wait_until(lambda: good.worker_alive)
        good.submit(ok)
        assert ok.future.result(timeout=10) == 9   # replica 0 fully revived
        assert _wait_until(lambda: sup.dead == [False, True], timeout=30.0)
        s = sup.stats()
        assert s["dead"] == [False, True]
        assert s["restarts"][1] == 2
        assert 0 in restarted and gave_up == [1]
    good.close()
    assert bad.closed                          # closed by the give-up path

"""Assigned input-shape sets (identical across the LM pool).

``decode_*`` / ``long_*`` lower serve decode (one new token against a
seq_len-sized cache); ``prefill_*`` lowers the prompt pass; ``train_*``
lowers the full fwd+bwd+optimizer step.  ``long_500k`` applies only to
sub-quadratic families (SSM / hybrid / linear-attn) — skips recorded in
DESIGN.md §4.
"""

SHAPES = {
    "train_4k": dict(kind="train", seq_len=4_096, global_batch=256),
    "prefill_32k": dict(kind="prefill", seq_len=32_768, global_batch=32),
    "decode_32k": dict(kind="decode", seq_len=32_768, global_batch=128),
    "long_500k": dict(kind="decode", seq_len=524_288, global_batch=1),
}

_SUBQUADRATIC = ("mamba2", "rwkv6", "zamba_hybrid")


def shape_names_for(cfg) -> list[str]:
    names = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.block_type in _SUBQUADRATIC:
        names.append("long_500k")
    return names


def is_skipped(cfg, shape_name: str) -> bool:
    return shape_name == "long_500k" and cfg.block_type not in _SUBQUADRATIC

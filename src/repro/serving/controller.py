"""p99-targeted adaptive ``max_wait`` controller for the micro-batcher (§14).

The batcher's ``max_wait_ms`` trades straggler-coalescing (throughput,
batch occupancy) against added queueing latency.  A fixed value is tuned
for one load shape; this controller closes the loop against the latency
SLO instead: it watches the *windowed* p99 of the gateway latency
histogram (differencing bucket counts between control ticks, the same
trick the SLO evaluator uses) and steers the wait with **bounded AIMD**:

* p99 over the objective   → multiplicative decrease (halve the wait) —
  back off hard, the objective is burning;
* p99 under ``headroom × objective`` → additive increase (one small step)
  — cheap exploration toward better batching while the budget is slack;
* in the dead band between → hold.

Both directions clamp to ``[min_wait_ms, max_wait_ms]``, so the controller
can never wait longer than the configured ceiling nor go below the greedy
floor — a broken signal degrades to a fixed-wait batcher, never to an
unbounded one.

**Bit-identity is untouched** (§10 contract): the wait only changes *which
requests land in the same batch*, i.e. dispatch timing.  Every response is
still computed by the same padded-bucket match + top-k as
``recommend(basket, top_k, batch_size=response.bucket)`` for its
generation, so responses remain bit-identical regardless of what the
controller does.  That is why this knob — and only this knob — is safe to
drive from a feedback loop.

The batcher calls :meth:`AdaptiveMaxWait.current_wait_s` once per batch;
the controller re-evaluates at most every ``interval_s`` and only when the
window holds ``min_samples`` fresh observations (a p99 of three requests
is noise, not signal).
"""

from __future__ import annotations

import math
import threading
import time
from typing import Callable, Optional

from repro.obs.registry import Histogram


class AdaptiveMaxWait:
    """Bounded-AIMD ``max_wait`` controller driven by windowed p99."""

    def __init__(
        self,
        histogram: Histogram,
        *,
        objective_ms: float,
        initial_wait_ms: float,
        min_wait_ms: float = 0.0,
        max_wait_ms: Optional[float] = None,
        decrease_factor: float = 0.5,
        increase_ms: float = 0.25,
        interval_s: float = 0.25,
        headroom: float = 0.8,
        min_samples: int = 16,
        now_fn: Callable[[], float] = time.perf_counter,
    ):
        if objective_ms <= 0:
            raise ValueError("objective_ms must be positive")
        if not 0.0 < decrease_factor < 1.0:
            raise ValueError("decrease_factor must be in (0, 1)")
        if not 0.0 < headroom <= 1.0:
            raise ValueError("headroom must be in (0, 1]")
        self._hist = histogram
        self.objective_ms = float(objective_ms)
        self.min_wait_ms = max(0.0, float(min_wait_ms))
        self.max_wait_ms = (float(max_wait_ms) if max_wait_ms is not None
                            else float(initial_wait_ms))
        if self.max_wait_ms < self.min_wait_ms:
            raise ValueError("max_wait_ms must be >= min_wait_ms")
        self._decrease = float(decrease_factor)
        self._increase_ms = float(increase_ms)
        self._interval_s = float(interval_s)
        self._headroom = float(headroom)
        self._min_samples = int(min_samples)
        self._now = now_fn
        self._lock = threading.Lock()
        self._wait_ms = min(max(float(initial_wait_ms), self.min_wait_ms),
                            self.max_wait_ms)
        self._last_tick = self._now()
        self._last_counts, self._last_count = self._baseline()
        self.ticks = 0          # control decisions taken (observability)
        self.decreases = 0
        self.increases = 0
        self.last_window_p99_ms = float("nan")

    def _baseline(self):
        counts, count, _, _, _ = self._hist._state()
        return counts, count

    # ------------------------------------------------------------- control --
    def current_wait_s(self) -> float:
        """The batcher's per-batch hook: maybe tick, then return the wait."""
        now = self._now()
        with self._lock:
            if now - self._last_tick >= self._interval_s:
                self._tick_locked(now)
            return self._wait_ms / 1e3

    @property
    def current_wait_ms(self) -> float:
        with self._lock:
            return self._wait_ms

    def force_tick(self) -> None:
        """Evaluate immediately regardless of the interval (tests)."""
        with self._lock:
            self._tick_locked(self._now())

    def _tick_locked(self, now: float) -> None:
        counts, count = self._baseline()
        delta_count = count - self._last_count
        if delta_count < self._min_samples:
            # not enough fresh signal: hold, but do NOT reset the window —
            # a trickle of requests still accumulates toward min_samples
            self._last_tick = now
            return
        delta = [c - o for c, o in zip(counts, self._last_counts)]
        p99_s = Histogram._quantile_from(delta, delta_count, math.inf, 0.99)
        self._last_counts, self._last_count = counts, count
        self._last_tick = now
        self.ticks += 1
        p99_ms = p99_s * 1e3
        self.last_window_p99_ms = p99_ms
        if p99_ms > self.objective_ms:
            new = max(self.min_wait_ms, self._wait_ms * self._decrease)
            if new != self._wait_ms:
                self.decreases += 1
            self._wait_ms = new
        elif p99_ms < self.objective_ms * self._headroom:
            new = min(self.max_wait_ms, self._wait_ms + self._increase_ms)
            if new != self._wait_ms:
                self.increases += 1
            self._wait_ms = new
        # dead band [headroom*objective, objective]: hold steady

    # -------------------------------------------------------------- status --
    def snapshot(self) -> dict:
        with self._lock:
            return {
                "wait_ms": self._wait_ms,
                "objective_ms": self.objective_ms,
                "window_p99_ms": self.last_window_p99_ms,
                "ticks": self.ticks,
                "increases": self.increases,
                "decreases": self.decreases,
                "min_wait_ms": self.min_wait_ms,
                "max_wait_ms": self.max_wait_ms,
            }

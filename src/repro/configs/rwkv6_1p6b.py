"""RWKV6-1.6B "Finch" [arXiv:2404.05892] — attention-free, data-dependent decay."""

from repro.models.config import ModelConfig, RWKVConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    num_layers=24,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=7168,
    vocab_size=65536,
    block_type="rwkv6",
    rwkv=RWKVConfig(head_dim=64, lora_dim=32, d_ff=7168, chunk=128),
)

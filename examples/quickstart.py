"""Quickstart: mine frequent itemsets + association rules on synthetic data.

PYTHONPATH=src python examples/quickstart.py

To go from mined rules to an ONLINE service (store -> mine_streamed ->
rulebook -> micro-batched gateway with live hot-swap, DESIGN.md §10), the
whole pipeline is one command:

    PYTHONPATH=src python -m repro.launch.serve --transactions 4000 \
        --items 128 --requests 2000 --concurrency 16 --hot-swap-mid-load

(`examples/serve_gateway.py` is the same flow, step by step; the smaller
`examples/serve_rules.py` stops at the pre-assembled batch engine.)

To watch that service against declared SLOs — burn-rate alerts, brownout
admission, p99-adaptive batching (DESIGN.md §14) — add ``--slo``:

    PYTHONPATH=src python -m repro.launch.serve --replicas 3 --slo \
        --kill-replica-mid-load --alerts-jsonl alerts.jsonl

(`examples/serve_slo.py` is the same loop, step by step.)
"""

from repro.core.apriori import AprioriConfig, mine
from repro.core.rules import extract_rules
from repro.data.synthetic import QuestConfig, gen_transactions


def main():
    # 1. generate a T10-style transaction database (the paper's workload)
    db = gen_transactions(QuestConfig(num_transactions=5_000, num_items=200, avg_len=9, seed=42))
    print(f"DB: {db.shape[0]} transactions x {db.shape[1]} items, density {db.mean():.3f}")

    # 2. level-wise distributed Apriori (single device here; add a mesh for a pod)
    result = mine(db, AprioriConfig(min_support=0.03, max_k=5))
    for k in sorted(result.levels):
        print(f"  L{k}: {result.levels[k][0].shape[0]} frequent itemsets")

    # 3. association rules (KDD interpretation step)
    rules = extract_rules(result, min_confidence=0.7, max_rules=10)
    print("top rules:")
    for r in rules:
        print(f"  {r.antecedent} -> {r.consequent}   conf={r.confidence:.2f} lift={r.lift:.2f}")

    # 4. serve them online: see the module docstring — `repro.launch.serve`
    #    runs store -> mine_streamed -> rulebook -> micro-batched gateway
    print("next: PYTHONPATH=src python -m repro.launch.serve --hot-swap-mid-load")
    # 5. keep them fresh: appended rows fold in at delta cost and hot-swap
    #    under live traffic (DESIGN.md §15, examples/serve_refresh.py)
    print("then: PYTHONPATH=src python -m repro.launch.serve --refresh delta "
          "--append-mid-load 0.05")


if __name__ == "__main__":
    main()

"""Batched basket -> recommendation query engine (DESIGN.md §8).

The serving loop over a compiled rulebook: baskets are packed to the uint32
bitset word layout, streamed through the rule-match kernel in fixed-size
batches (one jit bucket), and each basket's per-item evidence scores are
reduced to top-k item recommendations with ``lax.top_k`` — items already in
the basket are masked to ``-inf`` first (you don't recommend what the user
already has) unless ``exclude_basket=False``.

On a mesh, the match step is the same Map/Reduce shape as mining, flipped:
baskets row-shard over the data axes (the query "HDFS blocks") while the
rulebook row-shards over ``rule_axis`` — each device matches its rule slice
against its basket shard and a ``lax.psum`` over the rule axis assembles the
full (B, I) score matrix (``core.mapreduce.MapReduceJob``, reduce over the
*model* axis where mining reduces over *data*).

``recommend_python`` is the per-basket pure-Python engine — the oracle for
tests and the baseline the serving benchmark measures QPS against.
"""

from __future__ import annotations

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core import itemsets as enc
from repro.core.mapreduce import MapReduceJob, mapreduce
from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.serving.rulebook import Rulebook


@dataclasses.dataclass
class RecommendResult:
    """Top-k recommendations per basket.  ``scores == -inf`` marks slots
    beyond the basket's candidate items (k larger than what's scoreable)."""

    items: np.ndarray    # (B, top_k) int32 item ids
    scores: np.ndarray   # (B, top_k) float32 aggregated rule evidence


def pack_baskets(baskets, num_items: int) -> np.ndarray:
    """Item-id lists or a dense {0,1} matrix -> packed uint32 (B, W) bitsets.

    A 2-D ndarray is always the dense form and must be exactly ``num_items``
    wide — a mismatched matrix is an error, never reinterpreted as id lists
    (a {0,1} row read as item ids would silently score garbage)."""
    if isinstance(baskets, np.ndarray) and baskets.ndim == 2:
        if baskets.shape[1] != num_items:
            raise ValueError(
                f"dense baskets are {baskets.shape[1]} items wide but the "
                f"rulebook vocabulary is {num_items}"
            )
        return enc.pack_bits(baskets)
    return enc.pack_bits(enc.dense_from_lists(list(baskets), num_items))


@functools.lru_cache(maxsize=16)
def _cached_match_step(mesh, impl, data_axes, rule_axis, block_n, block_k):
    return make_match_step(
        mesh, impl=impl, data_axes=data_axes, rule_axis=rule_axis,
        block_n=block_n, block_k=block_k,
    )


def make_match_step(
    mesh=None,
    *,
    impl: str = "auto",
    data_axes: tuple = ("data",),
    rule_axis: str = "model",
    block_n: int = 256,
    block_k: int = 256,
):
    """Build the jit'd batched match step:
    ``fn(b_packed (B, W), ante, lens, cons, scores) -> (B, 32·W) float32``.

    Single-device: a jit around ``kernels.ops.rule_match``.  Mesh: the
    Map/Reduce form — baskets sharded ``P(data_axes, None)``, rulebook
    columns ``P(rule_axis, ...)``, partial item scores psum'd over the rule
    axis (replicated result rows stay sharded over the data axes).
    """
    def local_match(b, a, ln, c, s):
        return kops.rule_match(b, a, ln, c, s, impl=impl, block_n=block_n, block_k=block_k)

    if mesh is None or math.prod(mesh.shape.values()) == 1:
        return jax.jit(local_match)

    job = MapReduceJob(map_fn=local_match, reduce_axes=(rule_axis,))
    in_specs = (
        P(data_axes, None),       # baskets: query row partition
        P(rule_axis, None),       # antecedent bitsets
        P(rule_axis),             # antecedent lengths
        P(rule_axis, None),       # consequent bitsets
        P(rule_axis),             # score column
    )
    return mapreduce(job, mesh, in_specs=in_specs, out_specs=P(data_axes, None))


@functools.partial(jax.jit, static_argnames=("top_k", "exclude_basket", "num_items"))
def _topk_items(item_scores, b_packed, *, top_k, exclude_basket, num_items):
    item_scores = item_scores[:, :num_items]
    if exclude_basket:
        in_basket = kref.unpack_bits_ref(b_packed, num_items) > 0
        item_scores = jnp.where(in_basket, -jnp.inf, item_scores)
    vals, idx = jax.lax.top_k(item_scores, top_k)
    return idx.astype(jnp.int32), vals


def recommend(
    rb: Rulebook,
    baskets,
    *,
    top_k: int = 10,
    batch_size: int = 1024,
    impl: str = "auto",
    exclude_basket: bool = True,
    mesh=None,
    data_axes: tuple = ("data",),
    rule_axis: str = "model",
    match_step=None,
    block_n: int = 256,
    block_k: int = 256,
) -> RecommendResult:
    """Batched end-to-end query loop: pack -> match -> mask -> top-k.

    ``baskets``: item-id lists, a dense {0,1} matrix, or pre-packed uint32
    bitsets.  Every batch is padded to ``batch_size`` (zero baskets are
    inert), so the whole stream compiles exactly one match-step bucket.
    Pass ``match_step`` to reuse a step across calls (e.g. a mesh-compiled
    one); otherwise one is built from ``mesh``/``impl``.
    """
    w = enc.packed_words(rb.num_items)
    b_np = np.asarray(baskets) if not isinstance(baskets, (list, tuple)) else None
    if b_np is not None and b_np.dtype == np.uint32 and b_np.ndim == 2 and b_np.shape[1] == w:
        b_packed = b_np
    else:
        b_packed = pack_baskets(baskets, rb.num_items)
    n = b_packed.shape[0]
    top_k = min(top_k, rb.num_items)

    if mesh is not None:
        shards = math.prod(mesh.shape[a] for a in data_axes)
        batch_size = ((batch_size + shards - 1) // shards) * shards
        if not isinstance(rb.ante_packed, jax.Array):
            from repro.serving.rulebook import place_rulebook

            rb = place_rulebook(rb, mesh, rule_axis)
        basket_sharding = NamedSharding(mesh, P(data_axes, None))
    elif not isinstance(rb.ante_packed, jax.Array):
        from repro.serving.rulebook import place_rulebook

        # commit the columns to device ONCE — not re-uploaded per batch
        rb = place_rulebook(rb, None)
    # cached per (mesh, impl, axes, blocks): repeated recommend() calls hit
    # the same jit entry instead of re-tracing the serving hot path
    step = match_step or _cached_match_step(
        mesh, impl, tuple(data_axes), rule_axis, block_n, block_k
    )

    items_out = np.zeros((n, top_k), np.int32)
    scores_out = np.zeros((n, top_k), np.float32)
    for start in range(0, n, batch_size):
        blk = b_packed[start : start + batch_size]
        m = blk.shape[0]
        if m < batch_size:
            blk = np.pad(blk, ((0, batch_size - m), (0, 0)))
        if mesh is not None:
            blk_dev = jax.device_put(blk, basket_sharding)
        else:
            blk_dev = jnp.asarray(blk)
        item_scores = step(blk_dev, rb.ante_packed, rb.ante_len, rb.cons_packed, rb.scores)
        idx, vals = _topk_items(
            item_scores, blk_dev,
            top_k=top_k, exclude_basket=exclude_basket, num_items=rb.num_items,
        )
        items_out[start : start + m] = np.asarray(idx)[:m]
        scores_out[start : start + m] = np.asarray(vals)[:m]
    return RecommendResult(items=items_out, scores=scores_out)


def rulebook_as_python(rb: Rulebook) -> list[tuple[frozenset, np.ndarray, float]]:
    """Decode a rulebook into (antecedent set, consequent item ids, score)
    triples — the working set of :func:`recommend_python`."""
    lens = np.asarray(rb.ante_len)
    keep = lens >= 0
    ante = enc.unpack_bits(np.asarray(rb.ante_packed)[keep], rb.num_items)
    cons = enc.unpack_bits(np.asarray(rb.cons_packed)[keep], rb.num_items)
    scores = np.asarray(rb.scores)[keep]
    return [
        (frozenset(np.flatnonzero(a).tolist()), np.flatnonzero(c), float(s))
        for a, c, s in zip(ante, cons, scores)
    ]


def recommend_python(
    rb: Rulebook,
    baskets,
    *,
    top_k: int = 10,
    exclude_basket: bool = True,
    decoded=None,
) -> RecommendResult:
    """Naive per-basket rule matching — oracle and QPS baseline.

    Same semantics as :func:`recommend`: summed score evidence per
    consequent item over matched rules, basket items masked to ``-inf``,
    ties broken by lowest item id (matching ``lax.top_k``).
    """
    rules = rulebook_as_python(rb) if decoded is None else decoded
    if isinstance(baskets, np.ndarray) and baskets.dtype == np.uint32:
        baskets = enc.unpack_bits(baskets, rb.num_items)
    if isinstance(baskets, np.ndarray) and baskets.ndim == 2:
        baskets = [np.flatnonzero(row).tolist() for row in np.asarray(baskets)]
    top_k = min(top_k, rb.num_items)

    items_out = np.zeros((len(baskets), top_k), np.int32)
    scores_out = np.zeros((len(baskets), top_k), np.float32)
    for b, basket in enumerate(baskets):
        bset = set(int(x) for x in basket)
        acc = np.zeros(rb.num_items, np.float64)
        for ante, cons, score in rules:
            if ante <= bset:
                acc[cons] += score
        if exclude_basket:
            acc[sorted(bset)] = -np.inf
        idx = np.lexsort((np.arange(rb.num_items), -acc))[:top_k]
        items_out[b] = idx
        scores_out[b] = acc[idx]
    return RecommendResult(items=items_out, scores=scores_out)

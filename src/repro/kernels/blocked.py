"""Blocked jnp counting path: fuse the containment epilogue per candidate
block so the (N, K) int32 intersection matrix is never fully materialised —
the pure-JAX analogue of the Pallas kernel's VMEM tiling (used on the dry-run
path, where Pallas cannot lower to the CPU backend)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def support_count_blocked(t_dense, c_dense, lengths, block_k: int = 512):
    """Exact counts, intermediates bounded to (N, block_k)."""
    n, i = t_dense.shape
    k = c_dense.shape[0]
    pad = (-k) % block_k
    c_pad = jnp.pad(c_dense, ((0, pad), (0, 0)))
    len_pad = jnp.pad(lengths.astype(jnp.int32), (0, pad), constant_values=-1)
    cb = c_pad.reshape(-1, block_k, i)
    lb = len_pad.reshape(-1, block_k)
    t32 = t_dense.astype(jnp.bfloat16)

    def one(args):
        c_blk, l_blk = args
        inter = jax.lax.dot_general(
            t32, c_blk.astype(jnp.bfloat16).T, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return (inter == l_blk[None].astype(jnp.float32)).sum(0, dtype=jnp.int32)

    counts = jax.lax.map(one, (cb, lb))
    return counts.reshape(-1)[:k]

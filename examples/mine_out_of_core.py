"""Out-of-core mining walkthrough: ingest a Quest DB into an on-disk
partitioned store (chunked — the dense matrix is never materialized), then
mine it with the streaming Map/Reduce driver and verify bit-identical
results against the in-memory miner, reporting peak host RSS for both.

python examples/mine_out_of_core.py [--transactions N] [--items I]
                                    [--chunk-rows C] [--min-support S]

Exits non-zero if streamed and in-memory results differ — CI runs this as
the out-of-core smoke (DESIGN.md §9).
"""

import argparse
import os
import resource
import shutil
import tempfile
import time


def rss_mb() -> float:
    """Peak RSS of this process so far, in MB (ru_maxrss is KB on Linux)."""
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--transactions", type=int, default=30_000)
    ap.add_argument("--items", type=int, default=256)
    ap.add_argument("--chunk-rows", type=int, default=2048)
    ap.add_argument("--shard-rows", type=int, default=4096)
    ap.add_argument("--min-support", type=float, default=0.02)
    ap.add_argument("--max-k", type=int, default=4)
    ap.add_argument("--keep-store", default="", metavar="DIR",
                    help="ingest here and keep it (default: temp dir, removed)")
    args = ap.parse_args()

    from repro.core.apriori import AprioriConfig, mine
    from repro.core.streaming import mine_son_streamed, mine_streamed
    from repro.data.store import ingest_quest
    from repro.data.synthetic import QuestConfig, gen_transactions

    qcfg = QuestConfig(num_transactions=args.transactions, num_items=args.items,
                       avg_len=10, seed=7)
    cfg = AprioriConfig(min_support=args.min_support, max_k=args.max_k,
                        count_impl="jnp", representation="packed")

    store_dir = args.keep_store or tempfile.mkdtemp(prefix="quest_store_")
    try:
        # --- 1. chunked ingest: generator -> packed shards on disk ---------
        t0 = time.time()
        store = ingest_quest(qcfg, store_dir, shard_rows=args.shard_rows,
                             chunk_rows=args.chunk_rows)
        disk_mb = sum(
            os.path.getsize(os.path.join(store_dir, f)) for f in os.listdir(store_dir)
        ) / 1e6
        print(f"ingest: {time.time()-t0:.2f}s -> {store.num_partitions} shards, "
              f"{disk_mb:.1f} MB on disk "
              f"(dense would be {args.transactions*args.items/1e6:.1f} MB in RAM)")

        # --- 2. streamed mine: host RAM bounded by chunk_rows --------------
        rss_before = rss_mb()
        t0 = time.time()
        streamed = mine_streamed(store, cfg, chunk_rows=args.chunk_rows)
        print(f"mine_streamed: {time.time()-t0:.2f}s, "
              f"{streamed.total_frequent} itemsets, "
              f"peak RSS delta {rss_mb()-rss_before:.1f} MB "
              f"(chunk = {args.chunk_rows} rows)")

        # --- 3. streamed SON: 2 rounds, shards as partitions ----------------
        t0 = time.time()
        son = mine_son_streamed(store, cfg, chunk_rows=args.chunk_rows)
        print(f"mine_son_streamed: {time.time()-t0:.2f}s, {son.total_frequent} itemsets")

        # --- 4. in-memory reference: the dense-materialization baseline ----
        t0 = time.time()
        db = gen_transactions(qcfg)
        inmem = mine(db, cfg)
        print(f"in-memory mine: {time.time()-t0:.2f}s "
              f"(dense DB resident: {db.nbytes/1e6:.1f} MB), "
              f"total peak RSS now {rss_mb():.1f} MB")

        assert streamed.as_dict() == inmem.as_dict(), "streamed != in-memory"
        assert son.as_dict() == inmem.as_dict(), "streamed SON != in-memory"
        assert streamed.min_count == inmem.min_count
        print("OUT_OF_CORE_OK — streamed, streamed-SON and in-memory results "
              "are dict-identical")
    finally:
        if not args.keep_store:
            shutil.rmtree(store_dir, ignore_errors=True)


if __name__ == "__main__":
    main()

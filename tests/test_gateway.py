"""Gateway tests: bit-identity under any arrival interleaving, hot-swap
with zero dropped / zero mixed-generation requests, backpressure reporting,
cache semantics, metrics arithmetic (DESIGN.md §10).

The bit-identity contract: a gateway response equals a direct
``recommend()`` call against the generation named in the response, run at
the same jit bucket (``batch_size=resp.bucket``) — the match contraction is
row-independent, so only the padded batch shape (never the other requests
in the batch) affects a row's floats.
"""

import time
from concurrent.futures import Future, ThreadPoolExecutor

import numpy as np
import pytest

from repro.serving import (
    AdmissionRejected,
    BasketCache,
    Gateway,
    GatewayMetrics,
    LatencyHistogram,
    MicroBatcher,
    Request,
    basket_key,
    compile_rulebook,
    pow2_bucket,
    recommend,
)

NUM_ITEMS = 32


@pytest.fixture(scope="module")
def rulebooks(small_db):
    from repro.core.apriori import AprioriConfig, mine

    rb0 = compile_rulebook(
        mine(small_db, AprioriConfig(min_support=0.05, max_k=3, count_impl="jnp")),
        min_confidence=0.3, num_items=NUM_ITEMS,
    )
    rb1 = compile_rulebook(
        mine(small_db, AprioriConfig(min_support=0.12, max_k=3, count_impl="jnp")),
        min_confidence=0.5, num_items=NUM_ITEMS,
    )
    assert rb0.num_rules > rb1.num_rules > 0
    return rb0, rb1


@pytest.fixture(scope="module")
def baskets(small_db):
    return [np.flatnonzero(row).tolist() for row in small_db[:64]]


def check_response(resp, rb, basket, top_k):
    """One response vs the direct batch engine at the answering bucket."""
    direct = recommend(rb, [basket], top_k=top_k, batch_size=resp.bucket)
    assert np.array_equal(resp.items, direct.items[0])
    assert np.array_equal(resp.scores, direct.scores[0])


# ------------------------------------------------------------ bit-identity --
def test_sequential_singles_match_recommend(rulebooks, baskets):
    rb0, _ = rulebooks
    with Gateway(rb0, max_batch=8, max_wait_ms=0.0, cache_capacity=0) as gw:
        for b in baskets[:10]:
            resp = gw.query(b, top_k=5)
            assert resp.generation == 0 and not resp.cached
            check_response(resp, rb0, b, 5)


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_random_interleavings_bit_identical(rulebooks, baskets, seed):
    """Arrival-pattern property: singles, concurrent bursts, duplicate
    baskets and mixed top_k all yield responses bit-identical to the batch
    engine for the answering generation."""
    rb0, _ = rulebooks
    rng = np.random.default_rng(seed)
    plan = []                           # (basket index, top_k)
    for _ in range(rng.integers(3, 6)):
        burst = int(rng.integers(1, 24))
        k = int(rng.choice([3, 7]))
        idx = rng.integers(0, len(baskets), burst)
        plan += [(int(i), k) for i in idx]   # duplicates arise naturally

    with Gateway(rb0, max_batch=16, max_wait_ms=1.0, cache_capacity=256) as gw:
        with ThreadPoolExecutor(max_workers=8) as pool:
            futs = list(pool.map(lambda p: (p, gw.submit(baskets[p[0]], top_k=p[1])), plan))
        for (i, k), fut in futs:
            resp = fut.result(timeout=60)
            assert resp.generation == 0
            check_response(resp, rb0, baskets[i], k)


def test_packed_row_submission_equals_id_list(rulebooks, baskets):
    from repro.serving import pack_baskets

    rb0, _ = rulebooks
    with Gateway(rb0, max_batch=4, max_wait_ms=0.0, cache_capacity=0) as gw:
        packed = pack_baskets([baskets[0]], NUM_ITEMS)[0]
        a = gw.query(packed, top_k=5)
        b = gw.query(baskets[0], top_k=5)
        assert np.array_equal(a.items, b.items) and np.array_equal(a.scores, b.scores)


def test_top_k_clamps_to_vocabulary(rulebooks, baskets):
    rb0, _ = rulebooks
    with Gateway(rb0, max_batch=4, max_wait_ms=0.0) as gw:
        resp = gw.query(baskets[0], top_k=10_000)
        assert resp.items.shape == (NUM_ITEMS,)
        check_response(resp, rb0, baskets[0], 10_000)


# ---------------------------------------------------------------- hot-swap --
def test_hot_swap_zero_dropped_zero_mixed(rulebooks, baskets):
    """Concurrent load across a swap: every admitted request resolves, every
    response verifies bit-identically against the generation it names, and
    requests submitted after the swap returns are answered by the new
    generation only."""
    rb0, rb1 = rulebooks
    rbs = {0: rb0, 1: rb1}
    with Gateway(rb0, max_batch=8, max_wait_ms=0.5, queue_depth=4096,
                 cache_capacity=0) as gw:
        pre = [gw.submit(baskets[i % len(baskets)], top_k=5) for i in range(40)]
        for f in pre:                      # guarantee gen-0 traffic completed
            assert f.result(60).generation == 0

        with ThreadPoolExecutor(max_workers=8) as pool:
            mid = list(pool.map(
                lambda i: (i, gw.submit(baskets[i % len(baskets)], top_k=5)),
                range(120)))
            new_gen = gw.hot_swap(rb1)     # swap while the pool is firing
            assert new_gen == 1
        post = [(i, gw.submit(baskets[i % len(baskets)], top_k=5)) for i in range(20)]

        responses = [(i, f.result(timeout=60)) for i, f in mid + post]
        assert len(responses) == 140       # zero dropped
        for i, resp in responses:
            assert resp.generation in (0, 1)
            check_response(resp, rbs[resp.generation], baskets[i % len(baskets)], 5)
        for _, resp in responses[-20:]:    # after hot_swap returned: new gen only
            assert resp.generation == 1
        assert gw.generation == 1
        assert gw.stats()["swaps"] == 1


def test_hot_swap_rejects_vocabulary_change(rulebooks):
    import dataclasses

    rb0, _ = rulebooks
    with Gateway(rb0, max_batch=4, max_wait_ms=0.0) as gw:
        widened = dataclasses.replace(rb0, num_items=NUM_ITEMS * 2)
        with pytest.raises(ValueError, match="vocabulary"):
            gw.hot_swap(widened)


# ------------------------------------------------------------ backpressure --
def test_batcher_backpressure_rejects_are_reported():
    metrics = GatewayMetrics()
    done = []

    def slow_dispatch(group):
        time.sleep(0.05)
        for r in group:
            done.append(r)
            r.future.set_result(r.top_k)

    batcher = MicroBatcher(slow_dispatch, max_batch=2, max_wait_ms=0.0,
                           queue_depth=4, metrics=metrics)
    accepted, rejected = [], 0
    for i in range(30):
        req = Request(packed=np.zeros(1, np.uint32), top_k=i, future=Future(),
                      t_submit=time.perf_counter())
        try:
            batcher.submit(req)
            accepted.append(req)
        except AdmissionRejected as e:
            assert e.reason == "admission queue full"
            rejected += 1
    batcher.close()

    assert rejected > 0                        # overload actually rejected
    assert len(accepted) + rejected == 30      # every request accounted for
    assert metrics.submitted == len(accepted) and metrics.rejected == rejected
    for req in accepted:                       # admitted -> answered, no drops
        assert req.future.result(timeout=10) == req.top_k
    assert len(done) == len(accepted)


def test_gateway_backpressure_counts_are_consistent(rulebooks, baskets):
    rb0, _ = rulebooks
    gw = Gateway(rb0, max_batch=4, max_wait_ms=0.0, queue_depth=2, cache_capacity=0)
    real_match = gw._match
    gw._match = lambda *a, **kw: (time.sleep(0.03), real_match(*a, **kw))[1]
    futs, rejected = [], 0
    for i in range(60):
        try:
            futs.append(gw.submit(baskets[i % len(baskets)], top_k=5))
        except AdmissionRejected:
            rejected += 1
    responses = [f.result(timeout=60) for f in futs]
    gw.close()
    assert rejected > 0 and len(responses) + rejected == 60
    s = gw.stats()
    assert s["submitted"] == len(responses) and s["rejected"] == rejected
    assert s["completed"] == len(responses) and s["failed"] == 0
    # rejected probes are not misses, and the cache's own counters agree
    # with the gateway metrics even under rejection-heavy load
    assert s["cache_hits"] + s["cache_misses"] == s["submitted"]
    assert s["cache"]["hits"] == s["cache_hits"]
    assert s["cache"]["misses"] == s["cache_misses"]


def test_dispatch_failure_reaches_futures_never_drops():
    metrics = GatewayMetrics()

    def broken_dispatch(group):
        raise ValueError("kernel exploded")

    batcher = MicroBatcher(broken_dispatch, max_batch=4, max_wait_ms=0.0,
                           queue_depth=8, metrics=metrics)
    req = Request(packed=np.zeros(1, np.uint32), top_k=1, future=Future(),
                  t_submit=time.perf_counter())
    batcher.submit(req)
    with pytest.raises(ValueError, match="kernel exploded"):
        req.future.result(timeout=10)
    batcher.close()
    assert metrics.failed == 1


def test_submit_after_close_rejected(rulebooks, baskets):
    rb0, _ = rulebooks
    gw = Gateway(rb0, max_batch=4, max_wait_ms=0.0)
    gw.close()
    with pytest.raises(AdmissionRejected, match="closed"):
        gw.submit(baskets[0])


# ----------------------------------------------------------------- caching --
def test_cache_hit_is_bit_identical_and_generation_scoped(rulebooks, baskets):
    rb0, rb1 = rulebooks
    with Gateway(rb0, max_batch=4, max_wait_ms=0.0, cache_capacity=64) as gw:
        miss = gw.query(baskets[0], top_k=5)
        hit = gw.query(baskets[0], top_k=5)
        assert not miss.cached and hit.cached
        assert np.array_equal(miss.items, hit.items)
        assert np.array_equal(miss.scores, hit.scores)
        assert gw.cache.hits == 1

        other_k = gw.query(baskets[0], top_k=3)          # top_k is in the key
        assert not other_k.cached

        gw.hot_swap(rb1)
        fresh = gw.query(baskets[0], top_k=5)            # generation is in the key
        assert not fresh.cached and fresh.generation == 1
        check_response(fresh, rb1, baskets[0], 5)
        assert gw.cache.hit_rate == gw.metrics.cache_hit_rate
        evicted = gw.cache.evict_generation(0)
        assert evicted > 0


def test_basket_cache_lru_eviction_and_accounting():
    cache = BasketCache(capacity=2)
    k = lambda i: basket_key(np.full(2, i, np.uint32), 5, 0)
    e = lambda i: (np.array([i]), np.array([float(i)]), 0, 1)
    cache.put(k(0), e(0))
    cache.put(k(1), e(1))
    assert cache.get(k(0)) is not None       # refresh 0 -> 1 becomes LRU
    cache.put(k(2), e(2))                    # evicts 1
    assert cache.get(k(1)) is None
    assert cache.get(k(2)) is not None
    snap = cache.snapshot()
    assert snap["size"] == 2 and snap["evictions"] == 1
    assert snap["hits"] == 2 and snap["misses"] == 1
    disabled = BasketCache(capacity=0)
    disabled.put(k(0), e(0))
    assert disabled.get(k(0)) is None and len(disabled) == 0


# ----------------------------------------------------------------- metrics --
def test_latency_histogram_quantiles_conservative():
    h = LatencyHistogram()
    assert h.quantile(0.5) == 0.0
    rng = np.random.default_rng(0)
    samples = rng.uniform(1e-3, 100e-3, 2000)
    for s in samples:
        h.record(float(s))
    for q in (0.5, 0.95, 0.99):
        true = float(np.quantile(samples, q))
        est = h.quantile(q)
        assert est >= true * 0.999           # never an underestimate
        assert est <= true * 1.25 * 1.05     # within one bucket's growth
    snap = h.snapshot()
    assert snap["count"] == 2000
    assert snap["min_ms"] == pytest.approx(samples.min() * 1e3)
    assert snap["max_ms"] == pytest.approx(samples.max() * 1e3)
    assert h.quantile(1.0) <= samples.max() * 1.0001


def test_gateway_metrics_occupancy_and_snapshot():
    m = GatewayMetrics()
    m.record_batch(3, 4)
    m.record_batch(1, 4)
    assert m.batch_occupancy == pytest.approx(0.5)
    m.record_cache(True)
    m.record_cache(False)
    assert m.cache_hit_rate == pytest.approx(0.5)
    m.record_admission(True)
    m.record_response(0.010)
    snap = m.snapshot()
    assert snap["batches"] == 2 and snap["submitted"] == 1
    assert snap["latency"]["count"] == 1
    assert snap["latency"]["p50_ms"] >= 10.0


def test_occupancy_counts_real_vs_padded(rulebooks, baskets):
    rb0, _ = rulebooks
    with Gateway(rb0, max_batch=8, max_wait_ms=0.0, cache_capacity=0) as gw:
        for b in baskets[:5]:
            gw.query(b, top_k=5)
        s = gw.stats()
        assert s["batch_rows_real"] == 5
        assert s["batch_rows_padded"] >= 5
        assert 0.0 < s["batch_occupancy"] <= 1.0
        assert s["latency"]["count"] == 5


# ------------------------------------------------------------------ bucket --
def test_pow2_bucket_ladder():
    assert [pow2_bucket(n, 64) for n in (1, 2, 3, 5, 8, 9, 33, 64)] == \
        [1, 2, 4, 8, 8, 16, 64, 64]
    assert pow2_bucket(33, 48) == 48         # non-pow2 max_batch clamps
    assert pow2_bucket(3, 64, multiple=3) == 6
    assert pow2_bucket(1, 64, multiple=4) == 4
    with pytest.raises(ValueError):
        pow2_bucket(0, 64)
    with pytest.raises(ValueError):
        pow2_bucket(65, 64)


# --------------------------------------------------------------- deadlines --
def test_batcher_drops_expired_requests_at_dispatch():
    """A request whose deadline passed while queued is failed with
    DeadlineExceeded at dispatch time — the device never works for a caller
    that has given up — while live requests in the same batch are served."""
    from repro.serving import DeadlineExceeded

    metrics = GatewayMetrics()
    served = []

    def dispatch(group):
        for r in group:
            served.append(r.top_k)
            r.future.set_result(r.top_k)

    batcher = MicroBatcher(dispatch, max_batch=8, max_wait_ms=0.0,
                           queue_depth=16, metrics=metrics)
    now = time.perf_counter()
    expired = Request(packed=np.zeros(1, np.uint32), top_k=1, future=Future(),
                      t_submit=now, deadline=now - 0.001)      # already past
    live = Request(packed=np.zeros(1, np.uint32), top_k=2, future=Future(),
                   t_submit=now, deadline=now + 30.0)
    batcher.submit(expired)
    batcher.submit(live)
    assert live.future.result(timeout=10) == 2
    with pytest.raises(DeadlineExceeded):
        expired.future.result(timeout=10)
    batcher.close()
    assert served == [2]                       # expired never dispatched
    assert metrics.deadline_expired == 1
    assert metrics.failed == 1
    assert metrics.snapshot()["deadline_expired"] == 1


def test_gateway_deadline_ms_bounds_the_request(rulebooks, baskets):
    """deadline_ms=0 expires in the queue (typed failure, counted);
    a generous deadline serves normally and stays bit-identical."""
    from repro.serving import DeadlineExceeded

    rb0, _ = rulebooks
    with Gateway(rb0, max_wait_ms=0.0, warmup=False, cache_capacity=0) as gw:
        gw.query(baskets[0])                   # compile off the clock
        ok = gw.query(baskets[1], deadline_ms=30_000)
        check_response(ok, rb0, baskets[1], gw.default_top_k)
        with pytest.raises(DeadlineExceeded):
            gw.query(baskets[2], deadline_ms=0)
        s = gw.stats()
        assert s["deadline_expired"] == 1
        # deadline expiry is an explicit failure, never a silent drop
        assert s["completed"] == 2 and s["failed"] == 1


# ------------------------------------------------ generation age (§14) -----
def test_generation_age_gauge_resets_on_hot_swap(rulebooks):
    """``generation_age_seconds`` is a LIVE gauge: it grows between reads
    without anyone writing it, and a hot-swap commit re-stamps it — the
    signal the freshness SLO watches."""
    rb0, rb1 = rulebooks
    with Gateway(rb0, max_batch=4, max_wait_ms=0.0, cache_capacity=0) as gw:
        a1 = gw.metrics.generation_age.value
        time.sleep(0.05)
        a2 = gw.metrics.generation_age.value
        assert a2 > a1 >= 0.0                  # ages with no writer
        assert gw.stats()["generation_age_s"] >= a2
        pre_swap = gw.metrics.generation_age.value
        gw.hot_swap(rb1)
        assert gw.metrics.generation_age.value < pre_swap   # re-stamped
        # and it reaches the registry cut the SLO evaluator differences
        cut = gw.metrics.registry.raw_snapshot()
        assert 0.0 <= cut["gateway_generation_age_seconds"] < pre_swap

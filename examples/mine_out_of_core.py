"""Out-of-core mining walkthrough: ingest a Quest DB into an on-disk
partitioned store (chunked — the dense matrix is never materialized), then
mine it with the streaming Map/Reduce driver and verify bit-identical
results against the in-memory miner, reporting peak host RSS for both.

python examples/mine_out_of_core.py [--transactions N] [--items I]
                                    [--chunk-rows C] [--min-support S]
                                    [--kill-resume]

``--kill-resume`` adds the fault-tolerance walkthrough (DESIGN.md §11): the
same store is mined in a CHILD process with mid-level checkpointing enabled,
the child is ``kill -9``'d at its first mid-level commit, and a resumed mine
restores the snapshot and finishes — asserted dict-identical to the
uninterrupted streamed result.

Exits non-zero if streamed and in-memory results differ — CI runs this as
the out-of-core smoke (DESIGN.md §9).
"""

import argparse
import os
import resource
import shutil
import subprocess
import sys
import tempfile
import time


def _child_kill(store_dir, cfg, chunk_rows, every):
    """Child mode: mine with checkpoints, SIGKILL at the first mid-level
    commit — a real node loss, no atexit, no finally."""
    import signal

    from repro.core.streaming import mine_streamed
    from repro.data.store import open_store
    from repro.distributed.checkpoint import MiningCheckpoint

    store = open_store(store_dir)

    class Killing(MiningCheckpoint):
        def save(self, state, sfp, mfp):
            seq = super().save(state, sfp, mfp)
            if state.mid_level and state.next_k >= 2:
                self.wait()                 # snapshot committed; now "die"
                os.kill(os.getpid(), signal.SIGKILL)
            return seq

    mine_streamed(store, cfg, chunk_rows=chunk_rows,
                  checkpoint=Killing(store.checkpoint_path),
                  checkpoint_every_chunks=every)
    raise SystemExit("unreachable: the SIGKILL above must have fired")


def rss_mb() -> float:
    """Peak RSS of this process so far, in MB (ru_maxrss is KB on Linux)."""
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--transactions", type=int, default=30_000)
    ap.add_argument("--items", type=int, default=256)
    ap.add_argument("--chunk-rows", type=int, default=2048)
    ap.add_argument("--shard-rows", type=int, default=4096)
    ap.add_argument("--min-support", type=float, default=0.02)
    ap.add_argument("--max-k", type=int, default=4)
    ap.add_argument("--keep-store", default="", metavar="DIR",
                    help="ingest here and keep it (default: temp dir, removed)")
    ap.add_argument("--kill-resume", action="store_true",
                    help="also run the kill -9 / resume cycle (DESIGN.md §11)")
    ap.add_argument("--checkpoint-every", type=int, default=4, metavar="CHUNKS",
                    help="mid-level checkpoint cadence of the kill-resume cycle")
    ap.add_argument("--_child-kill", default="", help=argparse.SUPPRESS)
    args = ap.parse_args()

    from repro.core.apriori import AprioriConfig, mine
    from repro.core.streaming import mine_son_streamed, mine_streamed
    from repro.data.store import ingest_quest
    from repro.data.synthetic import QuestConfig, gen_transactions

    qcfg = QuestConfig(num_transactions=args.transactions, num_items=args.items,
                       avg_len=10, seed=7)
    cfg = AprioriConfig(min_support=args.min_support, max_k=args.max_k,
                        count_impl="jnp", representation="packed")

    if args._child_kill:
        _child_kill(args._child_kill, cfg, args.chunk_rows, args.checkpoint_every)
        return

    store_dir = args.keep_store or tempfile.mkdtemp(prefix="quest_store_")
    try:
        # --- 1. chunked ingest: generator -> packed shards on disk ---------
        t0 = time.time()
        store = ingest_quest(qcfg, store_dir, shard_rows=args.shard_rows,
                             chunk_rows=args.chunk_rows)
        disk_mb = sum(
            os.path.getsize(os.path.join(store_dir, f)) for f in os.listdir(store_dir)
        ) / 1e6
        print(f"ingest: {time.time()-t0:.2f}s -> {store.num_partitions} shards, "
              f"{disk_mb:.1f} MB on disk "
              f"(dense would be {args.transactions*args.items/1e6:.1f} MB in RAM)")

        # --- 2. streamed mine: host RAM bounded by chunk_rows --------------
        rss_before = rss_mb()
        t0 = time.time()
        streamed = mine_streamed(store, cfg, chunk_rows=args.chunk_rows)
        print(f"mine_streamed: {time.time()-t0:.2f}s, "
              f"{streamed.total_frequent} itemsets, "
              f"peak RSS delta {rss_mb()-rss_before:.1f} MB "
              f"(chunk = {args.chunk_rows} rows)")

        # --- 3. streamed SON: 2 rounds, shards as partitions ----------------
        t0 = time.time()
        son = mine_son_streamed(store, cfg, chunk_rows=args.chunk_rows)
        print(f"mine_son_streamed: {time.time()-t0:.2f}s, {son.total_frequent} itemsets")

        # --- 4. in-memory reference: the dense-materialization baseline ----
        t0 = time.time()
        db = gen_transactions(qcfg)
        inmem = mine(db, cfg)
        print(f"in-memory mine: {time.time()-t0:.2f}s "
              f"(dense DB resident: {db.nbytes/1e6:.1f} MB), "
              f"total peak RSS now {rss_mb():.1f} MB")

        assert streamed.as_dict() == inmem.as_dict(), "streamed != in-memory"
        assert son.as_dict() == inmem.as_dict(), "streamed SON != in-memory"
        assert streamed.min_count == inmem.min_count
        print("OUT_OF_CORE_OK — streamed, streamed-SON and in-memory results "
              "are dict-identical")

        # --- 5. (optional) kill -9 mid-mine, resume from the checkpoint ----
        if args.kill_resume:
            from repro.distributed.checkpoint import MiningCheckpoint

            t0 = time.time()
            child = subprocess.run(
                [sys.executable, os.path.abspath(__file__),
                 "--_child-kill", store_dir,
                 "--chunk-rows", str(args.chunk_rows),
                 "--min-support", str(args.min_support),
                 "--max-k", str(args.max_k),
                 "--checkpoint-every", str(args.checkpoint_every)],
                capture_output=True, text=True, timeout=600, env=dict(os.environ),
            )
            assert child.returncode == -9, (
                f"child should die by SIGKILL, got rc={child.returncode}\n"
                f"{child.stderr[-2000:]}")
            snap, _ = MiningCheckpoint(store.checkpoint_path).load_latest()
            print(f"kill -9'd the child mid-level ({time.time()-t0:.2f}s): "
                  f"committed snapshot at level {snap.next_k}, "
                  f"{snap.chunks_done} chunks folded")
            t0 = time.time()
            resumed = mine_streamed(store, cfg, chunk_rows=args.chunk_rows,
                                    checkpoint=True,
                                    checkpoint_every_chunks=args.checkpoint_every,
                                    resume=True)
            assert resumed.as_dict() == streamed.as_dict(), "resumed != streamed"
            print(f"resume: {time.time()-t0:.2f}s — KILL_RESUME_OK, resumed "
                  "mine is dict-identical to the uninterrupted one")
    finally:
        if not args.keep_store:
            shutil.rmtree(store_dir, ignore_errors=True)


if __name__ == "__main__":
    main()

"""MusicGen-medium [arXiv:2306.05284; hf] — decoder-only over EnCodec tokens.
Modality frontend is a STUB per the assignment: input_specs() provides
precomputed frame embeddings (B, S, d_model); the backbone is the deliverable."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    num_layers=48,
    d_model=1536,
    num_heads=24,
    num_kv_heads=24,
    d_ff=6144,
    vocab_size=2048,
    frontend="frames",
    norm="layernorm",
    act="gelu",
)

"""The paper's own workload: mining configuration (not an LM arch)."""

from repro.core.apriori import AprioriConfig
from repro.data.synthetic import QuestConfig

CONFIG = dict(
    mining=AprioriConfig(
        min_support=0.01,
        max_k=8,
        representation="packed",   # uint32 bitsets: the roofline-optimal store (DESIGN.md §4)
        data_axes=("data",),
        model_axis="model",
    ),
    dataset=QuestConfig(num_transactions=1 << 20, num_items=2048, avg_len=12, num_patterns=256),
)

"""Fill EXPERIMENTS.md table placeholders from experiments/dryrun/*.json."""

from repro.launch.report import dryrun_table, load_cells, roofline_table, summary_stats


def main():
    cells = load_cells()
    stats = summary_stats(cells)
    print("sweep:", stats)
    md = open("EXPERIMENTS.md").read()
    md = md.replace("<!-- DRYRUN_TABLE -->", dryrun_table(cells, "single"))
    md = md.replace("<!-- DRYRUN_TABLE_MULTI -->", dryrun_table(cells, "multi"))
    md = md.replace("<!-- ROOFLINE_TABLE -->", roofline_table(cells))
    open("EXPERIMENTS.md", "w").write(md)
    print("EXPERIMENTS.md tables filled")


if __name__ == "__main__":
    main()

"""Shared observability substrate: metrics registry, span tracer, mining
job counters (DESIGN.md §13) — plus the active layer on top (§14): SLO
specs with burn-rate alerting and the bench-trajectory regression gate."""

from .registry import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    Sampler,
)
from .trace import Span, Tracer
from .mining import MiningObs, MiningProgress, PHASES
from .slo import (
    AlertEvent,
    BurnRule,
    DEFAULT_RULES,
    SLOEvaluator,
    SLOSpec,
    mining_slos,
    serving_slos,
)

__all__ = [
    "AlertEvent",
    "BurnRule",
    "Counter",
    "DEFAULT_RULES",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "MiningObs",
    "MiningProgress",
    "PHASES",
    "Sampler",
    "SLOEvaluator",
    "SLOSpec",
    "Span",
    "Tracer",
    "mining_slos",
    "serving_slos",
]

"""Mining correctness: distributed Apriori vs exhaustive oracle (paper §3.3)."""

import math

import numpy as np
import pytest

from conftest import brute_force_frequent
from repro.core.apriori import AprioriConfig, mine
from repro.core.rules import extract_rules
from repro.core.son import mine_son


@pytest.mark.parametrize("impl", ["jnp", "pallas_interpret"])
def test_mine_matches_brute_force(small_db, impl):
    cfg = AprioriConfig(min_support=0.08, max_k=6, count_impl=impl)
    res = mine(small_db, cfg)
    oracle = brute_force_frequent(small_db, res.min_count, 6)
    assert res.as_dict() == oracle


def test_naive_paper_map_equals_pruned_join(small_db):
    """The paper's 'all subsets' map and the classical join+prune agree."""
    base = mine(small_db, AprioriConfig(min_support=0.12, max_k=4, count_impl="jnp"))
    naive = mine(
        small_db,
        AprioriConfig(min_support=0.12, max_k=4, count_impl="jnp", use_naive_paper_map=True),
    )
    assert base.as_dict() == naive.as_dict()


def test_son_equals_levelwise(small_db):
    cfg = AprioriConfig(min_support=0.08, max_k=6, count_impl="jnp")
    assert mine_son(small_db, cfg, num_partitions=5).as_dict() == mine(small_db, cfg).as_dict()


def test_son_honors_representation_and_impl(small_db):
    """SON phase 1 must inherit cfg's count path (regression: it used to
    hardcode count_impl='jnp'): the packed representation and a Pallas
    interpret impl both flow through local mining unchanged."""
    base = mine_son(
        small_db, AprioriConfig(min_support=0.08, max_k=4, count_impl="jnp"), num_partitions=3
    )
    packed = mine_son(
        small_db,
        AprioriConfig(
            min_support=0.08, max_k=4, count_impl="pallas_interpret",
            representation="packed", candidate_pad=128,
        ),
        num_partitions=3,
    )
    assert base.as_dict() == packed.as_dict()


def test_min_count_semantics(small_db):
    n = small_db.shape[0]
    cfg = AprioriConfig(min_support=0.1, max_k=2, count_impl="jnp")
    res = mine(small_db, cfg)
    assert res.min_count == math.ceil(0.1 * n)
    for _, (sets, sup) in res.levels.items():
        assert (sup >= res.min_count).all()


def test_checkpoint_resume_midway(small_db):
    """Kill after level 2, resume from the checkpoint -> identical result."""
    cfg = AprioriConfig(min_support=0.08, max_k=6, count_impl="jnp")
    full = mine(small_db, cfg)

    saved = {}

    class Killed(Exception):
        pass

    def cb(k, levels):
        saved["levels"] = {kk: (s.copy(), p.copy()) for kk, (s, p) in levels.items()}
        saved["next_k"] = k + 1
        if k == 2:
            raise Killed

    with pytest.raises(Killed):
        mine(small_db, cfg, checkpoint_cb=cb)
    resumed = mine(small_db, cfg, resume_state=saved)
    assert resumed.as_dict() == full.as_dict()


def test_support_query_and_rules(small_db):
    cfg = AprioriConfig(min_support=0.08, max_k=4, count_impl="jnp")
    res = mine(small_db, cfg)
    d = res.as_dict()
    some = next(iter(d))
    assert res.support(some) == d[some]
    assert res.support((0, 1, 2, 3, 4, 5, 6, 7)) == 0  # not frequent at this threshold

    rules = extract_rules(res, min_confidence=0.6)
    for r in rules[:50]:
        s_union = d[tuple(sorted(r.antecedent + r.consequent))]
        assert r.confidence == pytest.approx(s_union / d[r.antecedent])
        assert r.confidence >= 0.6


def test_empty_and_degenerate():
    empty = np.zeros((10, 8), dtype=np.int8)
    res = mine(empty, AprioriConfig(min_support=0.5, max_k=3, count_impl="jnp"))
    assert res.total_frequent == 0

    ones = np.ones((10, 4), dtype=np.int8)
    res = mine(ones, AprioriConfig(min_support=0.9, max_k=5, count_impl="jnp"))
    # every subset of {0,1,2,3} is frequent: 4 + 6 + 4 + 1
    assert res.total_frequent == 15

"""Sharded checkpointing with manifest + elastic restore.

Layout: <dir>/step_<N>/{manifest.json, arrays.npz}. The manifest records each
leaf's path, shape, dtype and PartitionSpec; restore re-shards onto ANY mesh
whose axis sizes divide the shapes (elastic node counts — the paper's cluster
grows/shrinks without invalidating checkpoints). On a multi-host deployment
each host would write its addressable shards (same manifest format, one npz
per host); this single-controller build holds all shards locally so one npz
suffices — the restore path is identical.

An async writer thread overlaps serialization with training (double-buffered;
`wait()` joins before the next save or at exit).
"""

from __future__ import annotations

import json
import os
import threading

import jax
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return {"/".join(_key(k) for k in path): leaf for path, leaf in flat}, treedef


def _key(k):
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return str(k.idx)
    return str(k)


def _spec_to_json(spec):
    if spec is None:
        return None

    def enc(e):
        if e is None:
            return None
        if isinstance(e, (tuple, list)):
            return list(e)
        return e

    return [enc(e) for e in spec]


def _spec_from_json(js):
    if js is None:
        return P()
    return P(*[tuple(e) if isinstance(e, list) else e for e in js])


def save_checkpoint(path: str, tree, step: int, specs=None, extra: dict | None = None):
    """Synchronous save. `specs`: optional PartitionSpec pytree (recorded for
    restore-time sharding; restore can also override)."""
    out_dir = os.path.join(path, f"step_{step:08d}")
    os.makedirs(out_dir, exist_ok=True)
    leaves, _ = _flatten(tree)
    spec_leaves = _flatten(specs)[0] if specs is not None else {}
    manifest = {
        "step": step,
        "extra": extra or {},
        "leaves": {
            k: {
                "shape": list(np.shape(v)),
                "dtype": str(np.asarray(jax.device_get(v)).dtype),
                "spec": _spec_to_json(spec_leaves.get(k)),
            }
            for k, v in leaves.items()
        },
    }
    arrays = {k: np.asarray(jax.device_get(v)) for k, v in leaves.items()}
    np.savez(os.path.join(out_dir, "arrays.npz"), **arrays)
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    # atomic-ish completion marker (crash-consistent restore)
    with open(os.path.join(out_dir, "COMMITTED"), "w") as f:
        f.write("ok")
    return out_dir


def latest_step(path: str):
    if not os.path.isdir(path):
        return None
    steps = []
    for d in os.listdir(path):
        if d.startswith("step_") and os.path.exists(os.path.join(path, d, "COMMITTED")):
            steps.append(int(d.split("_")[1]))
    return max(steps) if steps else None


def load_checkpoint(path: str, template, step: int | None = None, mesh=None, specs=None):
    """Restore into `template`'s structure. If mesh given, device_put each leaf
    with its (manifest or override) spec — elastic resharding is just this."""
    step = latest_step(path) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no committed checkpoint under {path}")
    in_dir = os.path.join(path, f"step_{step:08d}")
    with open(os.path.join(in_dir, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(in_dir, "arrays.npz"))

    leaves, _ = _flatten(template)
    spec_leaves = _flatten(specs)[0] if specs is not None else {}
    out = {}
    for k, tmpl in leaves.items():
        arr = data[k]
        want_dtype = np.asarray(tmpl).dtype if not hasattr(tmpl, "dtype") else tmpl.dtype
        arr = arr.astype(want_dtype)
        if mesh is not None:
            spec = spec_leaves.get(k)
            if spec is None:
                spec = _spec_from_json(manifest["leaves"][k]["spec"])
            out[k] = jax.device_put(arr, NamedSharding(mesh, spec))
        else:
            out[k] = jax.numpy.asarray(arr)
    # rebuild tree
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    ordered = ["/".join(_key(kk) for kk in path) for path, _ in flat]
    return jax.tree_util.tree_unflatten(treedef, [out[k] for k in ordered]), manifest


class CheckpointManager:
    """Async double-buffered writer + retention policy."""

    def __init__(self, path: str, keep: int = 3):
        self.path = path
        self.keep = keep
        self._thread: threading.Thread | None = None

    def save_async(self, tree, step: int, specs=None, extra=None):
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            save_checkpoint(self.path, host_tree, step, specs=specs, extra=extra)
            self._gc()

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(
            int(d.split("_")[1])
            for d in os.listdir(self.path)
            if d.startswith("step_") and os.path.exists(os.path.join(self.path, d, "COMMITTED"))
        )
        for s in steps[: -self.keep]:
            import shutil

            shutil.rmtree(os.path.join(self.path, f"step_{s:08d}"), ignore_errors=True)

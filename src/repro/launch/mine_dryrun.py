import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import.

"""Roofline dry-run for the paper's OWN workload: the distributed support-
count step at production scale (N=1M transactions x I=2048 items x K=64k
candidates) on the 16x16 mesh — the third hillclimb pair (§Perf).

Variants:
  paper_1d : the paper's decomposition — transactions row-sharded over ALL
             chips, candidates replicated (Hadoop map tasks are 1-D).
  ours_2d  : transactions over 'data', candidates over 'model' (2-D).
  ours_2d_blocked : + fused/blocked containment epilogue (no (N,K) int32
             intermediate — the jnp analogue of the Pallas kernel tiling).
"""

import argparse
import json


def run(variant: str, n=1 << 20, items=2048, k_cands=1 << 16):
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.core.apriori import AprioriConfig, make_count_step
    from repro.launch import hlo_analysis
    from repro.launch.mesh import make_production_mesh
    from repro.launch.roofline import roofline_terms

    mesh = make_production_mesh()
    if variant == "paper_1d":
        cfg = AprioriConfig(data_axes=("data", "model"), model_axis=None,
                            count_impl="jnp")
    elif variant == "ours_2d":
        cfg = AprioriConfig(data_axes=("data",), model_axis="model", count_impl="jnp")
    elif variant == "ours_2d_blocked":
        cfg = AprioriConfig(data_axes=("data",), model_axis="model",
                            count_impl="jnp_blocked")
    else:
        raise ValueError(variant)

    step = make_count_step(mesh, cfg)
    t_sds = jax.ShapeDtypeStruct((n, items), jnp.int8)
    c_sds = jax.ShapeDtypeStruct((k_cands, items), jnp.int8)
    l_sds = jax.ShapeDtypeStruct((k_cands,), jnp.int32)
    t_sh = NamedSharding(mesh, P(cfg.data_axes, None))
    c_sh = NamedSharding(mesh, P(cfg.model_axis, None))
    l_sh = NamedSharding(mesh, P(cfg.model_axis))
    lowered = jax.jit(step.__wrapped__ if hasattr(step, "__wrapped__") else step,
                      in_shardings=(t_sh, c_sh, l_sh)).lower(t_sds, c_sds, l_sds)
    compiled = lowered.compile()
    mem = compiled.memory_analysis()
    hlo = hlo_analysis.summarize(compiled.as_text())
    rl = roofline_terms(hlo["flops"], hlo["hbm_bytes"], hlo["collective_bytes"])
    model_flops = 2.0 * n * items * k_cands / 256
    return {
        "variant": variant,
        "temp_gb_per_dev": mem.temp_size_in_bytes / 1e9,
        "flops_per_dev": hlo["flops"],
        "hbm_per_dev": hlo["hbm_bytes"],
        "coll_per_dev": hlo["collective_bytes"],
        "compute_s": rl.compute_s,
        "memory_s": rl.memory_s,
        "collective_s": rl.collective_s,
        "dominant": rl.dominant,
        "useful_flops_ratio": model_flops / max(hlo["flops"], 1.0),
        "collective_counts": hlo["collective_counts"],
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--variant", default="all")
    ap.add_argument("--n", type=int, default=1 << 20)
    ap.add_argument("--k", type=int, default=1 << 16)
    ap.add_argument("--out", default="")
    args = ap.parse_args()
    variants = ["paper_1d", "ours_2d", "ours_2d_blocked"] if args.variant == "all" else [args.variant]
    recs = [run(v, n=args.n, k_cands=args.k) for v in variants]
    js = json.dumps(recs, indent=1)
    if args.out:
        with open(args.out, "w") as f:
            f.write(js)
    print(js)


if __name__ == "__main__":
    main()

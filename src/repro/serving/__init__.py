"""The Apriori serving stack: rulebook -> batch engine -> online gateway.

Public surface (DESIGN.md §8/§10/§12): compile/load a :class:`Rulebook`,
answer pre-assembled batches with :func:`recommend`, serve independent online
queries through a :class:`Gateway` (micro-batching, exact-basket cache,
live rulebook hot-swap, supervised dispatch worker — see
``distributed.supervisor``), or front N gateway replicas with a
:class:`Router` (consistent basket hashing, failover with bounded retries,
request deadlines, load shedding, coordinated two-phase hot-swap).
"""

from repro.serving.batcher import (
    AdmissionRejected,
    DeadlineExceeded,
    MicroBatcher,
    Request,
    WorkerCrashed,
)
from repro.serving.cache import BasketCache, basket_key
from repro.serving.gateway import Gateway, Response, pow2_bucket
from repro.serving.metrics import GatewayMetrics, LatencyHistogram, RouterMetrics
from repro.serving.refresh import RefreshController, RefreshMetrics
from repro.serving.router import HashRing, Router, RouterFaultInjection
from repro.serving.recommend import (
    RecommendResult,
    make_match_step,
    pack_baskets,
    recommend,
    recommend_python,
)
from repro.serving.rulebook import Rulebook, compile_rulebook, place_rulebook

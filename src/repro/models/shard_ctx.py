"""Trace-time activation-sharding context.

GSPMD propagates weight shardings well, but activation sharding at
ambiguity points (embedding gather output, per-block outputs, logits) can
resolve to full replication — at qwen-110B scale that is a ~1.5 TB/device
FFN hidden (measured; EXPERIMENTS.md §Perf iteration #3). The fix, as in
MaxText, is explicit ``with_sharding_constraint`` on every major activation.

Drivers (dryrun / trainer / server) install the mesh + logical axes here
before tracing; model code calls :func:`constrain` with a logical kind.
Without a context the calls are no-ops (single-device tests).
"""

from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

_TLS = threading.local()


@contextlib.contextmanager
def activation_sharding(mesh, dp_axes=("data",), tensor_axis="model"):
    prev = getattr(_TLS, "ctx", None)
    _TLS.ctx = {"mesh": mesh, "dp": tuple(dp_axes), "tensor": tensor_axis}
    try:
        yield
    finally:
        _TLS.ctx = prev


def _spec_for(kind: str, ndim: int, ctx) -> P:
    dp, tensor = ctx["dp"], ctx["tensor"]
    if kind == "hidden":        # (B, S, D) or (B, 1, D)
        return P(dp, *([None] * (ndim - 1)))
    if kind == "logits":        # (B, [S,] V): vocab over tensor axis
        return P(dp, *([None] * (ndim - 2)), tensor)
    if kind == "heads":         # (B, S, H, Dh): heads over tensor axis
        return P(dp, None, tensor, *([None] * (ndim - 3)))
    if kind == "w_in":          # (..., D_in, D_out): gather fsdp, keep TP out
        return P(*([None] * (ndim - 1)), tensor)
    if kind == "w_out":         # (..., D_contract(TP), D_out): gather fsdp
        return P(*([None] * (ndim - 2)), tensor, None)
    if kind == "expert_w":      # (E, D, F): experts stay sharded, D/F gathered
        return P(tensor, *([None] * (ndim - 1)))
    if kind == "moe_buf":       # (G, E, C, D): groups on dp, experts on tensor
        return P(dp, tensor, *([None] * (ndim - 2)))
    if kind == "expert_local":  # (E, C, D) inside a dp-manual region: EP only
        return P(tensor, *([None] * (ndim - 1)))
    return P(*([None] * ndim))


def current():
    return getattr(_TLS, "ctx", None)


def constrain(x, kind: str):
    ctx = getattr(_TLS, "ctx", None)
    if ctx is None:
        return x
    mesh = ctx["mesh"]
    # inside a shard_map manual region, constraints must be expressed on the
    # current abstract mesh (manual axes marked); outside it this is a no-op
    try:
        am = jax.sharding.get_abstract_mesh()
        if am is not None and not am.empty and any(
            t == jax.sharding.AxisType.Manual for t in getattr(am, "axis_types", ())
        ):
            mesh = am
    except Exception:
        pass
    spec = _spec_for(kind, x.ndim, ctx)
    # divisibility guards: drop any entry whose dim doesn't divide its axes
    sizes = {a: mesh.shape[a] for a in mesh.axis_names}
    fixed = []
    for dim, entry in zip(x.shape, spec):
        if entry is None:
            fixed.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        total = 1
        for a in axes:
            total *= sizes[a]
        fixed.append(entry if dim % total == 0 else None)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*fixed)))


def weight_use(w, out_side: bool = False):
    """Gather-at-use for FSDP-sharded weights (ZeRO-3 semantics): without
    this, GSPMD may instead all-reduce the (much larger) activations over the
    fsdp axis — measured 1.1e12 B/dev on dbrx prefill (perf iteration #5)."""
    if w.ndim < 2:
        return w
    return constrain(w, "w_out" if out_side else "w_in")


def expert_weight_use(w):
    return constrain(w, "expert_w")

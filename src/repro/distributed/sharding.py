"""Logical-axis sharding rules (FSDP x TP x pod-DP) for every arch family.

Rules operate on tree paths so they survive any stacking depth: a weight
(…, D_in, D_out) shards (fsdp, tensor); 'output-side' projections (wo,
out_proj, cm/wv) shard (tensor, fsdp) so the contraction dim is the sharded
one; experts shard E over the tensor axis (expert parallelism); vectors and
tiny adapters replicate. Dry-run meshes: ("data","model") and
("pod","data","model") — fsdp = "data", tensor = "model", dp = ("pod","data").
"""

from __future__ import annotations

import dataclasses
import re

import jax
import numpy as np
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    fsdp_axis: str = "data"
    tensor_axis: str = "model"
    dp_axes: tuple = ("data",)          # batch axes; multi-pod: ("pod","data")

    def fsdp(self, dim: int, mesh) -> str | None:
        return self.fsdp_axis if dim % mesh.shape[self.fsdp_axis] == 0 else None

    def tensor(self, dim: int, mesh) -> str | None:
        return self.tensor_axis if dim % mesh.shape[self.tensor_axis] == 0 else None


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


# matrices whose SECOND-to-last dim is the output/tensor dim (contract sharded)
_OUT_SIDE = re.compile(r"(wo|out_proj)$")
# MoE expert tensors: (E, D, F) / (E, F, D)
_EXPERT = re.compile(r"mlp/(wi|wo)$")
# embedding / head
_EMBED = re.compile(r"embed/table$")
_HEAD = re.compile(r"head$")


def param_pspecs(params, mesh, rules: ShardingRules = ShardingRules()):
    """PartitionSpec pytree for a model/optimizer parameter pytree."""

    def spec_for(path, leaf):
        name = _path_str(path)
        shape = leaf.shape
        nd = len(shape)
        if nd <= 1:
            return P()  # norms, biases, scalars: replicate
        lead = nd - 2  # stacking dims (L, or G,E for zamba/mamba groups)
        d_in, d_out = shape[-2], shape[-1]

        if _EXPERT.search(name) and nd >= 3:
            # (..., E, D, F): experts over tensor axis, d-side over fsdp
            e_dim = shape[-3]
            e_ax = rules.tensor(e_dim, mesh)
            if name.endswith("wi"):
                return P(*([None] * (nd - 3)), e_ax, rules.fsdp(d_in, mesh), None)
            return P(*([None] * (nd - 3)), e_ax, None, rules.fsdp(d_out, mesh))
        if _EMBED.search(name):
            return P(rules.tensor(d_in, mesh), rules.fsdp(d_out, mesh))  # (V, D)
        if _HEAD.search(name):
            return P(rules.fsdp(d_in, mesh), rules.tensor(d_out, mesh))  # (D, V)
        if _OUT_SIDE.search(name):
            return P(*([None] * lead), rules.tensor(d_in, mesh), rules.fsdp(d_out, mesh))
        # default 'input-side' matrix (D_in, D_out_parallel)
        return P(*([None] * lead), rules.fsdp(d_in, mesh), rules.tensor(d_out, mesh))

    return jax.tree_util.tree_map_with_path(spec_for, params)


def state_pspecs(state_tree, params_specs):
    """Optimizer state mirrors parameter sharding; scalars replicate."""

    def spec(path, leaf):
        return P()

    # state = {"m": params-like, "v": params-like, "step": scalar}
    return {
        "m": params_specs,
        "v": jax.tree.map(lambda s: s, params_specs),
        "step": P(),
    }


def batch_pspec(batch, rules: ShardingRules = ShardingRules()):
    """Shard leading (global-batch) dim over the dp axes."""
    return jax.tree.map(lambda x: P(rules.dp_axes, *([None] * (np.ndim(x) - 1))), batch)


def _divisible_axis(mesh, rules, *dims):
    """First cache dim divisible by the tensor axis size, else None."""
    t = mesh.shape[rules.tensor_axis]
    for i, d in enumerate(dims):
        if d % t == 0:
            return i
    return None


def cache_pspecs(cache, mesh, rules: ShardingRules = ShardingRules(), batch: int = 1):
    """Decode-cache shardings. Batch dim shards over dp axes when divisible;
    the head/state dim shards over the tensor axis with a fallback chain
    (KVH -> Dh -> S for KV caches; H for SSM/RWKV states; C for conv/shift)."""
    dp = rules.dp_axes
    n_dp = int(np.prod([mesh.shape[a] for a in dp]))
    t_ax = rules.tensor_axis
    t = mesh.shape[t_ax]

    def spec_for(path, leaf):
        name = _path_str(path)
        shape = leaf.shape
        nd = len(shape)
        b_ax = dp if (batch % n_dp == 0) else None
        tail = name.rsplit("/", 1)[-1]
        # identify the batch dim: first dim equal to `batch` after stack dims
        try:
            b_idx = next(i for i, d in enumerate(shape) if d == batch)
        except StopIteration:
            b_idx = None
        spec = [None] * nd
        if b_idx is not None and b_ax is not None:
            spec[b_idx] = dp
        if tail in ("k", "v"):  # (..., B, S, KVH, Dh)
            kvh, dh = shape[-2], shape[-1]
            s_len = shape[-3]
            if kvh % t == 0:
                spec[nd - 2] = t_ax
            elif dh % t == 0:
                spec[nd - 1] = t_ax
            elif s_len % t == 0:
                spec[nd - 3] = t_ax
        elif tail == "c_kv":  # (..., B, S, R): sequence-shard, R contract-partial
            if shape[-2] % t == 0:
                spec[nd - 2] = t_ax
        elif tail == "k_rope":  # small shared-rope cache: sequence-shard
            if shape[-2] % t == 0:
                spec[nd - 2] = t_ax
        elif tail == "ssm":  # (..., B, H, N, P)
            if shape[-3] % t == 0:
                spec[nd - 3] = t_ax
        elif tail == "wkv":  # (..., B, H, K, V)
            if shape[-3] % t == 0:
                spec[nd - 3] = t_ax
        elif tail in ("conv", "tm_shift", "cm_shift"):  # channel-sharded
            if shape[-1] % t == 0:
                spec[nd - 1] = t_ax
        return P(*spec)

    return jax.tree_util.tree_map_with_path(spec_for, cache)

"""Static analyzer for compiled HLO text: FLOPs, HBM-traffic estimate and
collective bytes, with while-loop bodies multiplied by their trip counts.

Why this exists: XLA's ``compiled.cost_analysis()`` counts a while body ONCE —
under any scanned count step (chunked candidate passes, level loops) that
underestimates FLOPs by the trip count.  The compiled text however carries
``backend_config={"known_trip_count":{"n":...}}`` on every scan-derived while,
so an exact static walk is possible:

  flops       = Σ dots 2·|result|·(contracted dims)       [× trip counts]
  hbm_bytes   = Σ top-level ops (operands + result bytes) [× trip counts]
                (fusions count as one op: internals never touch HBM — this is
                 precisely the TPU fusion-boundary traffic model)
  coll_bytes  = Σ collective ops' operand bytes           [× trip counts]

All numbers are PER DEVICE (the HLO module is the SPMD-partitioned program).
"""

from __future__ import annotations

import dataclasses
import json
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_INSTR_RE = re.compile(r"^\s*(ROOT\s+)?%([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$")
_COMP_HDR = re.compile(r"^(ENTRY\s+)?%([\w.\-]+)\s+\(.*\)\s*->.*\{")
_CALLED = re.compile(r"(?:calls=|condition=|body=|to_apply=|true_computation=|false_computation=)%([\w.\-]+)")
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_OPERAND = re.compile(r"%([\w.\-]+)")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute")

# ops that don't move HBM bytes (layout/meta only)
_FREE_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
}


def _shape_bytes(type_str: str) -> float:
    total = 0.0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _shape_elems_and_dims(type_str: str):
    m = _SHAPE_RE.search(type_str)
    if not m:
        return 0, []
    dims = [int(d) for d in m.group(2).split(",")] if m.group(2) else []
    n = 1
    for d in dims:
        n *= d
    return n, dims


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    rest: str            # raw text after the opening paren (operands + attrs)
    operands: list
    called: list
    trip: int | None


def parse_hlo(text: str):
    """-> dict comp_name -> (list[Instr], is_entry)."""
    comps = {}
    cur_name, cur_list, is_entry = None, None, False
    for line in text.splitlines():
        hdr = _COMP_HDR.match(line)
        if hdr:
            cur_name = hdr.group(2)
            cur_list = []
            is_entry = bool(hdr.group(1))
            comps[cur_name] = (cur_list, is_entry)
            continue
        if cur_list is None:
            continue
        if line.strip() == "}":
            cur_name, cur_list = None, None
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        _, name, type_str, opcode, rest = m.groups()
        # operand section: up to the matching close paren at depth 0
        depth, idx = 1, 0
        for idx, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        operand_str, attrs = rest[:idx], rest[idx + 1 :]
        operands = _OPERAND.findall(operand_str)
        called = _CALLED.findall(attrs)
        tm = _TRIP.search(attrs)
        cur_list.append(
            Instr(name, type_str, opcode, rest, operands, called, int(tm.group(1)) if tm else None)
        )
    return comps


def _dot_flops(instr: Instr, symtab) -> float:
    out_elems, _ = _shape_elems_and_dims(instr.type_str)
    lhs = instr.operands[0] if instr.operands else None
    lhs_type = symtab.get(lhs, "")
    _, lhs_dims = _shape_elems_and_dims(lhs_type)
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", instr.rest)
    contracted = 1
    if m and m.group(1):
        for i in m.group(1).split(","):
            if int(i) < len(lhs_dims):
                contracted *= lhs_dims[int(i)]
    return 2.0 * out_elems * contracted


@dataclasses.dataclass
class HloCosts:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_counts: dict = dataclasses.field(default_factory=dict)
    collective_by_type: dict = dataclasses.field(default_factory=dict)

    def scaled(self, k: float) -> "HloCosts":
        return HloCosts(
            self.flops * k,
            self.hbm_bytes * k,
            self.collective_bytes * k,
            {o: c * k for o, c in self.collective_counts.items()},
            {o: b * k for o, b in self.collective_by_type.items()},
        )

    def __iadd__(self, o: "HloCosts"):
        self.flops += o.flops
        self.hbm_bytes += o.hbm_bytes
        self.collective_bytes += o.collective_bytes
        for k, v in o.collective_counts.items():
            self.collective_counts[k] = self.collective_counts.get(k, 0) + v
        for k, v in o.collective_by_type.items():
            self.collective_by_type[k] = self.collective_by_type.get(k, 0) + v
        return self


def analyze(text: str) -> HloCosts:
    comps = parse_hlo(text)
    entry = next((n for n, (_, e) in comps.items() if e), None)
    if entry is None:
        return HloCosts()
    memo: dict[str, HloCosts] = {}

    def comp_cost(name: str) -> HloCosts:
        if name in memo:
            return memo[name]
        memo[name] = HloCosts()  # cycle guard
        instrs, _ = comps.get(name, ([], False))
        symtab = {i.name: i.type_str for i in instrs}
        total = HloCosts()
        for ins in instrs:
            op = ins.opcode
            if op == "while":
                trip = ins.trip if ins.trip is not None else 1
                body = next((c for c in ins.called), None)
                for c in ins.called:  # body + cond both iterate
                    total += comp_cost(c).scaled(trip)
                continue
            if op in ("fusion", "call", "conditional", "async-start"):
                for c in ins.called:
                    sub = comp_cost(c)
                    # count inner FLOPs/collectives, but NOT inner hbm bytes:
                    # fusion internals live in registers/VMEM, only the
                    # boundary moves HBM traffic.
                    total.flops += sub.flops
                    total.collective_bytes += sub.collective_bytes
                    for k, v in sub.collective_counts.items():
                        total.collective_counts[k] = total.collective_counts.get(k, 0) + v
                    for k, v in sub.collective_by_type.items():
                        total.collective_by_type[k] = total.collective_by_type.get(k, 0) + v
                opnd_bytes = sum(_shape_bytes(symtab.get(o, "")) for o in ins.operands)
                total.hbm_bytes += opnd_bytes + _shape_bytes(ins.type_str)
                continue
            if op == "dot":
                total.flops += _dot_flops(ins, symtab)
            if op == "convolution":
                # rough: 2 * |out| * sqrt(kernel elems) — the mining count
                # steps contain no convolutions, so this path is a fallback
                # for foreign modules only
                out_elems, _ = _shape_elems_and_dims(ins.type_str)
                k_elems, _ = _shape_elems_and_dims(symtab.get(ins.operands[1], "")) if len(ins.operands) > 1 else (1, [])
                total.flops += 2.0 * out_elems * max(1, k_elems) ** 0.5
            if op in COLLECTIVES or any(op.startswith(c + "-start") for c in COLLECTIVES):
                base = op.replace("-start", "")
                opnd_bytes = sum(_shape_bytes(symtab.get(o, "")) for o in ins.operands)
                total.collective_bytes += opnd_bytes
                total.collective_counts[base] = total.collective_counts.get(base, 0) + 1
                total.collective_by_type[base] = total.collective_by_type.get(base, 0) + opnd_bytes
            if op not in _FREE_OPS:
                opnd_bytes = sum(_shape_bytes(symtab.get(o, "")) for o in ins.operands)
                total.hbm_bytes += opnd_bytes + _shape_bytes(ins.type_str)
        memo[name] = total
        return total

    return comp_cost(entry)


def summarize(text: str) -> dict:
    c = analyze(text)
    return {
        "flops": c.flops,
        "hbm_bytes": c.hbm_bytes,
        "collective_bytes": c.collective_bytes,
        "collective_counts": dict(c.collective_counts),
        "collective_by_type": dict(c.collective_by_type),
    }

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks device count on first init).

"""Multi-pod dry-run: .lower().compile() for every (arch × shape × mesh) cell.

Per cell: build ShapeDtypeStruct inputs + shardings (launch.specs), jit the
step (train_step / prefill / decode) with explicit in/out shardings + donation,
compile for the 16x16 single-pod and (2,16,16) multi-pod mesh, then record:
  - memory_analysis()          (fits-on-chip proof: args/temps/aliasing)
  - cost_analysis()            (raw XLA numbers — scan bodies counted once)
  - hlo_analysis.summarize()   (trip-count-corrected flops / bytes / collectives)
  - roofline terms             (launch.roofline; EXPERIMENTS.md §Roofline)

Usage:
  python -m repro.launch.dryrun --arch qwen1p5_110b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --out experiments/dryrun   (subprocess per cell)
"""

import argparse
import dataclasses
import json
import subprocess
import sys
import time
import traceback


def lower_cell(arch_id: str, shape_name: str, multi_pod: bool) -> dict:
    import jax

    from repro.configs import get_config
    from repro.configs.shapes import SHAPES, is_skipped
    from repro.launch import hlo_analysis
    from repro.launch.mesh import make_production_mesh
    from repro.launch.roofline import count_params, model_flops, roofline_terms
    from repro.launch.specs import arch_for_mesh, cell_shardings, rules_for
    from repro.models.shard_ctx import activation_sharding
    from repro.models.transformer import decode_step, prefill_step
    from repro.training.optimizer import AdamWConfig
    from repro.training.train_loop import build_train_step

    cfg = get_config(arch_id)
    if is_skipped(cfg, shape_name):
        return {
            "arch": arch_id, "shape": shape_name, "mesh": "multi" if multi_pod else "single",
            "status": "skipped",
            "reason": "long_500k reserved for sub-quadratic families (DESIGN.md §4)",
        }
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = len(mesh.devices.ravel())
    cfg = arch_for_mesh(cfg, mesh)
    cell = cell_shardings(cfg, shape_name, mesh)
    kind = cell["kind"]
    rules = rules_for(mesh)
    act_ctx = activation_sharding(mesh, dp_axes=rules.dp_axes, tensor_axis=rules.tensor_axis)

    t0 = time.time()
    with act_ctx:
        lowered = _lower(kind, cfg, cell, shape)
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    return _record(arch_id, shape_name, multi_pod, chips, kind, cfg, shape,
                   compiled, t_lower, t_compile)


def _lower(kind, cfg, cell, shape):
    import jax

    from repro.models.transformer import decode_step, prefill_step
    from repro.training.optimizer import AdamWConfig
    from repro.training.train_loop import build_train_step

    if kind == "train":
        step = build_train_step(cfg, AdamWConfig())
        jitted = jax.jit(
            step,
            in_shardings=(cell["state_sh"], cell["batch_sh"]),
            out_shardings=(cell["state_sh"], None),
            donate_argnums=(0,),
        )
        lowered = jitted.lower(cell["state_sds"], cell["batch_sds"])
    elif kind == "prefill":
        cache_len = shape["seq_len"]

        def pf(params, batch):
            return prefill_step(params, cfg, batch, cache_len)

        jitted = jax.jit(
            pf,
            in_shardings=(cell["params_sh"], cell["batch_sh"]),
            out_shardings=(None, cell["cache_sh"]),
        )
        lowered = jitted.lower(cell["params_sds"], cell["batch_sds"])
    else:  # decode

        def dec(params, cache, tokens, pos):
            return decode_step(params, cfg, cache, tokens, pos)

        jitted = jax.jit(
            dec,
            in_shardings=(
                cell["params_sh"],
                cell["cache_sh"],
                cell["batch_sh"]["tokens"],
                cell["batch_sh"]["pos"],
            ),
            out_shardings=(None, cell["cache_sh"]),
            donate_argnums=(1,),
        )
        lowered = jitted.lower(
            cell["params_sds"], cell["cache_sds"],
            cell["batch_sds"]["tokens"], cell["batch_sds"]["pos"],
        )
    return lowered


def _record(arch_id, shape_name, multi_pod, chips, kind, cfg, shape, compiled, t_lower, t_compile):
    from repro.launch import hlo_analysis
    from repro.launch.roofline import count_params, model_flops, roofline_terms

    mem = compiled.memory_analysis()
    try:
        cost = compiled.cost_analysis()
        if isinstance(cost, list):
            cost = cost[0]
        cost = {k: float(v) for k, v in cost.items() if k in ("flops", "bytes accessed")}
    except Exception as e:  # pragma: no cover
        cost = {"error": str(e)}

    text = compiled.as_text()
    hlo = hlo_analysis.summarize(text)
    del text

    mf = model_flops(cfg, shape)
    rl = roofline_terms(hlo["flops"], hlo["hbm_bytes"], hlo["collective_bytes"])
    params_count = count_params(cfg)

    return {
        "arch": arch_id,
        "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "chips": chips,
        "status": "ok",
        "kind": kind,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes_per_dev": mem.argument_size_in_bytes,
            "output_bytes_per_dev": mem.output_size_in_bytes,
            "temp_bytes_per_dev": mem.temp_size_in_bytes,
            "alias_bytes_per_dev": mem.alias_size_in_bytes,
        },
        "xla_cost_raw": cost,
        "hlo": hlo,
        "params": params_count,
        "model_flops_global": mf,
        "model_flops_per_dev": mf / chips,
        "useful_flops_ratio": (mf / chips) / max(hlo["flops"], 1.0),
        "roofline": {
            "compute_s": rl.compute_s,
            "memory_s": rl.memory_s,
            "collective_s": rl.collective_s,
            "dominant": rl.dominant,
            "bound_s": rl.bound_s,
            "compute_fraction": rl.compute_fraction,
        },
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true", help="run every cell in subprocesses")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--timeout", type=int, default=3600)
    args = ap.parse_args()

    if args.all:
        from repro.configs import ARCH_IDS
        from repro.configs.shapes import SHAPES

        os.makedirs(args.out, exist_ok=True)
        cells = [
            (a, s, m)
            for a in ARCH_IDS
            if a != "apriori"
            for s in SHAPES
            for m in (["single", "multi"] if args.mesh == "both" else [args.mesh])
        ]
        failures = 0
        for arch, shp, mesh_kind in cells:
            out_file = os.path.join(args.out, f"{arch}--{shp}--{mesh_kind}.json")
            if os.path.exists(out_file):
                print(f"[skip-cached] {arch} {shp} {mesh_kind}")
                continue
            cmd = [
                sys.executable, "-m", "repro.launch.dryrun",
                "--arch", arch, "--shape", shp, "--mesh", mesh_kind, "--out", out_file,
            ]
            t0 = time.time()
            proc = subprocess.run(cmd, capture_output=True, text=True, timeout=args.timeout)
            status = "OK" if proc.returncode == 0 else "FAIL"
            if proc.returncode != 0:
                failures += 1
                with open(out_file, "w") as f:
                    json.dump({"arch": arch, "shape": shp, "mesh": mesh_kind,
                               "status": "error", "stderr": proc.stderr[-4000:]}, f, indent=1)
            print(f"[{status}] {arch} {shp} {mesh_kind}  ({time.time()-t0:.0f}s)")
        print(f"done; {failures} failures")
        sys.exit(1 if failures else 0)

    rec = lower_cell(args.arch, args.shape, args.mesh == "multi")
    js = json.dumps(rec, indent=1)
    if args.out and args.out.endswith(".json"):
        with open(args.out, "w") as f:
            f.write(js)
    print(js)


if __name__ == "__main__":
    main()

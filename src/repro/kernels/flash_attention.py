"""Pallas TPU flash attention (causal, GQA-aware) — the LM stack's
perf-critical attention contraction for TPU targets; the pure-JAX
``models.attention.chunked_attention`` is the same dataflow and serves as the
CPU/dry-run path.

Dataflow per (batch·head, q-block): stream KV blocks through VMEM with the
online-softmax running (m, l, acc) carried in scratch; logits never touch
HBM. Grid = (B·H, Sq/bq, Sk/bk), Sk innermost. GQA is handled in the K/V
BlockSpec index maps (kv head = q head // group), so no repeated KV in HBM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale, causal, block_q, block_k, seq_kv):
    i = pl.program_id(1)
    j = pl.program_id(2)
    nj = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32) * scale          # (bq, d)
    k = k_ref[0].astype(jnp.float32)                  # (bk, d)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (bq, bk)

    kpos = j * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = kpos < seq_kv
    if causal:
        qpos = i * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        mask &= qpos >= kpos
    s = jnp.where(mask, s, _NEG)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + p.sum(-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jnp.dot(
        p.astype(v_ref.dtype), v_ref[0], preferred_element_type=jnp.float32
    )
    m_scr[...] = m_new

    @pl.when(j == nj - 1)
    def _finish():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "block_q", "block_k", "interpret")
)
def flash_attention_pallas(q, k, v, *, causal: bool = True,
                           block_q: int = 512, block_k: int = 512,
                           interpret: bool = False):
    """q: (B, Sq, H, D); k/v: (B, Skv, KVH, D) -> (B, Sq, H, D)."""
    b, sq, h, d = q.shape
    _, skv, kvh, _ = k.shape
    group = h // kvh
    scale = d ** -0.5

    block_q = min(block_q, max(8, sq))
    block_k = min(block_k, max(128, skv))
    pq, pk = (-sq) % block_q, (-skv) % block_k
    qf = jnp.moveaxis(jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0))), 2, 1
                      ).reshape(b * h, sq + pq, d)
    kf = jnp.moveaxis(jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0))), 2, 1
                      ).reshape(b * kvh, skv + pk, d)
    vf = jnp.moveaxis(jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0))), 2, 1
                      ).reshape(b * kvh, skv + pk, d)

    def kv_index(bh, i, j):
        return (bh // h) * kvh + (bh % h) // group, j, 0

    grid = (b * h, (sq + pq) // block_q, (skv + pk) // block_k)
    out = pl.pallas_call(
        functools.partial(
            _kernel, scale=scale, causal=causal,
            block_q=block_q, block_k=block_k, seq_kv=skv,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, i, j: (bh, i, 0)),
            pl.BlockSpec((1, block_k, d), kv_index),
            pl.BlockSpec((1, block_k, d), kv_index),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda bh, i, j: (bh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq + pq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return jnp.moveaxis(out.reshape(b, h, sq + pq, d), 1, 2)[:, :sq]

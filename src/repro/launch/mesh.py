"""Production mesh factory. A FUNCTION (not a module-level constant) so that
importing this module never touches jax device state."""

from __future__ import annotations

import jax


def make_auto_mesh(shape, axes):
    """``jax.make_mesh`` with Auto axis types where supported.

    jax >= 0.5 takes ``axis_types``; jax 0.4 has neither ``AxisType`` nor the
    kwarg (all axes behave as Auto there). The single version-portable mesh
    entry point for launch scripts, tests and benches.
    """
    try:
        from jax.sharding import AxisType
    except ImportError:  # jax < 0.5
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    """v5e pod slice: 16x16 = 256 chips ("data","model"); multi-pod prepends a
    2-pod DCN axis (2,16,16) = 512 chips ("pod","data","model")."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_auto_mesh(shape, axes)


def make_host_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh over host (CPU) devices for tests/benches."""
    return make_auto_mesh(shape, axes)

"""Supervisor restart/elastic re-mesh + straggler backup-task simulation."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.distributed.fault_tolerance import (
    SimulatedFailure,
    Supervisor,
    run_with_backup_tasks,
)
from repro.training.optimizer import AdamWConfig
from repro.training.train_loop import build_train_step, init_train_state


def _batch_fn(cfg, b=4, s=16):
    def fn(step):
        rng = np.random.default_rng(step)
        toks = rng.integers(0, cfg.vocab_size, (b, s + 1))
        return {
            "tokens": jnp.asarray(toks[:, :-1], jnp.int32),
            "labels": jnp.asarray(toks[:, 1:], jnp.int32),
        }

    return fn


def test_supervisor_restarts_after_failure(tmp_path):
    cfg = get_config("deepseek_coder_33b").reduced()
    opt = AdamWConfig(peak_lr=1e-3)

    def make_mesh(n_nodes):
        return None  # single-device CPU run; elasticity exercised in subprocess tests

    def rebuild(mesh, state):
        return jax.jit(build_train_step(cfg, opt), donate_argnums=())

    killed = {"done": False}

    def injector(step):
        if step == 7 and not killed["done"]:
            killed["done"] = True
            raise SimulatedFailure(lost_nodes=1)

    sup = Supervisor(str(tmp_path), make_mesh, rebuild, checkpoint_every=5)
    state = init_train_state(jax.random.key(0), cfg)
    state, history, info = sup.run(
        state, None, _batch_fn(cfg), num_steps=12, num_nodes=4, failure_injector=injector
    )
    assert info["restarts"] == 1
    assert info["final_nodes"] == 3  # elastic shrink recorded
    assert int(jax.device_get(state["opt"]["step"])) == 12
    assert killed["done"]


def test_supervisor_resume_matches_uninterrupted(tmp_path):
    """Failure + restore from checkpoint reproduces the uninterrupted run
    exactly (deterministic data stream keyed by step count)."""
    cfg = get_config("deepseek_coder_33b").reduced()
    opt = AdamWConfig(peak_lr=1e-3)

    def rebuild(mesh, state):
        return jax.jit(build_train_step(cfg, opt), donate_argnums=())

    base = init_train_state(jax.random.key(0), cfg)

    sup_a = Supervisor(str(tmp_path / "a"), lambda n: None, rebuild, checkpoint_every=5)
    clean, _, _ = sup_a.run(
        jax.tree.map(jnp.copy, base), None, _batch_fn(cfg), num_steps=10, num_nodes=2
    )

    def injector(step):
        if step == 6 and not getattr(injector, "hit", False):
            injector.hit = True
            raise SimulatedFailure()

    sup_b = Supervisor(str(tmp_path / "b"), lambda n: None, rebuild, checkpoint_every=5)
    failed, _, info = sup_b.run(
        jax.tree.map(jnp.copy, base), None, _batch_fn(cfg), num_steps=10, num_nodes=2,
        failure_injector=injector,
    )
    assert info["restarts"] == 1
    for a, b in zip(jax.tree.leaves(clean["params"]), jax.tree.leaves(failed["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_backup_tasks_cut_straggler_makespan():
    """Paper Fig 4: heterogeneous cluster (FHDSC) pays the slow node;
    speculative backups recover most of the gap to homogeneous (FHSSC)."""
    rng = np.random.default_rng(0)
    shards = [rng.integers(0, 2, size=(rng.integers(500, 1500), 16)).astype(np.int8) for _ in range(32)]

    def worker(shard):
        return shard.sum()

    homo = [1.0] * 4
    hetero = [1.0, 1.0, 1.0, 0.25]  # one 4x-slower node

    res_h, t_homo = run_with_backup_tasks(shards, worker, homo, backup=False)
    res_n, t_no_backup = run_with_backup_tasks(shards, worker, hetero, backup=False)
    res_b, t_backup = run_with_backup_tasks(shards, worker, hetero, backup=True)

    # correctness is identical regardless of scheduling
    assert [int(x) for x in res_h] == [int(x) for x in res_n] == [int(x) for x in res_b]
    assert t_no_backup > t_homo  # the paper's FHDSC penalty
    assert t_backup < t_no_backup  # speculation recovers part of it


def test_mining_checkpoint_resume(tmp_path, small_db):
    """Level-wise mining checkpoint: kill at level 2, resume, identical output
    (the Supervisor pattern applied to the paper's own workload)."""
    from repro.core.apriori import AprioriConfig, mine

    cfg = AprioriConfig(min_support=0.08, max_k=5, count_impl="jnp")
    full = mine(small_db, cfg)

    import numpy as _np

    saved = {}

    class Boom(Exception):
        pass

    def cb(k, levels):
        saved["levels"] = {kk: (s.copy(), p.copy()) for kk, (s, p) in levels.items()}
        saved["next_k"] = k + 1
        if k == 2:
            raise Boom

    try:
        mine(small_db, cfg, checkpoint_cb=cb)
    except Boom:
        pass
    resumed = mine(small_db, cfg, resume_state=saved)
    assert resumed.as_dict() == full.as_dict()

"""Numerical-equivalence tests between the optimized (chunked / parallel)
forms and their exact sequential oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels.ref import flash_attention_ref
from repro.models.attention import chunked_attention
from repro.models.mamba2 import mamba2_apply, mamba2_apply_naive, mamba2_init


@pytest.mark.parametrize("sq,sk,h,kvh", [(16, 16, 4, 4), (32, 32, 8, 2), (7, 19, 6, 3)])
def test_chunked_attention_vs_ref(sq, sk, h, kvh):
    rng = np.random.default_rng(sq * sk)
    q = jnp.asarray(rng.standard_normal((2, sq, h, 16)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, sk, kvh, 16)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, sk, kvh, 16)), jnp.float32)
    got = chunked_attention(q, k, v, causal=True, block_k=8, q_offset=sk - sq)
    want = flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("block_k", [4, 16, 64])
def test_chunked_attention_block_invariance(block_k):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((1, 24, 4, 8)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 24, 4, 8)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 24, 4, 8)), jnp.float32)
    base = chunked_attention(q, k, v, causal=True, block_k=24)
    got = chunked_attention(q, k, v, causal=True, block_k=block_k)
    np.testing.assert_allclose(np.asarray(got), np.asarray(base), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("seq,chunk", [(32, 8), (64, 16), (24, 8)])
def test_mamba2_chunked_vs_naive(seq, chunk):
    """SSD chunk decomposition == exact per-step recurrence."""
    cfg = get_config("zamba2_2p7b").reduced()
    cfg = cfg.__class__(**{**cfg.__dict__, "ssm": cfg.ssm.__class__(
        state_dim=16, head_dim=16, chunk=chunk)})
    p = mamba2_init(jax.random.key(0), cfg)
    rng = np.random.default_rng(seq)
    x = jnp.asarray(rng.standard_normal((2, seq, cfg.d_model)) * 0.5, jnp.float32)
    fast = mamba2_apply(p, x, cfg)
    slow = mamba2_apply_naive(p, x, cfg)
    np.testing.assert_allclose(np.asarray(fast), np.asarray(slow), rtol=2e-4, atol=2e-4)


def test_moe_routing_properties():
    """Top-k gates normalized; dead (padded) experts never routed; output finite."""
    from repro.models.moe import moe_apply, moe_init, _router_probs

    cfg = get_config("granite_moe_3b_a800m").reduced()
    p = moe_init(jax.random.key(0), cfg)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((2, 16, cfg.d_model)), jnp.float32)
    out, aux = moe_apply(p, x, cfg)
    assert out.shape == x.shape and np.isfinite(np.asarray(out)).all()
    assert 0.0 < float(aux) < 10.0
    probs = _router_probs(p, x.reshape(-1, cfg.d_model), cfg)
    dead = np.asarray(probs)[:, cfg.moe.num_experts :]
    assert (dead == 0).all(), "padded experts must receive zero probability"


def test_moe_capacity_drops_are_bounded():
    """With capacity_factor >= E/top_k (capacity >= T), nothing drops and the
    MoE output equals the dense mixture of top-k experts."""
    from repro.models.config import MoEConfig
    from repro.models.moe import moe_apply, moe_init, _router_probs

    cfg = get_config("dbrx_132b").reduced()
    big_cap = MoEConfig(num_experts=4, top_k=2, d_ff_expert=64, capacity_factor=2.0)
    cfg = cfg.__class__(**{**cfg.__dict__, "moe": big_cap})
    p = moe_init(jax.random.key(1), cfg)
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((1, 8, cfg.d_model)), jnp.float32)
    out, _ = moe_apply(p, x, cfg)

    # dense oracle
    x2d = x.reshape(-1, cfg.d_model)
    probs = _router_probs(p, x2d, cfg)
    gv, ei = jax.lax.top_k(probs, 2)
    gv = gv / gv.sum(-1, keepdims=True)
    want = np.zeros_like(np.asarray(x2d))
    for t in range(x2d.shape[0]):
        for j in range(2):
            e = int(ei[t, j])
            h = np.asarray(x2d[t]) @ np.asarray(p["wi"][e])
            g_, u_ = np.split(h, 2)
            h = (g_ / (1 + np.exp(-g_))) * u_
            want[t] += float(gv[t, j]) * (h @ np.asarray(p["wo"][e]))
    np.testing.assert_allclose(np.asarray(out).reshape(-1, cfg.d_model), want, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("s,chunk", [(16, 4), (40, 8), (33, 16)])
def test_rwkv_chunked_wkv_vs_stepwise(s, chunk):
    """Chunk-parallel WKV (perf iter #4) == exact per-step recurrence."""
    from repro.models.rwkv6 import _wkv_chunked, _wkv_scan

    rng = np.random.default_rng(s * chunk)
    b, h, dh = 2, 3, 8
    r = jnp.asarray(rng.standard_normal((b, s, h, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, h, dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, h, dh)), jnp.float32)
    lw = jnp.asarray(-np.exp(rng.standard_normal((b, s, h, dh)) - 1.0), jnp.float32)
    u = jnp.asarray(rng.standard_normal((h, dh)) * 0.3, jnp.float32)

    y_fast, s_fast = _wkv_chunked(r, k, v, lw, u, chunk=chunk)
    y_ref, s_ref = _wkv_scan(r, k, v, jnp.exp(lw), u, h, dh)
    np.testing.assert_allclose(np.asarray(y_fast), np.asarray(y_ref), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s_fast), np.asarray(s_ref), rtol=2e-4, atol=2e-4)


def test_moe_grouped_equals_ungrouped():
    """GShard grouping must not change results when capacity is drop-free."""
    import dataclasses

    from repro.models.config import MoEConfig
    from repro.models.moe import moe_apply, moe_init

    cfg = get_config("dbrx_132b").reduced()
    cfg = dataclasses.replace(
        cfg, moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=64, capacity_factor=2.0))
    p = moe_init(jax.random.key(3), cfg)
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((4, 8, cfg.d_model)), jnp.float32)
    out1, aux1 = moe_apply(p, x, cfg)                      # moe_groups = 1
    cfg2 = dataclasses.replace(cfg, moe_groups=2)
    out2, aux2 = moe_apply(p, x, cfg2)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), rtol=2e-4, atol=2e-5)

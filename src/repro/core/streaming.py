"""Streaming Map/Reduce mining over an on-disk transaction store.

The paper's jobs never load the DB: each map task streams its HDFS block,
emits partial counts, and a combiner folds them before the reduce. This
module is that dataflow for the miner (DESIGN.md §9): the DB lives in a
``data.store.TransactionStore`` (packed uint32 shards on disk), and each
level's count pass iterates fixed-size row chunks through the SAME jit'd
count step as the in-memory driver, **accumulating per-candidate partial
counts on device** — the combiner. The host syncs a candidate pass exactly
once, after its last chunk, so per level there is a single device→host
transfer regardless of chunk count.

Host peak RSS is bounded by O(chunk_rows · row_bytes) (plus the candidate
tensors), not the dataset size: chunks are copied out of the mmap'd shards
one at a time, and a ``data.pipeline.ShardedBatchIterator`` double-buffers
the host→device transfer so chunk assembly overlaps device counting.

Exactness: support counting is integer arithmetic and every chunk row is
either a real transaction or an inert zero row (DESIGN.md §3), so the
chunk-sum equals the whole-DB count bit-for-bit — ``mine_streamed`` /
``mine_son_streamed`` are dict-equal to ``mine`` / ``mine_son`` at any
chunk size.

Fault tolerance (DESIGN.md §11):

  * ``mine_streamed(checkpoint=..., resume=True)`` persists the driver's
    complete state through :class:`distributed.checkpoint.MiningCheckpoint`
    — completed levels at every level boundary, plus (every
    ``checkpoint_every_chunks`` chunks) the mid-level pass cursor and the
    in-progress device accumulator. Because the store's chunk iteration is
    step-indexed and deterministic and counting is integer arithmetic,
    folding the remaining chunks into the restored accumulator equals
    folding all chunks into zeros: a resumed mine is dict-identical to an
    uninterrupted one.
  * ``mine_son_streamed(fault=FaultConfig(...))`` dispatches phase-1 shard
    partitions through ``distributed.fault_tolerance.run_partitions`` —
    bounded-retry re-execution plus speculative re-issue of stragglers,
    the paper's Hadoop task-recovery story made real.
"""

from __future__ import annotations

import math
import time
from typing import TYPE_CHECKING, Callable

import jax
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core import apriori as ap
from repro.core import son as son_mod
from repro.data.pipeline import ShardedBatchIterator, batch_spec
from repro.distributed.checkpoint import (
    CheckpointMismatch,
    MiningCheckpoint,
    MiningState,
    mining_fingerprint,
    store_fingerprint,
)
from repro.distributed.fault_tolerance import FaultConfig, run_partitions

if TYPE_CHECKING:  # import-time would cycle: data.store -> core -> streaming
    from repro.data.store import TransactionStore


def make_accum_count_step(mesh, cfg: ap.AprioriConfig) -> Callable:
    """The combiner: jit'd ``(t_chunk, c, lengths, acc) -> acc + counts``.

    Wraps :func:`core.apriori.make_count_step` (so dense/packed, jnp/Pallas
    and the mesh Map/Reduce shape are all inherited unchanged) and folds the
    chunk's counts into a device-resident int32 accumulator — partial
    aggregation happens where the data is, exactly like a Hadoop combiner.
    """
    count_step = ap.make_count_step(mesh, cfg)

    def step(t_chunk, c_dev, len_dev, acc):
        return acc + count_step(t_chunk, c_dev, len_dev)

    return jax.jit(step)


def _init_acc(kp: int, cfg: ap.AprioriConfig, mesh, init: np.ndarray | None = None):
    arr = np.zeros(kp, dtype=np.int32) if init is None else np.asarray(init, np.int32)
    if mesh is None:
        return jax.numpy.asarray(arr)
    return jax.device_put(arr, NamedSharding(mesh, P(cfg.model_axis)))


def _effective_chunk_rows(chunk_rows: int, cfg: ap.AprioriConfig, mesh) -> int:
    """Round the chunk up to a multiple of the data-shard count so every
    chunk splits evenly over P(data_axes) (padding rows are inert)."""
    if chunk_rows < 1:
        raise ValueError("chunk_rows must be >= 1")
    if mesh is None:
        return chunk_rows
    shards = math.prod(mesh.shape[a] for a in cfg.data_axes)
    return ((chunk_rows + shards - 1) // shards) * shards


def _count_pass_chunks(
    accum_step,
    chunks,
    c_dev,
    len_dev,
    kp,
    cfg,
    mesh,
    prefetch,
    init_acc: np.ndarray | None = None,
    chunks_done: int = 0,
    save_every: int = 0,
    save_fn: Callable | None = None,
    obs=None,
):
    """Fold every DB chunk into a device accumulator; sync ONCE — unless a
    mid-pass checkpoint cadence is set, in which case each save adds exactly
    one extra host sync (the measured checkpoint overhead, DESIGN.md §11).

    ``init_acc``/``chunks_done`` restore an interrupted pass: the caller
    skips the already-folded chunks at the store and hands the saved
    accumulator here; the save cadence stays aligned to ABSOLUTE chunk
    indices so a resumed pass checkpoints at the same points.

    ``obs`` (an :class:`repro.obs.MiningObs`) attributes the pass's time:
    ``prefetch_stall`` is the fold blocking on the chunk iterator,
    ``count_kernel`` the (async) dispatch of the accumulate step,
    ``host_sync`` the final device→host transfer that also drains the
    device queue, ``checkpoint_write`` the mid-pass saves.  The obs-off
    path is the original untouched loop.
    """
    acc = _init_acc(kp, cfg, mesh, init=init_acc)
    done = chunks_done
    it = ShardedBatchIterator(chunks, mesh, batch_spec(cfg.data_axes), prefetch=prefetch)
    try:
        if obs is None:
            for t_chunk in it:
                acc = accum_step(t_chunk, c_dev, len_dev, acc)
                done += 1
                if save_fn is not None and save_every > 0 and done % save_every == 0:
                    save_fn(np.asarray(acc), done)
        else:
            src = iter(it)
            while True:
                t0 = time.perf_counter()
                try:
                    t_chunk = next(src)
                except StopIteration:
                    break
                t1 = time.perf_counter()
                acc = accum_step(t_chunk, c_dev, len_dev, acc)
                t2 = time.perf_counter()
                obs.add_phase("prefetch_stall", t0, t1)
                obs.add_phase("count_kernel", t1, t2)
                obs.on_chunk(int(t_chunk.shape[0]))
                done += 1
                if save_fn is not None and save_every > 0 and done % save_every == 0:
                    t3 = time.perf_counter()
                    save_fn(np.asarray(acc), done)
                    obs.add_phase("checkpoint_write", t3, time.perf_counter())
    finally:
        it.close()
    if obs is None:
        return np.asarray(acc)   # the final host sync of this candidate pass
    t0 = time.perf_counter()
    out = np.asarray(acc)
    obs.add_phase("host_sync", t0, time.perf_counter())
    return out


def count_supports_streamed(
    store: TransactionStore,
    cand_sets: np.ndarray,
    cfg: ap.AprioriConfig = ap.AprioriConfig(),
    mesh=None,
    chunk_rows: int = 8192,
    prefetch: int = 2,
    obs=None,
) -> np.ndarray:
    """Exact support counts of ``cand_sets`` over an on-disk store.

    The streamed twin of the in-memory driver's per-level count: candidates
    split into ``max_candidates_per_pass`` passes padded to the same jit
    buckets; each pass streams all DB chunks through the accumulate step.
    Equals the whole-DB count exactly, for both representations, at any
    ``chunk_rows`` (including sizes that don't divide n — the final chunk
    zero-pads, and zero rows are inert).
    """
    cand_sets = np.asarray(cand_sets, dtype=np.int32)
    num_items = store.num_items
    chunk_rows = _effective_chunk_rows(chunk_rows, cfg, mesh)
    accum_step = make_accum_count_step(mesh, cfg)
    return _count_level_streamed(
        accum_step, store, cand_sets, num_items, cfg, mesh, chunk_rows, prefetch,
        obs=obs,
    )


def _count_level_streamed(
    accum_step,
    store,
    cand_sets,
    num_items,
    cfg,
    mesh,
    chunk_rows,
    prefetch,
    cursor: MiningState | None = None,
    save_cb: Callable | None = None,
    save_every: int = 0,
    obs=None,
):
    """One level's candidate passes over the store.

    ``cursor`` (a mid-level :class:`MiningState`) resumes an interrupted
    level: finished passes' counts are restored verbatim, the in-progress
    pass restarts from its saved accumulator at its saved chunk index, and
    later passes run normally. ``save_cb(counts, pass_start, acc, done)``
    is invoked every ``save_every`` chunks with the level's cursor state.
    """
    k_total = cand_sets.shape[0]
    quantum = ap._candidate_quantum(cfg, mesh)
    counts = np.zeros(k_total, dtype=np.int64)
    start0, resume_chunks, resume_acc = 0, 0, None
    if cursor is not None:
        if cursor.counts is None or cursor.counts.shape[0] != k_total:
            raise CheckpointMismatch(
                f"mid-level checkpoint carries {None if cursor.counts is None else cursor.counts.shape[0]} "
                f"candidate counts, but level {cursor.next_k} regenerated {k_total} "
                "candidates — checkpoint does not match this mine"
            )
        counts[:] = cursor.counts
        start0 = int(cursor.pass_start)
        resume_chunks = int(cursor.chunks_done)
        resume_acc = cursor.acc
    for start in range(start0, k_total, cfg.max_candidates_per_pass):
        chunk_c = cand_sets[start : start + cfg.max_candidates_per_pass]
        kp = ap._pad_bucket(chunk_c.shape[0], quantum)
        if obs is not None:
            obs.observe_max_candidate_bucket(kp)
        c_dev, len_dev = ap._place_candidates(chunk_c, kp, num_items, cfg, mesh)
        init_acc, start_chunk = None, 0
        if resume_acc is not None:   # first pass after a mid-level resume only
            if resume_acc.shape[0] != kp:
                raise CheckpointMismatch(
                    f"saved accumulator has {resume_acc.shape[0]} slots, this pass "
                    f"pads to {kp} — candidate bucketing (candidate_pad / mesh) changed"
                )
            init_acc, start_chunk = resume_acc, resume_chunks
            resume_acc = None
        chunks = (
            chunk
            for chunk, _ in store.iter_chunks(
                chunk_rows,
                representation=cfg.representation,
                pad=True,
                start_chunk=start_chunk,
            )
        )
        if save_cb is not None and save_every > 0:
            def save_fn(acc_np, done, _start=start):
                save_cb(counts, _start, acc_np, done)
        else:
            save_fn = None
        out = _count_pass_chunks(
            accum_step, chunks, c_dev, len_dev, kp, cfg, mesh, prefetch,
            init_acc=init_acc, chunks_done=start_chunk,
            save_every=save_every, save_fn=save_fn, obs=obs,
        )
        counts[start : start + chunk_c.shape[0]] = out[: chunk_c.shape[0]]
    return counts


def _as_manager(checkpoint, store) -> MiningCheckpoint | None:
    if checkpoint is None or checkpoint is False:
        return None
    if isinstance(checkpoint, MiningCheckpoint):
        return checkpoint
    if checkpoint is True:
        return MiningCheckpoint(store.checkpoint_path)
    return MiningCheckpoint(str(checkpoint))


def mine_streamed(
    store: TransactionStore,
    cfg: ap.AprioriConfig = ap.AprioriConfig(),
    mesh=None,
    chunk_rows: int = 8192,
    prefetch: int = 2,
    checkpoint_cb: Callable | None = None,
    resume_state: dict | None = None,
    checkpoint: "MiningCheckpoint | str | bool | None" = None,
    checkpoint_every_chunks: int = 0,
    resume: bool = False,
    obs=None,
) -> ap.AprioriResult:
    """Level-wise Apriori over an on-disk store, dict-equal to ``mine``.

    Identical driver semantics by construction — this is
    ``core.apriori.run_level_loop`` with the count function swapped for the
    chunk-streaming accumulator. Host RSS scales with ``chunk_rows``, not
    ``store.num_transactions``; the DB is re-streamed from disk once per
    candidate pass (sequential mmap reads — the per-pass I/O the paper's
    per-level Hadoop jobs pay too).

    Fault tolerance: pass ``checkpoint=True`` (next to the store manifest,
    ``store.checkpoint_path``), a path, or a :class:`MiningCheckpoint` to
    persist driver state at every level boundary — plus, when
    ``checkpoint_every_chunks > 0``, mid-level at that chunk cadence.
    ``resume=True`` restores the newest committed snapshot (validated
    against the store and config fingerprints) and continues; the result is
    dict-identical to an uninterrupted mine. ``checkpoint_cb`` /
    ``resume_state`` remain the raw level-boundary hooks (in-memory
    restarts, tests) and compose with the manager.
    """
    n, num_items = store.num_transactions, store.num_items
    chunk_rows = _effective_chunk_rows(chunk_rows, cfg, mesh)
    if checkpoint_every_chunks < 0:
        raise ValueError("checkpoint_every_chunks must be >= 0")
    accum_step = make_accum_count_step(mesh, cfg)
    mgr = _as_manager(checkpoint, store)

    if mgr is None:
        if resume:
            raise ValueError("resume=True requires checkpoint=")

        def count_fn(cand_sets, level_k):
            return _count_level_streamed(
                accum_step, store, cand_sets, num_items, cfg, mesh, chunk_rows,
                prefetch, obs=obs,
            )

        return ap.run_level_loop(count_fn, n, num_items, cfg, checkpoint_cb,
                                 resume_state, obs=obs)

    store_fp = store_fingerprint(store)
    mine_fp = mining_fingerprint(cfg, chunk_rows)

    cursor: MiningState | None = None
    if resume:
        loaded = mgr.load_latest()
        if loaded is not None:
            state, manifest = loaded
            mgr.validate(manifest, store_fp, mine_fp)
            resume_state = {"levels": dict(state.levels), "next_k": state.next_k}
            if state.mid_level:
                cursor = state
    else:
        mgr.clear()   # don't mix snapshots of distinct mines under one seq line

    # completed levels as of NOW — what a mid-level snapshot must carry
    done_levels = {"levels": dict(resume_state["levels"]) if resume_state else {}}

    def level_cb(k, levels):
        done_levels["levels"] = dict(levels)
        mgr.save(MiningState(levels=dict(levels), next_k=k + 1), store_fp, mine_fp)
        if checkpoint_cb:
            checkpoint_cb(k, levels)

    def count_fn(cand_sets, level_k):
        nonlocal cursor
        cur, cursor = cursor, None   # the cursor resumes exactly one level
        if cur is not None and cur.next_k != level_k:
            raise CheckpointMismatch(
                f"mid-level checkpoint is for level {cur.next_k}, "
                f"but the loop resumed at level {level_k}"
            )

        def save_cb(counts, pass_start, acc_np, done):
            mgr.save(
                MiningState(
                    levels=done_levels["levels"],
                    next_k=level_k,
                    mid_level=True,
                    pass_start=pass_start,
                    chunks_done=done,
                    counts=counts,
                    acc=acc_np,
                ),
                store_fp,
                mine_fp,
            )

        return _count_level_streamed(
            accum_step, store, cand_sets, num_items, cfg, mesh, chunk_rows, prefetch,
            cursor=cur,
            save_cb=save_cb if checkpoint_every_chunks > 0 else None,
            save_every=checkpoint_every_chunks,
            obs=obs,
        )

    result = ap.run_level_loop(count_fn, n, num_items, cfg, level_cb, resume_state,
                               obs=obs)
    mgr.wait()   # the last boundary snapshot is committed before we return
    return result


def count_union_streamed(
    store: TransactionStore,
    per_level: dict,
    cfg: ap.AprioriConfig = ap.AprioriConfig(),
    mesh=None,
    chunk_rows: int = 8192,
    prefetch: int = 2,
    shards: tuple | None = None,
    obs=None,
) -> dict:
    """Exact streamed counts of a multi-level candidate union in ONE pass
    over the store (or over the shard range ``shards=(s0, s1)``).

    ``per_level`` maps ``k -> (K_k, k) int32`` candidate arrays; the return
    maps ``k -> (K_k,) int64`` counts, aligned. Every level's candidate
    passes are device-placed up front (the union is the modest survivor set,
    not a full level's candidates — this trades the max_candidates_per_pass
    memory bound for a single disk scan), then every DB chunk folds into
    every pass's accumulator. This is SON's phase 2 made reusable: the full
    mine counts the whole union over the whole store, the delta miner
    (DESIGN.md §15) counts the union over appended shards and the novel
    candidates over the base shards — same kernel path, same exactness
    argument (zero-padded rows are inert).
    """
    chunk_rows = _effective_chunk_rows(chunk_rows, cfg, mesh)
    num_items = store.num_items
    accum_step = make_accum_count_step(mesh, cfg)
    quantum = ap._candidate_quantum(cfg, mesh)
    per_level = {
        k: np.asarray(cands, dtype=np.int32)
        for k, cands in sorted(per_level.items())
        if np.asarray(cands).shape[0]
    }
    units = []   # (k, start, rows, c_dev, len_dev, acc)
    for k, cands in per_level.items():
        for start in range(0, cands.shape[0], cfg.max_candidates_per_pass):
            chunk_c = cands[start : start + cfg.max_candidates_per_pass]
            kp = ap._pad_bucket(chunk_c.shape[0], quantum)
            if obs is not None:
                obs.observe_max_candidate_bucket(kp)
            c_dev, len_dev = ap._place_candidates(chunk_c, kp, num_items, cfg, mesh)
            units.append([k, start, chunk_c.shape[0], c_dev, len_dev, _init_acc(kp, cfg, mesh)])
    if units:
        chunks = (
            chunk
            for chunk, _ in store.iter_chunks(
                chunk_rows, representation=cfg.representation, pad=True, shards=shards
            )
        )
        it = ShardedBatchIterator(chunks, mesh, batch_spec(cfg.data_axes), prefetch=prefetch)
        try:
            if obs is None:
                for t_chunk in it:
                    for u in units:
                        u[5] = accum_step(t_chunk, u[3], u[4], u[5])
            else:
                src = iter(it)
                while True:
                    t0 = time.perf_counter()
                    try:
                        t_chunk = next(src)
                    except StopIteration:
                        break
                    t1 = time.perf_counter()
                    for u in units:
                        u[5] = accum_step(t_chunk, u[3], u[4], u[5])
                    t2 = time.perf_counter()
                    obs.add_phase("prefetch_stall", t0, t1)
                    obs.add_phase("count_kernel", t1, t2)
                    obs.on_chunk(int(t_chunk.shape[0]))
        finally:
            it.close()

    t_sync0 = time.perf_counter()
    counts = {}
    for k, cands in per_level.items():
        sup = np.zeros(cands.shape[0], dtype=np.int64)
        for uk, start, rows, _, _, acc in units:
            if uk == k:
                sup[start : start + rows] = np.asarray(acc)[:rows]
        counts[k] = sup
    if obs is not None:
        obs.add_phase("host_sync", t_sync0, time.perf_counter())
    return counts


def mine_son_streamed(
    store: TransactionStore,
    cfg: ap.AprioriConfig = ap.AprioriConfig(),
    mesh=None,
    chunk_rows: int = 8192,
    prefetch: int = 2,
    fault: FaultConfig | None = None,
    obs=None,
    collect_union: bool = False,
) -> ap.AprioriResult:
    """SON two-phase mining over an on-disk store, dict-equal to
    ``mine_son`` (and to ``mine`` — SON is exact for any partitioning).

    Phase 1 maps over the store's *on-disk shards* as the SON partitions:
    each shard is unpacked and mined locally to completion at the
    shard-scaled threshold. With ``fault=FaultConfig(...)`` the shard
    mappers run through the retrying work queue
    (:func:`distributed.fault_tolerance.run_partitions`): a failed shard
    read or mapper is re-executed with backoff — shards are re-loadable by
    index, the HDFS-split property — stragglers are speculatively
    re-issued, and the executor's :class:`FaultReport` lands on
    ``result.fault_report``. In ``on_exhausted="skip"`` mode a dropped
    partition is an EXPLICITLY reported completeness gap (SON's no-miss
    guarantee needs every partition).

    Phase 2 is ONE streamed exact count of the union — two distributed
    rounds total, never the whole DB in memory.

    ``collect_union=True`` additionally attaches the full PRE-prune union
    with its exact counts as ``result.union_counts`` (``k -> (cands,
    counts)``) — exactly what phase 2 computes and the prune would throw
    away. The incremental count cache (DESIGN.md §15) persists this.
    """
    n = store.num_transactions
    min_count = max(1, math.ceil(cfg.min_support * n))
    chunk_rows = _effective_chunk_rows(chunk_rows, cfg, mesh)

    # ---- phase 1: local mining per on-disk shard, union of local winners --
    report = None
    if fault is None:
        union = son_mod.union_local_winners(
            (store.partition_dense(p) for p in range(store.num_partitions)), cfg
        )
    else:
        def map_shard(p: int) -> dict:
            # re-reads shard p from disk on every (re-)execution — idempotent
            return son_mod.local_winners(store.partition_dense(p), cfg)

        winners, report = run_partitions(map_shard, store.num_partitions, fault,
                                         obs=obs)
        union = son_mod.merge_winners(w for w in winners if w is not None)

    # ---- phase 2: ONE streamed exact count of the whole union ----
    per_level = son_mod.winners_to_arrays(union)
    counts = count_union_streamed(
        store, per_level, cfg, mesh, chunk_rows=chunk_rows, prefetch=prefetch, obs=obs
    )
    levels = {}
    for k, cands in per_level.items():
        sup = counts[k]
        keep = sup >= min_count
        if keep.any():
            levels[k] = (cands[keep], sup[keep])
    return ap.AprioriResult(
        levels=levels, num_transactions=n, min_count=min_count, fault_report=report,
        union_counts=(
            {k: (cands, counts[k]) for k, cands in per_level.items()}
            if collect_union else None
        ),
    )

"""Bench-trajectory regression gate (obs.regress): noise-aware trajectory
checks, declarative invariants, FAILED/missing-row handling, the CLI — and
the committed BENCH_*.json files themselves (DESIGN.md §14)."""

import copy
import json
import pathlib

import pytest

from repro.obs.regress import (INVARIANTS, check_files, check_trajectory,
                               main, parse_derived)

REPO = pathlib.Path(__file__).resolve().parents[1]
COMMITTED = [str(REPO / n) for n in
             ("BENCH_serve.json", "BENCH_fault.json", "BENCH_obs.json")]


# ------------------------------------------------------------ trajectory --

def test_stable_trajectory_passes_and_degraded_fails():
    history = [100.0, 102.0, 98.0, 101.0, 99.0]
    ok, _ = check_trajectory("row", 105.0, history)
    assert ok
    # a tight history gets the floor tolerance (30%): 1.5x is a regression
    ok, detail = check_trajectory("row", 150.0, history)
    assert not ok and "baseline=100" in detail


def test_noisy_history_widens_the_gate():
    noisy = [100.0, 160.0, 70.0, 140.0, 60.0]    # MAD = 40 -> tol = 160%
    ok, _ = check_trajectory("row", 200.0, noisy)
    assert ok                                    # inside the widened gate
    tight = [100.0, 101.0, 99.0, 100.0, 100.0]
    ok, _ = check_trajectory("row", 200.0, tight)
    assert not ok                                # same latest, tight history


def test_young_trajectory_passes_vacuously_and_improvement_always_passes():
    ok, detail = check_trajectory("row", 9e9, [100.0, 100.0])
    assert ok and "no baseline" in detail
    ok, _ = check_trajectory("row", 1.0, [100.0] * 10)
    assert ok                                    # only degradation flags


def test_failed_markers_in_history_are_ignored():
    ok, detail = check_trajectory("row", 100.0, [-1.0, 100.0, 100.0, 100.0])
    assert ok and "n=3" in detail


# ------------------------------------------------------- files + gates ----

def write_bench(tmp_path, name, rows):
    p = tmp_path / name
    p.write_text(json.dumps({"rows": rows}))
    return str(p)


def row(name, us=100.0, history=(100.0, 100.0, 100.0), derived=""):
    return {"name": name, "us_per_call": us, "history": list(history),
            "derived": derived}


def test_check_files_flags_failed_row_and_invariant_violation(tmp_path):
    rows = [
        row("fine"),
        row("regressed", us=1000.0),
        row("crashed", us=-1.0),
        row("serve_gateway_microbatch_c32",
            derived="qps=100;speedup_vs_sequential=1.2x"),   # < 2.0 gate
    ]
    path = write_bench(tmp_path, "b.json", rows)
    inv = {"serve_gateway_microbatch_c32": INVARIANTS["serve_gateway_microbatch_c32"]}
    ok, findings = check_files([path], invariants=inv)
    assert not ok
    bad = {(f.row, f.check) for f in findings if not f.ok}
    assert bad == {("regressed", "trajectory"), ("crashed", "failed_row"),
                   ("serve_gateway_microbatch_c32", "invariant")}
    assert findings[0].ok is False               # violations sort first


def test_missing_gated_row_fails_by_default(tmp_path):
    path = write_bench(tmp_path, "b.json", [row("fine")])
    inv = {"fault_kill_resume_n60000": INVARIANTS["fault_kill_resume_n60000"]}
    ok, findings = check_files([path], invariants=inv)
    assert not ok
    (f,) = [f for f in findings if not f.ok]
    assert f.check == "missing_row" and f.row == "fault_kill_resume_n60000"


def test_invariants_resolve_across_the_union_of_files(tmp_path):
    a = write_bench(tmp_path, "a.json", [row("fine")])
    b = write_bench(tmp_path, "b.json", [
        row("fault_kill_resume_n60000", derived="parity=ok;replayed_levels=0")])
    inv = {"fault_kill_resume_n60000": INVARIANTS["fault_kill_resume_n60000"]}
    ok, _ = check_files([a, b], invariants=inv)
    assert ok


def test_unreadable_file_fails(tmp_path):
    p = tmp_path / "broken.json"
    p.write_text("{nope")
    ok, findings = check_files([str(p)], invariants={})
    assert not ok and findings[0].check == "failed_row"


def test_parse_derived_tolerates_units_and_flat_fragments():
    d = parse_derived("qps=1234;speedup=2.5x;note;hit_rate=80%")
    assert d == {"qps": "1234", "speedup": "2.5x", "hit_rate": "80%"}


# ----------------------------------------------- the committed files ------

def test_committed_trajectories_pass_the_gate():
    """The acceptance criterion: the gate CI runs must be green on the
    repo's own committed trajectory files."""
    ok, findings = check_files(COMMITTED)
    assert ok, [f for f in findings if not f.ok]


def test_synthetically_degraded_committed_copy_fails(tmp_path):
    """...and a 10x-slowed copy of a gated row must NOT be green."""
    data = json.loads((REPO / "BENCH_serve.json").read_text())
    degraded = copy.deepcopy(data)
    for r in degraded["rows"]:
        if r["name"] == "serve_gateway_microbatch_c32":
            # seed enough history that the trajectory gate is armed, then
            # make the latest run 10x slower than that baseline
            r["history"] = [r["us_per_call"]] * 3
            r["us_per_call"] = r["us_per_call"] * 10.0
    p = tmp_path / "BENCH_serve.json"
    p.write_text(json.dumps(degraded))
    ok, findings = check_files([str(p)] + COMMITTED[1:])
    assert not ok
    bad = [f for f in findings if not f.ok]
    assert any(f.row == "serve_gateway_microbatch_c32"
               and f.check == "trajectory" for f in bad)


# ------------------------------------------------------------------- CLI --

def test_cli_exit_codes_and_json_output(tmp_path, capsys):
    good = write_bench(tmp_path, "good.json", [row("fine")])
    assert main(["--check", good]) == 1          # default INVARIANTS missing
    out = capsys.readouterr().out
    assert "missing_row" in out and "FAIL" in out

    assert main(["--check"] + COMMITTED + ["--json"]) == 0
    rep = json.loads(capsys.readouterr().out)
    assert rep["ok"] is True
    assert all(f["ok"] for f in rep["findings"])

"""Per-kernel shape/dtype sweeps: Pallas (interpret) vs pure-jnp oracle."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.core.itemsets import itemsets_to_dense, pack_bits

from conftest import random_problem as _random_problem


SHAPES = [
    (8, 16, 4),        # tiny, sub-block everything
    (100, 64, 33),     # ragged, non-multiples
    (256, 128, 128),   # exact single blocks
    (300, 130, 257),   # every dim unaligned
    (512, 512, 300),   # multi-block N and I
]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("operand_dtype", ["bf16", "int8"])
def test_support_count_pallas_vs_ref(shape, operand_dtype):
    n, i, k = shape
    t, c, lengths = _random_problem(n, i, k, seed=n + i + k)
    want = np.asarray(ref.support_count_ref(jnp.asarray(t), jnp.asarray(c), jnp.asarray(lengths)))
    got = np.asarray(
        ops.support_count(
            jnp.asarray(t),
            jnp.asarray(c),
            jnp.asarray(lengths),
            impl="pallas_interpret",
            operand_dtype=operand_dtype,
            block_n=128,
            block_k=128,
            block_i=128,
        )
    )
    np.testing.assert_array_equal(got, want)  # counting is exact — no tolerance


@pytest.mark.parametrize("seed", range(3))
def test_support_count_packed_vs_dense(seed):
    t, c, lengths = _random_problem(200, 96, 50, seed=seed)
    want = np.asarray(ref.support_count_ref(jnp.asarray(t), jnp.asarray(c), jnp.asarray(lengths)))
    got = np.asarray(
        ref.support_count_packed_ref(jnp.asarray(pack_bits(t)), jnp.asarray(pack_bits(c)), block_k=32)
    )
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("mode", ["and_cmp", "popcount"])
def test_support_count_packed_pallas_vs_ref(shape, mode):
    """Packed Pallas kernel (interpret) vs dense oracle, same shape sweep as
    the dense kernel — includes non-multiple-of-32 item counts."""
    n, i, k = shape
    t, c, lengths = _random_problem(n, i, k, seed=n + i + k)
    want = np.asarray(ref.support_count_ref(jnp.asarray(t), jnp.asarray(c), jnp.asarray(lengths)))
    got = np.asarray(
        ops.support_count_packed(
            jnp.asarray(pack_bits(t)),
            jnp.asarray(pack_bits(c)),
            jnp.asarray(lengths),
            impl="pallas_interpret",
            mode=mode,
            block_n=64,
            block_k=128,
            block_w=2,
        )
    )
    np.testing.assert_array_equal(got, want)


def test_support_count_oracle_is_right():
    """Pin the oracle itself against a hand-computed case."""
    t = np.array([[1, 1, 0, 1], [1, 0, 0, 1], [0, 1, 1, 0]], np.int8)
    cands = np.array([[0], [1], [3]], np.int32)  # singletons 0,1,3
    dense = itemsets_to_dense(cands, 4)
    got = np.asarray(ref.support_count_ref(jnp.asarray(t), jnp.asarray(dense), jnp.asarray([1, 1, 1], np.int32)))
    assert got.tolist() == [2, 2, 2]
    pair = itemsets_to_dense(np.array([[0, 3], [1, 2]], np.int32), 4)
    got = np.asarray(ref.support_count_ref(jnp.asarray(t), jnp.asarray(pair), jnp.asarray([2, 2], np.int32)))
    assert got.tolist() == [2, 1]


def test_padding_rows_never_count():
    """Padded candidates (|c| = -1) and zero-row transactions are inert."""
    t, c, lengths = _random_problem(64, 32, 16, seed=3)
    t_padded = np.concatenate([t, np.zeros((64, 32), np.int8)])
    want = np.asarray(ref.support_count_ref(jnp.asarray(t), jnp.asarray(c), jnp.asarray(lengths)))
    got = np.asarray(
        ops.support_count(
            jnp.asarray(t_padded), jnp.asarray(c), jnp.asarray(lengths), impl="pallas_interpret"
        )
    )
    np.testing.assert_array_equal(got, want)

"""Mining job counters + live progress, Hadoop style (§13).

The paper's Hadoop deployment got phase attribution for free from the
framework's job counters and task-progress reporting; :class:`MiningObs` is
that layer for our streamed miner.  It bundles a :class:`MetricsRegistry`
(per-level candidate/frequent counters, chunk/row counters, per-phase
wall-time split, partition retry/speculation counters), an optional
:class:`Tracer` (each mined level is one trace: candidate-gen / count-pass /
chunk phases nest under it), and an optional :class:`MiningProgress`
reporter that prints throughput + ETA while a multi-minute mine streams.

Everything is observation-only.  Call sites guard with ``if obs is not
None`` so the uninstrumented path stays untouched, and nothing here feeds
back into mining decisions — mined dicts are bit-identical with obs on/off
(CI-enforced).

Phase taxonomy (the per-phase wall-time split):

- ``candidate_gen``   — host-side k-itemset join from the (k-1) survivors
- ``prefetch_stall``  — time the fold blocked on the chunk iterator
- ``count_kernel``    — dispatch of the jit'd accumulate step
- ``host_sync``       — final device→host sync of the level's counts
- ``checkpoint_write``— mid-level cursor/accumulator saves
"""

from __future__ import annotations

import sys
import time
from typing import Optional

from .registry import MetricsRegistry
from .trace import Span, Tracer

PHASES = ("candidate_gen", "prefetch_stall", "count_kernel", "host_sync",
          "checkpoint_write")


class MiningProgress:
    """Throttled live progress lines: level, chunks, rows/s, ETA of the
    current pass.  Writes plain newline-terminated lines (CI-log safe)."""

    def __init__(self, total_rows: Optional[int] = None, out=None,
                 interval_s: float = 0.5):
        self.total_rows = total_rows
        self.out = out if out is not None else sys.stderr
        self.interval_s = float(interval_s)
        self._t_start = time.perf_counter()
        self._t_last = 0.0
        self._level = 0
        self._candidates = 0
        self._pass_rows = 0
        self._pass_t0 = self._t_start
        self.lines_emitted = 0

    def _emit(self, force: bool = False) -> None:
        now = time.perf_counter()
        if not force and (now - self._t_last) < self.interval_s:
            return
        self._t_last = now
        dt = max(now - self._pass_t0, 1e-9)
        rate = self._pass_rows / dt
        msg = (f"[mine] L{self._level} cand={self._candidates} "
               f"rows={self._pass_rows} ({rate / 1e3:.1f}k rows/s)")
        if self.total_rows:
            frac = min(1.0, self._pass_rows / self.total_rows)
            eta = (self.total_rows - self._pass_rows) / rate if rate > 0 else 0.0
            msg += f" {frac * 100:5.1f}% eta={eta:.1f}s"
        self.out.write(msg + "\n")
        try:
            self.out.flush()
        except Exception:
            pass
        self.lines_emitted += 1

    def on_level_start(self, level: int, candidates: int) -> None:
        self._level = level
        self._candidates = candidates
        self._pass_rows = 0
        self._pass_t0 = time.perf_counter()
        self._emit(force=True)

    def on_rows(self, rows: int) -> None:
        self._pass_rows += rows
        self._emit()

    def on_level_end(self, level: int, frequent: int) -> None:
        dt = time.perf_counter() - self._pass_t0
        self.out.write(f"[mine] L{level} done: {frequent} frequent "
                       f"({dt:.2f}s)\n")
        self.lines_emitted += 1

    def finish(self) -> None:
        dt = time.perf_counter() - self._t_start
        self.out.write(f"[mine] finished in {dt:.2f}s\n")
        self.lines_emitted += 1


class MiningObs:
    """Job counters + phase timers + optional tracing for one mine run."""

    def __init__(self, registry: Optional[MetricsRegistry] = None,
                 tracer: Optional[Tracer] = None,
                 progress: Optional[MiningProgress] = None):
        self.registry = registry if registry is not None else MetricsRegistry()
        self.tracer = tracer
        self.progress = progress
        self._level_span: Optional[Span] = None

    # -- level lifecycle ---------------------------------------------------

    def on_level_start(self, level: int, candidates: int) -> None:
        self.registry.counter("mine_levels").inc()
        self.registry.counter("mine_candidates_total").inc(candidates)
        self.registry.counter("mine_candidates", {"level": str(level)}).inc(candidates)
        if self.tracer is not None:
            self._level_span = self.tracer.root("mine.level", level=level,
                                                candidates=candidates)
        if self.progress is not None:
            self.progress.on_level_start(level, candidates)

    def on_level_end(self, level: int, frequent: int) -> None:
        self.registry.counter("mine_frequent_total").inc(frequent)
        self.registry.counter("mine_frequent", {"level": str(level)}).inc(frequent)
        if self._level_span is not None:
            self._level_span.end(frequent=frequent)
            self._level_span = None
        if self.progress is not None:
            self.progress.on_level_end(level, frequent)

    # -- phase + chunk accounting -----------------------------------------

    def add_phase(self, phase: str, t0: float, t1: float) -> None:
        """Fold one measured interval (``perf_counter`` endpoints) into the
        phase's cumulative wall-time and, when tracing, the level trace."""
        self.registry.gauge("mine_phase_seconds", {"phase": phase}).inc(t1 - t0)
        if self.tracer is not None and self._level_span is not None:
            self.tracer.add_span(self._level_span, f"mine.{phase}", t0, t1)

    def on_chunk(self, rows: int) -> None:
        self.registry.counter("mine_chunks_streamed").inc()
        self.registry.counter("mine_rows_streamed").inc(rows)
        if self.progress is not None:
            self.progress.on_rows(rows)

    def observe_max_candidate_bucket(self, kp: int) -> None:
        self.registry.gauge("mine_max_candidate_bucket").max(kp)

    # -- fault-tolerance accounting (run_partitions) -----------------------

    def on_partition_attempt(self, retry: bool, speculative: bool) -> None:
        self.registry.counter("mine_partition_attempts").inc()
        if retry:
            self.registry.counter("mine_partition_retries").inc()
        if speculative:
            self.registry.counter("mine_speculative_issued").inc()

    def on_partition_done(self, speculative_win: bool) -> None:
        self.registry.counter("mine_partitions_completed").inc()
        if speculative_win:
            self.registry.counter("mine_speculative_wins").inc()

    def on_partition_skipped(self) -> None:
        self.registry.counter("mine_partitions_skipped").inc()

    # -- exposition --------------------------------------------------------

    def counters(self) -> dict:
        """One atomic Hadoop-style job-counter dump (plain dict)."""
        return self.registry.snapshot()

    def finish(self) -> None:
        if self.progress is not None:
            self.progress.finish()

"""Retryable partition execution — Hadoop task re-execution for SON phase 1.

The paper's whole case for Map/Reduce is that a map task which dies is simply
re-executed from its replicated split; "Observations on Factors Affecting
Performance of MapReduce based Apriori" (1701.05982) adds that stragglers on
heterogeneous nodes dominate wall-clock, which Hadoop answers with
speculative execution. This module is both mechanisms for the mining stack's
real phase-1 executor (DESIGN.md §11): SON partitions (= the store's on-disk
shards) are dispatched through a bounded-retry work queue over a thread
pool —

  * a failed partition (shard read error, injected fault, worker exception)
    is retried with exponential backoff, up to ``max_retries`` re-executions;
  * a straggling partition is speculatively re-issued to an idle worker once
    it has run ``speculative_factor``× the median completed-task time
    (first completion wins; duplicates are discarded);
  * a partition that exhausts its retries either raises
    :class:`PartitionFailure` naming the partition (default) or — in
    ``on_exhausted="skip"`` mode — is recorded in the :class:`FaultReport`
    and the mine continues with an EXPLICITLY reported gap.

Partitions must be *re-loadable by index* (the worker takes the partition
number, not the data) — exactly the property the on-disk store's shards
have, and the analogue of HDFS split replication.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable

_UNSET = object()


class PartitionFailure(RuntimeError):
    """A partition exhausted its retries. Names the partition and keeps the
    last underlying exception as ``__cause__``/``cause``."""

    def __init__(self, partition: int, attempts: int, cause: BaseException):
        super().__init__(
            f"partition {partition} failed after {attempts} attempt(s): {cause!r}"
        )
        self.partition = partition
        self.attempts = attempts
        self.cause = cause


class InjectedFailure(RuntimeError):
    """Raised by failure injectors to emulate a lost map task."""


@dataclasses.dataclass(frozen=True)
class FaultConfig:
    """Policy knobs of the retrying partition executor."""

    max_retries: int = 2              # re-executions after the first attempt
    backoff_s: float = 0.02           # sleep before retry #1
    backoff_multiplier: float = 2.0   # backoff_s * mult**(attempt-1)
    max_workers: int = 2              # thread-pool width (peak RAM ~ workers * shard)
    speculative: bool = True          # re-issue stragglers to idle workers
    speculative_factor: float = 4.0   # straggler = runtime > factor * median done
    on_exhausted: str = "raise"       # "raise" | "skip" (explicit-report gap)
    failure_injector: Callable | None = None   # (partition, attempt) -> may raise

    def __post_init__(self):
        if self.max_retries < 0:
            raise ValueError("max_retries must be >= 0")
        if self.max_workers < 1:
            raise ValueError("max_workers must be >= 1")
        if self.on_exhausted not in ("raise", "skip"):
            raise ValueError(f"on_exhausted must be raise|skip, got {self.on_exhausted!r}")


@dataclasses.dataclass
class FaultReport:
    """What the executor actually did — published, never silent."""

    attempts: dict = dataclasses.field(default_factory=dict)  # partition -> executions
    retries: int = 0                 # failure-triggered re-executions
    speculative_issued: int = 0      # straggler backup copies launched
    speculative_wins: int = 0        # partitions whose backup copy finished first
    skipped: tuple = ()              # partitions dropped in "skip" mode
    completed: int = 0

    @property
    def total_failures(self) -> int:
        return self.retries + len(self.skipped)

    def to_json(self) -> dict:
        return {
            "attempts": {int(k): int(v) for k, v in self.attempts.items()},
            "retries": self.retries,
            "speculative_issued": self.speculative_issued,
            "speculative_wins": self.speculative_wins,
            "skipped": [int(p) for p in self.skipped],
            "completed": self.completed,
        }


def retry_delay(fault: FaultConfig, attempt: int) -> float:
    """Backoff before re-execution ``attempt`` (0-based):
    ``backoff_s * backoff_multiplier**attempt``. Shared by the partition
    executor and the serving router's failover path — one retry policy
    object (:class:`FaultConfig`) drives both."""
    return fault.backoff_s * fault.backoff_multiplier**attempt


class _Task:
    __slots__ = ("idx", "attempt", "speculative")

    def __init__(self, idx: int, attempt: int, speculative: bool = False):
        self.idx = idx
        self.attempt = attempt
        self.speculative = speculative


def run_partitions(
    worker_fn: Callable[[int], object],
    num_partitions: int,
    fault: FaultConfig = FaultConfig(),
    obs=None,
) -> tuple[list, FaultReport]:
    """Execute ``worker_fn(p)`` for every partition through the retrying,
    speculating work queue; returns ``(results, report)`` with ``results[p]``
    being the partition's value (or None for a skipped partition).

    ``worker_fn`` must be idempotent and re-invokable (it re-reads its
    partition — the HDFS-split property); duplicate completions from
    speculative copies are discarded under a lock, first writer wins.

    ``obs`` (an :class:`repro.obs.MiningObs`) mirrors the report into live
    Hadoop-style job counters — attempts, retries, speculative issues/wins,
    skips — purely observational: results are identical with obs on/off.
    """
    if num_partitions == 0:
        return [], FaultReport()
    results = [_UNSET] * num_partitions
    report = FaultReport(attempts={p: 0 for p in range(num_partitions)})
    lock = threading.Lock()
    done_evt = threading.Event()
    pending: list[_Task] = [_Task(p, 0) for p in range(num_partitions)]
    running: dict[int, float] = {}       # partition -> oldest running start time
    durations: list[float] = []          # completed-task wall times (for median)
    remaining = [num_partitions]         # partitions not yet done/skipped
    error: list = []                     # first PartitionFailure in "raise" mode

    def _finish_one():
        remaining[0] -= 1
        if remaining[0] <= 0:
            done_evt.set()

    def _next_task():
        with lock:
            if pending:
                t = pending.pop(0)
                running.setdefault(t.idx, time.perf_counter())
                return t
        return None

    def _run_task(t: _Task):
        if obs is not None:
            obs.on_partition_attempt(retry=t.attempt > 0, speculative=t.speculative)
        t0 = time.perf_counter()
        try:
            if fault.failure_injector is not None:
                fault.failure_injector(t.idx, t.attempt)
            value = worker_fn(t.idx)
        except BaseException as e:  # noqa: BLE001 — every failure is policy-handled
            with lock:
                report.attempts[t.idx] += 1
                if results[t.idx] is not _UNSET:
                    return          # a twin already completed it; failure moot
                if t.attempt < fault.max_retries:
                    report.retries += 1
                    running.pop(t.idx, None)   # restart the straggler clock
                    delay = retry_delay(fault, t.attempt)
                    retry = _Task(t.idx, t.attempt + 1)
                else:
                    running.pop(t.idx, None)
                    if fault.on_exhausted == "skip":
                        report.skipped = report.skipped + (t.idx,)
                        results[t.idx] = None
                        if obs is not None:
                            obs.on_partition_skipped()
                    elif not error:
                        error.append(PartitionFailure(t.idx, t.attempt + 1, e))
                        done_evt.set()
                    _finish_one()
                    return
            if delay > 0:
                time.sleep(delay)   # backoff outside the lock
            with lock:
                if results[t.idx] is _UNSET:
                    pending.append(retry)
            return
        dt = time.perf_counter() - t0
        with lock:
            report.attempts[t.idx] += 1
            won = results[t.idx] is _UNSET
            if won:
                results[t.idx] = value
                report.completed += 1
                if t.speculative:      # the backup copy beat the original
                    report.speculative_wins += 1
                durations.append(dt)
                running.pop(t.idx, None)
                _finish_one()
        if won and obs is not None:
            obs.on_partition_done(speculative_win=t.speculative)

    def _worker():
        while not done_evt.is_set():
            t = _next_task()
            if t is None:
                if done_evt.wait(timeout=0.005):
                    return
                continue
            _run_task(t)

    n_workers = min(fault.max_workers, num_partitions)
    threads = [
        threading.Thread(target=_worker, name=f"son-partition-{i}", daemon=True)
        for i in range(n_workers)
    ]
    for th in threads:
        th.start()

    # ---- the driver doubles as the speculation monitor -------------------
    speculated: set[int] = set()
    while not done_evt.wait(timeout=0.01):
        if not fault.speculative:
            continue
        with lock:
            if pending or len(durations) < 1:
                continue            # no idle capacity signal / no baseline yet
            med = sorted(durations)[len(durations) // 2]
            now = time.perf_counter()
            for idx, started in list(running.items()):
                if (
                    idx not in speculated
                    and results[idx] is _UNSET
                    and now - started > fault.speculative_factor * max(med, 1e-4)
                ):
                    pending.append(_Task(idx, 0, speculative=True))
                    speculated.add(idx)
                    report.speculative_issued += 1
    # The job is complete once every partition has a recorded outcome. A
    # worker may still be parked inside a SUPERSEDED attempt (its twin
    # already won) — abandon it after a short grace, as Hadoop kills the
    # slower speculative attempt: the daemon thread's late completion is
    # discarded under the results lock, so it cannot change the outcome.
    for th in threads:
        th.join(timeout=0.05)

    if error:
        raise error[0]
    return [None if r is _UNSET else r for r in results], report

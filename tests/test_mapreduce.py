"""Map/Reduce engine semantics + shard-count invariance (the paper's core
design claim: the distributed job computes exactly what a single node does)."""

import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core.mapreduce import MapReduceJob, mapreduce, pad_rows_to_shards

from conftest import REPO_ROOT, subprocess_env



def test_mapreduce_single_device_sum():
    mesh = jax.make_mesh((1,), ("data",))
    job = MapReduceJob(map_fn=lambda x: x.sum(0), reduce_axes=("data",))
    fn = mapreduce(job, mesh, in_specs=(P("data", None),))
    x = jnp.arange(12.0).reshape(4, 3)
    np.testing.assert_allclose(np.asarray(fn(x)), np.asarray(x.sum(0)))


def test_mapreduce_reduce_ops():
    mesh = jax.make_mesh((1,), ("data",))
    x = jnp.arange(8.0).reshape(4, 2)
    for op, expect in [("max", x.max(0)), ("min", x.min(0))]:
        job = MapReduceJob(map_fn=lambda v: v.max(0) if op == "max" else v.min(0), reduce_axes=("data",), reduce_op=op)
        fn = mapreduce(job, mesh, in_specs=(P("data", None),))
        np.testing.assert_allclose(np.asarray(fn(x)), np.asarray(expect))


def test_pad_rows_to_shards():
    x = np.ones((5, 3), np.int8)
    padded, n = pad_rows_to_shards(x, 4)
    assert padded.shape == (8, 3) and n == 5
    assert padded[5:].sum() == 0
    same, _ = pad_rows_to_shards(x, 5)
    assert same.shape == (5, 3)


_INVARIANCE_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np, jax
    from repro.data.synthetic import gen_transactions, QuestConfig
    from repro.core.apriori import mine, AprioriConfig

    T = gen_transactions(QuestConfig(num_transactions=333, num_items=48, avg_len=8, seed=11))
    single = mine(T, AprioriConfig(min_support=0.06, max_k=5, count_impl="jnp"))

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    dist = mine(
        T,
        AprioriConfig(min_support=0.06, max_k=5, count_impl="jnp",
                      data_axes=("data",), model_axis="model"),
        mesh=mesh,
    )
    assert dist.as_dict() == single.as_dict(), "distributed != single-node"

    # 3-axis multi-pod style mesh, pod+data both shard rows
    mesh3 = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    dist3 = mine(
        T,
        AprioriConfig(min_support=0.06, max_k=5, count_impl="jnp",
                      data_axes=("pod", "data"), model_axis="model"),
        mesh=mesh3,
    )
    assert dist3.as_dict() == single.as_dict(), "multi-pod != single-node"
    print("INVARIANCE_OK", single.total_frequent)
    """
)


def test_shard_count_invariance_multidevice():
    """Runs in a subprocess with 8 host devices: mining results are invariant
    to the mesh decomposition (1 node == 4x2 == 2x2x2)."""
    proc = subprocess.run(
        [sys.executable, "-c", _INVARIANCE_SCRIPT],
        capture_output=True,
        text=True,
        timeout=600,
        env=subprocess_env(),
        cwd=REPO_ROOT,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "INVARIANCE_OK" in proc.stdout

"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (stdout), mirroring:
  Fig 4  — FHDSC vs FHSSC (heterogeneous straggler penalty + backup recovery)
  Fig 5  — transactions vs configuration (standalone / pseudo / distributed)
  §4 eqn — η = FHDSC/FHSSC and node-count scaling (1..8 host devices)
plus the framework's own kernel/driver benches (support-count kernel,
candidate generation, SON vs level-wise rounds) and the rule-serving engine
(queries/sec of the rule-match kernel path vs per-basket Python matching at
the 4096-basket x 8192-rule acceptance shape, DESIGN.md §8).

Run: PYTHONPATH=src python -m benchmarks.run  [--quick] [--json out.json]

``--json`` additionally emits the rows as machine-readable JSON
(name/us/derived per row + backend metadata) so CI can archive the perf
trajectory (BENCH_*.json artifacts) across PRs. The ``serve_*`` rows
(rule-match engine + online gateway QPS/latency percentiles, §8/§10) are
ALWAYS persisted to ``BENCH_serve.json`` at the repo root — the committed
cross-PR serving-perf trajectory the CI throughput gate reads.
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

import numpy as np

ROWS = []


def row(name, us, derived=""):
    ROWS.append((name, us, derived))
    print(f"{name},{us:.1f},{derived}")


def _time(fn, reps=3):
    fn()  # warmup / compile
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps * 1e6


# ------------------------------------------------------------------ Fig 5 ----
def bench_fig5_transactions(quick=False):
    """Runtime vs DB size, single device (the paper's 'standalone' column)."""
    from repro.core.apriori import AprioriConfig, mine
    from repro.data.synthetic import QuestConfig, gen_transactions

    sizes = [2_000, 4_000, 8_000] if quick else [2_000, 4_000, 8_000, 16_000, 32_000]
    cfg = AprioriConfig(min_support=0.03, max_k=4, count_impl="jnp")
    for n in sizes:
        db = gen_transactions(QuestConfig(num_transactions=n, num_items=256, seed=1))
        us = _time(lambda: mine(db, cfg), reps=1)
        row(f"fig5_standalone_n{n}", us, f"transactions={n}")


def bench_fig5_node_scaling(quick=False):
    """Distributed mode across 1..8 host devices (subprocess per point) —
    the paper's standalone/pseudo/fully-distributed comparison + η ~ ln N."""
    script = r"""
import os, sys, time, json
n_dev = int(sys.argv[1])
os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
import jax
from repro.core.apriori import AprioriConfig, mine
from repro.data.synthetic import QuestConfig, gen_transactions
db = gen_transactions(QuestConfig(num_transactions=%d, num_items=512, seed=1))
mesh = None
kw = {}
if n_dev > 1:
    from repro.launch.mesh import make_auto_mesh
    mesh = make_auto_mesh((n_dev, 1), ("data", "model"))
    kw = dict(data_axes=("data",), model_axis="model")
cfg = AprioriConfig(min_support=0.02, max_k=4, count_impl="jnp", **kw)
mine(db, cfg, mesh=mesh)   # warm
t0 = time.time(); res = mine(db, cfg, mesh=mesh); dt = time.time() - t0
print(json.dumps({"n_dev": n_dev, "seconds": dt, "frequent": res.total_frequent}))
""" % (8_000 if quick else 24_000)
    base = None
    for n_dev in ([1, 2, 4] if quick else [1, 2, 4, 8]):
        proc = subprocess.run(
            [sys.executable, "-c", script, str(n_dev)],
            capture_output=True, text=True, timeout=1800,
            env={"PYTHONPATH": "src", "PATH": os.environ.get("PATH", "/usr/bin:/bin"),
                 "HOME": os.environ.get("HOME", "/root"),
                 "JAX_PLATFORMS": os.environ.get("JAX_PLATFORMS", "cpu")},
        )
        if proc.returncode != 0:
            row(f"fig5_nodes_{n_dev}", -1, "FAILED")
            continue
        out = json.loads(proc.stdout.strip().splitlines()[-1])
        base = base or out["seconds"]
        speedup = base / out["seconds"]
        row(f"fig5_nodes_{n_dev}", out["seconds"] * 1e6,
            f"speedup={speedup:.2f};eta_lnN={np.log(max(n_dev, 2)):.2f}")


# ------------------------------------------------------------------ Fig 4 ----
def bench_fig4_straggler(quick=False):
    """FHDSC vs FHSSC makespans + speculative recovery (paper §4), measured
    through the REAL retrying executor (``distributed.fault_tolerance``).

    Partitions are sleep-calibrated map tasks: the homogeneous pool is the
    paper's FHSSC cluster; one 20x-slow partition emulates the FHDSC
    straggler node. The recovery row re-runs the straggler case with
    speculation ON — the backup copy lands on a fast 'node' (re-invocations
    run at 1x) and the superseded original is abandoned, so the makespan
    collapses toward homogeneous: the paper's Fig-4 story executed rather
    than simulated.
    """
    from repro.distributed.fault_tolerance import FaultConfig, run_partitions

    n_parts = 16 if quick else 32
    base_s = 0.02 if quick else 0.04
    slow = n_parts - 1          # the straggler shard (scheduled last-ish)

    def homogeneous(p):
        time.sleep(base_s)
        return p

    calls: dict = {}
    def heterogeneous(p):
        a = calls.setdefault(p, 0)
        calls[p] = a + 1
        time.sleep(base_s * (20.0 if (p == slow and a == 0) else 1.0))
        return p

    fc = FaultConfig(max_workers=4, speculative=False)
    t0 = time.perf_counter(); run_partitions(homogeneous, n_parts, fc)
    t_fhssc = (time.perf_counter() - t0) * 1e6
    calls.clear()
    t0 = time.perf_counter(); run_partitions(heterogeneous, n_parts, fc)
    t_fhdsc = (time.perf_counter() - t0) * 1e6
    calls.clear()
    spec = FaultConfig(max_workers=4, speculative=True, speculative_factor=2.0)
    t0 = time.perf_counter(); _, rep = run_partitions(heterogeneous, n_parts, spec)
    t_backup = (time.perf_counter() - t0) * 1e6
    row("fig4_fhssc_makespan", t_fhssc, "homogeneous")
    row("fig4_fhdsc_makespan", t_fhdsc, f"eta={t_fhdsc/t_fhssc:.2f}")
    row("fig4_fhdsc_backup", t_backup,
        f"speculative_issued={rep.speculative_issued};"
        f"recovered={100*(t_fhdsc-t_backup)/max(t_fhdsc-t_fhssc,1e-9):.0f}%_of_gap")


# ----------------------------------------------------------------- kernel ----
def bench_kernel_support_count(quick=False):
    """Dense MXU containment matmul vs packed uint32 bitset counting.

    The dense-vs-packed pair always runs at the roofline comparison shape
    (16384, 1024, 4096) — quick mode only drops the rep count — so the
    BENCH_*.json trajectory tracks the same point on every backend.
    """
    import jax
    import jax.numpy as jnp

    from repro.kernels import ops, ref

    n, i, k = 16384, 1024, 4096
    reps = 1 if quick else 3
    rng = np.random.default_rng(0)
    t = jnp.asarray((rng.random((n, i)) < 0.2).astype(np.int8))
    c_np = (rng.random((k, i)) < 0.02).astype(np.int8)
    c_np[c_np.sum(1) == 0, 0] = 1   # every candidate has >= 1 item (lengths contract)
    c = jnp.asarray(c_np)
    lengths = c.sum(1).astype(jnp.int32)

    jit_ref = jax.jit(lambda: ref.support_count_ref(t, c, lengths))
    us_dense = _time(lambda: jit_ref().block_until_ready(), reps=reps)
    flops = 2.0 * n * i * k
    row("kernel_support_ref_jnp", us_dense, f"GFLOP/s={flops/us_dense*1e-3:.1f}")

    # packed counting path (pre-packed operands, device-resident — the
    # format core.apriori keeps across the level loop). 'auto' resolves to
    # the Pallas VPU kernel on TPU, the jnp bitset oracle elsewhere.
    impl = ops.resolve_impl("auto")
    tp, cp = jnp.asarray(np_pack(t)), jnp.asarray(np_pack(c))
    jit_packed = jax.jit(lambda: ops.support_count_packed(tp, cp, lengths, impl="auto"))
    us_packed = _time(lambda: jit_packed().block_until_ready(), reps=reps)
    row(
        "kernel_support_packed_pallas",
        us_packed,
        f"impl={impl};speedup_vs_dense={us_dense/us_packed:.1f}x;"
        f"packed_bytes={(n + k) * (i // 8) / 1e6:.1f}MB",
    )

    # packed path including on-device bit-packing of dense operands
    jit_e2e = jax.jit(lambda: ops.support_count(t, c, lengths, impl="packed"))
    us_e2e = _time(lambda: jit_e2e().block_until_ready(), reps=reps)
    row("kernel_support_packed_with_packing", us_e2e, f"pack_overhead={us_e2e/us_packed:.2f}x")

    # pallas interpret (semantics validation path; wall time not meaningful on CPU)
    small_t, small_c, small_l = t[:512], c[:256], lengths[:256]
    f_pal = lambda: np.asarray(ops.support_count(small_t, small_c, small_l, impl="pallas_interpret"))
    us = _time(f_pal, reps=1)
    row("kernel_support_pallas_interpret_512x256", us, "correctness_path")
    f_pp = lambda: np.asarray(ops.support_count(small_t, small_c, small_l, impl="packed_interpret"))
    us = _time(f_pp, reps=1)
    row("kernel_support_packed_interpret_512x256", us, "correctness_path")


def np_pack(dense):
    from repro.core.itemsets import pack_bits

    return pack_bits(np.asarray(dense))


def bench_candidate_generation(quick=False):
    from repro.core.candidates import generate_candidates, lex_sort_rows

    rng = np.random.default_rng(0)
    f = 2_000 if quick else 20_000
    freq = np.unique(np.sort(rng.integers(0, 400, (f, 3)), axis=1), axis=0)
    freq = freq[(np.diff(freq, axis=1) > 0).all(1)]
    freq = lex_sort_rows(freq)
    us = _time(lambda: generate_candidates(freq), reps=3)
    out = generate_candidates(freq)
    row("driver_candidate_gen_k4", us, f"in={freq.shape[0]};out={out.shape[0]}")


def bench_son_vs_levelwise(quick=False):
    """Distributed ROUNDS (the paper's per-level barrier) vs SON's 2 rounds."""
    from repro.core.apriori import AprioriConfig, mine
    from repro.core.son import mine_son
    from repro.data.synthetic import QuestConfig, gen_transactions

    db = gen_transactions(QuestConfig(num_transactions=6_000 if quick else 12_000,
                                      num_items=256, seed=2))
    cfg = AprioriConfig(min_support=0.03, max_k=5, count_impl="jnp")
    us_lw = _time(lambda: mine(db, cfg), reps=1)
    res = mine(db, cfg)
    rounds_lw = max(res.levels) if res.levels else 0
    us_son = _time(lambda: mine_son(db, cfg, num_partitions=8), reps=1)
    row("son_levelwise", us_lw, f"distributed_rounds={rounds_lw}")
    row("son_two_phase", us_son, "distributed_rounds=2")


# ----------------------------------------------------------------- serving ----
def _synthetic_rulebook(num_rules, num_items, seed=0):
    """Random rulebook at serving-benchmark scale (1-3 item antecedents,
    1-2 item consequents, random scores) — mining wouldn't hit an exact R."""
    from repro.core.itemsets import itemsets_to_packed, packed_words
    from repro.serving.rulebook import Rulebook

    rng = np.random.default_rng(seed)
    picks = rng.random((num_rules, num_items)).argpartition(5, axis=1)[:, :5]
    na = rng.integers(1, 4, num_rules)
    nc = rng.integers(1, 3, num_rules)
    w = packed_words(num_items)
    ante = np.zeros((num_rules, w), np.uint32)
    cons = np.zeros((num_rules, w), np.uint32)
    for s in (1, 2, 3):
        m = na == s
        ante[m] = itemsets_to_packed(picks[m][:, :s], num_items)
    for s in (1, 2):
        m = nc == s
        cons[m] = itemsets_to_packed(picks[m][:, 3 : 3 + s], num_items)
    scores = rng.random(num_rules).astype(np.float32)
    return Rulebook(ante, cons, na.astype(np.int32), scores, num_items)


def bench_serve_gateway(quick=False):
    """Online gateway QPS: micro-batched concurrent clients vs sequential
    single-request serving, plus the hot exact-basket cache path (§10).

    Both QPS rows run with the cache DISABLED so they measure the scheduler
    + match-step path. The sequential baseline runs ``max_wait_ms=0``
    (greedy) so it pays no artificial per-request wait; the micro-batched
    row runs the standard 1 ms coalescing window — the configuration the CI
    throughput gate (micro-batched >= 2x sequential) asserts."""
    from benchmarks.load_gen import closed_loop
    from repro.core.itemsets import pack_bits
    from repro.serving import Gateway

    num_rules, num_items = 4096, 256
    rb = _synthetic_rulebook(num_rules, num_items)
    rng = np.random.default_rng(2)
    baskets = list(pack_bits((rng.random((512, num_items)) < 0.1).astype(np.int8)))
    n_seq = 300 if quick else 1_500
    n_con = 1_500 if quick else 6_000

    with Gateway(rb, max_batch=64, max_wait_ms=0.0, cache_capacity=0) as gw:
        seq = closed_loop(gw, baskets, num_requests=n_seq, concurrency=1)
    row("serve_gateway_sequential", seq["wall_s"] / max(seq["responses"], 1) * 1e6,
        f"qps={seq['qps']:.0f};p50_ms={seq['p50_ms']:.2f};p95_ms={seq['p95_ms']:.2f};"
        f"p99_ms={seq['p99_ms']:.2f};rules={num_rules}")

    with Gateway(rb, max_batch=64, max_wait_ms=1.0, cache_capacity=0,
                 warmup="ladder") as gw:
        con = closed_loop(gw, baskets, num_requests=n_con, concurrency=32)
        occ = gw.metrics.batch_occupancy
    row("serve_gateway_microbatch_c32",
        con["wall_s"] / max(con["responses"], 1) * 1e6,
        f"qps={con['qps']:.0f};p50_ms={con['p50_ms']:.2f};p95_ms={con['p95_ms']:.2f};"
        f"p99_ms={con['p99_ms']:.2f};occupancy={occ:.2f};"
        f"speedup_vs_sequential={con['qps'] / max(seq['qps'], 1e-9):.1f}x")

    # hot-cache path: every basket repeats, second pass all hits
    with Gateway(rb, max_batch=64, max_wait_ms=1.0, cache_capacity=1024) as gw:
        closed_loop(gw, baskets[:64], num_requests=64, concurrency=8)   # fill
        hot = closed_loop(gw, baskets[:64], num_requests=512, concurrency=8)
        hit_rate = gw.cache.hit_rate
    row("serve_gateway_cache_hot",
        hot["wall_s"] / max(hot["responses"], 1) * 1e6,
        f"qps={hot['qps']:.0f};hit_rate={hit_rate:.2f};p50_ms={hot['p50_ms']:.3f}")


def bench_replicated_serve(quick=False):
    """Replicated serving tier (§12): N-replica scaling + kill-mid-load
    recovery.

    The scaling pair is a CACHE-PARTITIONING experiment, robust on any core
    count: the working set is 384 distinct baskets accessed cyclically —
    the LRU worst case — against a 256-entry per-replica cache. One replica
    thrashes (every pass re-evicts what the previous pass cached, ~0% hits,
    every request runs the match step); two replicas consistent-hash the
    set into ~192-basket shards that FIT, so repeat passes serve from the
    exact-basket cache. That is the router's cache argument measured: the
    CI scaling gate asserts 2-replica QPS >= 1.5x single-replica.

    The kill row drives a closed loop while a replica's dispatch worker is
    killed mid-load (in-worker SystemExit, batch in flight): supervisor
    restart + failover must keep availability — answered / admitted — at
    >= 99% (the CI availability gate), with every loss a typed failure.
    """
    import threading

    from benchmarks.load_gen import closed_loop
    from repro.core.itemsets import pack_bits
    from repro.distributed import FaultConfig
    from repro.serving import DeadlineExceeded, Router, WorkerCrashed

    num_rules, num_items, working_set, cache = 2048, 256, 384, 256
    rb = _synthetic_rulebook(num_rules, num_items, seed=3)
    rng = np.random.default_rng(4)
    baskets = list(pack_bits((rng.random((working_set, num_items)) < 0.1).astype(np.int8)))
    passes = 4 if quick else 8
    n_req = passes * working_set

    qps = {}
    for n_rep in (1, 2):
        with Router(rb, n_rep, max_batch=64, max_wait_ms=1.0,
                    cache_capacity=cache, warmup="ladder") as r:
            closed_loop(r, baskets, num_requests=working_set, concurrency=16)  # fill
            res = closed_loop(r, baskets, num_requests=n_req, concurrency=16)
            hits = sum(rep.gateway.metrics.cache_hits for rep in r._replicas)
            total = hits + sum(rep.gateway.metrics.cache_misses for rep in r._replicas)
        qps[n_rep] = res["qps"]
        derived = (f"qps={res['qps']:.0f};hit_rate={hits / max(total, 1):.2f};"
                   f"p50_ms={res['p50_ms']:.2f};p99_ms={res['p99_ms']:.2f};"
                   f"working_set={working_set};cache_per_replica={cache}")
        if n_rep == 2:
            derived += f";scaling_vs_r1={qps[2] / max(qps[1], 1e-9):.2f}x"
        row(f"serve_replicated_r{n_rep}",
            res["wall_s"] / max(res["responses"], 1) * 1e6, derived)

    # ---- kill a replica mid-load, measure availability -------------------
    n_kill = 1_000 if quick else 2_500
    with Router(rb, 2, max_batch=64, max_wait_ms=1.0, cache_capacity=0,
                attempt_timeout_s=1.0,
                fault=FaultConfig(max_retries=3, backoff_s=0.01)) as r:
        out: dict = {}

        def load():
            out.update(closed_loop(
                r, baskets, num_requests=n_kill, concurrency=16,
                tolerate=(WorkerCrashed, DeadlineExceeded),
            ))

        t = threading.Thread(target=load)
        t.start()
        while r.metrics.routed < n_kill // 2 and t.is_alive():
            time.sleep(0.002)
        r.fault_injection.kill_replica(0)      # SystemExit with batch in flight
        t.join()
        restarts = sum(r.supervisor.stats()["restarts"])
        failovers = r.metrics.failovers
        kills = r.fault_injection.kills_fired
    admitted = out["responses"] + out["failed"]
    availability = out["responses"] / max(admitted, 1)
    row("serve_replicated_kill_recovery",
        out["wall_s"] / max(out["responses"], 1) * 1e6,
        f"availability={availability:.4f};failed={out['failed']};"
        f"kills_fired={kills};restarts={restarts};failovers={failovers};"
        f"qps={out['qps']:.0f};p99_ms={out['p99_ms']:.2f}")


def bench_rule_serving(quick=False):
    """Rule-match serving engine QPS: kernel path vs per-basket Python.

    Always runs at the acceptance shape (4096 baskets x 8192 rules, 256
    items) so the BENCH_*.json trajectory tracks the same point; quick mode
    only drops reps and the Python-baseline subset size (per-basket cost is
    constant, so its QPS doesn't depend on the subset)."""
    from repro.core.itemsets import pack_bits
    from repro.kernels import ops
    from repro.serving.recommend import recommend, recommend_python, rulebook_as_python

    num_rules, num_items, b_kernel = 8192, 256, 4096
    rb = _synthetic_rulebook(num_rules, num_items)
    rng = np.random.default_rng(1)
    b_packed = pack_bits((rng.random((b_kernel, num_items)) < 0.1).astype(np.int8))

    b_py = 64 if quick else 256
    decoded = rulebook_as_python(rb)
    us_py = _time(
        lambda: recommend_python(rb, b_packed[:b_py], top_k=10, decoded=decoded), reps=1
    )
    qps_py = b_py / (us_py / 1e6)
    row("serve_rulematch_python", us_py,
        f"qps={qps_py:.0f};baskets={b_py};rules={num_rules}")

    impl = ops.resolve_impl("auto")
    fn = lambda: recommend(rb, b_packed, top_k=10, batch_size=1024, impl="auto",
                           block_n=512)   # large-batch serving block
    us_k = _time(fn, reps=1 if quick else 3)
    qps_k = b_kernel / (us_k / 1e6)
    row("serve_rulematch_kernel", us_k,
        f"impl={impl};qps={qps_k:.0f};baskets={b_kernel};rules={num_rules};"
        f"speedup_vs_python={qps_k / qps_py:.1f}x")

    # interpret-mode kernel body (semantics validation; wall time not meaningful)
    us_i = _time(
        lambda: recommend(rb, b_packed[:256], top_k=10, batch_size=256,
                          impl="pallas_interpret"),
        reps=1,
    )
    row("serve_rulematch_interpret_256", us_i, "correctness_path")


def bench_mine_representations(quick=False):
    """End-to-end mine(): dense vs packed device representation."""
    from repro.core.apriori import AprioriConfig, mine
    from repro.data.synthetic import QuestConfig, gen_transactions

    n = 4_000 if quick else 16_000
    db = gen_transactions(QuestConfig(num_transactions=n, num_items=512, seed=1))
    cfg_d = AprioriConfig(min_support=0.02, max_k=4, count_impl="auto")
    us_dense = _time(lambda: mine(db, cfg_d), reps=1)
    row(f"mine_dense_n{n}", us_dense, f"transactions={n}")
    cfg_p = AprioriConfig(min_support=0.02, max_k=4, count_impl="auto", representation="packed")
    us_packed = _time(lambda: mine(db, cfg_p), reps=1)
    row(f"mine_packed_n{n}", us_packed,
        f"transactions={n};speedup_vs_dense={us_dense/us_packed:.2f}x")


# ------------------------------------------------------------- out-of-core ----
_OOC_SCRIPT = r"""
import os, sys, json, time, resource, tempfile, shutil
mode, n, items, chunk = sys.argv[1], int(sys.argv[2]), int(sys.argv[3]), int(sys.argv[4])
import jax  # noqa: F401  (import before measuring: exclude the runtime arena)
from repro.core.apriori import AprioriConfig, mine
from repro.data.synthetic import QuestConfig, gen_transactions
qcfg = QuestConfig(num_transactions=n, num_items=items, avg_len=10, seed=5)
cfg = AprioriConfig(min_support=0.02, max_k=3, count_impl="jnp", representation="packed")
rss0 = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
t0 = time.time()
if mode == "inmem":
    db = gen_transactions(qcfg)              # the dense materialization
    res = mine(db, cfg)
else:
    from repro.core.streaming import mine_streamed
    from repro.data.store import ingest_quest
    d = tempfile.mkdtemp(prefix="bench_store_")
    try:
        store = ingest_quest(qcfg, d, shard_rows=chunk, chunk_rows=chunk)
        res = mine_streamed(store, cfg, chunk_rows=chunk)
    finally:
        shutil.rmtree(d, ignore_errors=True)
dt = time.time() - t0
rss1 = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
print(json.dumps({"seconds": dt, "peak_rss_delta_mb": (rss1 - rss0) / 1024.0,
                  "frequent": res.total_frequent}))
"""


def bench_out_of_core(quick=False):
    """Streamed vs in-memory mining: wall time AND peak host RSS (§9).

    One subprocess per mode so ``ru_maxrss`` (a process-lifetime high-water
    mark) isolates each driver's own peak. The shape is FIXED (60000 x 1024,
    chunk 2048) in quick mode too, so the BENCH_*.json trajectory and the CI
    RSS gate always compare the same point: the in-memory driver must
    materialize the 60 MB dense matrix; the streamed driver's working set is
    the 2048-row chunk (~0.3 MB packed) + candidate tensors.
    """
    n, items, chunk = 60_000, 1024, 2_048
    outs = {}
    for mode in ("inmem", "stream"):
        proc = subprocess.run(
            [sys.executable, "-c", _OOC_SCRIPT, mode, str(n), str(items), str(chunk)],
            capture_output=True, text=True, timeout=1800,
            env={"PYTHONPATH": "src", "PATH": os.environ.get("PATH", "/usr/bin:/bin"),
                 "HOME": os.environ.get("HOME", "/root"),
                 "JAX_PLATFORMS": os.environ.get("JAX_PLATFORMS", "cpu")},
        )
        if proc.returncode != 0:
            row(f"ooc_mine_{mode}_n{n}", -1, "FAILED")
            return
        outs[mode] = json.loads(proc.stdout.strip().splitlines()[-1])
    inmem, stream = outs["inmem"], outs["stream"]
    assert inmem["frequent"] == stream["frequent"], "streamed result drifted"
    row(f"ooc_mine_inmem_n{n}", inmem["seconds"] * 1e6,
        f"peak_rss_mb={inmem['peak_rss_delta_mb']:.1f};frequent={inmem['frequent']}")
    row(f"ooc_mine_streamed_n{n}", stream["seconds"] * 1e6,
        f"peak_rss_mb={stream['peak_rss_delta_mb']:.1f};chunk_rows={chunk};"
        f"rss_vs_inmem={stream['peak_rss_delta_mb']/max(inmem['peak_rss_delta_mb'],1e-9):.2f}x;"
        f"frequent={stream['frequent']}")


# ---------------------------------------------------------- fault tolerance ----
_FT_SCRIPT = r"""
import hashlib, json, os, signal, sys, time
mode, store_dir, chunk, every = sys.argv[1], sys.argv[2], int(sys.argv[3]), int(sys.argv[4])
import jax  # noqa: F401  (import before measuring: exclude the runtime arena)
from repro.core.apriori import AprioriConfig
cfg = AprioriConfig(min_support=0.02, max_k=3, count_impl="jnp", representation="packed")

if mode == "prep":
    from repro.data.store import ingest_quest
    from repro.data.synthetic import QuestConfig
    qcfg = QuestConfig(num_transactions=60_000, num_items=1024, avg_len=10, seed=5)
    store = ingest_quest(qcfg, store_dir, shard_rows=chunk, chunk_rows=chunk)
    print(json.dumps({"n": store.num_transactions}))
    sys.exit(0)

from repro.core.streaming import mine_streamed
from repro.data.store import open_store
from repro.distributed.checkpoint import MiningCheckpoint
store = open_store(store_dir)

def sig(res):
    blob = json.dumps(sorted(
        (k, res.levels[k][0].tolist(), res.levels[k][1].tolist()) for k in res.levels
    ))
    return hashlib.md5(blob.encode()).hexdigest()

if mode == "plain":
    t0 = time.time(); res = mine_streamed(store, cfg, chunk_rows=chunk); dt = time.time() - t0
    print(json.dumps({"seconds": dt, "frequent": res.total_frequent, "sig": sig(res)}))
elif mode == "chk":
    class Counting(MiningCheckpoint):
        saves = 0
        def save(self, *a, **kw):
            Counting.saves += 1
            return super().save(*a, **kw)
    m = Counting(store.checkpoint_path)
    t0 = time.time()
    res = mine_streamed(store, cfg, chunk_rows=chunk, checkpoint=m,
                        checkpoint_every_chunks=every)
    dt = time.time() - t0
    print(json.dumps({"seconds": dt, "frequent": res.total_frequent, "sig": sig(res),
                      "saves": Counting.saves}))
elif mode == "kill":
    class Killing(MiningCheckpoint):
        def save(self, state, *a, **kw):
            seq = super().save(state, *a, **kw)
            if state.mid_level and state.next_k >= 2:
                self.wait()                       # the snapshot IS committed
                os.kill(os.getpid(), signal.SIGKILL)
            return seq
    mine_streamed(store, cfg, chunk_rows=chunk, checkpoint=Killing(store.checkpoint_path),
                  checkpoint_every_chunks=every)
    print(json.dumps({"error": "kill never fired"}))   # reaching here is a failure
elif mode == "resume":
    m = MiningCheckpoint(store.checkpoint_path)
    state, manifest = m.load_latest()
    t0 = time.time()
    res = mine_streamed(store, cfg, chunk_rows=chunk, checkpoint=m, resume=True,
                        checkpoint_every_chunks=every)
    dt = time.time() - t0
    print(json.dumps({"seconds": dt, "frequent": res.total_frequent, "sig": sig(res),
                      "restored_levels": len(state.levels),
                      "replayed_levels": 1 if state.mid_level else 0,
                      "resumed_at_level": state.next_k,
                      "chunks_already_folded": state.chunks_done}))
"""


def _ft_run(mode, store_dir, chunk, every, check=True):
    proc = subprocess.run(
        [sys.executable, "-c", _FT_SCRIPT, mode, store_dir, str(chunk), str(every)],
        capture_output=True, text=True, timeout=1800,
        env={"PYTHONPATH": "src", "PATH": os.environ.get("PATH", "/usr/bin:/bin"),
             "HOME": os.environ.get("HOME", "/root"),
             "JAX_PLATFORMS": os.environ.get("JAX_PLATFORMS", "cpu")},
    )
    if check and proc.returncode != 0:
        raise RuntimeError(f"fault bench {mode} failed: {proc.stderr[-2000:]}")
    return proc


def bench_fault_tolerance(quick=False):
    """Checkpoint overhead + kill-and-resume recovery of the streamed miner
    (DESIGN.md §11), at the SAME fixed shape as the out-of-core bench
    (60000 x 1024, chunk 2048) so the trajectories are comparable.

    Three measured points, one subprocess each: an un-checkpointed mine, a
    checkpointed mine (every 8 chunks — the CI gate asserts <= 1.10x), and a
    mine SIGKILL'd at the first committed mid-level snapshot of level 2,
    then resumed — the resumed result must hash-match the uninterrupted one
    and recovery replays ONLY the unfinished level (completed levels are
    restored, not recounted).
    """
    chunk, every = 2_048, 8
    import tempfile, shutil
    d = tempfile.mkdtemp(prefix="bench_fault_store_")
    try:
        _ft_run("prep", d, chunk, every)
        plain = json.loads(_ft_run("plain", d, chunk, every).stdout.strip().splitlines()[-1])
        chk = json.loads(_ft_run("chk", d, chunk, every).stdout.strip().splitlines()[-1])
        assert chk["sig"] == plain["sig"], "checkpointed mine drifted"
        overhead = chk["seconds"] / max(plain["seconds"], 1e-9)
        row(f"fault_mine_unchk_n60000", plain["seconds"] * 1e6,
            f"frequent={plain['frequent']}")
        row(f"fault_mine_chk_n60000", chk["seconds"] * 1e6,
            f"overhead_vs_unchk={overhead:.3f}x;saves={chk['saves']};every={every}")

        killed = _ft_run("kill", d, chunk, every, check=False)
        if killed.returncode == 0:
            row("fault_kill_resume_n60000", -1, "FAILED_kill_never_fired")
            return
        res = json.loads(_ft_run("resume", d, chunk, every).stdout.strip().splitlines()[-1])
        assert res["sig"] == plain["sig"], "resumed mine drifted from uninterrupted"
        row("fault_kill_resume_n60000", res["seconds"] * 1e6,
            f"parity=ok;restored_levels={res['restored_levels']};"
            f"replayed_levels={res['replayed_levels']};"
            f"resumed_at_level={res['resumed_at_level']};"
            f"chunks_already_folded={res['chunks_already_folded']};"
            f"recovery_vs_full={res['seconds']/max(plain['seconds'],1e-9):.2f}x")
    finally:
        shutil.rmtree(d, ignore_errors=True)


# ------------------------------------------------------------ incremental ----
def bench_incremental(quick=False):
    """Delta refresh latency vs full re-mine at 1% / 5% / 20% appended rows
    (DESIGN.md §15), FIXED shape (40000 x 256, max_k 3) in quick mode too so
    the trajectory always compares the same point.

    For each delta point the base store (carrying its persisted count cache)
    is cloned, FRAC·n new rows are appended, and the grown store is mined
    both ways: a full SON re-mine and ``core.incremental.mine_delta`` (fold
    cached counts arithmetically, re-verify only novel candidates over the
    base shards). The two results must be dict-identical — parity is part of
    the row, and the CI invariant gate holds the 1% point to >= 3x over full.
    """
    import shutil
    import tempfile

    from repro.core import incremental as inc
    from repro.core.apriori import AprioriConfig
    from repro.core.streaming import mine_son_streamed
    from repro.data.store import append_chunks, ingest_quest, open_store
    from repro.data.synthetic import QuestConfig, gen_transactions_chunked

    n, items, chunk = 40_000, 256, 4_096
    cfg = AprioriConfig(min_support=0.02, max_k=3, count_impl="jnp",
                        representation="packed")
    base_dir = tempfile.mkdtemp(prefix="bench_incr_base_")
    clones = []
    try:
        store = ingest_quest(
            QuestConfig(num_transactions=n, num_items=items, seed=11),
            base_dir, shard_rows=chunk, chunk_rows=chunk)
        inc.build_count_cache(store, cfg, chunk_rows=chunk)  # also warms jit
        # largest delta first: it absorbs the delta path's one-off compiles,
        # so the gated 1% point measures the warm steady state
        for pct in (20, 5, 1):
            d = tempfile.mkdtemp(prefix=f"bench_incr_p{pct}_")
            clones.append(d)
            shutil.rmtree(d)
            shutil.copytree(base_dir, d)
            extra = n * pct // 100
            append_chunks(
                gen_transactions_chunked(
                    QuestConfig(num_transactions=extra, num_items=items,
                                seed=100 + pct), chunk),
                d)
            grown = open_store(d)
            t0 = time.perf_counter()
            full = mine_son_streamed(grown, cfg, chunk_rows=chunk)
            full_s = time.perf_counter() - t0
            t0 = time.perf_counter()
            res, rep = inc.mine_delta(grown, cfg, chunk_rows=chunk)
            delta_s = time.perf_counter() - t0
            parity = "ok" if res.as_dict() == full.as_dict() else "DRIFTED"
            row(f"fault_refresh_full_p{pct}", full_s * 1e6,
                f"rows={grown.num_transactions};frequent={full.total_frequent}")
            row(f"fault_refresh_delta_p{pct}", delta_s * 1e6,
                f"speedup_vs_full={full_s / max(delta_s, 1e-9):.2f}x;"
                f"mode={rep.mode};delta_rows={rep.delta_rows};"
                f"novel={rep.novel_candidates};parity={parity}")
    finally:
        shutil.rmtree(base_dir, ignore_errors=True)
        for d in clones:
            shutil.rmtree(d, ignore_errors=True)


# ------------------------------------------------------------- observability ----
_OBS_SCRIPT = r"""
import hashlib, json, sys, time
store_dir, chunk = sys.argv[1], int(sys.argv[2])
import jax  # noqa: F401  (import before measuring: exclude the runtime arena)
from repro.core.apriori import AprioriConfig
from repro.core.streaming import mine_streamed
from repro.data.store import open_store
from repro.obs import MetricsRegistry, MiningObs, Tracer
cfg = AprioriConfig(min_support=0.02, max_k=3, count_impl="jnp", representation="packed")
store = open_store(store_dir)

def sig(res):
    blob = json.dumps(sorted(
        (k, res.levels[k][0].tolist(), res.levels[k][1].tolist()) for k in res.levels
    ))
    return hashlib.md5(blob.encode()).hexdigest()

# Both modes run INTERLEAVED in this one process: machine-state drift (load,
# page cache) hits both equally, and the shared jit cache means each
# plain/obs pair isolates pure instrumentation overhead — the thing the
# gate bounds.  A single ~0.8 s streamed mine jitters by several percent
# from one-off spikes (GC, scheduler), so the overhead is the ratio of
# MINIMA over 5 reps each — min is the spike-free estimate of true runtime.
times = {"plain": [], "obs": []}
sigs, counters = {}, None
for rep in range(5):
    for mode in ("plain", "obs"):
        obs = None
        if mode == "obs":      # fresh counters per rep: no cross-run doubling
            obs = MiningObs(registry=MetricsRegistry(), tracer=Tracer(sample_rate=1.0))
        t0 = time.time()
        res = mine_streamed(store, cfg, chunk_rows=chunk, obs=obs)
        dt = time.time() - t0
        times[mode].append(dt)
        sigs[mode] = sig(res)
        if obs is not None:
            snap = obs.counters()
            counters = {k: v for k, v in snap.items() if not isinstance(v, dict)}
overhead = min(times["obs"]) / min(times["plain"])
print(json.dumps({"plain_seconds": min(times["plain"]),
                  "obs_seconds": min(times["obs"]), "overhead": overhead,
                  "frequent": res.total_frequent, "plain_sig": sigs["plain"],
                  "obs_sig": sigs["obs"], "counters": counters}))
"""


def _obs_run(store_dir, chunk):
    proc = subprocess.run(
        [sys.executable, "-c", _OBS_SCRIPT, store_dir, str(chunk)],
        capture_output=True, text=True, timeout=1800,
        env={"PYTHONPATH": "src", "PATH": os.environ.get("PATH", "/usr/bin:/bin"),
             "HOME": os.environ.get("HOME", "/root"),
             "JAX_PLATFORMS": os.environ.get("JAX_PLATFORMS", "cpu")},
    )
    if proc.returncode != 0:
        raise RuntimeError(f"obs bench failed: {proc.stderr[-2000:]}")
    return json.loads(proc.stdout.strip().splitlines()[-1])


def bench_observability(quick=False):
    """Observability overhead + the p99 request breakdown (DESIGN.md §13).

    Overhead pair: the streamed mine at the SAME fixed shape as the
    out-of-core / fault benches (60000 x 1024, chunk 2048), both modes
    interleaved in one subprocess so drift hits them equally, overhead =
    ratio of min-of-5 runtimes; the instrumented mode runs with
    full counters AND a 100%-sampled tracer — the worst obs configuration —
    and must hash-match the plain result (provable inertness) while staying
    within the CI overhead gate (<= 1.05x).

    Breakdown row: a 100%-sampled gateway under concurrent load; every
    request span carries queue/batch-assembly/device wall-time attributes,
    so "where does the p99 request actually go" is read straight off the
    sampled spans instead of guessed from aggregate percentiles.
    """
    import shutil
    import tempfile

    chunk = 2_048
    d = tempfile.mkdtemp(prefix="bench_obs_store_")
    try:
        _ft_run("prep", d, chunk, 0)
        pair = _obs_run(d, chunk)
        assert pair["obs_sig"] == pair["plain_sig"], "instrumented mine drifted"
        overhead = pair["overhead"]
        c = pair["counters"]
        row("obs_mine_plain_n60000", pair["plain_seconds"] * 1e6,
            f"frequent={pair['frequent']}")
        row("obs_mine_instrumented_n60000", pair["obs_seconds"] * 1e6,
            f"overhead_vs_plain={overhead:.3f}x;parity=ok;"
            f"chunks={c.get('mine_chunks_streamed', 0)};"
            f"rows={c.get('mine_rows_streamed', 0)};"
            f"levels={c.get('mine_levels', 0)}")
    finally:
        shutil.rmtree(d, ignore_errors=True)

    # ---- where does the p99 request go? (sampled-span breakdown) ---------
    from benchmarks.load_gen import closed_loop
    from repro.core.itemsets import pack_bits
    from repro.obs import Tracer
    from repro.serving import Gateway

    num_rules, num_items = 4096, 256
    rb = _synthetic_rulebook(num_rules, num_items)
    rng = np.random.default_rng(2)
    baskets = list(pack_bits((rng.random((512, num_items)) < 0.1).astype(np.int8)))
    n_req = 1_500 if quick else 6_000
    tracer = Tracer(sample_rate=1.0, capacity=2 * n_req)
    with Gateway(rb, max_batch=64, max_wait_ms=1.0, cache_capacity=0,
                 warmup="ladder", tracer=tracer) as gw:
        closed_loop(gw, baskets, num_requests=n_req, concurrency=32)
    reqs = [s for s in tracer.spans()
            if s.name == "gateway.request" and "queue_ms" in s.attrs]
    reqs.sort(key=lambda s: s.duration_s())
    if not reqs:
        row("obs_p99_breakdown", -1, "FAILED_no_sampled_requests")
        return
    p99 = reqs[min(len(reqs) - 1, int(0.99 * len(reqs)))]
    total_ms = p99.duration_s() * 1e3
    row("obs_p99_breakdown", total_ms * 1e3,
        f"queue_ms={p99.attrs['queue_ms']:.2f};"
        f"batch_ms={p99.attrs['batch_ms']:.3f};"
        f"device_ms={p99.attrs['device_ms']:.2f};"
        f"total_ms={total_ms:.2f};sampled={len(reqs)}")


_HISTORY_CAP = 20


def bench_slo(quick=False):
    """Closed-loop p99 batching (§14): adaptive max_wait vs a fixed wait.

    Both gateways run the SAME deliberately mis-tuned 20 ms straggler wait
    against a 5 ms p99 objective at low concurrency (batches never fill, so
    a fixed-wait worker sits out the full window on every batch — the
    configuration a static tune gets wrong under a shifted load shape). The
    fixed gateway pays the window at p99; the adaptive gateway's AIMD
    controller watches the windowed p99 burn past the objective and shrinks
    the wait toward the greedy floor. The CI gate asserts
    ``toward_objective=yes``: |p99_adaptive - objective| <
    |p99_fixed - objective| — the controller demonstrably steers p99 toward
    the SLO. Bit-identity is untouched (only batching timing changes)."""
    from benchmarks.load_gen import closed_loop
    from repro.core.itemsets import pack_bits
    from repro.serving import Gateway

    num_rules, num_items = 4096, 256
    objective_ms = 5.0
    rb = _synthetic_rulebook(num_rules, num_items)
    rng = np.random.default_rng(6)
    baskets = list(pack_bits((rng.random((512, num_items)) < 0.1).astype(np.int8)))
    n_req = 1_200 if quick else 3_000

    with Gateway(rb, max_batch=64, max_wait_ms=20.0, cache_capacity=0,
                 warmup="ladder") as gw:
        fixed = closed_loop(gw, baskets, num_requests=n_req, concurrency=8)
    row("obs_slo_fixed_wait",
        fixed["wall_s"] / max(fixed["responses"], 1) * 1e6,
        f"qps={fixed['qps']:.0f};p99_ms={fixed['p99_ms']:.2f};"
        f"objective_ms={objective_ms};max_wait_ms=20.0")

    with Gateway(rb, max_batch=64, max_wait_ms=20.0, p99_target_ms=objective_ms,
                 cache_capacity=0, warmup="ladder") as gw:
        adapt = closed_loop(gw, baskets, num_requests=n_req, concurrency=8)
        ctrl = gw.wait_controller.snapshot()
    toward = (abs(adapt["p99_ms"] - objective_ms)
              < abs(fixed["p99_ms"] - objective_ms))
    row("obs_slo_adaptive_wait",
        adapt["wall_s"] / max(adapt["responses"], 1) * 1e6,
        f"qps={adapt['qps']:.0f};p99_ms={adapt['p99_ms']:.2f};"
        f"objective_ms={objective_ms};fixed_p99_ms={fixed['p99_ms']:.2f};"
        f"final_wait_ms={ctrl['wait_ms']:.2f};ticks={ctrl['ticks']};"
        f"decreases={ctrl['decreases']};"
        f"toward_objective={'yes' if toward else 'no'}")


def _persist_trajectory(path, new_rows, backend, quick):
    """Merge-update a committed BENCH_*.json trajectory file.

    Rows are keyed by ``name``: a re-run bench REPLACES its own rows and
    every other committed row survives — a partial run can no longer
    clobber the whole trajectory — and the file is stamped with THIS run's
    actual wall-clock time (each file gets its own fresh stamp, not one
    shared timestamp taken before any bench ran).

    When a row is replaced, the superseded ``us_per_call`` is appended to
    the row's ``history`` (bounded at the newest %d values) — the
    per-row trajectory ``repro.obs.regress`` computes its noise-aware
    baseline from. FAILED markers (negative values) never enter history.
    """ % _HISTORY_CAP
    existing = []
    if os.path.exists(path):
        try:
            with open(path) as f:
                existing = json.load(f).get("rows", [])
        except (json.JSONDecodeError, OSError):
            existing = []          # unreadable trajectory: rebuild from this run
    fresh = {r["name"] for r in new_rows}
    prior = {r.get("name"): r for r in existing}
    for r in new_rows:
        old = prior.get(r["name"])
        hist = list(old.get("history", ())) if old else []
        if old is not None:
            old_us = old.get("us_per_call")
            if isinstance(old_us, (int, float)) and old_us >= 0:
                hist.append(old_us)
        r["history"] = hist[-_HISTORY_CAP:]
    rows = [r for r in existing if r.get("name") not in fresh] + new_rows
    with open(path, "w") as f:
        json.dump({"backend": backend, "quick": quick, "unix_time": time.time(),
                   "rows": rows}, f, indent=2)
    return len(rows)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", default=None, metavar="OUT", help="also write rows as JSON")
    args, _ = ap.parse_known_args()
    q = args.quick

    print("name,us_per_call,derived")
    bench_fig5_transactions(q)
    bench_fig5_node_scaling(q)
    bench_fig4_straggler(q)
    bench_kernel_support_count(q)
    bench_candidate_generation(q)
    bench_son_vs_levelwise(q)
    bench_mine_representations(q)
    bench_out_of_core(q)
    bench_fault_tolerance(q)
    bench_incremental(q)
    bench_rule_serving(q)
    bench_serve_gateway(q)
    bench_replicated_serve(q)
    bench_observability(q)
    bench_slo(q)

    import jax

    backend = jax.default_backend()
    payload = {
        "backend": backend,
        "quick": q,
        "unix_time": time.time(),
        "rows": [{"name": n, "us_per_call": u, "derived": d} for n, u, d in ROWS],
    }
    if args.json:
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"# wrote {len(ROWS)} rows to {args.json}", file=sys.stderr)

    repo_root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

    # the serving trajectory is ALWAYS persisted at the repo root so QPS +
    # latency percentiles are comparable across PRs (CI gates read this)
    serve_rows = [r for r in payload["rows"] if r["name"].startswith("serve_")]
    serve_path = os.path.join(repo_root, "BENCH_serve.json")
    n_rows = _persist_trajectory(serve_path, serve_rows, backend, q)
    print(f"# merged {len(serve_rows)} serving rows into {serve_path} "
          f"({n_rows} total)", file=sys.stderr)

    # ... and the fault-tolerance trajectory (checkpoint overhead + recovery),
    # the committed numbers the CI checkpoint-overhead gate reads (§11)
    fault_rows = [r for r in payload["rows"] if r["name"].startswith("fault_")]
    if fault_rows:
        fault_path = os.path.join(repo_root, "BENCH_fault.json")
        n_rows = _persist_trajectory(fault_path, fault_rows, backend, q)
        print(f"# merged {len(fault_rows)} fault rows into {fault_path} "
              f"({n_rows} total)", file=sys.stderr)

    # ... and the observability trajectory (instrumentation overhead + p99
    # breakdown), the committed numbers the CI overhead gate reads (§13)
    obs_rows = [r for r in payload["rows"] if r["name"].startswith("obs_")]
    if obs_rows:
        obs_path = os.path.join(repo_root, "BENCH_obs.json")
        n_rows = _persist_trajectory(obs_path, obs_rows, backend, q)
        print(f"# merged {len(obs_rows)} obs rows into {obs_path} "
              f"({n_rows} total)", file=sys.stderr)


if __name__ == "__main__":
    main()

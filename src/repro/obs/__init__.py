"""Shared observability substrate: metrics registry, span tracer, mining
job counters (DESIGN.md §13)."""

from .registry import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    Sampler,
)
from .trace import Span, Tracer
from .mining import MiningObs, MiningProgress, PHASES

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "MiningObs",
    "MiningProgress",
    "PHASES",
    "Sampler",
    "Span",
    "Tracer",
]

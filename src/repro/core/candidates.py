"""Level-k candidate generation — the Hadoop *driver* step of the paper.

Classical Apriori join + prune, fully vectorised NumPy (data-dependent shapes
stay on the host, exactly as candidate generation runs on the Hadoop namenode
in the paper).  Frequent itemsets are (F, k) int32 arrays with item ids
ascending within each row and rows in lexicographic order; both invariants are
preserved by construction.
"""

from __future__ import annotations

import numpy as np


def _row_view(a: np.ndarray) -> np.ndarray:
    """View (F, k) rows as a 1-D structured array for set operations."""
    a = np.ascontiguousarray(a)
    return a.view([("", a.dtype)] * a.shape[1]).ravel()


def rows_isin(queries: np.ndarray, table: np.ndarray) -> np.ndarray:
    """Row-wise membership: queries (Q, k) in table (T, k) -> bool (Q,)."""
    if table.shape[0] == 0:
        return np.zeros(queries.shape[0], dtype=bool)
    if queries.shape[1] != table.shape[1]:
        raise ValueError("row width mismatch")
    return np.isin(_row_view(queries), _row_view(table))


def lex_sort_rows(a: np.ndarray) -> np.ndarray:
    """Sort rows lexicographically (first column most significant)."""
    if a.shape[0] == 0:
        return a
    order = np.lexsort(a.T[::-1])
    return a[order]


def generate_candidates(frequent: np.ndarray) -> np.ndarray:
    """F_{k-1} ⋈ F_{k-1} join + downward-closure prune -> candidates (C, k).

    ``frequent``: (F, k-1) lexicographically sorted itemsets. Two itemsets
    sharing their first k-2 items join into a k-candidate; the prune keeps
    only candidates whose every (k-1)-subset is frequent.
    """
    frequent = np.asarray(frequent, dtype=np.int32)
    f, km1 = frequent.shape
    if f < 2:
        return np.zeros((0, km1 + 1), dtype=np.int32)

    # --- join: group rows by their (k-2)-prefix; groups are contiguous. ---
    if km1 == 1:
        group_change = np.zeros(f - 1, dtype=bool)  # single global group
    else:
        prefix = frequent[:, :-1]
        group_change = np.any(prefix[1:] != prefix[:-1], axis=1)
    group_id = np.concatenate([[0], np.cumsum(group_change)])
    sizes = np.bincount(group_id)
    starts = np.concatenate([[0], np.cumsum(sizes)[:-1]])
    local = np.arange(f) - starts[group_id]

    # each row pairs with the (g - 1 - local) rows after it in its group
    reps = sizes[group_id] - 1 - local
    total = int(reps.sum())
    if total == 0:
        return np.zeros((0, km1 + 1), dtype=np.int32)
    a_idx = np.repeat(np.arange(f), reps)
    seg_start = np.concatenate([[0], np.cumsum(reps)[:-1]])
    b_idx = a_idx + 1 + (np.arange(total) - np.repeat(seg_start, reps))
    candidates = np.concatenate([frequent[a_idx], frequent[b_idx][:, -1:]], axis=1)

    # --- prune: every (k-1)-subset must be frequent. Dropping the last or ---
    # second-to-last column reproduces the two parents (frequent by
    # construction), so only columns 0..k-3 need checking.
    keep = np.ones(candidates.shape[0], dtype=bool)
    for drop in range(km1 - 1):
        sub = np.delete(candidates, drop, axis=1)
        keep &= rows_isin(sub, frequent)
    return candidates[keep]


def all_k_subsets_of_universe(num_items: int, k: int) -> np.ndarray:
    """Paper-faithful naive enumeration (§3.3 'all the subsets'). Exponential —
    only used by the fidelity baseline on small vocabularies."""
    from itertools import combinations

    combos = np.fromiter(
        (i for combo in combinations(range(num_items), k) for i in combo),
        dtype=np.int32,
    )
    return combos.reshape(-1, k)

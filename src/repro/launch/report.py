"""Aggregate experiments/dryrun/*.json into the EXPERIMENTS.md tables."""

from __future__ import annotations

import glob
import json
import os


def load_cells(path="experiments/dryrun"):
    cells = []
    for f in sorted(glob.glob(os.path.join(path, "*.json"))):
        txt = open(f).read()
        start = txt.find("{")
        if start < 0:
            continue
        try:
            cells.append(json.loads(txt[start:]))
        except json.JSONDecodeError:
            continue
    return cells


def fmt_bytes(b):
    if b >= 1e12:
        return f"{b/1e12:.2f}TB"
    if b >= 1e9:
        return f"{b/1e9:.2f}GB"
    if b >= 1e6:
        return f"{b/1e6:.1f}MB"
    return f"{b/1e3:.0f}KB"


def fmt_s(x):
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.2f}ms"
    return f"{x*1e6:.1f}us"


def dryrun_table(cells, mesh="single"):
    rows = [
        "| arch | shape | status | compile | args/dev | temps/dev | collectives (count) |",
        "|---|---|---|---|---|---|---|",
    ]
    for c in cells:
        if c.get("mesh") != mesh:
            continue
        if c["status"] == "skipped":
            rows.append(f"| {c['arch']} | {c['shape']} | SKIP (full-attn @500k) | — | — | — | — |")
            continue
        if c["status"] != "ok":
            rows.append(f"| {c['arch']} | {c['shape']} | **{c['status']}** | — | — | — | — |")
            continue
        m = c["memory"]
        counts = ", ".join(f"{k}:{int(v)}" for k, v in sorted(c["hlo"]["collective_counts"].items()))
        rows.append(
            f"| {c['arch']} | {c['shape']} | ok | {c['compile_s']:.0f}s "
            f"| {fmt_bytes(m['argument_bytes_per_dev'])} | {fmt_bytes(m['temp_bytes_per_dev'])} "
            f"| {counts} |"
        )
    return "\n".join(rows)


def roofline_table(cells):
    rows = [
        "| arch | shape | FLOPs/dev | HBM B/dev | coll B/dev | compute | memory | collective | dominant | MODEL/HLO |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for c in cells:
        if c.get("mesh") != "single" or c["status"] != "ok":
            continue
        h, r = c["hlo"], c["roofline"]
        rows.append(
            f"| {c['arch']} | {c['shape']} | {h['flops']:.2e} | {h['hbm_bytes']:.2e} "
            f"| {h['collective_bytes']:.2e} | {fmt_s(r['compute_s'])} | {fmt_s(r['memory_s'])} "
            f"| {fmt_s(r['collective_s'])} | **{r['dominant']}** | {c['useful_flops_ratio']:.3f} |"
        )
    return "\n".join(rows)


def summary_stats(cells):
    ok = [c for c in cells if c["status"] == "ok"]
    skipped = [c for c in cells if c["status"] == "skipped"]
    failed = [c for c in cells if c["status"] not in ("ok", "skipped")]
    return {
        "ok": len(ok),
        "skipped": len(skipped),
        "failed": len(failed),
        "single": len([c for c in ok if c["mesh"] == "single"]),
        "multi": len([c for c in ok if c["mesh"] == "multi"]),
    }


if __name__ == "__main__":
    cells = load_cells()
    print(summary_stats(cells))
    print()
    print(roofline_table(cells))

"""Per-architecture smoke tests: REDUCED same-family configs, one forward /
train-grad / decode step on CPU; shape + finiteness asserts; decode path
cross-checked against the full forward (cache correctness)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models.transformer import (
    decode_step,
    forward,
    init_decode_cache,
    init_model,
    loss_fn,
    prefill_step,
)

LM_ARCHS = [a for a in ARCH_IDS if a != "apriori"]


def _batch_for(cfg, b=2, s=16, seed=0):
    rng = np.random.default_rng(seed)
    batch = {}
    if cfg.frontend == "tokens":
        batch["tokens"] = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
        batch["labels"] = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    elif cfg.frontend == "frames":
        batch["frames"] = jnp.asarray(rng.standard_normal((b, s, cfg.d_model)), jnp.float32)
        batch["labels"] = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    elif cfg.frontend == "vlm":
        p = cfg.num_patches
        batch["patches"] = jnp.asarray(rng.standard_normal((b, p, cfg.d_model)), jnp.float32)
        batch["tokens"] = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
        batch["labels"] = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    return batch


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_forward_and_shapes(arch):
    cfg = get_config(arch).reduced()
    params = init_model(jax.random.key(0), cfg)
    batch = _batch_for(cfg, b=2, s=16)
    logits, aux = forward(params, cfg, batch)
    s_total = 16 + (cfg.num_patches if cfg.frontend == "vlm" else 0)
    assert logits.shape == (2, s_total, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_train_grad_step(arch):
    cfg = get_config(arch).reduced()
    params = init_model(jax.random.key(1), cfg)
    batch = _batch_for(cfg, b=2, s=16, seed=1)
    (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, cfg, batch)
    assert np.isfinite(float(loss)) and float(loss) > 0
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in jax.tree.leaves(grads)))
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0
    # one SGD step changes the loss
    new_params = jax.tree.map(lambda p, g: p - 0.1 * g.astype(p.dtype), params, grads)
    loss2, _ = loss_fn(new_params, cfg, batch)
    assert float(loss2) != float(loss)


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_prefill_then_decode_matches_forward(arch):
    """Cache correctness: prefill(x[:t]) + decode(x[t]) == forward(x[:t+1])[-1]."""
    cfg = get_config(arch).reduced()
    if cfg.frontend == "vlm":
        pytest.skip("vlm decode tested via backbone archs (text-only decode path)")
    params = init_model(jax.random.key(2), cfg)
    t = 12
    cache_len = 32
    batch = _batch_for(cfg, b=2, s=t + 1, seed=2)

    full_logits, _ = forward(params, cfg, batch)

    if cfg.frontend == "frames":
        prompt = {"frames": batch["frames"][:, :t]}
        nxt = batch["frames"][:, t : t + 1]
    else:
        prompt = {"tokens": batch["tokens"][:, :t]}
        nxt = batch["tokens"][:, t : t + 1]

    last_logits, cache = prefill_step(params, cfg, prompt, cache_len)
    np.testing.assert_allclose(
        np.asarray(last_logits), np.asarray(full_logits[:, t - 1]), rtol=2e-4, atol=2e-4
    )

    pos = jnp.full((2,), t, jnp.int32)
    dec_logits, _ = decode_step(params, cfg, cache, nxt, pos)
    np.testing.assert_allclose(
        np.asarray(dec_logits), np.asarray(full_logits[:, t]), rtol=2e-4, atol=2e-4
    )


def test_full_configs_match_assignment():
    """Pin the exact published numbers (the full configs are dry-run-only)."""
    c = get_config("qwen1p5_110b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff, c.vocab_size) == (
        80, 8192, 64, 8, 49152, 152064) and c.qkv_bias
    c = get_config("zamba2_2p7b")
    assert c.block_type == "zamba_hybrid" and c.ssm.state_dim == 64 and c.num_layers == 54
    c = get_config("dbrx_132b")
    assert c.moe.num_experts == 16 and c.moe.top_k == 4 and c.moe.d_ff_expert == 10752
    c = get_config("granite_moe_3b_a800m")
    assert c.moe.num_experts == 40 and c.moe.top_k == 8 and c.moe.e_padded == 48
    c = get_config("minicpm3_4b")
    assert c.attn_type == "mla" and c.mla.kv_lora_rank == 256
    c = get_config("rwkv6_1p6b")
    assert c.block_type == "rwkv6" and c.vocab_size == 65536
    c = get_config("musicgen_medium")
    assert c.frontend == "frames" and c.vocab_size == 2048
    c = get_config("internvl2_2b")
    assert c.frontend == "vlm" and c.vocab_size == 92553

"""Unified metrics registry (obs.registry): histogram merge ≡ union,
atomic snapshots under concurrent writers, prometheus exposition, and the
Sampler's JSONL time series (DESIGN.md §13)."""

import json
import threading

import numpy as np
import pytest

from repro.obs.registry import Counter, Gauge, Histogram, MetricsRegistry, Sampler


# ---------------------------------------------------------------- histogram --

def test_histogram_merge_equals_recording_the_union():
    """Property: merging per-replica histograms is indistinguishable from one
    histogram that recorded every sample — same count/sum/min/max and same
    quantiles (bucket resolution is identical, so equality is EXACT)."""
    rng = np.random.default_rng(42)
    for trial in range(5):
        parts = [Histogram() for _ in range(4)]
        union = Histogram()
        for h in parts:
            for v in rng.lognormal(mean=-6.0, sigma=2.0, size=rng.integers(1, 200)):
                h.record(float(v))
                union.record(float(v))
        merged = Histogram.merged(parts)
        assert merged.count == union.count
        assert merged.sum == pytest.approx(union.sum)
        assert merged.min == union.min
        assert merged.max == union.max
        for q in (0.0, 0.5, 0.9, 0.95, 0.99, 1.0):
            assert merged.quantile(q) == union.quantile(q), (trial, q)
        assert merged.snapshot() == pytest.approx(union.snapshot())


def test_histogram_quantile_conservative():
    """Quantiles come from bucket upper edges: never below the true value,
    and clamped to the observed max."""
    h = Histogram()
    samples = [0.001, 0.002, 0.004, 0.010, 0.100]
    for s in samples:
        h.record(s)
    assert h.quantile(1.0) == pytest.approx(0.100)
    assert h.quantile(0.5) >= 0.004 * (1 - 1e-9)
    assert h.quantile(0.0) >= 0.001 * (1 - 1e-9)


def test_histogram_merge_from_empty_and_into_empty():
    a, b = Histogram(), Histogram()
    a.record(0.01)
    b.merge_from(a)                      # into empty
    assert b.count == 1 and b.min == a.min and b.max == a.max
    b.merge_from(Histogram())            # from empty: no-op
    assert b.count == 1


# ----------------------------------------------------------------- registry --

def test_registry_get_or_create_and_labels():
    reg = MetricsRegistry()
    c1 = reg.counter("jobs", labels={"level": "1"})
    c2 = reg.counter("jobs", labels={"level": "1"})
    c3 = reg.counter("jobs", labels={"level": "2"})
    assert c1 is c2 and c1 is not c3
    c1.inc(3)
    snap = reg.snapshot()
    assert snap['jobs{level="1"}'] == 3
    assert snap['jobs{level="2"}'] == 0


def test_registry_snapshot_is_atomic_under_concurrent_writers():
    """Writers keep two counters in lockstep (+2 real / +4 padded per batch);
    every registry snapshot must observe them at an exact 0.5 ratio — a torn
    read would show a ratio off by one update."""
    reg = MetricsRegistry()
    real = reg.counter("rows_real")
    padded = reg.counter("rows_padded")
    stop = threading.Event()

    def writer():
        while not stop.is_set():
            with reg.lock:
                real.inc(2)
                padded.inc(4)

    threads = [threading.Thread(target=writer, daemon=True) for _ in range(4)]
    for t in threads:
        t.start()
    try:
        for _ in range(300):
            snap = reg.snapshot()
            r, p = snap["rows_real"], snap["rows_padded"]
            assert r * 2 == p, f"torn snapshot: real={r} padded={p}"
    finally:
        stop.set()
        for t in threads:
            t.join(timeout=5)


def test_prometheus_exposition_shape():
    reg = MetricsRegistry()
    reg.counter("requests", labels={"route": "mine"}).inc(7)
    reg.gauge("depth").set(3)
    reg.histogram("latency_seconds").record(0.004)
    text = reg.to_prometheus()
    assert '# TYPE requests counter' in text
    assert 'requests{route="mine"} 7' in text
    assert '# TYPE depth gauge' in text
    assert "depth 3" in text
    assert "# TYPE latency_seconds histogram" in text
    assert "latency_seconds_count 1" in text
    assert "latency_seconds_sum" in text
    # cumulative buckets end at +Inf with the total count
    assert 'latency_seconds_bucket{le="+Inf"} 1' in text


def test_gauge_max_and_counter_monotonic():
    reg = MetricsRegistry()
    g = reg.gauge("peak")
    g.max(4.0)
    g.max(2.0)
    assert g.value == 4.0
    with pytest.raises(ValueError):
        reg.counter("c").inc(-1)


# ------------------------------------------------------------------ sampler --

def test_sampler_jsonl_round_trip(tmp_path):
    reg = MetricsRegistry()
    c = reg.counter("events")
    path = tmp_path / "series.jsonl"
    with Sampler(reg, str(path), interval_s=0.01) as s:
        for i in range(5):
            c.inc()
    assert s.samples_written >= 1           # stop() always writes a final one
    lines = [json.loads(line) for line in path.read_text().splitlines()]
    assert len(lines) == s.samples_written
    for rec in lines:
        assert set(rec) == {"t", "metrics"}
        assert rec["metrics"]["events"] <= 5
    # the series is monotone in t and in the counter
    ts = [rec["t"] for rec in lines]
    vals = [rec["metrics"]["events"] for rec in lines]
    assert ts == sorted(ts)
    assert vals == sorted(vals)
    assert vals[-1] == 5                    # final sample sees the last inc


# -------------------------------------------- prometheus exposition (§14) --

def test_prometheus_label_values_are_escaped():
    reg = MetricsRegistry()
    reg.counter("events", labels={"path": 'a\\b"c\nd'}).inc(1)
    text = reg.to_prometheus()
    # backslash, double-quote and newline escaped per the text format
    assert 'events{path="a\\\\b\\"c\\nd"} 1' in text
    assert text.count("\n# TYPE") + 1 == 1      # one family, one TYPE line


def test_prometheus_histogram_series_are_consistent():
    """``_bucket`` counts are cumulative, ``le`` edges are the histogram's
    real bucket edges in increasing order ending at +Inf with the total,
    and ``_count`` / ``_sum`` reconcile with the recorded samples."""
    import re

    reg = MetricsRegistry()
    h = reg.histogram("lat_seconds", labels={"tier": "serve"})
    samples = [0.001, 0.001, 0.004, 0.050, 1.5]
    for s in samples:
        h.record(s)
    text = reg.to_prometheus()
    bucket_lines = re.findall(
        r'lat_seconds_bucket\{le="([^"]+)",tier="serve"\} (\d+)', text)
    assert bucket_lines[-1][0] == "+Inf"
    assert int(bucket_lines[-1][1]) == len(samples)
    edges = [float(le) for le, _ in bucket_lines[:-1]]
    cums = [int(c) for _, c in bucket_lines]
    assert edges == sorted(edges)               # increasing le edges
    assert cums == sorted(cums)                 # cumulative counts
    # every edge must actually cover its cumulative count of samples
    for le, cum in zip(edges, cums):
        assert sum(1 for s in samples if s <= le) >= cum
    assert f"lat_seconds_count{{tier=\"serve\"}} {len(samples)}" in text
    m = re.search(r'lat_seconds_sum\{tier="serve"\} ([0-9.e+-]+)', text)
    assert float(m.group(1)) == pytest.approx(sum(samples), rel=1e-6)


def test_raw_snapshot_shape_and_differencing():
    """The SLO evaluator's input: full-resolution histogram counts that can
    be differenced between cuts, plus plain floats for counters/gauges."""
    reg = MetricsRegistry()
    c = reg.counter("done")
    reg.gauge("depth").set(2.0)
    h = reg.histogram("lat_seconds")
    h.record(0.004)
    cut0 = reg.raw_snapshot()
    assert cut0["done"] == 0.0 and cut0["depth"] == 2.0
    hs = cut0["lat_seconds"]
    assert hs["kind"] == "histogram" and hs["count"] == 1
    assert sum(hs["counts"]) == 1 and hs["sum"] == pytest.approx(0.004)
    c.inc(3)
    h.record(0.100)
    cut1 = reg.raw_snapshot()
    assert cut1["done"] - cut0["done"] == 3.0
    delta = [a - b for a, b in zip(cut1["lat_seconds"]["counts"], hs["counts"])]
    assert sum(delta) == 1                      # exactly the new sample
    assert cut0["lat_seconds"]["counts"] is not cut1["lat_seconds"]["counts"]

"""Property tests (hypothesis) for the host-side driver: encodings + join."""

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis (see requirements.txt)")
from hypothesis import given, settings
from hypothesis import strategies as st
from itertools import combinations

from repro.core.candidates import generate_candidates, lex_sort_rows, rows_isin
from repro.core.itemsets import (
    dense_from_lists,
    itemsets_to_dense,
    pack_bits,
    singleton_itemsets,
    unpack_bits,
)


@st.composite
def itemset_table(draw, k=None):
    k = k if k is not None else draw(st.integers(1, 4))
    num_items = draw(st.integers(k, 24))
    n_rows = draw(st.integers(2, 40))
    rows = {
        tuple(sorted(draw(st.permutations(range(num_items)))[:k])) for _ in range(n_rows)
    }
    return np.array(sorted(rows), dtype=np.int32), num_items


@given(itemset_table())
@settings(max_examples=60, deadline=None)
def test_generate_candidates_matches_definition(table):
    """Join+prune == {all (k+1)-sets whose every k-subset is in F_k}."""
    freq, num_items = table
    k = freq.shape[1]
    got = {tuple(r) for r in generate_candidates(freq)}
    fset = {tuple(r) for r in freq}
    items = sorted({int(i) for r in freq for i in r})
    expect = {
        c
        for c in combinations(items, k + 1)
        if all(tuple(sorted(s)) in fset for s in combinations(c, k))
    }
    assert got == expect


@given(itemset_table())
@settings(max_examples=40, deadline=None)
def test_candidates_sorted_and_unique(table):
    freq, _ = table
    cands = generate_candidates(freq)
    if cands.shape[0] == 0:
        return
    # ascending within rows
    assert (np.diff(cands, axis=1) > 0).all()
    # unique rows
    assert np.unique(cands, axis=0).shape[0] == cands.shape[0]


@given(st.lists(st.lists(st.integers(0, 63), max_size=20), min_size=1, max_size=30))
@settings(max_examples=50, deadline=None)
def test_pack_unpack_roundtrip(lists):
    dense = dense_from_lists([set(l) for l in lists], 64)
    assert (unpack_bits(pack_bits(dense), 64) == dense).all()


@given(st.integers(1, 100), st.integers(1, 5))
@settings(max_examples=30, deadline=None)
def test_itemsets_to_dense_rowsums(num_items_extra, k):
    num_items = k + num_items_extra
    rng = np.random.default_rng(k)
    sets = np.sort(rng.choice(num_items, size=(7, k), replace=True), axis=1)
    # dedupe within rows for valid itemsets
    sets = np.array([sorted(set(r.tolist()))[:k] for r in sets if len(set(r.tolist())) >= k])
    if sets.size == 0:
        return
    dense = itemsets_to_dense(sets, num_items)
    assert (dense.sum(1) == sets.shape[1]).all()


def test_rows_isin_and_lexsort():
    table = np.array([[0, 1], [0, 2], [1, 2]], np.int32)
    q = np.array([[0, 1], [1, 3], [1, 2]], np.int32)
    assert rows_isin(q, table).tolist() == [True, False, True]
    shuffled = table[::-1].copy()
    assert (lex_sort_rows(shuffled) == table).all()
    assert singleton_itemsets(3).tolist() == [[0], [1], [2]]

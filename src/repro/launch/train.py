"""End-to-end training driver.

CPU-scale run (reduced or small-preset configs) with the full production
stack: sharded data pipeline, AdamW, checkpoint/restart supervisor, optional
multi-device mesh via --host-devices (subprocess re-exec sets XLA_FLAGS).

  PYTHONPATH=src python -m repro.launch.train --arch qwen1p5_4b --preset 10m \
      --steps 100 --batch 8 --seq 256 --ckpt /tmp/ckpt
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time


PRESETS = {
    # ~param-count presets for CPU-runnable end-to-end training
    "smoke": dict(num_layers=2, d_model=128, num_heads=4, num_kv_heads=4, d_ff=256, vocab_size=1024),
    "10m": dict(num_layers=6, d_model=320, num_heads=8, num_kv_heads=8, d_ff=1280, vocab_size=8192),
    "100m": dict(num_layers=12, d_model=768, num_heads=12, num_kv_heads=12, d_ff=3072, vocab_size=32768),
    "full": {},
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1p5_4b")
    ap.add_argument("--preset", default="10m", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--host-devices", type=int, default=0, help="re-exec with N fake devices")
    ap.add_argument("--mesh", default="", help="e.g. 4x2 (data x model); needs --host-devices")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    if args.host_devices and "XLA_FLAGS" not in os.environ:
        os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={args.host_devices}"
        os.execv(sys.executable, [sys.executable] + sys.argv)

    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs import get_config
    from repro.distributed.sharding import ShardingRules
    from repro.models.shard_ctx import activation_sharding
    from repro.training.optimizer import AdamWConfig
    from repro.training.train_loop import init_train_state, make_train_step, state_specs

    base = get_config(args.arch)
    if args.preset == "full":
        cfg = base
    else:
        over = dict(PRESETS[args.preset])
        if base.num_kv_heads < base.num_heads:  # keep the family's GQA ratio
            over["num_kv_heads"] = max(1, over["num_heads"] // 2)
        cfg = base.reduced(**over, compute_dtype="float32", remat=True)
    print(f"[train] arch={cfg.name} preset={args.preset} "
          f"L={cfg.num_layers} d={cfg.d_model} vocab={cfg.vocab_size}")

    mesh = None
    rules = ShardingRules()
    if args.mesh:
        dd, mm = (int(x) for x in args.mesh.split("x"))
        from repro.launch.mesh import make_auto_mesh

        mesh = make_auto_mesh((dd, mm), ("data", "model"))

    opt_cfg = AdamWConfig(peak_lr=args.lr, warmup_steps=max(5, args.steps // 20),
                          decay_steps=args.steps)
    state = init_train_state(jax.random.key(0), cfg)
    ctx = activation_sharding(mesh, rules.dp_axes, rules.tensor_axis) if mesh else None

    if mesh is not None:
        specs = state_specs(state, mesh, rules)
        state = jax.device_put(state, jax.tree.map(
            lambda s: NamedSharding(mesh, s), specs, is_leaf=lambda s: isinstance(s, P)))
    if ctx:
        ctx.__enter__()
    step_fn = make_train_step(cfg, opt_cfg, mesh=mesh, rules=rules,
                              microbatches=args.microbatches, donate=False)

    def batch_fn(step):
        rng = np.random.default_rng((1234, step))
        toks = rng.integers(0, cfg.vocab_size, (args.batch, args.seq + 1), dtype=np.int64)
        b = {"tokens": jnp.asarray(toks[:, :-1], jnp.int32),
             "labels": jnp.asarray(toks[:, 1:], jnp.int32)}
        if mesh is not None:
            b = jax.device_put(b, NamedSharding(mesh, P(("data",), None)))
        return b

    losses = []
    t0 = time.time()
    if args.ckpt:
        from repro.distributed.fault_tolerance import Supervisor

        sup = Supervisor(args.ckpt, lambda n: mesh, lambda m, s: step_fn,
                         checkpoint_every=args.ckpt_every)
        state, history, info = sup.run(state, None, batch_fn, args.steps, num_nodes=1)
        losses = [h["loss"] for h in history]
    else:
        for step in range(args.steps):
            state, metrics = step_fn(state, batch_fn(step))
            losses.append(float(metrics["loss"]))
            if step % args.log_every == 0 or step == args.steps - 1:
                print(f"  step {step:5d}  loss {losses[-1]:.4f}  "
                      f"lr {float(metrics['lr']):.2e}  gnorm {float(metrics['grad_norm']):.2f}")
    dt = time.time() - t0
    tok_s = args.steps * args.batch * args.seq / dt
    print(f"[train] done: {args.steps} steps in {dt:.1f}s ({tok_s:.0f} tok/s); "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    print(json.dumps({"first_loss": losses[0], "last_loss": losses[-1],
                      "tokens_per_s": tok_s}))
    if ctx:
        ctx.__exit__(None, None, None)


if __name__ == "__main__":
    main()

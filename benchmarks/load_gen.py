"""Open/closed-loop load generators for the serving gateway (DESIGN.md §10).

Two standard load shapes against a :class:`repro.serving.Gateway`:

* **closed loop** — ``concurrency`` client threads, each submitting its next
  basket only after its previous response arrives. Measures the gateway's
  sustainable throughput at a given client population (the micro-batcher
  back-builds batches while the device is busy).
* **open loop** — requests fired on a fixed-rate schedule regardless of
  completions (the arrival process of independent web users). Overload shows
  up as admission rejects + latency growth instead of silently throttling
  the generator.

Both return one plain dict: achieved QPS, exact p50/p95/p99 from the raw
latency samples (the gateway's own histogram is the bucketed view of the
same numbers), rejects, cache hits, and the set of rulebook generations
that answered — the fields the bench rows, the serve CLI and the CI gates
consume.
"""

from __future__ import annotations

import itertools
import threading
import time
from concurrent.futures import ThreadPoolExecutor

import numpy as np

from repro.serving import AdmissionRejected


def _summarize(latencies, rejected, generations, cached, wall_s, failed=0) -> dict:
    lat = np.asarray(sorted(latencies), dtype=np.float64)
    pct = lambda q: float(np.percentile(lat, q)) * 1e3 if lat.size else 0.0
    return {
        "responses": int(lat.size),
        "rejected": int(rejected),
        "failed": int(failed),
        "cached": int(cached),
        "generations": sorted(generations),
        "wall_s": wall_s,
        "qps": lat.size / wall_s if wall_s > 0 else 0.0,
        "p50_ms": pct(50),
        "p95_ms": pct(95),
        "p99_ms": pct(99),
    }


def closed_loop(gateway, baskets, *, num_requests: int, concurrency: int,
                top_k: int = 10, tolerate: tuple = ()) -> dict:
    """``concurrency`` synchronous clients round-robin over ``baskets``.

    ``tolerate`` lists exception types counted into ``failed`` instead of
    crashing the client thread — the chaos benches pass the router's typed
    outcomes (``WorkerCrashed``, ``DeadlineExceeded``) so availability is
    measured, not aborted, while anything untyped still surfaces loudly."""
    counter = itertools.count()
    lock = threading.Lock()
    latencies, generations = [], set()
    rejected = cached = failed = 0

    def client():
        nonlocal rejected, cached, failed
        while True:
            i = next(counter)
            if i >= num_requests:
                return
            try:
                resp = gateway.submit(baskets[i % len(baskets)], top_k).result(timeout=120)
            except AdmissionRejected:
                with lock:
                    rejected += 1
                continue
            except tolerate:
                with lock:
                    failed += 1
                continue
            with lock:
                latencies.append(resp.latency_s)
                generations.add(resp.generation)
                cached += resp.cached

    t0 = time.perf_counter()
    with ThreadPoolExecutor(max_workers=concurrency) as pool:
        workers = [pool.submit(client) for _ in range(concurrency)]
    wall = time.perf_counter() - t0
    for w in workers:           # surface client-thread failures, don't swallow
        w.result()
    return _summarize(latencies, rejected, generations, cached, wall, failed)


def open_loop(gateway, baskets, *, rate_qps: float, duration_s: float,
              top_k: int = 10) -> dict:
    """Fixed-rate arrivals for ``duration_s``; completions collected after."""
    period = 1.0 / rate_qps
    futures, rejected = [], 0
    t0 = time.perf_counter()
    n = 0
    while True:
        now = time.perf_counter()
        if now - t0 >= duration_s:
            break
        target = t0 + n * period
        if now < target:
            time.sleep(min(target - now, 0.005))
            continue
        try:
            futures.append(gateway.submit(baskets[n % len(baskets)], top_k))
        except AdmissionRejected:
            rejected += 1
        n += 1
    latencies, generations = [], set()
    cached = 0
    for f in futures:
        resp = f.result(timeout=120)
        latencies.append(resp.latency_s)
        generations.add(resp.generation)
        cached += resp.cached
    wall = time.perf_counter() - t0
    out = _summarize(latencies, rejected, generations, cached, wall)
    out["offered_qps"] = rate_qps
    return out

"""IBM Quest-style synthetic transaction generator (the T10I4D family used by
the Apriori literature, incl. the datasets the paper's testbed mimics).

Transactions are built from a pool of 'potentially frequent' patterns: each
transaction draws a few patterns (sizes ~ Poisson(pattern_len)), keeps each
pattern item with prob (1 - corruption), and tops up with zipf-weighted noise
items until ~Poisson(avg_len) items. Deterministic under seed.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class QuestConfig:
    num_transactions: int = 10_000
    num_items: int = 512
    avg_len: float = 10.0          # T in T10I4D
    num_patterns: int = 64
    avg_pattern_len: float = 4.0   # I in T10I4D
    corruption: float = 0.35
    patterns_per_txn: float = 1.5
    zipf_a: float = 1.3            # item popularity skew for noise items
    seed: int = 0


def gen_transactions(cfg: QuestConfig = QuestConfig()) -> np.ndarray:
    """Returns dense {0,1} int8 (num_transactions, num_items)."""
    rng = np.random.default_rng(cfg.seed)
    n, i = cfg.num_transactions, cfg.num_items

    # item popularity (zipf-ish, normalized)
    weights = 1.0 / np.power(np.arange(1, i + 1, dtype=np.float64), cfg.zipf_a)
    weights /= weights.sum()

    # pattern pool
    patterns = []
    for _ in range(cfg.num_patterns):
        size = max(2, rng.poisson(cfg.avg_pattern_len))
        size = min(size, i)
        patterns.append(rng.choice(i, size=size, replace=False, p=weights))

    out = np.zeros((n, i), dtype=np.int8)
    n_pat = rng.poisson(cfg.patterns_per_txn, size=n)
    txn_len = np.maximum(1, rng.poisson(cfg.avg_len, size=n))
    pat_weights = 1.0 / np.arange(1, cfg.num_patterns + 1, dtype=np.float64)
    pat_weights /= pat_weights.sum()
    for t in range(n):
        for _ in range(n_pat[t]):
            pat = patterns[rng.choice(cfg.num_patterns, p=pat_weights)]
            keep = rng.random(pat.size) > cfg.corruption
            out[t, pat[keep]] = 1
        deficit = txn_len[t] - int(out[t].sum())
        if deficit > 0:
            noise = rng.choice(i, size=min(deficit, i), replace=False, p=weights)
            out[t, noise] = 1
    return out


def gen_transaction_lists(cfg: QuestConfig = QuestConfig()) -> list:
    dense = gen_transactions(cfg)
    return [np.flatnonzero(row).tolist() for row in dense]

from repro.kernels import ops, ref
from repro.kernels.ops import support_count

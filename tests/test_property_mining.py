"""Hypothesis property tests on the mining system's invariants."""

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis (see requirements.txt)")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.apriori import AprioriConfig, mine


@st.composite
def random_db(draw):
    n = draw(st.integers(20, 120))
    items = draw(st.integers(6, 20))
    density = draw(st.floats(0.1, 0.5))
    seed = draw(st.integers(0, 2**16))
    rng = np.random.default_rng(seed)
    return (rng.random((n, items)) < density).astype(np.int8)


@given(random_db(), st.floats(0.05, 0.5))
@settings(max_examples=25, deadline=None)
def test_downward_closure_and_support_bounds(db, min_support):
    """Anti-monotonicity: every subset of a frequent itemset is frequent with
    support >= the superset's; all supports lie in [min_count, N]."""
    res = mine(db, AprioriConfig(min_support=min_support, max_k=4, count_impl="jnp"))
    d = res.as_dict()
    n = db.shape[0]
    for itemset, sup in d.items():
        assert res.min_count <= sup <= n
        if len(itemset) >= 2:
            for drop in range(len(itemset)):
                sub = tuple(x for j, x in enumerate(itemset) if j != drop)
                assert sub in d, f"subset {sub} of frequent {itemset} missing"
                assert d[sub] >= sup


@given(random_db())
@settings(max_examples=15, deadline=None)
def test_threshold_monotonicity(db):
    """Raising min_support can only shrink the frequent set (and the survivors
    keep identical supports)."""
    lo = mine(db, AprioriConfig(min_support=0.1, max_k=3, count_impl="jnp")).as_dict()
    hi = mine(db, AprioriConfig(min_support=0.3, max_k=3, count_impl="jnp")).as_dict()
    assert set(hi) <= set(lo)
    for k, v in hi.items():
        assert lo[k] == v


@given(random_db(), st.integers(2, 5))
@settings(max_examples=10, deadline=None)
def test_row_permutation_invariance(db, seed):
    """Transaction order must not matter (the Map phase is a bag, not a list)."""
    rng = np.random.default_rng(seed)
    perm = rng.permutation(db.shape[0])
    cfg = AprioriConfig(min_support=0.15, max_k=3, count_impl="jnp")
    assert mine(db, cfg).as_dict() == mine(db[perm], cfg).as_dict()


@given(random_db())
@settings(max_examples=10, deadline=None)
def test_supports_equal_exact_counts(db):
    """Reported support == literal containment count for every winner."""
    res = mine(db, AprioriConfig(min_support=0.2, max_k=3, count_impl="jnp"))
    for itemset, sup in list(res.as_dict().items())[:50]:
        mask = db[:, list(itemset)].all(axis=1)
        assert int(mask.sum()) == sup

"""Attention blocks: GQA (optional qkv-bias) and MLA (latent KV compression).

Prefill/train uses ``chunked_attention`` — an online-softmax scan over KV
blocks (flash-attention dataflow in pure JAX), so the S×S score matrix is
never materialised; on TPU backends kernels/flash_attention.py provides the
Pallas version of the same contraction. Decode attends one query against the
(padded, position-masked) cache.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, dense_init, rmsnorm_init, rmsnorm_apply, vzero

_NEG = -1e30


def chunked_attention(q, k, v, *, causal: bool = True, block_k: int = 512, q_offset=0):
    """Online-softmax attention.

    q: (B, Sq, H, Dqk); k: (B, Sk, KVH, Dqk); v: (B, Sk, KVH, Dv) — MLA uses
    Dv != Dqk. H % KVH == 0.
    q_offset: global position of q[0] relative to k[0] (prefill: Sk - Sq).
    """
    b, sq, h, d = q.shape
    _, sk, kvh, _ = k.shape
    dv = v.shape[-1]
    g = h // kvh
    scale = d ** -0.5
    qr = (q * scale).reshape(b, sq, kvh, g, d)

    pad = (-sk) % block_k
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nblk = k.shape[1] // block_k
    kb = jnp.moveaxis(k.reshape(b, nblk, block_k, kvh, d), 1, 0)
    vb = jnp.moveaxis(v.reshape(b, nblk, block_k, kvh, dv), 1, 0)

    qpos = q_offset + jnp.arange(sq)

    def step(carry, blk):
        m, l, acc, blk_idx = carry
        kblk, vblk = blk
        logits = jnp.einsum(
            "bqhgd,bkhd->bqhgk", qr, kblk, preferred_element_type=jnp.float32
        )
        kpos = blk_idx * block_k + jnp.arange(block_k)
        mask = kpos[None, :] < sk  # padding
        if causal:
            mask = mask & (qpos[:, None] >= kpos[None, :])
        logits = jnp.where(mask[None, :, None, None, :], logits, _NEG)
        m_new = jnp.maximum(m, logits.max(-1))
        p = jnp.exp(logits - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bqhgk,bkhd->bqhgd", p.astype(vblk.dtype), vblk, preferred_element_type=jnp.float32
        )
        return (m_new, l_new, acc_new, blk_idx + 1), None

    vz = vzero(qr)  # vma-correct carry seeds (see layers.vzero)
    m0 = jnp.full((b, sq, kvh, g), _NEG, jnp.float32) + vz
    l0 = jnp.zeros((b, sq, kvh, g), jnp.float32) + vz
    acc0 = jnp.zeros((b, sq, kvh, g, dv), jnp.float32) + vz
    (m, l, acc, _), _ = jax.lax.scan(step, (m0, l0, acc0, jnp.int32(0)), (kb, vb))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, sq, h, dv).astype(q.dtype)


def decode_attention(q, k_cache, v_cache, pos):
    """One-step attention: q (B, 1, H, Dqk) vs cache (B, S, KVH, Dqk/Dv);
    positions > pos are masked (cache is pre-allocated to max length)."""
    b, _, h, d = q.shape
    _, s, kvh, _ = k_cache.shape
    dv = v_cache.shape[-1]
    g = h // kvh
    qr = (q[:, 0] * (d ** -0.5)).reshape(b, kvh, g, d)
    logits = jnp.einsum("bhgd,bshd->bhgs", qr, k_cache, preferred_element_type=jnp.float32)
    valid = jnp.arange(s)[None, :] <= pos[:, None]  # (B, S)
    logits = jnp.where(valid[:, None, None, :], logits, _NEG)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", probs.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, 1, h, dv).astype(q.dtype)


# ------------------------------------------------------------------ GQA ----
def gqa_init(key, cfg):
    d, h, kvh, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, h * dh)),
        "wk": dense_init(ks[1], (d, kvh * dh)),
        "wv": dense_init(ks[2], (d, kvh * dh)),
        "wo": dense_init(ks[3], (h * dh, d)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * dh,), jnp.float32)
        p["bk"] = jnp.zeros((kvh * dh,), jnp.float32)
        p["bv"] = jnp.zeros((kvh * dh,), jnp.float32)
    return p


def _proj(x, w, b=None, out_side=False):
    from repro.models.shard_ctx import weight_use

    y = x @ weight_use(w.astype(x.dtype), out_side=out_side)
    return y if b is None else y + b.astype(x.dtype)


def gqa_qkv(p, x, positions, cfg):
    b, s, _ = x.shape
    h, kvh, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = _proj(x, p["wq"], p.get("bq")).reshape(b, s, h, dh)
    k = _proj(x, p["wk"], p.get("bk")).reshape(b, s, kvh, dh)
    v = _proj(x, p["wv"], p.get("bv")).reshape(b, s, kvh, dh)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def gqa_apply(p, x, cfg):
    """Train/prefill self-attention. x: (B, S, D)."""
    b, s, _ = x.shape
    positions = jnp.arange(s)[None, :]
    q, k, v = gqa_qkv(p, x, positions, cfg)
    out = chunked_attention(q, k, v, causal=True, block_k=cfg.attn_block_k)
    return _proj(out.reshape(b, s, -1), p["wo"], out_side=True)


def gqa_prefill(p, x, cfg, cache_len: int):
    """Prefill returning output AND the filled (padded) KV cache."""
    b, s, _ = x.shape
    positions = jnp.arange(s)[None, :]
    q, k, v = gqa_qkv(p, x, positions, cfg)
    out = chunked_attention(q, k, v, causal=True, block_k=cfg.attn_block_k)
    pad = cache_len - s
    k_c = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    v_c = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    return _proj(out.reshape(b, s, -1), p["wo"], out_side=True), {"k": k_c, "v": v_c}


def gqa_decode(p, x, cfg, cache, pos):
    """x: (B, 1, D); cache {'k','v'}: (B, S, KVH, Dh); pos: (B,) current index."""
    b = x.shape[0]
    h, kvh, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = _proj(x, p["wq"], p.get("bq")).reshape(b, 1, h, dh)
    k = _proj(x, p["wk"], p.get("bk")).reshape(b, 1, kvh, dh)
    v = _proj(x, p["wv"], p.get("bv")).reshape(b, 1, kvh, dh)
    q = apply_rope(q, pos[:, None], cfg.rope_theta)
    k = apply_rope(k, pos[:, None], cfg.rope_theta)
    # per-row positions (continuous batching): one-hot masked write
    k_cache = _write_cache(cache["k"], k, pos)
    v_cache = _write_cache(cache["v"], v, pos)
    out = decode_attention(q, k_cache, v_cache, pos)
    return _proj(out.reshape(b, 1, -1), p["wo"], out_side=True), {"k": k_cache, "v": v_cache}


def _write_cache(cache, new, pos):
    """Write (B, 1, ...) `new` at per-row positions `pos` into (B, S, ...).

    Scatter (not arithmetic masking): only the touched rows move, and with
    cache donation XLA updates in place — O(B·row) HBM traffic per token
    instead of O(B·S·row) (perf iteration #1, EXPERIMENTS.md §Perf)."""
    b = cache.shape[0]
    return cache.at[jnp.arange(b), pos].set(new[:, 0].astype(cache.dtype))


# ------------------------------------------------------------------ MLA ----
def mla_init(key, cfg):
    m = cfg.mla
    d, h = cfg.d_model, cfg.num_heads
    qk_dim = m.qk_nope_dim + m.qk_rope_dim
    ks = jax.random.split(key, 7)
    return {
        "wq_a": dense_init(ks[0], (d, m.q_lora_rank)),
        "q_norm": rmsnorm_init(m.q_lora_rank),
        "wq_b": dense_init(ks[1], (m.q_lora_rank, h * qk_dim)),
        "wkv_a": dense_init(ks[2], (d, m.kv_lora_rank + m.qk_rope_dim)),
        "kv_norm": rmsnorm_init(m.kv_lora_rank),
        "wkv_b": dense_init(ks[3], (m.kv_lora_rank, h * (m.qk_nope_dim + m.v_head_dim))),
        "wo": dense_init(ks[4], (h * m.v_head_dim, d)),
    }


def _mla_qkv(p, x, positions, cfg):
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.num_heads
    from repro.models.shard_ctx import weight_use as _wu
    q = rmsnorm_apply(p["q_norm"], x @ _wu(p["wq_a"].astype(x.dtype)))
    q = (q @ _wu(p["wq_b"].astype(x.dtype))).reshape(b, s, h, m.qk_nope_dim + m.qk_rope_dim)
    q_nope, q_rope = q[..., : m.qk_nope_dim], q[..., m.qk_nope_dim :]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    kv = x @ _wu(p["wkv_a"].astype(x.dtype))  # (B, S, kv_lora + rope)
    c_kv, k_rope = kv[..., : m.kv_lora_rank], kv[..., m.kv_lora_rank :]
    c_kv = rmsnorm_apply(p["kv_norm"], c_kv)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)  # shared head
    return q_nope, q_rope, c_kv, k_rope[:, :, 0, :]


def _mla_expand_kv(p, c_kv, k_rope, cfg):
    """Latent -> per-head K/V. k: [k_nope | k_rope(shared)], v: v_head_dim."""
    m = cfg.mla
    b, s, _ = c_kv.shape
    h = cfg.num_heads
    from repro.models.shard_ctx import weight_use as _wu2
    kv = (c_kv @ _wu2(p["wkv_b"].astype(c_kv.dtype))).reshape(b, s, h, m.qk_nope_dim + m.v_head_dim)
    k_nope, v = kv[..., : m.qk_nope_dim], kv[..., m.qk_nope_dim :]
    k_rope_b = jnp.broadcast_to(k_rope[:, :, None, :], (b, s, h, m.qk_rope_dim))
    k = jnp.concatenate([k_nope, k_rope_b], axis=-1)
    return k, v


def mla_apply(p, x, cfg):
    b, s, _ = x.shape
    positions = jnp.arange(s)[None, :]
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(p, x, positions, cfg)
    k, v = _mla_expand_kv(p, c_kv, k_rope, cfg)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    out = chunked_attention(q, k, v, causal=True, block_k=cfg.attn_block_k)
    return _proj(out.reshape(b, s, -1), p["wo"], out_side=True)


def mla_prefill(p, x, cfg, cache_len: int):
    """MLA caches the LATENT (c_kv, k_rope) — the paper-sized cache win."""
    b, s, _ = x.shape
    positions = jnp.arange(s)[None, :]
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(p, x, positions, cfg)
    k, v = _mla_expand_kv(p, c_kv, k_rope, cfg)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    out = chunked_attention(q, k, v, causal=True, block_k=cfg.attn_block_k)
    pad = cache_len - s
    cache = {
        "c_kv": jnp.pad(c_kv, ((0, 0), (0, pad), (0, 0))),
        "k_rope": jnp.pad(k_rope, ((0, 0), (0, pad), (0, 0))),
    }
    return _proj(out.reshape(b, s, -1), p["wo"], out_side=True), cache


def mla_decode(p, x, cfg, cache, pos):
    """Matrix-absorbed MLA decode (DeepSeek-V2 §2.1 trick): attention runs
    directly over the latent cache — per-head K/V are never materialised, so
    the decode working set is O(S · kv_lora_rank), not O(S · H · d_head)."""
    m = cfg.mla
    b = x.shape[0]
    h = cfg.num_heads
    positions = pos[:, None]
    q_nope, q_rope, c_kv_new, k_rope_new = _mla_qkv(p, x, positions, cfg)
    s = cache["c_kv"].shape[1]
    bidx = jnp.arange(b)
    c_kv = cache["c_kv"].at[bidx, pos].set(c_kv_new[:, 0].astype(cache["c_kv"].dtype))
    k_rope = cache["k_rope"].at[bidx, pos].set(k_rope_new[:, 0].astype(cache["k_rope"].dtype))

    wkv_b = p["wkv_b"].astype(x.dtype).reshape(m.kv_lora_rank, h, m.qk_nope_dim + m.v_head_dim)
    wk_b, wv_b = wkv_b[..., : m.qk_nope_dim], wkv_b[..., m.qk_nope_dim :]
    scale = (m.qk_nope_dim + m.qk_rope_dim) ** -0.5
    # absorb W^UK into q:  (B,H,nope)·(lora,H,nope) -> (B,H,lora)
    q_eff = jnp.einsum("bhn,lhn->bhl", q_nope[:, 0] * scale, wk_b)
    logits = jnp.einsum("bhl,bsl->bhs", q_eff, c_kv, preferred_element_type=jnp.float32)
    logits += jnp.einsum("bhr,bsr->bhs", q_rope[:, 0] * scale, k_rope,
                         preferred_element_type=jnp.float32)
    valid = jnp.arange(s)[None, :] <= pos[:, None]
    logits = jnp.where(valid[:, None, :], logits, _NEG)
    probs = jax.nn.softmax(logits, axis=-1)
    o_lat = jnp.einsum("bhs,bsl->bhl", probs.astype(c_kv.dtype), c_kv)
    out = jnp.einsum("bhl,lhv->bhv", o_lat, wv_b).reshape(b, 1, -1)
    return _proj(out, p["wo"], out_side=True), {"c_kv": c_kv, "k_rope": k_rope}

"""Checkpoint/restart: roundtrip, bit-exact resume, async manager, elastic
restore onto a different mesh (subprocess with 8 host devices)."""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.distributed.checkpoint import (
    CheckpointManager,
    latest_step,
    load_checkpoint,
    save_checkpoint,
)
from repro.training.optimizer import AdamWConfig
from repro.training.train_loop import build_train_step, init_train_state

from conftest import REPO_ROOT, subprocess_env



def test_roundtrip_bit_exact(tmp_path):
    tree = {
        "a": jnp.arange(12.0).reshape(3, 4),
        "nested": {"b": jnp.asarray([1, 2, 3], jnp.int32), "c": jnp.float32(7)},
    }
    save_checkpoint(str(tmp_path), tree, step=5)
    assert latest_step(str(tmp_path)) == 5
    restored, manifest = load_checkpoint(str(tmp_path), tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert manifest["step"] == 5


def test_resume_is_bit_exact(tmp_path):
    cfg = get_config("musicgen_medium").reduced()
    rng = np.random.default_rng(0)
    batches = [
        {
            "frames": jnp.asarray(rng.standard_normal((2, 8, cfg.d_model)), jnp.float32),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 8)), jnp.int32),
        }
        for _ in range(6)
    ]
    step = jax.jit(build_train_step(cfg, AdamWConfig(peak_lr=1e-3)), donate_argnums=())

    state = init_train_state(jax.random.key(0), cfg)
    for i in range(3):
        state, _ = step(state, batches[i])
    save_checkpoint(str(tmp_path), state, step=3)
    for i in range(3, 6):
        state, _ = step(state, batches[i])
    final_a = jax.tree.leaves(state["params"])

    state_b, _ = load_checkpoint(str(tmp_path), init_train_state(jax.random.key(1), cfg))
    for i in range(3, 6):
        state_b, _ = step(state_b, batches[i])
    final_b = jax.tree.leaves(state_b["params"])
    for a, b in zip(final_a, final_b):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_manager_async_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"w": jnp.ones(4)}
    for s in (1, 2, 3, 4):
        mgr.save_async(tree, step=s)
    mgr.wait()
    mgr._gc()
    assert latest_step(str(tmp_path)) == 4
    import os

    kept = [d for d in os.listdir(tmp_path) if d.startswith("step_")]
    assert len(kept) == 2


_ELASTIC = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.distributed.checkpoint import save_checkpoint, load_checkpoint

    mesh_a = jax.make_mesh((4, 2), ("data", "model"))
    mesh_b = jax.make_mesh((2, 2), ("data", "model"))  # elastic: 2 'nodes' lost

    spec = {"w": P("data", "model"), "b": P()}
    tree = {
        "w": jax.device_put(jnp.arange(64.0).reshape(8, 8), NamedSharding(mesh_a, spec["w"])),
        "b": jax.device_put(jnp.float32(3), NamedSharding(mesh_a, spec["b"])),
    }
    save_checkpoint("/tmp/elastic_ckpt", tree, step=1, specs=spec)
    restored, _ = load_checkpoint("/tmp/elastic_ckpt", tree, mesh=mesh_b, specs=spec)
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.arange(64.0).reshape(8, 8))
    shard_shapes = {s.data.shape for s in restored["w"].addressable_shards}
    assert shard_shapes == {(4, 4)}, shard_shapes  # resharded for the smaller mesh
    print("ELASTIC_OK")
    """
)


def test_elastic_restore_multidevice():
    proc = subprocess.run(
        [sys.executable, "-c", _ELASTIC],
        capture_output=True, text=True, timeout=300,
        env=subprocess_env(),
        cwd=REPO_ROOT,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "ELASTIC_OK" in proc.stdout

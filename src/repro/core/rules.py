"""Association-rule extraction from mined frequent itemsets (KDD step 5)."""

from __future__ import annotations

import dataclasses
from itertools import combinations


@dataclasses.dataclass(frozen=True)
class Rule:
    antecedent: tuple
    consequent: tuple
    support: float      # s(A ∪ C) / N
    confidence: float   # s(A ∪ C) / s(A)
    lift: float         # confidence / (s(C) / N)


def extract_rules(result, min_confidence: float = 0.5, max_rules: int | None = None):
    """All rules A -> C with A ∪ C frequent and confidence >= threshold."""
    supports = result.as_dict()
    n = result.num_transactions
    rules = []
    for itemset, sup in supports.items():
        if len(itemset) < 2:
            continue
        for r in range(1, len(itemset)):
            for ante in combinations(itemset, r):
                s_a = supports.get(tuple(sorted(ante)))
                if not s_a:
                    continue
                conf = sup / s_a
                if conf < min_confidence:
                    continue
                cons = tuple(sorted(set(itemset) - set(ante)))
                s_c = supports.get(cons)
                lift = (conf / (s_c / n)) if s_c else float("nan")
                rules.append(Rule(tuple(sorted(ante)), cons, sup / n, conf, lift))
    rules.sort(key=lambda r: (-r.confidence, -r.support))
    return rules[:max_rules] if max_rules else rules

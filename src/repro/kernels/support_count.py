"""Pallas TPU kernel: candidate-support counting on the MXU.

This is the paper's map task, compute-reshaped for TPU (DESIGN.md §2):
support counting of K candidate itemsets against N transactions over an
I-item vocabulary is a blocked (N×I)·(I×K) {0,1} matmul with a fused
containment epilogue::

    counts[k] = Σ_n [ Σ_i T[n,i]·C[k,i] == |c_k| ]

Grid = (K/bk, N/bn, I/bi), I innermost so a VMEM scratch accumulator carries
the partial intersection matmul across I tiles; at the last I tile the
epilogue compares against |c_k| and folds the per-transaction bools into the
output block, which is revisited (accumulated) across the N grid dimension.

Two operand modes:
  * ``bf16``: bf16 operands, fp32 accumulation — native MXU issue shape;
    exact because products are {0,1} and partial sums stay « 2^24.
  * ``int8``: int8 operands, int32 accumulation — MXU int8 path.

Block shapes default to MXU/VMEM-aligned (multiples of 128 on the matmul
dims). VMEM working set per step = bn·bi (T tile) + bk·bi (C tile) +
bn·bk·4 (acc) — defaults give 256·512 + 256·512 + 256·256·4 ≈ 0.5 MB, far
under the ~16 MB v5e VMEM budget, leaving room for double buffering.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(t_ref, c_ref, len_ref, out_ref, acc_ref, *, acc_dtype):
    i = pl.program_id(2)
    n = pl.program_id(1)
    num_i = pl.num_programs(2)

    @pl.when(i == 0)
    def _zero_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # partial intersection sizes for this (N, K) tile over the I slab
    acc_ref[...] += jnp.dot(
        t_ref[...], c_ref[...].T, preferred_element_type=acc_dtype
    )

    @pl.when(i == num_i - 1)
    def _epilogue():
        lengths = len_ref[...].astype(acc_dtype)  # (1, bk)
        contained = (acc_ref[...] == lengths).astype(jnp.int32)  # (bn, bk)
        cnt = contained.sum(axis=0, keepdims=True)  # (1, bk)

        @pl.when(n == 0)
        def _init():
            out_ref[...] = cnt

        @pl.when(n > 0)
        def _accum():
            out_ref[...] += cnt


@functools.partial(
    jax.jit,
    static_argnames=("block_n", "block_k", "block_i", "operand_dtype", "interpret"),
)
def support_count_pallas(
    t_dense: jax.Array,
    c_dense: jax.Array,
    lengths: jax.Array,
    *,
    block_n: int = 256,
    block_k: int = 256,
    block_i: int = 512,
    operand_dtype: str = "bf16",
    interpret: bool = False,
) -> jax.Array:
    """Counts for pre-padded operands: N % block_n == K % block_k ==
    I % block_i == 0 (use kernels.ops.support_count for the padding wrapper).
    """
    n, i = t_dense.shape
    k, i2 = c_dense.shape
    assert i == i2 and lengths.shape == (k,)
    assert n % block_n == 0 and k % block_k == 0 and i % block_i == 0, (
        f"operands must be pre-padded: {(n, k, i)} vs blocks {(block_n, block_k, block_i)}"
    )
    if operand_dtype == "bf16":
        op_dt, acc_dt = jnp.bfloat16, jnp.float32
    elif operand_dtype == "int8":
        op_dt, acc_dt = jnp.int8, jnp.int32
    else:
        raise ValueError(f"operand_dtype must be bf16|int8, got {operand_dtype}")

    t_op = t_dense.astype(op_dt)
    c_op = c_dense.astype(op_dt)
    len2d = lengths.astype(jnp.int32).reshape(1, k)

    grid = (k // block_k, n // block_n, i // block_i)
    out = pl.pallas_call(
        functools.partial(_kernel, acc_dtype=acc_dt),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, block_i), lambda kk, nn, ii: (nn, ii)),
            pl.BlockSpec((block_k, block_i), lambda kk, nn, ii: (kk, ii)),
            pl.BlockSpec((1, block_k), lambda kk, nn, ii: (0, kk)),
        ],
        out_specs=pl.BlockSpec((1, block_k), lambda kk, nn, ii: (0, kk)),
        out_shape=jax.ShapeDtypeStruct((1, k), jnp.int32),
        scratch_shapes=[pltpu.VMEM((block_n, block_k), acc_dt)],
        interpret=interpret,
    )(t_op, c_op, len2d)
    return out.reshape(k)

from repro.distributed.sharding import (
    ShardingRules,
    param_pspecs,
    batch_pspec,
    cache_pspecs,
    state_pspecs,
)
from repro.distributed.compression import compressed_psum, int8_ef_state
from repro.distributed.checkpoint import save_checkpoint, load_checkpoint, CheckpointManager
from repro.distributed.fault_tolerance import (
    Supervisor,
    SimulatedFailure,
    WorkQueue,
    run_with_backup_tasks,
)

from repro.serving.serve_loop import make_prefill_step, make_decode_step, generate

"""MiniCPM3-4B [hf:openbmb/MiniCPM3-4B] — dense transformer with MLA."""

from repro.models.config import MLAConfig, ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b",
    num_layers=62,
    d_model=2560,
    num_heads=40,
    num_kv_heads=40,
    d_ff=6400,
    vocab_size=73448,
    attn_type="mla",
    mla=MLAConfig(q_lora_rank=768, kv_lora_rank=256, qk_nope_dim=64, qk_rope_dim=32, v_head_dim=64),
)

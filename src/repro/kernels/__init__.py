from repro.kernels import ops, ref
from repro.kernels.ops import pack_bits_device, support_count, support_count_packed

"""Incremental delta mining: fold appended shards into a persisted count
cache instead of re-scanning the whole store (DESIGN.md §15).

The batch Map/Reduce Apriori of the paper re-reads every HDFS block per
refresh. The MapReduce-Apriori survey (PAPERS.md, 1702.06284) catalogs the
incremental family this module implements on top of SON:

  * **Count cache** — after a full SON mine, phase 2 has the EXACT global
    count of every phase-1 union candidate (``mine_son_streamed`` computes
    them all and prunes the sub-threshold ones away). We persist the whole
    pre-prune union with its counts, keyed to the shard prefix it covers,
    as a ``.npz`` sidecar referenced from the store manifest's
    ``count_cache`` section.

  * **Delta mine** — when shards are appended, mine ONLY the new shards as
    fresh SON partitions (phase 1 at the same support fraction θ), then:

      - candidates already in the cache need NO base-store I/O: their grown
        total is ``cached_base_count + delta_count``, exact by additivity of
        integer counts over disjoint row sets. Whether such an itemset
        crosses minsup in either direction is settled by arithmetic alone —
        the "borderline" set costs nothing to re-verify.
      - candidates that are NEW (locally frequent in an appended shard but
        never in the base union) lack a base count; their base support is
        only bounded above by per-partition local-infrequency. These — and
        only these — are re-verified in ONE streamed phase-2 pass over the
        base shards.

    Union completeness is SON's pigeonhole applied to the grown store: a
    globally θ-frequent itemset is locally θ-frequent in ≥ 1 partition, and
    the partitions of the grown store are exactly (base shards ∪ appended
    shards) — the cache holds every base winner, phase 1 here finds every
    appended-shard winner. Exact counts + complete union + same min_count
    ⇒ the delta result is dict-identical to a full re-mine (property-tested
    in ``tests/test_incremental.py``).

  * **Fallback** — when the appended fraction or the level-1 candidate
    drift ("vocabulary drift": new singletons entering the candidate space)
    exceeds a threshold, the incremental pass would approach full-scan cost
    anyway, so we fall back to :func:`build_count_cache` (a full SON
    re-mine that also rewrites the cache).

Crash recovery reuses the PR-6 :class:`MiningCheckpoint` machinery: the
delta mine snapshots at its two phase boundaries (appended-shard winners;
union delta counts), validated by a fingerprint that pins the grown store
AND the cache generation it folds into — a crash mid-delta resumes without
re-mining the appended partitions, and the cache itself is only rewritten
at the very end via the store's atomic manifest swap, so a crash anywhere
leaves the previous cache authoritative.
"""

from __future__ import annotations

import dataclasses
import math
import os
import typing

import numpy as np

from repro.core import apriori as ap
from repro.core import son as son_mod
from repro.core import streaming as st

if typing.TYPE_CHECKING:   # runtime import would cycle: data.store -> core
    from repro.data.store import TransactionStore
from repro.distributed.checkpoint import (
    MiningCheckpoint,
    MiningState,
    mining_fingerprint,
    store_fingerprint,
)
from repro.distributed.fault_tolerance import run_partitions

CACHE_VERSION = 1

#: delta fraction above which a delta mine degenerates to full-scan cost
DEFAULT_MAX_DELTA_FRACTION = 0.5
#: fraction of level-1 union candidates that are novel (vocabulary drift)
#: above which the borderline re-verify pass stops being "borderline"
DEFAULT_MAX_DRIFT_FRACTION = 0.5

# delta-checkpoint phase markers (stored in MiningState.next_k)
_PHASE_WINNERS = 1      # appended-shard phase-1 winners snapshotted
_PHASE_DELTA_COUNTS = 2  # union counts over the appended shards snapshotted


def cache_filename(seq: int) -> str:
    return f"count_cache_{seq:08d}.npz"


@dataclasses.dataclass
class CountCache:
    """The persisted pre-prune SON union with exact global counts.

    ``store_fp`` fingerprints the shard PREFIX the counts cover (the whole
    store at build time); after appends it still validates against the grown
    store via ``store_fingerprint(store, num_shards)`` — that prefix scoping
    is what lets the delta path accept a store a full-mine checkpoint must
    reject. ``levels`` maps ``k -> (cands (K, k) int32, counts (K,) int64)``.
    """

    seq: int
    min_support: float
    max_k: int
    n: int
    store_fp: dict
    levels: dict
    version: int = CACHE_VERSION

    @property
    def num_shards(self) -> int:
        return len(self.store_fp["shard_rows"])

    def candidate_total(self) -> int:
        return int(sum(c.shape[0] for c, _ in self.levels.values()))

    def winner_sets(self) -> dict:
        return son_mod.arrays_to_winners({k: c for k, (c, _) in self.levels.items()})

    def lookup(self) -> dict:
        """``k -> {itemset tuple -> base count}`` for the fold."""
        return {
            k: {
                tuple(int(x) for x in row): int(cnt)
                for row, cnt in zip(cands, counts)
            }
            for k, (cands, counts) in self.levels.items()
        }


@dataclasses.dataclass
class DeltaReport:
    """What the refresh actually did — surfaced through RefreshController
    metrics and the serve CLI summary."""

    mode: str                 # "delta" | "full" | "noop"
    reason: str               # why this mode was chosen
    base_rows: int
    delta_rows: int
    base_shards: int
    delta_shards: int
    cached_candidates: int = 0
    novel_candidates: int = 0   # re-verified over the base store
    resumed_phase: int = 0      # delta-checkpoint phase restored from


# ------------------------------------------------------------- persistence --
def save_count_cache(store: TransactionStore, cache: CountCache) -> None:
    """Sidecar arrays first, then the atomic manifest swap publishes them —
    torn writes leave the previous cache generation authoritative."""
    fname = cache_filename(cache.seq)
    final = os.path.join(store.path, fname)
    tmp = final + ".tmp"
    arrays = {}
    for k, (cands, counts) in cache.levels.items():
        arrays[f"sets_{k}"] = np.asarray(cands, dtype=np.int32)
        arrays[f"cnt_{k}"] = np.asarray(counts, dtype=np.int64)
    with open(tmp, "wb") as f:
        np.savez(f, **arrays)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, final)
    store.set_count_cache({
        "version": cache.version,
        "seq": cache.seq,
        "file": fname,
        "min_support": cache.min_support,
        "max_k": cache.max_k,
        "n": cache.n,
        "store": cache.store_fp,
        "levels": sorted(int(k) for k in cache.levels),
    })


def load_count_cache(store: TransactionStore) -> CountCache | None:
    """The cache the manifest points at, or None (absent / unreadable /
    future version — all mean "no usable cache", never an exception)."""
    meta = store.count_cache_meta
    if not meta or int(meta.get("version", -1)) != CACHE_VERSION:
        return None
    path = os.path.join(store.path, meta["file"])
    if not os.path.exists(path):
        return None
    with np.load(path) as data:
        levels = {
            int(k): (
                np.asarray(data[f"sets_{k}"], dtype=np.int32),
                np.asarray(data[f"cnt_{k}"], dtype=np.int64),
            )
            for k in meta["levels"]
        }
    return CountCache(
        seq=int(meta["seq"]),
        min_support=float(meta["min_support"]),
        max_k=int(meta["max_k"]),
        n=int(meta["n"]),
        store_fp=meta["store"],
        levels=levels,
    )


def build_count_cache(
    store: TransactionStore,
    cfg: ap.AprioriConfig = ap.AprioriConfig(),
    mesh=None,
    chunk_rows: int = 8192,
    prefetch: int = 2,
    fault=None,
    obs=None,
) -> tuple[ap.AprioriResult, CountCache]:
    """Full SON mine that ALSO persists the pre-prune union counts as the
    count cache — the starting point (and the fallback) of the delta path."""
    res = st.mine_son_streamed(
        store, cfg, mesh, chunk_rows=chunk_rows, prefetch=prefetch,
        fault=fault, obs=obs, collect_union=True,
    )
    prev = store.count_cache_meta or {}
    cache = CountCache(
        seq=int(prev.get("seq", 0)) + 1,
        min_support=cfg.min_support,
        max_k=cfg.max_k,
        n=store.num_transactions,
        store_fp=store_fingerprint(store),
        levels=res.union_counts or {},
    )
    save_count_cache(store, cache)
    return res, cache


# ------------------------------------------------------------------ delta ----
def result_from_cache(cache: CountCache, min_count: int) -> ap.AprioriResult:
    levels = {}
    for k, (cands, counts) in sorted(cache.levels.items()):
        keep = counts >= min_count
        if keep.any():
            levels[k] = (cands[keep], counts[keep])
    return ap.AprioriResult(
        levels=levels, num_transactions=cache.n, min_count=min_count
    )


def cache_invalid_reason(
    store: TransactionStore, cache: CountCache | None, cfg: ap.AprioriConfig
) -> str | None:
    """Why this cache cannot seed a delta mine of this store (None = it can).

    The store check is the prefix fingerprint: the grown store must contain,
    unmodified, exactly the shards the cache counted — appended shards after
    that prefix are what the delta path exists for.
    """
    if cache is None:
        return "no_cache"
    if cache.min_support != cfg.min_support or cache.max_k != cfg.max_k:
        return "config_changed"
    if cache.num_shards > store.num_partitions:
        return "base_mutated"
    if store_fingerprint(store, cache.num_shards) != cache.store_fp:
        return "base_mutated"
    return None


def _delta_manager(checkpoint, store) -> MiningCheckpoint | None:
    if checkpoint is None or checkpoint is False:
        return None
    if isinstance(checkpoint, MiningCheckpoint):
        return checkpoint
    if checkpoint is True:
        # separate namespace from full-mine snapshots: the fingerprints
        # differ by construction, but keeping the dirs apart means a delta
        # clear() never deletes a full mine's resume state
        return MiningCheckpoint(os.path.join(store.checkpoint_path, "delta"))
    return MiningCheckpoint(str(checkpoint))


def delta_fingerprints(
    store: TransactionStore, cache: CountCache, cfg: ap.AprioriConfig, chunk_rows: int
) -> tuple[dict, dict]:
    """(store_fp, mine_fp) a delta checkpoint is valid for: the exact grown
    store plus the cache generation whose counts it folds into."""
    mine_fp = mining_fingerprint(cfg, chunk_rows)
    mine_fp["delta_base_shards"] = cache.num_shards
    mine_fp["delta_cache_seq"] = cache.seq
    return store_fingerprint(store), mine_fp


def mine_delta(
    store: TransactionStore,
    cfg: ap.AprioriConfig = ap.AprioriConfig(),
    mesh=None,
    chunk_rows: int = 8192,
    prefetch: int = 2,
    fault=None,
    checkpoint=None,
    resume: bool = False,
    max_delta_fraction: float = DEFAULT_MAX_DELTA_FRACTION,
    max_drift_fraction: float = DEFAULT_MAX_DRIFT_FRACTION,
    update_cache: bool = True,
    obs=None,
) -> tuple[ap.AprioriResult, DeltaReport]:
    """Mine the grown store incrementally against its persisted count cache.

    Returns ``(result, report)`` where ``result`` is dict-identical to a
    full re-mine of the current store and ``report`` says which path ran
    (delta / full fallback / noop) and why. On success the cache is advanced
    to cover the whole store (``update_cache=False`` skips that, for
    read-only probes). ``checkpoint=True|path|manager`` + ``resume=True``
    give phase-boundary crash recovery via the PR-6 snapshot machinery.
    """
    n_total = store.num_transactions
    min_count = max(1, math.ceil(cfg.min_support * n_total))
    cache = load_count_cache(store)

    def full(reason: str, mgr=None) -> tuple[ap.AprioriResult, DeltaReport]:
        if mgr is not None:
            mgr.clear()
        res, _ = build_count_cache(
            store, cfg, mesh, chunk_rows=chunk_rows, prefetch=prefetch,
            fault=fault, obs=obs,
        )
        base = cache.n if cache is not None else 0
        return res, DeltaReport(
            mode="full", reason=reason,
            base_rows=base, delta_rows=n_total - base,
            base_shards=cache.num_shards if cache is not None else 0,
            delta_shards=store.num_partitions
            - (cache.num_shards if cache is not None else 0),
        )

    reason = cache_invalid_reason(store, cache, cfg)
    if reason is not None:
        return full(reason)

    base_shards = cache.num_shards
    delta_shards = store.num_partitions - base_shards
    delta_rows = n_total - cache.n
    if delta_shards == 0:
        return (
            result_from_cache(cache, min_count),
            DeltaReport(
                mode="noop", reason="no_new_shards",
                base_rows=cache.n, delta_rows=0,
                base_shards=base_shards, delta_shards=0,
                cached_candidates=cache.candidate_total(),
            ),
        )
    if delta_rows > max_delta_fraction * n_total:
        return full("delta_fraction")

    mgr = _delta_manager(checkpoint, store)
    store_fp, mine_fp = delta_fingerprints(store, cache, cfg, chunk_rows)
    restored: MiningState | None = None
    if mgr is not None:
        if resume:
            loaded = mgr.load_latest()
            if loaded is not None:
                state, manifest = loaded
                mgr.validate(manifest, store_fp, mine_fp)
                restored = state
        else:
            mgr.clear()

    # ---- phase 1: SON local mining over ONLY the appended shards ----------
    fault_report = None
    if restored is not None:
        new_union = son_mod.arrays_to_winners(
            {k: c for k, (c, _) in restored.levels.items()}
            if restored.next_k == _PHASE_WINNERS
            else {}
        )
    if restored is None:
        if fault is None:
            new_union = son_mod.union_local_winners(
                (
                    store.partition_dense(p)
                    for p in range(base_shards, store.num_partitions)
                ),
                cfg,
            )
        else:
            def map_shard(p: int) -> dict:
                return son_mod.local_winners(
                    store.partition_dense(base_shards + p), cfg
                )

            winners, fault_report = run_partitions(
                map_shard, delta_shards, fault, obs=obs
            )
            new_union = son_mod.merge_winners(
                w for w in winners if w is not None
            )
        if mgr is not None:
            winner_arrays = son_mod.winners_to_arrays(new_union)
            mgr.save(
                MiningState(
                    levels={
                        k: (c, np.zeros(c.shape[0], np.int64))
                        for k, c in winner_arrays.items()
                    },
                    next_k=_PHASE_WINNERS,
                ),
                store_fp, mine_fp,
            )
            mgr.wait()

    # ---- split the grown union into cached vs novel candidates ------------
    cached_sets = cache.winner_sets()
    if restored is not None and restored.next_k == _PHASE_DELTA_COUNTS:
        union_sets = son_mod.arrays_to_winners(
            {k: c for k, (c, _) in restored.levels.items()}
        )
        new_union = union_sets  # superset is all we need for the novel split
    novel = {
        k: s - cached_sets.get(k, set()) for k, s in new_union.items()
    }
    novel = {k: s for k, s in novel.items() if s}
    union_sets = {
        k: cached_sets.get(k, set()) | new_union.get(k, set())
        for k in set(cached_sets) | set(new_union)
    }
    union_arrays = son_mod.winners_to_arrays(union_sets)

    # vocabulary drift: novel singletons flooding the candidate space mean
    # the "borderline" re-verify pass is no longer a borderline pass
    u1 = len(union_sets.get(1, set()))
    if u1 and len(novel.get(1, set())) > max_drift_fraction * u1:
        return full("vocabulary_drift", mgr=mgr)

    # ---- delta counts: ONE streamed pass over ONLY the appended shards ----
    if restored is not None and restored.next_k == _PHASE_DELTA_COUNTS:
        delta_counts = {
            k: np.asarray(sup, dtype=np.int64)
            for k, (_, sup) in restored.levels.items()
        }
    else:
        delta_counts = st.count_union_streamed(
            store, union_arrays, cfg, mesh, chunk_rows=chunk_rows,
            prefetch=prefetch, shards=(base_shards, store.num_partitions),
            obs=obs,
        )
        if mgr is not None:
            mgr.save(
                MiningState(
                    levels={
                        k: (union_arrays[k], delta_counts[k])
                        for k in union_arrays
                    },
                    next_k=_PHASE_DELTA_COUNTS,
                ),
                store_fp, mine_fp,
            )
            mgr.wait()

    # ---- borderline re-verify: novel candidates over the BASE shards ------
    novel_arrays = son_mod.winners_to_arrays(novel)
    novel_base = (
        st.count_union_streamed(
            store, novel_arrays, cfg, mesh, chunk_rows=chunk_rows,
            prefetch=prefetch, shards=(0, base_shards), obs=obs,
        )
        if novel_arrays
        else {}
    )
    novel_lookup = {
        k: {
            tuple(int(x) for x in row): int(cnt)
            for row, cnt in zip(novel_arrays[k], novel_base[k])
        }
        for k in novel_arrays
    }

    # ---- fold: total = base + delta, exact by additivity ------------------
    cached_lookup = cache.lookup()
    levels = {}
    new_levels = {}
    for k, cands in union_arrays.items():
        base_counts = np.empty(cands.shape[0], dtype=np.int64)
        ck = cached_lookup.get(k, {})
        nk = novel_lookup.get(k, {})
        for i, row in enumerate(cands):
            key = tuple(int(x) for x in row)
            base_counts[i] = ck[key] if key in ck else nk[key]
        totals = base_counts + delta_counts[k]
        new_levels[k] = (cands, totals)
        keep = totals >= min_count
        if keep.any():
            levels[k] = (cands[keep], totals[keep])

    if update_cache:
        save_count_cache(
            store,
            CountCache(
                seq=cache.seq + 1,
                min_support=cfg.min_support,
                max_k=cfg.max_k,
                n=n_total,
                store_fp=store_fingerprint(store),
                levels=new_levels,
            ),
        )
    if mgr is not None:
        mgr.clear()

    result = ap.AprioriResult(
        levels=levels, num_transactions=n_total, min_count=min_count,
        fault_report=fault_report,
    )
    report = DeltaReport(
        mode="delta", reason="ok",
        base_rows=cache.n, delta_rows=delta_rows,
        base_shards=base_shards, delta_shards=delta_shards,
        cached_candidates=cache.candidate_total(),
        novel_candidates=int(
            sum(c.shape[0] for c in novel_arrays.values())
        ),
        resumed_phase=restored.next_k if restored is not None else 0,
    )
    return result, report

from repro.data.synthetic import gen_transactions, gen_transactions_chunked, QuestConfig
from repro.data.corpus import transactions_from_tokens
from repro.data.pipeline import ShardedBatchIterator
from repro.data.store import (
    TransactionStore,
    StoreWriter,
    open_store,
    append_chunks,
    ingest_chunks,
    ingest_dense,
    ingest_lists,
    ingest_quest,
)

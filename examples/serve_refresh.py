"""Continuous-refresh walkthrough: append -> delta mine -> hot-swap, live.

  PYTHONPATH=src python examples/serve_refresh.py \
      [--transactions 6000] [--items 96] [--append-frac 0.05]

The DESIGN.md §15 loop, step by step:

  1. ingest     — a synthetic Quest DB goes into an on-disk
                  ``TransactionStore`` (packed shards + manifest);
  2. seed       — ``build_count_cache`` runs the SON streamed mine ONCE and
                  persists what it used to throw away: the entire pre-prune
                  phase-1 union with exact global counts, stamped with the
                  store fingerprint it covers;
  3. serve      — the result compiles into a rulebook behind a live
                  ``Gateway`` (generation 0), and a ``RefreshController``
                  starts watching the store's row watermark;
  4. append     — new rows land through ``append_chunks``: shard files
                  first, then ONE atomic manifest rewrite publishes them
                  (a torn append is invisible);
  5. delta mine — the controller notices rows above the watermark and runs
                  ``mine_delta``: SON phase 1 over the NEW shards only,
                  cached candidates folded by integer addition, only the
                  genuinely novel ones re-counted over the base shards —
                  dict-identical to a full re-mine, at delta cost;
  6. swap       — the fresh rulebook hot-swaps in under traffic
                  (generation 1), ``generation_age_seconds`` re-stamps,
                  and the watermark advances to the rows now covered.

The same flow as a single command (plus a JSON summary for scripting):

  PYTHONPATH=src python -m repro.launch.serve --refresh delta \
      --append-mid-load 0.05 --json refresh-smoke.json
"""

import argparse
import tempfile
import time

import numpy as np

from repro.core import incremental as inc
from repro.core.apriori import AprioriConfig
from repro.data.store import append_chunks, ingest_quest, open_store
from repro.data.synthetic import QuestConfig, gen_transactions
from repro.serving import Gateway, RefreshController, compile_rulebook


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--transactions", type=int, default=6_000)
    ap.add_argument("--items", type=int, default=96)
    ap.add_argument("--append-frac", type=float, default=0.05)
    args = ap.parse_args()

    cfg = AprioriConfig(min_support=0.02, max_k=3, representation="packed")
    with tempfile.TemporaryDirectory(prefix="refresh_store_") as d:
        # 1. ingest the base store
        store = ingest_quest(
            QuestConfig(num_transactions=args.transactions,
                        num_items=args.items, seed=1),
            d, shard_rows=1024)
        print(f"[1] store: n={store.num_transactions} "
              f"shards={store.num_partitions} (manifest seq={store.manifest.seq})")

        # 2. seed the count cache: one full SON mine, byproducts persisted
        res, cache = inc.build_count_cache(store, cfg, chunk_rows=1024)
        print(f"[2] count cache seq={cache.seq}: {cache.candidate_total()} "
              f"pre-prune candidates over levels {sorted(cache.levels)} "
              f"({res.total_frequent} frequent after pruning)")

        # 3. serve generation 0, controller watching the watermark
        rb = compile_rulebook(res, min_confidence=0.5, num_items=args.items)
        with Gateway(rb) as gw, RefreshController(
            d, gw, cfg, chunk_rows=1024, min_confidence=0.5,
            poll_interval_s=0.05,
        ) as ctl:
            print(f"[3] serving generation {gw.generation} "
                  f"({rb.num_rules} rules); watermark={ctl.watermark}")
            basket = np.flatnonzero(
                gen_transactions(QuestConfig(8, args.items, seed=2))[0]
            ).tolist() or [0]
            print(f"    probe basket {basket} -> "
                  f"{gw.submit(basket, top_k=3).result().items}")

            # 4. append new rows into the LIVE store
            extra = max(1, int(args.append_frac * args.transactions))
            grown = append_chunks(
                [gen_transactions(QuestConfig(extra, args.items, seed=9))], d)
            print(f"[4] appended {extra} rows -> n={grown.num_transactions} "
                  f"(manifest seq={grown.manifest.seq}); "
                  f"pending={ctl.pending_rows()}")

            # 5+6. the controller folds them in and swaps under traffic
            deadline = time.time() + 120
            while gw.generation == 0 and time.time() < deadline:
                gw.submit(basket, top_k=3).result()
                time.sleep(0.02)
            last = ctl.history[-1]
            print(f"[5] refresh: mode={last['mode']} ({last['reason']}) "
                  f"folded {last['delta_rows']} rows, "
                  f"{last['novel_candidates']} novel re-verified, "
                  f"in {last['seconds']:.2f}s")
            print(f"[6] serving generation {gw.generation} "
                  f"({last['rules']} rules); watermark={ctl.watermark}; "
                  f"age={gw.metrics.generation_age.value:.2f}s")

            # the delta result is dict-identical to a full re-mine: the
            # NEXT delta over the same store is a noop cache read
            _, rep = inc.mine_delta(open_store(d), cfg, chunk_rows=1024)
            print(f"[=] re-check: mine_delta now reports mode={rep.mode} "
                  f"({rep.reason}) — the cache covers the grown store")


if __name__ == "__main__":
    main()

"""Incremental delta mining (DESIGN.md §15): count-cache persistence, the
delta == full-re-mine equivalence property, fallback triggers, and the
checkpoint story — a pre-append full-mine checkpoint is rejected while the
delta path accepts the same grown store, and a crash mid-delta resumes."""

import dataclasses
import os

import numpy as np
import pytest

from repro.core import apriori as ap
from repro.core import incremental as inc
from repro.core import son as son_mod
from repro.core import streaming as stm
from repro.data import store as ds
from repro.data.synthetic import QuestConfig, gen_transactions
from repro.distributed.checkpoint import (
    CheckpointMismatch,
    MiningCheckpoint,
    mining_fingerprint,
    store_fingerprint,
)

CFG = ap.AprioriConfig(min_support=0.02, max_k=3)


def _quest(n, seed, items=48):
    return gen_transactions(QuestConfig(num_transactions=n, num_items=items, seed=seed))


def _grown(tmp_path, base, extra, shard_rows=256, cfg=CFG, chunk_rows=300):
    """Ingest base, build the cache, append extra; returns (path, grown)."""
    p = str(tmp_path / "db")
    s = ds.ingest_dense(base, p, shard_rows=shard_rows)
    inc.build_count_cache(s, cfg, chunk_rows=chunk_rows)
    if len(extra):
        ds.append_chunks([extra], p)
    return p, np.concatenate([base, extra]) if len(extra) else base


# ------------------------------------------------------------- persistence ----
def test_build_count_cache_persists_full_union(tmp_path):
    base = _quest(1500, seed=1)
    p = str(tmp_path / "db")
    s = ds.ingest_dense(base, p, shard_rows=256)
    res, cache = inc.build_count_cache(s, CFG, chunk_rows=300)
    assert res.as_dict() == ap.mine(base, CFG).as_dict()
    # reload through the manifest section: byte-identical arrays
    loaded = inc.load_count_cache(ds.open_store(p))
    assert loaded is not None and loaded.seq == cache.seq == 1
    assert loaded.store_fp == store_fingerprint(s)
    assert set(loaded.levels) == set(cache.levels)
    for k in cache.levels:
        assert np.array_equal(loaded.levels[k][0], cache.levels[k][0])
        assert np.array_equal(loaded.levels[k][1], cache.levels[k][1])
    # the cache is the PRE-prune union: counts below min_count are kept too
    assert any((cnt < res.min_count).any() for _, cnt in cache.levels.values())
    # rebuilding bumps the seq and GCs the superseded sidecar
    _, cache2 = inc.build_count_cache(ds.open_store(p), CFG, chunk_rows=300)
    assert cache2.seq == 2
    assert not os.path.exists(os.path.join(p, inc.cache_filename(1)))
    assert os.path.exists(os.path.join(p, inc.cache_filename(2)))


def test_load_count_cache_absent_or_stale(tmp_path):
    p = str(tmp_path / "db")
    s = ds.ingest_dense(_quest(200, seed=2), p, shard_rows=64)
    assert inc.load_count_cache(s) is None
    _, cache = inc.build_count_cache(s, CFG, chunk_rows=128)
    # missing sidecar file -> unusable, not an exception
    os.remove(os.path.join(p, inc.cache_filename(cache.seq)))
    assert inc.load_count_cache(ds.open_store(p)) is None


# ----------------------------------------- the equivalence property (§15) ----
@pytest.mark.parametrize("representation", ["dense", "packed"])
@pytest.mark.parametrize("append_n", [40, 400, 1400])
def test_delta_mine_dict_identical_to_full_remine(tmp_path, representation, append_n):
    """The acceptance property: after an append (1 shard .. many shards,
    distribution-shifted so supports cross minsup in BOTH directions), the
    delta mine equals a full re-mine of the grown store — in both
    representations."""
    cfg = dataclasses.replace(CFG, representation=representation)
    base = _quest(3000, seed=3)
    extra = _quest(append_n, seed=103)   # different seed = shifted mixture
    p, grown = _grown(tmp_path, base, extra, cfg=cfg)
    res, rep = inc.mine_delta(ds.open_store(p), cfg, chunk_rows=300)
    assert rep.mode == "delta"
    full = stm.mine_son_streamed(ds.ingest_dense(grown, str(tmp_path / "ref"), shard_rows=256), cfg, chunk_rows=300)
    assert res.as_dict() == full.as_dict()
    assert res.min_count == full.min_count
    assert res.num_transactions == len(grown)
    # the advanced cache seeds the NEXT delta: append again and re-check
    extra2 = _quest(200, seed=7)
    ds.append_chunks([extra2], p)
    res2, rep2 = inc.mine_delta(ds.open_store(p), cfg, chunk_rows=300)
    assert rep2.mode == "delta"
    assert res2.as_dict() == ap.mine(np.concatenate([grown, extra2]), cfg).as_dict()


def test_delta_crossings_both_directions_and_novel_reverify(tmp_path):
    """Engineered crossings: itemset A is frequent in the base and falls
    below minsup after the append; itemset B is infrequent in the base (so
    it is NOT in the cache union — the base is one partition) and crosses
    above, which forces the borderline re-verify pass over the base shards."""
    rng = np.random.default_rng(0)
    n_base, items = 200, 16
    base = (rng.random((n_base, items)) < 0.05).astype(np.int8)
    base[:, :4] = 0
    base[:21, [0, 1]] = 1        # A = {0,1}: 21 >= ceil(0.1*200) = 20
    base[30:49, [2, 3]] = 1      # B = {2,3}: 19 < 20 -> NOT in the union
    extra = (rng.random((40, items)) < 0.05).astype(np.int8)
    extra[:, :4] = 0
    extra[:30, [2, 3]] = 1       # B gains 30
    cfg = ap.AprioriConfig(min_support=0.1, max_k=2)
    p = str(tmp_path / "db")
    s = ds.ingest_dense(base, p, shard_rows=1000)   # ONE base partition
    _, cache = inc.build_count_cache(s, cfg, chunk_rows=64)
    assert (0, 1) in inc.result_from_cache(cache, 20).as_dict()
    assert (2, 3) not in son_mod.arrays_to_winners(
        {k: c for k, (c, _) in cache.levels.items()}
    ).get(2, set())
    ds.append_chunks([extra], p)
    # drift guard off: the 16-item toy vocabulary would trip it, and the
    # drift fallback has its own test — here we want the delta path
    res, rep = inc.mine_delta(
        ds.open_store(p), cfg, chunk_rows=64, max_drift_fraction=1.0
    )
    got = res.as_dict()
    # grown: n=240, min_count=24; A: 21 < 24 (crossed down), B: 49 >= 24 (up)
    assert (0, 1) not in got and got[(2, 3)] == 49
    assert rep.novel_candidates > 0, "B must have gone through the re-verify pass"
    assert got == ap.mine(np.concatenate([base, extra]), cfg).as_dict()


def test_delta_noop_without_new_shards(tmp_path):
    p, grown = _grown(tmp_path, _quest(800, seed=4), np.zeros((0, 48), np.int8))
    res, rep = inc.mine_delta(ds.open_store(p), CFG, chunk_rows=300)
    assert rep.mode == "noop" and rep.delta_rows == 0
    assert res.as_dict() == ap.mine(grown, CFG).as_dict()


# ---------------------------------------------------------------- fallbacks ----
def test_delta_fallback_reasons(tmp_path):
    base = _quest(1000, seed=5)
    p, _ = _grown(tmp_path, base, _quest(100, seed=6))
    # config changed -> full re-mine, cache rebuilt at the new config
    other = dataclasses.replace(CFG, min_support=0.05)
    res, rep = inc.mine_delta(ds.open_store(p), other, chunk_rows=300)
    assert (rep.mode, rep.reason) == ("full", "config_changed")
    assert res.as_dict() == ap.mine(np.concatenate([base, _quest(100, seed=6)]), other).as_dict()
    # no cache at all
    p2 = str(tmp_path / "db2")
    ds.ingest_dense(base, p2, shard_rows=256)
    _, rep2 = inc.mine_delta(ds.open_store(p2), CFG, chunk_rows=300)
    assert (rep2.mode, rep2.reason) == ("full", "no_cache")
    # oversized delta
    ds.append_chunks([_quest(1500, seed=8)], p2)
    _, rep3 = inc.mine_delta(ds.open_store(p2), CFG, chunk_rows=300)
    assert (rep3.mode, rep3.reason) == ("full", "delta_fraction")


def test_delta_fallback_on_base_mutation_and_drift(tmp_path):
    base = _quest(600, seed=9, items=24)
    p, _ = _grown(tmp_path, base, np.zeros((0, 24), np.int8), shard_rows=128)
    # re-ingest different base under the SAME cache section -> base_mutated
    meta = ds.open_store(p).count_cache_meta
    ds.ingest_dense(_quest(600, seed=10, items=24), p, shard_rows=100)
    s = ds.open_store(p)
    s.set_count_cache(meta)   # graft the stale section back on
    assert inc.cache_invalid_reason(s, inc.load_count_cache(s), CFG) == "base_mutated"
    # vocabulary drift: the append lights up items the base never had
    p2 = str(tmp_path / "db2")
    rng = np.random.default_rng(1)
    narrow = np.zeros((400, 24), np.int8)
    narrow[:, :4] = (rng.random((400, 4)) < 0.5).astype(np.int8)
    s2 = ds.ingest_dense(narrow, p2, shard_rows=128)
    inc.build_count_cache(s2, CFG, chunk_rows=128)
    wide = (rng.random((120, 24)) < 0.5).astype(np.int8)   # all 24 items hot
    ds.append_chunks([wide], p2)
    res, rep = inc.mine_delta(ds.open_store(p2), CFG, chunk_rows=128)
    assert (rep.mode, rep.reason) == ("full", "vocabulary_drift")
    assert res.as_dict() == ap.mine(np.concatenate([narrow, wide]), CFG).as_dict()


# ------------------------------------------ checkpoints vs appended shards ----
def test_full_mine_checkpoint_rejected_after_append_but_delta_accepts(tmp_path):
    """The satellite contract: a mining checkpoint taken BEFORE an append
    must be rejected for a full-mine resume of the grown store (its counts
    covered fewer rows), while the delta path accepts the very same store —
    its fingerprint covers only the base-shard prefix it counted."""
    base = _quest(1200, seed=11)
    p = str(tmp_path / "db")
    s = ds.ingest_dense(base, p, shard_rows=256)
    inc.build_count_cache(s, CFG, chunk_rows=300)
    # a pre-append full-mine snapshot (level boundary is enough)
    mgr = MiningCheckpoint(str(tmp_path / "ck"))
    from repro.distributed.checkpoint import MiningState
    mgr.save(MiningState(levels={}, next_k=2), store_fingerprint(s),
             mining_fingerprint(CFG, 300))
    mgr.wait()
    grown = ds.append_chunks([_quest(150, seed=12)], p)
    # full-mine resume: explicit mismatch, never a silent wrong answer
    _, manifest = mgr.load_latest()
    with pytest.raises(CheckpointMismatch):
        mgr.validate(manifest, store_fingerprint(grown), mining_fingerprint(CFG, 300))
    with pytest.raises(CheckpointMismatch):
        stm.mine_streamed(grown, CFG, chunk_rows=300, checkpoint=mgr, resume=True)
    # the delta path accepts the same grown store: its base-prefix
    # fingerprint still matches what the cache counted
    cache = inc.load_count_cache(grown)
    assert inc.cache_invalid_reason(grown, cache, CFG) is None
    assert store_fingerprint(grown, cache.num_shards) == cache.store_fp
    res, rep = inc.mine_delta(grown, CFG, chunk_rows=300)
    assert rep.mode == "delta"
    assert res.as_dict() == ap.mine(np.concatenate([base, _quest(150, seed=12)]), CFG).as_dict()


class _Crash(BaseException):
    """Out-of-band interrupt no library code catches."""


def test_delta_crash_resume_skips_phase1(tmp_path, monkeypatch):
    """Crash after the phase-1 snapshot: the resumed delta mine restores the
    appended-shard winners from the PR-6 checkpoint (phase 1 is NOT re-run)
    and still matches the full re-mine."""
    base = _quest(2000, seed=13)
    extra = _quest(300, seed=14)
    p, grown = _grown(tmp_path, base, extra)
    store = ds.open_store(p)
    real_count = stm.count_union_streamed

    def boom(*a, **kw):
        raise _Crash()

    monkeypatch.setattr(inc.st, "count_union_streamed", boom)
    with pytest.raises(_Crash):
        inc.mine_delta(store, CFG, chunk_rows=300, checkpoint=True)
    monkeypatch.setattr(inc.st, "count_union_streamed", real_count)

    def no_phase1(*a, **kw):
        raise AssertionError("phase 1 must be restored from the checkpoint")

    monkeypatch.setattr(inc.son_mod, "union_local_winners", no_phase1)
    res, rep = inc.mine_delta(
        ds.open_store(p), CFG, chunk_rows=300, checkpoint=True, resume=True
    )
    monkeypatch.undo()
    assert rep.mode == "delta" and rep.resumed_phase == inc._PHASE_WINNERS
    assert res.as_dict() == ap.mine(grown, CFG).as_dict()
    # a completed delta clears its snapshots
    assert MiningCheckpoint(
        os.path.join(ds.open_store(p).checkpoint_path, "delta")
    ).load_latest() is None


def test_delta_crash_resume_after_delta_counts(tmp_path, monkeypatch):
    """Crash after the phase-2 snapshot (delta counts done, base re-verify
    pending): resume restores the union AND its delta counts, then only the
    base pass runs."""
    base = _quest(2000, seed=15)
    extra = _quest(300, seed=16)
    p, grown = _grown(tmp_path, base, extra)
    real_count = stm.count_union_streamed
    calls = {"n": 0}

    def crash_on_base_pass(store, per_level, *a, **kw):
        calls["n"] += 1
        if calls["n"] == 2:      # 1st call = delta pass, 2nd = base re-verify
            raise _Crash()
        return real_count(store, per_level, *a, **kw)

    monkeypatch.setattr(inc.st, "count_union_streamed", crash_on_base_pass)
    with pytest.raises(_Crash):
        inc.mine_delta(ds.open_store(p), CFG, chunk_rows=300, checkpoint=True)
    monkeypatch.undo()
    seen_shards = []
    real_count2 = stm.count_union_streamed

    def record(store, per_level, *a, **kw):
        seen_shards.append(kw.get("shards"))
        return real_count2(store, per_level, *a, **kw)

    monkeypatch.setattr(inc.st, "count_union_streamed", record)
    res, rep = inc.mine_delta(
        ds.open_store(p), CFG, chunk_rows=300, checkpoint=True, resume=True
    )
    monkeypatch.undo()
    assert rep.resumed_phase == inc._PHASE_DELTA_COUNTS
    cache_shards = rep.base_shards
    assert all(s == (0, cache_shards) for s in seen_shards), seen_shards
    assert res.as_dict() == ap.mine(grown, CFG).as_dict()


def test_delta_checkpoint_rejects_foreign_cache_generation(tmp_path):
    """A delta snapshot is pinned to the cache generation it folds into:
    if the cache advanced underneath it, resume refuses."""
    base = _quest(1000, seed=17)
    p, _ = _grown(tmp_path, base, _quest(100, seed=18))
    store = ds.open_store(p)
    cache = inc.load_count_cache(store)
    sfp, mfp = inc.delta_fingerprints(store, cache, CFG, 300)
    mgr = inc._delta_manager(True, store)
    from repro.distributed.checkpoint import MiningState
    mgr.save(MiningState(levels={}, next_k=inc._PHASE_WINNERS), sfp, mfp)
    mgr.wait()
    _, manifest = mgr.load_latest()
    stale = dataclasses.replace(cache, seq=cache.seq + 1)
    sfp2, mfp2 = inc.delta_fingerprints(store, stale, CFG, 300)
    with pytest.raises(CheckpointMismatch):
        mgr.validate(manifest, sfp2, mfp2)

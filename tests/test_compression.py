"""Int8 error-feedback gradient compression: quantization bounds, multi-device
psum equivalence, and the compressed cross-pod train path."""

import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed.compression import int8_ef_state, wire_bytes

from conftest import REPO_ROOT, subprocess_env


# Partial-manual shard_map (manual over 'pod', auto elsewhere) needs the
# jax >= 0.5 surface; the 0.4 experimental `auto=` path raises
# NotImplementedError on collectives inside the body.
_requires_partial_manual = pytest.mark.skipif(
    not hasattr(jax, "shard_map"),
    reason="partial-manual shard_map requires jax >= 0.5",
)


def test_wire_bytes():
    grads = {"a": jnp.zeros((10, 10)), "b": jnp.zeros(5)}
    assert wire_bytes(grads, compressed=False) == 105 * 4
    assert wire_bytes(grads, compressed=True) == 105


def test_ef_state_shapes():
    params = {"w": jnp.ones((3, 4), jnp.bfloat16)}
    err = int8_ef_state(params)
    assert err["w"].shape == (3, 4) and err["w"].dtype == jnp.float32


_PSUM = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.distributed.compression import compressed_psum, int8_ef_state

    mesh = jax.make_mesh((4, 2), ("pod", "data"))
    rng = np.random.default_rng(0)
    g_global = jnp.asarray(rng.standard_normal((4, 64)), jnp.float32)  # one row per pod

    def body(g, err):
        out, new_err = compressed_psum({"g": g}, {"g": err}, ("pod",))
        return out["g"], new_err["g"]

    from repro.core.mapreduce import shard_map
    fn = shard_map(body, mesh=mesh, in_specs=(P("pod", None), P("pod", None)),
                   out_specs=(P(None, None), P("pod", None)), axis_names={"pod"})

    exact = np.asarray(g_global.sum(0))  # each pod holds one row
    err = jnp.zeros((4, 64), jnp.float32)
    approx, err = fn(g_global, err)
    approx = np.asarray(approx)[0]
    scale = np.abs(np.asarray(g_global)).max() / 127.0
    assert np.abs(approx - exact).max() <= 4 * scale + 1e-6, (approx[:4], exact[:4])

    # error feedback: repeated reduction of the SAME gradient converges in mean
    g_err = jnp.zeros((4, 64), jnp.float32)
    acc = np.zeros(64)
    steps = 50
    for _ in range(steps):
        out, g_err = fn(g_global, g_err)
        acc += np.asarray(out)[0]
    bias = np.abs(acc / steps - exact).max()
    assert bias < scale, f"EF bias {bias} vs scale {scale}"
    print("COMPRESS_OK")
    """
)


@_requires_partial_manual
def test_compressed_psum_multidevice():
    proc = subprocess.run(
        [sys.executable, "-c", _PSUM],
        capture_output=True, text=True, timeout=600,
        env=subprocess_env(),
        cwd=REPO_ROOT,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "COMPRESS_OK" in proc.stdout


_TRAIN = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_config
    from repro.launch.mesh import make_auto_mesh
    from repro.training.optimizer import AdamWConfig
    from repro.training.train_loop import init_train_state, make_train_step

    mesh = make_auto_mesh((2, 2, 2), ("pod", "data", "model"))
    cfg = get_config("qwen1p5_4b").reduced()
    state = init_train_state(jax.random.key(0), cfg, compress=True, n_pods=2)
    step = make_train_step(cfg, AdamWConfig(peak_lr=3e-3, warmup_steps=2), mesh=mesh,
                           cross_pod_compress=True, donate=False)
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab_size, (8, 17))
    batch = {"tokens": jnp.asarray(toks[:, :-1], jnp.int32),
             "labels": jnp.asarray(toks[:, 1:], jnp.int32)}
    losses = []
    for _ in range(12):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.2, losses
    print("COMPRESSED_TRAIN_OK", round(losses[0], 3), "->", round(losses[-1], 3))
    """
)


@_requires_partial_manual
def test_compressed_cross_pod_training():
    """End-to-end: int8-EF cross-pod reduction still trains (loss decreases)."""
    proc = subprocess.run(
        [sys.executable, "-c", _TRAIN],
        capture_output=True, text=True, timeout=900,
        env=subprocess_env(),
        cwd=REPO_ROOT,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "COMPRESSED_TRAIN_OK" in proc.stdout

"""SON two-phase mining — beyond-paper round-count optimization.

The paper's job structure synchronizes once per level k (max_k Hadoop rounds).
SON (Savasere–Omiecinski–Navathe, VLDB'95) needs exactly TWO distributed
rounds regardless of depth:

  phase 1 (Map):    each partition is mined *locally* to completion at the
                    scaled threshold; the union of local winners is the global
                    candidate set.  No globally frequent itemset can be missed
                    (if s(X)/N >= θ then X is locally frequent in >= 1
                    partition by pigeonhole).
  phase 2 (Reduce): one exact distributed count of the union (the same
                    kernels.support_count Map/Reduce step), then prune.

Fewer barriers = fewer straggler exposures and a 2-checkpoint recovery story —
this directly attacks the paper's Fig-4 heterogeneity penalty.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core import apriori as ap
from repro.core import itemsets as enc


def _mine_local(t_np: np.ndarray, min_count: int, cfg: ap.AprioriConfig) -> dict:
    """Single-partition in-memory Apriori (the phase-1 'mapper').

    Inherits the caller's count/representation config — only the support
    threshold is rescaled to the partition and the mesh axes dropped (each
    mapper is single-device), so a packed/Pallas mine runs phase 1 on the
    packed path too."""
    local_cfg = dataclasses.replace(
        cfg,
        min_support=min_count / max(1, t_np.shape[0]),
        data_axes=("data",),
        model_axis=None,
    )
    res = ap.mine(t_np, local_cfg, mesh=None)
    return res.levels


def local_winners(partition_dense, cfg: ap.AprioriConfig) -> dict:
    """One partition's phase-1 map output: its locally frequent itemsets at
    the partition-scaled threshold, as ``k -> set of itemset tuples``.

    This is the unit the fault-tolerant executor re-runs: it is a pure
    function of (partition data, cfg), so re-executing a lost mapper from
    its re-read shard yields the identical output — Hadoop's task
    re-execution contract (DESIGN.md §11)."""
    part = np.asarray(partition_dense, dtype=np.int8)
    if part.shape[0] == 0:
        return {}
    local_min = max(1, math.ceil(cfg.min_support * part.shape[0]))
    return {
        k: {tuple(int(x) for x in row) for row in sets}
        for k, (sets, _) in _mine_local(part, local_min, cfg).items()
    }


def merge_winners(winner_dicts) -> dict:
    """The phase-1 reduce: union per-partition winner dicts per level.
    Order-independent (set union), so it is insensitive to the completion
    order of a retrying/speculating executor."""
    union: dict[int, set] = {}
    for w in winner_dicts:
        for k, s in w.items():
            union.setdefault(k, set()).update(s)
    return union


def union_local_winners(partitions, cfg: ap.AprioriConfig) -> dict:
    """The phase-1 mapper over an iterable of dense partitions: mine each
    locally at the partition-scaled threshold and union the winners per
    level. Streaming-friendly — partitions are consumed one at a time, so an
    on-disk store can feed its shards without materializing the DB
    (``core.streaming.mine_son_streamed``)."""
    return merge_winners(local_winners(part, cfg) for part in partitions)


def winners_to_arrays(union: dict) -> dict:
    """Canonicalize a phase-1 union ``k -> set of tuples`` into sorted
    ``k -> (K, k) int32`` candidate arrays — the count-export format the
    streamed phase 2 and the incremental count cache share (DESIGN.md §15).
    Sorting makes the layout deterministic: the same union always persists
    and counts byte-identically."""
    return {
        k: np.array(sorted(s), dtype=np.int32).reshape(len(s), k)
        for k, s in sorted(union.items())
        if s
    }


def arrays_to_winners(levels: dict) -> dict:
    """Inverse of :func:`winners_to_arrays` (accepts bare candidate arrays)."""
    return {
        k: {tuple(int(x) for x in row) for row in np.asarray(cands)}
        for k, cands in levels.items()
    }


def mine_son(
    transactions_dense,
    cfg: ap.AprioriConfig = ap.AprioriConfig(),
    mesh=None,
    num_partitions: int = 8,
) -> ap.AprioriResult:
    t_np = np.asarray(transactions_dense, dtype=np.int8)
    n, num_items = t_np.shape
    min_count = max(1, math.ceil(cfg.min_support * n))

    # ---- phase 1: local mining per partition, union of local winners ----
    bounds = np.linspace(0, n, num_partitions + 1).astype(int)
    union = union_local_winners(
        (t_np[bounds[p] : bounds[p + 1]] for p in range(num_partitions)), cfg
    )

    # ---- phase 2: one exact global count of the union (the same encode +
    # place + count path as the level-wise miner, incl. packed bitsets) ----
    count_step = ap.make_count_step(mesh, cfg)
    t_dev = ap.place_db(t_np, cfg, mesh)
    levels = {}
    for k in sorted(union):
        cands = np.array(sorted(union[k]), dtype=np.int32)
        sup = ap._count_level(count_step, t_dev, cands, num_items, cfg, mesh)
        keep = sup >= min_count
        if keep.any():
            levels[k] = (cands[keep], sup[keep])
    return ap.AprioriResult(levels=levels, num_transactions=n, min_count=min_count)

"""Lightweight sampled span tracer with a perfetto-loadable exporter (§13).

A :class:`Tracer` hands out :class:`Span` objects carrying a trace-id (one
per sampled request / mine level) and a span-id, with parent nesting and
free-form attributes.  Finished spans land in a thread-safe ring buffer;
``export_chrome()`` renders them as Chrome trace-event JSON ("X" complete
events, microsecond timestamps) that https://ui.perfetto.dev loads directly.

Sampling is deterministic: with ``sample_rate=r`` every ``round(1/r)``-th
root is traced (the first root always is), so tests and CI smokes get
reproducible traces without a seeded RNG.  Unsampled call sites cost one
``None`` check — instrumentation stays inert when tracing is off, and every
helper (``child``/``end``/``add_span``) accepts ``None`` parents so call
sites never branch.

Span ends are idempotent: failure paths can ``end()`` a span that a success
path may also try to close, and only the first close is recorded.
"""

from __future__ import annotations

import itertools
import json
import threading
import time
from collections import deque
from contextlib import contextmanager
from typing import Dict, Optional


class Span:
    """One timed operation.  ``end()`` is idempotent; attributes set at end
    merge over those set at start."""

    __slots__ = ("tracer", "trace_id", "span_id", "parent_id", "name",
                 "t0", "t1", "attrs", "tid", "_ended")

    def __init__(self, tracer: "Tracer", trace_id: int, span_id: int,
                 parent_id: Optional[int], name: str, t0: float, attrs: dict):
        self.tracer = tracer
        self.trace_id = trace_id
        self.span_id = span_id
        self.parent_id = parent_id
        self.name = name
        self.t0 = t0
        self.t1 = None
        self.attrs = attrs
        self.tid = threading.get_ident()
        self._ended = False

    def child(self, name: str, **attrs) -> "Span":
        """Start a child span on the same trace (current time, this thread)."""
        return self.tracer._start(name, self.trace_id, self.span_id, attrs)

    def end(self, **attrs) -> None:
        if self._ended:
            return
        self._ended = True
        self.t1 = time.perf_counter()
        if attrs:
            self.attrs.update(attrs)
        self.tracer._finish(self)

    def set(self, **attrs) -> None:
        self.attrs.update(attrs)

    def duration_s(self) -> float:
        return (self.t1 - self.t0) if self.t1 is not None else 0.0


class Tracer:
    """Sampled span factory + thread-safe ring buffer of finished spans."""

    def __init__(self, sample_rate: float = 1.0, capacity: int = 16384):
        self.sample_rate = float(sample_rate)
        self._period = 0 if self.sample_rate <= 0.0 else max(1, round(1.0 / self.sample_rate))
        self._lock = threading.Lock()
        self._roots_seen = 0
        self._span_ids = itertools.count(1)
        self._trace_ids = itertools.count(1)
        self._done = deque(maxlen=int(capacity))
        self._thread_names: Dict[int, str] = {}
        self.epoch = time.perf_counter()
        self.sampled_roots = 0

    # -- span creation -----------------------------------------------------

    def _start(self, name: str, trace_id: int, parent_id: Optional[int],
               attrs: dict) -> Span:
        with self._lock:
            sid = next(self._span_ids)
            tid = threading.get_ident()
            if tid not in self._thread_names:
                self._thread_names[tid] = threading.current_thread().name
        return Span(self, trace_id, sid, parent_id, name, time.perf_counter(), attrs)

    def root(self, name: str, force: bool = False, **attrs) -> Optional[Span]:
        """Start a new root span iff this root is sampled, else ``None``.
        ``force=True`` bypasses sampling (and does not consume a sampling
        slot) — for rare, always-interesting roots like hot-swaps."""
        if not force:
            with self._lock:
                i = self._roots_seen
                self._roots_seen += 1
                take = self._period > 0 and i % self._period == 0
                if take:
                    self.sampled_roots += 1
            if not take:
                return None
        with self._lock:
            trace_id = next(self._trace_ids)
        return self._start(name, trace_id, None, attrs)

    def child(self, parent: Optional[Span], name: str, **attrs) -> Optional[Span]:
        """Child of ``parent``, or ``None`` when the parent wasn't sampled."""
        if parent is None:
            return None
        return parent.child(name, **attrs)

    def add_span(self, parent: Optional[Span], name: str,
                 t0: float, t1: float, **attrs) -> None:
        """Record an already-elapsed interval (``perf_counter`` endpoints) as
        a finished child span — for phases measured before the span's shape
        was known, e.g. queue wait reconstructed at dispatch time."""
        if parent is None:
            return
        sp = self._start(name, parent.trace_id, parent.span_id, attrs)
        sp.t0 = t0
        sp._ended = True
        sp.t1 = t1
        self._finish(sp)

    @contextmanager
    def span(self, parent: Optional[Span], name: str, **attrs):
        sp = self.child(parent, name, **attrs)
        try:
            yield sp
        finally:
            if sp is not None:
                sp.end()

    # -- collection & export ----------------------------------------------

    def _finish(self, span: Span) -> None:
        with self._lock:
            self._done.append(span)

    def spans(self) -> list:
        with self._lock:
            return list(self._done)

    def export_chrome(self) -> dict:
        """Chrome trace-event JSON (perfetto-loadable): one "X" complete
        event per finished span, µs timestamps relative to tracer epoch,
        plus "M" thread-name metadata."""
        with self._lock:
            spans = list(self._done)
            thread_names = dict(self._thread_names)
        tid_map = {t: i for i, t in enumerate(sorted(thread_names), start=1)}
        events = []
        for t, name in thread_names.items():
            events.append({"name": "thread_name", "ph": "M", "pid": 1,
                           "tid": tid_map[t], "args": {"name": name}})
        for sp in spans:
            args = {"trace_id": sp.trace_id, "span_id": sp.span_id}
            if sp.parent_id is not None:
                args["parent_id"] = sp.parent_id
            args.update(sp.attrs)
            events.append({
                "name": sp.name,
                "ph": "X",
                "ts": (sp.t0 - self.epoch) * 1e6,
                "dur": max(0.0, (sp.t1 - sp.t0) * 1e6),
                "pid": 1,
                "tid": tid_map.get(sp.tid, 0),
                "args": args,
            })
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def save_chrome(self, path: str) -> None:
        with open(path, "w") as fh:
            json.dump(self.export_chrome(), fh)

"""Dry-run machinery unit tests: the HLO static analyzer (trip-count
correctness against hand-computed FLOPs) and a miniature end-to-end
lower+compile+analyze on an 8-device mesh (subprocess)."""

import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_analysis import analyze, parse_hlo

from conftest import REPO_ROOT, subprocess_env



def test_analyzer_counts_scan_trips():
    """XLA cost_analysis counts while bodies once; ours multiplies by trip."""
    L, D, F = 8, 64, 128

    def fwd(params, x):
        def body(h, p):
            return jnp.tanh(h @ p["w1"]) @ p["w2"], None

        h, _ = jax.lax.scan(body, x, params)
        return h.sum()

    params = {"w1": jnp.ones((L, D, F)), "w2": jnp.ones((L, F, D))}
    x = jnp.ones((4, D))
    compiled = jax.jit(fwd).lower(params, x).compile()
    c = analyze(compiled.as_text())
    expect = 2 * 4 * D * F * 2 * L  # two matmuls per layer, L layers
    assert abs(c.flops - expect) / expect < 0.01, (c.flops, expect)

    raw = compiled.cost_analysis()
    raw = raw[0] if isinstance(raw, list) else raw
    if "flops" in raw and raw["flops"] > 0:
        assert raw["flops"] < c.flops  # the very bug this analyzer fixes


def test_analyzer_parses_computations():
    txt = """HloModule test, num_partitions=2

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %r = f32[] add(%a, %b)
}

ENTRY %main (x: f32[8,16]) -> f32[8,8] {
  %x = f32[8,16]{1,0} parameter(0)
  %y = f32[16,8]{1,0} constant(0)
  %d = f32[8,8]{1,0} dot(%x, %y), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %ar = f32[8,8]{1,0} all-reduce(%d), replica_groups={}, to_apply=%add
}
"""
    comps = parse_hlo(txt)
    assert "main" in comps and comps["main"][1]
    c = analyze(txt)
    assert c.flops == 2 * 8 * 8 * 16
    assert c.collective_bytes == 8 * 8 * 4
    assert c.collective_counts == {"all-reduce": 1}


_MINI = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs import get_config
    from repro.distributed.sharding import ShardingRules, param_pspecs
    from repro.launch import hlo_analysis
    from repro.launch.mesh import make_auto_mesh
    from repro.models.shard_ctx import activation_sharding
    from repro.training.optimizer import AdamWConfig
    from repro.training.train_loop import build_train_step
    from repro.launch.specs import params_sds, train_state_sds

    mesh = make_auto_mesh((4, 2), ("data", "model"))
    cfg = get_config("deepseek_coder_33b").reduced(
        d_model=128, num_heads=4, num_kv_heads=2, d_ff=256, vocab_size=512,
        compute_dtype="bfloat16", remat=True)
    rules = ShardingRules()
    state = train_state_sds(cfg)
    pspecs = param_pspecs(state["params"], mesh, rules)
    st_sh = {"params": jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                                    is_leaf=lambda s: isinstance(s, P)),
             "opt": {"m": jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                                       is_leaf=lambda s: isinstance(s, P)),
                     "v": jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                                       is_leaf=lambda s: isinstance(s, P)),
                     "step": NamedSharding(mesh, P())}}
    batch = {"tokens": jax.ShapeDtypeStruct((8, 32), jnp.int32),
             "labels": jax.ShapeDtypeStruct((8, 32), jnp.int32)}
    b_sh = jax.tree.map(lambda x: NamedSharding(mesh, P(("data",), None)), batch)

    step = build_train_step(cfg, AdamWConfig())
    with activation_sharding(mesh, ("data",), "model"):
        lowered = jax.jit(step, in_shardings=(st_sh, b_sh),
                          out_shardings=(st_sh, None), donate_argnums=(0,)
                          ).lower(state, batch)
    compiled = lowered.compile()
    mem = compiled.memory_analysis()
    assert mem.temp_size_in_bytes > 0
    s = hlo_analysis.summarize(compiled.as_text())
    assert s["flops"] > 0
    assert s["collective_counts"], "sharded train step must emit collectives"
    print("MINI_DRYRUN_OK", int(s["flops"]), sorted(s["collective_counts"]))
    """
)


def test_mini_dryrun_8dev():
    proc = subprocess.run(
        [sys.executable, "-c", _MINI],
        capture_output=True, text=True, timeout=900,
        env=subprocess_env(),
        cwd=REPO_ROOT,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "MINI_DRYRUN_OK" in proc.stdout

"""Host-side validation of the sharding rules for every arch on both
production mesh shapes — every sharded dim must divide its axis size.
(Uses a fake mesh object: specs only consult mesh.shape.)"""

import dataclasses

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.configs.shapes import SHAPES, is_skipped
from repro.distributed.sharding import ShardingRules, cache_pspecs, param_pspecs
from repro.launch.roofline import count_params
from repro.models.transformer import init_decode_cache, init_model

LM_ARCHS = [a for a in ARCH_IDS if a != "apriori"]


class FakeMesh:
    def __init__(self, shape: dict):
        self.shape = shape
        self.axis_names = tuple(shape)


MESHES = {
    "single": FakeMesh({"data": 16, "model": 16}),
    "multi": FakeMesh({"pod": 2, "data": 16, "model": 16}),
}


def _check_divisibility(tree, spec_tree, mesh):
    leaves = jax.tree.leaves(tree)
    specs = jax.tree.leaves(spec_tree, is_leaf=lambda s: isinstance(s, P))
    assert len(leaves) == len(specs)
    for leaf, spec in zip(leaves, specs):
        for dim, entry in zip(np.shape(leaf), spec):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            size = 1
            for a in axes:
                size *= mesh.shape[a]
            assert dim % size == 0, f"dim {dim} not divisible by {axes} ({size}) in spec {spec}"


@pytest.mark.parametrize("mesh_name", list(MESHES))
@pytest.mark.parametrize("arch", LM_ARCHS)
def test_param_specs_divisible_at_full_scale(arch, mesh_name):
    """Eval-shape the FULL config (no allocation) and validate every spec."""
    cfg = get_config(arch)
    mesh = MESHES[mesh_name]
    p_sds = jax.eval_shape(lambda: init_model(jax.random.key(0), cfg))
    specs = param_pspecs(p_sds, mesh, ShardingRules())
    _check_divisibility(p_sds, specs, mesh)


@pytest.mark.parametrize("mesh_name", list(MESHES))
@pytest.mark.parametrize("arch", LM_ARCHS)
@pytest.mark.parametrize("shape_name", ["decode_32k", "long_500k"])
def test_cache_specs_divisible_at_full_scale(arch, mesh_name, shape_name):
    cfg = get_config(arch)
    if is_skipped(cfg, shape_name):
        pytest.skip("long_500k: full-attention arch")
    sh = SHAPES[shape_name]
    mesh = MESHES[mesh_name]
    cache = jax.eval_shape(lambda: init_decode_cache(cfg, sh["global_batch"], sh["seq_len"]))
    specs = cache_pspecs(cache, mesh, ShardingRules(), batch=sh["global_batch"])
    _check_divisibility(cache, specs, mesh)


def test_big_matrices_are_sharded():
    """No >64 MB parameter may end up fully replicated (memory safety)."""
    mesh = MESHES["single"]
    for arch in LM_ARCHS:
        cfg = get_config(arch)
        p_sds = jax.eval_shape(lambda: init_model(jax.random.key(0), cfg))
        specs = param_pspecs(p_sds, mesh, ShardingRules())
        leaves = jax.tree.leaves(p_sds)
        spec_leaves = jax.tree.leaves(specs, is_leaf=lambda s: isinstance(s, P))
        for leaf, spec in zip(leaves, spec_leaves):
            size = np.prod(np.shape(leaf)) * 4
            if size > 64e6:
                assert any(e is not None for e in spec), (arch, np.shape(leaf), spec)


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_param_count_estimator_close(arch):
    """Analytic count_params ~ eval-shape truth (MODEL_FLOPS credibility)."""
    cfg = get_config(arch)
    p_sds = jax.eval_shape(lambda: init_model(jax.random.key(0), cfg))
    true_total = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(p_sds))
    est = count_params(cfg)["total"]
    # zamba stores one shared block; estimator models the same
    assert abs(est - true_total) / true_total < 0.05, (arch, est, true_total)

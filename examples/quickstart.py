"""Quickstart: mine frequent itemsets + association rules on synthetic data.

PYTHONPATH=src python examples/quickstart.py
"""

from repro.core.apriori import AprioriConfig, mine
from repro.core.rules import extract_rules
from repro.data.synthetic import QuestConfig, gen_transactions


def main():
    # 1. generate a T10-style transaction database (the paper's workload)
    db = gen_transactions(QuestConfig(num_transactions=5_000, num_items=200, avg_len=9, seed=42))
    print(f"DB: {db.shape[0]} transactions x {db.shape[1]} items, density {db.mean():.3f}")

    # 2. level-wise distributed Apriori (single device here; add a mesh for a pod)
    result = mine(db, AprioriConfig(min_support=0.03, max_k=5))
    for k in sorted(result.levels):
        print(f"  L{k}: {result.levels[k][0].shape[0]} frequent itemsets")

    # 3. association rules (KDD interpretation step)
    rules = extract_rules(result, min_confidence=0.7, max_rules=10)
    print("top rules:")
    for r in rules:
        print(f"  {r.antecedent} -> {r.consequent}   conf={r.confidence:.2f} lift={r.lift:.2f}")


if __name__ == "__main__":
    main()

"""Dry-run machinery unit tests: the HLO static analyzer (trip-count
correctness against hand-computed FLOPs and parser coverage). The end-to-end
lower+compile+analyze path of the MINER is covered by
``launch.mine_dryrun`` via the quick bench in CI."""

import jax
import jax.numpy as jnp

from repro.launch.hlo_analysis import analyze, parse_hlo



def test_analyzer_counts_scan_trips():
    """XLA cost_analysis counts while bodies once; ours multiplies by trip."""
    L, D, F = 8, 64, 128

    def fwd(params, x):
        def body(h, p):
            return jnp.tanh(h @ p["w1"]) @ p["w2"], None

        h, _ = jax.lax.scan(body, x, params)
        return h.sum()

    params = {"w1": jnp.ones((L, D, F)), "w2": jnp.ones((L, F, D))}
    x = jnp.ones((4, D))
    compiled = jax.jit(fwd).lower(params, x).compile()
    c = analyze(compiled.as_text())
    expect = 2 * 4 * D * F * 2 * L  # two matmuls per layer, L layers
    assert abs(c.flops - expect) / expect < 0.01, (c.flops, expect)

    raw = compiled.cost_analysis()
    raw = raw[0] if isinstance(raw, list) else raw
    if "flops" in raw and raw["flops"] > 0:
        assert raw["flops"] < c.flops  # the very bug this analyzer fixes


def test_analyzer_parses_computations():
    txt = """HloModule test, num_partitions=2

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %r = f32[] add(%a, %b)
}

ENTRY %main (x: f32[8,16]) -> f32[8,8] {
  %x = f32[8,16]{1,0} parameter(0)
  %y = f32[16,8]{1,0} constant(0)
  %d = f32[8,8]{1,0} dot(%x, %y), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %ar = f32[8,8]{1,0} all-reduce(%d), replica_groups={}, to_apply=%add
}
"""
    comps = parse_hlo(txt)
    assert "main" in comps and comps["main"][1]
    c = analyze(txt)
    assert c.flops == 2 * 8 * 8 * 16
    assert c.collective_bytes == 8 * 8 * 4
    assert c.collective_counts == {"all-reduce": 1}

"""End-to-end rule serving: mine -> compile rulebook -> batched recommend.

  PYTHONPATH=src python examples/serve_rules.py \
      [--transactions 4000] [--items 128] [--min-support 0.02] \
      [--min-confidence 0.5] [--top-k 5] [--batch-size 512] [--rulebook rb.npz]

The three stages (DESIGN.md §8):

  1. mine        — level-wise Apriori on the packed bitset path
                   (``core.apriori.mine``, representation='packed');
  2. compile     — vectorized rule extraction + rulebook compilation
                   (``serving.compile_rulebook``): packed uint32
                   antecedent/consequent bitsets + a float32 score column,
                   saved/loaded as one ``.npz`` artifact;
  3. serve       — the batched query engine (``serving.recommend``): the
                   rule-match kernel scores every (basket, rule) pair,
                   aggregates evidence per item, masks the basket's own
                   items, and takes top-k.

The same artifact can be produced straight from the mining CLI:

  PYTHONPATH=src python -m repro.launch.mine --transactions 4000 --items 128 \
      --rulebook rb.npz --min-confidence 0.5 --rule-score confidence

and a stored rulebook can be served without re-mining by passing
``--rulebook rb.npz`` here (it is loaded if the file exists).
"""

import argparse
import os
import time

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--transactions", type=int, default=4_000)
    ap.add_argument("--items", type=int, default=128)
    ap.add_argument("--avg-len", type=float, default=10.0)
    ap.add_argument("--min-support", type=float, default=0.02)
    ap.add_argument("--max-k", type=int, default=4)
    ap.add_argument("--min-confidence", type=float, default=0.5)
    ap.add_argument("--top-k", type=int, default=5)
    ap.add_argument("--batch-size", type=int, default=512)
    ap.add_argument("--num-queries", type=int, default=1024)
    ap.add_argument("--impl", default="auto",
                    choices=["auto", "jnp", "pallas", "pallas_interpret"])
    ap.add_argument("--rulebook", default="", metavar="PATH",
                    help="save the compiled rulebook here (and reuse it if present)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.core.apriori import AprioriConfig, mine
    from repro.data.synthetic import QuestConfig, gen_transactions
    from repro.serving import Rulebook, compile_rulebook, recommend

    print(f"[serve_rules] generating {args.transactions} x {args.items} transactions ...")
    db = gen_transactions(QuestConfig(
        num_transactions=args.transactions, num_items=args.items,
        avg_len=args.avg_len, seed=args.seed))

    if args.rulebook and os.path.exists(args.rulebook):
        rb = Rulebook.load(args.rulebook)
        print(f"[serve_rules] loaded rulebook {args.rulebook}: {rb.num_rules} rules")
    else:
        t0 = time.perf_counter()
        res = mine(db, AprioriConfig(
            min_support=args.min_support, max_k=args.max_k,
            count_impl="auto", representation="packed"))
        t_mine = time.perf_counter() - t0
        print(f"[serve_rules] mined {res.total_frequent} frequent itemsets "
              f"in {t_mine:.2f}s (min_count={res.min_count})")

        t0 = time.perf_counter()
        rb = compile_rulebook(res, min_confidence=args.min_confidence,
                              num_items=args.items)
        print(f"[serve_rules] compiled {rb.num_rules} rules "
              f"({rb.num_rows} padded rows, score={rb.score_kind}) "
              f"in {time.perf_counter() - t0:.2f}s")
        if args.rulebook:
            rb.save(args.rulebook)
            rb = Rulebook.load(args.rulebook)   # round-trip the artifact
            print(f"[serve_rules] saved + reloaded {args.rulebook}")

    # queries: the transaction rows themselves make natural baskets
    queries = db[: args.num_queries]
    out = recommend(rb, queries, top_k=args.top_k,
                    batch_size=args.batch_size, impl=args.impl)   # warm/compile
    t0 = time.perf_counter()
    out = recommend(rb, queries, top_k=args.top_k,
                    batch_size=args.batch_size, impl=args.impl)
    dt = time.perf_counter() - t0
    qps = len(queries) / dt
    print(f"[serve_rules] served {len(queries)} baskets in {dt:.3f}s "
          f"({qps:,.0f} queries/s, batch={args.batch_size})")

    for b in range(min(3, len(queries))):
        have = np.flatnonzero(db[b]).tolist()
        recs = [(int(i), float(s)) for i, s in zip(out.items[b], out.scores[b])
                if np.isfinite(s) and s > 0]
        print(f"  basket {b} {have} -> {recs}")


if __name__ == "__main__":
    main()

"""Span tracer (obs.trace): parent nesting, deterministic sampling, ring
capacity, idempotent ends, and the Chrome trace-event exporter schema that
ui.perfetto.dev requires (DESIGN.md §13)."""

import json
import time

from repro.obs.trace import Tracer


def test_span_nesting_and_ids():
    tr = Tracer(sample_rate=1.0)
    root = tr.root("request", top_k=5)
    assert root is not None and root.parent_id is None
    inner = root.child("dispatch", bucket=8)
    assert inner.trace_id == root.trace_id
    assert inner.parent_id == root.span_id
    assert inner.span_id != root.span_id
    inner.end(outcome="ok")
    root.end()
    spans = tr.spans()
    assert [s.name for s in spans] == ["dispatch", "request"]  # finish order
    assert spans[0].t0 >= root.t0 and spans[0].t1 <= spans[1].t1


def test_end_is_idempotent():
    tr = Tracer(sample_rate=1.0)
    sp = tr.root("op")
    sp.end(outcome="failed")
    t1 = sp.t1
    sp.end(outcome="ok")        # second close: ignored entirely
    assert sp.t1 == t1
    assert sp.attrs["outcome"] == "failed"
    assert len(tr.spans()) == 1


def test_deterministic_sampling():
    tr = Tracer(sample_rate=0.25)
    picks = [tr.root("r", i=i) is not None for i in range(12)]
    # every 4th root, starting with the first — no RNG involved
    assert picks == [i % 4 == 0 for i in range(12)]
    assert tr.sampled_roots == 3
    # rate 0 never samples; force bypasses sampling without consuming a slot
    tr0 = Tracer(sample_rate=0.0)
    assert tr0.root("r") is None
    assert tr0.root("swap", force=True) is not None


def test_unsampled_paths_are_none_safe():
    tr = Tracer(sample_rate=0.0)
    parent = tr.root("r")
    assert parent is None
    assert tr.child(parent, "c") is None
    tr.add_span(parent, "phase", 0.0, 1.0)       # silently dropped
    with tr.span(parent, "ctx") as sp:
        assert sp is None
    assert tr.spans() == []


def test_ring_buffer_capacity():
    tr = Tracer(sample_rate=1.0, capacity=8)
    for i in range(20):
        tr.root(f"op{i}").end()
    spans = tr.spans()
    assert len(spans) == 8
    assert spans[0].name == "op12" and spans[-1].name == "op19"


def test_add_span_records_elapsed_interval():
    tr = Tracer(sample_rate=1.0)
    root = tr.root("level")
    t0 = time.perf_counter()
    t1 = t0 + 0.25
    tr.add_span(root, "count_kernel", t0, t1, chunk=3)
    root.end()
    kernel = next(s for s in tr.spans() if s.name == "count_kernel")
    assert kernel.parent_id == root.span_id
    assert kernel.duration_s() == 0.25
    assert kernel.attrs["chunk"] == 3


def test_chrome_export_schema(tmp_path):
    tr = Tracer(sample_rate=1.0)
    root = tr.root("request")
    child = root.child("dispatch")
    child.end()
    root.end(outcome="ok")
    path = tmp_path / "trace.json"
    tr.save_chrome(str(path))

    doc = json.loads(path.read_text())            # must be valid JSON
    assert set(doc) == {"traceEvents", "displayTimeUnit"}
    xs = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    ms = [e for e in doc["traceEvents"] if e["ph"] == "M"]
    assert len(xs) == 2 and len(ms) >= 1
    for e in xs:                                   # perfetto-required keys
        assert {"name", "ph", "ts", "dur", "pid", "tid", "args"} <= set(e)
        assert e["ts"] >= 0 and e["dur"] >= 0
        assert {"trace_id", "span_id"} <= set(e["args"])
    assert ms[0]["name"] == "thread_name"
    # the child event nests inside the root event on the µs timeline
    ce = next(e for e in xs if e["name"] == "dispatch")
    re = next(e for e in xs if e["name"] == "request")
    assert ce["args"]["parent_id"] == re["args"]["span_id"]
    assert re["ts"] <= ce["ts"]
    assert ce["ts"] + ce["dur"] <= re["ts"] + re["dur"] + 1e-3
    assert ce["args"]["trace_id"] == re["args"]["trace_id"]


def test_tracer_is_thread_safe_under_concurrent_roots():
    import threading

    tr = Tracer(sample_rate=1.0)

    def burst(n):
        for _ in range(n):
            sp = tr.root("op")
            sp.child("inner").end()
            sp.end()

    threads = [threading.Thread(target=burst, args=(50,)) for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    spans = tr.spans()
    assert len(spans) == 4 * 50 * 2
    assert len({s.span_id for s in spans}) == len(spans)   # ids never collide
    doc = tr.export_chrome()
    # every event maps to a registered exporter tid (the OS may reuse thread
    # idents across short-lived threads, so only >= 1 distinct is guaranteed)
    tids = {e["tid"] for e in doc["traceEvents"] if e["ph"] == "X"}
    assert tids and all(t >= 1 for t in tids)

"""RWKV-6 "Finch" block (arXiv:2404.05892): time-mix with data-dependent
per-channel decay + channel-mix. Attention-free; state is O(H·K·V) per layer.

Projections/decays for the whole sequence are computed in parallel (MXU);
only the WKV recurrence scans over time. Decode is the exact one-step update.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init


def _dims(cfg):
    dh = cfg.rwkv.head_dim
    h = cfg.d_model // dh
    return h, dh


def rwkv6_init(key, cfg):
    d = cfg.d_model
    r = cfg.rwkv
    h, dh = _dims(cfg)
    ks = jax.random.split(key, 12)
    # decay bias: spread across channels like the reference init
    decay_speed = -6.0 + 5.0 * (jnp.arange(d) / max(1, d - 1)) ** 0.9
    return {
        "tm": {
            # ddlerp: 5 mixing directions (w,k,v,r,g), base mu + low-rank adapter
            "mu": jax.random.uniform(ks[0], (5, d), jnp.float32, 0.0, 1.0),
            "mix_a": dense_init(ks[1], (d, 5 * r.lora_dim)),
            "mix_b": jax.random.normal(ks[2], (5, r.lora_dim, d), jnp.float32) * 0.01,
            "w0": decay_speed,                                  # (d,) decay base
            "w_a": dense_init(ks[3], (d, r.lora_dim)),
            "w_b": jax.random.normal(ks[4], (r.lora_dim, d), jnp.float32) * 0.01,
            "u": jax.random.normal(ks[5], (d,), jnp.float32) * 0.1,  # bonus
            "wr": dense_init(ks[6], (d, d)),
            "wk": dense_init(ks[7], (d, d)),
            "wv": dense_init(ks[8], (d, d)),
            "wg": dense_init(ks[9], (d, d)),
            "wo": dense_init(ks[10], (d, d)),
            "ln_scale": jnp.ones((d,), jnp.float32),            # per-head groupnorm
            "ln_bias": jnp.zeros((d,), jnp.float32),
        },
        "cm": {
            "mu_k": jax.random.uniform(ks[11], (d,), jnp.float32, 0.0, 1.0),
            "mu_r": jnp.full((d,), 0.5, jnp.float32),
            "wk": dense_init(jax.random.fold_in(key, 1), (d, r.d_ff)),
            "wv": dense_init(jax.random.fold_in(key, 2), (r.d_ff, d)),
            "wr": dense_init(jax.random.fold_in(key, 3), (d, d)),
        },
    }


def _ddlerp(p, x, x_prev):
    """Data-dependent token-shift interpolation for the 5 branches."""
    d = x.shape[-1]
    delta = (x_prev - x).astype(x.dtype)
    base = x + delta * p["mu"][:, None, None, :].astype(x.dtype)  # (5, B, S, D) lazy: build per branch
    lora = jnp.tanh(x @ p["mix_a"].astype(x.dtype))
    lora = lora.reshape(*x.shape[:-1], 5, -1)                     # (B, S, 5, R)
    adj = jnp.einsum("bsfr,frd->fbsd", lora, p["mix_b"].astype(x.dtype))
    return base + delta[None] * adj                               # (5, B, S, D)


def _wkv_scan(r, k, v, w, u, h, dh):
    """Oracle: exact per-timestep RWKV-6 recurrence (used by tests and as the
    chunked form's reference).

    r,k,v: (B, S, H, Dh); w: per-step decay in (0,1), same shape as k.
    y_t = r_t · (S_{t-1} + u ⊙ k_t v_tᵀ);  S_t = diag(w_t) S_{t-1} + k_t v_tᵀ.
    """
    b, s, _, _ = r.shape

    def step(state, inp):
        r_t, k_t, v_t, w_t = inp  # (B, H, Dh)
        kv = jnp.einsum("bhk,bhv->bhkv", k_t, v_t)
        y = jnp.einsum("bhk,bhkv->bhv", r_t, state + u[None, :, :, None] * kv)
        state = state * w_t[..., None] + kv
        return state, y

    from repro.models.layers import vzero

    s0 = jnp.zeros((b, h, dh, dh), jnp.float32) + vzero(r)
    xs = tuple(a.swapaxes(0, 1).astype(jnp.float32) for a in (r, k, v, w))
    state, ys = jax.lax.scan(step, s0, xs)
    return ys.swapaxes(0, 1), state  # (B, S, H, Dh), (B, H, K, V)


def _wkv_chunked(r, k, v, lw, u, chunk: int = 32):
    """Chunk-parallel WKV (perf iteration #4, EXPERIMENTS.md §Perf).

    The per-timestep scan does O(T) sequential state read/writes and the scan
    bwd stacks per-step residuals — at 4k train that measured ~2e15 HBM
    B/dev. The chunked form runs the recurrence at chunk granularity
    (T/Q iterations) with matmul-form intra-chunk mixing, so residuals and
    state traffic shrink by Q× and the inner compute lands on the MXU.

    r,k,v: (B, S, H, K) fp32; lw = log(decay) ≤ 0 per step, same shape;
    u: (H, K) bonus. Every exponent formed here is ≤ 0 (joint (t,s,k)
    differences), so no overflow — the factored e^{+c}·e^{-c} form is never
    materialised. Returns (y (B,S,H,V), final state (B,H,K,V)).
    """
    from repro.models.layers import vzero

    b, s, h, kdim = r.shape
    q = min(chunk, s)
    pad = (-s) % q
    if pad:
        # inert tail: zero r (no output), zero k (no state write), lw=0 (no decay)
        r = jnp.pad(r, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        lw = jnp.pad(lw, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = (s + pad) // q

    def to_chunks(a):  # (B, S, H, K) -> (nc, B, H, Q, K)
        return a.reshape(b, nc, q, h, -1).transpose(1, 0, 3, 2, 4)

    rc, kc, vc, lwc = map(to_chunks, (r, k, v, lw))
    c_inc = jnp.cumsum(lwc, axis=3)          # inclusive Σ_{j<=t} lw  (nc,B,H,Q,K)
    c_exc = c_inc - lwc                      # exclusive Σ_{j<t}
    causal = jnp.tril(jnp.ones((q, q), bool), k=-1)  # strict s < t

    def body(state, inp):
        r_, k_, v_, ci, ce = inp             # (B, H, Q, K/V)
        # intra-chunk: A[t,s] = Σ_k r[t,k] k[s,k] e^{ce[t,k]-ci[s,k]}, s<t
        gap = ce[:, :, :, None, :] - ci[:, :, None, :, :]      # (B,H,Qt,Qs,K) ≤ 0
        decay = jnp.where(causal[None, None, :, :, None], jnp.exp(gap), 0.0)
        a = jnp.einsum("bhtk,bhsk,bhtsk->bhts", r_, k_, decay)
        a_diag = jnp.einsum("bhtk,bhtk->bht", r_ * u[None, :, None, :], k_)
        a = a + a_diag[..., None] * jnp.eye(q)[None, None]
        y = jnp.einsum("bhts,bhsv->bhtv", a, v_)
        # inter-chunk: carry-in state decayed to each position
        y = y + jnp.einsum("bhtk,bhkv->bhtv", r_ * jnp.exp(ce), state)
        # state handoff: S' = diag(e^{c_last}) S + Σ_s e^{c_last - ci[s]} k_s ⊗ v_s
        c_last = ci[:, :, -1, :]                               # (B,H,K)
        w_k = jnp.exp(c_last[:, :, None, :] - ci)              # ≤ 1
        state = state * jnp.exp(c_last)[..., None] + jnp.einsum(
            "bhsk,bhsv->bhkv", k_ * w_k, v_)
        return state, y

    body = jax.checkpoint(body)
    s0 = jnp.zeros((b, h, kdim, v.shape[-1]), jnp.float32) + vzero(rc)
    state, ys = jax.lax.scan(body, s0, (rc, kc, vc, c_inc, c_exc))
    y = ys.transpose(1, 0, 3, 2, 4).reshape(b, s + pad, h, -1)
    return y[:, :s], state


def _group_norm(p, y, h, dh, eps=64e-5):
    """Per-head LayerNorm (RWKV's GroupNorm over heads)."""
    b, s, _, _ = y.shape
    mean = y.mean(-1, keepdims=True)
    var = ((y - mean) ** 2).mean(-1, keepdims=True)
    yn = (y - mean) * jax.lax.rsqrt(var + eps)
    yn = yn.reshape(b, s, h * dh)
    return yn * p["ln_scale"] + p["ln_bias"]


def timemix_apply(tm, x, x_prev, cfg, return_state: bool = False):
    h, dh = _dims(cfg)
    b, s, d = x.shape
    mixed = _ddlerp(tm, x, x_prev)                   # (5, B, S, D) order: w,k,v,r,g
    xw, xk, xv, xr, xg = mixed[0], mixed[1], mixed[2], mixed[3], mixed[4]

    # data-dependent decay: w = exp(-exp(w0 + lora(xw)))  in (0,1)
    w_log = tm["w0"].astype(jnp.float32) + (
        jnp.tanh(xw @ tm["w_a"].astype(x.dtype)) @ tm["w_b"].astype(x.dtype)
    ).astype(jnp.float32)
    lw = -jnp.exp(w_log)                             # log decay <= 0, (B, S, D)

    from repro.models.shard_ctx import weight_use as _wu
    r = (xr @ _wu(tm["wr"].astype(x.dtype))).reshape(b, s, h, dh).astype(jnp.float32)
    k = (xk @ _wu(tm["wk"].astype(x.dtype))).reshape(b, s, h, dh).astype(jnp.float32)
    v = (xv @ _wu(tm["wv"].astype(x.dtype))).reshape(b, s, h, dh).astype(jnp.float32)
    g = xg @ _wu(tm["wg"].astype(x.dtype))
    u = tm["u"].astype(jnp.float32).reshape(h, dh)

    chunk = getattr(cfg.rwkv, "chunk", 32)
    if chunk > 1:
        y, state = _wkv_chunked(r, k, v, lw.reshape(b, s, h, dh), u, chunk=chunk)
    else:
        y, state = _wkv_scan(r, k, v, jnp.exp(lw).reshape(b, s, h, dh), u, h, dh)
    y = _group_norm(tm, y, h, dh).astype(x.dtype)
    out = (y * jax.nn.silu(g)) @ _wu(tm["wo"].astype(x.dtype), out_side=True)
    return (out, state) if return_state else out


def channelmix_apply(cm, x, x_prev):
    from repro.models.shard_ctx import weight_use as _wu

    xk = x + (x_prev - x) * cm["mu_k"].astype(x.dtype)
    xr = x + (x_prev - x) * cm["mu_r"].astype(x.dtype)
    k = jnp.square(jax.nn.relu(xk @ _wu(cm["wk"].astype(x.dtype))))
    return jax.nn.sigmoid(xr @ _wu(cm["wr"].astype(x.dtype))) * (k @ _wu(cm["wv"].astype(x.dtype), out_side=True))


def shift_tokens(x, seed_row=None):
    """Token shift: row t sees row t-1 (first row sees zeros / carried state)."""
    first = jnp.zeros_like(x[:, :1]) if seed_row is None else seed_row[:, None]
    return jnp.concatenate([first, x[:, :-1]], axis=1)


# ----------------------------------------------------------------- decode ----
def rwkv6_init_state(cfg, batch: int, dtype):
    h, dh = _dims(cfg)
    d = cfg.d_model
    return {
        "tm_shift": jnp.zeros((batch, d), dtype),
        "cm_shift": jnp.zeros((batch, d), dtype),
        "wkv": jnp.zeros((batch, h, dh, dh), jnp.float32),
    }


def timemix_decode(tm, x, state_shift, wkv_state, cfg):
    """x: (B, 1, D). Returns (y, new_shift, new_wkv)."""
    from repro.models.shard_ctx import weight_use as _wu

    h, dh = _dims(cfg)
    b, _, d = x.shape
    x_prev = state_shift[:, None]
    mixed = _ddlerp(tm, x, x_prev)
    xw, xk, xv, xr, xg = (m[:, 0] for m in mixed)    # (B, D)

    w_log = tm["w0"].astype(jnp.float32) + (
        jnp.tanh(xw @ tm["w_a"].astype(x.dtype)) @ tm["w_b"].astype(x.dtype)
    ).astype(jnp.float32)
    w = jnp.exp(-jnp.exp(w_log)).reshape(b, h, dh)

    r = (xr @ tm["wr"].astype(x.dtype)).reshape(b, h, dh).astype(jnp.float32)
    k = (xk @ tm["wk"].astype(x.dtype)).reshape(b, h, dh).astype(jnp.float32)
    v = (xv @ tm["wv"].astype(x.dtype)).reshape(b, h, dh).astype(jnp.float32)
    g = xg @ _wu(tm["wg"].astype(x.dtype))
    u = tm["u"].astype(jnp.float32).reshape(h, dh)

    kv = jnp.einsum("bhk,bhv->bhkv", k, v)
    y = jnp.einsum("bhk,bhkv->bhv", r, wkv_state + u[None, :, :, None] * kv)[:, None]  # (B,1,H,Dh)
    new_wkv = wkv_state * w[..., None] + kv
    y = _group_norm(tm, y.reshape(b, 1, h, dh), h, dh).astype(x.dtype)
    out = (y * jax.nn.silu(g[:, None])) @ tm["wo"].astype(x.dtype)
    return out, x[:, 0], new_wkv


def channelmix_decode(cm, x, state_shift):
    x_prev = state_shift[:, None]
    out = channelmix_apply(cm, x, x_prev)
    return out, x[:, 0]

"""Mining job counters (obs.mining): provable inertness — obs on/off mined
dicts identical — plus Hadoop-style counter reconciliation, fault-executor
counters, progress reporting, and the serving tier's merged replica
histograms (DESIGN.md §13)."""

import io
import os

import numpy as np
import pytest

from repro.core.apriori import AprioriConfig
from repro.core.streaming import mine_son_streamed, mine_streamed
from repro.data.store import ingest_dense
from repro.distributed.fault_tolerance import (FaultConfig, InjectedFailure,
                                               run_partitions)
from repro.obs import MetricsRegistry, MiningObs, MiningProgress, Tracer

CFG = AprioriConfig(min_support=0.02, max_k=3, count_impl="jnp")


@pytest.fixture(scope="module")
def store(tmp_path_factory):
    rng = np.random.default_rng(11)
    dense = (rng.random((3000, 48)) < 0.12).astype(np.uint8)
    path = os.path.join(str(tmp_path_factory.mktemp("obs_store")), "db")
    return ingest_dense(dense, path, shard_rows=800)


def _assert_same_result(a, b):
    assert set(a.levels) == set(b.levels)
    for k in a.levels:
        assert np.array_equal(a.levels[k][0], b.levels[k][0])
        assert np.array_equal(a.levels[k][1], b.levels[k][1])


def test_mine_streamed_obs_parity_and_counters(store):
    """Instrumentation is observation-only: the mined result is bit-identical
    with obs on/off, and the counters reconcile with the result."""
    plain = mine_streamed(store, CFG, chunk_rows=512)

    reg = MetricsRegistry()
    tracer = Tracer(sample_rate=1.0)
    obs = MiningObs(registry=reg, tracer=tracer)
    inst = mine_streamed(store, CFG, chunk_rows=512, obs=obs)

    _assert_same_result(plain, inst)

    snap = obs.counters()
    total_frequent = sum(v[0].shape[0] for v in plain.levels.values())
    # mine_levels counts levels ATTEMPTED — the final attempt may keep zero
    # itemsets and so not appear in the result dict
    assert len(plain.levels) <= snap["mine_levels"] <= len(plain.levels) + 1
    assert snap["mine_frequent_total"] == total_frequent
    for k, (sets, _) in plain.levels.items():
        assert snap[f'mine_frequent{{level="{k}"}}'] == sets.shape[0]
        assert snap[f'mine_candidates{{level="{k}"}}'] >= sets.shape[0]
    # every level streams the full store once per candidate pass
    assert snap["mine_rows_streamed"] >= store.num_transactions
    assert snap["mine_chunks_streamed"] > 0
    # all five phases of the wall-time split are populated
    for phase in ("candidate_gen", "prefetch_stall", "count_kernel", "host_sync"):
        assert snap[f'mine_phase_seconds{{phase="{phase}"}}'] > 0.0, phase
    # the trace shows one mine.level root per attempted level, phase children
    roots = [s for s in tracer.spans() if s.name == "mine.level"]
    assert len(roots) == snap["mine_levels"]
    kinds = {s.name for s in tracer.spans()}
    assert {"mine.candidate_gen", "mine.count_kernel", "mine.prefetch_stall"} <= kinds


def test_mine_son_streamed_obs_parity_and_fault_counters(store):
    fault = FaultConfig(max_workers=2)
    plain = mine_son_streamed(store, CFG, chunk_rows=512, fault=fault)

    obs = MiningObs(registry=MetricsRegistry())
    inst = mine_son_streamed(store, CFG, chunk_rows=512, fault=fault, obs=obs)

    _assert_same_result(plain, inst)
    snap = obs.counters()
    assert snap["mine_partitions_completed"] == store.num_partitions
    assert snap["mine_partition_attempts"] >= store.num_partitions
    assert snap["mine_chunks_streamed"] > 0


def test_fault_executor_counters_mirror_report():
    """Counters track the FaultReport exactly: retries, skips, completions."""
    def worker(p):
        return p * 10

    def injector(p, attempt):
        if p == 1 and attempt == 0:
            raise InjectedFailure("boom")
        if p == 2:                       # always fails -> exhausts -> skipped
            raise InjectedFailure("dead")

    obs = MiningObs(registry=MetricsRegistry())
    fault = FaultConfig(max_retries=1, backoff_s=0.0, speculative=False,
                        on_exhausted="skip", failure_injector=injector)
    results, report = run_partitions(worker, 4, fault, obs=obs)
    assert results == [0, 10, None, 30]

    snap = obs.counters()
    assert snap["mine_partitions_completed"] == report.completed == 3
    assert snap["mine_partition_retries"] == report.retries == 2
    assert snap["mine_partitions_skipped"] == len(report.skipped) == 1
    assert snap["mine_partition_attempts"] == sum(report.attempts.values())
    assert "speculative_wins" in report.to_json()


def test_speculative_win_counter():
    """A straggling partition whose backup copy finishes first shows up in
    both the report and the live counter."""
    import threading

    release = threading.Event()
    calls = {}
    lock = threading.Lock()

    def worker(p):
        with lock:
            calls[p] = calls.get(p, 0) + 1
            nth = calls[p]
        if p == 3 and nth == 1:
            release.wait(timeout=30)     # original copy stalls...
        return p

    obs = MiningObs(registry=MetricsRegistry())
    fault = FaultConfig(max_workers=2, speculative=True, speculative_factor=2.0,
                        backoff_s=0.0)
    try:
        results, report = run_partitions(worker, 4, fault, obs=obs)
    finally:
        release.set()
    assert results == [0, 1, 2, 3]
    snap = obs.counters()
    assert snap["mine_speculative_issued"] == report.speculative_issued
    assert snap["mine_speculative_wins"] == report.speculative_wins
    if report.speculative_issued:        # ...so the backup wins the race
        assert report.speculative_wins >= 1


def test_mining_progress_reporter(store):
    out = io.StringIO()
    progress = MiningProgress(total_rows=store.num_transactions, out=out,
                              interval_s=0.0)
    obs = MiningObs(registry=MetricsRegistry(), progress=progress)
    mine_streamed(store, CFG, chunk_rows=512, obs=obs)
    obs.finish()
    text = out.getvalue()
    assert progress.lines_emitted > 0
    assert "[mine]" in text and "L1" in text
    assert "rows/s" in text


def test_router_stats_aggregate_replica_histograms_by_merge(small_db):
    """The router's latency view is the MERGE of its replicas' histograms —
    total count equals the sum of per-replica counts, no re-measuring."""
    from repro.core.apriori import mine
    from repro.serving import Router, compile_rulebook

    rb = compile_rulebook(
        mine(small_db, AprioriConfig(min_support=0.05, max_k=3, count_impl="jnp")),
        min_confidence=0.3, num_items=32)
    with Router(rb, 2, max_wait_ms=0.2, cache_capacity=0) as router:
        baskets = [list(np.flatnonzero(r)) for r in small_db[:20]]
        for b in baskets:
            router.query(b, timeout=30)
        stats = router.stats()
        merged = stats["replica_latency"]
        per_replica = [r["gateway"]["latency"]["count"] for r in stats["replicas"]]
        assert merged["count"] == sum(per_replica) == len(baskets)
        assert merged["p99_ms"] >= merged["p50_ms"] >= 0.0

"""SLO engine: declarative objectives, burn-rate alerting, alert stream (§14).

PR 8 left the system with *measurements* — a :class:`MetricsRegistry` full of
counters, gauges and latency histograms — but no *objectives*: nothing said
how slow is too slow, how stale is too stale, or when someone (or some
control loop) should act.  This module closes that gap, SRE-workbook style:

* :class:`SLOSpec` declares one objective over registry metrics.  Four
  kinds cover the serving + mining surface:

  - ``latency``      — "``target_ratio`` of requests complete under
    ``threshold_s``", read from a histogram's bucket counts.  A request in a
    bucket whose upper edge exceeds the threshold counts as an error — the
    same conservative bucket-upper-edge bias the registry quantiles use, so
    the SLO can over-fire a hair but never under-fire.
  - ``error_ratio``  — classic availability: ``bad`` counters over
    ``bad + good`` counters (e.g. failed+shed over completed+failed+shed).
  - ``gauge_bound``  — a bound on a live gauge: rulebook freshness
    (``generation_age_seconds`` > bound is an error sample), replica-set
    health (``healthy_replica_ratio`` < bound), generation lag (> 0).
  - ``throughput``   — a floor on a counter's windowed rate (rows mined per
    second); a window below the floor is an error sample.

* Each spec evaluates to a windowed **error ratio** e_W = errors/total over
  any lookback window W, differenced from a ring of timestamped
  :meth:`MetricsRegistry.raw_snapshot` cuts.  The **burn rate** is
  e_W / budget where budget = 1 - target_ratio: burn 1.0 spends the error
  budget exactly at the sustainable pace, burn 14.4 exhausts a 30-day
  budget in ~2 days — the SRE-workbook calibration that motivates the
  default rule ladder.

* :class:`BurnRule` is one multi-window alert condition: it fires when the
  burn rate over BOTH a long and a short window exceeds the threshold.  The
  short window makes alerts *recover* quickly (stop firing as soon as the
  recent past is clean) while the long window keeps them from triggering on
  a single bad tick.  Default ladder: fast-burn → ``page``, slow-burn →
  ``warn``.

* :class:`SLOEvaluator` drives an ok → warn → page **alert state machine**
  per spec: upgrades are immediate, downgrades require the calmer verdict
  to hold for ``clear_after_s`` (hysteresis — no flapping across a
  threshold), and a typed :class:`AlertEvent` is emitted only on state
  *transitions* (dedup — a burning SLO alerts once, not once per tick).
  Events go to subscribers (the router's closed-loop reactions live there)
  and, when configured, to a JSONL alert stream next to the metrics
  series.

Determinism for tests: the evaluator takes ``now_fn`` and exposes
:meth:`SLOEvaluator.tick` so a test can feed synthetic cuts on a synthetic
clock; the background thread is just ``tick`` on an interval.
"""

from __future__ import annotations

import dataclasses
import json
import math
import threading
import time
from typing import Callable, Dict, List, Optional, Tuple

from repro.obs.registry import Histogram, MetricsRegistry

OK = "ok"
WARN = "warn"
PAGE = "page"
_SEVERITY_RANK = {OK: 0, WARN: 1, PAGE: 2}


@dataclasses.dataclass(frozen=True)
class BurnRule:
    """One multi-window burn-rate condition: fires when the burn rate over
    BOTH windows is >= ``burn_threshold`` (long window = sustained damage,
    short window = still happening *now*, so recovery clears fast)."""

    severity: str               # WARN or PAGE
    long_window_s: float
    short_window_s: float
    burn_threshold: float

    def __post_init__(self):
        if self.severity not in (WARN, PAGE):
            raise ValueError(f"rule severity must be warn|page, got {self.severity!r}")
        if self.short_window_s > self.long_window_s:
            raise ValueError("short window must not exceed the long window")


# SRE-workbook-shaped default ladder, scaled to interactive-process
# lifetimes (seconds, not days): fast burn pages, slow burn warns.
DEFAULT_RULES: Tuple[BurnRule, ...] = (
    BurnRule(PAGE, long_window_s=60.0, short_window_s=5.0, burn_threshold=14.4),
    BurnRule(WARN, long_window_s=300.0, short_window_s=30.0, burn_threshold=3.0),
)


@dataclasses.dataclass(frozen=True)
class SLOSpec:
    """One declarative objective over metrics in a single registry."""

    name: str                       # unique id, e.g. "latency_p99"
    kind: str                       # latency | error_ratio | gauge_bound | throughput
    signal: str = ""                # semantic tag consumers key reactions on:
                                    # "latency" / "availability" / "freshness"
                                    # / "generation_lag" / "throughput"
    target_ratio: float = 0.99      # good-events fraction the objective demands
    # latency / gauge_bound / throughput: the metric's registry key
    metric: str = ""
    threshold_s: float = 0.0        # latency objective (seconds)
    # error_ratio: counter keys summed into errors / successes
    bad: Tuple[str, ...] = ()
    good: Tuple[str, ...] = ()
    # gauge_bound: the bound, and which side of it is an error
    bound: float = 0.0
    above_is_error: bool = True     # freshness: age > bound errs; set False
                                    # for floors (healthy_ratio < bound errs)
    # throughput: minimum sustained rate (units of the counter per second)
    floor_per_s: float = 0.0
    rules: Tuple[BurnRule, ...] = DEFAULT_RULES

    def __post_init__(self):
        if self.kind not in ("latency", "error_ratio", "gauge_bound", "throughput"):
            raise ValueError(f"unknown SLO kind {self.kind!r}")
        if not 0.0 < self.target_ratio < 1.0:
            raise ValueError("target_ratio must be in (0, 1): the error budget "
                             "is 1 - target_ratio and must be positive")
        if not self.rules:
            raise ValueError("an SLO needs at least one burn rule")

    @property
    def budget(self) -> float:
        return 1.0 - self.target_ratio

    @property
    def objective(self) -> float:
        """The human-facing objective number for status displays."""
        if self.kind == "latency":
            return self.threshold_s
        if self.kind == "gauge_bound":
            return self.bound
        if self.kind == "throughput":
            return self.floor_per_s
        return self.target_ratio


@dataclasses.dataclass(frozen=True)
class AlertEvent:
    """One alert state TRANSITION (never a repeat of an unchanged state)."""

    slo: str                    # spec name
    signal: str                 # spec semantic tag (reaction key)
    kind: str
    severity: str               # new state: ok | warn | page
    previous: str               # prior state
    burn_rate: float            # worst firing rule's long-window burn (0 on clear)
    window_s: float             # that rule's long window (0 on clear)
    value: float                # current error ratio / gauge value / rate
    objective: float
    t_wall: float               # epoch seconds (JSONL ordering across files)
    message: str

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @property
    def cleared(self) -> bool:
        return self.severity == OK


class _Cut:
    __slots__ = ("t", "metrics")

    def __init__(self, t: float, metrics: dict):
        self.t = t
        self.metrics = metrics


def _counter_sum(cut: _Cut, keys: Tuple[str, ...]) -> float:
    total = 0.0
    for k in keys:
        v = cut.metrics.get(k, 0.0)
        if isinstance(v, dict):
            v = v.get("count", 0.0)
        total += float(v)
    return total


def _hist(cut: _Cut, key: str) -> Optional[dict]:
    v = cut.metrics.get(key)
    return v if isinstance(v, dict) and v.get("kind") == "histogram" else None


class _SpecState:
    """Mutable evaluation state for one spec: the alert state machine plus
    the latest measured values (for status views)."""

    __slots__ = ("spec", "state", "since", "pending", "pending_since",
                 "burns", "value", "fired_rule")

    def __init__(self, spec: SLOSpec, t: float):
        self.spec = spec
        self.state = OK
        self.since = t
        self.pending: Optional[str] = None     # desired downgrade awaiting hysteresis
        self.pending_since = 0.0
        self.burns: Dict[float, Optional[float]] = {}   # window_s -> burn rate
        self.value = 0.0
        self.fired_rule: Optional[BurnRule] = None


class SLOEvaluator:
    """Background evaluator: registry cuts → burn rates → alert machine.

    ``subscribe(fn)`` registers a callback receiving every
    :class:`AlertEvent`; subscriber exceptions are counted, never fatal
    (an alert reaction must not kill the alerting loop).  ``jsonl_path``
    additionally appends every event to a JSONL alert stream.  ``tick()``
    is the whole evaluation step — call it directly (tests, CLIs) or let
    ``start()`` run it on ``interval_s``.
    """

    def __init__(
        self,
        registry: MetricsRegistry,
        specs: List[SLOSpec],
        *,
        interval_s: float = 0.25,
        clear_after_s: float = 1.0,
        jsonl_path: Optional[str] = None,
        now_fn: Callable[[], float] = time.perf_counter,
    ):
        names = [s.name for s in specs]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate SLO spec names: {names}")
        self.registry = registry
        self.specs = list(specs)
        self.interval_s = float(interval_s)
        self.clear_after_s = float(clear_after_s)
        self.jsonl_path = jsonl_path
        self._now = now_fn
        self._lock = threading.Lock()
        self._cuts: List[_Cut] = []
        self._subscribers: List[Callable[[AlertEvent], None]] = []
        self.subscriber_errors = 0
        self._history: List[AlertEvent] = []
        self._fh = None
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        t0 = self._now()
        self._states = {s.name: _SpecState(s, t0) for s in self.specs}
        self._max_window = max(
            (r.long_window_s for s in self.specs for r in s.rules), default=60.0
        )

    # ------------------------------------------------------------ wiring --
    def subscribe(self, fn: Callable[[AlertEvent], None]) -> None:
        with self._lock:
            self._subscribers.append(fn)

    def alert_history(self) -> List[AlertEvent]:
        with self._lock:
            return list(self._history)

    def states(self) -> Dict[str, str]:
        with self._lock:
            return {name: st.state for name, st in self._states.items()}

    def status(self) -> Dict[str, dict]:
        """Per-spec status for display: state, per-window burns, value."""
        with self._lock:
            out = {}
            for name, st in self._states.items():
                out[name] = {
                    "state": st.state,
                    "signal": st.spec.signal,
                    "kind": st.spec.kind,
                    "objective": st.spec.objective,
                    "value": st.value,
                    "burns": {f"{w:g}s": b for w, b in st.burns.items()},
                    "since": st.since,
                }
            return out

    # -------------------------------------------------------- measurement --
    def _window_cut(self, t: float, window_s: float) -> Optional[_Cut]:
        """Oldest cut not older than ``t - window_s`` (partial windows use
        the oldest available cut — a young process evaluates what it has)."""
        lo = t - window_s
        for cut in self._cuts:
            if cut.t >= lo:
                return cut
        return None

    def _error_ratio(self, spec: SLOSpec, old: _Cut, new: _Cut) -> Optional[float]:
        """Windowed error fraction between two cuts; None = no data."""
        if spec.kind == "error_ratio":
            bad = _counter_sum(new, spec.bad) - _counter_sum(old, spec.bad)
            good = _counter_sum(new, spec.good) - _counter_sum(old, spec.good)
            total = bad + good
            return None if total <= 0 else max(0.0, bad) / total
        if spec.kind == "latency":
            h_new, h_old = _hist(new, spec.metric), _hist(old, spec.metric)
            if h_new is None:
                return None
            counts_new = h_new["counts"]
            counts_old = h_old["counts"] if h_old is not None else [0] * len(counts_new)
            total = errors = 0
            for b, c_new in enumerate(counts_new):
                d = c_new - counts_old[b]
                if d <= 0:
                    continue
                total += d
                # bucket-upper-edge > threshold counts as over-objective: the
                # straddling bucket errs conservatively (never under-fires)
                if Histogram._edge(b) > spec.threshold_s:
                    errors += d
            return None if total == 0 else errors / total
        if spec.kind == "gauge_bound":
            # fraction of cut SAMPLES in the window violating the bound
            samples = [c for c in self._cuts if c.t >= old.t]
            vals = [c.metrics.get(spec.metric) for c in samples]
            vals = [float(v) for v in vals if isinstance(v, (int, float))]
            if not vals:
                return None
            if spec.above_is_error:
                bad = sum(1 for v in vals if v > spec.bound)
            else:
                bad = sum(1 for v in vals if v < spec.bound)
            return bad / len(vals)
        if spec.kind == "throughput":
            span = new.t - old.t
            if span <= 0:
                return None
            v_new, v_old = new.metrics.get(spec.metric), old.metrics.get(spec.metric)
            if not isinstance(v_new, (int, float)) or not isinstance(v_old, (int, float)):
                return None
            rate = max(0.0, float(v_new) - float(v_old)) / span
            return 1.0 if rate < spec.floor_per_s else 0.0
        return None

    def _current_value(self, spec: SLOSpec, new: _Cut, err_long: Optional[float]) -> float:
        if spec.kind == "gauge_bound":
            v = new.metrics.get(spec.metric)
            return float(v) if isinstance(v, (int, float)) else math.nan
        if spec.kind == "throughput":
            old = self._window_cut(new.t, spec.rules[0].long_window_s)
            if old is not None and new.t > old.t:
                v_new = new.metrics.get(spec.metric, 0.0)
                v_old = old.metrics.get(spec.metric, 0.0)
                if isinstance(v_new, (int, float)) and isinstance(v_old, (int, float)):
                    return max(0.0, float(v_new) - float(v_old)) / (new.t - old.t)
            return math.nan
        return err_long if err_long is not None else 0.0

    # --------------------------------------------------------- evaluation --
    def tick(self, cut: Optional[dict] = None) -> List[AlertEvent]:
        """One evaluation step: snapshot (or adopt ``cut``), window the ring,
        run every spec's rules, advance state machines, emit transitions."""
        t = self._now()
        metrics = self.registry.raw_snapshot() if cut is None else cut
        events: List[AlertEvent] = []
        with self._lock:
            self._cuts.append(_Cut(t, metrics))
            # retain 2x the longest window of history (burn math never needs more)
            lo = t - 2.0 * self._max_window
            while len(self._cuts) > 2 and self._cuts[0].t < lo:
                self._cuts.pop(0)
            new = self._cuts[-1]
            for st in self._states.values():
                events.extend(self._eval_spec(st, new, t))
            if events:
                self._history.extend(events)
        for ev in events:
            self._emit(ev)
        return events

    def _eval_spec(self, st: _SpecState, new: _Cut, t: float) -> List[AlertEvent]:
        spec = st.spec
        desired = OK
        fired: Optional[BurnRule] = None
        fired_burn = 0.0
        burns: Dict[float, Optional[float]] = {}
        err_long_any: Optional[float] = None
        for rule in spec.rules:
            e_long = e_short = None
            old_l = self._window_cut(t, rule.long_window_s)
            if old_l is not None and new.t > old_l.t:
                e_long = self._error_ratio(spec, old_l, new)
            old_s = self._window_cut(t, rule.short_window_s)
            if old_s is not None and new.t > old_s.t:
                e_short = self._error_ratio(spec, old_s, new)
            b_long = None if e_long is None else e_long / spec.budget
            b_short = None if e_short is None else e_short / spec.budget
            burns[rule.long_window_s] = b_long
            if e_long is not None:
                err_long_any = e_long
            if (
                b_long is not None and b_short is not None
                and b_long >= rule.burn_threshold and b_short >= rule.burn_threshold
                and _SEVERITY_RANK[rule.severity] > _SEVERITY_RANK[desired]
            ):
                desired = rule.severity
                fired = rule
                fired_burn = b_long
        st.burns = burns
        st.value = self._current_value(spec, new, err_long_any)
        return self._advance(st, desired, fired, fired_burn, t)

    def _advance(self, st: _SpecState, desired: str, rule: Optional[BurnRule],
                 burn: float, t: float) -> List[AlertEvent]:
        """State machine step.  Upgrades fire immediately; a downgrade must
        hold for ``clear_after_s`` before it lands (hysteresis: one calm tick
        in a burning stretch never clears — and so never re-fires — an
        alert)."""
        cur = st.state
        if _SEVERITY_RANK[desired] > _SEVERITY_RANK[cur]:
            st.pending = None
            return [self._transition(st, desired, rule, burn, t)]
        if _SEVERITY_RANK[desired] < _SEVERITY_RANK[cur]:
            if st.pending != desired:
                st.pending = desired
                st.pending_since = t
                return []
            if t - st.pending_since >= self.clear_after_s:
                st.pending = None
                return [self._transition(st, desired, rule, burn, t)]
            return []
        st.pending = None       # desired == current: nothing pending, no event
        return []

    def _transition(self, st: _SpecState, new_state: str,
                    rule: Optional[BurnRule], burn: float, t: float) -> AlertEvent:
        spec = st.spec
        prev = st.state
        st.state = new_state
        st.since = t
        st.fired_rule = rule
        if new_state == OK:
            msg = f"SLO {spec.name}: recovered ({prev} -> ok)"
        else:
            msg = (f"SLO {spec.name}: {new_state} — burn {burn:.1f}x budget over "
                   f"{rule.long_window_s:g}s (objective {spec.objective:g}, "
                   f"value {st.value:.4g})")
        return AlertEvent(
            slo=spec.name, signal=spec.signal, kind=spec.kind,
            severity=new_state, previous=prev,
            burn_rate=burn if rule is not None else 0.0,
            window_s=rule.long_window_s if rule is not None else 0.0,
            value=st.value, objective=spec.objective,
            t_wall=time.time(), message=msg,
        )

    # ------------------------------------------------------------ fan-out --
    def _emit(self, ev: AlertEvent) -> None:
        with self._lock:
            subs = list(self._subscribers)
            fh = self._fh
        for fn in subs:
            try:
                fn(ev)
            except Exception:
                with self._lock:
                    self.subscriber_errors += 1
        if fh is not None:
            line = json.dumps(ev.to_json())
            with self._lock:
                fh.write(line + "\n")
                fh.flush()

    # ---------------------------------------------------------- lifecycle --
    def _run(self) -> None:
        while not self._stop.wait(self.interval_s):
            self.tick()

    def start(self) -> "SLOEvaluator":
        if self.jsonl_path:
            self._fh = open(self.jsonl_path, "a")
        self.tick()     # baseline cut so the first interval has a delta
        self._thread = threading.Thread(target=self._run, name="slo-evaluator",
                                        daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
            self._thread = None
        self.tick()     # final evaluation so short runs still resolve states
        if self._fh is not None:
            self._fh.close()
            self._fh = None

    def __enter__(self) -> "SLOEvaluator":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()


# --------------------------------------------------------------------------
# Canonical serving-spec builders (the serve CLI and examples share these).
# --------------------------------------------------------------------------

def serving_slos(
    prefix: str,
    *,
    p99_ms: float = 50.0,
    latency_target_ratio: float = 0.99,
    availability_target: float = 0.999,
    freshness_bound_s: Optional[float] = None,
    replicated: bool = False,
    rules: Tuple[BurnRule, ...] = DEFAULT_RULES,
) -> List[SLOSpec]:
    """The standard SLO set over a gateway ("gateway") or router ("router")
    metrics registry.  ``freshness_bound_s`` adds the rulebook-freshness
    objective only when the deployment actually refreshes continuously —
    a batch-mined rulebook ages unboundedly by design."""
    specs = [
        SLOSpec(
            name="latency_p99", kind="latency", signal="latency",
            metric=f"{prefix}_latency_seconds",
            threshold_s=p99_ms / 1e3, target_ratio=latency_target_ratio,
            rules=rules,
        ),
    ]
    if replicated:
        specs += [
            SLOSpec(
                name="availability", kind="error_ratio", signal="availability",
                bad=("router_failed", "router_shed"),
                good=("router_completed",),
                target_ratio=availability_target, rules=rules,
            ),
            SLOSpec(
                name="replica_availability", kind="gauge_bound",
                signal="availability",
                metric="router_healthy_replica_ratio",
                bound=1.0, above_is_error=False,       # any unhealthy replica errs
                target_ratio=availability_target, rules=rules,
            ),
            # counter-based disruption: failovers / attempt timeouts are
            # requests that needed RESCUE — recovered, but budget-burning.
            # Unlike the sampled health gauge (which can miss a replica that
            # dies and is revived between two cuts), counter deltas LATCH the
            # event, so a mid-load kill reliably fires this one even when
            # supervised recovery lands in milliseconds.
            SLOSpec(
                name="replica_disruption", kind="error_ratio",
                signal="availability",
                bad=("router_failovers", "router_attempt_timeouts"),
                good=("router_completed",),
                target_ratio=availability_target, rules=rules,
            ),
            SLOSpec(
                name="generation_lag", kind="gauge_bound", signal="generation_lag",
                metric="router_current_generation_lag",
                bound=0.0, above_is_error=True,        # any lagging replica errs
                target_ratio=0.99, rules=rules,
            ),
        ]
    else:
        specs.append(
            SLOSpec(
                name="availability", kind="error_ratio", signal="availability",
                bad=("gateway_rejected", "gateway_failed"),
                good=("gateway_completed",),
                target_ratio=availability_target, rules=rules,
            )
        )
    if freshness_bound_s is not None:
        specs.append(
            SLOSpec(
                name="freshness", kind="gauge_bound", signal="freshness",
                metric=f"{prefix}_generation_age_seconds",
                bound=float(freshness_bound_s), above_is_error=True,
                target_ratio=0.99, rules=rules,
            )
        )
    return specs


def mining_slos(
    *,
    rows_per_s_floor: float,
    rules: Tuple[BurnRule, ...] = DEFAULT_RULES,
) -> List[SLOSpec]:
    """Mining-throughput floor over a ``MiningObs`` registry."""
    return [
        SLOSpec(
            name="mining_throughput", kind="throughput", signal="throughput",
            metric="mine_rows_streamed", floor_per_s=float(rows_per_s_floor),
            target_ratio=0.99, rules=rules,
        )
    ]

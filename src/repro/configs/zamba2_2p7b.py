"""Zamba2-2.7B [arXiv:2411.15242; hf] — Mamba2 backbone + ONE weight-shared
attention+FFN block applied every 6 layers (hybrid)."""

from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    d_ff=10240,
    vocab_size=32000,
    block_type="zamba_hybrid",
    share_every=6,
    ssm=SSMConfig(state_dim=64, head_dim=64, expand=2, n_groups=1, conv_width=4, chunk=256),
)

"""Jit'd public wrappers around the Pallas kernels.

Handles shape padding to block multiples, impl dispatch ('auto' resolves to
the Pallas kernel on TPU and the jnp oracle on CPU — interpret-mode Pallas is
kept for tests, where it validates the kernel body semantics), and padding
semantics (padded transactions are zero rows; padded candidates get |c| = -1
so they can never match; packed operands additionally pad the word axis with
zero words — see DESIGN.md §3).

Two counting entry points:
  * :func:`support_count` — dense {0,1} operands. ``impl="packed"`` packs
    them to uint32 bitsets on device and routes through the packed path.
  * :func:`support_count_packed` — pre-packed uint32 operands (the format
    ``core.apriori`` keeps device-resident across the whole level loop).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.kernels.support_count import support_count_pallas
from repro.kernels.support_count_packed import support_count_packed_pallas


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def resolve_impl(impl: str) -> str:
    if impl != "auto":
        return impl
    return "pallas" if jax.default_backend() == "tpu" else "jnp"


@functools.partial(jax.jit, static_argnames=("num_items",))
def pack_bits_device(dense: jax.Array, num_items: int | None = None) -> jax.Array:
    """Device-side dense {0,1} (R, I) -> packed uint32 (R, ceil(I/32)).

    Little-endian bits per word — the jnp twin of ``core.itemsets.pack_bits``.
    """
    r, i = dense.shape
    if num_items is not None:
        assert i == num_items
    words = (i + 31) // 32
    d = jnp.pad(dense.astype(jnp.uint32), ((0, 0), (0, words * 32 - i)))
    shifts = jnp.arange(32, dtype=jnp.uint32)
    return (d.reshape(r, words, 32) << shifts).sum(axis=2, dtype=jnp.uint32)


def support_count(
    t_dense,
    c_dense,
    lengths,
    *,
    impl: str = "auto",
    block_n: int = 256,
    block_k: int = 256,
    block_i: int = 512,
    operand_dtype: str = "bf16",
):
    """Support counts of K candidates over N transactions (exact int32).

    Accepts arbitrary (N, I, K); pads to kernel block multiples internally.
    impl: auto | jnp | pallas | pallas_interpret
        | packed | packed_jnp | packed_pallas | packed_interpret
    The packed impls bit-pack the dense operands on device and dispatch to
    :func:`support_count_packed` ('packed' resolves like 'auto').
    """
    impl = resolve_impl(impl)
    n, i = t_dense.shape
    k = c_dense.shape[0]
    if impl == "jnp":
        return ref.support_count_ref(t_dense, c_dense, lengths)
    if impl == "jnp_blocked":
        from repro.kernels.blocked import support_count_blocked

        return support_count_blocked(t_dense, c_dense, lengths)
    if impl == "packed" or impl.startswith("packed_"):
        sub = "auto" if impl == "packed" else impl[len("packed_") :]
        sub = {"interpret": "pallas_interpret"}.get(sub, sub)
        return support_count_packed(
            pack_bits_device(t_dense, i),
            pack_bits_device(c_dense, i),
            lengths,
            impl=sub,
            block_n=block_n,
            block_k=block_k,
        )
    if impl not in ("pallas", "pallas_interpret"):
        raise ValueError(f"unknown impl {impl!r}")

    # Shrink blocks for small problems (keep the 128-lane minor alignment).
    block_n = min(block_n, _round_up(n, 8))
    block_k = min(block_k, _round_up(k, 128))
    block_i = min(block_i, _round_up(i, 128))
    np_, kp, ip = _round_up(n, block_n), _round_up(k, block_k), _round_up(i, block_i)
    t_p = jnp.pad(t_dense, ((0, np_ - n), (0, ip - i)))
    c_p = jnp.pad(c_dense, ((0, kp - k), (0, ip - i)))
    len_p = jnp.pad(lengths.astype(jnp.int32), (0, kp - k), constant_values=-1)
    counts = support_count_pallas(
        t_p,
        c_p,
        len_p,
        block_n=block_n,
        block_k=block_k,
        block_i=block_i,
        operand_dtype=operand_dtype,
        interpret=(impl == "pallas_interpret"),
    )
    return counts[:k]


def support_count_packed(
    t_packed,
    c_packed,
    lengths,
    *,
    impl: str = "auto",
    block_n: int = 256,
    block_k: int = 256,
    block_w: int = 8,
    mode: str = "and_cmp",
):
    """Support counts over packed uint32 bitset operands (exact int32).

    t_packed: (N, W) uint32, c_packed: (K, W) uint32, lengths: (K,) int32
    with |c| = -1 marking padded candidate rows. Accepts arbitrary (N, W, K);
    pads rows/words to kernel block multiples internally (zero words / zero
    rows / -1 lengths — all inert, DESIGN.md §3).
    impl: auto | jnp | pallas | pallas_interpret
    """
    impl = resolve_impl(impl)
    n, w = t_packed.shape
    k = c_packed.shape[0]
    if impl == "jnp":
        return ref.support_count_packed_ref(t_packed, c_packed, lengths)
    if impl not in ("pallas", "pallas_interpret"):
        raise ValueError(f"unknown packed impl {impl!r}")

    block_n = min(block_n, _round_up(n, 8))
    block_k = min(block_k, _round_up(k, 128))
    block_w = min(block_w, w)
    np_, kp, wp = _round_up(n, block_n), _round_up(k, block_k), _round_up(w, block_w)
    t_p = jnp.pad(t_packed, ((0, np_ - n), (0, wp - w)))
    c_p = jnp.pad(c_packed, ((0, kp - k), (0, wp - w)))
    len_p = jnp.pad(lengths.astype(jnp.int32), (0, kp - k), constant_values=-1)
    counts = support_count_packed_pallas(
        t_p,
        c_p,
        len_p,
        block_n=block_n,
        block_k=block_k,
        block_w=block_w,
        mode=mode,
        interpret=(impl == "pallas_interpret"),
    )
    return counts[:k]


@functools.partial(jax.jit, static_argnames=("block_n",))
def _rule_match_jnp_blocked(b_packed, a_packed, lengths, c_packed, scores, block_n=512):
    """Basket-blocked oracle dispatch: bounds the (bn, R, W) broadcast the
    plain reference materializes, so the jnp path serves large batches
    without an O(B·R·W) intermediate."""
    n, w = b_packed.shape
    pad = (-n) % block_n
    b_p = jnp.pad(b_packed, ((0, pad), (0, 0)))  # zero baskets match nothing real

    def one_block(b_blk):
        return ref.rule_match_ref(b_blk, a_packed, lengths, c_packed, scores)

    out = jax.lax.map(one_block, b_p.reshape(-1, block_n, w))
    return out.reshape(-1, 32 * w)[:n]


def rule_match(
    b_packed,
    a_packed,
    lengths,
    c_packed,
    scores,
    *,
    num_items: int | None = None,
    impl: str = "auto",
    block_n: int = 256,
    block_k: int = 256,
):
    """Per-item rule-evidence scores for a batch of basket bitsets.

    b_packed: (B, W) uint32; a_packed/c_packed: (R, W) uint32 rulebook
    columns; lengths: (R,) int32 antecedent sizes (-1 = padding row);
    scores: (R,) float32.  Returns (B, num_items or 32·W) float32 where
    ``out[b, i] = Σ_r [antecedent_r ⊆ basket_b] · scores[r] · consequent_r[i]``.
    Accepts arbitrary (B, R); pads to kernel block multiples internally
    (zero basket rows / zero rule rows with len = -1 and score 0 — inert).
    impl: auto | jnp | pallas | pallas_interpret
    """
    impl = resolve_impl(impl)
    n, w = b_packed.shape
    r = a_packed.shape[0]
    assert a_packed.shape == (r, w) and c_packed.shape == (r, w), (
        "basket and rulebook word counts must agree"
    )
    items = 32 * w if num_items is None else num_items
    if impl == "jnp":
        # honor the caller's basket block, capped at the (padded) batch so
        # small batches don't broadcast/matmul against a full default block
        bn = min(max(block_n, 8), _round_up(n, 8))
        out = _rule_match_jnp_blocked(
            b_packed, a_packed, lengths, c_packed, scores, block_n=bn
        )
        return out[:, :items]
    if impl not in ("pallas", "pallas_interpret"):
        raise ValueError(f"unknown rule_match impl {impl!r}")

    block_n = min(block_n, _round_up(n, 8))
    block_k = min(block_k, _round_up(r, 128))
    np_, rp = _round_up(n, block_n), _round_up(r, block_k)
    b_p = jnp.pad(b_packed, ((0, np_ - n), (0, 0)))
    a_p = jnp.pad(a_packed, ((0, rp - r), (0, 0)))
    c_p = jnp.pad(c_packed, ((0, rp - r), (0, 0)))
    len_p = jnp.pad(lengths.astype(jnp.int32), (0, rp - r), constant_values=-1)
    score_p = jnp.pad(scores.astype(jnp.float32), (0, rp - r))
    from repro.kernels.rule_match import rule_match_pallas

    out = rule_match_pallas(
        b_p, a_p, len_p, c_p, score_p,
        block_n=block_n, block_k=block_k,
        interpret=(impl == "pallas_interpret"),
    )
    return out[:n, :items]

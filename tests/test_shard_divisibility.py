"""Regression: the candidate pad bucket must split evenly over P(model_axis).

The seed computed ``quantum = max(candidate_pad, model_shards)`` which is NOT
a multiple of ``model_shards`` when the shard count is not a power-of-two
divisor of ``candidate_pad`` (e.g. 3 model shards -> kp = 256 -> uneven
split, shard_map rejects the spec). ``_candidate_quantum`` now rounds the
bucket up to a multiple of the model-shard count; ``_pad_bucket`` only
doubles, which preserves divisibility.
"""

import subprocess
import sys
import textwrap

import pytest

from repro.core.apriori import AprioriConfig, _candidate_quantum, _pad_bucket

from conftest import REPO_ROOT, subprocess_env



class _FakeMesh:
    def __init__(self, shape):
        self.shape = shape


@pytest.mark.parametrize("shards,pad", [(1, 256), (2, 256), (3, 256), (5, 256), (6, 64), (7, 100)])
def test_candidate_quantum_divisible(shards, pad):
    cfg = AprioriConfig(candidate_pad=pad, model_axis="model")
    mesh = _FakeMesh({"data": 2, "model": shards})
    q = _candidate_quantum(cfg, mesh)
    assert q >= pad and q % shards == 0
    # every bucket grown from the quantum stays divisible
    for k in (1, pad - 1, pad + 1, 10 * pad + 3):
        assert _pad_bucket(k, q) % shards == 0
        assert _pad_bucket(k, q) >= k


def test_candidate_quantum_no_model_axis():
    cfg = AprioriConfig(candidate_pad=128, model_axis=None)
    assert _candidate_quantum(cfg, None) == 128


_MESH_2x3 = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=6"
    import jax
    from repro.core.apriori import AprioriConfig, mine
    from repro.data.synthetic import QuestConfig, gen_transactions

    db = gen_transactions(QuestConfig(num_transactions=400, num_items=64, avg_len=8, seed=13))
    single = mine(db, AprioriConfig(min_support=0.05, max_k=4, count_impl="jnp"))

    mesh = jax.make_mesh((2, 3), ("data", "model"))   # 3 model shards: the bug trigger
    for rep in ("dense", "packed"):
        dist = mine(
            db,
            AprioriConfig(min_support=0.05, max_k=4, count_impl="jnp",
                          representation=rep, data_axes=("data",), model_axis="model",
                          candidate_pad=256),
            mesh=mesh,
        )
        assert dist.as_dict() == single.as_dict(), rep
    print("MESH_2x3_OK", single.total_frequent)
    """
)


def test_mine_on_2x3_mesh():
    """Runs in a subprocess with 6 host devices: a (2, 3) data×model mesh
    mines identically to a single node, for both representations."""
    proc = subprocess.run(
        [sys.executable, "-c", _MESH_2x3],
        capture_output=True,
        text=True,
        timeout=600,
        env=subprocess_env(),
        cwd=REPO_ROOT,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "MESH_2x3_OK" in proc.stdout

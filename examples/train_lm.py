"""End-to-end LM training driver (deliverable b): train a ~100M-param model
for a few hundred steps through the full production stack (sharded pipeline,
AdamW, checkpointing supervisor).

Quick CPU check (~10M params):
  PYTHONPATH=src python examples/train_lm.py --preset 10m --steps 60
Full deliverable run (~100M params, few hundred steps — slow on CPU):
  PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300
"""

import sys

from repro.launch import train as train_mod


def main():
    argv = ["--arch", "qwen1p5_4b", "--preset", "10m", "--steps", "60",
            "--batch", "8", "--seq", "256", "--ckpt", "/tmp/repro_train_ckpt"]
    # allow overrides
    argv += sys.argv[1:]
    sys.argv = ["train"] + argv
    train_mod.main()


if __name__ == "__main__":
    main()

"""Rule-match kernel: Pallas (interpret) vs jnp oracle parity, padding
invariants (all-padding rulebooks, zero baskets, non-multiple-of-32 item
counts), and dispatch equivalence — the CI parity gate."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.itemsets import itemsets_to_packed, pack_bits, packed_words
from repro.kernels import ops, ref


def random_rule_problem(b, i, r, seed=0, pad_frac=0.2, density=0.3):
    """Random (baskets, antecedents, lengths, consequents, scores) with a
    sprinkle of padding rows (zero words, len = -1, score 0)."""
    rng = np.random.default_rng(seed)
    w = packed_words(i)
    baskets = pack_bits((rng.random((b, i)) < density).astype(np.int8))
    na = rng.integers(1, min(4, i) + 1, r)
    nc = rng.integers(1, min(3, i) + 1, r)
    ante = np.zeros((r, w), np.uint32)
    cons = np.zeros((r, w), np.uint32)
    for row in range(r):
        ante[row] = itemsets_to_packed(
            np.sort(rng.choice(i, na[row], replace=False))[None], i
        )
        cons[row] = itemsets_to_packed(
            np.sort(rng.choice(i, nc[row], replace=False))[None], i
        )
    lengths = na.astype(np.int32)
    scores = rng.random(r).astype(np.float32)
    if pad_frac:
        pad = rng.choice(r, max(1, int(r * pad_frac)), replace=False)
        ante[pad] = 0
        cons[pad] = 0
        lengths[pad] = -1
        scores[pad] = 0
    return baskets, ante, lengths, cons, scores


RULE_SHAPES = [
    (8, 16, 4),       # tiny
    (100, 37, 33),    # I not a multiple of 32
    (64, 96, 300),    # word-aligned I, R spans blocks
    (33, 130, 257),   # multi-word, ragged everywhere
    (16, 31, 128),    # single partial word
]


@pytest.mark.parametrize("shape", RULE_SHAPES)
def test_rule_match_kernel_matches_ref(shape):
    b, i, r = shape
    args = [jnp.asarray(x) for x in random_rule_problem(b, i, r, seed=sum(shape))]
    want = np.asarray(ref.rule_match_ref(*args))[:, :i]
    got_jnp = np.asarray(ops.rule_match(*args, num_items=i, impl="jnp"))
    got_pal = np.asarray(
        ops.rule_match(*args, num_items=i, impl="pallas_interpret", block_n=32, block_k=128)
    )
    np.testing.assert_allclose(got_jnp, want, rtol=1e-6)
    np.testing.assert_allclose(got_pal, want, rtol=1e-6)


@pytest.mark.parametrize("impl", ["jnp", "pallas_interpret"])
def test_rule_match_all_padding_rules(impl):
    """A rulebook that is ALL padding rows (len = -1, zero words) must score
    zero everywhere — padded rules can never match any basket."""
    baskets, *_ = random_rule_problem(20, 64, 4, seed=9, pad_frac=0)
    r, w = 12, packed_words(64)
    z = jnp.zeros((r, w), jnp.uint32)
    out = ops.rule_match(
        jnp.asarray(baskets), z, jnp.full(r, -1, jnp.int32), z,
        jnp.zeros(r, jnp.float32), num_items=64, impl=impl,
    )
    np.testing.assert_array_equal(np.asarray(out), 0.0)


def test_rule_match_zero_baskets_score_zero():
    """Zero basket rows (batch padding) match no real antecedent."""
    _, ante, lengths, cons, scores = random_rule_problem(4, 48, 40, seed=5, pad_frac=0)
    z = jnp.zeros((8, packed_words(48)), jnp.uint32)
    out = ops.rule_match(
        z, jnp.asarray(ante), jnp.asarray(lengths), jnp.asarray(cons),
        jnp.asarray(scores), num_items=48, impl="pallas_interpret",
    )
    np.testing.assert_array_equal(np.asarray(out), 0.0)


def test_rule_match_exact_containment_semantics():
    """Hand-built case: out[b] sums scores of exactly the contained rules."""
    i = 40
    baskets = pack_bits(
        np.asarray(
            [
                [1 if x in (0, 1, 35) else 0 for x in range(i)],
                [1 if x in (2,) else 0 for x in range(i)],
            ],
            np.int8,
        )
    )
    # rule 0: {0,35} -> {2} (matches basket 0); rule 1: {2} -> {0} (matches 1)
    ante = itemsets_to_packed(np.array([[0, 35], [2, 2]], np.int32), i)
    cons = itemsets_to_packed(np.array([[2, 2], [0, 0]], np.int32), i)
    lengths = np.array([2, 1], np.int32)
    scores = np.array([0.5, 2.0], np.float32)
    for impl in ("jnp", "pallas_interpret"):
        out = np.asarray(
            ops.rule_match(
                jnp.asarray(baskets), jnp.asarray(ante), jnp.asarray(lengths),
                jnp.asarray(cons), jnp.asarray(scores), num_items=i, impl=impl,
            )
        )
        want = np.zeros((2, i), np.float32)
        want[0, 2] = 0.5
        want[1, 0] = 2.0
        np.testing.assert_array_equal(out, want)

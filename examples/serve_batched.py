"""Batched serving demo: prefill a batch of prompts, then greedy-decode with
per-family caches (GQA KV / MLA latent / SSD state / RWKV state).

PYTHONPATH=src python examples/serve_batched.py [arch]
"""

import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.transformer import init_model
from repro.serving.serve_loop import generate


def main():
    arch = sys.argv[1] if len(sys.argv) > 1 else "rwkv6_1p6b"
    cfg = get_config(arch).reduced()
    params = init_model(jax.random.key(0), cfg)
    rng = np.random.default_rng(0)

    if cfg.frontend == "frames":
        prompt = {"frames": jnp.asarray(rng.standard_normal((4, 12, cfg.d_model)), jnp.float32)}
    else:
        prompt = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 12)), jnp.int32)}
    toks = generate(params, cfg, prompt, max_new_tokens=16)
    print(f"[{cfg.name}] generated {toks.shape} tokens:")
    print(np.asarray(toks))


if __name__ == "__main__":
    main()

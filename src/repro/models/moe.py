"""Top-k MoE with capacity-bounded sort dispatch (expert parallelism).

TPU-native dispatch: route -> stable-sort token assignments by expert ->
position-in-expert rank via segment arithmetic -> capacity drop -> scatter
into the (G, E, C, D) expert buffer -> batched expert FFN (the MXU-heavy
grouped matmul) -> unscatter + combine-weight sum. All shapes static; dropped
tokens follow the standard capacity-factor contract.

GShard group semantics: tokens are split into ``cfg.moe_groups`` routing
groups (one per data shard on the production mesh, G dim pinned to the data
axes) with per-group capacity, so the sort/scatter stays LOCAL to each data
shard; the expert dim is pinned to the tensor axis (EP). The G/E dims are
explicit in every einsum — an earlier vmap formulation hid them from GSPMD,
which replicated the expert compute 16x (perf iteration #5b, EXPERIMENTS.md).

Experts shard over the tensor ('model') axis; granite's 40 experts pad to 48
(`MoEConfig.padded_experts`) with router masking (DESIGN.md §4).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init
from repro.models.shard_ctx import constrain, expert_weight_use


def moe_init(key, cfg):
    m = cfg.moe
    d, e, f = cfg.d_model, m.e_padded, m.d_ff_expert
    ks = jax.random.split(key, 4)
    if cfg.act == "swiglu":
        wi = dense_init(ks[0], (e, d, 2 * f), in_axis=1)
    else:
        wi = dense_init(ks[0], (e, d, f), in_axis=1)
    return {
        "router": dense_init(ks[1], (d, e)),
        "wi": wi,
        "wo": dense_init(ks[2], (e, f, d), in_axis=1),
    }


def _router_probs(p, x, cfg):
    """fp32 router; padded (dead) experts masked to -inf before softmax."""
    m = cfg.moe
    logits = x.astype(jnp.float32) @ p["router"].astype(jnp.float32)
    if m.e_padded > m.num_experts:
        dead = jnp.arange(m.e_padded) >= m.num_experts
        logits = jnp.where(dead[None, :], -1e30, logits)
    return jax.nn.softmax(logits, axis=-1)


def moe_apply(p, x, cfg, no_drop: bool = False):
    """x: (B, S, D) -> (B, S, D). Returns (out, aux) with load-balance loss.

    On a mesh (shard_ctx active) the dispatch runs under shard_map MANUAL
    over the data axes: each data shard is one GShard routing group doing a
    plain local 2-D sort/scatter (no cross-shard index ops for GSPMD to
    mis-partition — the batched-scatter formulation measured an 16x token
    all-gather, perf iteration #5c), while the expert dim stays GSPMD-auto on
    the tensor axis (EP). Single-device path: one group, same code.

    no_drop=True sizes capacity to the worst case (decode path: serving must
    not drop tokens; T is tiny there so the buffer stays small)."""
    from repro.models import shard_ctx

    ctx = shard_ctx.current()
    b, s, d = x.shape
    if ctx is not None:
        mesh, dp = ctx["mesh"], ctx["dp"]
        n_dp = 1
        for a in dp:
            n_dp *= mesh.shape[a]
        if n_dp > 1 and b % n_dp == 0:
            import jax as _jax
            from jax.sharding import PartitionSpec as _P

            def body(p_local, x_local):
                out, aux = _moe_one_group(
                    p_local, x_local.reshape(-1, d), cfg, no_drop, local=True
                )
                # aux stays per-shard (out_specs P(dp)); the mean happens
                # OUTSIDE the manual region — a pmean here differentiates
                # into a copy-reducer all-reduce that crashes XLA:CPU's
                # AllReducePromotion pass.
                return out.reshape(x_local.shape), aux[None]

            from repro.core.mapreduce import shard_map as _shard_map

            fn = _shard_map(
                body,
                mesh=mesh,
                in_specs=(_P(), _P(dp, None, None)),
                out_specs=(_P(dp, None, None), _P(dp)),
                axis_names=set(dp),
                # vma tracking inserts bf16 pvary (copy-reducer all-reduce)
                # under AD, which crashes XLA:CPU's AllReducePromotion pass;
                # every out_spec references dp so the check is not needed.
                check_vma=False,
            )
            out, aux = fn(p, x)
            return out, aux.mean()
    out, aux = _moe_one_group(p, x.reshape(b * s, d), cfg, no_drop)
    return out.reshape(b, s, d), aux


def _moe_one_group(p, x2d, cfg, no_drop: bool = False, local: bool = False):
    """One routing group: x2d (T, D) -> ((T, D), aux)."""
    m = cfg.moe
    t, d = x2d.shape
    e = m.e_padded
    capacity = t * m.top_k if no_drop else max(1, int(m.capacity_factor * t * m.top_k / e))
    buf_kind = "expert_local" if local else "moe_buf"

    probs = _router_probs(p, x2d, cfg)                   # (T, E) fp32
    gate_vals, expert_ids = jax.lax.top_k(probs, m.top_k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # ---- rank within expert: stable sort of (T*K,) assignments ----
    flat_expert = expert_ids.reshape(t * m.top_k)
    flat_token = jnp.repeat(jnp.arange(t), m.top_k)
    flat_gate = gate_vals.reshape(t * m.top_k)
    order = jnp.argsort(flat_expert, stable=True)
    sorted_expert = flat_expert[order]
    sorted_token = flat_token[order]
    sorted_gate = flat_gate[order]
    same = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32),
         (sorted_expert[1:] == sorted_expert[:-1]).astype(jnp.int32)])
    seg_pos = _segment_positions(same)
    keep = seg_pos < capacity
    dest = jnp.where(keep, sorted_expert * capacity + seg_pos, e * capacity)

    # ---- dispatch: local 2-D scatter (last row = trash) ----
    buf = jnp.zeros((e * capacity + 1, d), x2d.dtype).at[dest].set(x2d[sorted_token])
    expert_in = buf[: e * capacity].reshape(e, capacity, d)
    if not local:
        expert_in = constrain(expert_in, buf_kind)

    # ---- expert FFN: grouped matmul, E pinned to the tensor axis ----
    # (inside the dp-manual region the weights arrive with their model-axis
    # sharding intact, so no constraints are needed — and wsc-under-grad in a
    # manual region triggers an XLA:CPU AllReducePromotion crash)
    wi = p["wi"].astype(x2d.dtype)
    wo = p["wo"].astype(x2d.dtype)
    if not local:
        wi, wo = expert_weight_use(wi), expert_weight_use(wo)
    h = jnp.einsum("ecd,edf->ecf", expert_in, wi)
    if not local:
        h = constrain(h, buf_kind)
    if cfg.act == "swiglu":
        gate, up = jnp.split(h, 2, axis=-1)
        h = jax.nn.silu(gate) * up
    else:
        h = jax.nn.gelu(h)
    expert_out = jnp.einsum("ecf,efd->ecd", h, wo)
    if not local:
        expert_out = constrain(expert_out, buf_kind)

    # ---- combine: gather back + weight + scatter-add over duplicates ----
    flat_out = jnp.concatenate(
        [expert_out.reshape(e * capacity, d), jnp.zeros((1, d), x2d.dtype)])
    gathered = flat_out[dest] * sorted_gate[:, None].astype(x2d.dtype)
    out = jnp.zeros((t, d), x2d.dtype).at[sorted_token].add(gathered)

    # load-balance aux (Switch-style)
    frac_probs = probs.mean(0)
    frac_tokens = jnp.zeros(e, jnp.float32).at[flat_expert].add(1.0) / (t * m.top_k)
    aux = m.num_experts * jnp.sum(frac_probs * frac_tokens)
    return out, aux


def _moe_apply_grouped_reference(p, x, cfg, no_drop: bool = False):
    """Retired all-GSPMD grouped formulation (kept as documentation of perf
    iteration #5b/5c — the batched scatter forced token all-gathers)."""
    m = cfg.moe
    g = max(1, getattr(cfg, "moe_groups", 1))
    b, s, d = x.shape
    assert b % g == 0, f"batch {b} % moe_groups {g} != 0"
    t = (b // g) * s                                     # tokens per group
    e = m.e_padded
    capacity = t * m.top_k if no_drop else max(1, int(m.capacity_factor * t * m.top_k / e))

    xg = constrain(x.reshape(g, t, d), "hidden")         # (G, T, D), G on dp axes

    probs = _router_probs(p, xg, cfg)                    # (G, T, E) fp32
    gate_vals, expert_ids = jax.lax.top_k(probs, m.top_k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # ---- rank within expert: per-group stable sort of (T*K,) assignments ----
    flat_expert = expert_ids.reshape(g, t * m.top_k)
    flat_token = jnp.broadcast_to(
        jnp.repeat(jnp.arange(t), m.top_k)[None], (g, t * m.top_k))
    flat_gate = gate_vals.reshape(g, t * m.top_k)
    order = jnp.argsort(flat_expert, axis=-1, stable=True)
    sorted_expert = jnp.take_along_axis(flat_expert, order, axis=-1)
    sorted_token = jnp.take_along_axis(flat_token, order, axis=-1)
    sorted_gate = jnp.take_along_axis(flat_gate, order, axis=-1)
    same = jnp.concatenate(
        [jnp.zeros((g, 1), jnp.int32),
         (sorted_expert[:, 1:] == sorted_expert[:, :-1]).astype(jnp.int32)], axis=-1)
    seg_pos = _segment_positions(same)
    keep = seg_pos < capacity
    dest = jnp.where(keep, sorted_expert * capacity + seg_pos, e * capacity)

    # ---- dispatch: per-group scatter into (G, E*C+1, D) (last row = trash) ----
    g_idx = jnp.arange(g)[:, None]
    x_sorted = constrain(jnp.take_along_axis(xg, sorted_token[..., None], axis=1), "hidden")
    buf = jnp.zeros((g, e * capacity + 1, d), x.dtype).at[g_idx, dest].set(x_sorted)
    buf = constrain(buf, "hidden")
    expert_in = constrain(buf[:, : e * capacity].reshape(g, e, capacity, d), "moe_buf")

    # ---- expert FFN: grouped matmul, E pinned to the tensor axis ----
    wi = expert_weight_use(p["wi"].astype(x.dtype))
    wo = expert_weight_use(p["wo"].astype(x.dtype))
    h = constrain(jnp.einsum("gecd,edf->gecf", expert_in, wi), "moe_buf")
    if cfg.act == "swiglu":
        gate, up = jnp.split(h, 2, axis=-1)
        h = jax.nn.silu(gate) * up
    else:
        h = jax.nn.gelu(h)
    expert_out = constrain(jnp.einsum("gecf,efd->gecd", h, wo), "moe_buf")

    # ---- combine: gather back + weight + scatter-add over duplicates ----
    flat_out = constrain(jnp.concatenate(
        [expert_out.reshape(g, e * capacity, d), jnp.zeros((g, 1, d), x.dtype)], axis=1),
        "hidden")
    gathered = constrain(jnp.take_along_axis(flat_out, dest[..., None], axis=1), "hidden")
    gathered = gathered * sorted_gate[..., None].astype(x.dtype)
    out = jnp.zeros((g, t, d), x.dtype).at[g_idx, sorted_token].add(gathered)
    out = constrain(out, "hidden")

    # load-balance aux (Switch-style): E * mean over groups of Σ f_i·p_i
    frac_probs = probs.mean(1)                                        # (G, E)
    ones = jnp.ones_like(flat_expert, jnp.float32)
    frac_tokens = jnp.zeros((g, e), jnp.float32).at[g_idx, flat_expert].add(ones)
    frac_tokens = frac_tokens / (t * m.top_k)
    aux = m.num_experts * jnp.sum(frac_probs * frac_tokens, axis=-1).mean()
    return out.reshape(b, s, d), aux


def _segment_positions(same_as_prev):
    """same_as_prev[..., i] in {0,1}: 1 if element i continues the previous
    run. Returns the 0-based position of each element within its run — a
    segmented counter via (reset ? 0 : +1) associative scan over the last
    axis."""

    def combine(a, b):
        cnt_a, brk_a = a
        cnt_b, brk_b = b
        return jnp.where(brk_b, cnt_b, cnt_a + cnt_b), brk_a | brk_b

    cnt = same_as_prev.astype(jnp.int32)
    brk = same_as_prev == 0
    pos, _ = jax.lax.associative_scan(combine, (cnt, brk), axis=-1)
    return pos

"""Sharded host→device data pipeline.

Deterministic epoch shuffling (seed fold-in), global-batch sharding over the
mesh data axes, and a one-step prefetch thread (double buffering) so host
batch assembly overlaps device compute — the data-pipeline substrate for both
the miner and the LM trainer.
"""

from __future__ import annotations

import queue
import threading

import jax
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P


def synthetic_token_batches(vocab_size: int, batch: int, seq_len: int, seed: int = 0):
    """Infinite deterministic stream of {tokens, labels} int32 batches."""
    step = 0
    while True:
        rng = np.random.default_rng((seed, step))
        toks = rng.integers(0, vocab_size, size=(batch, seq_len + 1), dtype=np.int32)
        yield {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        step += 1


class ShardedBatchIterator:
    """Wraps a host batch generator; device_puts each pytree leaf with the
    given sharding and prefetches `prefetch` batches on a worker thread."""

    def __init__(self, gen, mesh, spec_fn, prefetch: int = 2):
        self._gen = gen
        self._mesh = mesh
        self._spec_fn = spec_fn  # leaf_path-free: array -> PartitionSpec
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _place(self, batch):
        if self._mesh is None:
            return jax.tree.map(jax.numpy.asarray, batch)
        return jax.tree.map(
            lambda x: jax.device_put(x, NamedSharding(self._mesh, self._spec_fn(x))), batch
        )

    def _worker(self):
        try:
            for batch in self._gen:
                if self._stop.is_set():
                    return
                self._q.put(self._place(batch))
        finally:
            self._q.put(None)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is None:
            raise StopIteration
        return item

    def close(self):
        self._stop.set()


def batch_spec(data_axes=("data",)):
    """PartitionSpec factory: shard axis 0 (global batch) over the data axes."""

    def fn(x):
        return P(data_axes, *([None] * (np.ndim(x) - 1)))

    return fn

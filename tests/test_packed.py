"""Packed uint32 bitset counting path: encoding helpers, dispatch parity,
padding invariants, and end-to-end mine() equivalence (DESIGN.md §4)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.itemsets import (
    itemsets_to_dense,
    itemsets_to_packed,
    pack_bits,
    packed_words,
    pad_packed,
    unpack_bits,
)
from repro.kernels import ops, ref

from conftest import random_problem as _random_problem


# ----------------------------------------------------------- encodings -------
def test_packed_words():
    assert [packed_words(x) for x in (1, 31, 32, 33, 64, 100)] == [1, 1, 1, 2, 2, 4]


@pytest.mark.parametrize("num_items", [7, 32, 33, 96, 130])
def test_itemsets_to_packed_matches_dense_pack(num_items):
    rng = np.random.default_rng(num_items)
    sets = np.sort(
        rng.choice(num_items, size=(20, min(4, num_items)), replace=True), axis=1
    ).astype(np.int32)
    np.testing.assert_array_equal(
        itemsets_to_packed(sets, num_items), pack_bits(itemsets_to_dense(sets, num_items))
    )


def test_itemsets_to_packed_rejects_out_of_range():
    with pytest.raises(ValueError):
        itemsets_to_packed(np.array([[0, 5]], np.int32), 5)


def test_pad_packed_is_inert():
    t, c, lengths = _random_problem(30, 40, 9, seed=1)
    tp, cp = pack_bits(t), pack_bits(c)
    want = np.asarray(ref.support_count_ref(jnp.asarray(t), jnp.asarray(c), jnp.asarray(lengths)))
    tp_pad = pad_packed(tp, row_multiple=16, word_multiple=4)  # zero rows + words
    cp_pad = pad_packed(cp, word_multiple=4)
    got = np.asarray(
        ops.support_count_packed(jnp.asarray(tp_pad), jnp.asarray(cp_pad), jnp.asarray(lengths), impl="jnp")
    )
    np.testing.assert_array_equal(got, want)


def test_pack_bits_device_matches_host():
    rng = np.random.default_rng(3)
    for i in (17, 32, 75, 128):
        dense = (rng.random((13, i)) < 0.4).astype(np.int8)
        np.testing.assert_array_equal(
            np.asarray(ops.pack_bits_device(jnp.asarray(dense), i)), pack_bits(dense)
        )
        np.testing.assert_array_equal(unpack_bits(pack_bits(dense), i), dense)


# ---------------------------------------------------- dispatch parity --------
RANDOM_SHAPES = [
    (8, 16, 4),       # tiny
    (100, 37, 33),    # I not a multiple of 32
    (200, 96, 50),    # word-aligned I
    (130, 257, 70),   # multi-word, ragged everywhere
    (64, 31, 128),    # single partial word
]


@pytest.mark.parametrize("shape", RANDOM_SHAPES)
def test_packed_impl_matches_ref_and_dense_pallas(shape):
    """support_count(impl='packed') == dense oracle == dense Pallas interpret."""
    n, i, k = shape
    t, c, lengths = _random_problem(n, i, k, seed=sum(shape))
    tj, cj, lj = jnp.asarray(t), jnp.asarray(c), jnp.asarray(lengths)
    want = np.asarray(ref.support_count_ref(tj, cj, lj))
    got_packed = np.asarray(ops.support_count(tj, cj, lj, impl="packed"))
    np.testing.assert_array_equal(got_packed, want)
    got_dense_pallas = np.asarray(
        ops.support_count(tj, cj, lj, impl="pallas_interpret", block_n=64, block_k=128, block_i=128)
    )
    np.testing.assert_array_equal(got_packed, got_dense_pallas)


@pytest.mark.parametrize("impl", ["jnp", "pallas_interpret"])
def test_packed_all_padding_candidate_rows(impl):
    """A pass whose candidate rows are ALL padding (len = -1, zero words)
    must count zero — padded rows can never match any transaction."""
    t, _, _ = _random_problem(40, 64, 4, seed=9)
    k = 12
    cp = np.zeros((k, packed_words(64)), np.uint32)
    lengths = np.full(k, -1, np.int32)
    got = np.asarray(
        ops.support_count_packed(
            jnp.asarray(pack_bits(t)), jnp.asarray(cp), jnp.asarray(lengths), impl=impl
        )
    )
    np.testing.assert_array_equal(got, np.zeros(k, np.int32))


def test_packed_zero_transaction_rows_inert():
    t, c, lengths = _random_problem(64, 48, 16, seed=5)
    want = np.asarray(ref.support_count_ref(jnp.asarray(t), jnp.asarray(c), jnp.asarray(lengths)))
    t_pad = np.concatenate([t, np.zeros((40, 48), np.int8)])
    got = np.asarray(
        ops.support_count(jnp.asarray(t_pad), jnp.asarray(c), jnp.asarray(lengths), impl="packed")
    )
    np.testing.assert_array_equal(got, want)


# ------------------------------------------------------- end-to-end ----------
def test_mine_packed_matches_dense(small_db):
    """mine() with representation='packed' returns identical results to the
    dense path on the Quest synthetic DB (the acceptance-criterion check)."""
    from repro.core.apriori import AprioriConfig, mine

    dense = mine(small_db, AprioriConfig(min_support=0.05, max_k=4, count_impl="jnp"))
    packed = mine(
        small_db,
        AprioriConfig(min_support=0.05, max_k=4, count_impl="jnp", representation="packed"),
    )
    assert dense.as_dict() == packed.as_dict()
    assert dense.min_count == packed.min_count


def test_mine_packed_interpret_kernel_small(small_db):
    """The packed Pallas kernel body (interpret) inside the full mine loop."""
    from repro.core.apriori import AprioriConfig, mine

    db = small_db[:120]
    dense = mine(db, AprioriConfig(min_support=0.08, max_k=3, count_impl="jnp"))
    packed = mine(
        db,
        AprioriConfig(
            min_support=0.08,
            max_k=3,
            count_impl="pallas_interpret",
            representation="packed",
            candidate_pad=128,
        ),
    )
    assert dense.as_dict() == packed.as_dict()

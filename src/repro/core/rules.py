"""Association-rule extraction from mined frequent itemsets (KDD step 5).

Two implementations of the same contract:

* :func:`extract_rules` — the pure-Python reference: per frequent itemset,
  enumerate every (antecedent, consequent) split and emit :class:`Rule`
  dataclasses.  O(Σ_k F_k · 2^k) Python-loop work; kept as the oracle.
* :func:`extract_rules_vectorized` / :func:`extract_rule_arrays` — the
  production path: splits are enumerated as index arrays (one gather per
  (k, r) split shape), antecedent/consequent supports are resolved with a
  single vectorized ``np.unique`` join per level, and support / confidence /
  lift are computed with jnp ops over the whole rule set at once.  The array
  form (:class:`RuleArrays`) carries packed uint32 bitsets in the same word
  layout as ``kernels/support_count_packed.py`` — the input format of the
  serving rulebook compiler (``serving/rulebook.py``, DESIGN.md §8).

Both paths skip splits whose antecedent *or* consequent support is absent
from the mined result (a truncated/partial ``AprioriResult`` — e.g. a
filtered resume checkpoint — would otherwise yield rules with undefined
confidence or ``lift=NaN``), and both sort deterministically:
``(-confidence, -support, antecedent, consequent)``.
"""

from __future__ import annotations

import dataclasses
from itertools import combinations

import numpy as np

from repro.core import itemsets as enc

_SORT_DOC = "(-confidence, -support, antecedent, consequent)"


@dataclasses.dataclass(frozen=True)
class Rule:
    antecedent: tuple
    consequent: tuple
    support: float      # s(A ∪ C) / N
    confidence: float   # s(A ∪ C) / s(A)
    lift: float         # confidence / (s(C) / N)


def _rule_sort_key(r: Rule):
    return (-r.confidence, -r.support, r.antecedent, r.consequent)


def extract_rules(result, min_confidence: float = 0.5, max_rules: int | None = None):
    """All rules A -> C with A ∪ C frequent and confidence >= threshold.

    Reference implementation (Python loop over all splits). Splits whose
    antecedent or consequent support is missing from ``result`` are skipped
    — never emitted with NaN statistics. Sorted by ``(-confidence,
    -support, antecedent, consequent)`` so ties break deterministically.
    """
    supports = result.as_dict()
    n = result.num_transactions
    rules = []
    for itemset, sup in supports.items():
        if len(itemset) < 2:
            continue
        for r in range(1, len(itemset)):
            for ante in combinations(itemset, r):
                s_a = supports.get(tuple(sorted(ante)))
                if not s_a:
                    continue
                conf = sup / s_a
                if conf < min_confidence:
                    continue
                cons = tuple(sorted(set(itemset) - set(ante)))
                s_c = supports.get(cons)
                if not s_c:
                    continue  # truncated result: lift undefined — skip, not NaN
                lift = conf / (s_c / n)
                rules.append(Rule(tuple(sorted(ante)), cons, sup / n, conf, lift))
    rules.sort(key=_rule_sort_key)
    return rules[:max_rules] if max_rules else rules


# ------------------------------------------------------------------------
# vectorized path
# ------------------------------------------------------------------------

@dataclasses.dataclass
class RuleArrays:
    """Column-oriented rule set — the compile input of the serving rulebook.

    ``ante_packed`` / ``cons_packed`` are uint32 bitsets in the exact word
    layout of ``kernels/support_count_packed.py`` (little-endian bits,
    ``ceil(num_items/32)`` words); ``ante_len`` is the antecedent popcount
    (``-1`` marks padding rows, same sentinel as the counting kernels).
    Score columns are float32, one row per rule, unsorted.
    """

    ante_packed: np.ndarray   # (R, W) uint32
    cons_packed: np.ndarray   # (R, W) uint32
    ante_len: np.ndarray      # (R,)   int32
    support: np.ndarray       # (R,)   float32 — s(A ∪ C) / N
    confidence: np.ndarray    # (R,)   float32
    lift: np.ndarray          # (R,)   float32
    num_items: int
    # exact integer counts (s(A ∪ C), s(A), s(C)) and N: `to_rules` derives
    # its statistics from these in float64 so ordering and values are
    # bit-identical to the Python reference; the float32 columns above are
    # the *serving* payload.
    count: np.ndarray | None = None        # (R,) int64
    ante_count: np.ndarray | None = None   # (R,) int64
    cons_count: np.ndarray | None = None   # (R,) int64
    num_transactions: int = 0

    @property
    def num_rules(self) -> int:
        return int((self.ante_len >= 0).sum())

    def to_rules(self, max_rules: int | None = None) -> list[Rule]:
        """Materialize :class:`Rule` dataclasses, sorted like the reference."""
        keep = self.ante_len >= 0
        ante = enc.unpack_bits(self.ante_packed[keep], self.num_items)
        cons = enc.unpack_bits(self.cons_packed[keep], self.num_items)
        n = self.num_transactions
        rules = [
            Rule(
                tuple(int(i) for i in np.flatnonzero(a)),
                tuple(int(i) for i in np.flatnonzero(c)),
                sup / n, sup / s_a, (sup / s_a) / (s_c / n),
            )
            for a, c, sup, s_a, s_c in zip(
                ante, cons,
                self.count[keep].tolist(), self.ante_count[keep].tolist(),
                self.cons_count[keep].tolist(),
            )
        ]
        rules.sort(key=_rule_sort_key)
        return rules[:max_rules] if max_rules else rules


def _lookup_supports(level, queries: np.ndarray) -> np.ndarray:
    """Vectorized itemset -> support join: for each query row (sorted item
    ids) return its mined support, or 0 if absent. One ``np.unique`` over
    the stacked (table ∪ queries) rows — no per-row Python."""
    q = queries.shape[0]
    if level is None or q == 0:
        return np.zeros(q, dtype=np.int64)
    table, sup = level
    if table.shape[0] == 0:
        return np.zeros(q, dtype=np.int64)
    stacked = np.concatenate([np.asarray(table, np.int64), np.asarray(queries, np.int64)])
    _, inv = np.unique(stacked, axis=0, return_inverse=True)
    by_uid = np.zeros(int(inv.max()) + 1, dtype=np.int64)
    by_uid[inv[: table.shape[0]]] = np.asarray(sup, np.int64)
    return by_uid[inv[table.shape[0]:]]


def extract_rule_arrays(
    result,
    min_confidence: float = 0.5,
    num_items: int | None = None,
) -> RuleArrays:
    """Vectorized rule extraction into :class:`RuleArrays`.

    Per (itemset size k, antecedent size r) the C(k, r) split patterns are a
    single fancy-index gather; supports resolve via :func:`_lookup_supports`;
    the confidence filter runs in float64 (bit-identical selection to the
    Python reference) and the returned score columns are computed with jnp
    ops over all surviving rules at once.
    """
    import jax.numpy as jnp

    levels = result.levels
    n = result.num_transactions
    if num_items is None:
        sizes = [int(sets.max()) + 1 for sets, _ in levels.values() if sets.size]
        num_items = max(sizes) if sizes else 1
    w = enc.packed_words(num_items)

    ante_pk, cons_pk, ante_ln = [], [], []
    sup_l, sa_l, sc_l = [], [], []
    for k in sorted(levels):
        sets_k, sup_k = levels[k]
        f = sets_k.shape[0]
        if k < 2 or f == 0:
            continue
        for r in range(1, k):
            patterns = np.array(list(combinations(range(k), r)), dtype=np.int64)  # (P, r)
            p = patterns.shape[0]
            mask = np.ones((p, k), dtype=bool)
            mask[np.arange(p)[:, None], patterns] = False
            comp = np.nonzero(mask)[1].reshape(p, k - r)                          # (P, k-r)
            ante = np.asarray(sets_k)[:, patterns].reshape(f * p, r)
            cons = np.asarray(sets_k)[:, comp].reshape(f * p, k - r)
            s_a = _lookup_supports(levels.get(r), ante)
            s_c = _lookup_supports(levels.get(k - r), cons)
            # f64 selection — the same arithmetic the reference performs
            with np.errstate(divide="ignore", invalid="ignore"):
                conf64 = np.asarray(sup_k, np.float64).repeat(p) / s_a
            keep = (s_a > 0) & (s_c > 0) & (conf64 >= min_confidence)
            if not keep.any():
                continue
            ante_pk.append(enc.itemsets_to_packed(ante[keep], num_items))
            cons_pk.append(enc.itemsets_to_packed(cons[keep], num_items))
            ante_ln.append(np.full(int(keep.sum()), r, dtype=np.int32))
            sup_l.append(np.asarray(sup_k, np.int64).repeat(p)[keep])
            sa_l.append(s_a[keep])
            sc_l.append(s_c[keep])

    if not ante_pk:
        z = np.zeros((0, w), np.uint32)
        zf = np.zeros(0, np.float32)
        zi = np.zeros(0, np.int64)
        return RuleArrays(
            z, z.copy(), np.zeros(0, np.int32), zf, zf.copy(), zf.copy(),
            num_items, zi, zi.copy(), zi.copy(), n,
        )

    count = np.concatenate(sup_l)
    ante_count = np.concatenate(sa_l)
    cons_count = np.concatenate(sc_l)
    sup = jnp.asarray(count, jnp.float32)
    s_a = jnp.asarray(ante_count, jnp.float32)
    s_c = jnp.asarray(cons_count, jnp.float32)
    conf = sup / s_a
    return RuleArrays(
        ante_packed=np.concatenate(ante_pk),
        cons_packed=np.concatenate(cons_pk),
        ante_len=np.concatenate(ante_ln),
        support=np.asarray(sup / n),
        confidence=np.asarray(conf),
        lift=np.asarray(conf * n / s_c),
        num_items=num_items,
        count=count,
        ante_count=ante_count,
        cons_count=cons_count,
        num_transactions=n,
    )


def extract_rules_vectorized(
    result,
    min_confidence: float = 0.5,
    max_rules: int | None = None,
    num_items: int | None = None,
) -> list[Rule]:
    """Drop-in vectorized replacement for :func:`extract_rules`."""
    return extract_rule_arrays(result, min_confidence, num_items).to_rules(max_rules)

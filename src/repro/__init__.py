"""repro — Map/Reduce Apriori (ACIJ 2012) as a production JAX/TPU framework.

Layers:
  core/         the paper's contribution: distributed level-wise Apriori
  data/         transaction + token pipelines
  kernels/      Pallas TPU kernels (support counting, flash attention)
  models/       assigned-architecture LM zoo (pure JAX)
  configs/      one config per assigned architecture
  distributed/  sharding rules, checkpointing, fault tolerance, compression
  training/     optimizer + train step
  serving/      KV/state caches + decode step
  launch/       mesh, dry-run, drivers
"""

__version__ = "1.0.0"

"""Adaptive max-wait controller (serving.controller): bounded AIMD on the
windowed p99, hold-below-min-samples, clamps — and the gateway integration
with the §10 bit-identity contract intact (DESIGN.md §14)."""

import numpy as np
import pytest

from repro.obs.registry import Histogram
from repro.serving import Gateway, compile_rulebook, recommend
from repro.serving.controller import AdaptiveMaxWait

NUM_ITEMS = 32


class FakeClock:
    def __init__(self, t: float = 0.0):
        self.t = t

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


def make(h=None, **kw):
    h = h if h is not None else Histogram()
    clock = FakeClock()
    kw.setdefault("objective_ms", 5.0)
    kw.setdefault("initial_wait_ms", 8.0)
    kw.setdefault("min_samples", 4)
    ctl = AdaptiveMaxWait(h, now_fn=clock, **kw)
    return ctl, h, clock


def feed(h, ms, n):
    for _ in range(n):
        h.record(ms / 1e3)


# ----------------------------------------------------------------- AIMD ----

def test_p99_over_objective_halves_the_wait():
    ctl, h, clock = make()
    feed(h, 50.0, 20)                     # way over the 5ms objective
    clock.advance(1.0)
    assert ctl.current_wait_s() == pytest.approx(4.0 / 1e3)   # 8 -> 4
    assert ctl.decreases == 1 and ctl.ticks == 1
    assert ctl.last_window_p99_ms > 5.0


def test_p99_under_headroom_steps_up_and_clamps_at_max():
    ctl, h, clock = make(initial_wait_ms=8.0, max_wait_ms=8.25)
    feed(h, 0.5, 20)                      # far under 0.8 * 5ms
    clock.advance(1.0)
    ctl.force_tick()
    assert ctl.current_wait_ms == pytest.approx(8.25)         # +0.25, capped
    feed(h, 0.5, 20)
    ctl.force_tick()
    assert ctl.current_wait_ms == pytest.approx(8.25)         # clamped
    assert ctl.increases == 1             # the no-op step is not counted


def test_dead_band_holds_steady():
    ctl, h, clock = make()                # band = [4ms, 5ms]
    feed(h, 4.5, 20)
    ctl.force_tick()
    assert ctl.current_wait_ms == pytest.approx(8.0)
    assert ctl.ticks == 1 and ctl.increases == 0 and ctl.decreases == 0


def test_decrease_clamps_at_min_wait():
    ctl, h, clock = make(initial_wait_ms=2.0, min_wait_ms=1.5)
    feed(h, 50.0, 20)
    ctl.force_tick()
    assert ctl.current_wait_ms == pytest.approx(1.5)          # 1.0 clamped up
    feed(h, 50.0, 20)
    ctl.force_tick()
    assert ctl.current_wait_ms == pytest.approx(1.5)
    assert ctl.decreases == 1


def test_thin_window_holds_without_resetting_the_window():
    ctl, h, clock = make(min_samples=16)
    feed(h, 50.0, 10)                     # below min_samples
    clock.advance(1.0)
    assert ctl.current_wait_s() == pytest.approx(8.0 / 1e3)   # held
    assert ctl.ticks == 0
    feed(h, 50.0, 10)                     # trickle accumulates: 20 total now
    clock.advance(1.0)
    assert ctl.current_wait_s() == pytest.approx(4.0 / 1e3)   # now it acts
    assert ctl.ticks == 1


def test_interval_gates_reevaluation():
    ctl, h, clock = make(interval_s=0.25)
    feed(h, 50.0, 20)
    clock.advance(0.1)                    # inside the interval: no tick
    assert ctl.current_wait_s() == pytest.approx(8.0 / 1e3)
    clock.advance(0.2)
    assert ctl.current_wait_s() == pytest.approx(4.0 / 1e3)


def test_snapshot_and_validation():
    ctl, _, _ = make()
    snap = ctl.snapshot()
    assert snap["wait_ms"] == 8.0 and snap["objective_ms"] == 5.0
    assert snap["min_wait_ms"] == 0.0 and snap["max_wait_ms"] == 8.0
    with pytest.raises(ValueError):
        AdaptiveMaxWait(Histogram(), objective_ms=0.0, initial_wait_ms=1.0)
    with pytest.raises(ValueError):
        AdaptiveMaxWait(Histogram(), objective_ms=1.0, initial_wait_ms=1.0,
                        decrease_factor=1.0)
    with pytest.raises(ValueError):
        AdaptiveMaxWait(Histogram(), objective_ms=1.0, initial_wait_ms=1.0,
                        min_wait_ms=2.0, max_wait_ms=1.0)


# ------------------------------------------------- gateway integration -----

@pytest.fixture(scope="module")
def rulebook(small_db):
    from repro.core.apriori import AprioriConfig, mine

    return compile_rulebook(
        mine(small_db, AprioriConfig(min_support=0.05, max_k=3, count_impl="jnp")),
        min_confidence=0.3, num_items=NUM_ITEMS,
    )


def test_gateway_wires_controller_and_stays_bit_identical(small_db, rulebook):
    baskets = [np.flatnonzero(row).tolist() for row in small_db[:32]]
    with Gateway(rulebook, max_batch=8, max_wait_ms=5.0, cache_capacity=0,
                 p99_target_ms=1.0) as gw:
        assert gw.wait_controller is not None
        assert gw._batcher._wait_controller is gw.wait_controller
        responses = [(b, gw.query(b, top_k=5)) for b in baskets]
        gw.wait_controller.force_tick()   # guarantee at least one decision
        stats = gw.stats()
    # the controller is live and visible in stats()
    ctl = stats["wait_controller"]
    assert ctl["objective_ms"] == 1.0 and ctl["max_wait_ms"] == 5.0
    assert stats["max_wait_ms"] == ctl["wait_ms"] <= 5.0
    # §10 contract survives adaptation: every response equals the direct
    # batch engine at the answering bucket, no matter what the wait did
    for b, resp in responses:
        direct = recommend(rulebook, [b], top_k=5, batch_size=resp.bucket)
        assert np.array_equal(resp.items, direct.items[0])
        assert np.array_equal(resp.scores, direct.scores[0])


def test_gateway_without_target_keeps_fixed_wait(rulebook):
    with Gateway(rulebook, max_batch=8, max_wait_ms=5.0, cache_capacity=0) as gw:
        assert gw.wait_controller is None
        stats = gw.stats()
    assert stats["max_wait_ms"] == 5.0
    assert "wait_controller" not in stats

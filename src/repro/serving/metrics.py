"""Serving-side observability: latency histograms + gateway counters (§10).

The gateway records every request into a :class:`GatewayMetrics` — admission
(submitted / rejected), cache hits vs misses, per-dispatch batch occupancy
(real rows vs the padded jit bucket), rulebook swaps, and end-to-end request
latency into a :class:`LatencyHistogram`. ``snapshot()`` returns one plain
dict (JSON-able) with p50/p95/p99 so the load harness, the serve CLI and CI
gates all read the same numbers.

The histogram is log-bucketed (geometric ``GROWTH``-spaced edges from 1 µs):
recording is O(1) and lock-cheap, quantiles are resolved to a bucket's upper
edge — a conservative ≤ ``GROWTH``-factor overestimate, never an
underestimate, which is the right bias for latency SLO gates.
"""

from __future__ import annotations

import math
import threading

_FLOOR_S = 1e-6    # first bucket edge: 1 us
_GROWTH = 1.25
_NUM_BUCKETS = 96  # 1us * 1.25**95 ~= 1.6e3 s: covers any sane request
_LOG_GROWTH = math.log(_GROWTH)


class LatencyHistogram:
    """Log-bucketed latency histogram with exact count/sum/min/max."""

    def __init__(self):
        self._lock = threading.Lock()
        self._counts = [0] * _NUM_BUCKETS
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = 0.0

    @staticmethod
    def _bucket(seconds: float) -> int:
        if seconds <= _FLOOR_S:
            return 0
        return min(_NUM_BUCKETS - 1, 1 + int(math.log(seconds / _FLOOR_S) / _LOG_GROWTH))

    @staticmethod
    def _edge(bucket: int) -> float:
        """Upper edge of ``bucket`` in seconds: bucket b holds samples in
        ``[FLOOR·GROWTH^(b-1), FLOOR·GROWTH^b)`` (bucket 0: everything ≤ FLOOR)."""
        return _FLOOR_S * _GROWTH**bucket

    def record(self, seconds: float) -> None:
        seconds = max(0.0, float(seconds))
        with self._lock:
            self._counts[self._bucket(seconds)] += 1
            self.count += 1
            self.sum += seconds
            self.min = min(self.min, seconds)
            self.max = max(self.max, seconds)

    def quantile(self, q: float) -> float:
        """Latency (seconds) at quantile ``q`` in (0, 1]: the upper edge of
        the bucket holding the ceil(q·count)-th sample; 0.0 when empty."""
        with self._lock:
            if self.count == 0:
                return 0.0
            target = max(1, math.ceil(q * self.count))
            cum = 0
            for b, c in enumerate(self._counts):
                cum += c
                if cum >= target:
                    return min(self._edge(b), self.max)
            return self.max

    def snapshot(self) -> dict:
        return {
            "count": self.count,
            "mean_ms": (self.sum / self.count * 1e3) if self.count else 0.0,
            "min_ms": (self.min * 1e3) if self.count else 0.0,
            "max_ms": self.max * 1e3,
            "p50_ms": self.quantile(0.50) * 1e3,
            "p95_ms": self.quantile(0.95) * 1e3,
            "p99_ms": self.quantile(0.99) * 1e3,
        }


class GatewayMetrics:
    """All gateway counters + the request-latency histogram, one lock."""

    def __init__(self):
        self._lock = threading.Lock()
        self.latency = LatencyHistogram()
        self.submitted = 0       # admitted into the queue (or served from cache)
        self.rejected = 0        # refused at admission (queue full / closed)
        self.completed = 0       # responses delivered (cache hits included)
        self.failed = 0          # futures resolved with an exception
        self.cache_hits = 0
        self.cache_misses = 0
        self.swaps = 0
        self.deadline_expired = 0  # requests dropped past-deadline at dispatch
        self.worker_restarts = 0  # dead dispatch workers re-armed (§11)
        self.batches = 0         # dispatches through the match step
        self.batch_rows_real = 0     # requests actually in dispatched batches
        self.batch_rows_padded = 0   # rows of the padded jit buckets

    def record_admission(self, accepted: bool) -> None:
        with self._lock:
            if accepted:
                self.submitted += 1
            else:
                self.rejected += 1

    def record_cache(self, hit: bool) -> None:
        with self._lock:
            if hit:
                self.cache_hits += 1
            else:
                self.cache_misses += 1

    def record_batch(self, real_rows: int, padded_rows: int) -> None:
        with self._lock:
            self.batches += 1
            self.batch_rows_real += real_rows
            self.batch_rows_padded += padded_rows

    def record_response(self, latency_s: float, failed: bool = False) -> None:
        with self._lock:
            if failed:
                self.failed += 1
            else:
                self.completed += 1
        if not failed:
            self.latency.record(latency_s)

    def record_swap(self) -> None:
        with self._lock:
            self.swaps += 1

    def record_deadline_expired(self) -> None:
        with self._lock:
            self.deadline_expired += 1

    def record_worker_restart(self) -> None:
        with self._lock:
            self.worker_restarts += 1

    @property
    def batch_occupancy(self) -> float:
        """Real rows / padded bucket rows over all dispatches (1.0 = full)."""
        return self.batch_rows_real / self.batch_rows_padded if self.batch_rows_padded else 0.0

    @property
    def cache_hit_rate(self) -> float:
        total = self.cache_hits + self.cache_misses
        return self.cache_hits / total if total else 0.0

    def snapshot(self) -> dict:
        with self._lock:
            out = {
                "submitted": self.submitted,
                "rejected": self.rejected,
                "completed": self.completed,
                "failed": self.failed,
                "cache_hits": self.cache_hits,
                "cache_misses": self.cache_misses,
                "swaps": self.swaps,
                "deadline_expired": self.deadline_expired,
                "worker_restarts": self.worker_restarts,
                "batches": self.batches,
                "batch_rows_real": self.batch_rows_real,
                "batch_rows_padded": self.batch_rows_padded,
            }
        out["batch_occupancy"] = self.batch_occupancy
        out["cache_hit_rate"] = self.cache_hit_rate
        out["latency"] = self.latency.snapshot()
        return out


class RouterMetrics:
    """Replica-router counters + the router-level latency histogram (§12).

    Router latency is submit → terminal outcome INCLUDING failover retries
    and backoff, so it is an end-to-end client view; a replica gateway's own
    histogram sees only the attempts that reached it."""

    def __init__(self):
        self._lock = threading.Lock()
        self.latency = LatencyHistogram()
        self.routed = 0            # requests accepted by the router
        self.completed = 0         # outer futures resolved with a Response
        self.failed = 0            # outer futures resolved with an exception
        self.shed = 0              # refused: every candidate replica dead/saturated
        self.failovers = 0         # re-submissions to another replica
        self.attempt_timeouts = 0  # attempts abandoned as unresponsive
        self.deadline_failed = 0   # outer futures failed with DeadlineExceeded
        self.retries_exhausted = 0 # outer futures failed after the retry budget
        self.resyncs = 0           # lagging replicas re-synced to the target gen
        self.swap_prepare_failures = 0  # replicas that failed two-phase prepare
        self.coordinated_swaps = 0      # successful two-phase hot-swaps
        self.replica_deaths = 0         # replicas declared dead (restart storm)
        self.max_generation_lag = 0     # peak (target - replica) generation gap
        self.current_generation_lag = 0

    def record_routed(self) -> None:
        with self._lock:
            self.routed += 1

    def record_completed(self, latency_s: float) -> None:
        with self._lock:
            self.completed += 1
        self.latency.record(latency_s)

    def record_failed(self, *, deadline: bool = False, exhausted: bool = False) -> None:
        with self._lock:
            self.failed += 1
            if deadline:
                self.deadline_failed += 1
            if exhausted:
                self.retries_exhausted += 1

    def record_shed(self) -> None:
        with self._lock:
            self.shed += 1

    def record_failover(self) -> None:
        with self._lock:
            self.failovers += 1

    def record_attempt_timeout(self) -> None:
        with self._lock:
            self.attempt_timeouts += 1

    def record_resync(self) -> None:
        with self._lock:
            self.resyncs += 1

    def record_swap_prepare_failure(self) -> None:
        with self._lock:
            self.swap_prepare_failures += 1

    def record_coordinated_swap(self) -> None:
        with self._lock:
            self.coordinated_swaps += 1

    def record_replica_death(self) -> None:
        with self._lock:
            self.replica_deaths += 1

    def observe_generation_lag(self, lag: int) -> None:
        with self._lock:
            self.current_generation_lag = lag
            self.max_generation_lag = max(self.max_generation_lag, lag)

    def snapshot(self) -> dict:
        with self._lock:
            out = {
                "routed": self.routed,
                "completed": self.completed,
                "failed": self.failed,
                "shed": self.shed,
                "failovers": self.failovers,
                "attempt_timeouts": self.attempt_timeouts,
                "deadline_failed": self.deadline_failed,
                "retries_exhausted": self.retries_exhausted,
                "resyncs": self.resyncs,
                "swap_prepare_failures": self.swap_prepare_failures,
                "coordinated_swaps": self.coordinated_swaps,
                "replica_deaths": self.replica_deaths,
                "max_generation_lag": self.max_generation_lag,
                "current_generation_lag": self.current_generation_lag,
            }
        out["latency"] = self.latency.snapshot()
        return out

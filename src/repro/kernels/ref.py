"""Pure-jnp oracles for every Pallas kernel in this package."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def support_count_ref(t_dense, c_dense, lengths):
    """Exact support counts.

    t_dense: (N, I) {0,1} int8 transactions
    c_dense: (K, I) {0,1} int8 candidate itemsets
    lengths: (K,)   int32 itemset sizes (|c| >= 1; padded rows use -1)
    returns: (K,)   int32  —  #transactions t with c ⊆ t
    """
    inter = jnp.matmul(
        t_dense.astype(jnp.int32), c_dense.astype(jnp.int32).T
    )  # (N, K) intersection sizes
    contained = inter == lengths[None, :].astype(jnp.int32)
    return jnp.sum(contained, axis=0, dtype=jnp.int32)


def support_count_packed_ref(t_packed, c_packed, lengths=None, block_k: int = 256):
    """Bitset oracle over packed uint32 words (VPU-style path).

    t_packed: (N, W) uint32, c_packed: (K, W) uint32.
    lengths:  optional (K,) int32 itemset sizes; rows with ``len = -1`` are
              padding and never match (same semantics as the dense path).
              Without lengths, padding rows are encoded as all-ones words.
    Containment: (t & c) == c for every word. Blocked over K to bound memory.
    """
    n, w = t_packed.shape
    k, _ = c_packed.shape
    pad = (-k) % block_k
    c_pad = jnp.pad(c_packed, ((0, pad), (0, 0)), constant_values=jnp.uint32(0xFFFFFFFF))
    valid = None
    if lengths is not None:
        valid = jnp.pad(lengths.astype(jnp.int32), (0, pad), constant_values=-1) >= 0

    def one_block(c_blk):
        # (N, 1, W) & (1, bk, W)
        inter = t_packed[:, None, :] & c_blk[None, :, :]
        contained = jnp.all(inter == c_blk[None, :, :], axis=-1)
        return contained.sum(axis=0, dtype=jnp.int32)

    blocks = c_pad.reshape(-1, block_k, w)
    counts = jax.lax.map(one_block, blocks).reshape(-1)
    if valid is not None:
        counts = jnp.where(valid, counts, 0)
    return counts[:k]


def unpack_bits_ref(packed, num_items: int):
    """Packed uint32 (R, W) -> dense {0,1} float32 (R, num_items) — jnp twin
    of ``core.itemsets.unpack_bits`` (little-endian bits per word)."""
    r, w = packed.shape
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (packed[:, :, None] >> shifts) & jnp.uint32(1)
    return bits.reshape(r, w * 32)[:, :num_items].astype(jnp.float32)


def rule_match_ref(b_packed, a_packed, lengths, c_packed, scores):
    """Per-item rule-evidence scores — oracle for ``kernels/rule_match.py``.

    b_packed: (B, W) uint32 basket bitsets
    a_packed: (R, W) uint32 antecedent bitsets
    lengths:  (R,)   int32  antecedent sizes (-1 = padding row, never matches)
    c_packed: (R, W) uint32 consequent bitsets
    scores:   (R,)   float32 rule weights
    returns:  (B, 32·W) float32 — out[b, i] = Σ_r [a_r ⊆ basket_b] · s_r · c_r[i]
    """
    contains = jnp.all(
        (b_packed[:, None, :] & a_packed[None, :, :]) == a_packed[None, :, :], axis=-1
    )  # (B, R)
    matched = contains & (lengths.astype(jnp.int32) >= 0)[None, :]
    weights = matched.astype(jnp.float32) * scores.astype(jnp.float32)[None, :]
    cons_dense = unpack_bits_ref(c_packed, 32 * c_packed.shape[1])  # (R, 32·W)
    return weights @ cons_dense

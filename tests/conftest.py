import os

import numpy as np
import pytest

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def subprocess_env():
    """Minimal env for subprocess-spawning tests: repo importable via
    ``PYTHONPATH=src`` (cwd must be REPO_ROOT), and JAX pinned to the CPU
    platform — without it, children on TPU-image containers try TPU-plugin
    init and hang for minutes retrying GCP metadata fetches."""
    return {
        "PYTHONPATH": "src",
        "PATH": "/usr/bin:/bin",
        "HOME": os.environ.get("HOME", "/root"),
        "JAX_PLATFORMS": os.environ.get("JAX_PLATFORMS", "cpu"),
    }


def random_problem(n, i, k, seed=0, density=0.3):
    """Random (transactions, candidates, lengths) triple for counting tests."""
    rng = np.random.default_rng(seed)
    t = (rng.random((n, i)) < density).astype(np.int8)
    sizes = rng.integers(1, min(6, i) + 1, size=k)
    cands = np.zeros((k, i), dtype=np.int8)
    for row, s in enumerate(sizes):
        cands[row, rng.choice(i, size=s, replace=False)] = 1
    return t, cands, cands.sum(1).astype(np.int32)


@pytest.fixture(scope="session")
def small_db():
    """Small deterministic transaction DB shared across tests."""
    from repro.data.synthetic import QuestConfig, gen_transactions

    return gen_transactions(QuestConfig(num_transactions=300, num_items=32, avg_len=7, num_patterns=6, seed=7))


def brute_force_frequent(dense: np.ndarray, min_count: int, max_k: int) -> dict:
    """Oracle: exhaustive frequent-itemset mining via python sets."""
    from itertools import combinations

    rows = [frozenset(np.flatnonzero(r)) for r in dense]
    items = sorted(set().union(*rows)) if rows else []
    out = {}
    prev = {(): None}
    for k in range(1, max_k + 1):
        level = {}
        if k <= 2:
            cands = combinations(items, k)
        else:
            seeds = [set(c) for c in prev]
            cands = {tuple(sorted(s | {b})) for s in seeds for b in items if b not in s}
        for c in cands:
            cs = set(c)
            s = sum(1 for r in rows if cs <= r)
            if s >= min_count:
                level[tuple(c)] = s
        if not level:
            break
        out.update(level)
        prev = level
    return out

"""Bench-trajectory regression gate: one uniform check over BENCH_*.json (§14).

The committed trajectory files (``BENCH_serve.json`` / ``BENCH_fault.json``
/ ``BENCH_obs.json``) accumulate one row per benchmark, merge-by-name
across runs, each row carrying a bounded ``history`` of its prior
``us_per_call`` values.  CI used to spot-check a handful of rows with
hand-coded jq thresholds; this module replaces those with one detector run
as ``python -m repro.obs.regress --check BENCH_*.json``.

Two checks per row:

* **Trajectory** (noise-aware): the latest ``us_per_call`` is compared to
  the trajectory baseline — the **median** of the row's history (median,
  not mean: one historic outlier run must not poison the baseline).  The
  tolerance is ``max(rel_floor, noise_k * MAD / baseline)`` where MAD is
  the history's median absolute deviation — a row that historically
  jitters ±20% gets a proportionally wider gate than a row that repeats to
  1%, so noisy benches don't cry wolf and stable benches stay tight.  Only
  DEGRADATION (latest slower than baseline by more than the tolerance) is
  flagged; getting faster just becomes the new history.  Rows with fewer
  than ``min_history`` prior values pass vacuously — a young trajectory
  has no baseline to regress from.  "Factors Affecting Performance of
  MapReduce based Apriori" (1701.05982) is the motivation: cluster-Apriori
  throughput swings heavily with configuration drift, exactly what a
  trajectory baseline catches and a fixed threshold misses.

* **Invariant** (semantic): the correctness/efficiency claims the old
  per-row CI gates asserted, now declarative: micro-batching must still
  beat sequential, the replicated tier must still scale and survive the
  kill with ≥ 99% availability, checkpoint/instrumentation overhead must
  stay bounded with parity intact, and the adaptive-wait controller must
  move p99 TOWARD the objective.  A row named by an invariant that is
  missing from every checked file fails by default — a silently-dropped
  bench must not read as green.  Any row with ``us_per_call < 0`` (the
  harness's FAILED marker) fails unconditionally.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import statistics
import sys
from typing import Dict, List, Optional, Sequence, Tuple

#: Declarative replacements for the retired hand-coded CI gates:
#: row name -> list of (derived key, operator, expected) triples.
#: Operators: ">=", "<=" compare numerically (trailing unit suffixes like
#: "x" / "%" are stripped); "==" compares numerically when both sides
#: parse, else as strings ("parity=ok").
INVARIANTS: Dict[str, List[Tuple[str, str, object]]] = {
    # gateway micro-batching must beat sequential serving (§10 gate)
    "serve_gateway_microbatch_c32": [("speedup_vs_sequential", ">=", 2.0)],
    # 2 replicas must partition the cache working set into real scaling (§12)
    "serve_replicated_r2": [("scaling_vs_r1", ">=", 1.5)],
    # mid-load replica kill: supervised restart + failover keep availability
    "serve_replicated_kill_recovery": [
        ("availability", ">=", 0.99),
        ("kills_fired", "==", 1),
        ("restarts", ">=", 1),
    ],
    # checkpointing the streamed mine stays cheap (§11 gate)
    "fault_mine_chk_n60000": [("overhead_vs_unchk", "<=", 1.10)],
    # kill+resume reproduces the uninterrupted result, replaying <= 1 level
    "fault_kill_resume_n60000": [
        ("parity", "==", "ok"),
        ("replayed_levels", "<=", 1),
    ],
    # incremental refresh (§15): folding a 1% append through the count cache
    # must stay dict-identical to the full re-mine AND well ahead of it
    "fault_refresh_delta_p1": [
        ("parity", "==", "ok"),
        ("mode", "==", "delta"),
        ("speedup_vs_full", ">=", 3.0),
    ],
    # full instrumentation is near-free and provably inert (§13 gate)
    "obs_mine_instrumented_n60000": [
        ("overhead_vs_plain", "<=", 1.05),
        ("parity", "==", "ok"),
    ],
    # the adaptive-wait controller moves p99 toward the objective (§14 gate)
    "obs_slo_adaptive_wait": [("toward_objective", "==", "yes")],
}


@dataclasses.dataclass(frozen=True)
class Finding:
    """One check outcome (ok or violation) for the report."""

    file: str
    row: str
    check: str          # trajectory | invariant | failed_row | missing_row
    ok: bool
    detail: str

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


def parse_derived(derived: str) -> Dict[str, str]:
    """``key=value;key=value`` pairs from a bench row's derived string;
    fragments without ``=`` (e.g. ``correctness_path``) are skipped."""
    out: Dict[str, str] = {}
    for frag in (derived or "").split(";"):
        if "=" in frag:
            k, v = frag.split("=", 1)
            out[k.strip()] = v.strip()
    return out


def _num(v: object) -> Optional[float]:
    """Float from a derived value, tolerating unit suffixes (``1.05x``,
    ``80%_of_gap`` does NOT parse — only a trailing x/% on a clean number)."""
    if isinstance(v, (int, float)):
        return float(v)
    s = str(v).strip()
    for suffix in ("x", "%"):
        if s.endswith(suffix):
            s = s[: -len(suffix)]
    try:
        return float(s)
    except ValueError:
        return None


def _check_invariant(key: str, op: str, expected, derived: Dict[str, str]) -> Tuple[bool, str]:
    if key not in derived:
        return False, f"derived key {key!r} missing"
    actual = derived[key]
    a_num, e_num = _num(actual), _num(expected)
    if op == ">=":
        ok = a_num is not None and e_num is not None and a_num >= e_num
    elif op == "<=":
        ok = a_num is not None and e_num is not None and a_num <= e_num
    elif op == "==":
        if a_num is not None and e_num is not None:
            ok = a_num == e_num
        else:
            ok = str(actual) == str(expected)
    else:  # pragma: no cover — INVARIANTS is static
        raise ValueError(f"unknown invariant operator {op!r}")
    return ok, f"{key}={actual} (want {op} {expected})"


def check_trajectory(
    name: str,
    latest_us: float,
    history: Sequence[float],
    *,
    min_history: int = 3,
    rel_floor: float = 0.30,
    noise_k: float = 4.0,
) -> Tuple[bool, str]:
    """Noise-aware degradation check for one row. Returns (ok, detail)."""
    hist = [h for h in history if isinstance(h, (int, float)) and h >= 0]
    if len(hist) < min_history:
        return True, (f"history={len(hist)} < {min_history}: no baseline yet, "
                      f"pass vacuously")
    baseline = statistics.median(hist)
    if baseline <= 0:
        return True, "non-positive baseline: skipped"
    mad = statistics.median(abs(h - baseline) for h in hist)
    tol = max(rel_floor, noise_k * mad / baseline)
    limit = baseline * (1.0 + tol)
    ok = latest_us <= limit
    return ok, (f"latest={latest_us:.1f}us baseline={baseline:.1f}us "
                f"tol={tol:.0%} limit={limit:.1f}us (n={len(hist)})")


def check_files(
    paths: Sequence[str],
    *,
    min_history: int = 3,
    rel_floor: float = 0.30,
    noise_k: float = 4.0,
    invariants: Optional[Dict[str, List[Tuple[str, str, object]]]] = None,
) -> Tuple[bool, List[Finding]]:
    """Run both checks over every row of every file; invariants resolve
    against the UNION of rows (a gate row may live in any of the files).
    Returns (all ok, findings — violations first)."""
    if invariants is None:
        invariants = INVARIANTS
    findings: List[Finding] = []
    seen_rows: Dict[str, Tuple[str, dict]] = {}
    for path in paths:
        try:
            with open(path) as f:
                rows = json.load(f).get("rows", [])
        except (OSError, json.JSONDecodeError) as e:
            findings.append(Finding(path, "-", "failed_row", False,
                                    f"unreadable trajectory file: {e}"))
            continue
        for r in rows:
            name = r.get("name", "?")
            seen_rows[name] = (path, r)
            us = r.get("us_per_call")
            if not isinstance(us, (int, float)) or us < 0:
                findings.append(Finding(path, name, "failed_row", False,
                                        f"us_per_call={us!r} marks a FAILED bench"))
                continue
            ok, detail = check_trajectory(
                name, float(us), r.get("history", ()),
                min_history=min_history, rel_floor=rel_floor, noise_k=noise_k,
            )
            findings.append(Finding(path, name, "trajectory", ok, detail))
    for name, checks in invariants.items():
        loc = seen_rows.get(name)
        if loc is None:
            findings.append(Finding("-", name, "missing_row", False,
                                    "invariant-gated row missing from every "
                                    "checked trajectory"))
            continue
        path, r = loc
        derived = parse_derived(r.get("derived", ""))
        for key, op, expected in checks:
            ok, detail = _check_invariant(key, op, expected, derived)
            findings.append(Finding(path, name, "invariant", ok, detail))
    findings.sort(key=lambda f: (f.ok, f.file, f.row))
    return all(f.ok for f in findings), findings


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.regress",
        description="Uniform bench-trajectory regression gate over BENCH_*.json",
    )
    ap.add_argument("--check", nargs="+", metavar="FILE", required=True,
                    help="trajectory files to gate (e.g. BENCH_serve.json)")
    ap.add_argument("--min-history", type=int, default=3,
                    help="prior runs required before the trajectory gate arms")
    ap.add_argument("--rel-floor", type=float, default=0.30,
                    help="minimum relative degradation tolerance")
    ap.add_argument("--noise-k", type=float, default=4.0,
                    help="tolerance multiplier on history MAD/baseline")
    ap.add_argument("--json", action="store_true",
                    help="emit findings as JSON instead of text")
    args = ap.parse_args(argv)
    ok, findings = check_files(
        args.check, min_history=args.min_history,
        rel_floor=args.rel_floor, noise_k=args.noise_k,
    )
    if args.json:
        print(json.dumps({"ok": ok, "findings": [f.to_json() for f in findings]},
                         indent=2))
    else:
        for f in findings:
            mark = "ok  " if f.ok else "FAIL"
            print(f"{mark} [{f.check:>10}] {f.row:<36} {f.detail}  ({f.file})")
        n_bad = sum(1 for f in findings if not f.ok)
        print(f"# {len(findings)} checks, {n_bad} violations -> "
              f"{'PASS' if ok else 'FAIL'}")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())

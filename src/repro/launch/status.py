"""Terminal status view: SLO compliance, burn rates, alerts, replica health.

Renders one human-readable panel from the observability artifacts the rest
of the stack already produces — no new measurement, just presentation:

* a :class:`~repro.obs.MetricsRegistry` snapshot (live object, or a line
  of the ``--metrics-jsonl`` time series),
* :meth:`SLOEvaluator.status` (per-SLO state + per-window burn rates),
* the JSONL alert stream (``--alerts-jsonl``),
* the router's per-replica records.

Used two ways:

* **in-process** — ``launch/serve.py --slo`` prints the final panel via
  :func:`render_status`;
* **offline / follow** —
  ``python -m repro.launch.status --metrics-jsonl serve-metrics.jsonl
  [--alerts-jsonl serve-alerts.jsonl] [--follow]`` renders the newest
  sample of a (possibly still growing) series; ``--follow`` re-renders as
  lines append — a poor man's dashboard over two flat files.  Offline, the
  alert state per SLO is reconstructed from the LAST event in the alert
  stream (the state machine's transitions are total, so its latest
  transition IS its current state).
"""

from __future__ import annotations

import argparse
import json
import sys
import time

_STATE_GLYPH = {"ok": "·", "warn": "▲", "page": "●"}


def _fmt_val(v) -> str:
    if isinstance(v, float):
        if v != v:                       # NaN
            return "-"
        return f"{v:.4g}"
    return str(v)


def _hist_line(name: str, h: dict) -> str:
    return (f"  {name:<38} n={h.get('count', 0):<8} "
            f"p50={h.get('p50_ms', 0.0):>8.3f}ms "
            f"p95={h.get('p95_ms', 0.0):>8.3f}ms "
            f"p99={h.get('p99_ms', 0.0):>8.3f}ms")


def render_status(metrics: dict | None = None, slo_status: dict | None = None,
                  alerts: list | None = None, replicas: list | None = None,
                  title: str = "serving status") -> str:
    """One status panel as a string (caller prints — testable, pipeable)."""
    lines = [f"== {title} =="]
    if slo_status:
        lines.append("-- SLOs --")
        for name, st in sorted(slo_status.items()):
            glyph = _STATE_GLYPH.get(st.get("state", "ok"), "?")
            burns = st.get("burns", {}) or {}
            burn_s = " ".join(
                f"{w}={'-' if b is None else f'{b:.2f}x'}"
                for w, b in sorted(burns.items())) or "-"
            lines.append(
                f"  {glyph} {name:<22} [{st.get('state', '?'):>4}] "
                f"value={_fmt_val(st.get('value')):<10} "
                f"objective={_fmt_val(st.get('objective')):<10} burn {burn_s}")
    if alerts:
        lines.append(f"-- alerts ({len(alerts)} events, newest last) --")
        for ev in alerts[-8:]:
            lines.append(
                f"  {ev.get('severity', '?'):>4} <- {ev.get('previous', '?'):<4} "
                f"{ev.get('slo', '?'):<22} {ev.get('message', '')}")
    if replicas:
        lines.append("-- replicas --")
        for rep in replicas:
            lines.append(
                f"  #{rep.get('id', '?')} {rep.get('state', '?'):<8} "
                f"gen={rep.get('generation', '?')} "
                f"worker_alive={rep.get('worker_alive', '?')} "
                f"consecutive_failures={rep.get('consecutive_failures', 0)}")
    if metrics:
        hists = {k: v for k, v in metrics.items()
                 if isinstance(v, dict) and "p99_ms" in v}
        scalars = {k: v for k, v in metrics.items()
                   if isinstance(v, (int, float))}
        if hists:
            lines.append("-- latency --")
            for k in sorted(hists):
                lines.append(_hist_line(k, hists[k]))
        if scalars:
            lines.append("-- counters / gauges --")
            # freshness + lag + health first: the signals the SLOs watch
            front = [k for k in sorted(scalars)
                     if "generation_age" in k or "lag" in k or "healthy" in k]
            rest = [k for k in sorted(scalars) if k not in front]
            for k in front + rest:
                lines.append(f"  {k:<44} {_fmt_val(scalars[k])}")
    return "\n".join(lines)


def _last_metrics_sample(path: str) -> tuple[float | None, dict | None]:
    last = None
    try:
        with open(path) as f:
            for line in f:
                line = line.strip()
                if line:
                    last = line
    except OSError:
        return None, None
    if last is None:
        return None, None
    try:
        rec = json.loads(last)
    except json.JSONDecodeError:
        return None, None      # a partially-written tail line: wait for more
    return rec.get("t"), rec.get("metrics")


def _read_alerts(path: str) -> list[dict]:
    events: list[dict] = []
    try:
        with open(path) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    events.append(json.loads(line))
                except json.JSONDecodeError:
                    continue
    except OSError:
        pass
    return events


def slo_status_from_alerts(events: list[dict]) -> dict:
    """Reconstruct each SLO's current state from its newest transition —
    the offline stand-in for a live ``SLOEvaluator.status()``."""
    out: dict = {}
    for ev in events:       # in file order: the last event per spec wins
        out[ev.get("slo", "?")] = {
            "state": ev.get("severity", "?"),
            "signal": ev.get("signal", ""),
            "kind": ev.get("kind", ""),
            "value": ev.get("value"),
            "objective": ev.get("objective"),
            "burns": {f"{ev.get('window_s', 0):g}s": ev.get("burn_rate")},
        }
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.launch.status",
        description="Render SLO/alert/metrics status from serve's JSONL streams",
    )
    ap.add_argument("--metrics-jsonl", required=True, metavar="FILE",
                    help="registry time series written by serve --metrics-jsonl")
    ap.add_argument("--alerts-jsonl", default="", metavar="FILE",
                    help="alert stream written by serve --slo --alerts-jsonl")
    ap.add_argument("--follow", action="store_true",
                    help="re-render as the series grows (ctrl-c to stop)")
    ap.add_argument("--interval", type=float, default=1.0,
                    help="refresh period with --follow (seconds)")
    args = ap.parse_args(argv)

    def render_once() -> bool:
        t, metrics = _last_metrics_sample(args.metrics_jsonl)
        if metrics is None:
            print(f"[status] no samples in {args.metrics_jsonl} yet",
                  file=sys.stderr)
            return False
        alerts = _read_alerts(args.alerts_jsonl) if args.alerts_jsonl else []
        age = "" if t is None else f" (sample {time.time() - t:.1f}s old)"
        print(render_status(metrics, slo_status_from_alerts(alerts) or None,
                            alerts or None, title=f"serving status{age}"))
        return True

    if not args.follow:
        return 0 if render_once() else 1
    try:
        while True:
            render_once()
            time.sleep(args.interval)
    except KeyboardInterrupt:
        pass
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""Exact-basket LRU result cache for the serving gateway (DESIGN.md §10).

Keys are ``(packed basket words, top_k, generation)``: the packed uint32
bitset is the canonical basket identity (two id-lists with the same item set
hash identically), ``top_k`` because a smaller k is served as a different
response object, and the rulebook **generation** so a hot-swap can never
serve a stale entry — post-swap lookups use the new generation number and
simply miss; old-generation entries age out of the LRU (or are dropped
eagerly via :meth:`evict_generation`).

Values are ``(items, scores, generation, bucket)`` tuples — the *same*
arrays a dispatch produced, so a hit is bit-identical to the miss that
filled it (bucket included: the hit reports the jit bucket that computed it).
Thread-safe: ``get``/``put`` run from client threads and the batcher worker
concurrently.
"""

from __future__ import annotations

import threading
from collections import OrderedDict

import numpy as np


def basket_key(packed_row: np.ndarray, top_k: int, generation: int) -> tuple:
    """Cache key for one packed basket row: (words-bytes, top_k, generation)."""
    return (np.ascontiguousarray(packed_row, np.uint32).tobytes(), int(top_k), int(generation))


class BasketCache:
    """Bounded LRU over exact baskets with hit/miss accounting.

    ``capacity <= 0`` disables the cache (every ``get`` misses, ``put`` is a
    no-op) — the gateway wiring stays unconditional."""

    def __init__(self, capacity: int = 4096):
        self.capacity = int(capacity)
        self._lock = threading.Lock()
        self._entries: OrderedDict[tuple, tuple] = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def get(self, key: tuple, count: bool = True):
        """The cached ``(items, scores, generation, bucket)`` entry or
        ``None``. ``count=False`` probes without touching the hit/miss
        counters — for callers (the gateway) that only want to account
        probes whose request is actually admitted; pair it with
        :meth:`record`."""
        with self._lock:
            entry = self._entries.get(key)
            if entry is None:
                if count:
                    self.misses += 1
                return None
            self._entries.move_to_end(key)
            if count:
                self.hits += 1
            return entry

    def record(self, hit: bool) -> None:
        """Count a probe outcome separately from :meth:`get`."""
        with self._lock:
            if hit:
                self.hits += 1
            else:
                self.misses += 1

    def put(self, key: tuple, entry: tuple) -> None:
        if self.capacity <= 0:
            return
        with self._lock:
            self._entries[key] = entry
            self._entries.move_to_end(key)
            while len(self._entries) > self.capacity:
                self._entries.popitem(last=False)
                self.evictions += 1

    def evict_generation(self, generation: int) -> int:
        """Drop every entry answered by ``generation``; returns the count.
        Optional eager cleanup after a hot-swap (stale entries are already
        unreachable — their keys carry the old generation)."""
        with self._lock:
            stale = [k for k, v in self._entries.items() if v[2] == generation]
            for k in stale:
                del self._entries[k]
            return len(stale)

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def snapshot(self) -> dict:
        with self._lock:
            size = len(self._entries)
        return {
            "size": size,
            "capacity": self.capacity,
            "hits": self.hits,
            "misses": self.misses,
            "evictions": self.evictions,
            "hit_rate": self.hit_rate,
        }

"""repro — Map/Reduce Apriori (ACIJ 2012) as a production JAX/TPU framework.

Layers:
  core/         the paper's contribution: distributed level-wise Apriori,
                SON two-phase mining, streamed out-of-core driver, rules
  data/         transaction pipelines + the on-disk shard store
  kernels/      Pallas TPU kernels (support counting, rule matching)
  distributed/  fault tolerance: mining checkpoints, retryable partitions,
                serving supervision
  serving/      rulebook -> batch engine -> online gateway
  launch/       mesh, dry-run, mine/serve drivers
"""

__version__ = "1.0.0"

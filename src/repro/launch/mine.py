"""End-to-end mining driver — the paper's job, CLI form.

  PYTHONPATH=src python -m repro.launch.mine --transactions 20000 --items 256 \
      --min-support 0.02 --max-k 5
  # multi-device (the paper's multi-node mode):
  PYTHONPATH=src python -m repro.launch.mine --host-devices 8 --mesh 4x2 ...
  # mine AND emit a servable rulebook artifact (serving/rulebook.py):
  PYTHONPATH=src python -m repro.launch.mine ... --rulebook rb.npz \
      --min-confidence 0.6 --rule-score confidence --max-rules 8192
  # out-of-core: ingest to an on-disk store, then stream-mine it
  # (host RAM bounded by --stream-chunk-rows, DESIGN.md §9):
  PYTHONPATH=src python -m repro.launch.mine --transactions 2000000 \
      --store /data/quest_2m --ingest --stream-chunk-rows 8192
  # fault-tolerant: checkpoint every 64 chunks; after a crash, rerun with
  # --resume for a dict-identical result (DESIGN.md §11):
  PYTHONPATH=src python -m repro.launch.mine ... --store /data/quest_2m \
      --checkpoint-every 64 [--resume]
  # retryable SON phase 1 over the store's shards:
  PYTHONPATH=src python -m repro.launch.mine ... --store /data/quest_2m \
      --algo son --max-partition-retries 2
  # incremental (DESIGN.md §15): seed the count cache once, then each later
  # run folds ONLY the rows appended since it (dict-identical result):
  PYTHONPATH=src python -m repro.launch.mine ... --store /data/quest_2m \
      --count-cache
  PYTHONPATH=src python -m repro.launch.mine ... --store /data/quest_2m --delta
  # observability (DESIGN.md §13): live per-level progress + Hadoop-style
  # job counters + a perfetto-loadable trace of every mining phase:
  PYTHONPATH=src python -m repro.launch.mine ... --store /data/quest_2m \
      --progress --trace-out mine-trace.json --metrics-out mine-metrics.json

``--rulebook PATH`` compiles the mined itemsets into the packed-bitset rule
columns the Pallas rule-match serving engine consumes (DESIGN.md §8) and
saves them as one ``.npz``; serve it with ``examples/serve_rules.py``.

``--store PATH`` switches the driver to the out-of-core path: the synthetic
DB is ingested CHUNKED into a packed-shard store at PATH (``--ingest``
forces re-ingest; otherwise an existing store is reused) and mined with the
streaming Map/Reduce driver — the dense matrix is never materialized.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time


def static_count_cost(cfg, mesh, rows: int, num_items: int, k_cands: int) -> dict:
    """Static roofline of ONE streamed count dispatch at the mined shapes.

    Lowers the jnp count step (the dense reference decomposition — a shape-
    faithful proxy for whatever impl actually ran) at (rows x num_items)
    transactions against the LARGEST candidate bucket the mine dispatched,
    and walks the compiled HLO (launch.hlo_analysis). Paired with the
    measured ``count_kernel`` phase seconds this turns padding + dispatch
    overhead into a reported ratio instead of a vibe.
    """
    import dataclasses

    import jax
    import jax.numpy as jnp

    from repro.core.apriori import make_count_step
    from repro.launch import hlo_analysis
    from repro.launch.roofline import roofline_terms

    jcfg = dataclasses.replace(cfg, count_impl="jnp", representation="dense")
    step = make_count_step(mesh, jcfg)
    t_sds = jax.ShapeDtypeStruct((rows, num_items), jnp.int8)
    c_sds = jax.ShapeDtypeStruct((k_cands, num_items), jnp.int8)
    l_sds = jax.ShapeDtypeStruct((k_cands,), jnp.int32)
    fn = step.__wrapped__ if hasattr(step, "__wrapped__") else step
    compiled = jax.jit(fn).lower(t_sds, c_sds, l_sds).compile()
    hlo = hlo_analysis.summarize(compiled.as_text())
    rl = roofline_terms(hlo["flops"], hlo["hbm_bytes"], hlo["collective_bytes"])
    # the miner's useful-FLOPs model: K containment tests per row, each a
    # words-per-row AND+popcount pass over packed uint32 bitsets
    useful_flops = 2.0 * rows * num_items * k_cands / 256
    return {
        "rows_per_dispatch": rows,
        "candidate_rows": k_cands,
        "flops_per_dispatch": hlo["flops"],
        "hbm_bytes_per_dispatch": hlo["hbm_bytes"],
        "roofline_s_per_dispatch": rl.bound_s,
        "roofline_dominant": rl.dominant,
        "useful_flops_per_dispatch": useful_flops,
        "useful_flops_ratio": useful_flops / max(hlo["flops"], 1.0),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--transactions", type=int, default=20_000)
    ap.add_argument("--items", type=int, default=256)
    ap.add_argument("--avg-len", type=float, default=10.0)
    ap.add_argument("--min-support", type=float, default=0.02)
    ap.add_argument("--max-k", type=int, default=6)
    ap.add_argument("--impl", default="auto", choices=["auto", "jnp", "pallas", "pallas_interpret"])
    ap.add_argument("--representation", default="dense", choices=["dense", "packed"],
                    help="device transaction store: dense int8 or packed uint32 bitsets")
    ap.add_argument("--algo", default="levelwise", choices=["levelwise", "son", "naive_paper"])
    ap.add_argument("--partitions", type=int, default=8, help="SON phase-1 partitions")
    ap.add_argument("--host-devices", type=int, default=0)
    ap.add_argument("--mesh", default="", help="e.g. 4x2 = data x model")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--rules", action="store_true", help="extract association rules")
    ap.add_argument("--min-confidence", type=float, default=0.6)
    ap.add_argument("--rulebook", default="", metavar="PATH",
                    help="compile + save a servable rulebook artifact (.npz)")
    ap.add_argument("--rule-score", default="confidence", choices=["confidence", "lift"],
                    help="rulebook serving score column")
    ap.add_argument("--max-rules", type=int, default=None,
                    help="truncate the rulebook to the top-scoring rules")
    ap.add_argument("--checkpoint-every", type=int, default=0, metavar="CHUNKS",
                    help="streamed mining: persist a resumable checkpoint next to "
                         "the store manifest every N chunks (0 = level "
                         "boundaries only when --resume is possible, i.e. off)")
    ap.add_argument("--resume", action="store_true",
                    help="resume the streamed mine from the newest committed "
                         "checkpoint in the store's checkpoint dir")
    ap.add_argument("--max-partition-retries", type=int, default=None, metavar="N",
                    help="SON streamed phase 1: run shard mappers through the "
                         "retrying executor with N re-executions per partition")
    ap.add_argument("--count-cache", action="store_true",
                    help="SON streamed mine that ALSO persists the pre-prune "
                         "phase-2 union counts into the store manifest as the "
                         "incremental count cache (DESIGN.md §15, the seed "
                         "for --delta); needs --store")
    ap.add_argument("--delta", action="store_true",
                    help="incremental mine: fold rows appended since the "
                         "count cache generation into it and re-verify only "
                         "novel candidates (core.incremental.mine_delta; "
                         "full-scan fallback on a cold/invalid cache or an "
                         "oversized delta — the report says which); needs "
                         "--store")
    ap.add_argument("--store", default="", metavar="DIR",
                    help="on-disk transaction store: mine out-of-core via the "
                         "streaming driver (ingested here if absent)")
    ap.add_argument("--ingest", action="store_true",
                    help="force (re-)ingest of the synthetic DB into --store")
    ap.add_argument("--stream-chunk-rows", type=int, default=8192,
                    help="rows per streamed chunk (bounds host RAM during mining)")
    ap.add_argument("--shard-rows", type=int, default=8192,
                    help="rows per on-disk shard at ingest (= SON partition size)")
    ap.add_argument("--progress", action="store_true",
                    help="streamed mining: live per-level progress lines with "
                         "rows/s throughput and ETA (stderr)")
    ap.add_argument("--trace-out", default="", metavar="PATH",
                    help="streamed mining: write a Chrome trace-event JSON of "
                         "the mining phase spans (load in ui.perfetto.dev)")
    ap.add_argument("--metrics-out", default="", metavar="PATH",
                    help="streamed mining: write the Hadoop-style job counters "
                         "plus the static roofline cost of the count step as JSON")
    args = ap.parse_args()

    if args.host_devices and "XLA_FLAGS" not in os.environ:
        os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={args.host_devices}"
        os.execv(sys.executable, [sys.executable] + sys.argv)

    import jax
    import numpy as np

    from repro.core.apriori import AprioriConfig, mine
    from repro.core.rules import extract_rules
    from repro.core.son import mine_son
    from repro.data.synthetic import QuestConfig, gen_transactions

    mesh = None
    data_axes, model_axis = ("data",), None
    if args.mesh:
        from repro.launch.mesh import make_auto_mesh

        dd, mm = (int(x) for x in args.mesh.split("x"))
        mesh = make_auto_mesh((dd, mm), ("data", "model"))
        model_axis = "model"

    qcfg = QuestConfig(
        num_transactions=args.transactions, num_items=args.items,
        avg_len=args.avg_len, seed=args.seed)

    db = store = None
    if args.store:
        from repro.data.store import ingest_quest, open_store

        if args.ingest or not os.path.exists(os.path.join(args.store, "manifest.json")):
            print(f"[mine] ingesting {args.transactions} x {args.items} (chunked) "
                  f"-> {args.store} ...")
            store = ingest_quest(qcfg, args.store, shard_rows=args.shard_rows,
                                 chunk_rows=args.stream_chunk_rows)
        else:
            store = open_store(args.store)
        print(f"[mine] store: n={store.num_transactions} items={store.num_items} "
              f"shards={store.num_partitions}")
    else:
        print(f"[mine] generating {args.transactions} transactions x {args.items} items ...")
        db = gen_transactions(qcfg)

    cfg = AprioriConfig(
        min_support=args.min_support, max_k=args.max_k, count_impl=args.impl,
        representation=args.representation,
        data_axes=data_axes, model_axis=model_axis,
        use_naive_paper_map=(args.algo == "naive_paper"),
    )

    if (args.checkpoint_every or args.resume) and store is None:
        ap.error("--checkpoint-every/--resume need the streamed driver: add --store DIR")
    if (args.count_cache or args.delta) and store is None:
        ap.error("--count-cache/--delta need the on-disk store: add --store DIR")
    if args.max_partition_retries is not None and (
        store is None or (args.algo != "son" and not (args.count_cache or args.delta))
    ):
        ap.error("--max-partition-retries needs --store DIR and --algo son "
                 "(or --count-cache/--delta, which run SON phase 1 inside)")
    if (args.progress or args.trace_out or args.metrics_out) and store is None:
        ap.error("--progress/--trace-out/--metrics-out instrument the streamed "
                 "driver: add --store DIR")

    obs = tracer = None
    if args.progress or args.trace_out or args.metrics_out:
        from repro.obs import MetricsRegistry, MiningObs, MiningProgress, Tracer

        tracer = Tracer(sample_rate=1.0) if args.trace_out else None
        progress = (MiningProgress(total_rows=store.num_transactions)
                    if args.progress else None)
        obs = MiningObs(registry=MetricsRegistry(), tracer=tracer,
                        progress=progress)

    t0 = time.time()
    if store is not None:
        from repro.core.streaming import mine_son_streamed, mine_streamed

        fault = None
        if args.max_partition_retries is not None:
            from repro.distributed.fault_tolerance import FaultConfig

            fault = FaultConfig(max_retries=args.max_partition_retries)
        if args.delta:
            import dataclasses as _dc

            from repro.core import incremental as inc

            res, rep = inc.mine_delta(
                store, cfg, mesh=mesh, chunk_rows=args.stream_chunk_rows,
                fault=fault, checkpoint=True, resume=args.resume, obs=obs)
            print(f"[mine] delta report: {json.dumps(_dc.asdict(rep))}")
        elif args.count_cache:
            from repro.core import incremental as inc

            res, cache = inc.build_count_cache(
                store, cfg, mesh=mesh, chunk_rows=args.stream_chunk_rows,
                fault=fault, obs=obs)
            print(f"[mine] count cache seq={cache.seq} covering n={cache.n} "
                  f"({cache.candidate_total()} cached candidates over levels "
                  f"{sorted(cache.levels)}) -> {store.path}")
        elif args.algo == "son":
            res = mine_son_streamed(store, cfg, mesh=mesh,
                                    chunk_rows=args.stream_chunk_rows, fault=fault,
                                    obs=obs)
            if res.fault_report is not None:
                print(f"[mine] SON fault report: {json.dumps(res.fault_report.to_json())}")
        else:
            use_ckpt = bool(args.checkpoint_every) or args.resume
            if args.resume:
                print(f"[mine] resuming from {store.checkpoint_path} (if a committed "
                      "checkpoint exists)")
            res = mine_streamed(store, cfg, mesh=mesh,
                                chunk_rows=args.stream_chunk_rows,
                                checkpoint=True if use_ckpt else None,
                                checkpoint_every_chunks=args.checkpoint_every,
                                resume=args.resume, obs=obs)
    elif args.algo == "son":
        res = mine_son(db, cfg, mesh=mesh, num_partitions=args.partitions)
    else:
        res = mine(db, cfg, mesh=mesh)
    dt = time.time() - t0

    print(f"[mine] {dt:.2f}s; min_count={res.min_count}")
    for k in sorted(res.levels):
        sets, sup = res.levels[k]
        print(f"  level {k}: {sets.shape[0]:6d} frequent itemsets "
              f"(max support {int(sup.max()) if sup.size else 0})")
    print(f"  total: {res.total_frequent}")

    if args.rules:
        rules = extract_rules(res, min_confidence=args.min_confidence, max_rules=20)
        print(f"[rules] top {len(rules)} by confidence:")
        for r in rules:
            print(f"  {r.antecedent} -> {r.consequent}  conf={r.confidence:.3f} "
                  f"supp={r.support:.4f} lift={r.lift:.2f}")
    if args.rulebook:
        from repro.serving.rulebook import compile_rulebook

        rb = compile_rulebook(
            res, min_confidence=args.min_confidence, score=args.rule_score,
            max_rules=args.max_rules, num_items=args.items,
        )
        rb.save(args.rulebook)
        print(f"[rulebook] {rb.num_rules} rules ({rb.num_rows} padded rows, "
              f"score={rb.score_kind}) -> {args.rulebook}")

    if obs is not None:
        obs.finish()
        if args.trace_out:
            tracer.save_chrome(args.trace_out)
            print(f"[obs] wrote {len(tracer.spans())} spans -> {args.trace_out} "
                  "(load in ui.perfetto.dev)", file=sys.stderr)
        if args.metrics_out:
            counters = obs.counters()
            out = {"seconds": dt, "counters": counters}
            k_cands = int(counters.get("mine_max_candidate_bucket", 0))
            measured = counters.get('mine_phase_seconds{phase="count_kernel"}', 0.0)
            dispatches = int(counters.get("mine_chunks_streamed", 0))
            if k_cands > 0:
                try:
                    static = static_count_cost(
                        cfg, mesh, min(args.stream_chunk_rows, store.num_transactions),
                        store.num_items, k_cands)
                except Exception as e:  # noqa: BLE001 — the estimate is advisory
                    static = {"error": f"{type(e).__name__}: {e}"}
                else:
                    static["count_dispatches"] = dispatches
                    static["measured_count_kernel_s"] = measured
                    ideal = static["roofline_s_per_dispatch"] * max(dispatches, 1)
                    # >> 1 on CPU; the interesting signal is its TREND as
                    # padding/bucketing knobs move, not its absolute value
                    static["measured_vs_roofline"] = measured / max(ideal, 1e-12)
                out["static_cost"] = static
            with open(args.metrics_out, "w") as f:
                json.dump(out, f, indent=2)
            print(f"[obs] wrote job counters -> {args.metrics_out}", file=sys.stderr)

    print(json.dumps({"seconds": dt, "total_frequent": res.total_frequent,
                      "levels": {k: int(v[0].shape[0]) for k, v in res.levels.items()}}))


if __name__ == "__main__":
    main()

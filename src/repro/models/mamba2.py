"""Mamba-2 (SSD) block — chunked state-space dual form (arXiv:2405.21060).

Train/prefill uses the chunk decomposition: intra-chunk causal (C·Bᵀ ⊙ decay)
matmuls (MXU-friendly) + inter-chunk state propagation via an associative
scan over chunk states (log-depth). Decode is the exact linear recurrence
``S ← a·S + dt·B⊗x ; y = C·S``. A naive per-step scan oracle lives here too
for the equivalence tests.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, rmsnorm_apply


def _dims(cfg):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    return d_inner, n_heads, s.head_dim, s.state_dim, s.n_groups


def mamba2_init(key, cfg):
    s = cfg.ssm
    d = cfg.d_model
    d_inner, h, p_dim, n, g = _dims(cfg)
    conv_dim = d_inner + 2 * g * n
    ks = jax.random.split(key, 4)
    return {
        # order: [z (d_inner) | xBC (conv_dim) | dt (h)]
        "in_proj": dense_init(ks[0], (d, 2 * d_inner + 2 * g * n + h)),
        "conv_w": jax.random.normal(ks[1], (s.conv_width, conv_dim), jnp.float32) * 0.2,
        "conv_b": jnp.zeros((conv_dim,), jnp.float32),
        "a_log": jnp.zeros((h,), jnp.float32),          # A = -exp(a_log) = -1
        "d_skip": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm": {"scale": jnp.ones((d_inner,), jnp.float32)},
        "out_proj": dense_init(ks[3], (d_inner, d)),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv, width W. x: (B, L, C); w: (W, C)."""
    w_ = w.astype(x.dtype)
    width = w_.shape[0]
    pad = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(pad[:, i : i + x.shape[1], :] * w_[i] for i in range(width))
    return out + b.astype(x.dtype)


def _split_proj(p, x, cfg):
    d_inner, h, p_dim, n, g = _dims(cfg)
    from repro.models.shard_ctx import weight_use

    zxbcdt = x @ weight_use(p["in_proj"].astype(x.dtype))
    z = zxbcdt[..., :d_inner]
    xbc = zxbcdt[..., d_inner : d_inner + d_inner + 2 * g * n]
    dt = zxbcdt[..., -h:]
    return z, xbc, dt


def _conv_split(xbc, cfg):
    d_inner, h, p_dim, n, g = _dims(cfg)
    xi = xbc[..., :d_inner]
    b_ = xbc[..., d_inner : d_inner + g * n]
    c_ = xbc[..., d_inner + g * n :]
    return xi, b_, c_


def mamba2_apply(p, x, cfg):
    """Chunked SSD forward. x: (B, L, D); L is padded internally to the chunk
    multiple (causality makes the zero tail inert for the kept positions)."""
    s = cfg.ssm
    d_inner, h, p_dim, n, g = _dims(cfg)
    bsz, l_in, _ = x.shape
    q = s.chunk
    pad = (-l_in) % q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    l = l_in + pad
    nc = l // q

    z, xbc, dt = _split_proj(p, x, cfg)
    xbc = jax.nn.silu(_causal_conv(xbc, p["conv_w"], p["conv_b"]))
    xi, b_, c_ = _conv_split(xbc, cfg)

    xh = xi.reshape(bsz, l, h, p_dim)
    bh = b_.reshape(bsz, l, g, n)
    ch = c_.reshape(bsz, l, g, n)
    # broadcast groups over heads (g divides h)
    rep = h // g
    bh = jnp.repeat(bh, rep, axis=2)  # (B, L, H, N)
    ch = jnp.repeat(ch, rep, axis=2)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B, L, H)
    a = -jnp.exp(p["a_log"])                                     # (H,)
    log_decay = dt * a[None, None, :]                            # (B, L, H)  (<= 0)

    # ---- chunk views ----
    xc = xh.reshape(bsz, nc, q, h, p_dim)
    bc = bh.reshape(bsz, nc, q, h, n)
    cc = ch.reshape(bsz, nc, q, h, n)
    dtc = dt.reshape(bsz, nc, q, h)
    ld = log_decay.reshape(bsz, nc, q, h)
    cum = jnp.cumsum(ld, axis=2)                                 # within-chunk cumulative

    # ---- intra-chunk: att[q,k] = (C_q·B_k) * exp(cum_q - cum_k) * dt_k, q>=k ----
    scores = jnp.einsum("bcqhn,bckhn->bchqk", cc, bc, preferred_element_type=jnp.float32)
    dq = cum.transpose(0, 1, 3, 2)                               # (B, nc, H, Q)
    gap = dq[..., :, None] - dq[..., None, :]                    # (B, nc, H, Q, K)
    causal = jnp.tril(jnp.ones((q, q), bool))
    att = scores * jnp.where(causal, jnp.exp(gap), 0.0)
    att = att * dtc.transpose(0, 1, 3, 2)[..., None, :]          # * dt_k
    y_intra = jnp.einsum("bchqk,bckhp->bcqhp", att.astype(xc.dtype), xc,
                         preferred_element_type=jnp.float32)

    # ---- chunk states: S_c = Σ_k exp(cum_last - cum_k)·dt_k·B_k⊗x_k ----
    last = cum[:, :, -1:, :]                                     # (B, nc, 1, H)
    w_k = jnp.exp(last - cum) * dtc                              # (B, nc, Q, H)
    s_c = jnp.einsum("bcqhn,bcqhp,bcqh->bchnp", bc, xc, w_k.astype(xc.dtype),
                     preferred_element_type=jnp.float32)         # (B, nc, H, N, P)
    chunk_decay = jnp.exp(last[:, :, 0, :])                      # (B, nc, H)

    # ---- inter-chunk: associative scan  (d, S) ∘ (d', S') = (dd', S·d' + S') ----
    def combine(x1, x2):
        d1, s1 = x1
        d2, s2 = x2
        return d1 * d2, s1 * d2[..., None, None] + s2

    dec_scan, s_scan = jax.lax.associative_scan(
        combine, (chunk_decay.swapaxes(0, 1), s_c.swapaxes(0, 1))
    )  # scanned over nc (leading axis)
    s_inc = s_scan.swapaxes(0, 1)                                # inclusive states
    # exclusive prefix: state entering each chunk
    s_prev = jnp.concatenate([jnp.zeros_like(s_inc[:, :1]), s_inc[:, :-1]], axis=1)

    y_inter = jnp.einsum("bcqhn,bchnp,bcqh->bcqhp", cc, s_prev.astype(cc.dtype),
                         jnp.exp(cum).astype(cc.dtype), preferred_element_type=jnp.float32)

    y = (y_intra + y_inter).reshape(bsz, l, h, p_dim)
    y = y + xh.astype(y.dtype) * p["d_skip"][None, None, :, None]
    y = y.reshape(bsz, l, d_inner).astype(x.dtype)
    y = rmsnorm_apply(p["norm"], y * jax.nn.silu(z))
    from repro.models.shard_ctx import weight_use as _wu
    out = y @ _wu(p["out_proj"].astype(x.dtype), out_side=True)
    return out[:, :l_in]


def mamba2_apply_naive(p, x, cfg):
    """Oracle: exact per-step recurrence via lax.scan (for tests)."""
    d_inner, h, p_dim, n, g = _dims(cfg)
    bsz, l, _ = x.shape
    z, xbc, dt = _split_proj(p, x, cfg)
    xbc = jax.nn.silu(_causal_conv(xbc, p["conv_w"], p["conv_b"]))
    xi, b_, c_ = _conv_split(xbc, cfg)
    xh = xi.reshape(bsz, l, h, p_dim)
    rep = h // g
    bh = jnp.repeat(b_.reshape(bsz, l, g, n), rep, axis=2)
    ch = jnp.repeat(c_.reshape(bsz, l, g, n), rep, axis=2)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])

    def step(state, inp):
        x_t, b_t, c_t, dt_t = inp  # (B,H,P), (B,H,N), (B,H,N), (B,H)
        decay = jnp.exp(dt_t * a[None, :])
        state = state * decay[..., None, None] + jnp.einsum(
            "bhn,bhp,bh->bhnp", b_t, x_t, dt_t.astype(x_t.dtype))
        y_t = jnp.einsum("bhn,bhnp->bhp", c_t, state)
        return state, y_t

    s0 = jnp.zeros((bsz, h, n, p_dim), jnp.float32)
    xs = (xh.swapaxes(0, 1), bh.swapaxes(0, 1), ch.swapaxes(0, 1), dt.swapaxes(0, 1))
    _, ys = jax.lax.scan(step, s0, xs)
    y = ys.swapaxes(0, 1)  # (B, L, H, P)
    y = y + xh.astype(y.dtype) * p["d_skip"][None, None, :, None]
    y = y.reshape(bsz, l, d_inner).astype(x.dtype)
    y = rmsnorm_apply(p["norm"], y * jax.nn.silu(z))
    return y @ p["out_proj"].astype(x.dtype)


# ----------------------------------------------------------------- decode ----
def mamba2_init_state(cfg, batch: int, dtype):
    s = cfg.ssm
    d_inner, h, p_dim, n, g = _dims(cfg)
    conv_dim = d_inner + 2 * g * n
    return {
        "conv": jnp.zeros((batch, s.conv_width - 1, conv_dim), dtype),
        "ssm": jnp.zeros((batch, h, n, p_dim), jnp.float32),
    }


def mamba2_decode(p, x, cfg, state):
    """x: (B, 1, D) -> (y (B,1,D), new state). Exact recurrence step."""
    s = cfg.ssm
    d_inner, h, p_dim, n, g = _dims(cfg)
    bsz = x.shape[0]
    z, xbc, dt = _split_proj(p, x, cfg)
    # conv over [state_window | new]: take the last output position
    window = jnp.concatenate([state["conv"], xbc], axis=1)       # (B, W, C)
    w_ = p["conv_w"].astype(x.dtype)
    conv_out = (window * w_[None]).sum(1, keepdims=True) + p["conv_b"].astype(x.dtype)
    xbc1 = jax.nn.silu(conv_out)
    xi, b_, c_ = _conv_split(xbc1, cfg)
    x_t = xi.reshape(bsz, h, p_dim)
    rep = h // g
    b_t = jnp.repeat(b_.reshape(bsz, g, n), rep, axis=1)
    c_t = jnp.repeat(c_.reshape(bsz, g, n), rep, axis=1)
    dt_t = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])
    decay = jnp.exp(dt_t * a[None, :])
    ssm = state["ssm"] * decay[..., None, None] + jnp.einsum(
        "bhn,bhp,bh->bhnp", b_t.astype(jnp.float32), x_t.astype(jnp.float32), dt_t)
    y = jnp.einsum("bhn,bhnp->bhp", c_t.astype(jnp.float32), ssm)
    y = y + x_t.astype(y.dtype) * p["d_skip"][None, :, None]
    y = y.reshape(bsz, 1, d_inner).astype(x.dtype)
    y = rmsnorm_apply(p["norm"], y * jax.nn.silu(z))
    new_state = {"conv": window[:, 1:], "ssm": ssm}
    from repro.models.shard_ctx import weight_use as _wu
    return y @ _wu(p["out_proj"].astype(x.dtype), out_side=True), new_state

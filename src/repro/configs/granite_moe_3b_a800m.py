"""Granite-MoE-3B-A800M [hf:ibm-granite] — fine-grained MoE 40e top-8.
40 experts pad to 48 for 16-way expert sharding (DESIGN.md §4)."""

from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    num_layers=32,
    d_model=1536,
    num_heads=24,
    num_kv_heads=8,
    d_ff=512,
    vocab_size=49155,
    moe=MoEConfig(num_experts=40, top_k=8, d_ff_expert=512, padded_experts=48),
)

"""On-disk partitioned transaction store — the repo's HDFS.

The paper's substrate is a DB *split into HDFS blocks*: no node ever holds
the whole dataset, mappers stream their block, and the namenode only keeps
metadata. This module is that substrate for the miner: a directory of
fixed-row **shards** of packed uint32 bitsets (DESIGN.md §4 layout, 1 bit
per cell) saved as ``.npy`` files, plus a JSON **manifest** recording the
logical shape (``n``, ``num_items``), the per-shard row counts, and a
layout version. Shards open memory-mapped, so reading a chunk touches only
that chunk's pages — host peak RSS during mining is bounded by the chunk
size, not the dataset size (DESIGN.md §9).

Ingest paths (all route through :class:`StoreWriter`, which buffers at most
one shard of rows):

  * :func:`ingest_dense`        — an in-memory {0,1} matrix (tests, small DBs)
  * :func:`ingest_lists`        — transaction lists of item ids
  * :func:`ingest_chunks`       — any iterator of dense or packed row chunks
  * :func:`ingest_quest`        — a chunked QuestConfig generator
                                  (``data.synthetic.gen_transactions_chunked``),
                                  so huge synthetic DBs never materialize

Read path: :meth:`TransactionStore.iter_chunks` yields fixed-size row
chunks (packed uint32 or unpacked dense int8) assembled across shard
boundaries; ``pad=True`` zero-pads the final chunk to the full chunk size —
zero rows are inert for support counting in both representations
(DESIGN.md §3), which is what lets the streaming driver jit one chunk shape.
"""

from __future__ import annotations

import dataclasses
import json
import os

import numpy as np

from repro.core import itemsets as enc

LAYOUT_VERSION = 1
LAYOUT_NAME = "packed-u32-le"   # uint32 words, little-endian bit order (§4)
MANIFEST_NAME = "manifest.json"


DEFAULT_CHECKPOINT_DIR = "_checkpoints"


@dataclasses.dataclass(frozen=True)
class StoreManifest:
    """The namenode metadata: logical shape + physical shard layout.

    ``checkpoint_dir`` points (relative to the store directory) at where
    mining checkpoints for this store live — resume tooling finds the
    snapshots next to the data they were taken over (DESIGN.md §11).
    Manifests written before the field existed read back with the default.

    ``seq`` is the manifest generation: it bumps on every manifest rewrite
    (shard append, count-cache refresh), so readers can tell "same directory,
    new contents" apart from "unchanged". ``count_cache`` is the optional
    incremental-mining section (DESIGN.md §15): metadata for the persisted
    SON phase-1/2 count cache, whose arrays live in a sidecar ``.npz`` the
    section points at. Appends preserve the section verbatim — the cache
    records which shard prefix it covers, so the delta miner can validate it
    against a grown store.
    """

    version: int
    layout: str
    n: int                      # logical transaction count (sum of shard_rows)
    num_items: int
    words: int                  # packed words per row == packed_words(num_items)
    shard_rows: tuple           # rows per shard, in order
    checkpoint_dir: str = DEFAULT_CHECKPOINT_DIR
    seq: int = 0                # manifest generation; bumps on every rewrite
    count_cache: dict | None = None   # incremental count-cache section (§15)

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d["shard_rows"] = list(self.shard_rows)
        return d

    @staticmethod
    def from_json(d: dict) -> "StoreManifest":
        return StoreManifest(
            version=int(d["version"]),
            layout=str(d["layout"]),
            n=int(d["n"]),
            num_items=int(d["num_items"]),
            words=int(d["words"]),
            shard_rows=tuple(int(r) for r in d["shard_rows"]),
            checkpoint_dir=str(d.get("checkpoint_dir", DEFAULT_CHECKPOINT_DIR)),
            seq=int(d.get("seq", 0)),
            count_cache=d.get("count_cache"),
        )


def _write_manifest(path: str, manifest: StoreManifest) -> None:
    """Atomic manifest (re)write: temp file + ``os.replace``, so a reader (or
    a crash) never observes a torn manifest — it sees the old one or the new
    one, nothing in between. This is what makes appends torn-append-safe:
    shard files land first, and only this single atomic rename publishes them.
    """
    final = os.path.join(path, MANIFEST_NAME)
    tmp = final + ".tmp"
    with open(tmp, "w") as f:
        json.dump(manifest.to_json(), f, indent=2)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, final)


def shard_filename(index: int) -> str:
    return f"shard_{index:05d}.npy"


class TransactionStore:
    """Read handle over an ingested store directory (shards open mmap'd)."""

    def __init__(self, path: str, manifest: StoreManifest):
        self.path = path
        self.manifest = manifest

    # ------------------------------------------------------------ metadata --
    @property
    def num_transactions(self) -> int:
        return self.manifest.n

    @property
    def num_items(self) -> int:
        return self.manifest.num_items

    @property
    def num_partitions(self) -> int:
        return len(self.manifest.shard_rows)

    def shard_path(self, index: int) -> str:
        return os.path.join(self.path, shard_filename(index))

    @property
    def checkpoint_path(self) -> str:
        """Where this store's mining checkpoints live (manifest pointer)."""
        return os.path.join(self.path, self.manifest.checkpoint_dir)

    # ----------------------------------------------------------- count cache --
    @property
    def count_cache_meta(self) -> dict | None:
        """The manifest's incremental count-cache section, or None (§15)."""
        return self.manifest.count_cache

    def set_count_cache(self, meta: dict | None) -> None:
        """Publish (or clear) the count-cache section: atomic manifest rewrite
        with a ``seq`` bump. Callers write the sidecar arrays FIRST, then call
        this — a crash in between leaves the previous manifest (and previous
        cache pointer) fully readable."""
        old_file = (self.manifest.count_cache or {}).get("file")
        self.manifest = dataclasses.replace(
            self.manifest, seq=self.manifest.seq + 1, count_cache=meta
        )
        _write_manifest(self.path, self.manifest)
        # GC the superseded sidecar only after the new manifest is durable
        new_file = (meta or {}).get("file")
        if old_file and old_file != new_file:
            try:
                os.remove(os.path.join(self.path, old_file))
            except OSError:
                pass

    # ---------------------------------------------------------- partitions --
    def partition_packed(self, index: int) -> np.ndarray:
        """One shard as a read-only memory-mapped (rows, words) uint32 array."""
        arr = np.load(self.shard_path(index), mmap_mode="r")
        rows = self.manifest.shard_rows[index]
        if arr.shape != (rows, self.manifest.words) or arr.dtype != np.uint32:
            raise ValueError(
                f"shard {index} shape/dtype {arr.shape}/{arr.dtype} does not match "
                f"manifest ({rows}, {self.manifest.words}) uint32"
            )
        return arr

    def partition_dense(self, index: int) -> np.ndarray:
        """One shard unpacked to dense {0,1} int8 (materializes ONE shard)."""
        return enc.unpack_bits(np.asarray(self.partition_packed(index)), self.num_items)

    # -------------------------------------------------------------- chunks --
    def iter_chunks(
        self,
        chunk_rows: int,
        representation: str = "packed",
        pad: bool = False,
        start_chunk: int = 0,
        shards: tuple | None = None,
    ):
        """Yield ``(chunk, valid_rows)`` covering all n rows in order.

        chunk: (chunk_rows or fewer, words) uint32 when ``representation ==
        "packed"``, (rows, num_items) int8 when ``"dense"``. Chunks are
        assembled across shard boundaries, copying only the sliced rows out
        of the mmap. With ``pad=True`` every chunk has exactly
        ``chunk_rows`` rows, the tail zero-filled (inert, DESIGN.md §3).

        ``start_chunk`` seeks: the first ``start_chunk`` chunks are skipped
        WITHOUT copying their rows (whole shards before the cursor are never
        even opened), and the yielded sequence is identical to dropping that
        prefix of a full iteration — the resume cursor of DESIGN.md §11.
        Chunk indices are deterministic for a fixed ``chunk_rows``: chunk i
        is always rows ``[i*chunk_rows, (i+1)*chunk_rows)``.

        ``shards=(s0, s1)`` restricts iteration to the half-open shard range
        ``[s0, s1)`` — the delta miner's view (§15): chunk indices (and the
        row coordinates above) are then local to the range, and shards
        outside it are never opened.
        """
        if chunk_rows < 1:
            raise ValueError("chunk_rows must be >= 1")
        if start_chunk < 0:
            raise ValueError("start_chunk must be >= 0")
        if representation not in ("packed", "dense"):
            raise ValueError(f"representation must be packed|dense, got {representation!r}")
        s0, s1 = (0, self.num_partitions) if shards is None else shards
        if not (0 <= s0 <= s1 <= self.num_partitions):
            raise ValueError(
                f"shards must satisfy 0 <= s0 <= s1 <= {self.num_partitions}, got {(s0, s1)}"
            )
        total = sum(self.manifest.shard_rows[s0:s1])
        skip = start_chunk * chunk_rows
        if skip >= total:
            return
        parts: list[np.ndarray] = []
        have = 0
        for s in range(s0, s1):
            if skip >= self.manifest.shard_rows[s]:
                skip -= self.manifest.shard_rows[s]
                continue
            shard = self.partition_packed(s)
            pos, skip = skip, 0
            while pos < shard.shape[0]:
                take = min(chunk_rows - have, shard.shape[0] - pos)
                parts.append(np.asarray(shard[pos : pos + take]))
                have += take
                pos += take
                if have == chunk_rows:
                    yield self._emit(parts, have, chunk_rows, representation, pad)
                    parts, have = [], 0
        if have:
            yield self._emit(parts, have, chunk_rows, representation, pad)

    def _emit(self, parts, have, chunk_rows, representation, pad):
        packed = parts[0] if len(parts) == 1 else np.concatenate(parts, axis=0)
        if pad and have < chunk_rows:
            packed = np.concatenate(
                [packed, np.zeros((chunk_rows - have, packed.shape[1]), np.uint32)]
            )
        if representation == "dense":
            return enc.unpack_bits(packed, self.num_items), have
        return packed, have

    def read_dense(self) -> np.ndarray:
        """The whole DB as dense {0,1} int8 — test/debug helper ONLY; this is
        exactly the materialization the store exists to avoid."""
        return np.concatenate([self.partition_dense(s) for s in range(self.num_partitions)])


class StoreWriter:
    """Streaming ingest: buffers at most one shard of packed rows in RAM,
    flushing each full shard to its own ``.npy``. Context-managed; the
    manifest is written on :meth:`close` (a crashed ingest leaves no
    manifest, so :func:`open_store` refuses the partial directory)."""

    def __init__(self, path: str, num_items: int, shard_rows: int = 8192):
        if shard_rows < 1:
            raise ValueError("shard_rows must be >= 1")
        if num_items < 1:
            raise ValueError("num_items must be >= 1")
        os.makedirs(path, exist_ok=True)
        # re-ingest: invalidate the old store first — manifest AND shards
        # (a smaller re-ingest must not leave orphan shard files behind)
        stale = os.path.join(path, MANIFEST_NAME)
        if os.path.exists(stale):
            os.remove(stale)
        for name in os.listdir(path):
            if name.startswith("shard_") and name.endswith(".npy"):
                os.remove(os.path.join(path, name))
        self.path = path
        self.num_items = num_items
        self.words = enc.packed_words(num_items)
        self.shard_rows = shard_rows
        self._buf: list[np.ndarray] = []
        self._buf_rows = 0
        self._shards: list[int] = []
        self._closed = False
        self._base: StoreManifest | None = None   # set in append mode only

    @classmethod
    def open_for_append(cls, path: str, shard_rows: int | None = None) -> "StoreWriter":
        """Reopen an existing store to append shards (DESIGN.md §15).

        Existing shard files are never rewritten: appended rows always start
        a NEW shard (the last base shard may stay partial — ``shard_rows`` is
        per-shard in the manifest, so readers don't care). New shard files
        land on disk as they fill; only :meth:`close` publishes them, via one
        atomic manifest rewrite with a ``seq`` bump. A crash before close
        (torn append) therefore leaves the old manifest — and the old logical
        store — fully readable; the orphaned shard files it may leave behind
        are swept here on the next append open.
        """
        base = open_store(path)   # validates version/layout/words
        m = base.manifest
        w = cls.__new__(cls)
        w.path = path
        w.num_items = m.num_items
        w.words = m.words
        w.shard_rows = shard_rows or (max(m.shard_rows) if m.shard_rows else 8192)
        if w.shard_rows < 1:
            raise ValueError("shard_rows must be >= 1")
        w._buf, w._buf_rows = [], 0
        w._shards = list(m.shard_rows)
        w._closed = False
        w._base = m
        # sweep orphan shards from a previous torn append (files past the
        # manifest's shard list were written but never published)
        i = len(w._shards)
        while os.path.exists(os.path.join(path, shard_filename(i))):
            os.remove(os.path.join(path, shard_filename(i)))
            i += 1
        return w

    # ------------------------------------------------------------- appends --
    def append_packed(self, packed_chunk: np.ndarray) -> None:
        packed_chunk = np.ascontiguousarray(packed_chunk, dtype=np.uint32)
        if packed_chunk.ndim != 2 or packed_chunk.shape[1] != self.words:
            raise ValueError(
                f"packed chunk must be (rows, {self.words}), got {packed_chunk.shape}"
            )
        pos = 0
        while pos < packed_chunk.shape[0]:
            take = min(self.shard_rows - self._buf_rows, packed_chunk.shape[0] - pos)
            self._buf.append(packed_chunk[pos : pos + take])
            self._buf_rows += take
            pos += take
            if self._buf_rows == self.shard_rows:
                self._flush()

    def append_dense(self, dense_chunk: np.ndarray) -> None:
        dense_chunk = np.asarray(dense_chunk)
        if dense_chunk.ndim != 2 or dense_chunk.shape[1] != self.num_items:
            raise ValueError(
                f"dense chunk must be (rows, {self.num_items}), got {dense_chunk.shape}"
            )
        self.append_packed(enc.pack_bits(dense_chunk))

    def append_lists(self, transactions, num_items: int | None = None) -> None:
        if num_items is not None and num_items != self.num_items:
            raise ValueError("num_items mismatch")
        self.append_dense(enc.dense_from_lists(transactions, self.num_items))

    # --------------------------------------------------------------- flush --
    def _flush(self) -> None:
        if self._buf_rows == 0:
            return
        shard = self._buf[0] if len(self._buf) == 1 else np.concatenate(self._buf)
        np.save(os.path.join(self.path, shard_filename(len(self._shards))), shard)
        self._shards.append(shard.shape[0])
        self._buf, self._buf_rows = [], 0

    def close(self) -> TransactionStore:
        if self._closed:
            raise RuntimeError("StoreWriter already closed")
        self._flush()
        if self._base is not None:
            # append mode: preserve checkpoint_dir and the count-cache
            # section (the cache self-describes which shard prefix it
            # covers), bump seq, publish atomically
            manifest = dataclasses.replace(
                self._base,
                n=sum(self._shards),
                shard_rows=tuple(self._shards),
                seq=self._base.seq + 1,
            )
        else:
            manifest = StoreManifest(
                version=LAYOUT_VERSION,
                layout=LAYOUT_NAME,
                n=sum(self._shards),
                num_items=self.num_items,
                words=self.words,
                shard_rows=tuple(self._shards),
            )
        _write_manifest(self.path, manifest)
        self._closed = True
        return TransactionStore(self.path, manifest)

    def __enter__(self) -> "StoreWriter":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if exc_type is None and not self._closed:
            self.close()


# ------------------------------------------------------------------- open ----
def open_store(path: str) -> TransactionStore:
    manifest_path = os.path.join(path, MANIFEST_NAME)
    if not os.path.exists(manifest_path):
        raise FileNotFoundError(f"no transaction store manifest at {manifest_path}")
    with open(manifest_path) as f:
        manifest = StoreManifest.from_json(json.load(f))
    if manifest.version != LAYOUT_VERSION:
        raise ValueError(
            f"store layout version {manifest.version} != supported {LAYOUT_VERSION}"
        )
    if manifest.layout != LAYOUT_NAME:
        raise ValueError(f"unknown store layout {manifest.layout!r}")
    if manifest.words != enc.packed_words(manifest.num_items):
        raise ValueError("manifest words inconsistent with num_items")
    return TransactionStore(path, manifest)


# ----------------------------------------------------------------- ingest ----
def ingest_chunks(chunks, num_items: int, path: str, shard_rows: int = 8192) -> TransactionStore:
    """Ingest any iterator of row chunks — dense {0,1} (rows, num_items) or
    pre-packed uint32 (rows, words); each chunk's dtype/width decides."""
    words = enc.packed_words(num_items)
    with StoreWriter(path, num_items, shard_rows=shard_rows) as w:
        for chunk in chunks:
            chunk = np.asarray(chunk)
            if chunk.dtype == np.uint32 and chunk.shape[1] == words:
                w.append_packed(chunk)
            else:
                w.append_dense(chunk)
    return open_store(path)


def append_chunks(chunks, path: str, shard_rows: int | None = None) -> TransactionStore:
    """Append row chunks (dense or packed, as :func:`ingest_chunks`) to an
    EXISTING store — the continuous-refresh write path (DESIGN.md §15)."""
    w = StoreWriter.open_for_append(path, shard_rows=shard_rows)
    words = w.words
    try:
        for chunk in chunks:
            chunk = np.asarray(chunk)
            if chunk.dtype == np.uint32 and chunk.shape[1] == words:
                w.append_packed(chunk)
            else:
                w.append_dense(chunk)
        return w.close()
    except BaseException:
        # leave the torn append unpublished: old manifest stays authoritative
        w._closed = True
        raise


def ingest_dense(dense: np.ndarray, path: str, shard_rows: int = 8192) -> TransactionStore:
    dense = np.asarray(dense)
    with StoreWriter(path, dense.shape[1], shard_rows=shard_rows) as w:
        w.append_dense(dense)
    return open_store(path)


def ingest_lists(
    transactions, num_items: int, path: str, shard_rows: int = 8192, chunk_rows: int = 8192
) -> TransactionStore:
    with StoreWriter(path, num_items, shard_rows=shard_rows) as w:
        for start in range(0, len(transactions), chunk_rows):
            w.append_lists(transactions[start : start + chunk_rows])
    return open_store(path)


def ingest_quest(qcfg, path: str, shard_rows: int = 8192, chunk_rows: int | None = None) -> TransactionStore:
    """Ingest a synthetic Quest DB via the chunked generator — peak host RAM
    is O(chunk_rows · num_items + num_transactions), never the dense matrix."""
    from repro.data.synthetic import gen_transactions_chunked

    chunk_rows = chunk_rows or shard_rows
    return ingest_chunks(
        gen_transactions_chunked(qcfg, chunk_rows), qcfg.num_items, path, shard_rows=shard_rows
    )

"""Replicated serving walkthrough: router, replica kill, coordinated swap.

  PYTHONPATH=src python examples/serve_replicated.py \
      [--transactions 4000] [--items 128] [--requests 1200] [--replicas 3]

The DESIGN.md §12 tier, step by step:

  1. ingest + mine — same store -> ``mine_streamed`` -> rulebook pipeline
                     as examples/serve_gateway.py;
  2. replicate     — a ``Router`` fronts N independent ``Gateway`` replicas
                     (each with its own micro-batcher, basket cache and
                     device-resident rulebook) behind consistent basket
                     hashing, so a repeat basket lands on the SAME replica
                     and its LRU cache stays effective;
  3. kill          — mid-load, fault injection kills one replica's dispatch
                     worker: in-flight requests fail over to the next
                     replica on the hash ring while the router's supervisor
                     restarts the dead worker — zero requests dropped;
  4. swap          — a coordinated two-phase hot-swap (prepare on every
                     healthy replica, then flip) moves the whole replica
                     set to the new rulebook generation with traffic live;
  5. verify        — every response is bit-identical to an offline
                     ``recommend()`` against the generation that answered.

The same flow as a single command (plus a JSON summary for scripting):

  PYTHONPATH=src python -m repro.launch.serve --replicas 3 \
      --kill-replica-mid-load --hot-swap-mid-load --requests 2000
"""

import argparse
import tempfile
import threading
import time
from concurrent.futures import ThreadPoolExecutor

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--transactions", type=int, default=4_000)
    ap.add_argument("--items", type=int, default=128)
    ap.add_argument("--avg-len", type=float, default=10.0)
    ap.add_argument("--min-support", type=float, default=0.02)
    ap.add_argument("--max-k", type=int, default=4)
    ap.add_argument("--min-confidence", type=float, default=0.4)
    ap.add_argument("--top-k", type=int, default=5)
    ap.add_argument("--requests", type=int, default=1_200)
    ap.add_argument("--concurrency", type=int, default=12)
    ap.add_argument("--replicas", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.core.apriori import AprioriConfig
    from repro.core.streaming import mine_streamed
    from repro.data.store import ingest_quest
    from repro.data.synthetic import QuestConfig
    from repro.distributed import FaultConfig
    from repro.serving import Router, compile_rulebook, recommend

    # ---- 1. ingest + mine (identical to the single-gateway example) ----
    qcfg = QuestConfig(num_transactions=args.transactions, num_items=args.items,
                       avg_len=args.avg_len, seed=args.seed)
    tmp = tempfile.TemporaryDirectory(prefix="router_store_")
    store = ingest_quest(qcfg, tmp.name, shard_rows=2048, chunk_rows=2048)
    print(f"[router] store: n={store.num_transactions} items={store.num_items}")

    def mine_rulebook(min_support):
        res = mine_streamed(
            store,
            AprioriConfig(min_support=min_support, max_k=args.max_k,
                          representation="packed"),
            chunk_rows=2048,
        )
        rb = compile_rulebook(res, min_confidence=args.min_confidence,
                              num_items=store.num_items)
        print(f"[router] min_support={min_support}: {res.total_frequent} itemsets "
              f"-> {rb.num_rules} rules")
        return rb

    rb0 = mine_rulebook(args.min_support)
    rb1 = mine_rulebook(2 * args.min_support)
    rulebooks = {0: rb0, 1: rb1}

    chunk, real = next(store.iter_chunks(min(2048, store.num_transactions)))
    baskets = list(chunk[:real])

    # ---- 2. the replicated tier + a concurrent client load ----
    responses, lock = [], threading.Lock()

    with Router(rb0, args.replicas, top_k=args.top_k, max_batch=64,
                max_wait_ms=1.0, cache_capacity=2048,
                fault=FaultConfig(max_retries=3, backoff_s=0.01),
                attempt_timeout_s=1.0) as router:
        print(f"[router] {args.replicas} replicas on a consistent hash ring, "
              f"supervised")

        def client(indices):
            for i in indices:
                resp = router.submit(baskets[i % len(baskets)]).result(timeout=120)
                with lock:
                    responses.append((baskets[i % len(baskets)], resp))

        half = args.requests // 2
        t0 = time.perf_counter()
        with ThreadPoolExecutor(max_workers=args.concurrency) as pool:
            for w in [pool.submit(client, range(o, half, args.concurrency))
                      for o in range(args.concurrency)]:
                w.result()
            # ---- 3. kill replica 0 mid-load: failover + restart ----
            router.fault_injection.kill_replica(0)
            print("[router] killed replica 0's dispatch worker mid-load")
            # ---- 4. coordinated two-phase swap with traffic live ----
            gen = router.hot_swap(rb1)
            print(f"[router] two-phase swap -> generation {gen}, traffic live")
            for w in [pool.submit(client, range(half + o, args.requests,
                                                args.concurrency))
                      for o in range(args.concurrency)]:
                w.result()
        wall = time.perf_counter() - t0

        # let the supervisor finish reviving the killed replica
        deadline = time.perf_counter() + 5.0
        while time.perf_counter() < deadline:
            if all(r["state"] == "healthy" for r in router.stats()["replicas"]):
                break
            time.sleep(0.02)
        stats = router.stats()

    # ---- 5. every answer is bit-identical to the offline path ----
    assert len(responses) == args.requests, "a request was dropped"
    gens = sorted({r.generation for _, r in responses})
    assert gens == [0, 1], f"expected both generations to answer, saw {gens}"
    for basket, resp in responses[:: max(1, len(responses) // 50)]:
        ref = recommend(rulebooks[resp.generation], np.asarray([basket]),
                        top_k=args.top_k, batch_size=resp.bucket)
        np.testing.assert_array_equal(np.asarray(resp.items), np.asarray(ref.items[0]))

    lat = np.array(sorted(r.latency_s for _, r in responses)) * 1e3
    print(f"[router] {len(responses)} responses in {wall:.2f}s "
          f"({len(responses) / wall:,.0f} qps) | generations={gens}")
    print(f"[router] latency p50={np.percentile(lat, 50):.2f}ms "
          f"p99={np.percentile(lat, 99):.2f}ms")
    print(f"[router] failovers={stats['failovers']} "
          f"replica_states={[r['state'] for r in stats['replicas']]} "
          f"replica_gens={[r['generation'] for r in stats['replicas']]} "
          f"restarts={sum(g['gateway']['worker_restarts'] for g in stats['replicas'])} "
          f"max_gen_lag={stats['max_generation_lag']}")
    print("[router] spot-checked responses are bit-identical to offline "
          "recommend() for their generation")
    tmp.cleanup()


if __name__ == "__main__":
    main()

"""Replicated serving tier: failure-aware router over N gateway replicas (§12).

The single :class:`~repro.serving.gateway.Gateway` already survives a crashed
dispatch worker (supervisor restart, §11) — but it is still ONE queue, ONE
worker, ONE cache. This module replicates the whole gateway N times and puts
a :class:`Router` in front, the serving-side analogue of the paper's
JobTracker over N TaskTrackers:

* **Consistent basket hashing.** Every basket's packed bitset hashes onto a
  virtual-node ring (:class:`HashRing`); the owning replica answers it.
  Repeat baskets keep landing on the same replica, so each replica's
  exact-basket LRU stays effective — N replicas partition the working set
  instead of duplicating it (the N-replica cache argument, DESIGN.md §12).

* **Health + failover.** Replicas move healthy → suspect → dead, driven by
  dispatch-worker liveness and consecutive attempt failures; a failed
  attempt (``WorkerCrashed``, an unresponsive replica's attempt timeout) is
  re-submitted to the next candidate on the ring with bounded retries and
  exponential backoff — the SAME :class:`FaultConfig` / ``retry_delay``
  policy the SON partition executor uses for map re-execution. Re-running a
  basket query is safe for the same reason a map task is: matching is
  read-only, first completion wins.

* **Deadlines.** ``submit(..., deadline_ms=...)`` bounds the REQUEST across
  all retries: the per-replica batcher drops past-deadline queued requests
  at dispatch, and the router's watchdog fails the outer future with
  :class:`DeadlineExceeded` even when the holding replica never answers.

* **Load shedding.** When every candidate replica is dead or its admission
  queue is full, the router rejects with a typed
  :class:`AdmissionRejected` — overload and total failure degrade loudly,
  never as a hang.

* **Coordinated two-phase hot-swap.** :meth:`Router.hot_swap` runs phase 1
  (``prepare_swap``: place + warm, double-buffered) on EVERY live replica,
  then phase 2 flips all serving references to the coordinated generation
  id. A replica that fails prepare is marked suspect and keeps answering
  its stale generation — tracked by the ``max_generation_lag`` metric —
  until the monitor re-syncs it to the target generation.

Fault injection for tests/benchmarks rides the batcher's in-worker crash
hook: :class:`RouterFaultInjection` can kill a replica's worker mid-batch,
delay its dispatches, or fail its swap prepares.
"""

from __future__ import annotations

import bisect
import dataclasses
import functools
import hashlib
import heapq
import itertools
import threading
import time
from concurrent.futures import Future

from repro.distributed.fault_tolerance import FaultConfig, InjectedFailure, retry_delay
from repro.distributed.supervisor import ReplicaSetSupervisor
from repro.obs.registry import Histogram
from repro.serving.batcher import AdmissionRejected, DeadlineExceeded, WorkerCrashed
from repro.serving.gateway import Gateway
from repro.serving.metrics import RouterMetrics
from repro.serving.rulebook import Rulebook

HEALTHY = "healthy"
SUSPECT = "suspect"
DEAD = "dead"


def _stable_hash(data: bytes) -> int:
    """64-bit blake2b — stable across processes/runs (unlike ``hash()``),
    so ring placement and tests are reproducible."""
    return int.from_bytes(hashlib.blake2b(data, digest_size=8).digest(), "big")


class HashRing:
    """Consistent-hash ring with virtual nodes.

    ``preference(key)`` returns ALL replica ids in ring order starting at the
    key's owner — the router's failover order, so a dead owner's baskets
    spill deterministically onto the same successor (that successor's cache
    absorbs exactly one shard, not a random shuffle)."""

    def __init__(self, num_replicas: int, vnodes: int = 64):
        if num_replicas < 1:
            raise ValueError("num_replicas must be >= 1")
        self.num_replicas = int(num_replicas)
        self.vnodes = int(vnodes)
        points = []
        for rid in range(num_replicas):
            for v in range(vnodes):
                points.append((_stable_hash(f"replica-{rid}/vnode-{v}".encode()), rid))
        points.sort()
        self._points = points
        self._hashes = [h for h, _ in points]

    def preference(self, key: bytes) -> list[int]:
        """Replica ids in ring-walk order from the key's owner (owner first,
        every replica exactly once)."""
        h = _stable_hash(key)
        start = bisect.bisect_right(self._hashes, h) % len(self._points)
        seen: set[int] = set()
        order: list[int] = []
        for j in range(len(self._points)):
            rid = self._points[(start + j) % len(self._points)][1]
            if rid not in seen:
                seen.add(rid)
                order.append(rid)
                if len(order) == self.num_replicas:
                    break
        return order

    def owner(self, key: bytes) -> int:
        return self.preference(key)[0]


class RouterFaultInjection:
    """Chaos hooks for the replica set (tests, benchmarks, serve CLI).

    ``kill_replica`` arms a one-shot in-worker ``SystemExit`` on the
    replica's NEXT dispatch — the worker dies with the batch in flight,
    exercising the real stranding → supervisor-restart → failover path.
    ``delay_replica`` makes every dispatch sleep first (an unresponsive
    replica: the router's attempt watchdog fires, the slow answer is
    discarded). ``fail_swap_on`` makes two-phase prepare fail (sticky until
    cleared, or one-shot) — the stale-generation degradation path."""

    def __init__(self):
        self._lock = threading.Lock()
        self._kill_once: set[int] = set()
        self._delay_s: dict[int, float] = {}
        self._swap_fail: set[int] = set()
        self._swap_fail_once: set[int] = set()
        self.kills_fired = 0

    def kill_replica(self, rid: int) -> None:
        with self._lock:
            self._kill_once.add(int(rid))

    def delay_replica(self, rid: int, seconds: float) -> None:
        with self._lock:
            if seconds > 0:
                self._delay_s[int(rid)] = float(seconds)
            else:
                self._delay_s.pop(int(rid), None)

    def fail_swap_on(self, rid: int, once: bool = False) -> None:
        with self._lock:
            (self._swap_fail_once if once else self._swap_fail).add(int(rid))

    def clear_swap_failures(self, rid: int | None = None) -> None:
        with self._lock:
            if rid is None:
                self._swap_fail.clear()
                self._swap_fail_once.clear()
            else:
                self._swap_fail.discard(int(rid))
                self._swap_fail_once.discard(int(rid))

    # ---- consulted by the router / installed into replica batchers --------
    def _on_dispatch(self, rid: int, batch=None) -> None:
        """Runs IN the replica's dispatch worker, batch already in flight."""
        with self._lock:
            kill = rid in self._kill_once
            if kill:
                self._kill_once.discard(rid)
                self.kills_fired += 1
            delay = self._delay_s.get(rid, 0.0)
        if delay > 0:
            time.sleep(delay)
        if kill:
            raise SystemExit(f"injected kill: replica {rid} dispatch worker")

    def _should_fail_swap(self, rid: int) -> bool:
        with self._lock:
            if rid in self._swap_fail_once:
                self._swap_fail_once.discard(rid)
                return True
            return rid in self._swap_fail


class Replica:
    """One gateway plus its router-side health record."""

    __slots__ = ("rid", "gateway", "state", "consecutive_failures", "last_failure_t")

    def __init__(self, rid: int, gateway: Gateway):
        self.rid = rid
        self.gateway = gateway
        self.state = HEALTHY
        self.consecutive_failures = 0
        self.last_failure_t = 0.0

    @property
    def available(self) -> bool:
        """Dispatchable: not declared dead and still admitting. A replica
        whose worker just died but is being supervised stays available —
        queued requests survive the restart."""
        return self.state != DEAD and not self.gateway._batcher.closed

    def note_failure(self, suspect_after: int) -> None:
        self.consecutive_failures += 1
        self.last_failure_t = time.perf_counter()
        if self.state == HEALTHY and self.consecutive_failures >= suspect_after:
            self.state = SUSPECT

    def note_success(self) -> None:
        # successes end the failure streak but do NOT promote the replica:
        # re-healthy is the monitor's call, after ``healthy_after_s`` of
        # quiet (a suspect replica that answers one request hasn't proven
        # anything yet, and an instant flip would make the health dip
        # invisible to anything sampling the healthy-replica gauge)
        self.consecutive_failures = 0

    def mark_dead(self) -> bool:
        """Returns True on the transition (for once-only death accounting)."""
        if self.state != DEAD:
            self.state = DEAD
            return True
        return False


class _RouterTask:
    """One routed request across all its attempts."""

    __slots__ = ("outer", "packed", "top_k", "deadline", "t_submit",
                 "attempts", "cursor", "pref", "lock",
                 "span", "att_span", "t_parked")

    def __init__(self, outer, packed, top_k, deadline, t_submit, pref):
        self.outer = outer
        self.packed = packed
        self.top_k = top_k
        self.deadline = deadline
        self.t_submit = t_submit
        self.attempts = 0        # dispatches actually made (or burnt retries)
        self.cursor = 0          # rotation into the ring preference list
        self.pref = pref
        self.lock = threading.Lock()   # guards the outer future's resolution
        self.span = None         # sampled root span for the whole request (§13)
        self.att_span = None     # span of the single in-flight attempt
        self.t_parked = 0.0      # when the task was parked for retry backoff


class Router:
    """Failure-aware front over N independent :class:`Gateway` replicas.

    Same submit/query surface as a single gateway — drop-in for the load
    harness — plus coordinated :meth:`hot_swap`, replica-set :meth:`stats`,
    and a :attr:`fault_injection` chaos seam. Every admitted request reaches
    exactly one terminal outcome: a Response (bit-identical to
    ``recommend()`` against the answering generation), or a typed
    :class:`DeadlineExceeded` / :class:`AdmissionRejected` /
    :class:`WorkerCrashed` — never a hang.
    """

    def __init__(
        self,
        rulebook: Rulebook,
        num_replicas: int = 2,
        *,
        fault: FaultConfig = FaultConfig(),
        attempt_timeout_s: float = 1.0,
        suspect_after: int = 2,
        healthy_after_s: float = 0.2,
        vnodes: int = 64,
        supervise: bool = True,
        monitor_interval_s: float = 0.02,
        max_restarts: int = 5,
        restart_window_s: float = 10.0,
        tracer=None,
        **gateway_kwargs,
    ):
        if num_replicas < 1:
            raise ValueError("num_replicas must be >= 1")
        self.fault = fault
        self._tracer = tracer
        self._attempt_timeout = float(attempt_timeout_s)
        self._suspect_after = int(suspect_after)
        self._healthy_after = float(healthy_after_s)
        self._monitor_interval = float(monitor_interval_s)
        self.metrics = RouterMetrics()
        self.fault_injection = RouterFaultInjection()
        self._ring = HashRing(num_replicas, vnodes)
        self._closed = False
        # alert-driven brownout (§14): 0 = normal, 1 = warn (shed above 50%
        # aggregate queue fill), 2 = page (shed above 25%) — a burning
        # availability SLO tightens admission instead of letting queues fill
        self._brownout_level = 0

        # N fully independent gateways: own batcher, own cache, own device
        # placement. The jit cache is shared underneath (same shapes, same
        # cached match step), so replica warmup compiles mostly once.
        # replicas share the router's tracer but never START a trace
        # themselves (trace_root=False): one request = one trace, sampled
        # once at the router, continued through whichever replicas serve it
        self._replicas = [
            Replica(rid, Gateway(rulebook, tracer=tracer, trace_root=False,
                                 **gateway_kwargs))
            for rid in range(num_replicas)
        ]
        for rep in self._replicas:
            rep.gateway._batcher._crash_hook = functools.partial(
                self.fault_injection._on_dispatch, rep.rid
            )
        self.num_items = self._replicas[0].gateway.num_items
        self.default_top_k = self._replicas[0].gateway.default_top_k

        self._target_generation = 0
        self._target_rulebook = rulebook
        self._swap_lock = threading.Lock()

        # retry heap + in-flight attempt watchdog, drained by the driver
        self._lock = threading.Lock()
        self._heap: list = []            # (due_time, seq, task)
        self._inflight: dict = {}        # token -> (task, rid, timeout_at)
        self._seq = itertools.count()
        self._token = itertools.count()

        self._stop_driver = threading.Event()
        self._driver = threading.Thread(
            target=self._drive, name="router-driver", daemon=True
        )
        self._driver.start()
        self._stop_monitor = threading.Event()
        self._monitor = threading.Thread(
            target=self._monitor_loop, name="router-monitor", daemon=True
        )
        self._monitor.start()
        self.supervisor = None
        if supervise:
            self.supervisor = ReplicaSetSupervisor(
                [rep.gateway for rep in self._replicas],
                max_restarts=max_restarts,
                restart_window_s=restart_window_s,
                on_gave_up=self._on_replica_gave_up,
            )

    # ----------------------------------------------------------- requests --
    def submit(self, basket, top_k: int | None = None, deadline_ms: float | None = None):
        """Admit one basket query; returns a Future resolving to a gateway
        :class:`~repro.serving.gateway.Response` whose ``latency_s`` is the
        ROUTER-level submit→resolution time (failover + backoff included).

        Raises :class:`AdmissionRejected` when the router is closed or no
        candidate replica can take the request (all dead / all saturated) —
        the load-shedding path."""
        if self._closed:
            self.metrics.record_shed()
            raise AdmissionRejected("router closed")
        level = self._brownout_level
        if level:
            # brownout: admit only while aggregate queue fill stays under the
            # level's budget — overload sheds EARLY (typed reject in ~µs)
            # instead of queueing work the burning tier cannot absorb
            depth = cap = 0
            for rep in self._replicas:
                if rep.available:
                    depth += rep.gateway.queue_depth
                    cap += rep.gateway.queue_capacity
            budget = 0.5 if level == 1 else 0.25
            if cap == 0 or depth >= cap * budget:
                self.metrics.record_brownout_shed()
                raise AdmissionRejected(
                    f"brownout (availability alert, level {level}): "
                    f"aggregate queue {depth}/{cap} over the {budget:.0%} budget"
                )
        t0 = time.perf_counter()
        packed = self._replicas[0].gateway._pack_one(basket)
        k = min(self.default_top_k if top_k is None else int(top_k), self.num_items)
        deadline = None if deadline_ms is None else t0 + max(0.0, float(deadline_ms)) / 1e3
        task = _RouterTask(Future(), packed, k, deadline, t0,
                           self._ring.preference(packed.tobytes()))
        if self._tracer is not None:
            task.span = self._tracer.root("router.request", top_k=k)
            if task.span is not None:
                task.span.t0 = t0   # backdate to submit entry: admit nests
                # admission: pack + ring lookup, before the first attempt
                self._tracer.add_span(task.span, "router.admit", t0,
                                      time.perf_counter(),
                                      owner=task.pref[0])
        if not self._try_dispatch(task):
            self.metrics.record_shed()
            if task.span is not None:
                task.span.end(outcome="shed")
            raise AdmissionRejected("all replicas dead or saturated")
        self.metrics.record_routed()
        return task.outer

    def query(self, basket, top_k: int | None = None, timeout: float | None = 60.0,
              deadline_ms: float | None = None):
        """Blocking convenience wrapper: ``submit(...).result(timeout)``."""
        return self.submit(basket, top_k, deadline_ms=deadline_ms).result(timeout)

    # ----------------------------------------------------------- hot-swap --
    def hot_swap(self, rulebook: Rulebook) -> int:
        """Coordinated two-phase swap across the replica set.

        Phase 1 prepares (place + warm) on every live replica; phase 2 flips
        all their serving references to one coordinated generation id. A
        replica that fails prepare — or is down — is marked suspect, keeps
        answering its stale generation (``max_generation_lag`` tracks the
        gap), and is re-synced by the monitor once it can take the swap.
        Raises if NO replica completed prepare (nothing was committed)."""
        with self._swap_lock:
            target = self._target_generation + 1
            swap_sp = None
            if self._tracer is not None:
                swap_sp = self._tracer.root("router.swap", force=True,
                                            generation=target)
            prepared: dict[int, object] = {}
            for rep in self._replicas:
                gw = rep.gateway
                if rep.state == DEAD or gw._batcher.closed or not gw._batcher.worker_alive:
                    continue          # revived replicas re-sync via the monitor
                prep_sp = None if swap_sp is None else swap_sp.child(
                    "swap.prepare", replica=rep.rid)
                try:
                    if self.fault_injection._should_fail_swap(rep.rid):
                        raise InjectedFailure(
                            f"injected swap-prepare failure on replica {rep.rid}"
                        )
                    prepared[rep.rid] = gw.prepare_swap(rulebook, generation=target)
                    if prep_sp is not None:
                        prep_sp.end(outcome="ok")
                except Exception:
                    # prepare is side-effect-free for serving: the replica
                    # keeps answering its current generation
                    if prep_sp is not None:
                        prep_sp.end(outcome="failed")
                    self.metrics.record_swap_prepare_failure()
                    if rep.state == HEALTHY:
                        rep.state = SUSPECT
            if not prepared:
                if swap_sp is not None:
                    swap_sp.end(outcome="no_replica_prepared")
                raise RuntimeError(
                    "coordinated hot-swap failed: no replica completed prepare"
                )
            for rid, gen in prepared.items():
                commit_sp = None if swap_sp is None else swap_sp.child(
                    "swap.commit", replica=rid)
                self._replicas[rid].gateway.commit_swap(gen)
                if commit_sp is not None:
                    commit_sp.end()
            self._target_generation = target
            self._target_rulebook = rulebook
            self.metrics.record_coordinated_swap()
            self.metrics.mark_generation_commit()   # freshness clock restarts
            if swap_sp is not None:
                swap_sp.end(outcome="ok", prepared=len(prepared))
        self._observe_lag()
        return target

    # ------------------------------------------------------- alert reactions --
    def handle_alert(self, event) -> None:
        """SLO-alert subscriber (§14): measurement → enforcement, closed loop.

        Wire with ``evaluator.subscribe(router.handle_alert)``. Reactions
        key on the alert's semantic ``signal``, not the spec name:

        * ``availability`` — warn/page tighten admission (brownout level
          1/2, see :meth:`submit`); a clear lifts the brownout.
        * ``generation_lag`` / ``freshness`` — a burning staleness SLO
          triggers an immediate replica re-sync instead of waiting for the
          monitor's next pass.

        Exceptions must not escape into the evaluator's emit path, so the
        whole body is defensive — an unknown signal is ignored."""
        signal = getattr(event, "signal", "")
        severity = getattr(event, "severity", "ok")
        if signal == "availability":
            self._brownout_level = {"warn": 1, "page": 2}.get(severity, 0)
        elif signal in ("generation_lag", "freshness") and severity != "ok":
            self.metrics.record_alert_resync()
            self._resync_lagging()
            self._observe_lag()

    @property
    def brownout_level(self) -> int:
        """Current alert-driven admission tightening (0 = normal)."""
        return self._brownout_level

    @property
    def replicas(self) -> list:
        """The live :class:`Replica` wrappers — read-only, for observability
        surfaces that want each replica's gateway metrics registry."""
        return list(self._replicas)

    @property
    def generation(self) -> int:
        """The coordinated target generation (replicas may lag — see
        ``stats()['replicas']`` / ``max_generation_lag``)."""
        return self._target_generation

    # -------------------------------------------------------------- stats --
    def stats(self) -> dict:
        out = self.metrics.snapshot()
        # the replica-side latency view: the N gateway histograms MERGED
        # (bucket-wise addition ≡ recording the union of their samples, §13)
        # instead of re-measured — attempt latency across the whole set
        out["replica_latency"] = Histogram.merged(
            [rep.gateway.metrics.latency for rep in self._replicas]
        ).snapshot()
        out["target_generation"] = self._target_generation
        out["num_replicas"] = len(self._replicas)
        out["brownout_level"] = self._brownout_level
        out["replicas"] = [
            {
                "id": rep.rid,
                "state": rep.state,
                "generation": rep.gateway.generation,
                "worker_alive": rep.gateway._batcher.worker_alive,
                "consecutive_failures": rep.consecutive_failures,
                "gateway": rep.gateway.stats(),
            }
            for rep in self._replicas
        ]
        if self.supervisor is not None:
            out["supervisor"] = self.supervisor.stats()
        return out

    # ---------------------------------------------------------- lifecycle --
    def close(self) -> None:
        """Stop admitting; flush every replica; fail anything still pending
        (retry-parked or in flight) with a typed exception — never a hang."""
        if self._closed:
            return
        self._closed = True
        if self.supervisor is not None:
            self.supervisor.close()
        self._stop_monitor.set()
        self._monitor.join(timeout=5.0)
        for rep in self._replicas:
            rep.gateway.close()     # flushes admitted work; callbacks fire
        self._stop_driver.set()
        self._driver.join(timeout=5.0)
        with self._lock:
            heap, self._heap = self._heap, []
            inflight, self._inflight = self._inflight, {}
        for _, _, task in heap:
            self._finish(task, exc=AdmissionRejected("router closed"))
        for task, rid, _ in inflight.values():
            self._finish(task, exc=WorkerCrashed(
                f"router closed with attempt in flight on replica {rid}"
            ))

    def __enter__(self) -> "Router":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()

    # ----------------------------------------------------------- dispatch --
    def _candidates(self, task: _RouterTask) -> list[int]:
        """Ring-order candidates: owner-first on the first attempt (cache
        affinity even for a suspect owner), healthy-first on retries."""
        pref = task.pref
        start = task.cursor % len(pref)
        order = pref[start:] + pref[:start]
        avail = [rid for rid in order if self._replicas[rid].available]
        if task.attempts == 0:
            return avail
        healthy = [rid for rid in avail if self._replicas[rid].state == HEALTHY]
        rest = [rid for rid in avail if self._replicas[rid].state != HEALTHY]
        return healthy + rest

    def _try_dispatch(self, task: _RouterTask) -> bool:
        """Hand the task to the first candidate that admits it. Returns True
        when the task reached a terminal state OR an attempt is in flight;
        False when every candidate is dead/saturated."""
        now = time.perf_counter()
        if task.deadline is not None and now >= task.deadline:
            self._finish(task, exc=DeadlineExceeded(
                f"deadline passed before attempt {task.attempts + 1}"
            ), deadline=True)
            return True
        remaining_ms = None if task.deadline is None else max(
            0.0, (task.deadline - now) * 1e3
        )
        for rid in self._candidates(task):
            gw = self._replicas[rid].gateway
            att = None
            if task.span is not None:
                att = self._tracer.child(task.span, "router.attempt",
                                         replica=rid, attempt=task.attempts + 1)
            try:
                inner = gw.submit(task.packed, task.top_k, deadline_ms=remaining_ms,
                                  _span_parent=att)
            except AdmissionRejected:
                if att is not None:
                    att.end(outcome="rejected")
                continue            # saturated/closed: spill to the next candidate
            task.att_span = att
            task.attempts += 1
            task.cursor += 1
            token = next(self._token)
            timeout_at = now + self._attempt_timeout
            if task.deadline is not None:
                timeout_at = min(timeout_at, task.deadline)
            with self._lock:
                self._inflight[token] = (task, rid, timeout_at)
            inner.add_done_callback(
                functools.partial(self._on_attempt_done, token, rid, task)
            )
            return True
        return False

    def _on_attempt_done(self, token: int, rid: int, task: _RouterTask, fut) -> None:
        with self._lock:
            claimed = self._inflight.pop(token, None) is not None
        if not claimed:
            return    # watchdog already abandoned this attempt; late answer moot
        rep = self._replicas[rid]
        exc = fut.exception()
        if task.att_span is not None:
            task.att_span.end(
                outcome="ok" if exc is None else type(exc).__name__)
        if exc is None:
            rep.note_success()
            resp = fut.result()
            self._finish(task, result=dataclasses.replace(
                resp, latency_s=time.perf_counter() - task.t_submit
            ))
        elif isinstance(exc, DeadlineExceeded):
            # expired in the replica's queue: terminal, and not the
            # replica's fault — no failure note
            self._finish(task, exc=exc, deadline=True)
        else:
            if not isinstance(exc, AdmissionRejected):
                rep.note_failure(self._suspect_after)
            self._retry_or_fail(task, exc)

    def _retry_or_fail(self, task: _RouterTask, exc: BaseException) -> None:
        now = time.perf_counter()
        if task.outer.done():
            return
        if task.deadline is not None and now >= task.deadline:
            self._finish(task, exc=DeadlineExceeded(
                f"deadline passed after {task.attempts} attempt(s); last: {exc!r}"
            ), deadline=True)
            return
        if self._closed or task.attempts > self.fault.max_retries:
            self._finish(task, exc=exc, exhausted=not self._closed)
            return
        self.metrics.record_failover()
        task.t_parked = now
        delay = retry_delay(self.fault, max(0, task.attempts - 1))
        with self._lock:
            heapq.heappush(self._heap, (now + delay, next(self._seq), task))

    def _finish(self, task: _RouterTask, *, result=None, exc=None,
                deadline: bool = False, exhausted: bool = False) -> bool:
        with task.lock:
            if task.outer.done():
                return False
            if exc is None:
                task.outer.set_result(result)
            else:
                task.outer.set_exception(exc)
        if exc is None:
            self.metrics.record_completed(result.latency_s)
        else:
            self.metrics.record_failed(deadline=deadline, exhausted=exhausted)
        if task.att_span is not None:
            task.att_span.end()       # idempotent: usually already closed
        if task.span is not None:
            task.span.end(
                outcome="ok" if exc is None else type(exc).__name__,
                attempts=task.attempts,
                latency_ms=(time.perf_counter() - task.t_submit) * 1e3,
            )
        return True

    # -------------------------------------------------- driver + watchdog --
    def _drive(self) -> None:
        """Pop due retries and time out unresponsive in-flight attempts."""
        while not self._stop_driver.wait(0.005):
            now = time.perf_counter()
            due: list[_RouterTask] = []
            timed_out: list[tuple] = []
            with self._lock:
                while self._heap and self._heap[0][0] <= now:
                    due.append(heapq.heappop(self._heap)[2])
                expired = [t for t, (_, _, at) in self._inflight.items() if now >= at]
                for t in expired:
                    timed_out.append(self._inflight.pop(t))
            for task in due:
                if task.outer.done():
                    continue
                if task.span is not None and task.t_parked:
                    # the failover gap: parked after a failed attempt until
                    # redispatched to the next candidate
                    self._tracer.add_span(task.span, "router.failover",
                                          task.t_parked, now,
                                          next_attempt=task.attempts + 1)
                    task.t_parked = 0.0
                if not self._try_dispatch(task):
                    task.attempts += 1    # a burnt retry, not a free spin
                    self._retry_or_fail(
                        task, AdmissionRejected("no replica available for retry")
                    )
            for task, rid, _ in timed_out:
                if task.outer.done():
                    continue
                if task.att_span is not None:
                    task.att_span.end(outcome="timeout")
                self.metrics.record_attempt_timeout()
                self._replicas[rid].note_failure(self._suspect_after)
                self._retry_or_fail(task, WorkerCrashed(
                    f"replica {rid} unresponsive: attempt exceeded "
                    f"{self._attempt_timeout * 1e3:.0f} ms"
                ))

    # ----------------------------------------------------- health monitor --
    def _monitor_loop(self) -> None:
        while not self._stop_monitor.wait(self._monitor_interval):
            self._health_tick()

    def _health_tick(self) -> None:
        now = time.perf_counter()
        for rep in self._replicas:
            gw = rep.gateway
            if rep.state == DEAD:
                continue
            if gw._batcher.closed:
                if rep.mark_dead():
                    self.metrics.record_replica_death()
                continue
            alive = gw._batcher.worker_alive
            if rep.state == HEALTHY and not alive:
                rep.state = SUSPECT      # suspected until the supervisor revives it
                # stamp the kill itself as a failure: without this, a
                # supervisor restart landing within one monitor tick would
                # satisfy the re-healthy check immediately (last_failure_t
                # still at its value from a long-past attempt failure) and
                # the suspect window — the observable health dip — would
                # collapse to milliseconds.
                rep.last_failure_t = now
            elif (
                rep.state == SUSPECT
                and alive
                and now - rep.last_failure_t >= self._healthy_after
                and gw.generation == self._target_generation
            ):
                rep.state = HEALTHY
                rep.consecutive_failures = 0
        healthy = sum(1 for rep in self._replicas if rep.state == HEALTHY)
        self.metrics.set_healthy_ratio(healthy / len(self._replicas))
        self._observe_lag()
        self._resync_lagging()

    def _observe_lag(self) -> None:
        target = self._target_generation
        lag = 0
        for rep in self._replicas:
            if rep.state != DEAD and not rep.gateway._batcher.closed:
                lag = max(lag, target - rep.gateway.generation)
        self.metrics.observe_generation_lag(lag)

    def _resync_lagging(self) -> None:
        """Re-apply the target rulebook on replicas that missed a swap (the
        stale-generation recovery path). Skipped while a coordinated swap
        holds the lock — the swap itself brings everyone current."""
        if not self._swap_lock.acquire(blocking=False):
            return
        try:
            target = self._target_generation
            rb = self._target_rulebook
            for rep in self._replicas:
                gw = rep.gateway
                if (
                    rep.state == DEAD
                    or gw._batcher.closed
                    or not gw._batcher.worker_alive
                    or gw.generation >= target
                ):
                    continue
                if self.fault_injection._should_fail_swap(rep.rid):
                    continue          # injected: stays stale, lag keeps showing
                try:
                    gw.commit_swap(gw.prepare_swap(rb, generation=target))
                    self.metrics.record_resync()
                except Exception:
                    self.metrics.record_swap_prepare_failure()
                    if rep.state == HEALTHY:
                        rep.state = SUSPECT
        finally:
            self._swap_lock.release()

    # --------------------------------------------------------- supervision --
    def _on_replica_gave_up(self, rid: int) -> None:
        """ReplicaSetSupervisor callback: restart storm → replica dead. Its
        batcher was closed, so pending futures already failed explicitly and
        the failover path re-routes them."""
        if self._replicas[rid].mark_dead():
            self.metrics.record_replica_death()

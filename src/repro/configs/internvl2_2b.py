"""InternVL2-2B [arXiv:2404.16821; hf] — InternViT (STUB frontend: precomputed
patch embeddings) + InternLM2-1.8B backbone (GQA kv=8)."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=92553,
    frontend="vlm",
    num_patches=256,
)

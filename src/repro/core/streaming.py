"""Streaming Map/Reduce mining over an on-disk transaction store.

The paper's jobs never load the DB: each map task streams its HDFS block,
emits partial counts, and a combiner folds them before the reduce. This
module is that dataflow for the miner (DESIGN.md §9): the DB lives in a
``data.store.TransactionStore`` (packed uint32 shards on disk), and each
level's count pass iterates fixed-size row chunks through the SAME jit'd
count step as the in-memory driver, **accumulating per-candidate partial
counts on device** — the combiner. The host syncs a candidate pass exactly
once, after its last chunk, so per level there is a single device→host
transfer regardless of chunk count.

Host peak RSS is bounded by O(chunk_rows · row_bytes) (plus the candidate
tensors), not the dataset size: chunks are copied out of the mmap'd shards
one at a time, and a ``data.pipeline.ShardedBatchIterator`` double-buffers
the host→device transfer so chunk assembly overlaps device counting.

Exactness: support counting is integer arithmetic and every chunk row is
either a real transaction or an inert zero row (DESIGN.md §3), so the
chunk-sum equals the whole-DB count bit-for-bit — ``mine_streamed`` /
``mine_son_streamed`` are dict-equal to ``mine`` / ``mine_son`` at any
chunk size.
"""

from __future__ import annotations

import math
from typing import TYPE_CHECKING, Callable

import jax
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core import apriori as ap
from repro.core import son as son_mod
from repro.data.pipeline import ShardedBatchIterator, batch_spec

if TYPE_CHECKING:  # import-time would cycle: data.store -> core -> streaming
    from repro.data.store import TransactionStore


def make_accum_count_step(mesh, cfg: ap.AprioriConfig) -> Callable:
    """The combiner: jit'd ``(t_chunk, c, lengths, acc) -> acc + counts``.

    Wraps :func:`core.apriori.make_count_step` (so dense/packed, jnp/Pallas
    and the mesh Map/Reduce shape are all inherited unchanged) and folds the
    chunk's counts into a device-resident int32 accumulator — partial
    aggregation happens where the data is, exactly like a Hadoop combiner.
    """
    count_step = ap.make_count_step(mesh, cfg)

    def step(t_chunk, c_dev, len_dev, acc):
        return acc + count_step(t_chunk, c_dev, len_dev)

    return jax.jit(step)


def _init_acc(kp: int, cfg: ap.AprioriConfig, mesh):
    zeros = np.zeros(kp, dtype=np.int32)
    if mesh is None:
        return jax.numpy.asarray(zeros)
    return jax.device_put(zeros, NamedSharding(mesh, P(cfg.model_axis)))


def _effective_chunk_rows(chunk_rows: int, cfg: ap.AprioriConfig, mesh) -> int:
    """Round the chunk up to a multiple of the data-shard count so every
    chunk splits evenly over P(data_axes) (padding rows are inert)."""
    if chunk_rows < 1:
        raise ValueError("chunk_rows must be >= 1")
    if mesh is None:
        return chunk_rows
    shards = math.prod(mesh.shape[a] for a in cfg.data_axes)
    return ((chunk_rows + shards - 1) // shards) * shards


def _count_pass_chunks(accum_step, chunks, c_dev, len_dev, kp, cfg, mesh, prefetch):
    """Fold every DB chunk into a fresh device accumulator; sync ONCE."""
    acc = _init_acc(kp, cfg, mesh)
    it = ShardedBatchIterator(chunks, mesh, batch_spec(cfg.data_axes), prefetch=prefetch)
    try:
        for t_chunk in it:
            acc = accum_step(t_chunk, c_dev, len_dev, acc)
    finally:
        it.close()
    return np.asarray(acc)   # the single host sync of this candidate pass


def count_supports_streamed(
    store: TransactionStore,
    cand_sets: np.ndarray,
    cfg: ap.AprioriConfig = ap.AprioriConfig(),
    mesh=None,
    chunk_rows: int = 8192,
    prefetch: int = 2,
) -> np.ndarray:
    """Exact support counts of ``cand_sets`` over an on-disk store.

    The streamed twin of the in-memory driver's per-level count: candidates
    split into ``max_candidates_per_pass`` passes padded to the same jit
    buckets; each pass streams all DB chunks through the accumulate step.
    Equals the whole-DB count exactly, for both representations, at any
    ``chunk_rows`` (including sizes that don't divide n — the final chunk
    zero-pads, and zero rows are inert).
    """
    cand_sets = np.asarray(cand_sets, dtype=np.int32)
    num_items = store.num_items
    chunk_rows = _effective_chunk_rows(chunk_rows, cfg, mesh)
    accum_step = make_accum_count_step(mesh, cfg)
    return _count_level_streamed(
        accum_step, store, cand_sets, num_items, cfg, mesh, chunk_rows, prefetch
    )


def _count_level_streamed(
    accum_step, store, cand_sets, num_items, cfg, mesh, chunk_rows, prefetch
):
    k_total = cand_sets.shape[0]
    quantum = ap._candidate_quantum(cfg, mesh)
    counts = np.zeros(k_total, dtype=np.int64)
    for start in range(0, k_total, cfg.max_candidates_per_pass):
        chunk_c = cand_sets[start : start + cfg.max_candidates_per_pass]
        kp = ap._pad_bucket(chunk_c.shape[0], quantum)
        c_dev, len_dev = ap._place_candidates(chunk_c, kp, num_items, cfg, mesh)
        chunks = (
            chunk
            for chunk, _ in store.iter_chunks(
                chunk_rows, representation=cfg.representation, pad=True
            )
        )
        out = _count_pass_chunks(
            accum_step, chunks, c_dev, len_dev, kp, cfg, mesh, prefetch
        )
        counts[start : start + chunk_c.shape[0]] = out[: chunk_c.shape[0]]
    return counts


def mine_streamed(
    store: TransactionStore,
    cfg: ap.AprioriConfig = ap.AprioriConfig(),
    mesh=None,
    chunk_rows: int = 8192,
    prefetch: int = 2,
    checkpoint_cb: Callable | None = None,
    resume_state: dict | None = None,
) -> ap.AprioriResult:
    """Level-wise Apriori over an on-disk store, dict-equal to ``mine``.

    Identical driver semantics by construction — this is
    ``core.apriori.run_level_loop`` with the count function swapped for the
    chunk-streaming accumulator. Host RSS scales with ``chunk_rows``, not
    ``store.num_transactions``; the DB is re-streamed from disk once per
    candidate pass (sequential mmap reads — the per-pass I/O the paper's
    per-level Hadoop jobs pay too).
    """
    n, num_items = store.num_transactions, store.num_items
    chunk_rows = _effective_chunk_rows(chunk_rows, cfg, mesh)
    accum_step = make_accum_count_step(mesh, cfg)

    def count_fn(cand_sets):
        return _count_level_streamed(
            accum_step, store, cand_sets, num_items, cfg, mesh, chunk_rows, prefetch
        )

    return ap.run_level_loop(count_fn, n, num_items, cfg, checkpoint_cb, resume_state)


def mine_son_streamed(
    store: TransactionStore,
    cfg: ap.AprioriConfig = ap.AprioriConfig(),
    mesh=None,
    chunk_rows: int = 8192,
    prefetch: int = 2,
) -> ap.AprioriResult:
    """SON two-phase mining over an on-disk store, dict-equal to
    ``mine_son`` (and to ``mine`` — SON is exact for any partitioning).

    Phase 1 maps over the store's *on-disk shards* as the SON partitions:
    each shard is unpacked and mined locally to completion at the
    shard-scaled threshold, one shard in RAM at a time. Phase 2 is ONE
    streamed exact count of the union — two distributed rounds total, never
    the whole DB in memory.
    """
    n, num_items = store.num_transactions, store.num_items
    min_count = max(1, math.ceil(cfg.min_support * n))
    chunk_rows = _effective_chunk_rows(chunk_rows, cfg, mesh)

    # ---- phase 1: local mining per on-disk shard, union of local winners --
    union = son_mod.union_local_winners(
        (store.partition_dense(p) for p in range(store.num_partitions)), cfg
    )

    # ---- phase 2: ONE streamed exact count of the whole union ----
    # All levels' candidate passes are device-placed up front (the union is
    # the modest survivor set, not a full level's candidates — this trades
    # the max_candidates_per_pass memory bound for a single disk scan), then
    # every DB chunk folds into every pass's accumulator: one pass over the
    # store total, the SON round-count promise kept at the I/O layer too.
    accum_step = make_accum_count_step(mesh, cfg)
    quantum = ap._candidate_quantum(cfg, mesh)
    per_level = {k: np.array(sorted(union[k]), dtype=np.int32) for k in sorted(union)}
    units = []   # (k, start, rows, c_dev, len_dev, acc)
    for k, cands in per_level.items():
        for start in range(0, cands.shape[0], cfg.max_candidates_per_pass):
            chunk_c = cands[start : start + cfg.max_candidates_per_pass]
            kp = ap._pad_bucket(chunk_c.shape[0], quantum)
            c_dev, len_dev = ap._place_candidates(chunk_c, kp, num_items, cfg, mesh)
            units.append([k, start, chunk_c.shape[0], c_dev, len_dev, _init_acc(kp, cfg, mesh)])
    if units:
        chunks = (
            chunk
            for chunk, _ in store.iter_chunks(
                chunk_rows, representation=cfg.representation, pad=True
            )
        )
        it = ShardedBatchIterator(chunks, mesh, batch_spec(cfg.data_axes), prefetch=prefetch)
        try:
            for t_chunk in it:
                for u in units:
                    u[5] = accum_step(t_chunk, u[3], u[4], u[5])
        finally:
            it.close()

    levels = {}
    for k, cands in per_level.items():
        sup = np.zeros(cands.shape[0], dtype=np.int64)
        for uk, start, rows, _, _, acc in units:
            if uk == k:
                sup[start : start + rows] = np.asarray(acc)[:rows]
        keep = sup >= min_count
        if keep.any():
            levels[k] = (cands[keep], sup[keep])
    return ap.AprioriResult(levels=levels, num_transactions=n, min_count=min_count)

import numpy as np
import pytest


@pytest.fixture(scope="session")
def small_db():
    """Small deterministic transaction DB shared across tests."""
    from repro.data.synthetic import QuestConfig, gen_transactions

    return gen_transactions(QuestConfig(num_transactions=300, num_items=32, avg_len=7, num_patterns=6, seed=7))


def brute_force_frequent(dense: np.ndarray, min_count: int, max_k: int) -> dict:
    """Oracle: exhaustive frequent-itemset mining via python sets."""
    from itertools import combinations

    rows = [frozenset(np.flatnonzero(r)) for r in dense]
    items = sorted(set().union(*rows)) if rows else []
    out = {}
    prev = {(): None}
    for k in range(1, max_k + 1):
        level = {}
        if k <= 2:
            cands = combinations(items, k)
        else:
            seeds = [set(c) for c in prev]
            cands = {tuple(sorted(s | {b})) for s in seeds for b in items if b not in s}
        for c in cands:
            cs = set(c)
            s = sum(1 for r in rows if cs <= r)
            if s >= min_count:
                level[tuple(c)] = s
        if not level:
            break
        out.update(level)
        prev = level
    return out

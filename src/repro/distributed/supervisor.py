"""Serving-side supervision: restart a dead gateway dispatch worker.

The gateway's micro-batcher runs ONE dispatch worker thread; if that thread
dies (a bug outside the per-group exception fence, an injected fault), every
queued request would hang forever — the exact failure mode the paper's
JobTracker answers by re-arming a dead TaskTracker's work. The
:class:`WorkerSupervisor` polls the worker's liveness and, on death, calls
``MicroBatcher.restart_worker()``: the futures of the batch that was
IN FLIGHT inside the dead worker are failed explicitly (with the
:class:`~repro.serving.batcher.WorkerCrashed` cause — a client sees an
error, never a hang), the admission queue is left intact and a fresh worker
thread re-arms it, and the restart lands in
``serving/metrics.py::worker_restarts``.

Scope: supervision restarts the DISPATCH LOOP, not the device state — the
rulebook generations are immutable host/device records owned by the gateway,
so a restarted worker serves the same generation bit-for-bit.
"""

from __future__ import annotations

import threading


class WorkerSupervisor:
    """Poll a gateway's dispatch worker; restart it when it dies.

    Context-managed::

        with Gateway(rb) as gw, WorkerSupervisor(gw):
            ...

    ``restarts`` counts successful restarts (also mirrored into the
    gateway's metrics by ``restart_worker`` itself).
    """

    def __init__(self, gateway, poll_interval_s: float = 0.02):
        self._batcher = gateway._batcher
        self._interval = float(poll_interval_s)
        self._stop = threading.Event()
        self.restarts = 0
        self._thread = threading.Thread(
            target=self._run, name="gateway-supervisor", daemon=True
        )
        self._thread.start()

    def _run(self) -> None:
        while not self._stop.wait(self._interval):
            if self._batcher.closed:
                continue            # shutdown is not a crash
            if not self._batcher.worker_alive:
                if self._batcher.restart_worker():
                    self.restarts += 1

    def close(self) -> None:
        self._stop.set()
        self._thread.join(timeout=5.0)

    def __enter__(self) -> "WorkerSupervisor":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()

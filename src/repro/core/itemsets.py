"""Itemset / transaction encodings.

The canonical device format is a dense {0,1} int8 matrix over the item
vocabulary: transactions (N, I) and candidate itemsets (K, I).  Containment
``c ⊆ t`` then becomes ``<t, c> == |c|``, turning support counting into an
int8 matmul with an exact int32 accumulation — the MXU-native reshape of the
paper's per-transaction subset scan (DESIGN.md §2).

A packed uint32 bitset format (N, ceil(I/32)) is the bandwidth-optimal device
format (DESIGN.md §4): containment ``c ⊆ t`` becomes per-word
``t & c == c`` on the VPU, at 1 bit per cell instead of 8–16.  Packing
helpers here are host-side NumPy; the device-side (jnp) packer lives in
``kernels.ops``.  Packed padding invariants: padded transaction rows are
all-zero words (inert), padded candidate rows are all-zero words with
``|c| = -1`` sentinels in the lengths vector (never match), and the word
axis pads with zero words on both operands (DESIGN.md §3).
"""

from __future__ import annotations

import numpy as np


def dense_from_lists(transactions, num_items: int) -> np.ndarray:
    """Lists of item ids -> dense {0,1} int8 matrix (N, num_items)."""
    out = np.zeros((len(transactions), num_items), dtype=np.int8)
    for row, items in enumerate(transactions):
        if len(items):
            idx = np.asarray(list(items), dtype=np.int64)
            if (idx < 0).any() or (idx >= num_items).any():
                raise ValueError(f"item id out of range in transaction {row}")
            out[row, idx] = 1
    return out


def itemsets_to_dense(itemsets: np.ndarray, num_items: int) -> np.ndarray:
    """(K, k) arrays of item ids -> dense {0,1} int8 matrix (K, num_items)."""
    itemsets = np.asarray(itemsets)
    if itemsets.ndim != 2:
        raise ValueError("itemsets must be (K, k)")
    k_count = itemsets.shape[0]
    out = np.zeros((k_count, num_items), dtype=np.int8)
    rows = np.repeat(np.arange(k_count), itemsets.shape[1])
    out[rows, itemsets.ravel()] = 1
    return out


def pack_bits(dense: np.ndarray) -> np.ndarray:
    """Dense {0,1} (N, I) -> packed uint32 (N, ceil(I/32)), little-endian bits."""
    dense = np.asarray(dense, dtype=np.uint8)
    n, i = dense.shape
    words = (i + 31) // 32
    padded = np.zeros((n, words * 32), dtype=np.uint8)
    padded[:, :i] = dense
    bits = padded.reshape(n, words, 32)
    shifts = np.arange(32, dtype=np.uint32)
    return (bits.astype(np.uint32) << shifts).sum(axis=2, dtype=np.uint32)


def unpack_bits(packed: np.ndarray, num_items: int) -> np.ndarray:
    """Inverse of :func:`pack_bits`."""
    packed = np.asarray(packed, dtype=np.uint32)
    n, words = packed.shape
    shifts = np.arange(32, dtype=np.uint32)
    bits = (packed[:, :, None] >> shifts) & np.uint32(1)
    return bits.reshape(n, words * 32)[:, :num_items].astype(np.int8)


def packed_words(num_items: int) -> int:
    """Number of uint32 words holding ``num_items`` bits."""
    return (num_items + 31) // 32


def itemsets_to_packed(itemsets: np.ndarray, num_items: int) -> np.ndarray:
    """(K, k) arrays of item ids -> packed uint32 bitsets (K, ceil(I/32)).

    Direct scatter into words — never materialises the (K, I) dense matrix,
    so candidate packing stays O(K·k) on the driver regardless of vocabulary
    size.
    """
    itemsets = np.asarray(itemsets)
    if itemsets.ndim != 2:
        raise ValueError("itemsets must be (K, k)")
    if itemsets.size and (itemsets.min() < 0 or itemsets.max() >= num_items):
        raise ValueError("item id out of range")
    k_count = itemsets.shape[0]
    out = np.zeros((k_count, packed_words(num_items)), dtype=np.uint32)
    rows = np.repeat(np.arange(k_count), itemsets.shape[1])
    ids = itemsets.ravel().astype(np.int64)
    np.bitwise_or.at(out, (rows, ids >> 5), np.uint32(1) << (ids & 31).astype(np.uint32))
    return out


def pad_packed(packed: np.ndarray, row_multiple: int = 1, word_multiple: int = 1) -> np.ndarray:
    """Zero-pad a packed (R, W) bitset to row/word-count multiples.

    Zero rows are inert transactions; zero words add no items — both sides of
    the ``t & c == c`` containment test are unchanged by this padding
    (candidate *row* padding must additionally carry ``|c| = -1`` in the
    lengths vector, which the caller owns).
    """
    packed = np.asarray(packed, dtype=np.uint32)
    r, w = packed.shape
    rp = (-r) % row_multiple
    wp = (-w) % word_multiple
    if rp == 0 and wp == 0:
        return packed
    return np.pad(packed, ((0, rp), (0, wp)))


def singleton_itemsets(num_items: int) -> np.ndarray:
    """All 1-itemsets, (num_items, 1)."""
    return np.arange(num_items, dtype=np.int32)[:, None]

"""Resumable streamed mining (DESIGN.md §11): checkpoint roundtrip and
crash-consistency, fingerprint validation, and the acceptance criterion —
a mine killed at an arbitrary chunk/level boundary and resumed is
dict-identical to an uninterrupted mine, including a real ``kill -9``."""

import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import streaming
from repro.core.apriori import AprioriConfig, mine
from repro.data import store as st
from repro.distributed.checkpoint import (
    COMMITTED,
    CheckpointMismatch,
    MiningCheckpoint,
    MiningState,
    mining_fingerprint,
    store_fingerprint,
)

from conftest import REPO_ROOT, subprocess_env

CFG = AprioriConfig(min_support=0.05, max_k=4, count_impl="jnp")


def _store(small_db, path, shard_rows=90):
    return st.ingest_dense(small_db, str(path), shard_rows=shard_rows)


def _fps(store, cfg=CFG, chunk_rows=64):
    return store_fingerprint(store), mining_fingerprint(cfg, chunk_rows)


# ------------------------------------------------------- manager mechanics --
def test_checkpoint_roundtrip_mid_level(tmp_path, small_db):
    s = _store(small_db, tmp_path / "db")
    sfp, mfp = _fps(s)
    mgr = MiningCheckpoint(str(tmp_path / "ck"))
    levels = {1: (np.arange(6, dtype=np.int32).reshape(6, 1),
                  np.arange(6, dtype=np.int64) + 40)}
    state = MiningState(
        levels=levels, next_k=2, mid_level=True, pass_start=8, chunks_done=3,
        counts=np.arange(20, dtype=np.int64),
        acc=np.arange(16, dtype=np.int32),
    )
    seq = mgr.save(state, sfp, mfp)
    mgr.wait()
    assert mgr.latest_seq() == seq
    got, manifest = mgr.load_latest()
    mgr.validate(manifest, sfp, mfp)    # same store + config: accepted
    assert got.next_k == 2 and got.mid_level
    assert got.pass_start == 8 and got.chunks_done == 3
    np.testing.assert_array_equal(got.counts, state.counts)
    np.testing.assert_array_equal(got.acc, state.acc)
    np.testing.assert_array_equal(got.levels[1][0], levels[1][0])
    np.testing.assert_array_equal(got.levels[1][1], levels[1][1])


def test_uncommitted_snapshot_is_invisible(tmp_path, small_db):
    """Crash-consistency: a snapshot directory without the COMMITTED marker
    (a mid-write kill) must be ignored by load_latest."""
    s = _store(small_db, tmp_path / "db")
    sfp, mfp = _fps(s)
    mgr = MiningCheckpoint(str(tmp_path / "ck"))
    mgr.save(MiningState(levels={}, next_k=1), sfp, mfp)
    mgr.wait()
    good_seq = mgr.latest_seq()
    # emulate a torn write: seq+1 exists on disk but never committed
    torn = os.path.join(mgr.path, f"ckpt_{good_seq + 1:08d}")
    os.makedirs(torn)
    with open(os.path.join(torn, "manifest.json"), "w") as f:
        json.dump({"version": 1}, f)
    assert not os.path.exists(os.path.join(torn, COMMITTED))
    assert mgr.latest_seq() == good_seq
    state, _ = mgr.load_latest()
    assert state.next_k == 1
    # a NEW manager over the same dir must also sequence past the torn dir
    mgr2 = MiningCheckpoint(mgr.path)
    assert mgr2.save(MiningState(levels={}, next_k=2), sfp, mfp) > good_seq + 1


def test_retention_keeps_newest(tmp_path, small_db):
    s = _store(small_db, tmp_path / "db")
    sfp, mfp = _fps(s)
    mgr = MiningCheckpoint(str(tmp_path / "ck"), keep=2)
    for k in range(1, 6):
        mgr.save(MiningState(levels={}, next_k=k), sfp, mfp)
    mgr.wait()
    dirs = sorted(d for d in os.listdir(mgr.path) if d.startswith("ckpt_"))
    assert len(dirs) == 2
    state, _ = mgr.load_latest()
    assert state.next_k == 5


@pytest.mark.parametrize("what", ["store", "config", "chunk_rows"])
def test_validate_rejects_foreign_checkpoint(tmp_path, small_db, what):
    """Resuming against a different store, result-affecting config, or
    chunking is an explicit CheckpointMismatch, never a silent wrong answer."""
    s = _store(small_db, tmp_path / "db")
    sfp, mfp = _fps(s)
    mgr = MiningCheckpoint(str(tmp_path / "ck"))
    mgr.save(MiningState(levels={}, next_k=2), sfp, mfp)
    mgr.wait()
    _, manifest = mgr.load_latest()
    if what == "store":
        other = _store(small_db[:200], tmp_path / "db2")
        sfp = store_fingerprint(other)
    elif what == "config":
        import dataclasses

        mfp = mining_fingerprint(dataclasses.replace(CFG, min_support=0.1), 64)
    else:
        mfp = mining_fingerprint(CFG, 77)
    with pytest.raises(CheckpointMismatch):
        mgr.validate(manifest, sfp, mfp)


def test_clear_drops_all_snapshots(tmp_path, small_db):
    s = _store(small_db, tmp_path / "db")
    sfp, mfp = _fps(s)
    mgr = MiningCheckpoint(str(tmp_path / "ck"))
    mgr.save(MiningState(levels={}, next_k=1), sfp, mfp)
    mgr.clear()
    assert mgr.load_latest() is None


# ------------------------------------------------- in-process kill + resume --
class _Interrupt(BaseException):
    """Out-of-band stop that no library code catches."""


class _Killing(MiningCheckpoint):
    """Commits ``stop_after`` snapshots, then dies — the in-process stand-in
    for a node loss at an arbitrary checkpoint boundary."""

    def __init__(self, path, stop_after):
        super().__init__(path)
        self.stop_after = stop_after
        self.saves = 0

    def save(self, state, store_fp, mine_fp):
        seq = super().save(state, store_fp, mine_fp)
        self.saves += 1
        if self.saves >= self.stop_after:
            self.wait()   # the snapshot is committed; NOW the "node" dies
            raise _Interrupt()
        return seq


@pytest.mark.parametrize("rep", ["dense", "packed"])
@pytest.mark.parametrize("stop_after", [1, 2, 3, 5, 8])
def test_killed_and_resumed_mine_is_dict_identical(tmp_path, small_db, rep, stop_after):
    """The acceptance criterion: interrupt at the Nth committed snapshot
    (mid-level cursors and level boundaries alike, both representations),
    resume from disk, and the result is dict-identical to an uninterrupted
    mine AND to the in-memory driver."""
    import dataclasses

    cfg = dataclasses.replace(CFG, representation=rep)
    s = _store(small_db, tmp_path / "db")
    want = streaming.mine_streamed(s, cfg, chunk_rows=64)
    assert want.as_dict() == mine(small_db, cfg).as_dict()

    ck = str(tmp_path / "ck")
    killer = _Killing(ck, stop_after)
    with pytest.raises(_Interrupt):
        streaming.mine_streamed(
            s, cfg, chunk_rows=64, checkpoint=killer, checkpoint_every_chunks=1
        )
    assert MiningCheckpoint(ck).load_latest() is not None
    got = streaming.mine_streamed(
        s, cfg, chunk_rows=64, checkpoint=MiningCheckpoint(ck),
        checkpoint_every_chunks=1, resume=True,
    )
    assert got.as_dict() == want.as_dict()
    assert got.min_count == want.min_count


def test_level_boundary_only_checkpoint_resumes(tmp_path, small_db):
    """checkpoint_every_chunks=0: snapshots land at level boundaries only;
    a resume restores the completed levels and re-mines the rest."""
    s = _store(small_db, tmp_path / "db")
    want = streaming.mine_streamed(s, CFG, chunk_rows=64)
    ck = str(tmp_path / "ck")
    killer = _Killing(ck, stop_after=2)     # dies after committing level 2
    with pytest.raises(_Interrupt):
        streaming.mine_streamed(s, CFG, chunk_rows=64, checkpoint=killer)
    state, _ = MiningCheckpoint(ck).load_latest()
    assert not state.mid_level and state.next_k == 3
    got = streaming.mine_streamed(
        s, CFG, chunk_rows=64, checkpoint=MiningCheckpoint(ck), resume=True
    )
    assert got.as_dict() == want.as_dict()


def test_resume_rejects_changed_chunking(tmp_path, small_db):
    s = _store(small_db, tmp_path / "db")
    ck = str(tmp_path / "ck")
    killer = _Killing(ck, stop_after=3)
    with pytest.raises(_Interrupt):
        streaming.mine_streamed(
            s, CFG, chunk_rows=64, checkpoint=killer, checkpoint_every_chunks=1
        )
    with pytest.raises(CheckpointMismatch):
        streaming.mine_streamed(
            s, CFG, chunk_rows=77, checkpoint=MiningCheckpoint(ck),
            checkpoint_every_chunks=1, resume=True,
        )


def test_resume_without_manager_raises(tmp_path, small_db):
    s = _store(small_db, tmp_path / "db")
    with pytest.raises(ValueError, match="resume"):
        streaming.mine_streamed(s, CFG, resume=True)


def test_resume_with_empty_dir_mines_from_scratch(tmp_path, small_db):
    """resume=True against a checkpoint dir with no committed snapshot is a
    cold start, not an error — the operator retry loop stays uniform."""
    s = _store(small_db, tmp_path / "db")
    got = streaming.mine_streamed(
        s, CFG, chunk_rows=64, checkpoint=str(tmp_path / "ck"), resume=True
    )
    assert got.as_dict() == mine(small_db, CFG).as_dict()


def test_fresh_mine_clears_stale_snapshots(tmp_path, small_db):
    """A NON-resume checkpointed mine must not leave older-mine snapshots
    interleaved under the same sequence line."""
    s = _store(small_db, tmp_path / "db")
    ck = str(tmp_path / "ck")
    stale = MiningCheckpoint(ck)
    stale.save(MiningState(levels={}, next_k=9), *_fps(s))
    stale.wait()
    streaming.mine_streamed(s, CFG, chunk_rows=64, checkpoint=ck)
    state, _ = MiningCheckpoint(ck).load_latest()
    assert state.next_k != 9    # the stale snapshot is gone


# ------------------------------------------------------ kill -9 subprocess --
_KILL9 = textwrap.dedent(
    """
    import json, os, signal, sys
    import numpy as np
    from repro.core.apriori import AprioriConfig
    from repro.core.streaming import mine_streamed
    from repro.data.store import ingest_quest, open_store
    from repro.data.synthetic import QuestConfig
    from repro.distributed.checkpoint import MiningCheckpoint, MiningState

    mode, d = sys.argv[1], sys.argv[2]
    cfg = AprioriConfig(min_support=0.03, max_k=3, count_impl="jnp")
    if mode == "prep":
        ingest_quest(QuestConfig(2000, 64, avg_len=9, seed=11), d, shard_rows=256)
    else:
        store = open_store(d)
        if mode == "plain":
            res = mine_streamed(store, cfg, chunk_rows=128)
        elif mode == "kill":
            class Killing(MiningCheckpoint):
                def save(self, state, sfp, mfp):
                    seq = super().save(state, sfp, mfp)
                    if state.mid_level and state.next_k >= 2:
                        self.wait()
                        os.kill(os.getpid(), signal.SIGKILL)
                    return seq
            mine_streamed(store, cfg, chunk_rows=128,
                          checkpoint=Killing(store.checkpoint_path),
                          checkpoint_every_chunks=2)
            raise SystemExit("unreachable: SIGKILL must have fired")
        else:   # resume
            assert MiningCheckpoint(store.checkpoint_path).load_latest() is not None
            res = mine_streamed(store, cfg, chunk_rows=128, checkpoint=True,
                                checkpoint_every_chunks=2, resume=True)
        sig = {k: [v[0].tolist(), v[1].tolist()] for k, v in sorted(res.levels.items())}
        print("SIG", json.dumps(sig, sort_keys=True))
    """
)


def test_kill9_subprocess_resume_parity(tmp_path):
    """A real ``kill -9`` mid-level (no atexit, no finally) and a resume in a
    FRESH process reproduce the uninterrupted mine exactly."""
    def run(mode, check=True):
        proc = subprocess.run(
            [sys.executable, "-c", _KILL9, mode, str(tmp_path / "db")],
            capture_output=True, text=True, timeout=600,
            env=subprocess_env(), cwd=REPO_ROOT,
        )
        if check:
            assert proc.returncode == 0, proc.stderr[-3000:]
        return proc

    run("prep")
    plain = run("plain").stdout
    killed = run("kill", check=False)
    assert killed.returncode == -9, (killed.returncode, killed.stderr[-2000:])
    assert "SIG" not in killed.stdout            # it really died mid-mine
    resumed = run("resume").stdout
    want = plain[plain.index("SIG"):].strip()
    got = resumed[resumed.index("SIG"):].strip()
    assert got == want

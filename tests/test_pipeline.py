"""data.pipeline.ShardedBatchIterator: iteration semantics and the
close()-terminates-the-worker regression (the seed's close() only set the
stop event — a worker blocked in a full queue's put() never rechecked it
and leaked forever)."""

import itertools
import time

import numpy as np
import pytest

from repro.data.pipeline import ShardedBatchIterator, batch_spec


def _batches(n, rows=4, cols=3):
    for i in range(n):
        yield np.full((rows, cols), i, dtype=np.int32)


def test_iterates_all_batches_in_order():
    it = ShardedBatchIterator(_batches(5), None, batch_spec())
    got = [int(np.asarray(b)[0, 0]) for b in it]
    assert got == [0, 1, 2, 3, 4]
    assert not it._thread.is_alive()


def test_close_joins_blocked_worker():
    """Regression: the worker fills the prefetch queue, the consumer stops
    taking, close() must still terminate and join the thread."""
    it = ShardedBatchIterator(_batches(10_000), None, batch_spec(), prefetch=2)
    next(it)   # worker is now (or will be) blocked in a full-queue put
    time.sleep(0.05)
    it.close()
    assert not it._thread.is_alive(), "close() must join the worker thread"
    # iteration after close terminates instead of hanging
    assert list(itertools.islice(it, 5)) == []


def test_close_on_infinite_generator():
    def forever():
        i = 0
        while True:
            yield np.full((2, 2), i, np.int32)
            i += 1

    it = ShardedBatchIterator(forever(), None, batch_spec(), prefetch=3)
    for _ in range(4):
        next(it)
    it.close()
    assert not it._thread.is_alive()


def test_context_manager_closes():
    with ShardedBatchIterator(_batches(100), None, batch_spec()) as it:
        next(it)
    assert not it._thread.is_alive()


def test_worker_exception_propagates_to_consumer():
    """A generator failure mid-stream must raise at the consumer, not look
    like a clean (short) end-of-stream — streamed counts would silently
    undercount otherwise."""

    def broken():
        yield np.zeros((2, 2), np.int32)
        raise OSError("shard read failed")

    it = ShardedBatchIterator(broken(), None, batch_spec())
    next(it)
    with pytest.raises(OSError, match="shard read failed"):
        next(it)
    assert not it._thread.is_alive()


def test_close_idempotent_and_reentrant():
    it = ShardedBatchIterator(_batches(50), None, batch_spec())
    it.close()
    it.close()
    assert not it._thread.is_alive()

from repro.serving.serve_loop import make_prefill_step, make_decode_step, generate
from repro.serving.rulebook import Rulebook, compile_rulebook, place_rulebook
from repro.serving.recommend import (
    RecommendResult,
    make_match_step,
    pack_baskets,
    recommend,
    recommend_python,
)

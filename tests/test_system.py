"""End-to-end behaviour tests for the paper's system (mining pipeline +
corpus adapter + rule extraction as one KDD flow)."""

import numpy as np

from repro.core.apriori import AprioriConfig, mine
from repro.core.rules import extract_rules
from repro.data.corpus import transactions_from_tokens
from repro.data.synthetic import QuestConfig, gen_transactions


def test_end_to_end_kdd_flow():
    """selection -> mining -> rules, as in the paper's Figure 1 pipeline."""
    db = gen_transactions(QuestConfig(num_transactions=1000, num_items=64, avg_len=9, seed=3))
    res = mine(db, AprioriConfig(min_support=0.05, max_k=5, count_impl="jnp"))
    assert res.total_frequent > 0
    assert 2 in res.levels  # structure exists: patterns produce co-occurrence
    rules = extract_rules(res, min_confidence=0.7)
    assert all(r.confidence >= 0.7 for r in rules)
    # downward closure: every subset of a frequent itemset is frequent
    d = res.as_dict()
    for itemset in list(d)[:200]:
        if len(itemset) >= 2:
            for drop in range(len(itemset)):
                sub = tuple(x for j, x in enumerate(itemset) if j != drop)
                assert sub in d and d[sub] >= d[itemset]


def test_corpus_mining_flow():
    """LM-corpus -> transactions -> frequent token sets (DESIGN.md §4 form 1)."""
    rng = np.random.default_rng(0)
    # synthetic corpus with a planted bigram-set structure
    base = rng.integers(0, 100, size=20_000)
    base[::7] = 3
    base[1::7] = 5  # tokens 3,5 co-occur in most windows
    dense, vocab = transactions_from_tokens(base, window=32, num_items=64)
    assert dense.shape[1] == 64
    res = mine(dense, AprioriConfig(min_support=0.5, max_k=3, count_impl="jnp"))
    d = res.as_dict()
    i3 = int(np.where(vocab == 3)[0][0])
    i5 = int(np.where(vocab == 5)[0][0])
    assert tuple(sorted((i3, i5))) in d, "planted co-occurrence not mined"


def test_determinism():
    db = gen_transactions(QuestConfig(num_transactions=200, num_items=32, seed=9))
    cfg = AprioriConfig(min_support=0.1, max_k=4, count_impl="jnp")
    assert mine(db, cfg).as_dict() == mine(db, cfg).as_dict()

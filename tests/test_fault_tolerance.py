"""Retryable SON partitions (DESIGN.md §11): the bounded-retry /
speculative work queue in isolation, and mine_son_streamed through it —
injected map-task failures must not change the mined itemsets, exhausted
retries must name the partition, skips must be explicit."""

import threading
import time

import numpy as np
import pytest

from repro.core import streaming
from repro.core.apriori import AprioriConfig, mine
from repro.data import store as st
from repro.distributed.fault_tolerance import (
    FaultConfig,
    FaultReport,
    InjectedFailure,
    PartitionFailure,
    run_partitions,
)

CFG = AprioriConfig(min_support=0.05, max_k=4, count_impl="jnp")


def _store(small_db, path, shard_rows=80):
    return st.ingest_dense(small_db, str(path), shard_rows=shard_rows)


def _fail_at(*fail_attempts):
    """Injector raising on the given (partition, attempt) pairs."""

    def injector(partition, attempt):
        if (partition, attempt) in fail_attempts:
            raise InjectedFailure(f"injected loss of partition {partition}")

    return injector


# ----------------------------------------------------------- the executor --
def test_run_partitions_no_faults():
    results, report = run_partitions(lambda p: p * p, 7, FaultConfig(max_workers=3))
    assert results == [p * p for p in range(7)]
    assert report.completed == 7 and report.retries == 0
    assert report.skipped == () and report.total_failures == 0
    assert report.attempts == {p: 1 for p in range(7)}


def test_run_partitions_empty():
    results, report = run_partitions(lambda p: p, 0)
    assert results == [] and report.completed == 0


def test_retries_with_backoff_then_success():
    fault = FaultConfig(max_retries=2, backoff_s=0.001,
                        failure_injector=_fail_at((2, 0), (2, 1), (4, 0)))
    results, report = run_partitions(lambda p: p + 100, 6, fault)
    assert results == [p + 100 for p in range(6)]
    assert report.retries == 3
    assert report.attempts[2] == 3 and report.attempts[4] == 2
    assert report.skipped == ()


def test_exhausted_raises_naming_partition():
    fault = FaultConfig(max_retries=1, backoff_s=0.001,
                        failure_injector=_fail_at((3, 0), (3, 1)))
    with pytest.raises(PartitionFailure, match="partition 3") as ei:
        run_partitions(lambda p: p, 5, fault)
    assert ei.value.partition == 3
    assert ei.value.attempts == 2
    assert isinstance(ei.value.cause, InjectedFailure)


def test_skip_mode_records_explicit_gap():
    fault = FaultConfig(max_retries=1, backoff_s=0.001, on_exhausted="skip",
                        failure_injector=_fail_at((3, 0), (3, 1)))
    results, report = run_partitions(lambda p: p * 10, 5, fault)
    assert results[3] is None
    assert [r for i, r in enumerate(results) if i != 3] == [0, 10, 20, 40]
    assert report.skipped == (3,)
    assert report.total_failures >= 1


def test_worker_exception_is_retried_like_injection():
    """A real worker_fn exception (shard read error) goes through the same
    retry policy as an injected one."""
    calls = {}

    def flaky(p):
        calls[p] = calls.get(p, 0) + 1
        if p == 1 and calls[p] == 1:
            raise OSError("shard read failed")
        return p

    results, report = run_partitions(flaky, 4, FaultConfig(backoff_s=0.001))
    assert results == [0, 1, 2, 3]
    assert report.retries == 1 and calls[1] == 2


def test_speculative_reissue_of_straggler():
    """A partition stuck far past the median completed-task time is re-issued
    to an idle worker; the re-execution's (fast) completion wins and the
    stuck twin's late result is discarded."""
    release = threading.Event()
    calls = {}
    lock = threading.Lock()

    def worker(p):
        with lock:
            calls[p] = calls.get(p, 0) + 1
            first = calls[p] == 1
        if p == 0 and first:
            # the straggling original copy: parked until its backup finishes
            # (run_partitions joins every worker, so the BACKUP must be the
            # one to unpark it — exactly the node-bound-straggler shape)
            release.wait(timeout=30)
            time.sleep(0.2)              # lose the completion race for sure
            return (p, "slow")
        if p == 0:
            release.set()                # backup done -> unpark the original
        return (p, "fast")

    fault = FaultConfig(max_workers=2, speculative=True, speculative_factor=2.0)
    results, report = run_partitions(worker, 4, fault)
    assert report.speculative_issued >= 1
    assert calls[0] >= 2                       # a backup copy really ran
    assert results[0] == (0, "fast")           # first completion won
    assert [r[0] for r in results] == [0, 1, 2, 3]
    assert report.completed == 4


def test_fault_config_validation():
    with pytest.raises(ValueError):
        FaultConfig(max_retries=-1)
    with pytest.raises(ValueError):
        FaultConfig(max_workers=0)
    with pytest.raises(ValueError):
        FaultConfig(on_exhausted="explode")
    r = FaultReport(attempts={0: 2}, retries=1, skipped=(3,))
    j = r.to_json()
    assert j["attempts"] == {0: 2} and j["retries"] == 1 and j["skipped"] == [3]


# -------------------------------------------- mine_son_streamed through it --
def test_son_injected_failures_same_itemsets(tmp_path, small_db):
    """The acceptance criterion: a SON mine whose phase-1 map tasks fail and
    are re-executed returns EXACTLY the itemsets of a fault-free mine, with
    the retries counted in the published report."""
    want = mine(small_db, CFG)
    s = _store(small_db, tmp_path / "db")
    assert s.num_partitions >= 4
    clean = streaming.mine_son_streamed(s, CFG, chunk_rows=64)
    assert clean.as_dict() == want.as_dict()

    fault = FaultConfig(max_retries=2, backoff_s=0.001, max_workers=2,
                        failure_injector=_fail_at((0, 0), (0, 1), (3, 0)))
    got = streaming.mine_son_streamed(s, CFG, chunk_rows=64, fault=fault)
    assert got.as_dict() == want.as_dict()
    assert got.fault_report is not None
    assert got.fault_report.retries == 3
    assert got.fault_report.skipped == ()
    assert got.fault_report.completed == s.num_partitions


def test_son_fault_free_executor_matches_plain(tmp_path, small_db):
    """The retrying executor with no injected faults is a pure pass-through:
    same dict, all partitions single-attempt."""
    s = _store(small_db, tmp_path / "db")
    got = streaming.mine_son_streamed(
        s, CFG, chunk_rows=64, fault=FaultConfig(max_workers=3))
    assert got.as_dict() == mine(small_db, CFG).as_dict()
    assert got.fault_report.retries == 0
    assert got.fault_report.attempts == {p: 1 for p in range(s.num_partitions)}


def test_son_exhausted_retries_names_partition(tmp_path, small_db):
    s = _store(small_db, tmp_path / "db")
    fault = FaultConfig(max_retries=1, backoff_s=0.001,
                        failure_injector=_fail_at((1, 0), (1, 1)))
    with pytest.raises(PartitionFailure, match="partition 1"):
        streaming.mine_son_streamed(s, CFG, chunk_rows=64, fault=fault)


def test_son_skip_mode_reports_gap_explicitly(tmp_path, small_db):
    """on_exhausted='skip': the mine completes but the dropped partition is
    in the report — SON's no-false-negative guarantee needs every partition,
    so the gap must never be silent."""
    s = _store(small_db, tmp_path / "db")
    fault = FaultConfig(max_retries=0, backoff_s=0.001, on_exhausted="skip",
                        failure_injector=_fail_at((2, 0)))
    got = streaming.mine_son_streamed(s, CFG, chunk_rows=64, fault=fault)
    assert got.fault_report.skipped == (2,)
    # phase 2 still counts every surviving candidate exactly over the FULL
    # db: whatever IS reported is a true frequent itemset with its true
    # support (the gap can only lose candidates, never corrupt counts)
    want = mine(small_db, CFG).as_dict()
    got_d = got.as_dict()
    assert got_d
    for itemset, sup in got_d.items():
        assert want[itemset] == sup


def test_son_shard_read_error_retried(tmp_path, small_db, monkeypatch):
    """A transient shard READ failure (not an injector) is retried by
    re-loading the shard — the HDFS-split re-execution story end to end."""
    s = _store(small_db, tmp_path / "db")
    want = streaming.mine_son_streamed(s, CFG, chunk_rows=64)
    calls = {}
    orig = s.partition_dense

    def flaky(p):
        calls[p] = calls.get(p, 0) + 1
        if p == 2 and calls[p] == 1:
            raise OSError("shard 2 read failed")
        return orig(p)

    monkeypatch.setattr(s, "partition_dense", flaky)
    got = streaming.mine_son_streamed(
        s, CFG, chunk_rows=64,
        fault=FaultConfig(max_retries=2, backoff_s=0.001))
    assert got.as_dict() == want.as_dict()
    assert got.fault_report.retries == 1
    assert calls[2] == 2

"""Pallas TPU kernel: candidate-support counting over packed uint32 bitsets.

The dense kernel (``support_count.py``) spends MXU flops and HBM bandwidth on
a {0,1} matrix that carries one bit of information per 8–16-bit cell.  This
kernel is the roofline-correct representation (DESIGN.md §4): transactions
and candidates are packed little-endian into uint32 words, shrinking the item
axis 8–32× in bytes, and containment is a VPU bitwise test instead of a
matmul::

    c ⊆ t   ⟺   ∀w: t[n,w] & c[k,w] == c[k,w]
            ⟺   Σ_w popcount(t[n,w] & c[k,w]) == |c_k|      (popcount mode)

Grid = (K/bk, N/bn, W/bw), word-slabs innermost so a VMEM scratch accumulator
(`bn × bk` int32) carries the per-pair word state across W tiles; at the last
W tile the epilogue folds per-transaction containment into the output block,
which is revisited (accumulated) across the N grid dimension — the same
revisit/accumulate structure as the dense kernel, so the two are drop-in
interchangeable behind ``kernels.ops``.

Two containment modes:
  * ``and_cmp`` (default): the accumulator counts *violated* words
    (``t & c != c``); a candidate is contained iff zero violations.  Pure
    bitwise AND + compare — the cheapest VPU path.
  * ``popcount``: the accumulator sums intersection popcounts and the
    epilogue compares against ``|c|`` — bit-for-bit the dense kernel's
    semantics, useful for cross-checking and for future weighted variants.

Padding semantics match the dense kernel exactly: padded transactions are
zero rows (zero words — inert: any real candidate has a set bit they lack);
padded candidates are zero rows with ``len = -1`` (``and_cmp`` masks them via
``len >= 0``, ``popcount`` can never reach -1).  The word axis pads with zero
words on both operands, which perturbs neither test.

Contract (same as the dense kernel): ``lengths[k]`` must equal the true
popcount of ``c_packed[k]`` (or -1 for padding).  The modes diverge only on
*inconsistent* inputs — e.g. a zero-bit candidate labelled ``len = 1`` is
"contained nowhere" under dense/``popcount`` but "contained everywhere"
under ``and_cmp``, which never inspects the length's magnitude.

The per-tile word loop is a *static* Python unroll over ``block_w`` lane
slices — no dynamic lane indexing, which keeps the Mosaic lowering to plain
VPU ops.  VMEM per step = bn·bw·4 + bk·bw·4 + bn·bk·4; defaults
(256, 256, 8) give ≈ 0.27 MB, far under budget, leaving room for double
buffering.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

MODES = ("and_cmp", "popcount")


def _kernel(t_ref, c_ref, len_ref, out_ref, acc_ref, *, block_w, mode):
    w = pl.program_id(2)
    n = pl.program_id(1)
    num_w = pl.num_programs(2)

    @pl.when(w == 0)
    def _zero_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    t = t_ref[...]  # (bn, bw) uint32
    c = c_ref[...]  # (bk, bw) uint32

    # Static unroll over the word slab: each step is an outer (bn, bk)
    # broadcast of one transaction word column against one candidate word row.
    acc = acc_ref[...]
    for j in range(block_w):
        tw = t[:, j : j + 1]        # (bn, 1)
        cw = c[:, j : j + 1].T      # (1, bk)
        inter = tw & cw
        if mode == "popcount":
            acc += jax.lax.population_count(inter).astype(jnp.int32)
        else:
            acc += (inter != cw).astype(jnp.int32)  # violated words
    acc_ref[...] = acc

    @pl.when(w == num_w - 1)
    def _epilogue():
        lengths = len_ref[...]  # (1, bk) int32
        if mode == "popcount":
            contained = acc_ref[...] == lengths
        else:
            contained = (acc_ref[...] == 0) & (lengths >= 0)
        cnt = contained.astype(jnp.int32).sum(axis=0, keepdims=True)  # (1, bk)

        @pl.when(n == 0)
        def _init():
            out_ref[...] = cnt

        @pl.when(n > 0)
        def _accum():
            out_ref[...] += cnt


@functools.partial(
    jax.jit,
    static_argnames=("block_n", "block_k", "block_w", "mode", "interpret"),
)
def support_count_packed_pallas(
    t_packed: jax.Array,
    c_packed: jax.Array,
    lengths: jax.Array,
    *,
    block_n: int = 256,
    block_k: int = 256,
    block_w: int = 8,
    mode: str = "and_cmp",
    interpret: bool = False,
) -> jax.Array:
    """Counts for pre-padded packed operands: N % block_n == K % block_k ==
    W % block_w == 0 (use kernels.ops.support_count_packed for the
    padding/packing wrapper).
    """
    n, w = t_packed.shape
    k, w2 = c_packed.shape
    assert w == w2 and lengths.shape == (k,)
    assert t_packed.dtype == jnp.uint32 and c_packed.dtype == jnp.uint32
    assert n % block_n == 0 and k % block_k == 0 and w % block_w == 0, (
        f"operands must be pre-padded: {(n, k, w)} vs blocks {(block_n, block_k, block_w)}"
    )
    if mode not in MODES:
        raise ValueError(f"mode must be one of {MODES}, got {mode!r}")

    len2d = lengths.astype(jnp.int32).reshape(1, k)
    grid = (k // block_k, n // block_n, w // block_w)
    out = pl.pallas_call(
        functools.partial(_kernel, block_w=block_w, mode=mode),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, block_w), lambda kk, nn, ww: (nn, ww)),
            pl.BlockSpec((block_k, block_w), lambda kk, nn, ww: (kk, ww)),
            pl.BlockSpec((1, block_k), lambda kk, nn, ww: (0, kk)),
        ],
        out_specs=pl.BlockSpec((1, block_k), lambda kk, nn, ww: (0, kk)),
        out_shape=jax.ShapeDtypeStruct((1, k), jnp.int32),
        scratch_shapes=[pltpu.VMEM((block_n, block_k), jnp.int32)],
        interpret=interpret,
    )(t_packed, c_packed, len2d)
    return out.reshape(k)

"""Unified decoder LM covering all assigned architecture families.

Families (ModelConfig.block_type):
  attn         — dense / MoE transformer (GQA or MLA attention)
  mamba2       — SSD backbone (attention-free)
  rwkv6        — RWKV-6 time-mix / channel-mix (attention-free)
  zamba_hybrid — Mamba2 backbone + ONE weight-shared attn+FFN block applied
                 every `share_every` layers (Zamba2 pattern)

Layers are stacked with a leading L dim (vmap'd init) and driven by
``lax.scan`` so HLO size is O(1) in depth; ``cfg.remat`` wraps the block body
in ``jax.checkpoint``. Frontends: 'tokens', 'frames' (audio stub: precomputed
frame embeddings), 'vlm' (stub patch embeddings prepended to token embeds).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import mamba2 as m2
from repro.models import moe as moe_mod
from repro.models import rwkv6 as rk
from repro.models.config import ModelConfig
from repro.models.shard_ctx import constrain
from repro.models.layers import (
    dense_init,
    embed_init,
    ffn_init,
    ffn_apply,
    norm_apply,
    norm_init,
    vzero,
)


def _cdt(cfg):
    return jnp.dtype(cfg.compute_dtype)


# ================================================================ blocks ====
def _dense_block_init(key, cfg: ModelConfig):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {"ln1": norm_init(cfg.norm, cfg.d_model), "ln2": norm_init(cfg.norm, cfg.d_model)}
    p["attn"] = attn.mla_init(k1, cfg) if cfg.attn_type == "mla" else attn.gqa_init(k1, cfg)
    p["mlp"] = moe_mod.moe_init(k3, cfg) if cfg.moe else ffn_init(k2, cfg.d_model, cfg.d_ff, cfg.act)
    return p


def _dense_block_apply(p, x, cfg: ModelConfig):
    h = norm_apply(cfg.norm, p["ln1"], x)
    h = attn.mla_apply(p["attn"], h, cfg) if cfg.attn_type == "mla" else attn.gqa_apply(p["attn"], h, cfg)
    x = x + h
    h = norm_apply(cfg.norm, p["ln2"], x)
    if cfg.moe:
        out, aux = moe_mod.moe_apply(p["mlp"], h, cfg)
    else:
        out, aux = ffn_apply(p["mlp"], h, cfg.act), jnp.float32(0)
    return x + out, aux


def _dense_block_prefill(p, x, cfg, cache_len):
    h = norm_apply(cfg.norm, p["ln1"], x)
    if cfg.attn_type == "mla":
        h, cache = attn.mla_prefill(p["attn"], h, cfg, cache_len)
    else:
        h, cache = attn.gqa_prefill(p["attn"], h, cfg, cache_len)
    x = x + h
    h = norm_apply(cfg.norm, p["ln2"], x)
    if cfg.moe:
        out, _ = moe_mod.moe_apply(p["mlp"], h, cfg)
    else:
        out = ffn_apply(p["mlp"], h, cfg.act)
    return x + out, cache


def _dense_block_decode(p, x, cfg, cache, pos):
    h = norm_apply(cfg.norm, p["ln1"], x)
    if cfg.attn_type == "mla":
        h, cache = attn.mla_decode(p["attn"], h, cfg, cache, pos)
    else:
        h, cache = attn.gqa_decode(p["attn"], h, cfg, cache, pos)
    x = x + h
    h = norm_apply(cfg.norm, p["ln2"], x)
    if cfg.moe:
        out, _ = moe_mod.moe_apply(p["mlp"], h, cfg, no_drop=True)  # serving never drops
    else:
        out = ffn_apply(p["mlp"], h, cfg.act)
    return x + out, cache


def _mamba_block_init(key, cfg):
    return {"ln": norm_init(cfg.norm, cfg.d_model), "mix": m2.mamba2_init(key, cfg)}


def _mamba_block_apply(p, x, cfg):
    return x + m2.mamba2_apply(p["mix"], norm_apply(cfg.norm, p["ln"], x), cfg), jnp.float32(0)


def _mamba_block_decode(p, x, cfg, state):
    y, state = m2.mamba2_decode(p["mix"], norm_apply(cfg.norm, p["ln"], x), cfg, state)
    return x + y, state


def _rwkv_block_init(key, cfg):
    p = rk.rwkv6_init(key, cfg)
    p["ln1"] = norm_init("layernorm", cfg.d_model)
    p["ln2"] = norm_init("layernorm", cfg.d_model)
    return p


def _rwkv_block_apply(p, x, cfg):
    h = norm_apply("layernorm", p["ln1"], x)
    x = x + rk.timemix_apply(p["tm"], h, rk.shift_tokens(h), cfg)
    h = norm_apply("layernorm", p["ln2"], x)
    x = x + rk.channelmix_apply(p["cm"], h, rk.shift_tokens(h))
    return x, jnp.float32(0)


def _rwkv_block_decode(p, x, cfg, state):
    h = norm_apply("layernorm", p["ln1"], x)
    y, tm_shift, wkv = rk.timemix_decode(p["tm"], h, state["tm_shift"], state["wkv"], cfg)
    x = x + y
    h = norm_apply("layernorm", p["ln2"], x)
    y, cm_shift = rk.channelmix_decode(p["cm"], h, state["cm_shift"])
    x = x + y
    return x, {"tm_shift": tm_shift, "cm_shift": cm_shift, "wkv": wkv}


# ============================================================== assembly ====
def _stacked_init(key, n, init_fn):
    keys = jax.random.split(key, n)
    return jax.vmap(init_fn)(keys)


def init_model(key, cfg: ModelConfig):
    kb, ke, kh, ks = jax.random.split(key, 4)
    params = {"final_ln": norm_init(cfg.norm, cfg.d_model)}
    if cfg.frontend in ("tokens", "vlm"):
        params["embed"] = embed_init(ke, cfg.vocab_size, cfg.d_model)
    if not cfg.tie_embeddings:
        params["head"] = dense_init(kh, (cfg.d_model, cfg.vocab_size))

    if cfg.block_type == "attn":
        params["blocks"] = _stacked_init(kb, cfg.num_layers, lambda k: _dense_block_init(k, cfg))
    elif cfg.block_type == "mamba2":
        params["blocks"] = _stacked_init(kb, cfg.num_layers, lambda k: _mamba_block_init(k, cfg))
    elif cfg.block_type == "rwkv6":
        params["blocks"] = _stacked_init(kb, cfg.num_layers, lambda k: _rwkv_block_init(k, cfg))
    elif cfg.block_type == "zamba_hybrid":
        assert cfg.num_layers % cfg.share_every == 0
        groups = cfg.num_layers // cfg.share_every
        flat = _stacked_init(kb, cfg.num_layers, lambda k: _mamba_block_init(k, cfg))
        params["blocks"] = jax.tree.map(
            lambda a: a.reshape(groups, cfg.share_every, *a.shape[1:]), flat
        )
        params["shared"] = _dense_block_init(ks, cfg)   # ONE weight-shared block
    else:
        raise ValueError(cfg.block_type)
    return params


def _maybe_remat(fn, cfg):
    return jax.checkpoint(fn) if cfg.remat else fn


def _run_stack(stacked, x, body, cfg):
    body = _maybe_remat(body, cfg)

    def step(carry, p):
        h, aux = carry
        y, a = body(p, h)
        return (constrain(y, "hidden"), aux + a), None

    (x, aux), _ = jax.lax.scan(step, (x, jnp.float32(0) + vzero(x)), stacked)
    return x, aux


def _embed_input(params, cfg, batch):
    dt = _cdt(cfg)
    if cfg.frontend == "tokens":
        x = params["embed"]["table"].astype(dt)[batch["tokens"]]
    elif cfg.frontend == "frames":
        x = batch["frames"].astype(dt)
    elif cfg.frontend == "vlm":
        tok = params["embed"]["table"].astype(dt)[batch["tokens"]]
        x = jnp.concatenate([batch["patches"].astype(dt), tok], axis=1)
    else:
        raise ValueError(cfg.frontend)
    return constrain(x, "hidden")


def _head(params, cfg, x):
    if cfg.tie_embeddings:
        w = params["embed"]["table"].astype(x.dtype).T
    else:
        w = params["head"].astype(x.dtype)
    return (x @ w).astype(jnp.float32)


def forward_hidden(params, cfg: ModelConfig, batch):
    """Final-norm hidden states (B, S_total, D); aux (MoE balance) 2nd."""
    x = _embed_input(params, cfg, batch)

    if cfg.block_type == "attn":
        x, aux = _run_stack(params["blocks"], x, lambda p, h: _dense_block_apply(p, h, cfg), cfg)
    elif cfg.block_type == "mamba2":
        x, aux = _run_stack(params["blocks"], x, lambda p, h: _mamba_block_apply(p, h, cfg), cfg)
    elif cfg.block_type == "rwkv6":
        x, aux = _run_stack(params["blocks"], x, lambda p, h: _rwkv_block_apply(p, h, cfg), cfg)
    elif cfg.block_type == "zamba_hybrid":
        shared = params["shared"]

        def group_body(p, h):
            h, a = _run_stack(p, h, lambda q, hh: _mamba_block_apply(q, hh, cfg), cfg)
            h, a2 = _dense_block_apply(shared, h, cfg)
            return h, a + a2

        x, aux = _run_stack(params["blocks"], x, group_body, cfg)
    else:
        raise ValueError(cfg.block_type)

    x = norm_apply(cfg.norm, params["final_ln"], x)
    return x, aux


def forward(params, cfg: ModelConfig, batch):
    """Full-sequence logits (B, S_total, V); aux (MoE balance) as 2nd output."""
    x, aux = forward_hidden(params, cfg, batch)
    return _head(params, cfg, x), aux


def _ce_sum(params, cfg, x, labels):
    """Σ cross-entropy over a (B, S, D) slab (fp32 logits)."""
    logits = constrain(_head(params, cfg, x), "logits")
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return (logz - ll).sum()


def loss_fn(params, cfg: ModelConfig, batch, aux_weight: float = 0.01):
    """Mean next-token cross entropy (+ MoE load-balance aux).

    With cfg.loss_chunk > 0 and S divisible, the vocab projection + CE runs
    chunked over the sequence (scan + remat), so the (B, S, V) fp32 logits
    tensor is never materialised — at 150k vocab × 1M tokens that is the
    difference between ~300 MB and ~2.5 TB of per-device temps
    (perf iteration #2, EXPERIMENTS.md §Perf).
    """
    x, aux = forward_hidden(params, cfg, batch)
    labels = batch["labels"]
    if cfg.frontend == "vlm":  # loss only over the text segment (last S_text)
        x = x[:, -labels.shape[1] :]
    b, s, _ = x.shape
    chunk = cfg.loss_chunk
    if chunk and s % chunk == 0 and s > chunk:
        xc = x.reshape(b, s // chunk, chunk, -1).swapaxes(0, 1)
        lc = labels.reshape(b, s // chunk, chunk).swapaxes(0, 1)
        body = jax.checkpoint(lambda c, xs: (c + _ce_sum(params, cfg, xs[0], xs[1]), None))
        total, _ = jax.lax.scan(body, jnp.float32(0) + vzero(x), (xc, lc))
        loss = total / (b * s)
    else:
        loss = _ce_sum(params, cfg, x, labels) / (b * s)
    return loss + aux_weight * aux, {"ce": loss, "aux": aux}


# =============================================================== serving ====
def prefill_step(params, cfg: ModelConfig, batch, cache_len: int):
    """Process the prompt; return (last-position logits (B, V), decode cache)."""
    x = _embed_input(params, cfg, batch)

    if cfg.block_type == "attn":
        body = _maybe_remat(lambda p, h: _dense_block_prefill(p, h, cfg, cache_len), cfg)

        def step(h, p):
            y, cache = body(p, h)
            return constrain(y, "hidden"), cache

        x, caches = jax.lax.scan(step, x, params["blocks"])
    elif cfg.block_type in ("mamba2", "rwkv6"):
        x, caches = _recurrent_prefill(params["blocks"], x, cfg)
    elif cfg.block_type == "zamba_hybrid":
        x, caches = _zamba_prefill(params, x, cfg, cache_len)
    else:
        raise ValueError(cfg.block_type)

    x = norm_apply(cfg.norm, params["final_ln"], x[:, -1:])
    return _head(params, cfg, x)[:, 0], caches


def _recurrent_prefill(stacked, x, cfg):
    """SSM/RWKV prefill: run the parallel form AND extract the final state by
    replaying the last position through the decode step (cheap, exact)."""
    if cfg.block_type == "mamba2":
        body = _maybe_remat(lambda p, h: _mamba_state_prefill(p, h, cfg), cfg)
    else:
        body = _maybe_remat(lambda p, h: _rwkv_state_prefill(p, h, cfg), cfg)

    def step(h, p):
        y, state = body(p, h)
        return y, state

    return jax.lax.scan(step, x, stacked)


def _mamba_state_prefill(p, x, cfg):
    """Forward + final SSD state. Uses the naive-step identity: the state after
    L steps equals a decode pass over the (already computed) last conv window —
    we recompute the recurrence on the final chunk only."""
    y = x + m2.mamba2_apply(p["mix"], norm_apply(cfg.norm, p["ln"], x), cfg)
    state = _mamba_final_state(p["mix"], norm_apply(cfg.norm, p["ln"], x), cfg)
    return y, state


def _mamba_final_state(p, xin, cfg):
    s = cfg.ssm
    d_inner, h, p_dim, n, g = m2._dims(cfg)
    bsz, l, _ = xin.shape
    z, xbc, dt = m2._split_proj(p, xin, cfg)
    conv_tail = xbc[:, -(s.conv_width - 1) :, :]
    xbc = jax.nn.silu(m2._causal_conv(xbc, p["conv_w"], p["conv_b"]))
    xi, b_, c_ = m2._conv_split(xbc, cfg)
    xh = xi.reshape(bsz, l, h, p_dim)
    rep = h // g
    bh = jnp.repeat(b_.reshape(bsz, l, g, n), rep, axis=2)
    dtf = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])
    ld = dtf * a[None, None, :]
    cum = jnp.cumsum(ld, axis=1)                       # (B, L, H)
    w_k = jnp.exp(cum[:, -1:, :] - cum) * dtf          # decay from k to end
    ssm = jnp.einsum("blhn,blhp,blh->bhnp", bh, xh, w_k.astype(xh.dtype),
                     preferred_element_type=jnp.float32)
    return {"conv": conv_tail, "ssm": ssm}


def _rwkv_state_prefill(p, x, cfg):
    h1 = norm_apply("layernorm", p["ln1"], x)
    out, wkv = rk.timemix_apply(p["tm"], h1, rk.shift_tokens(h1), cfg, return_state=True)
    x1 = x + out
    h2 = norm_apply("layernorm", p["ln2"], x1)
    x2 = x1 + rk.channelmix_apply(p["cm"], h2, rk.shift_tokens(h2))
    state = {"tm_shift": h1[:, -1], "cm_shift": h2[:, -1], "wkv": wkv}
    return x2, state


def _zamba_prefill(params, x, cfg, cache_len):
    shared = params["shared"]
    body_m = _maybe_remat(lambda p, h: _mamba_state_prefill(p, h, cfg), cfg)
    body_s = _maybe_remat(lambda h: _dense_block_prefill(shared, h, cfg, cache_len), cfg)

    def group(h, p):
        h, mstates = jax.lax.scan(lambda hh, q: body_m(q, hh), h, p)
        h, kv = body_s(h)
        return h, {"mamba": mstates, "shared_kv": kv}

    return jax.lax.scan(group, x, params["blocks"])


def init_decode_cache(cfg: ModelConfig, batch: int, cache_len: int):
    """Zero caches shaped for decode_step (pre-allocated to cache_len)."""
    dt = _cdt(cfg)
    l = cfg.num_layers

    def stack(tree, n):
        return jax.tree.map(lambda a: jnp.zeros((n,) + a.shape, a.dtype), tree)

    if cfg.block_type == "attn":
        if cfg.attn_type == "mla":
            m = cfg.mla
            one = {
                "c_kv": jnp.zeros((batch, cache_len, m.kv_lora_rank), dt),
                "k_rope": jnp.zeros((batch, cache_len, m.qk_rope_dim), dt),
            }
        else:
            one = {
                "k": jnp.zeros((batch, cache_len, cfg.num_kv_heads, cfg.head_dim), dt),
                "v": jnp.zeros((batch, cache_len, cfg.num_kv_heads, cfg.head_dim), dt),
            }
        return stack(one, l)
    if cfg.block_type == "mamba2":
        return stack(m2.mamba2_init_state(cfg, batch, dt), l)
    if cfg.block_type == "rwkv6":
        return stack(rk.rwkv6_init_state(cfg, batch, dt), l)
    if cfg.block_type == "zamba_hybrid":
        groups = cfg.num_layers // cfg.share_every
        mamba = stack(stack(m2.mamba2_init_state(cfg, batch, dt), cfg.share_every), groups)
        kv = stack(
            {
                "k": jnp.zeros((batch, cache_len, cfg.num_kv_heads, cfg.head_dim), dt),
                "v": jnp.zeros((batch, cache_len, cfg.num_kv_heads, cfg.head_dim), dt),
            },
            groups,
        )
        return {"mamba": mamba, "shared_kv": kv}
    raise ValueError(cfg.block_type)


def decode_step(params, cfg: ModelConfig, cache, tokens, pos):
    """One token for every sequence. tokens: (B, 1) int32 (or frames (B,1,D));
    pos: (B,) current write index. Returns (logits (B, V), new cache)."""
    dt = _cdt(cfg)
    if cfg.frontend == "frames":
        x = tokens.astype(dt) if tokens.ndim == 3 else None
        assert x is not None, "frames frontend decodes from frame embeddings"
    else:
        x = params["embed"]["table"].astype(dt)[tokens]

    if cfg.block_type == "attn":
        def step(h, inp):
            p, c = inp
            y, c2 = _dense_block_decode(p, h, cfg, c, pos)
            return y, c2

        x, new_cache = jax.lax.scan(step, x, (params["blocks"], cache))
    elif cfg.block_type == "mamba2":
        def step(h, inp):
            p, c = inp
            return _mamba_block_decode(p, h, cfg, c)

        x, new_cache = jax.lax.scan(step, x, (params["blocks"], cache))
    elif cfg.block_type == "rwkv6":
        def step(h, inp):
            p, c = inp
            return _rwkv_block_decode(p, h, cfg, c)

        x, new_cache = jax.lax.scan(step, x, (params["blocks"], cache))
    elif cfg.block_type == "zamba_hybrid":
        shared = params["shared"]

        def group(h, inp):
            p, c = inp

            def inner(hh, q_c):
                q, cc = q_c
                return _mamba_block_decode(q, hh, cfg, cc)

            h, mstates = jax.lax.scan(inner, h, (p, c["mamba"]))
            h, kv = _dense_block_decode(shared, h, cfg, c["shared_kv"], pos)
            return h, {"mamba": mstates, "shared_kv": kv}

        x, new_cache = jax.lax.scan(group, x, (params["blocks"], cache))
    else:
        raise ValueError(cfg.block_type)

    x = norm_apply(cfg.norm, params["final_ln"], x)
    return _head(params, cfg, x)[:, 0], new_cache

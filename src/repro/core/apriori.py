"""Distributed level-wise Apriori — the paper's algorithm (§3.3) on a TPU mesh.

Per level k:
  driver (host):  candidate generation from F_{k-1}   (core.candidates)
  Map (device):   local support counting per transaction shard
                  (kernels.support_count — the MXU containment matmul)
  Reduce:         lax.psum of the count vector over the data axes
  driver (host):  prune by min support -> F_k

The candidate axis is additionally sharded over the 'model' mesh axis, a 2-D
decomposition of the paper's 1-D map phase (DESIGN.md §5). Padding rules:
transactions pad with zero rows (inert), candidates pad with |c| = -1 rows
(never match). Counting is exact (int32).

Two device representations of the transaction store (DESIGN.md §4):
  * ``dense``  — {0,1} int8 (N, I); counting is the MXU containment matmul.
  * ``packed`` — uint32 bitsets (N, ceil(I/32)); counting is the VPU
    bitwise-AND containment kernel, 8–32× less HBM traffic per cell.
The DB is packed + device-placed ONCE (``place_db``), and a level's
candidate passes run as a depth-2 pipeline — the host packs and dispatches
pass p+1 while the device counts pass p, blocking on a pass only after its
successor is in flight (and on the last one when the prune needs the
values).
"""

from __future__ import annotations

import dataclasses
import math
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core import candidates as cand_mod
from repro.core import itemsets as enc
from repro.core.mapreduce import MapReduceJob, mapreduce, pad_rows_to_shards
from repro.kernels import ops as kops


@dataclasses.dataclass(frozen=True)
class AprioriConfig:
    min_support: float = 0.01          # fraction of |DB|; min_count = ceil(frac * N)
    max_k: int = 8                     # maximum itemset size to mine
    count_impl: str = "auto"           # auto | jnp | pallas | pallas_interpret
    representation: str = "dense"      # dense {0,1} int8 | packed uint32 bitsets
    data_axes: tuple = ("data",)       # mesh axes sharding the transaction rows
    model_axis: str | None = None      # mesh axis sharding the candidate rows
    candidate_pad: int = 256           # K padded to a multiple (jit bucket + divisibility)
    max_candidates_per_pass: int = 1 << 16  # split huge candidate sets across passes
    use_naive_paper_map: bool = False  # paper's 'all subsets' enumeration (small I only)
    operand_dtype: str = "bf16"        # dense kernel operand mode (bf16 MXU / int8)
    packed_mode: str = "and_cmp"       # packed kernel containment mode (| popcount)


@dataclasses.dataclass
class AprioriResult:
    """k -> (itemsets (F_k, k) int32, supports (F_k,) int64).

    ``fault_report`` is populated only by the fault-tolerant SON executor
    (``streaming.mine_son_streamed(fault=...)``): what the retrying work
    queue actually did — retries, speculative copies, skipped partitions.
    """

    levels: dict
    num_transactions: int
    min_count: int
    fault_report: object | None = dataclasses.field(default=None, compare=False)
    # the full pre-prune SON phase-2 union with exact counts, k -> (cands,
    # counts) — populated only by mine_son_streamed(collect_union=True); the
    # raw material of the incremental count cache (DESIGN.md §15)
    union_counts: dict | None = dataclasses.field(default=None, compare=False)

    def frequent(self, k: int) -> np.ndarray:
        return self.levels[k][0] if k in self.levels else np.zeros((0, k), np.int32)

    def support(self, itemset) -> int:
        k = len(itemset)
        if k not in self.levels:
            return 0
        sets, sup = self.levels[k]
        hit = np.all(sets == np.asarray(sorted(itemset), np.int32)[None, :], axis=1)
        idx = np.flatnonzero(hit)
        return int(sup[idx[0]]) if idx.size else 0

    def as_dict(self) -> dict:
        out = {}
        for k, (sets, sup) in self.levels.items():
            for row, s in zip(sets, sup):
                out[tuple(int(x) for x in row)] = int(s)
        return out

    @property
    def total_frequent(self) -> int:
        return sum(v[0].shape[0] for v in self.levels.values())


def _pad_bucket(k: int, quantum: int) -> int:
    """Pad K to a power-of-two-ish bucket (bounds jit recompiles to O(log K))."""
    k = max(k, 1)
    bucket = quantum
    while bucket < k:
        bucket *= 2
    return bucket


def make_count_step(
    mesh: jax.sharding.Mesh | None,
    cfg: AprioriConfig,
) -> Callable:
    """Build the jit'd Map/Reduce support-count step.

    Dense:  fn(T (N,I) int8,  C (Kp,I) int8,  lengths (Kp,) int32)
    Packed: fn(T (N,W) uint32, C (Kp,W) uint32, lengths (Kp,) int32)
    with T sharded over data_axes -> counts (Kp,) int32, replicated over the
    data axes, sharded over model_axis. The sharded path is identical for
    both representations — P(data_axes, None) over rows, whatever the row
    payload is (DESIGN.md §2).
    """
    if cfg.representation == "packed":

        def local_count(t, c, ln):
            return kops.support_count_packed(
                t, c, ln, impl=cfg.count_impl, mode=cfg.packed_mode
            )

    elif cfg.representation == "dense":

        def local_count(t, c, ln):
            return kops.support_count(
                t, c, ln, impl=cfg.count_impl, operand_dtype=cfg.operand_dtype
            )

    else:
        raise ValueError(f"representation must be dense|packed, got {cfg.representation!r}")

    if mesh is None or math.prod(mesh.shape.values()) == 1:
        return jax.jit(local_count)

    job = MapReduceJob(map_fn=local_count, reduce_axes=tuple(cfg.data_axes))
    in_specs = (
        P(cfg.data_axes, None),          # transactions: HDFS-block row partition
        P(cfg.model_axis, None),         # candidates: 2-D decomposition over 'model'
        P(cfg.model_axis),
    )
    return mapreduce(job, mesh, in_specs=in_specs, out_specs=P(cfg.model_axis))


def place_db(t_np: np.ndarray, cfg: AprioriConfig, mesh) -> jax.Array:
    """Encode + device-place the transaction store ONCE for the whole mine.

    Packs to uint32 bitsets when ``cfg.representation == "packed"``, pads
    rows to the data-shard count (zero rows are inert for both
    representations), and row-shards over the data axes — the HDFS block
    layout of the paper, P(data_axes, None) regardless of row payload.
    """
    store = enc.pack_bits(t_np) if cfg.representation == "packed" else t_np
    if mesh is None:
        return jnp.asarray(store)
    data_shards = math.prod(mesh.shape[a] for a in cfg.data_axes)
    t_pad, _ = pad_rows_to_shards(store, data_shards)
    return jax.device_put(t_pad, NamedSharding(mesh, P(cfg.data_axes, None)))


def _candidate_quantum(cfg: AprioriConfig, mesh) -> int:
    """Pad quantum for the candidate axis: at least ``candidate_pad``, and a
    multiple of the model-shard count so every bucket splits evenly over
    P(model_axis) (``_pad_bucket`` only doubles, which preserves the
    divisibility — e.g. 3 shards with pad 256 give buckets 258, 516, ...)."""
    model_shards = mesh.shape[cfg.model_axis] if (mesh is not None and cfg.model_axis) else 1
    quantum = max(cfg.candidate_pad, model_shards)
    return ((quantum + model_shards - 1) // model_shards) * model_shards


def _place_candidates(chunk: np.ndarray, kp: int, num_items: int, cfg: AprioriConfig, mesh):
    """Encode one candidate pass to its device tensors: (Kp, ·) itemset rows
    (dense int8 or packed uint32) zero-padded to the bucket, plus the
    lengths vector with |c| = -1 padding sentinels, sharded P(model_axis)
    when a mesh is given. Shared by the in-memory and streaming drivers."""
    if cfg.representation == "packed":
        c_host = np.zeros((kp, enc.packed_words(num_items)), dtype=np.uint32)
        c_host[: chunk.shape[0]] = enc.itemsets_to_packed(chunk, num_items)
    else:
        c_host = np.zeros((kp, num_items), dtype=np.int8)
        c_host[: chunk.shape[0]] = enc.itemsets_to_dense(chunk, num_items)
    lengths = np.full(kp, -1, dtype=np.int32)
    lengths[: chunk.shape[0]] = chunk.shape[1]
    if mesh is not None:
        c_dev = jax.device_put(c_host, NamedSharding(mesh, P(cfg.model_axis, None)))
        len_dev = jax.device_put(lengths, NamedSharding(mesh, P(cfg.model_axis)))
    else:
        c_dev, len_dev = jnp.asarray(c_host), jnp.asarray(lengths)
    return c_dev, len_dev


def _count_level(count_step, t_dev, cand_sets: np.ndarray, num_items: int, cfg: AprioriConfig, mesh):
    """Count supports for one level's candidates, in passes, padded/bucketed.

    Passes form a depth-2 pipeline: the host builds and device-places the
    candidate tensors for pass p+1 while the device counts pass p, and only
    syncs a pass once its successor is dispatched (the last sync happens when
    the caller's prune needs the values, DESIGN.md §5). The bounded depth
    keeps at most two passes of candidate tensors live on device, preserving
    the memory bound ``max_candidates_per_pass`` exists to provide.
    """
    k_total = cand_sets.shape[0]
    quantum = _candidate_quantum(cfg, mesh)
    counts = np.zeros(k_total, dtype=np.int64)
    pending = []

    def _drain(limit):
        while len(pending) > limit:
            start, m, out = pending.pop(0)
            counts[start : start + m] = np.asarray(out)[:m]

    for start in range(0, k_total, cfg.max_candidates_per_pass):
        chunk = cand_sets[start : start + cfg.max_candidates_per_pass]
        kp = _pad_bucket(chunk.shape[0], quantum)
        c_dev, len_dev = _place_candidates(chunk, kp, num_items, cfg, mesh)
        pending.append((start, chunk.shape[0], count_step(t_dev, c_dev, len_dev)))
        _drain(limit=1)   # sync pass p only once pass p+1 is in flight
    _drain(limit=0)
    return counts


def run_level_loop(
    count_fn: Callable[[np.ndarray], np.ndarray],
    n: int,
    num_items: int,
    cfg: AprioriConfig,
    checkpoint_cb: Callable | None = None,
    resume_state: dict | None = None,
    obs=None,
) -> AprioriResult:
    """The driver's level loop, abstracted over HOW candidates are counted.

    ``count_fn(cand_sets (K, k) int32, level_k) -> supports (K,) int``. The
    level index lets a counting backend carry per-level resume state (the
    streamed driver's mid-level chunk cursor, DESIGN.md §11). Candidate
    generation, min-support pruning, checkpointing and termination live
    here — ``mine`` (whole DB device-resident) and
    ``core.streaming.mine_streamed`` (per-level chunk streaming over an
    on-disk store) both instantiate it, so the two drivers cannot drift.

    Determinism contract: given the same DB and config, the candidate array
    passed to ``count_fn`` for level k is a pure function of F_{k-1}
    (``generate_candidates`` is np.unique-canonical) — which is what lets a
    resumed mine regenerate the in-progress level's candidates instead of
    persisting them.

    ``obs`` (an :class:`repro.obs.MiningObs`) records per-level job counters
    (candidates generated / frequent survivors) and the candidate-generation
    phase time — observation only, the mined dicts are identical with obs
    on/off.
    """
    min_count = max(1, math.ceil(cfg.min_support * n))
    levels = dict(resume_state["levels"]) if resume_state else {}
    start_k = resume_state["next_k"] if resume_state else 1

    if start_k <= 1:
        # level 1: supports of singletons — the same count path (uniform Map/Reduce)
        t_gen0 = time.perf_counter()
        singles = enc.singleton_itemsets(num_items)
        if obs is not None:
            obs.on_level_start(1, singles.shape[0])
            obs.add_phase("candidate_gen", t_gen0, time.perf_counter())
        sup1 = count_fn(singles, 1)
        keep = sup1 >= min_count
        levels[1] = (singles[keep], sup1[keep])
        if obs is not None:
            obs.on_level_end(1, int(keep.sum()))
        if checkpoint_cb:
            checkpoint_cb(1, levels)
        start_k = 2

    for k in range(start_k, cfg.max_k + 1):
        prev_sets = levels.get(k - 1, (np.zeros((0, k - 1), np.int32),))[0]
        if prev_sets.shape[0] < k:   # cannot form a k-itemset
            break
        t_gen0 = time.perf_counter()
        if cfg.use_naive_paper_map:
            # paper §3.3: enumerate every k-subset of the (frequent) item universe
            freq_items = levels[1][0].ravel()
            combos = cand_mod.all_k_subsets_of_universe(freq_items.size, k)
            cands = freq_items[combos]
        else:
            cands = cand_mod.generate_candidates(prev_sets)
        if cands.shape[0] == 0:
            break
        if obs is not None:
            obs.on_level_start(k, cands.shape[0])
            obs.add_phase("candidate_gen", t_gen0, time.perf_counter())
        sup = count_fn(cands, k)
        keep = sup >= min_count
        if obs is not None:
            obs.on_level_end(k, int(keep.sum()))
        if not keep.any():
            break
        levels[k] = (cands[keep], sup[keep])
        if checkpoint_cb:
            checkpoint_cb(k, levels)

    return AprioriResult(levels=levels, num_transactions=n, min_count=min_count)


def mine(
    transactions_dense,
    cfg: AprioriConfig = AprioriConfig(),
    mesh: jax.sharding.Mesh | None = None,
    checkpoint_cb: Callable | None = None,
    resume_state: dict | None = None,
) -> AprioriResult:
    """Level-wise distributed Apriori over a dense {0,1} transaction matrix.

    checkpoint_cb(level_k, levels_dict): called after each completed level —
    the mining checkpoint hook (restartable via ``resume_state`` =
    {'levels': ..., 'next_k': ...}, see distributed.fault_tolerance).
    """
    t_np = np.asarray(transactions_dense, dtype=np.int8)
    n, num_items = t_np.shape

    # --- encode + place the DB once: row-sharded over the data axes (HDFS
    # layout); packed uint32 bitsets stay device-resident for the whole loop
    t_dev = place_db(t_np, cfg, mesh)
    count_step = make_count_step(mesh, cfg)

    def count_fn(cand_sets, level_k):
        return _count_level(count_step, t_dev, cand_sets, num_items, cfg, mesh)

    return run_level_loop(count_fn, n, num_items, cfg, checkpoint_cb, resume_state)

# The paper's primary contribution: distributed level-wise Apriori mining
# expressed as Map/Combine/Reduce over jax.shard_map + lax collectives.
from repro.core.itemsets import (
    dense_from_lists,
    itemsets_to_dense,
    pack_bits,
    unpack_bits,
    singleton_itemsets,
)
from repro.core.candidates import generate_candidates, rows_isin
from repro.core.mapreduce import MapReduceJob, mapreduce, hierarchical_psum
from repro.core.apriori import AprioriConfig, AprioriResult, mine, make_count_step
from repro.core.son import mine_son
from repro.core.streaming import (
    count_supports_streamed,
    count_union_streamed,
    mine_son_streamed,
    mine_streamed,
)
from repro.core.incremental import (
    CountCache,
    DeltaReport,
    build_count_cache,
    load_count_cache,
    mine_delta,
)
from repro.core.rules import extract_rules, Rule
